//! Regression pins for the allocation-policy refactor: the policy layer
//! must reproduce the pre-refactor offline-theory behavior
//! bit-identically. Analytic goldens pin the constructor outputs
//! (allocation vectors and refresh periods as exact integers), and
//! end-to-end runs pin that routing the trainer through an explicit
//! [`FixedPolicy`] changes nothing about the trajectory. The adaptive
//! path is pinned on its determinism contract: without pooled
//! wall-clock cost samples the decision stream is a pure function of
//! the telemetry stream, so identical runs stay bitwise identical.

use std::sync::Arc;

use dmlmc::config::ExperimentConfig;
use dmlmc::coordinator::{DelayedSchedule, Method, Trainer, TrainerBuilder};
use dmlmc::mlmc::LevelAllocation;
use dmlmc::obs::EstimatorStats;
use dmlmc::policy::{from_config, AllocationPolicy, FixedPolicy};

fn smoke_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::smoke();
    cfg.train.steps = 10;
    cfg.train.eval_every = 5;
    cfg
}

/// Analytic goldens for the paper allocation
/// `N_l = ceil(2^{-(b+c)l/2} / Z * N).max(1)`, worked out by hand — if
/// any float op inside the constructor changes, these integers move.
#[test]
fn paper_allocation_matches_hand_computed_goldens() {
    let cases: &[(usize, usize, f64, f64, &[usize])] = &[
        (6, 1024, 1.8, 1.0, &[637, 242, 92, 35, 14, 5, 2]),
        (6, 64, 1.8, 1.0, &[40, 16, 6, 3, 1, 1, 1]),
        (4, 256, 1.8, 1.0, &[161, 61, 24, 9, 4]),
        (6, 1024, 2.0, 1.0, &[663, 235, 83, 30, 11, 4, 2]),
        (3, 32, 1.8, 1.0, &[21, 8, 3, 2]),
    ];
    for &(lmax, n, b, c, want) in cases {
        let a = LevelAllocation::paper(lmax, n, b, c);
        assert_eq!(
            a.n_per_level, want,
            "paper({lmax}, {n}, {b}, {c})"
        );
    }
    let w = LevelAllocation::from_weights(&[3.0, 1.0, 0.0], 100);
    assert_eq!(w.n_per_level, vec![75, 25, 1]);
}

/// Analytic goldens for the delayed-refresh periods `⌊2^{dl}⌋.max(1)`.
#[test]
fn delayed_schedule_matches_hand_computed_goldens() {
    let cases: &[(f64, &[u64])] = &[
        (0.5, &[1, 1, 2, 2, 4, 5, 8]),
        (1.0, &[1, 2, 4, 8, 16, 32, 64]),
        (1.5, &[1, 2, 8, 22, 64, 181, 512]),
    ];
    for &(d, want) in cases {
        assert_eq!(DelayedSchedule::new(6, d).periods(), want, "d = {d}");
    }
}

/// [`FixedPolicy::initial`] makes the exact constructor calls the
/// trainer used to make inline, over a grid of configs.
#[test]
fn fixed_policy_initial_equals_direct_constructors_over_a_grid() {
    for &(b, d, n) in &[
        (1.8, 1.0, 1024usize),
        (1.8, 0.5, 64),
        (2.0, 1.5, 256),
        (1.9, 1.0, 32),
    ] {
        for lmax in [3usize, 4, 6] {
            let mut cfg = ExperimentConfig::smoke();
            cfg.mlmc.b = b;
            cfg.mlmc.d = d;
            cfg.mlmc.n_effective = n;
            let dec = FixedPolicy::from_config(&cfg).initial(lmax);
            assert_eq!(
                dec.allocation,
                LevelAllocation::paper(lmax, n, b, cfg.mlmc.c),
                "b={b} d={d} n={n} lmax={lmax}"
            );
            assert_eq!(
                dec.schedule.periods(),
                DelayedSchedule::new(lmax, d).periods(),
                "b={b} d={d} n={n} lmax={lmax}"
            );
            assert_eq!(dec.n_effective, n);
        }
    }
}

/// No amount of telemetry moves a fixed decision — `observe` is the
/// identity even under a stream that would reallocate any adaptive
/// policy (steep variance growth, inverted costs).
#[test]
fn fixed_policy_ignores_heavy_telemetry() {
    let cfg = smoke_cfg();
    let policy = FixedPolicy::from_config(&cfg);
    let dec = policy.initial(cfg.problem.lmax);
    let mut est = EstimatorStats::new(cfg.problem.lmax + 1);
    for l in 0..=cfg.problem.lmax {
        for step in 0..32u64 {
            est.record_refresh(l, step, 16, &[1000.0 * (l as f32 + 1.0)]);
            est.record_cost(l, 1e-3 / (l as f64 + 1.0));
        }
    }
    let out = policy.observe(&est.observe(32), &dec);
    assert!(out.same_as(&dec));
    assert_eq!(out.allocation, dec.allocation);
}

/// End-to-end bit-identity: the default build (policy from config), an
/// explicitly injected [`FixedPolicy`] and the pre-refactor entry point
/// [`Trainer::from_config`] all produce the same trajectory, losses and
/// layout, bit for bit, for every method.
#[test]
fn explicit_fixed_policy_runs_bit_identical_to_default() {
    let cfg = smoke_cfg();
    for method in Method::all() {
        let mut legacy = Trainer::from_config(&cfg, method, 3).unwrap();
        let legacy_curve = legacy.run().unwrap();

        let mut injected = TrainerBuilder::new(&cfg)
            .method(method)
            .seed(3)
            .policy(Arc::new(FixedPolicy::from_config(&cfg)))
            .build()
            .unwrap();
        let injected_curve = injected.run().unwrap();

        assert_eq!(injected.policy_name(), "fixed");
        assert_eq!(injected.adaptations(), 0, "fixed never adapts");
        assert_eq!(legacy.chunks_per_level(), injected.chunks_per_level());
        assert_eq!(legacy.schedule_periods(), injected.schedule_periods());
        for (a, b) in legacy_curve.points.iter().zip(&injected_curve.points) {
            assert_eq!(a.step, b.step);
            assert_eq!(
                a.loss.to_bits(),
                b.loss.to_bits(),
                "{method:?} loss drifted at step {}",
                a.step
            );
        }
        for (a, b) in legacy.params.iter().zip(&injected.params) {
            assert_eq!(a.to_bits(), b.to_bits(), "{method:?} params drifted");
        }
    }
}

/// The config-driven dispatch agrees with the injected policy: an
/// `[adaptive] enabled = false` config routes through `FixedPolicy`.
#[test]
fn config_dispatch_defaults_to_fixed() {
    let cfg = smoke_cfg();
    assert!(!cfg.adaptive.enabled);
    let policy = from_config(&cfg);
    assert_eq!(policy.name(), "fixed");
    let dec = policy.initial(cfg.problem.lmax);
    assert_eq!(
        dec.allocation,
        LevelAllocation::paper(
            cfg.problem.lmax,
            cfg.mlmc.n_effective,
            cfg.mlmc.b,
            cfg.mlmc.c
        )
    );
}

/// Determinism contract of the adaptive path: with sequential dispatch
/// (no pooled wall-clock cost samples) the decision stream is a pure
/// function of the telemetry stream, so two identical runs — losses,
/// parameters, adopted decision, adaptation count — stay bitwise equal.
#[test]
fn adaptive_runs_without_pool_are_bitwise_reproducible() {
    let mut cfg = smoke_cfg();
    cfg.train.steps = 16;
    cfg.adaptive.enabled = true;
    cfg.adaptive.adapt_every = 4;
    cfg.adaptive.min_refreshes = 1;
    let run = || {
        let mut tr = TrainerBuilder::new(&cfg)
            .method(Method::Dmlmc)
            .seed(7)
            .without_local_pool()
            .build()
            .unwrap();
        let curve = tr.run().unwrap();
        (curve, tr)
    };
    let (curve_a, tr_a) = run();
    let (curve_b, tr_b) = run();
    assert_eq!(tr_a.policy_name(), "adaptive");
    assert_eq!(tr_a.adaptations(), tr_b.adaptations());
    assert_eq!(
        tr_a.decision().allocation.n_per_level,
        tr_b.decision().allocation.n_per_level
    );
    assert_eq!(tr_a.schedule_periods(), tr_b.schedule_periods());
    for (a, b) in curve_a.points.iter().zip(&curve_b.points) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {}", a.step);
    }
    for (a, b) in tr_a.params.iter().zip(&tr_b.params) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    // Whatever the policy adopted still satisfies the hard invariants:
    // level 0 refreshes every step, every level keeps >= 1 sample, and
    // the effective batch size is conserved.
    assert_eq!(tr_a.schedule_periods()[0], 1);
    assert!(tr_a.decision().allocation.n_per_level.iter().all(|&n| n >= 1));
    assert_eq!(tr_a.decision().n_effective, cfg.mlmc.n_effective);
}
