//! Runtime integration: load real artifacts, compile on PJRT, execute,
//! and check structural/numeric sanity of every entry-point kind.

mod common;

use dmlmc::rng::{brownian::Purpose, BrownianSource};
use dmlmc::runtime::{GradBackend, XlaRuntime};

fn dw_for(rt: &XlaRuntime, level: usize, batch: usize) -> Vec<f32> {
    let p = rt.manifest().problem;
    BrownianSource::new(7).increments(
        Purpose::Grad,
        0,
        level as u32,
        0,
        batch,
        p.n_steps(level),
        p.dt(level),
    )
}

fn params(rt: &XlaRuntime) -> Vec<f32> {
    rt.manifest().load_init_params().unwrap()
}

#[test]
fn loads_and_compiles_hot_path() {
    let dir = require_artifacts!();
    let rt = XlaRuntime::load(&dir).unwrap();
    assert_eq!(rt.platform().to_lowercase(), "cpu");
    rt.warmup().unwrap();
}

#[test]
fn grad_coupled_every_level_is_finite_and_nonzero() {
    let dir = require_artifacts!();
    let rt = XlaRuntime::load(&dir).unwrap();
    let p = params(&rt);
    for level in 0..=rt.manifest().problem.lmax {
        let dw = dw_for(&rt, level, rt.grad_chunk(level));
        let (loss, grad) = rt.grad_coupled_chunk(level, &p, &dw).unwrap();
        assert!(loss.is_finite(), "level {level} loss");
        assert_eq!(grad.len(), rt.n_params());
        assert!(
            grad.iter().all(|g| g.is_finite()),
            "level {level} has non-finite grads"
        );
        assert!(
            grad.iter().any(|&g| g != 0.0),
            "level {level} grad identically zero"
        );
    }
}

#[test]
fn grad_naive_and_loss_eval_work() {
    let dir = require_artifacts!();
    let rt = XlaRuntime::load(&dir).unwrap();
    let p = params(&rt);
    let lmax = rt.manifest().problem.lmax;

    let dw = dw_for(&rt, lmax, rt.naive_chunk());
    let (loss, grad) = rt.grad_naive_chunk(&p, &dw).unwrap();
    assert!(loss > 0.0, "naive loss must be a positive mean square");
    assert!(grad.iter().any(|&g| g != 0.0));

    let dw_eval = BrownianSource::new(9).increments(
        Purpose::Eval,
        0,
        lmax as u32,
        0,
        rt.eval_chunk(),
        rt.manifest().problem.n_steps(lmax),
        rt.manifest().problem.dt(lmax),
    );
    let eval = rt.loss_eval_chunk(&p, &dw_eval).unwrap();
    assert!(eval > 0.0 && eval.is_finite());
}

#[test]
fn executions_are_deterministic() {
    let dir = require_artifacts!();
    let rt = XlaRuntime::load(&dir).unwrap();
    let p = params(&rt);
    let dw = dw_for(&rt, 2, rt.grad_chunk(2));
    let (l1, g1) = rt.grad_coupled_chunk(2, &p, &dw).unwrap();
    let (l2, g2) = rt.grad_coupled_chunk(2, &p, &dw).unwrap();
    assert_eq!(l1, l2);
    assert_eq!(g1, g2);
}

#[test]
fn diag_entries_execute() {
    let dir = require_artifacts!();
    let rt = XlaRuntime::load(&dir).unwrap();
    let p = params(&rt);
    let level = 1;
    let probm = rt.manifest().problem;
    let dw = BrownianSource::new(3).increments(
        Purpose::Diagnostic,
        0,
        level as u32,
        0,
        rt.diag_chunk(),
        probm.n_steps(level),
        probm.dt(level),
    );
    let norms = rt.grad_norms_chunk(level, &p, &dw).unwrap();
    assert_eq!(norms.len(), rt.diag_chunk());
    assert!(norms.iter().all(|&v| v >= 0.0 && v.is_finite()));

    let mut p2 = p.clone();
    for v in &mut p2 {
        *v += 0.01;
    }
    let smooth = rt.smoothness_chunk(level, &p, &p2, &dw).unwrap();
    assert_eq!(smooth.len(), rt.diag_chunk());
    assert!(smooth.iter().all(|&v| v >= 0.0 && v.is_finite()));

    let (fine, coarse) = rt.path_eval(level, &dw).unwrap();
    assert_eq!(fine.len(), rt.diag_chunk());
    assert_eq!(coarse.len(), rt.diag_chunk());
    // fine and coarse terminal values are close but not identical
    assert!(fine.iter().zip(&coarse).any(|(a, b)| a != b));
    let max_gap = fine
        .iter()
        .zip(&coarse)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_gap < 2.0, "coupled paths should stay close: {max_gap}");
}

#[test]
fn wrong_input_shape_is_rejected() {
    let dir = require_artifacts!();
    let rt = XlaRuntime::load(&dir).unwrap();
    let p = params(&rt);
    let too_short = vec![0.0f32; 8];
    assert!(rt.grad_coupled_chunk(0, &p, &too_short).is_err());
    let bad_params = vec![0.0f32; 3];
    let dw = dw_for(&rt, 0, rt.grad_chunk(0));
    assert!(rt.grad_coupled_chunk(0, &bad_params, &dw).is_err());
}

#[test]
fn smoothness_zero_for_identical_params_via_hlo() {
    let dir = require_artifacts!();
    let rt = XlaRuntime::load(&dir).unwrap();
    let p = params(&rt);
    let probm = rt.manifest().problem;
    let dw = BrownianSource::new(4).increments(
        Purpose::Diagnostic,
        0,
        0,
        0,
        rt.diag_chunk(),
        probm.n_steps(0),
        probm.dt(0),
    );
    let vals = rt.smoothness_chunk(0, &p, &p, &dw).unwrap();
    assert!(vals.iter().all(|&v| v == 0.0), "{vals:?}");
}

// ---------------------------------------------------------------------------
// failure injection: corrupted artifacts must fail loudly and helpfully
// ---------------------------------------------------------------------------

fn clone_artifacts(dir: &std::path::Path) -> std::path::PathBuf {
    // counter-named (not thread-id-named): stable across runs, unique
    // within the process — same policy as the run manifests
    use std::sync::atomic::{AtomicUsize, Ordering};
    static SEQ: AtomicUsize = AtomicUsize::new(0);
    let dst = std::env::temp_dir().join(format!(
        "dmlmc_corrupt_{}_{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dst);
    std::fs::create_dir_all(&dst).unwrap();
    for entry in std::fs::read_dir(dir).unwrap() {
        let p = entry.unwrap().path();
        std::fs::copy(&p, dst.join(p.file_name().unwrap())).unwrap();
    }
    dst
}

#[test]
fn truncated_hlo_artifact_fails_at_compile_with_entry_name() {
    let dir = require_artifacts!();
    let tmp = clone_artifacts(&dir);
    std::fs::write(tmp.join("grad_l0.hlo.txt"), "HloModule broken\n").unwrap();
    let rt = XlaRuntime::load(&tmp).unwrap(); // manifest parse still fine
    let err = rt.warmup().unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("grad_l0"), "error should name the entry: {msg}");
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn missing_hlo_file_fails_with_path() {
    let dir = require_artifacts!();
    let tmp = clone_artifacts(&dir);
    std::fs::remove_file(tmp.join("grad_l3.hlo.txt")).unwrap();
    let rt = XlaRuntime::load(&tmp).unwrap();
    let p = rt.manifest().load_init_params().unwrap();
    let dw = dw_for(&rt, 3, rt.grad_chunk(3));
    let err = rt.grad_coupled_chunk(3, &p, &dw).unwrap_err();
    assert!(format!("{err:#}").contains("grad_l3"));
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn corrupt_init_params_rejected_by_size_check() {
    let dir = require_artifacts!();
    let tmp = clone_artifacts(&dir);
    std::fs::write(tmp.join("init_params.bin"), [0u8; 12]).unwrap();
    let rt = XlaRuntime::load(&tmp).unwrap();
    let err = rt.manifest().load_init_params().unwrap_err();
    assert!(format!("{err:#}").contains("bytes"));
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn manifest_missing_level_rejected_at_load() {
    let dir = require_artifacts!();
    let tmp = clone_artifacts(&dir);
    // Drop the grad_l2 entry from the manifest json (crude but effective:
    // parse, filter, re-serialize via the in-repo json module).
    use dmlmc::util::json::Json;
    let text = std::fs::read_to_string(tmp.join("manifest.json")).unwrap();
    let mut doc = Json::parse(&text).unwrap();
    if let Json::Obj(m) = &mut doc {
        let entries = m.get_mut("entries").unwrap();
        if let Json::Arr(a) = entries {
            a.retain(|e| e.get("name").and_then(|n| n.as_str()) != Some("grad_l2"));
        }
    }
    std::fs::write(tmp.join("manifest.json"), doc.to_string()).unwrap();
    let err = match XlaRuntime::load(&tmp) {
        Err(e) => e,
        Ok(_) => panic!("load must reject a manifest missing level 2"),
    };
    assert!(format!("{err:#}").contains("level 2"), "{err:#}");
    let _ = std::fs::remove_dir_all(&tmp);
}
