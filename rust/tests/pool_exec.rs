//! Integration suite for the parallel execution runtime (`dmlmc::exec`):
//! bit-exact equivalence of pooled and sequential dispatch across worker
//! counts, oversubscription, schedule perturbation (chaos sleeps), the
//! resident-pool lifecycle (spawn-once threads, clean join, panic
//! survival), the trainer-level plumbing, and the parallel-sweep driver.

use std::sync::Arc;

use dmlmc::config::ExperimentConfig;
use dmlmc::coordinator::{
    run_jobs, run_jobs_pool, run_jobs_pool_with_report, run_jobs_threaded,
    LevelJobSpec, Method, Trainer,
};
use dmlmc::engine::mlp::init_params;
use dmlmc::exec::{ChunkTask, SpawnMode, WorkerPool};
use dmlmc::hedging::Problem;
use dmlmc::rng::BrownianSource;
use dmlmc::runtime::NativeBackend;
use dmlmc::scenarios::build_scenario;

fn setup() -> (Arc<NativeBackend>, BrownianSource, Vec<f32>) {
    (
        Arc::new(NativeBackend::new(Problem::default())),
        BrownianSource::new(11),
        init_params(0),
    )
}

fn assert_bitwise_eq(
    seq: &[dmlmc::coordinator::LevelResult],
    pooled: &[dmlmc::coordinator::LevelResult],
    tag: &str,
) {
    assert_eq!(seq.len(), pooled.len(), "{tag}: result count");
    for (a, b) in seq.iter().zip(pooled) {
        assert_eq!(a.level, b.level, "{tag}");
        assert_eq!(a.n_samples, b.n_samples, "{tag} level {}", a.level);
        assert_eq!(
            a.loss_delta.to_bits(),
            b.loss_delta.to_bits(),
            "{tag}: loss at level {}",
            a.level
        );
        assert_eq!(a.grad.len(), b.grad.len(), "{tag}");
        for (i, (x, y)) in a.grad.iter().zip(&b.grad).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{tag}: grad[{i}] at level {}",
                a.level
            );
        }
    }
}

#[test]
fn pool_bitwise_equal_to_sequential_for_required_worker_counts() {
    let (b, src, params) = setup();
    let jobs = vec![
        LevelJobSpec { level: 0, n_chunks: 3 },
        LevelJobSpec { level: 2, n_chunks: 2 },
        LevelJobSpec { level: 4, n_chunks: 1 },
        LevelJobSpec { level: 6, n_chunks: 2 },
    ];
    let seq = run_jobs(&*b, &src, 5, &params, &jobs).unwrap();
    for workers in [1usize, 2, 3, 8] {
        let mut pool = WorkerPool::new(workers);
        let pooled =
            run_jobs_pool(&b, &src, 5, &params, &jobs, &mut pool).unwrap();
        assert_bitwise_eq(&seq, &pooled, &format!("P={workers}"));
    }
}

#[test]
fn oversubscribed_pool_matches_sequential() {
    // More workers than total chunks: 8 workers, 2 chunks. Idle workers
    // must spin down cleanly and the reduction must be unaffected.
    let (b, src, params) = setup();
    let jobs = vec![
        LevelJobSpec { level: 1, n_chunks: 1 },
        LevelJobSpec { level: 5, n_chunks: 1 },
    ];
    let seq = run_jobs(&*b, &src, 3, &params, &jobs).unwrap();
    let mut pool = WorkerPool::new(8);
    let (pooled, report) =
        run_jobs_pool_with_report(&b, &src, 3, &params, &jobs, &mut pool)
            .unwrap();
    assert_bitwise_eq(&seq, &pooled, "oversubscribed");
    assert_eq!(report.workers.len(), 8);
    let executed: usize = report.workers.iter().map(|w| w.tasks).sum();
    assert_eq!(executed, 2);
    // at least 6 workers never saw a task
    let idle = report.workers.iter().filter(|w| w.tasks == 0).count();
    assert!(idle >= 6, "idle workers: {idle}");
}

#[test]
fn single_chunk_job_matches_sequential() {
    let (b, src, params) = setup();
    let jobs = vec![LevelJobSpec { level: 3, n_chunks: 1 }];
    let seq = run_jobs(&*b, &src, 0, &params, &jobs).unwrap();
    for workers in [1usize, 4] {
        let mut pool = WorkerPool::new(workers);
        let pooled =
            run_jobs_pool(&b, &src, 0, &params, &jobs, &mut pool).unwrap();
        assert_bitwise_eq(&seq, &pooled, &format!("single-chunk P={workers}"));
    }
}

#[test]
fn random_per_task_sleeps_cannot_change_the_gradient() {
    // Chaos mode sleeps a pseudorandom duration before every task,
    // scrambling which worker runs what and in which real-time order.
    // The pre-addressed slots + fixed-order reduction must erase all of
    // it: bit-identical to sequential, for several chaos seeds.
    let (b, src, params) = setup();
    let jobs = vec![
        LevelJobSpec { level: 0, n_chunks: 4 },
        LevelJobSpec { level: 2, n_chunks: 3 },
        LevelJobSpec { level: 5, n_chunks: 2 },
    ];
    let seq = run_jobs(&*b, &src, 9, &params, &jobs).unwrap();
    for chaos_seed in [0xA5u64, 0x5A, 0x77] {
        let mut pool = WorkerPool::new(4);
        pool.set_chaos_delays(chaos_seed, 400);
        let pooled =
            run_jobs_pool(&b, &src, 9, &params, &jobs, &mut pool).unwrap();
        assert_bitwise_eq(&seq, &pooled, &format!("chaos seed {chaos_seed}"));
    }
}

#[test]
fn two_factor_scenario_pools_bitwise() {
    // Heston (D = 2): factor-major increments flow through the pool
    // closure exactly as through run_one.
    let problem = Problem::default();
    let b = Arc::new(NativeBackend::with_scenario(
        problem,
        build_scenario("heston-call", &problem).unwrap(),
    ));
    let src = BrownianSource::new(4);
    let params = init_params(2);
    let jobs = vec![
        LevelJobSpec { level: 0, n_chunks: 2 },
        LevelJobSpec { level: 3, n_chunks: 2 },
    ];
    let seq = run_jobs(&*b, &src, 1, &params, &jobs).unwrap();
    for workers in [2usize, 5] {
        let mut pool = WorkerPool::new(workers);
        let pooled =
            run_jobs_pool(&b, &src, 1, &params, &jobs, &mut pool).unwrap();
        assert_bitwise_eq(&seq, &pooled, &format!("heston P={workers}"));
    }
}

#[test]
fn trainer_curves_identical_across_worker_counts_with_chaos_free_pool() {
    // End-to-end: full DMLMC training trajectories at P = 1 and P = 3
    // agree to the last bit (losses come from eval on fixed streams, so
    // equality means every parameter update matched).
    let mut cfg = ExperimentConfig::smoke();
    cfg.train.steps = 8;
    cfg.train.eval_every = 2;
    cfg.mlmc.n_effective = 64;
    let run = |workers: usize| {
        let mut c = cfg.clone();
        c.execution.workers = workers;
        let mut tr = Trainer::from_config(&c, Method::Dmlmc, 3).unwrap();
        let curve = tr.run().unwrap();
        (curve, tr.params.clone())
    };
    let (c1, p1) = run(1);
    let (c3, p3) = run(3);
    assert_eq!(p1.len(), p3.len());
    for (a, b) in p1.iter().zip(&p3) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    for (a, b) in c1.points.iter().zip(&c3.points) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
    }
}

#[test]
fn exec_report_telemetry_is_consistent() {
    let (b, src, params) = setup();
    let jobs = vec![
        LevelJobSpec { level: 0, n_chunks: 4 },
        LevelJobSpec { level: 6, n_chunks: 1 },
    ];
    let mut pool = WorkerPool::new(2);
    let (_, report) =
        run_jobs_pool_with_report(&b, &src, 0, &params, &jobs, &mut pool)
            .unwrap();
    assert_eq!(report.n_tasks, 5);
    assert_eq!(report.workers.len(), 2);
    // stable indices 0..P in order
    for (i, w) in report.workers.iter().enumerate() {
        assert_eq!(w.worker, i);
    }
    // busy time is measured inside the makespan window
    assert!(report.busy_total().as_secs_f64() > 0.0);
    let max_busy = report
        .workers
        .iter()
        .map(|w| w.busy.as_secs_f64())
        .fold(0.0f64, f64::max);
    assert!(
        report.makespan.as_secs_f64() >= max_busy * 0.5,
        "makespan {} vs max busy {max_busy}",
        report.makespan.as_secs_f64()
    );
    // a second dispatch accumulates into the same stats
    let _ = run_jobs_pool(&b, &src, 1, &params, &jobs, &mut pool).unwrap();
    assert_eq!(pool.stats().steps, 2);
    assert_eq!(pool.stats().tasks, 10);
}

// ---------------------------------------------------------------------------
// Resident lifecycle
// ---------------------------------------------------------------------------

#[test]
fn resident_pool_thread_count_is_constant_across_dispatches() {
    let (b, src, params) = setup();
    let jobs = vec![
        LevelJobSpec { level: 0, n_chunks: 2 },
        LevelJobSpec { level: 1, n_chunks: 1 },
    ];
    let mut pool = WorkerPool::new(3);
    assert_eq!(pool.mode(), SpawnMode::Resident);
    assert_eq!(pool.threads_spawned(), 3);
    assert_eq!(pool.resident_threads(), 3);
    for step in 0..4 {
        run_jobs_pool(&b, &src, step, &params, &jobs, &mut pool).unwrap();
        // spawn-once: no dispatch adds a thread
        assert_eq!(pool.threads_spawned(), 3, "after step {step}");
        assert_eq!(pool.resident_threads(), 3, "after step {step}");
    }
    assert_eq!(pool.stats().steps, 4);
    // the scoped baseline, by contrast, spawns fresh threads every time
    let mut scoped = WorkerPool::new_scoped(3);
    for step in 0..4 {
        run_jobs_pool(&b, &src, step, &params, &jobs, &mut scoped).unwrap();
    }
    assert_eq!(scoped.threads_spawned(), 4 * 3); // min(P=3, tasks=3) per step
    assert_eq!(scoped.resident_threads(), 0);
}

#[test]
fn dropping_the_pool_joins_resident_threads_cleanly() {
    let (b, src, params) = setup();
    let mut pool = WorkerPool::new(4);
    run_jobs_pool(
        &b,
        &src,
        0,
        &params,
        &[LevelJobSpec { level: 0, n_chunks: 2 }],
        &mut pool,
    )
    .unwrap();
    drop(pool); // must not hang or panic (threads join on Drop)
    // an unused pool joins cleanly too
    drop(WorkerPool::new(2));
}

#[test]
fn panicking_task_does_not_deadlock_later_dispatches() {
    let mut pool = WorkerPool::new(2);
    let tasks: Vec<ChunkTask> = (0..3)
        .map(|chunk| ChunkTask { group: 0, chunk, level: 0, weight: 1.0 })
        .collect();
    let err = pool
        .execute(&tasks, 1, |t: &ChunkTask| {
            if t.chunk == 2 {
                panic!("injected task panic");
            }
            Ok((t.chunk as f64, vec![1.0f32]))
        })
        .unwrap_err();
    assert!(format!("{err:#}").contains("panicked"), "{err:#}");
    // the resident workers must have survived the panic: a real
    // dispatch on the same pool completes and matches sequential
    let (b, src, params) = setup();
    let jobs = vec![LevelJobSpec { level: 0, n_chunks: 2 }];
    let seq = run_jobs(&*b, &src, 1, &params, &jobs).unwrap();
    let pooled = run_jobs_pool(&b, &src, 1, &params, &jobs, &mut pool).unwrap();
    assert_bitwise_eq(&seq, &pooled, "post-panic dispatch");
    assert_eq!(pool.stats().steps, 1); // the failed dispatch is not recorded
}

#[test]
fn arc_shared_heston_backend_runs_consecutive_resident_dispatches() {
    // Two-factor (Heston) backend behind the Arc that the resident
    // pool's 'static closures co-own: consecutive dispatches on one pool
    // stay bit-identical to sequential and accumulate telemetry.
    let problem = Problem::default();
    let b: Arc<NativeBackend> = Arc::new(NativeBackend::with_scenario(
        problem,
        build_scenario("heston-call", &problem).unwrap(),
    ));
    let src = BrownianSource::new(7);
    let params = init_params(1);
    let jobs = vec![
        LevelJobSpec { level: 0, n_chunks: 2 },
        LevelJobSpec { level: 2, n_chunks: 1 },
    ];
    let mut pool = WorkerPool::new(3);
    for step in 0..3 {
        let seq = run_jobs(&*b, &src, step, &params, &jobs).unwrap();
        let pooled =
            run_jobs_pool(&b, &src, step, &params, &jobs, &mut pool).unwrap();
        assert_bitwise_eq(&seq, &pooled, &format!("heston resident step {step}"));
    }
    assert_eq!(pool.stats().steps, 3);
    assert_eq!(pool.stats().tasks, 9);
    assert_eq!(pool.threads_spawned(), 3);
    // the Arc is still usable by the caller after all those dispatches
    assert_eq!(b.n_factors(), 2);
}

#[test]
fn threaded_wrapper_accumulates_stats_across_calls() {
    // Regression for the telemetry-loss bug: run_jobs_threaded used to
    // build (and drop) a fresh WorkerPool internally on every call.
    let (b, src, params) = setup();
    let jobs = vec![LevelJobSpec { level: 0, n_chunks: 2 }];
    let mut pool = WorkerPool::new(2);
    for step in 0..2 {
        run_jobs_threaded(&b, &src, step, &params, &jobs, &mut pool).unwrap();
    }
    assert_eq!(pool.stats().steps, 2);
    assert_eq!(pool.stats().tasks, 4);
    assert_eq!(pool.stats().overheads.len(), 2);
}

#[test]
fn parallel_sweep_end_to_end_smoke() {
    let mut cfg = ExperimentConfig::smoke();
    cfg.train.steps = 4;
    cfg.train.eval_every = 4;
    cfg.mlmc.n_effective = 32;
    let cells = dmlmc::experiments::ExperimentRunner::new(&cfg)
        .quiet(true)
        .parallel_sweep(&[2])
        .unwrap();
    assert_eq!(cells.len(), 3); // one P, three methods
    for cell in &cells {
        assert_eq!(cell.workers, 2);
        assert!(cell.measured_total_s >= 0.0);
        assert!(cell.overhead_mean_s >= 0.0);
        assert!(cell.pram_makespan > 0.0);
        assert!(cell.brent_bound > 0.0);
    }
    // model-level ordering: dmlmc's predicted mean per-step makespan is
    // the smallest of the three methods
    let pram = |m: Method| {
        cells
            .iter()
            .find(|c| c.method == m)
            .unwrap()
            .pram_makespan
    };
    assert!(pram(Method::Dmlmc) < pram(Method::Mlmc));
    assert!(pram(Method::Mlmc) <= pram(Method::Naive));
}

#[test]
fn exec_overhead_compare_smoke() {
    // The resident-vs-scoped comparison driver behind `repro exec-bench`
    // and the `exec_compare` row of BENCH_parallel.json. No timing
    // inequality is asserted (coarse CI clocks); structure and thread
    // accounting are.
    let mut cfg = ExperimentConfig::smoke();
    cfg.mlmc.n_effective = 64;
    let cmp = dmlmc::experiments::ExperimentRunner::new(&cfg)
        .quiet(true)
        .exec_overhead_compare(2, 3)
        .unwrap();
    assert_eq!(cmp.workers, 2);
    assert_eq!(cmp.steps, 3);
    assert!(cmp.resident_overhead_mean_s >= 0.0);
    assert!(cmp.scoped_overhead_mean_s >= 0.0);
    assert!(cmp.resident_makespan_mean_s >= 0.0);
    assert!(cmp.scoped_makespan_mean_s >= 0.0);
    // spawn-once vs spawn-per-dispatch (warmup + 3 measured dispatches)
    assert_eq!(cmp.resident_threads_spawned, 2);
    assert!(
        cmp.scoped_threads_spawned > cmp.resident_threads_spawned,
        "scoped spawned {} <= resident {}",
        cmp.scoped_threads_spawned,
        cmp.resident_threads_spawned
    );
}
