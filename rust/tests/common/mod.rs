//! Shared helpers for the integration tests.

use std::path::PathBuf;

/// Artifact directory, if `make artifacts` has been run AND this build
/// can actually execute artifacts (the default build substitutes the
/// stub runtime, whose `XlaRuntime::load` always errors — artifacts on
/// disk must not un-skip the XLA tests there).
pub fn artifacts_dir() -> Option<PathBuf> {
    if !cfg!(feature = "xla") {
        return None;
    }
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

/// Skip (with a loud message) when artifacts are missing, instead of
/// failing — `cargo test` must be runnable before `make artifacts` too.
#[macro_export]
macro_rules! require_artifacts {
    () => {
        match common::artifacts_dir() {
            Some(dir) => dir,
            None => {
                eprintln!("SKIP: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}
