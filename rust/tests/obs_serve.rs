//! Integration suite for the serving surface (`dmlmc::obs::serve`): a
//! live `MetricsServer` over a traced fleet answers `/metrics`,
//! `/status` and `/sessions/<id>` on a raw `TcpStream`, the per-level
//! variance gauges in the scraped exposition match a Welford computed
//! directly from independently recomputed refresh gradients (counter-
//! based RNG makes the recomputation bit-identical), and malformed
//! requests fail with the right status codes.

use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;

use dmlmc::config::ExperimentConfig;
use dmlmc::coordinator::{run_jobs, FleetCoordinator, Method, Trainer, TrainerBuilder};
use dmlmc::metrics::Welford;
use dmlmc::obs::{MetricsServer, ServeState, SharedRegistry};
use dmlmc::rng::BrownianSource;
use dmlmc::util::json::{obj, Json};

fn smoke_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::smoke();
    cfg.train.steps = 6;
    cfg.train.eval_every = 3;
    cfg.mlmc.n_effective = 64;
    cfg
}

/// Issue one raw request (the caller supplies the full head) and return
/// the full response text.
fn send(addr: SocketAddr, request: &str) -> String {
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.write_all(request.as_bytes()).unwrap();
    let mut out = String::new();
    conn.read_to_string(&mut out).unwrap();
    out
}

fn get(addr: SocketAddr, path: &str) -> String {
    send(addr, &format!("GET {path} HTTP/1.1\r\nHost: test\r\n\r\n"))
}

fn body(response: &str) -> &str {
    response
        .split("\r\n\r\n")
        .nth(1)
        .expect("response has a blank line after the head")
}

/// Value of one exact series line (`name{labels} value`) in a
/// Prometheus exposition.
fn series_value(exposition: &str, series: &str) -> Option<f64> {
    exposition.lines().find_map(|line| {
        line.strip_prefix(series)
            .and_then(|rest| rest.strip_prefix(' '))
            .and_then(|v| v.trim().parse().ok())
    })
}

/// Tentpole contract: the `dmlmc_level_variance` (and refresh/sample
/// count) gauges scraped from a served fleet run equal a Welford
/// computed directly from the refresh gradients — recomputed outside
/// the trainer via the public dispatcher API on a shadow run with the
/// same config and seed, which the counter-based RNG makes
/// bit-identical to what the session's estimator observed.
#[test]
fn scraped_variance_gauges_match_directly_computed_welford() {
    let cfg = smoke_cfg();
    let seed = 5u64;
    let steps = cfg.train.steps as u64;
    let n_levels = cfg.problem.lmax + 1;

    // Direct computation: recompute every due refresh's level-difference
    // gradient from the dispatcher, fold ‖∇Δ_l‖² into local Welfords,
    // then advance the shadow trainer one step.
    let mut shadow = Trainer::from_config(&cfg, Method::Dmlmc, seed).unwrap();
    let src = BrownianSource::new(seed);
    let mut direct = vec![Welford::new(); n_levels];
    let mut refreshes = vec![0u64; n_levels];
    let mut samples = vec![0u64; n_levels];
    for t in 0..steps {
        let jobs = shadow.jobs_for_step(t);
        let results = run_jobs(shadow.backend(), &src, t, &shadow.params, &jobs).unwrap();
        for r in &results {
            let norm2: f64 = r.grad.iter().map(|&g| g as f64 * g as f64).sum();
            direct[r.level].push(norm2);
            refreshes[r.level] += 1;
            samples[r.level] += r.n_samples as u64;
        }
        shadow.step(t).unwrap();
    }
    assert!(refreshes[0] > 0, "level 0 refreshes every step");

    // The served run: one traced fleet session with the same cfg/seed,
    // scraped over a real socket on an ephemeral port.
    let mut fleet = FleetCoordinator::new(2);
    fleet.enable_tracing();
    let state = Arc::new(ServeState::new(
        fleet.recorder().expect("tracing enabled").shared_metrics(),
    ));
    let mut server = MetricsServer::start(state.clone(), 0).unwrap();
    let addr = server.addr();
    let id = fleet
        .submit("serve-a", TrainerBuilder::new(&cfg).method(Method::Dmlmc).seed(seed))
        .unwrap();
    while fleet.pending_sessions() > 0 {
        fleet.tick().unwrap();
    }

    // Publish the JSON documents the way `repro serve`'s tick loop does.
    let detail = fleet.session_detail(id).expect("session still held");
    state.set_status(obj(vec![
        ("ticks", Json::Num(fleet.ticks() as f64)),
        ("sessions_done", Json::Num(1.0)),
    ]));
    state.set_session(
        id.0 as u64,
        obj(vec![
            ("step", Json::Num(detail.status.steps_done as f64)),
            (
                "last_loss",
                detail.last_loss.map(Json::Num).unwrap_or(Json::Null),
            ),
        ]),
    );

    // /metrics over a raw TcpStream: well-formed exposition with HELP
    // and TYPE lines for the estimator families.
    let response = get(addr, "/metrics");
    assert!(response.starts_with("HTTP/1.1 200 OK\r\n"), "{response}");
    assert!(
        response.contains("Content-Type: text/plain; version=0.0.4"),
        "{response}"
    );
    let exposition = body(&response);
    assert!(exposition.contains("# HELP dmlmc_level_variance "), "{exposition}");
    assert!(exposition.contains("# TYPE dmlmc_level_variance gauge"), "{exposition}");
    assert!(exposition.contains("# HELP obs_spans_dropped_total "), "{exposition}");

    // Gauge-by-gauge: the scraped values equal the direct Welford —
    // exact equality, since `{}`-formatted f64 round-trips through
    // parse and the estimator saw bit-identical observations.
    let sid = id.0;
    for l in 0..n_levels {
        let variance = format!("dmlmc_level_variance{{level=\"{l}\",session=\"{sid}\"}}");
        let served = series_value(exposition, &variance)
            .unwrap_or_else(|| panic!("missing series {variance} in:\n{exposition}"));
        assert_eq!(served, direct[l].variance(), "level {l} variance");
        let mean = format!("dmlmc_level_grad_norm2_mean{{level=\"{l}\",session=\"{sid}\"}}");
        assert_eq!(
            series_value(exposition, &mean),
            Some(direct[l].mean()),
            "level {l} mean"
        );
        let refr = format!("dmlmc_level_refreshes_total{{level=\"{l}\",session=\"{sid}\"}}");
        assert_eq!(
            series_value(exposition, &refr),
            Some(refreshes[l] as f64),
            "level {l} refreshes"
        );
        let samp = format!("dmlmc_level_samples_total{{level=\"{l}\",session=\"{sid}\"}}");
        assert_eq!(
            series_value(exposition, &samp),
            Some(samples[l] as f64),
            "level {l} samples"
        );
    }
    // The adopted allocation decision is scrape-visible alongside the
    // estimator gauges: per-level sample counts and refresh periods.
    // Under the default FixedPolicy they equal the shadow solo run's.
    assert!(exposition.contains("# TYPE dmlmc_alloc_n gauge"), "{exposition}");
    assert!(
        exposition.contains("# TYPE dmlmc_refresh_period gauge"),
        "{exposition}"
    );
    for l in 0..n_levels {
        let alloc = format!("dmlmc_alloc_n{{level=\"{l}\",session=\"{sid}\"}}");
        assert_eq!(
            series_value(exposition, &alloc),
            Some(shadow.decision().allocation.n(l) as f64),
            "level {l} alloc gauge"
        );
        let period = format!("dmlmc_refresh_period{{level=\"{l}\",session=\"{sid}\"}}");
        assert_eq!(
            series_value(exposition, &period),
            Some(shadow.schedule_periods()[l] as f64),
            "level {l} period gauge"
        );
    }

    // The deep snapshot the `/sessions/<id>` doc is built from agrees too.
    for snap in &detail.levels {
        assert_eq!(snap.variance, direct[snap.level].variance());
        assert_eq!(snap.refreshes_total, refreshes[snap.level]);
    }

    // /status and /sessions/<id> round-trip the strict JSON parser.
    let status = get(addr, "/status");
    assert!(status.starts_with("HTTP/1.1 200 OK\r\n"), "{status}");
    assert!(status.contains("Content-Type: application/json"), "{status}");
    let doc = Json::parse(body(&status).trim()).unwrap();
    assert_eq!(
        doc.get("ticks").unwrap().as_usize(),
        Some(fleet.ticks()),
        "{doc}"
    );
    assert_eq!(doc.get("sessions_done").unwrap().as_f64(), Some(1.0));

    let session = get(addr, &format!("/sessions/{sid}"));
    assert!(session.starts_with("HTTP/1.1 200 OK\r\n"), "{session}");
    let doc = Json::parse(body(&session).trim()).unwrap();
    assert_eq!(doc.get("step").unwrap().as_usize(), Some(cfg.train.steps));
    assert!(doc.get("last_loss").unwrap().as_f64().is_some());

    // The served session's trajectory stayed bit-identical to the
    // shadow solo run — serving never touches the computation.
    let runs = fleet.drain().unwrap();
    assert_eq!(runs.len(), 1);
    for (a, b) in runs[0].final_params.iter().zip(&shadow.params) {
        assert_eq!(a.to_bits(), b.to_bits(), "serving changed the trajectory");
    }
    server.shutdown();
}

/// Malformed request lines get 400, unknown paths and session ids get
/// 404, and the server keeps answering afterwards.
#[test]
fn malformed_requests_get_400_and_unknown_paths_404() {
    let state = Arc::new(ServeState::new(SharedRegistry::new()));
    state.set_session(3, obj(vec![("step", Json::Num(1.0))]));
    let mut server = MetricsServer::start(state, 0).unwrap();
    let addr = server.addr();

    assert!(send(addr, "garbage\r\n\r\n").starts_with("HTTP/1.1 400 Bad Request"));
    assert!(
        send(addr, "POST /metrics HTTP/1.1\r\n\r\n").starts_with("HTTP/1.1 400 Bad Request")
    );
    assert!(get(addr, "/nope").starts_with("HTTP/1.1 404 Not Found"));
    assert!(get(addr, "/sessions/99").starts_with("HTTP/1.1 404 Not Found"));
    assert!(get(addr, "/sessions/not-a-number").starts_with("HTTP/1.1 404 Not Found"));

    // Still serving after the error traffic.
    assert!(get(addr, "/sessions/3").starts_with("HTTP/1.1 200 OK"));
    assert!(get(addr, "/metrics").starts_with("HTTP/1.1 200 OK"));
    server.shutdown();
}
