//! End-to-end training integration over both backends: the coordinator,
//! scheduler, cache, dispatcher, optimizer, metrics and (for xla) the
//! PJRT runtime all composed, on small budgets.

mod common;

use dmlmc::config::{Backend, ExperimentConfig};
use dmlmc::coordinator::{Method, Trainer};
use dmlmc::experiments::ExperimentRunner;

fn small_cfg(backend: Backend) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::smoke();
    cfg.runtime.backend = backend;
    cfg.runtime.artifacts_dir =
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    cfg.train.steps = 12;
    cfg.train.eval_every = 4;
    cfg.mlmc.n_effective = 64;
    cfg
}

#[test]
fn native_all_methods_train_and_costs_are_ordered() {
    let cfg = small_cfg(Backend::Native);
    let mut depths = Vec::new();
    let mut works = Vec::new();
    for method in Method::all() {
        let mut tr = Trainer::from_config(&cfg, method, 0).unwrap();
        let curve = tr.run().unwrap();
        assert_eq!(curve.points.last().unwrap().step, 12);
        assert!(curve.final_loss().unwrap().is_finite());
        let c = tr.cumulative_cost();
        depths.push(c.depth);
        works.push(c.work);
    }
    // Table-1 ordering: naive depth == mlmc depth > dmlmc depth;
    // naive work > mlmc work >= dmlmc work.
    assert_eq!(depths[0], depths[1], "naive vs mlmc depth");
    assert!(depths[2] < depths[1], "dmlmc must cut parallel cost");
    assert!(works[0] > works[1], "naive work must dominate");
    assert!(works[2] <= works[1], "dmlmc work <= mlmc work");
}

#[test]
fn xla_backend_trains_and_loss_decreases() {
    let _dir = require_artifacts!();
    let mut cfg = small_cfg(Backend::Xla);
    cfg.train.steps = 10;
    cfg.train.eval_every = 10;
    cfg.train.lr = 0.08;
    let mut tr = Trainer::from_config(&cfg, Method::Dmlmc, 0).unwrap();
    let curve = tr.run().unwrap();
    let first = curve.points.first().unwrap().loss;
    let last = curve.points.last().unwrap().loss;
    assert!(last < first, "loss should decrease: {first} -> {last}");
}

#[test]
fn xla_and_native_trajectories_agree() {
    // Same seed, same streams, same model => the two backends must
    // produce near-identical learning curves (f32 tolerance over steps).
    let _dir = require_artifacts!();
    let mut cfg_n = small_cfg(Backend::Native);
    cfg_n.train.steps = 6;
    let mut cfg_x = cfg_n.clone();
    cfg_x.runtime.backend = Backend::Xla;

    let curve_n = Trainer::from_config(&cfg_n, Method::Mlmc, 1)
        .unwrap()
        .run()
        .unwrap();
    let curve_x = Trainer::from_config(&cfg_x, Method::Mlmc, 1)
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(curve_n.points.len(), curve_x.points.len());
    for (a, b) in curve_n.points.iter().zip(&curve_x.points) {
        let tol = 1e-3 + 5e-3 * a.loss.abs();
        assert!(
            (a.loss - b.loss).abs() < tol,
            "step {}: native {} vs xla {}",
            a.step,
            a.loss,
            b.loss
        );
        assert_eq!(a.std_cost, b.std_cost, "cost accounting must be identical");
        assert_eq!(a.par_cost, b.par_cost);
    }
}

#[test]
fn figure2_native_smoke_produces_ordered_parallel_costs() {
    let mut cfg = small_cfg(Backend::Native);
    cfg.train.n_seeds = 2;
    let results = ExperimentRunner::new(&cfg).quiet(true).figure2().unwrap();
    let get = |m: Method| {
        results
            .iter()
            .find(|(mm, _, _)| *mm == m)
            .map(|(_, _, agg)| *agg.par_cost.last().unwrap())
            .unwrap()
    };
    assert!(get(Method::Dmlmc) < get(Method::Mlmc));
    assert_eq!(get(Method::Mlmc), get(Method::Naive));
}

#[test]
fn validate_bs_converges_roughly() {
    // Martingale GBM (mu = 0): the optimal p0 is exactly the BS price
    // regardless of hedge quality (see ExperimentRunner::validate_bs docs).
    let mut cfg = small_cfg(Backend::Native);
    cfg.train.steps = 300;
    cfg.train.eval_every = 300;
    cfg.train.lr = 0.1;
    cfg.mlmc.n_effective = 128;
    let (p0, bs) = ExperimentRunner::new(&cfg).quiet(true).validate_bs().unwrap();
    assert!(bs > 1.0 && bs < 1.3, "BS anchor sanity: {bs}");
    assert!(
        (p0 - bs).abs() / bs < 0.15,
        "learned p0 {p0} too far from Black-Scholes {bs}"
    );
}

#[test]
fn figure1_native_fits_positive_decay_rates() {
    let mut cfg = small_cfg(Backend::Native);
    cfg.train.steps = 6;
    cfg.problem.lmax = 4; // keep runtime small; slopes only need 5 levels
    let fig = ExperimentRunner::new(&cfg).quiet(true).figure1(3).unwrap();
    assert!(
        fig.b_hat > 0.5,
        "variance decay rate should be clearly positive: {}",
        fig.b_hat
    );
    assert!(
        fig.d_hat > 0.3,
        "smoothness decay rate should be positive: {}",
        fig.d_hat
    );
}
