//! Integration suite for the observability layer (`dmlmc::obs`): span
//! ingestion reconciles bit-for-bit with the pool's busy telemetry even
//! under chaos scheduling, tracing never perturbs a training or fleet
//! trajectory, and the exported `trace.json` / `metrics.prom` artifacts
//! parse with the expected tracks and phases.

use std::sync::Arc;
use std::time::Duration;

use dmlmc::config::ExperimentConfig;
use dmlmc::coordinator::{
    run_jobs_pool_with_report, FleetCoordinator, LevelJobSpec, Method,
    TrainerBuilder,
};
use dmlmc::engine::mlp::init_params;
use dmlmc::exec::WorkerPool;
use dmlmc::hedging::Problem;
use dmlmc::metrics::RunArtifacts;
use dmlmc::obs::{GroupMeta, Recorder, TraceSink};
use dmlmc::rng::BrownianSource;
use dmlmc::runtime::NativeBackend;
use dmlmc::util::json::Json;

fn smoke_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::smoke();
    cfg.train.steps = 6;
    cfg.train.eval_every = 2;
    cfg.mlmc.n_effective = 64;
    cfg
}

/// Satellite contract: per worker track, the summed `task` span
/// durations must equal the pool's `WorkerStat::busy` rollup
/// bit-for-bit — on a REAL dispatch, with chaos sleeps scrambling the
/// schedule, at P = 1 and P = 4. The spans are re-materialized from the
/// same `TaskStat` telemetry the rollup was built from, so any drift
/// means the recorder invented or lost time.
#[test]
fn chaos_dispatch_spans_reconcile_with_worker_busy_bitwise() {
    let backend = Arc::new(NativeBackend::new(Problem::default()));
    let src = BrownianSource::new(11);
    let params = init_params(0);
    let jobs = vec![
        LevelJobSpec { level: 0, n_chunks: 4 },
        LevelJobSpec { level: 2, n_chunks: 3 },
        LevelJobSpec { level: 5, n_chunks: 2 },
    ];
    let metas: Vec<GroupMeta> = jobs
        .iter()
        .map(|j| GroupMeta { level: j.level, session: None })
        .collect();
    for workers in [1usize, 4] {
        let mut pool = WorkerPool::new(workers);
        pool.set_chaos_delays(0x5A, 400);
        let (_, report) =
            run_jobs_pool_with_report(&backend, &src, 7, &params, &jobs, &mut pool)
                .unwrap();
        let mut rec = Recorder::new(workers);
        let start = Duration::from_millis(3);
        rec.ingest_dispatch(&report, start, &metas);
        for w in &report.workers {
            let span_sum: Duration =
                rec.worker_spans(w.worker).iter().map(|s| s.dur).sum();
            assert_eq!(
                span_sum, w.busy,
                "P={workers}: worker {} span rollup drifted from busy",
                w.worker
            );
        }
        let total_spans: usize = rec.worker_span_counts().iter().sum();
        assert_eq!(total_spans, report.n_tasks, "P={workers}: span count");
        assert_eq!(rec.coordinator_spans().len(), 1, "P={workers}");
        // every task span sits inside the dispatch window
        let dispatch_end = start + report.makespan;
        for w in 0..rec.workers() {
            for s in rec.worker_spans(w).iter() {
                assert!(s.start >= start, "P={workers}: span before dispatch");
                assert!(
                    s.start + s.dur <= dispatch_end,
                    "P={workers}: span past makespan"
                );
            }
        }
    }
}

/// Tracing must be invisible to the computation: identical final
/// parameters and learning curves with the recorder on and off, at
/// P = 1 and P = 4.
#[test]
fn tracing_never_changes_trained_parameters_across_worker_counts() {
    for workers in [1usize, 4] {
        let mut cfg = smoke_cfg();
        cfg.execution.workers = workers;
        let run = |trace: bool| {
            let mut tr = TrainerBuilder::new(&cfg)
                .method(Method::Dmlmc)
                .seed(5)
                .trace(trace)
                .build()
                .unwrap();
            let curve = tr.run().unwrap();
            (curve, tr.params.clone())
        };
        let (plain_curve, plain_params) = run(false);
        let (traced_curve, traced_params) = run(true);
        assert_eq!(plain_params.len(), traced_params.len());
        for (a, b) in plain_params.iter().zip(&traced_params) {
            assert_eq!(a.to_bits(), b.to_bits(), "P={workers}: params diverged");
        }
        for (a, b) in plain_curve.points.iter().zip(&traced_curve.points) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "P={workers}: curve");
        }
    }
}

/// End-to-end export: a traced training run drains through `TraceSink`
/// into artifacts that round-trip the strict JSON parser with named
/// coordinator/worker tracks, `task`/`dispatch`/`step` phases, and a
/// Prometheus dump carrying the run's counters.
#[test]
fn traced_train_exports_parseable_tracks_and_phases() {
    let mut cfg = smoke_cfg();
    cfg.execution.workers = 2;
    let mut tr = TrainerBuilder::new(&cfg)
        .method(Method::Dmlmc)
        .seed(0)
        .trace(true)
        .build()
        .unwrap();
    tr.run().unwrap();
    let rec = tr.take_recorder().expect("traced trainer has a recorder");

    let out = std::env::temp_dir()
        .join(format!("dmlmc_obs_trace_it_{}", std::process::id()));
    let arts = RunArtifacts::create(&out, "trace").unwrap();
    let (trace_path, prom_path) = TraceSink::new(&arts).write(&rec).unwrap();

    let text = std::fs::read_to_string(&trace_path).unwrap();
    let doc = Json::parse(text.trim()).unwrap();
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    let track_names: Vec<&str> = events
        .iter()
        .filter(|e| e.get("ph").unwrap().as_str() == Some("M"))
        .filter_map(|e| e.get("args").unwrap().get("name").unwrap().as_str())
        .collect();
    assert!(track_names.contains(&"coordinator"), "{track_names:?}");
    assert!(track_names.contains(&"worker-0"), "{track_names:?}");
    assert!(track_names.contains(&"worker-1"), "{track_names:?}");
    let phase_of = |name: &str| {
        events
            .iter()
            .filter(|e| {
                e.get("ph").unwrap().as_str() == Some("X")
                    && e.get("name").unwrap().as_str() == Some(name)
            })
            .count()
    };
    // 6 steps => 6 step spans bracketing 6 dispatch spans, with task
    // spans underneath them
    assert_eq!(phase_of("step"), 6);
    assert_eq!(phase_of("dispatch"), 6);
    assert!(phase_of("task") > 0);
    assert_eq!(doc.get("droppedSpans").unwrap().as_usize(), Some(0));

    let prom = std::fs::read_to_string(&prom_path).unwrap();
    assert!(prom.contains("dmlmc_steps_total 6"), "{prom}");
    assert!(prom.contains("dmlmc_dispatches_total 6"), "{prom}");
    assert!(prom.contains("dmlmc_pool_workers 2"), "{prom}");
    assert!(prom.contains("dmlmc_step_makespan_seconds_count"), "{prom}");
    std::fs::remove_dir_all(&out).unwrap();
}

/// A traced fleet run stays bitwise identical to an untraced one and
/// records the serving-layer span vocabulary: `tick` spans on the
/// coordinator track, one `session` span per completed session, and
/// `task` spans carrying the owning session attr.
#[test]
fn traced_fleet_matches_untraced_and_records_session_spans() {
    let cfg = smoke_cfg();
    let run = |trace: bool| {
        let mut fleet = FleetCoordinator::new(2);
        if trace {
            fleet.enable_tracing();
        }
        fleet
            .submit("a", TrainerBuilder::new(&cfg).method(Method::Dmlmc).seed(1))
            .unwrap();
        fleet
            .submit("b", TrainerBuilder::new(&cfg).method(Method::Dmlmc).seed(2))
            .unwrap();
        let runs = fleet.drain().unwrap();
        (runs, fleet.take_recorder())
    };
    let (plain, no_rec) = run(false);
    let (traced, rec) = run(true);
    assert!(no_rec.is_none());
    let rec = rec.expect("traced fleet has a recorder");

    assert_eq!(plain.len(), traced.len());
    for (p, t) in plain.iter().zip(&traced) {
        assert_eq!(p.name, t.name);
        for (a, b) in p.final_params.iter().zip(&t.final_params) {
            assert_eq!(a.to_bits(), b.to_bits(), "{}: params diverged", p.name);
        }
        for (a, b) in p.curve.points.iter().zip(&t.curve.points) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{}: curve", p.name);
        }
    }

    let coord = |name: &str| {
        rec.coordinator_spans().iter().filter(|s| s.name == name).count()
    };
    // both sessions run concurrently: 6 ticks, one dispatch each
    assert_eq!(coord("tick"), 6);
    assert_eq!(coord("dispatch"), 6);
    assert_eq!(coord("session"), 2);
    assert_eq!(rec.metrics().counter("dmlmc_sessions_admitted_total"), 2);
    assert_eq!(rec.metrics().counter("dmlmc_ticks_total"), 6);
    // task spans are attributed to their owning session
    let mut session_attrs: Vec<f64> = (0..rec.workers())
        .flat_map(|w| {
            rec.worker_spans(w)
                .iter()
                .filter_map(|s| {
                    s.args.iter().find(|(k, _)| *k == "session").map(|&(_, v)| v)
                })
                .collect::<Vec<f64>>()
        })
        .collect();
    session_attrs.sort_by(f64::total_cmp);
    session_attrs.dedup();
    assert_eq!(session_attrs, vec![0.0, 1.0], "both sessions attributed");
}
