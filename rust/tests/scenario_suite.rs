//! Scenario-generic invariants: every registered scenario must satisfy
//! the structural prerequisites of the paper's estimator —
//!
//! * **strong fine/coarse coupling** — the MSE between fine- and
//!   coarse-grid evaluations of one coupled sample decays across levels
//!   (state-level for each SDE, payoff-level for each scenario), which is
//!   what Assumption 2 rests on;
//! * **finite coupled gradients** — the objective's hand-rolled backward
//!   pass stays finite (and generically non-zero) under every dynamics x
//!   payoff pair.
//!
//! This generalizes `engine::milstein::tests::strong_convergence_of_coupling`
//! from the hard-coded Black–Scholes call to the whole registry,
//! including the 2-factor Heston dynamics (factor-major increments,
//! per-factor coarsening) and the barrier payoffs (whose knock events
//! are tracked inside the streaming fold).

use dmlmc::engine::mlp::init_params;
use dmlmc::engine::{coupled_value_and_grad_scenario, simulate_paths_sde};
use dmlmc::hedging::Problem;
use dmlmc::rng::{brownian::Purpose, BrownianSource};
use dmlmc::scenarios::{all_scenario_names, build_scenario, Payoff, Scenario, SDE_KEYS};

const BATCH: usize = 2000;
const LEVELS: std::ops::RangeInclusive<usize> = 1..=4;

/// Fine/coarse MSE of `f(path)` per level for one scenario (price rows;
/// multi-factor dynamics coarsen each factor block independently).
fn coupling_mse(sc: &Scenario, p: &Problem, f: impl Fn(&[f32]) -> f32) -> Vec<f64> {
    let src = BrownianSource::new(0x5C);
    let dim = sc.sde.dim();
    let mut errs = Vec::new();
    for level in LEVELS {
        let n = p.n_steps(level);
        let dw = src.increments_multi(
            Purpose::Diagnostic,
            0,
            level as u32,
            0,
            BATCH,
            n,
            p.dt(level),
            dim,
        );
        let fine = simulate_paths_sde(&dw, BATCH, n, &*sc.sde, p.maturity);
        let dwc = BrownianSource::coarsen_multi(&dw, dim, BATCH, n);
        let coarse = simulate_paths_sde(&dwc, BATCH, n / 2, &*sc.sde, p.maturity);
        let mse = (0..BATCH)
            .map(|b| {
                let rf = &fine[b * (n + 1)..(b + 1) * (n + 1)];
                let rc = &coarse[b * (n / 2 + 1)..(b + 1) * (n / 2 + 1)];
                ((f(rf) - f(rc)) as f64).powi(2)
            })
            .sum::<f64>()
            / BATCH as f64;
        errs.push(mse);
    }
    errs
}

#[test]
fn every_sde_has_strong_state_coupling() {
    // Terminal-state MSE must decay geometrically for each dynamics —
    // the strong-order guarantee the payoff-level coupling inherits.
    let p = Problem::default();
    for sde_key in SDE_KEYS {
        let sc = build_scenario(&format!("{sde_key}-call"), &p).unwrap();
        let errs = coupling_mse(&sc, &p, |row| row[row.len() - 1]);
        for w in errs.windows(2) {
            assert!(
                w[1] < w[0] * 0.75,
                "{sde_key}: state MSE not decaying: {errs:?}"
            );
        }
    }
}

#[test]
fn every_scenario_has_decaying_payoff_coupling() {
    // Payoff-level MSE across levels: smooth payoffs decay like the
    // state; the discontinuous ones (digital, barriers) decay slower
    // (rate ~ strong order / 2) but must still decay end-to-end. Two
    // invariants per scenario:
    //
    // * **alive** — the level-1 fine/coarse MSE is strictly positive
    //   (a payoff that degenerates to a constant, e.g. a barrier that
    //   knocks every path out, fails here);
    // * **decaying** — continuous payoffs must beat the strict
    //   last-vs-first criterion (a finest-level regression fails
    //   immediately); the discontinuous ones (digital, barriers), whose
    //   per-level MSEs are sparse-event estimates on the mean-reverting
    //   dynamics, use a pooled coarse-levels-vs-fine-levels comparison
    //   that halves the estimator noise.
    let p = Problem::default();
    for name in all_scenario_names() {
        let sc = build_scenario(&name, &p).unwrap();
        let payoff = sc.payoff.clone();
        let errs = coupling_mse(&sc, &p, |row| payoff.value(row));
        assert!(
            errs.iter().all(|e| e.is_finite()),
            "{name}: non-finite payoff MSE {errs:?}"
        );
        assert!(
            errs[0] > 0.0,
            "{name}: payoff coupling is dead (constant payoff?): {errs:?}"
        );
        assert_eq!(errs.len(), 4);
        let discontinuous = name.ends_with("digital")
            || name.ends_with("uo-call")
            || name.ends_with("di-put");
        if discontinuous {
            let coarse_pool = errs[0] + errs[1];
            let fine_pool = errs[2] + errs[3];
            assert!(
                fine_pool < coarse_pool * 0.8,
                "{name}: payoff MSE not decaying: {errs:?}"
            );
        } else {
            assert!(
                *errs.last().unwrap() < errs[0] * 0.8,
                "{name}: payoff MSE not decaying: {errs:?}"
            );
        }
    }
}

#[test]
fn every_scenario_has_finite_coupled_gradients() {
    let p = Problem::default();
    let params = init_params(0);
    let src = BrownianSource::new(0x5D);
    for name in all_scenario_names() {
        let sc = build_scenario(&name, &p).unwrap();
        let dim = sc.sde.dim();
        for level in [0usize, 2] {
            let n = p.n_steps(level);
            let batch = 16;
            let dw = src.increments_multi(
                Purpose::Grad,
                0,
                level as u32,
                0,
                batch,
                n,
                p.dt(level),
                dim,
            );
            let (loss, grad) =
                coupled_value_and_grad_scenario(&params, &dw, batch, level, &p, &sc);
            assert!(loss.is_finite(), "{name} l{level}: loss {loss}");
            assert!(
                grad.iter().all(|g| g.is_finite()),
                "{name} l{level}: non-finite gradient"
            );
            // level 0 is an uncoupled objective: it must actually push on
            // the parameters for every scenario.
            if level == 0 {
                assert!(
                    grad.iter().any(|&g| g != 0.0),
                    "{name}: all-zero level-0 gradient"
                );
            }
        }
    }
}

#[test]
fn barrier_hits_split_between_fine_and_coarse_grids() {
    // The up-and-out knock event is grid-dependent: across a coupled
    // batch some fine paths must touch the barrier at a monitoring point
    // their coarse siblings skip. That asymmetry is the discontinuous
    // part of the level correction MLMC telescopes over — assert it is
    // statistically alive (and one-sided enough to be a *barrier* effect,
    // not noise).
    let p = Problem::default();
    let sc = build_scenario("bs-uo-call", &p).unwrap();
    let src = BrownianSource::new(0xBA);
    let level = 3;
    let n = p.n_steps(level);
    let dw = src.increments(Purpose::Diagnostic, 0, level as u32, 0, BATCH, n, p.dt(level));
    let fine = simulate_paths_sde(&dw, BATCH, n, &*sc.sde, p.maturity);
    let dwc = BrownianSource::coarsen(&dw, BATCH, n);
    let coarse = simulate_paths_sde(&dwc, BATCH, n / 2, &*sc.sde, p.maturity);
    let barrier = (p.s0 * dmlmc::scenarios::registry::UP_BARRIER_MULT) as f32;
    let hit = |row: &[f32]| row.iter().any(|&s| s >= barrier);
    let mut fine_only = 0usize;
    let mut coarse_only = 0usize;
    let mut both = 0usize;
    for b in 0..BATCH {
        let hf = hit(&fine[b * (n + 1)..(b + 1) * (n + 1)]);
        let hc = hit(&coarse[b * (n / 2 + 1)..(b + 1) * (n / 2 + 1)]);
        match (hf, hc) {
            (true, false) => fine_only += 1,
            (false, true) => coarse_only += 1,
            (true, true) => both += 1,
            _ => {}
        }
    }
    assert!(both > 0, "no coupled sample hit on both grids");
    assert!(
        fine_only > 0,
        "no fine-only hits — the finer grid must catch excursions the \
         coarse one skips"
    );
    // The finer grid monitors a superset of price excursions in
    // distribution: fine-only hits must dominate coarse-only ones.
    assert!(
        fine_only > coarse_only,
        "fine-only {fine_only} !> coarse-only {coarse_only}"
    );
}

#[test]
fn registry_is_complete_and_consistent() {
    let p = Problem::default();
    let names = all_scenario_names();
    assert!(names.len() >= 12, "registry shrank: {names:?}");
    assert!(
        names.iter().any(|n| n.starts_with("heston-")),
        "heston family missing"
    );
    assert!(
        names.iter().any(|n| n.ends_with("uo-call"))
            && names.iter().any(|n| n.ends_with("di-put")),
        "barrier payoffs missing"
    );
    for name in &names {
        let sc = build_scenario(name, &p).unwrap();
        // the key round-trips through the component names
        let (sde_key, payoff_key) = name.split_once('-').unwrap();
        assert_eq!(sc.payoff.name(), payoff_key, "{name}");
        // `bs` reports its drift-form-dependent name; others are exact
        if sde_key != "bs" {
            assert_eq!(sc.sde.name(), sde_key, "{name}");
        }
        assert!(sc.sde.dim() >= 1 && sc.sde.dim() <= dmlmc::scenarios::MAX_DIM);
    }
}
