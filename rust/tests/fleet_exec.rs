//! Serving-fleet bit-exactness: a [`FleetCoordinator`] multiplexing N
//! trainers over one resident pool must leave every problem's trajectory
//! **bit-identical to its solo run** — at every fleet size, every worker
//! count, for mixed scenarios and mixed methods, and under injected
//! chaos delays. The counter-based RNG makes each chunk a pure function
//! of its `(step, level, chunk)` address and each session's group
//! reduces in fixed chunk order, so sharing the pool must not move a
//! single bit.

use dmlmc::config::{Backend, ExperimentConfig};
use dmlmc::coordinator::{FleetCoordinator, FleetRun, Method, TrainerBuilder};
use dmlmc::metrics::LearningCurve;

fn cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::smoke();
    cfg.runtime.backend = Backend::Native;
    cfg.train.steps = 4;
    cfg.train.eval_every = 2;
    cfg.mlmc.n_effective = 64;
    cfg
}

/// The two scenarios fleet sessions cycle over: the default engine and
/// the 2-factor stochastic-vol barrier case (distinct dynamics, payoff
/// and dimension — if cross-problem batching leaked state anywhere,
/// these would diverge differently).
const SCENARIOS: [&str; 2] = ["bs-call", "heston-uo-call"];

fn builder(scenario: &str, method: Method, seed: u64) -> TrainerBuilder {
    TrainerBuilder::new(&cfg())
        .method(method)
        .seed(seed)
        .scenario(scenario)
}

/// Solo reference trajectory: same builder, run start-to-finish on its
/// own (with its own local pool).
fn solo(scenario: &str, method: Method, seed: u64) -> (LearningCurve, Vec<f32>) {
    let mut tr = builder(scenario, method, seed).build().unwrap();
    let curve = tr.run().unwrap();
    let params = tr.params.clone();
    (curve, params)
}

fn assert_curves_identical(ctx: &str, fleet: &LearningCurve, solo: &LearningCurve) {
    assert_eq!(fleet.method, solo.method, "{ctx}: method");
    assert_eq!(fleet.seed, solo.seed, "{ctx}: seed");
    assert_eq!(fleet.points.len(), solo.points.len(), "{ctx}: eval grid");
    for (a, b) in fleet.points.iter().zip(&solo.points) {
        assert_eq!(a.step, b.step, "{ctx}: eval step");
        // Bitwise, not approximate: the fleet reduction order is pinned.
        assert_eq!(
            a.loss.to_bits(),
            b.loss.to_bits(),
            "{ctx}: loss at step {} ({} vs {})",
            a.step,
            a.loss,
            b.loss
        );
        assert_eq!(a.std_cost.to_bits(), b.std_cost.to_bits(), "{ctx}: std cost");
        assert_eq!(a.par_cost.to_bits(), b.par_cost.to_bits(), "{ctx}: par cost");
        assert_eq!(
            a.grad_norm.to_bits(),
            b.grad_norm.to_bits(),
            "{ctx}: grad norm at step {}",
            a.step
        );
    }
}

fn assert_run_matches_solo(ctx: &str, run: &FleetRun) {
    let scenario = SCENARIOS[run.seed as usize % SCENARIOS.len()];
    let (ref_curve, ref_params) = solo(scenario, run.method, run.seed);
    assert_curves_identical(ctx, &run.curve, &ref_curve);
    assert_eq!(
        run.final_params.len(),
        ref_params.len(),
        "{ctx}: param count"
    );
    for (i, (a, b)) in run.final_params.iter().zip(&ref_params).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{ctx}: param {i} differs ({a} vs {b})"
        );
    }
}

/// Submit `fleet_size` DMLMC sessions cycling over [`SCENARIOS`], seed
/// `i`, and drain.
fn run_fleet(fleet: &mut FleetCoordinator, fleet_size: usize) -> Vec<FleetRun> {
    for i in 0..fleet_size {
        let scenario = SCENARIOS[i % SCENARIOS.len()];
        fleet
            .submit(
                &format!("{scenario}#{i}"),
                builder(scenario, Method::Dmlmc, i as u64),
            )
            .unwrap();
    }
    let runs = fleet.drain().unwrap();
    assert_eq!(runs.len(), fleet_size);
    runs
}

#[test]
fn every_fleet_size_and_worker_count_is_bit_identical_to_solo() {
    // The ISSUE's acceptance grid: fleet sizes {1, 2, 4} x workers
    // {1, 4}, mixed bs-call + heston-uo-call sessions throughout.
    for fleet_size in [1usize, 2, 4] {
        for workers in [1usize, 4] {
            let mut fleet = FleetCoordinator::new(workers);
            let runs = run_fleet(&mut fleet, fleet_size);
            assert_eq!(fleet.ticks(), cfg().train.steps, "fair-share: one step/tick");
            for run in &runs {
                let ctx = format!(
                    "fleet={fleet_size} workers={workers} session={}",
                    run.name
                );
                assert_run_matches_solo(&ctx, run);
            }
        }
    }
}

#[test]
fn chaos_delays_do_not_move_a_bit() {
    // Random per-task stalls reorder completion arbitrarily; the fixed
    // chunk-order reduction must make that invisible in the numbers.
    for chaos_seed in [0xA5u64, 0x5A, 0x77] {
        let mut fleet = FleetCoordinator::new(4);
        fleet.set_chaos_delays(chaos_seed, 400);
        let runs = run_fleet(&mut fleet, 4);
        for run in &runs {
            let ctx = format!("chaos_seed={chaos_seed:#x} session={}", run.name);
            assert_run_matches_solo(&ctx, run);
        }
    }
}

#[test]
fn mixed_method_fleet_matches_each_solo() {
    // Naive (one finest-grid group) and MLMC/DMLMC (one group per due
    // level) sessions batched into the same dispatches: per-problem
    // slices must still reduce exactly as their solo counterparts.
    let mut fleet = FleetCoordinator::new(4);
    let methods = [Method::Naive, Method::Mlmc, Method::Dmlmc];
    for (i, method) in methods.iter().enumerate() {
        let scenario = SCENARIOS[i % SCENARIOS.len()];
        fleet
            .submit(
                &format!("{}-{}", method.name(), scenario),
                builder(scenario, *method, i as u64),
            )
            .unwrap();
    }
    let runs = fleet.drain().unwrap();
    assert_eq!(runs.len(), methods.len());
    for run in &runs {
        assert_run_matches_solo(&format!("mixed session={}", run.name), run);
    }
}

#[test]
fn fleet_reports_slice_per_problem_work() {
    // Telemetry sanity on the shared dispatches: each session gets one
    // report per step, reports only ever cover that session's groups,
    // and a 2-session fleet's per-step task counts sum to the tick's.
    let mut fleet = FleetCoordinator::new(2);
    let runs = run_fleet(&mut fleet, 2);
    let steps = cfg().train.steps;
    for run in &runs {
        assert_eq!(run.reports.len(), steps, "one report per step");
        for rep in &run.reports {
            assert!(rep.n_tasks > 0, "a step always dispatches work");
            assert_eq!(
                rep.per_task.len(),
                rep.n_tasks,
                "per-task records cover the slice"
            );
        }
    }
    let stats = fleet.exec_stats();
    let sliced: usize = runs
        .iter()
        .flat_map(|r| r.reports.iter().map(|rep| rep.n_tasks))
        .sum();
    assert_eq!(sliced, stats.tasks, "slices partition the shared dispatches");
}
