//! Static-dispatch kernel registry invariants, across the full
//! `SDE_KEYS x PAYOFF_KEYS` cross product:
//!
//! * **no dyn fallback reachable from the trainer** — every registry key
//!   (and its `-simd` variant) resolves to a monomorphized
//!   [`ScenarioKernel`], and a [`NativeBackend`] built from any registry
//!   scenario reports a static kernel;
//! * **lane-vs-scalar golden tolerances** — the 8-wide lane-blocked
//!   kernels track the scalar reference within per-scenario relative
//!   tolerances for loss and every gradient component, including
//!   remainder batches that exercise the scalar tail path;
//! * **bitwise seed anchor** — the `bs-call` *scalar* static kernel is
//!   bit-identical to the seed engine entry points, so routing the
//!   backend through the kernel table cannot move the default scenario.

use dmlmc::engine::mlp::init_params;
use dmlmc::engine::{coupled_value_and_grad, loss_only, value_and_grad};
use dmlmc::hedging::Problem;
use dmlmc::rng::{brownian::Purpose, BrownianSource};
use dmlmc::runtime::NativeBackend;
use dmlmc::scenarios::{
    all_scenario_names, build_scenario, kernel_for, resolve_kernel,
};

/// Relative closeness with an absolute floor of 1: lane kernels
/// reassociate f32 reductions and use a polynomial `exp` in the MLP, so
/// exact equality is off the table by design.
fn close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * a.abs().max(b.abs()).max(1.0)
}

#[test]
fn every_registry_key_resolves_to_a_static_kernel() {
    let names = all_scenario_names();
    assert_eq!(names.len(), 35, "registry size drifted");
    for name in &names {
        let k = kernel_for(name)
            .unwrap_or_else(|| panic!("`{name}` has no static kernel"));
        assert_eq!(k.name, name.as_str());
        let (base, simd) = resolve_kernel(name).unwrap();
        assert!(!simd, "`{name}` is not a SIMD key");
        assert_eq!(base.name, name.as_str());
        let variant = format!("{name}-simd");
        let (lane, simd) = resolve_kernel(&variant)
            .unwrap_or_else(|| panic!("`{variant}` must resolve"));
        assert!(simd, "`{variant}` selects the lane kernels");
        assert_eq!(lane.name, name.as_str());
    }
    for bad in ["sabr-call", "bs-call-simd-simd", "bs", "", "-simd"] {
        assert!(resolve_kernel(bad).is_none(), "`{bad}` must not resolve");
    }
}

#[test]
fn native_backend_never_falls_back_to_dyn_for_registry_scenarios() {
    let p = Problem::default();
    for name in all_scenario_names() {
        for (key, want_simd) in [(name.clone(), false), (format!("{name}-simd"), true)]
        {
            let sc = build_scenario(&key, &p).unwrap();
            assert_eq!(sc.name, key, "registry must keep the full key as name");
            let backend = NativeBackend::with_scenario(p, sc);
            assert!(
                backend.has_static_kernel(),
                "`{key}`: trainer-reachable backend fell back to dyn dispatch"
            );
            assert_eq!(backend.is_simd(), want_simd, "`{key}`: wrong variant");
        }
    }
}

#[test]
fn lane_kernels_track_the_scalar_reference_for_every_scenario() {
    let p = Problem::default();
    let params = init_params(0);
    let src = BrownianSource::new(0xA11);
    let level = 2;
    let n = p.n_steps(level);
    // Remainder batches on purpose: 19 = 2 full lane blocks + 3 tail
    // paths through the scalar fallback, 27 = 3 blocks + 3.
    for (pass, batch) in [(0u64, 19usize), (1, 27)] {
        for name in all_scenario_names() {
            let k = kernel_for(&name).unwrap();
            let dw = src.increments_multi(
                Purpose::Grad,
                pass,
                level as u32,
                0,
                batch,
                n,
                p.dt(level),
                k.dim,
            );
            let (ls, gs) = (k.scalar.value_and_grad)(&params, &dw, batch, n, &p);
            let (ll, gl) = (k.lanes.value_and_grad)(&params, &dw, batch, n, &p);
            assert!(
                close(ll as f32, ls as f32, 1e-3),
                "{name}: lane loss {ll} vs scalar {ls}"
            );
            for (i, (a, b)) in gl.iter().zip(&gs).enumerate() {
                assert!(
                    close(*a, *b, 5e-3),
                    "{name}: grad[{i}] lane {a} vs scalar {b}"
                );
            }
            let (lcs, gcs) =
                (k.scalar.coupled_value_and_grad)(&params, &dw, batch, level, &p);
            let (lcl, gcl) =
                (k.lanes.coupled_value_and_grad)(&params, &dw, batch, level, &p);
            assert!(
                close(lcl as f32, lcs as f32, 1e-3),
                "{name}: lane coupled loss {lcl} vs scalar {lcs}"
            );
            for (i, (a, b)) in gcl.iter().zip(&gcs).enumerate() {
                assert!(
                    close(*a, *b, 5e-3),
                    "{name}: coupled grad[{i}] lane {a} vs scalar {b}"
                );
            }
            let es = (k.scalar.loss_only)(&params, &dw, batch, n, &p);
            let el = (k.lanes.loss_only)(&params, &dw, batch, n, &p);
            assert!(
                close(el as f32, es as f32, 1e-3),
                "{name}: lane eval loss {el} vs scalar {es}"
            );
        }
    }
}

#[test]
fn bs_call_scalar_kernel_is_bitwise_identical_to_the_seed_engine() {
    let p = Problem::default();
    let params = init_params(3);
    let k = kernel_for("bs-call").unwrap();
    let src = BrownianSource::new(7);
    for level in 0..=2usize {
        let batch = 33;
        let n = p.n_steps(level);
        let dw = src.increments_multi(
            Purpose::Grad,
            0,
            level as u32,
            0,
            batch,
            n,
            p.dt(level),
            1,
        );
        let (l1, g1) = (k.scalar.value_and_grad)(&params, &dw, batch, n, &p);
        let (l2, g2) = value_and_grad(&params, &dw, batch, n, &p);
        assert_eq!(l1, l2, "level {level}: value_and_grad loss drifted");
        assert_eq!(g1, g2, "level {level}: value_and_grad grad drifted");
        let (l1, g1) = (k.scalar.coupled_value_and_grad)(&params, &dw, batch, level, &p);
        let (l2, g2) = coupled_value_and_grad(&params, &dw, batch, level, &p);
        assert_eq!(l1, l2, "level {level}: coupled loss drifted");
        assert_eq!(g1, g2, "level {level}: coupled grad drifted");
        assert_eq!(
            (k.scalar.loss_only)(&params, &dw, batch, n, &p),
            loss_only(&params, &dw, batch, n, &p),
            "level {level}: loss_only drifted"
        );
    }
}
