//! Property-based tests (via the in-repo `testkit`) on the coordinator
//! and MLMC invariants: the schedule, the cache, the allocation, the cost
//! model and the RNG addressing — randomized over their parameter spaces.

mod common;

use dmlmc::coordinator::{DelayedSchedule, GradientCache};
use dmlmc::mlmc::allocation::LevelAllocation;
use dmlmc::parallel::{CostModel, StepCost};
use dmlmc::rng::{brownian::Purpose, BrownianSource};
use dmlmc::testkit::{check, Config, Gen};

fn cfg(cases: usize) -> Config {
    Config { cases, seed: 0xD31A }
}

#[test]
fn prop_schedule_tau_is_latest_refresh() {
    check("tau is the latest refresh <= t", cfg(300), |g: &mut Gen| {
        let lmax = g.usize(0, 8);
        let d = g.f64(0.0, 2.5);
        let s = DelayedSchedule::new(lmax, d);
        let t = g.u64() % 10_000;
        for l in 0..=lmax {
            let tau = s.tau(t, l);
            let p = s.period(l);
            if tau > t {
                return Err(format!("tau {tau} > t {t}"));
            }
            if tau % p != 0 {
                return Err(format!("tau {tau} not on period {p}"));
            }
            if t - tau >= p {
                return Err(format!("staleness {} >= period {p}", t - tau));
            }
            // tau must itself be a due step
            if !s.is_due(tau, l) {
                return Err(format!("tau {tau} not due at level {l}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_schedule_periods_monotone_in_level() {
    check("periods non-decreasing in level", cfg(200), |g: &mut Gen| {
        let lmax = g.usize(1, 10);
        let d = g.f64(0.0, 2.0);
        let s = DelayedSchedule::new(lmax, d);
        for l in 1..=lmax {
            if s.period(l) < s.period(l - 1) {
                return Err(format!(
                    "period({l}) = {} < period({}) = {}",
                    s.period(l),
                    l - 1,
                    s.period(l - 1)
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_allocation_covers_and_decays() {
    check("allocation sane for random (b, c, N)", cfg(300), |g: &mut Gen| {
        let lmax = g.usize(1, 8);
        let c = g.f64(0.2, 1.5);
        let b = c + g.f64(0.1, 1.5); // enforce b > c
        let n = g.usize(1, 1 << 14);
        let a = LevelAllocation::paper(lmax, n, b, c);
        if a.n_per_level.iter().any(|&x| x == 0) {
            return Err("zero-sample level".into());
        }
        for l in 1..=lmax {
            if a.n(l) > a.n(l - 1) {
                return Err(format!("N_l increasing at {l}: {:?}", a.n_per_level));
            }
        }
        let total: usize = a.n_per_level.iter().sum();
        if total < n {
            return Err(format!("total {total} < N {n}"));
        }
        if total > n + lmax + 1 {
            return Err(format!("over-allocated: {total} vs {n}"));
        }
        Ok(())
    });
}

#[test]
fn prop_chunk_rounding_never_reduces() {
    check("chunk rounding rounds up to multiples", cfg(300), |g: &mut Gen| {
        let lmax = g.usize(0, 7);
        let a = LevelAllocation {
            n_per_level: (0..=lmax).map(|_| g.usize(1, 500)).collect(),
        };
        let chunks: Vec<usize> = (0..=lmax).map(|_| g.usize(1, 64)).collect();
        let r = a.round_to_chunks(&chunks);
        for l in 0..=lmax {
            if r.n(l) < a.n(l) {
                return Err(format!("rounded down at {l}"));
            }
            if r.n(l) % chunks[l] != 0 {
                return Err(format!("not a chunk multiple at {l}"));
            }
            if r.n(l) - a.n(l) >= chunks[l] {
                return Err(format!("overshoot at {l}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_step_cost_work_geq_depth_scaling() {
    check("work >= depth; both positive for jobs", cfg(300), |g: &mut Gen| {
        let model = CostModel::new(g.f64(0.1, 2.0));
        let n_jobs = g.usize(1, 8);
        let jobs: Vec<(usize, usize)> = (0..n_jobs)
            .map(|_| (g.usize(0, 8), g.usize(1, 100)))
            .collect();
        let cost = StepCost::from_jobs(&model, &jobs);
        if cost.work < cost.depth {
            return Err(format!("work {} < depth {}", cost.work, cost.depth));
        }
        // depth equals the max single-sample cost among jobs
        let want_depth = jobs
            .iter()
            .map(|&(l, _)| model.sample_cost(l))
            .fold(0.0f64, f64::max);
        if (cost.depth - want_depth).abs() > 1e-12 {
            return Err("depth != max level cost".into());
        }
        Ok(())
    });
}

#[test]
fn prop_cache_assemble_is_sum_of_latest() {
    check("cache assembles the latest components", cfg(200), |g: &mut Gen| {
        let lmax = g.usize(0, 6);
        let dim = g.usize(1, 16);
        let mut cache = GradientCache::new(lmax, dim);
        let mut latest: Vec<(f64, Vec<f32>)> = Vec::new();
        for l in 0..=lmax {
            let mut last = (0.0f64, vec![0.0f32; dim]);
            let updates = g.usize(1, 3);
            let mut step = 0u64;
            for _ in 0..updates {
                let loss = g.f64(-2.0, 2.0);
                let grad: Vec<f32> =
                    (0..dim).map(|_| g.f64(-1.0, 1.0) as f32).collect();
                cache.update(l, step, loss, grad.clone());
                last = (loss, grad);
                step += g.u64() % 5 + 1;
            }
            latest.push(last);
        }
        let (loss, grad) = cache.assemble();
        let want_loss: f64 = latest.iter().map(|(l, _)| l).sum();
        if (loss - want_loss).abs() > 1e-9 {
            return Err(format!("loss {loss} != {want_loss}"));
        }
        for i in 0..dim {
            let want: f32 = latest.iter().map(|(_, g)| g[i]).sum();
            if (grad[i] - want).abs() > 1e-5 {
                return Err(format!("grad[{i}] {} != {want}", grad[i]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_brownian_addressing_is_injective_in_practice() {
    check("distinct addresses -> distinct batches", cfg(100), |g: &mut Gen| {
        let src = BrownianSource::new(g.u64());
        let step = g.u64() % 1000;
        let level = g.usize(0, 6) as u32;
        let chunk = g.usize(0, 7) as u32;
        let a = src.increments(Purpose::Grad, step, level, chunk, 2, 4, 0.25);
        // perturb exactly one coordinate
        let b = match g.usize(0, 2) {
            0 => src.increments(Purpose::Grad, step + 1, level, chunk, 2, 4, 0.25),
            1 => src.increments(Purpose::Grad, step, level + 1, chunk, 2, 4, 0.25),
            _ => src.increments(Purpose::Grad, step, level, chunk + 1, 2, 4, 0.25),
        };
        if a == b {
            return Err("collision between distinct addresses".into());
        }
        Ok(())
    });
}

#[test]
fn prop_coarsen_preserves_row_sums() {
    check("coarsening preserves total increment", cfg(200), |g: &mut Gen| {
        let batch = g.usize(1, 8);
        let n = 2 * g.usize(1, 32);
        let src = BrownianSource::new(g.u64());
        let dw = src.increments(Purpose::Grad, 0, 0, 0, batch, n, 0.1);
        let c = BrownianSource::coarsen(&dw, batch, n);
        for b in 0..batch {
            let fine: f32 = dw[b * n..(b + 1) * n].iter().sum();
            let coarse: f32 = c[b * n / 2..(b + 1) * n / 2].iter().sum();
            if (fine - coarse).abs() > 1e-4 {
                return Err(format!("row {b}: {fine} vs {coarse}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dmlmc_avg_due_levels_matches_theory() {
    check("avg #due levels ~ sum 2^{-dl}", cfg(30), |g: &mut Gen| {
        let lmax = g.usize(2, 7);
        let d_exp = *g.choose(&[0.5f64, 1.0, 1.5, 2.0]);
        let s = DelayedSchedule::new(lmax, d_exp);
        let horizon = 1u64 << 13;
        let total: usize = (0..horizon).map(|t| s.levels_due(t).len()).sum();
        let avg = total as f64 / horizon as f64;
        let theory: f64 =
            (0..=lmax).map(|l| 1.0 / s.period(l) as f64).sum();
        if (avg - theory).abs() > 0.05 {
            return Err(format!("avg {avg} vs theory {theory}"));
        }
        Ok(())
    });
}
