//! THE cross-layer correctness signal: the pure-rust engine and the
//! AOT-compiled JAX/Pallas artifacts must agree on identical inputs —
//! paths, losses and gradients — to f32 tolerance.
//!
//! Any mismatch means one of the two model implementations (or the AOT
//! plumbing) is wrong.

mod common;

use dmlmc::engine;
use dmlmc::rng::{brownian::Purpose, BrownianSource};
use dmlmc::runtime::{GradBackend, XlaRuntime};

const REL_TOL: f64 = 2e-3;
const ABS_TOL: f64 = 2e-4;

fn close(a: f64, b: f64, what: &str) {
    let tol = ABS_TOL + REL_TOL * a.abs().max(b.abs());
    assert!((a - b).abs() <= tol, "{what}: engine {a} vs hlo {b}");
}

/// Relative L2 error `||a - b|| / ||b||` — the right metric for coupled
/// gradients, whose per-element values are differences of similar numbers
/// (catastrophic cancellation makes per-element relative error noisy in
/// f32 even when both implementations are correct).
fn rel_l2_err(a: &[f32], b: &[f32]) -> f64 {
    let diff: f64 = a
        .iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        .sqrt();
    let norm: f64 = b.iter().map(|&y| (y as f64).powi(2)).sum::<f64>().sqrt();
    diff / norm.max(1e-12)
}

fn max_rel_err(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let (x, y) = (x as f64, y as f64);
            (x - y).abs() / (1e-4 + x.abs().max(y.abs()))
        })
        .fold(0.0, f64::max)
}

#[test]
fn milstein_paths_match() {
    let dir = require_artifacts!();
    let rt = XlaRuntime::load(&dir).unwrap();
    let prob = rt.manifest().problem;
    for level in [0usize, 2, 5] {
        let n = prob.n_steps(level);
        let batch = rt.diag_chunk();
        let dw = BrownianSource::new(11).increments(
            Purpose::Diagnostic, 0, level as u32, 0, batch, n, prob.dt(level),
        );
        let (hlo_fine, hlo_coarse) = rt.path_eval(level, &dw).unwrap();
        let eng_fine = engine::milstein::terminal_values(&dw, batch, n, &prob);
        assert!(
            max_rel_err(&eng_fine, &hlo_fine) < 1e-4,
            "fine terminal mismatch at level {level}"
        );
        if level > 0 {
            let dwc = BrownianSource::coarsen(&dw, batch, n);
            let eng_coarse =
                engine::milstein::terminal_values(&dwc, batch, n / 2, &prob);
            assert!(
                max_rel_err(&eng_coarse, &hlo_coarse) < 1e-4,
                "coarse terminal mismatch at level {level}"
            );
        }
    }
}

#[test]
fn coupled_loss_and_grad_match_every_level() {
    let dir = require_artifacts!();
    let rt = XlaRuntime::load(&dir).unwrap();
    let prob = rt.manifest().problem;
    let params = rt.manifest().load_init_params().unwrap();
    for level in 0..=prob.lmax {
        let batch = rt.grad_chunk(level);
        let n = prob.n_steps(level);
        let dw = BrownianSource::new(5).increments(
            Purpose::Grad, 1, level as u32, 0, batch, n, prob.dt(level),
        );
        let (hlo_loss, hlo_grad) =
            rt.grad_coupled_chunk(level, &params, &dw).unwrap();
        let (eng_loss, eng_grad) =
            engine::coupled_value_and_grad(&params, &dw, batch, level, &prob);
        close(eng_loss, hlo_loss, &format!("loss at level {level}"));
        let err = rel_l2_err(&eng_grad, &hlo_grad);
        assert!(
            err < 5e-3,
            "grad mismatch at level {level}: rel L2 err {err}"
        );
    }
}

#[test]
fn naive_grad_matches() {
    let dir = require_artifacts!();
    let rt = XlaRuntime::load(&dir).unwrap();
    let prob = rt.manifest().problem;
    let params = rt.manifest().load_init_params().unwrap();
    let batch = rt.naive_chunk();
    let n = prob.n_steps(prob.lmax);
    let dw = BrownianSource::new(6).increments(
        Purpose::Grad, 0, prob.lmax as u32, 0, batch, n, prob.dt(prob.lmax),
    );
    let (hlo_loss, hlo_grad) = rt.grad_naive_chunk(&params, &dw).unwrap();
    let (eng_loss, eng_grad) =
        engine::value_and_grad(&params, &dw, batch, n, &prob);
    close(eng_loss, hlo_loss, "naive loss");
    let err = rel_l2_err(&eng_grad, &hlo_grad);
    assert!(err < 5e-3, "naive grad mismatch: rel L2 err {err}");
}

#[test]
fn eval_loss_matches() {
    let dir = require_artifacts!();
    let rt = XlaRuntime::load(&dir).unwrap();
    let prob = rt.manifest().problem;
    let params = rt.manifest().load_init_params().unwrap();
    let batch = rt.eval_chunk();
    let n = prob.n_steps(prob.lmax);
    let dw = BrownianSource::new(8).increments(
        Purpose::Eval, 0, prob.lmax as u32, 0, batch, n, prob.dt(prob.lmax),
    );
    let hlo = rt.loss_eval_chunk(&params, &dw).unwrap();
    let eng = engine::loss_only(&params, &dw, batch, n, &prob);
    close(eng, hlo, "eval loss");
}

#[test]
fn grads_match_after_training_drift() {
    // Agreement must hold away from the init point too: nudge params
    // along a few native SGD steps, then compare again.
    let dir = require_artifacts!();
    let rt = XlaRuntime::load(&dir).unwrap();
    let prob = rt.manifest().problem;
    let mut params = rt.manifest().load_init_params().unwrap();
    let src = BrownianSource::new(13);
    for t in 0..5u64 {
        let dw = src.increments(
            Purpose::Grad, t, 1, 0, rt.grad_chunk(1), prob.n_steps(1), prob.dt(1),
        );
        let (_, g) = engine::coupled_value_and_grad(
            &params, &dw, rt.grad_chunk(1), 1, &prob,
        );
        for (p, &gv) in params.iter_mut().zip(&g) {
            *p -= 0.05 * gv;
        }
    }
    let level = 3;
    let dw = src.increments(
        Purpose::Grad, 99, level as u32, 0, rt.grad_chunk(level),
        prob.n_steps(level), prob.dt(level),
    );
    let (hlo_loss, hlo_grad) =
        rt.grad_coupled_chunk(level, &params, &dw).unwrap();
    let (eng_loss, eng_grad) = engine::coupled_value_and_grad(
        &params, &dw, rt.grad_chunk(level), level, &prob,
    );
    close(eng_loss, hlo_loss, "drifted loss");
    let err = rel_l2_err(&eng_grad, &hlo_grad);
    assert!(err < 5e-3, "drifted grad mismatch: rel L2 err {err}");
}
