//! Seeded property-testing kit (proptest substitute, offline build).
//!
//! Runs a property over many pseudo-random cases; on failure it reports
//! the failing case's seed so the exact input can be replayed, and
//! attempts a simple shrink (halving integer fields via the case's own
//! `shrink`) before reporting.

use crate::rng::Philox4x32;

/// Pseudo-random case generator handed to properties.
#[derive(Debug, Clone)]
pub struct Gen {
    rng: Philox4x32,
    counter: u64,
    pub case_seed: u64,
}

impl Gen {
    pub fn new(case_seed: u64) -> Self {
        Gen {
            rng: Philox4x32::new(case_seed),
            counter: 0,
            case_seed,
        }
    }

    fn next_u32(&mut self) -> u32 {
        let block = self.rng.block_at(0, self.counter / 4);
        let v = block[(self.counter % 4) as usize];
        self.counter += 1;
        v
    }

    pub fn u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.u64() % span) as i64
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.int(lo as i64, hi as i64) as usize
    }

    /// Uniform float in `[lo, hi)`.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * (self.next_u32() as f64 / 4294967296.0)
    }

    pub fn bool(&mut self) -> bool {
        self.next_u32() & 1 == 1
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize(0, items.len() - 1)]
    }
}

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 256, seed: 0x5EED }
    }
}

/// Run `prop` over `cfg.cases` random cases; panics with the failing
/// case seed on the first violation.
///
/// The property returns `Result<(), String>`: `Err` describes the
/// violation.
pub fn check<F>(name: &str, cfg: Config, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg
            .seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let mut g = Gen::new(case_seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property `{name}` failed on case {case} (replay seed \
                 {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Replay one specific failing case.
pub fn replay<F>(case_seed: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let mut g = Gen::new(case_seed);
    if let Err(msg) = prop(&mut g) {
        panic!("replayed case {case_seed:#x} fails: {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int_bounds_inclusive() {
        let mut g = Gen::new(1);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..1000 {
            let v = g.int(-2, 2);
            assert!((-2..=2).contains(&v));
            saw_lo |= v == -2;
            saw_hi |= v == 2;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn f64_in_range() {
        let mut g = Gen::new(2);
        for _ in 0..1000 {
            let v = g.f64(0.5, 1.5);
            assert!((0.5..1.5).contains(&v));
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = Gen::new(9);
        let mut b = Gen::new(9);
        for _ in 0..50 {
            assert_eq!(a.u64(), b.u64());
        }
    }

    #[test]
    fn check_passes_valid_property() {
        check("u64 halves fit", Config { cases: 64, seed: 1 }, |g| {
            let v = g.usize(0, 100);
            if v <= 100 {
                Ok(())
            } else {
                Err(format!("{v} > 100"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn check_reports_failing_seed() {
        check("always fails", Config { cases: 4, seed: 2 }, |_| {
            Err("nope".to_string())
        });
    }

    #[test]
    fn choose_covers_all_items() {
        let mut g = Gen::new(4);
        let items = [1, 2, 3];
        let mut seen = [false; 3];
        for _ in 0..200 {
            seen[*g.choose(&items) as usize - 1] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
