//! Welford's online algorithm for numerically stable streaming
//! mean/variance — used everywhere a running statistic is needed
//! (variance estimation, curve bands, bench harness).

/// Streaming mean / variance accumulator.
#[derive(Debug, Clone, Copy, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (n denominator); 0 for n < 2.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample variance (n-1 denominator); 0 for n < 2.
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Merge another accumulator (parallel reduction, Chan et al.).
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *other;
            return;
        }
        let n = (self.n + other.n) as f64;
        let delta = other.mean - self.mean;
        self.mean += delta * other.n as f64 / n;
        self.m2 += other.m2 + delta * delta * self.n as f64 * other.n as f64 / n;
        self.n += other.n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_two_pass() {
        let data = [1.5f64, -0.25, 3.0, 3.0, -7.5, 0.0];
        let mut w = Welford::new();
        for &x in &data {
            w.push(x);
        }
        let mean = data.iter().sum::<f64>() / data.len() as f64;
        let var =
            data.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / data.len() as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn degenerate_counts() {
        let mut w = Welford::new();
        assert_eq!(w.variance(), 0.0);
        w.push(5.0);
        assert_eq!(w.mean(), 5.0);
        assert_eq!(w.variance(), 0.0);
        assert_eq!(w.sample_variance(), 0.0);
    }

    #[test]
    fn merge_equals_sequential() {
        let data: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0).collect();
        let mut whole = Welford::new();
        for &x in &data {
            whole.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &data[..37] {
            a.push(x);
        }
        for &x in &data[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.variance() - whole.variance()).abs() < 1e-12);
        assert_eq!(a.count(), whole.count());
    }

    #[test]
    fn stable_with_large_offset() {
        // classic catastrophic-cancellation case
        let mut w = Welford::new();
        for x in [1e9 + 4.0, 1e9 + 7.0, 1e9 + 13.0, 1e9 + 16.0] {
            w.push(x);
        }
        assert!((w.sample_variance() - 30.0).abs() < 1e-6, "{}", w.sample_variance());
    }
}
