//! CSV / JSONL output for learning curves and experiment results.
//!
//! Output layout (under `--out-dir`):
//!   `curve_<method>_seed<k>.csv`   one row per evaluation point
//!   `runs.jsonl`                   one JSON object per completed run
//!
//! Run manifests are **reproducible across runs**: execution telemetry is
//! keyed by the pool's stable worker indices (0..P), never by thread ids
//! (which the OS hands out differently every run).

use std::fs::{self, File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::Path;

use super::recorder::LearningCurve;
use crate::exec::ExecStats;
use crate::util::json::{obj, Json};

/// Write one curve as CSV (header + one row per point).
pub fn write_csv(path: &Path, curve: &LearningCurve) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir)?;
    }
    let mut w = BufWriter::new(File::create(path)?);
    writeln!(w, "step,loss,std_cost,par_cost,grad_norm")?;
    for p in &curve.points {
        writeln!(
            w,
            "{},{},{},{},{}",
            p.step, p.loss, p.std_cost, p.par_cost, p.grad_norm
        )?;
    }
    w.flush()
}

/// Append one run-summary JSON object to a JSONL file.
pub fn write_jsonl(path: &Path, curve: &LearningCurve) -> std::io::Result<()> {
    write_jsonl_exec(path, curve, None)
}

/// The manifest's execution block: per-worker busy seconds indexed by the
/// pool's stable worker id (array position == worker index), plus the
/// run's makespan/utilization aggregates and the per-dispatch
/// makespan/overhead distribution tails (nearest-rank p50/p95 and max —
/// a mean alone hides stragglers).
fn exec_json(stats: &ExecStats) -> Json {
    let busy: Vec<Json> = stats
        .busy_per_worker
        .iter()
        .map(|d| Json::Num(d.as_secs_f64()))
        .collect();
    obj(vec![
        ("workers", Json::Num(stats.busy_per_worker.len() as f64)),
        ("steps", Json::Num(stats.steps as f64)),
        ("tasks", Json::Num(stats.tasks as f64)),
        ("total_makespan_s", Json::Num(stats.total_makespan())),
        ("mean_step_makespan_s", Json::Num(stats.mean_makespan())),
        ("p50_step_makespan_s", Json::Num(stats.makespan_percentile(0.5))),
        ("p95_step_makespan_s", Json::Num(stats.makespan_percentile(0.95))),
        ("max_step_makespan_s", Json::Num(stats.max_makespan())),
        (
            "mean_dispatch_overhead_s",
            Json::Num(stats.mean_dispatch_overhead()),
        ),
        (
            "p50_dispatch_overhead_s",
            Json::Num(stats.overhead_percentile(0.5)),
        ),
        (
            "p95_dispatch_overhead_s",
            Json::Num(stats.overhead_percentile(0.95)),
        ),
        ("max_dispatch_overhead_s", Json::Num(stats.max_overhead())),
        ("utilization", Json::Num(stats.utilization())),
        ("per_worker_busy_s", Json::Arr(busy)),
    ])
}

/// Append one run-summary JSON object, optionally carrying the pool's
/// execution telemetry ([`ExecStats`], worker-index keyed).
pub fn write_jsonl_exec(
    path: &Path,
    curve: &LearningCurve,
    exec: Option<&ExecStats>,
) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir)?;
    }
    let mut w = OpenOptions::new().create(true).append(true).open(path)?;
    let summary = obj(vec![
        ("method", Json::Str(curve.method.clone())),
        ("seed", Json::Num(curve.seed as f64)),
        ("points", Json::Num(curve.points.len() as f64)),
        (
            "final_loss",
            curve.final_loss().map(Json::Num).unwrap_or(Json::Null),
        ),
        (
            "best_loss",
            curve.best_loss().map(Json::Num).unwrap_or(Json::Null),
        ),
        (
            "total_std_cost",
            curve
                .points
                .last()
                .map(|p| Json::Num(p.std_cost))
                .unwrap_or(Json::Null),
        ),
        (
            "total_par_cost",
            curve
                .points
                .last()
                .map(|p| Json::Num(p.par_cost))
                .unwrap_or(Json::Null),
        ),
        ("exec", exec.map(exec_json).unwrap_or(Json::Null)),
    ]);
    writeln!(w, "{summary}")
}

/// The single policy for WHERE experiment output lands: a named run
/// directory `<out_dir>/<run>/` that every artifact of one experiment
/// run shares. [`crate::experiments::ExperimentRunner`] hands one of
/// these to each experiment — no experiment hand-rolls its own output
/// path anymore.
///
/// Bench JSONs ([`Self::write_bench_json`]) are additionally aliased at
/// the historical top-level location `./<stem>.json` (the path `make
/// bench-*` and CI schema checks key on), so moving the canonical copy
/// under the run directory broke nothing downstream.
#[derive(Debug, Clone)]
pub struct RunArtifacts {
    dir: std::path::PathBuf,
    run: String,
}

impl RunArtifacts {
    /// Create (or reuse) the run directory `<out_dir>/<run>/`.
    pub fn create(out_dir: &Path, run: &str) -> std::io::Result<RunArtifacts> {
        let dir = out_dir.join(run);
        fs::create_dir_all(&dir)?;
        Ok(RunArtifacts { dir, run: run.to_string() })
    }

    /// The run's directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The run's name.
    pub fn run(&self) -> &str {
        &self.run
    }

    /// A path inside the run directory.
    pub fn path(&self, file: &str) -> std::path::PathBuf {
        self.dir.join(file)
    }

    /// Write a text artifact (tables, CSV strings); returns its path.
    pub fn write_text(&self, file: &str, text: &str) -> std::io::Result<std::path::PathBuf> {
        let path = self.path(file);
        fs::write(&path, text)?;
        Ok(path)
    }

    /// Write one curve as `curve_<method>_seed<k>.csv` in the run dir.
    pub fn write_curve_csv(&self, curve: &LearningCurve) -> std::io::Result<std::path::PathBuf> {
        let path = self.path(&format!("curve_{}_seed{}.csv", curve.method, curve.seed));
        write_csv(&path, curve)?;
        Ok(path)
    }

    /// Append a run summary (plus optional execution telemetry) to the
    /// run's `runs.jsonl`.
    pub fn append_run_jsonl(
        &self,
        curve: &LearningCurve,
        exec: Option<&ExecStats>,
    ) -> std::io::Result<std::path::PathBuf> {
        let path = self.path("runs.jsonl");
        write_jsonl_exec(&path, curve, exec)?;
        Ok(path)
    }

    /// Write a JSON document (newline-terminated) into the run dir —
    /// non-bench JSON artifacts like `repro serve`'s final `status.json`
    /// (no top-level alias; see [`Self::write_bench_json`] for that).
    pub fn write_json(&self, file: &str, doc: &Json) -> std::io::Result<std::path::PathBuf> {
        self.write_text(file, &format!("{doc}\n"))
    }

    /// Write a bench document as `<stem>.json` in the run dir AND at the
    /// historical top-level alias `./<stem>.json` (what `make bench-*`
    /// and the CI schema checks read). Returns the canonical (run-dir)
    /// path.
    pub fn write_bench_json(&self, stem: &str, doc: &Json) -> std::io::Result<std::path::PathBuf> {
        let text = format!("{doc}\n");
        let path = self.path(&format!("{stem}.json"));
        fs::write(&path, &text)?;
        fs::write(format!("{stem}.json"), &text)?;
        Ok(path)
    }
}

/// Read a CSV produced by [`write_csv`] back into a curve (used by the
/// aggregation tooling and round-trip tests).
pub fn read_csv(path: &Path) -> std::io::Result<LearningCurve> {
    let text = fs::read_to_string(path)?;
    let mut curve = LearningCurve::default();
    for (i, line) in text.lines().enumerate() {
        if i == 0 || line.trim().is_empty() {
            continue;
        }
        let cols: Vec<&str> = line.split(',').collect();
        if cols.len() != 5 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("bad csv row {i}: `{line}`"),
            ));
        }
        let f = |s: &str| -> std::io::Result<f64> {
            s.parse().map_err(|_| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("bad number `{s}` in row {i}"),
                )
            })
        };
        curve.points.push(super::recorder::CurvePoint {
            step: f(cols[0])? as usize,
            loss: f(cols[1])?,
            std_cost: f(cols[2])?,
            par_cost: f(cols[3])?,
            grad_norm: f(cols[4])?,
        });
    }
    Ok(curve)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::recorder::CurvePoint;

    /// Unique-per-call temp dir from a process-stable counter — no
    /// thread-id tagging (thread ids differ run to run; a monotone index
    /// names the same dirs every run, matching the manifest policy).
    fn tempdir() -> std::path::PathBuf {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static SEQ: AtomicUsize = AtomicUsize::new(0);
        let dir = std::env::temp_dir().join(format!(
            "dmlmc_test_{}_{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn curve() -> LearningCurve {
        let mut c = LearningCurve::new("mlmc", 3);
        c.push(CurvePoint {
            step: 0,
            loss: 2.5,
            std_cost: 10.0,
            par_cost: 1.0,
            grad_norm: 0.7,
        });
        c.push(CurvePoint {
            step: 5,
            loss: 1.25,
            std_cost: 60.0,
            par_cost: 6.0,
            grad_norm: 0.2,
        });
        c
    }

    #[test]
    fn csv_roundtrip() {
        let path = tempdir().join("curve.csv");
        let c = curve();
        write_csv(&path, &c).unwrap();
        let back = read_csv(&path).unwrap();
        assert_eq!(back.points, c.points);
    }

    #[test]
    fn jsonl_appends_valid_json() {
        let path = tempdir().join("runs.jsonl");
        let _ = fs::remove_file(&path);
        write_jsonl(&path, &curve()).unwrap();
        write_jsonl(&path, &curve()).unwrap();
        let text = fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            let j = Json::parse(line).unwrap();
            assert_eq!(j.get("method").unwrap().as_str(), Some("mlmc"));
            assert_eq!(j.get("final_loss").unwrap().as_f64(), Some(1.25));
        }
    }

    #[test]
    fn jsonl_exec_block_uses_stable_worker_indices() {
        use std::time::Duration;
        let path = tempdir().join("runs_exec.jsonl");
        let _ = fs::remove_file(&path);
        let mut stats = crate::exec::ExecStats::new(2);
        stats.record(&crate::exec::StepExecReport {
            workers: vec![
                crate::exec::WorkerStat {
                    worker: 0,
                    busy: Duration::from_millis(30),
                    tasks: 3,
                    core: None,
                },
                crate::exec::WorkerStat {
                    worker: 1,
                    busy: Duration::from_millis(10),
                    tasks: 1,
                    core: None,
                },
            ],
            makespan: Duration::from_millis(40),
            n_tasks: 4,
            per_task: Vec::new(),
        });
        write_jsonl_exec(&path, &curve(), Some(&stats)).unwrap();
        let text = fs::read_to_string(&path).unwrap();
        let j = Json::parse(text.lines().next().unwrap()).unwrap();
        let exec = j.get("exec").unwrap();
        assert_eq!(exec.get("workers").unwrap().as_usize(), Some(2));
        assert_eq!(exec.get("tasks").unwrap().as_usize(), Some(4));
        // dispatch overhead: 40 ms makespan - 30 ms max busy = 10 ms
        assert!(
            (exec
                .get("mean_dispatch_overhead_s")
                .unwrap()
                .as_f64()
                .unwrap()
                - 0.01)
                .abs()
                < 1e-9
        );
        let busy = exec.get("per_worker_busy_s").unwrap().as_arr().unwrap();
        // array position IS the worker index — stable across runs
        assert_eq!(busy.len(), 2);
        assert!((busy[0].as_f64().unwrap() - 0.03).abs() < 1e-9);
        assert!((busy[1].as_f64().unwrap() - 0.01).abs() < 1e-9);
        // distribution tails survive to disk (single dispatch: every
        // percentile collapses onto the one observation)
        for key in [
            "p50_step_makespan_s",
            "p95_step_makespan_s",
            "max_step_makespan_s",
        ] {
            assert!(
                (exec.get(key).unwrap().as_f64().unwrap() - 0.04).abs() < 1e-9,
                "{key}"
            );
        }
        for key in [
            "p50_dispatch_overhead_s",
            "p95_dispatch_overhead_s",
            "max_dispatch_overhead_s",
        ] {
            assert!(
                (exec.get(key).unwrap().as_f64().unwrap() - 0.01).abs() < 1e-9,
                "{key}"
            );
        }
        // no exec stats -> explicit null, row still parses
        write_jsonl(&path, &curve()).unwrap();
        let text = fs::read_to_string(&path).unwrap();
        let j2 = Json::parse(text.lines().nth(1).unwrap()).unwrap();
        assert_eq!(j2.get("exec"), Some(&Json::Null));
    }

    #[test]
    fn run_artifacts_share_one_directory_and_alias_bench_json() {
        let out = tempdir();
        let arts = RunArtifacts::create(&out, "smoke").unwrap();
        assert_eq!(arts.run(), "smoke");
        assert_eq!(arts.dir(), out.join("smoke"));
        // text + curve + jsonl all land inside the run directory
        let t = arts.write_text("table.txt", "hello\n").unwrap();
        assert_eq!(fs::read_to_string(&t).unwrap(), "hello\n");
        let c = arts.write_curve_csv(&curve()).unwrap();
        assert_eq!(c, arts.path("curve_mlmc_seed3.csv"));
        assert_eq!(read_csv(&c).unwrap().points, curve().points);
        let j = arts.append_run_jsonl(&curve(), None).unwrap();
        assert_eq!(j, arts.path("runs.jsonl"));
        assert!(Json::parse(fs::read_to_string(&j).unwrap().trim()).is_ok());
        // plain json: run-dir only, newline-terminated, parseable
        let s = arts
            .write_json("status.json", &obj(vec![("ticks", Json::Num(4.0))]))
            .unwrap();
        assert_eq!(s, arts.path("status.json"));
        let text = fs::read_to_string(&s).unwrap();
        assert!(text.ends_with('\n'));
        assert_eq!(
            Json::parse(text.trim()).unwrap().get("ticks").unwrap().as_usize(),
            Some(4)
        );
        // bench json: canonical copy in the run dir, alias at the
        // historical top-level path, identical bytes
        let doc = obj(vec![("bench", Json::Str("unit".into()))]);
        let b = arts.write_bench_json("BENCH_unit_test", &doc).unwrap();
        assert_eq!(b, arts.path("BENCH_unit_test.json"));
        let canonical = fs::read_to_string(&b).unwrap();
        let alias = fs::read_to_string("BENCH_unit_test.json").unwrap();
        assert_eq!(canonical, alias);
        assert!(canonical.contains("\"bench\""));
        let _ = fs::remove_file("BENCH_unit_test.json");
    }

    #[test]
    fn read_rejects_malformed() {
        let path = tempdir().join("bad.csv");
        fs::write(&path, "step,loss,std_cost,par_cost,grad_norm\n1,2\n").unwrap();
        assert!(read_csv(&path).is_err());
    }
}
