//! Cross-seed aggregation: the mean ± std bands of Figure 2.
//!
//! All runs of one method share the same evaluation schedule (same steps,
//! same deterministic cost accounting), so aggregation is pointwise over
//! the common grid; a mismatch is a bug and is reported as an error.

use super::recorder::LearningCurve;
use super::welford::Welford;

/// Aggregated curve: per evaluation point, mean and std of the loss over
/// seeds, with the (shared) cost axes.
#[derive(Debug, Clone, Default)]
pub struct AggregatedCurve {
    pub method: String,
    pub n_runs: usize,
    pub steps: Vec<usize>,
    pub std_cost: Vec<f64>,
    pub par_cost: Vec<f64>,
    pub loss_mean: Vec<f64>,
    pub loss_std: Vec<f64>,
}

/// Aggregate same-method curves over seeds.
pub fn aggregate_curves(curves: &[LearningCurve]) -> Result<AggregatedCurve, String> {
    let first = curves.first().ok_or("no curves to aggregate")?;
    let n_pts = first.points.len();
    for c in curves {
        if c.method != first.method {
            return Err(format!(
                "mixed methods: `{}` vs `{}`",
                c.method, first.method
            ));
        }
        if c.points.len() != n_pts {
            return Err(format!(
                "curve length mismatch: {} vs {n_pts} (seed {})",
                c.points.len(),
                c.seed
            ));
        }
        for (a, b) in c.points.iter().zip(&first.points) {
            if a.step != b.step {
                return Err(format!(
                    "evaluation grids differ at step {} vs {}",
                    a.step, b.step
                ));
            }
        }
    }
    let mut agg = AggregatedCurve {
        method: first.method.clone(),
        n_runs: curves.len(),
        ..Default::default()
    };
    for i in 0..n_pts {
        let mut w = Welford::new();
        let mut std_cost = Welford::new();
        let mut par_cost = Welford::new();
        for c in curves {
            w.push(c.points[i].loss);
            std_cost.push(c.points[i].std_cost);
            par_cost.push(c.points[i].par_cost);
        }
        agg.steps.push(first.points[i].step);
        // Costs may differ slightly across seeds for DMLMC only via eval
        // cadence (they don't in practice); record the mean.
        agg.std_cost.push(std_cost.mean());
        agg.par_cost.push(par_cost.mean());
        agg.loss_mean.push(w.mean());
        agg.loss_std.push(w.std());
    }
    Ok(agg)
}

impl AggregatedCurve {
    /// Render as the CSV consumed by the plotting/reporting scripts.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("step,std_cost,par_cost,loss_mean,loss_std\n");
        for i in 0..self.steps.len() {
            out.push_str(&format!(
                "{},{},{},{},{}\n",
                self.steps[i],
                self.std_cost[i],
                self.par_cost[i],
                self.loss_mean[i],
                self.loss_std[i]
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::recorder::CurvePoint;

    fn mk(method: &str, seed: u64, losses: &[f64]) -> LearningCurve {
        let mut c = LearningCurve::new(method, seed);
        for (i, &l) in losses.iter().enumerate() {
            c.push(CurvePoint {
                step: i,
                loss: l,
                std_cost: i as f64,
                par_cost: i as f64 * 0.5,
                grad_norm: 0.0,
            });
        }
        c
    }

    #[test]
    fn mean_and_std_pointwise() {
        let a = mk("m", 0, &[2.0, 1.0]);
        let b = mk("m", 1, &[4.0, 3.0]);
        let agg = aggregate_curves(&[a, b]).unwrap();
        assert_eq!(agg.n_runs, 2);
        assert_eq!(agg.loss_mean, vec![3.0, 2.0]);
        assert_eq!(agg.loss_std, vec![1.0, 1.0]);
        assert_eq!(agg.steps, vec![0, 1]);
    }

    #[test]
    fn rejects_mismatched_curves() {
        let a = mk("m", 0, &[1.0, 2.0]);
        let b = mk("m", 1, &[1.0]);
        assert!(aggregate_curves(&[a.clone(), b]).is_err());
        let c = mk("other", 1, &[1.0, 2.0]);
        assert!(aggregate_curves(&[a, c]).is_err());
        assert!(aggregate_curves(&[]).is_err());
    }

    #[test]
    fn csv_render_has_header_and_rows() {
        let agg = aggregate_curves(&[mk("m", 0, &[1.0, 0.5])]).unwrap();
        let csv = agg.to_csv();
        assert!(csv.starts_with("step,"));
        assert_eq!(csv.lines().count(), 3);
    }
}
