//! Learning-curve recording: one point per evaluation, carrying all three
//! x-axes the paper plots against (iteration, cumulative standard
//! complexity, cumulative parallel complexity).

/// One evaluation point on a learning curve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CurvePoint {
    pub step: usize,
    /// Held-out loss F_lmax (the y-axis of Figure 2).
    pub loss: f64,
    /// Cumulative standard complexity (work units) up to this step.
    pub std_cost: f64,
    /// Cumulative parallel complexity (depth units) up to this step.
    pub par_cost: f64,
    /// Norm of the gradient estimate used at this step.
    pub grad_norm: f64,
}

/// A full training trajectory for one (method, seed) run.
#[derive(Debug, Clone, Default)]
pub struct LearningCurve {
    pub method: String,
    pub seed: u64,
    pub points: Vec<CurvePoint>,
}

impl LearningCurve {
    pub fn new(method: &str, seed: u64) -> Self {
        LearningCurve {
            method: method.to_string(),
            seed,
            points: Vec::new(),
        }
    }

    pub fn push(&mut self, p: CurvePoint) {
        debug_assert!(
            self.points.last().map_or(true, |last| {
                p.step >= last.step
                    && p.std_cost >= last.std_cost
                    && p.par_cost >= last.par_cost
            }),
            "curve must be monotone in step and costs"
        );
        self.points.push(p);
    }

    pub fn final_loss(&self) -> Option<f64> {
        self.points.last().map(|p| p.loss)
    }

    /// First point whose loss is at or below `target`, by parallel cost —
    /// the "cost to reach accuracy" metric used in EXPERIMENTS.md.
    pub fn par_cost_to_reach(&self, target: f64) -> Option<f64> {
        self.points
            .iter()
            .find(|p| p.loss <= target)
            .map(|p| p.par_cost)
    }

    /// Same, by standard cost.
    pub fn std_cost_to_reach(&self, target: f64) -> Option<f64> {
        self.points
            .iter()
            .find(|p| p.loss <= target)
            .map(|p| p.std_cost)
    }

    /// Minimum loss seen anywhere on the curve.
    pub fn best_loss(&self) -> Option<f64> {
        self.points
            .iter()
            .map(|p| p.loss)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve() -> LearningCurve {
        let mut c = LearningCurve::new("dmlmc", 0);
        for (i, loss) in [4.0, 2.0, 1.0, 1.2, 0.5].iter().enumerate() {
            c.push(CurvePoint {
                step: i * 10,
                loss: *loss,
                std_cost: (i as f64 + 1.0) * 100.0,
                par_cost: (i as f64 + 1.0) * 10.0,
                grad_norm: 1.0,
            });
        }
        c
    }

    #[test]
    fn final_and_best_loss() {
        let c = curve();
        assert_eq!(c.final_loss(), Some(0.5));
        assert_eq!(c.best_loss(), Some(0.5));
        assert_eq!(LearningCurve::new("x", 0).final_loss(), None);
    }

    #[test]
    fn cost_to_reach_finds_first_crossing() {
        let c = curve();
        assert_eq!(c.par_cost_to_reach(1.0), Some(30.0));
        assert_eq!(c.std_cost_to_reach(1.0), Some(300.0));
        assert_eq!(c.par_cost_to_reach(0.01), None);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "monotone")]
    fn non_monotone_push_panics_in_debug() {
        let mut c = curve();
        c.push(CurvePoint {
            step: 0,
            loss: 1.0,
            std_cost: 0.0,
            par_cost: 0.0,
            grad_norm: 0.0,
        });
    }
}
