//! Metrics: streaming statistics, learning-curve recording, CSV/JSONL
//! output, and cross-seed aggregation (the mean ± std bands of Figure 2).

pub mod aggregate;
pub mod recorder;
pub mod welford;
pub mod writer;

pub use aggregate::aggregate_curves;
pub use recorder::{CurvePoint, LearningCurve};
pub use welford::Welford;
pub use writer::{write_csv, write_jsonl, write_jsonl_exec, RunArtifacts};
