//! [`Scenario`] — one (SDE dynamics, path payoff) pair, the unit the
//! registry hands out and the engine simulates.

use std::sync::Arc;

use crate::hedging::Problem;

use super::payoff::{EuropeanCall, Payoff};
use super::sde::{BlackScholes, Sde};

/// Registry key of the seed scenario: the problem's own Black–Scholes
/// dynamics hedging a European call. Everything built before the scenario
/// subsystem (the AOT artifacts, the regression anchors) assumes it.
pub const DEFAULT_SCENARIO: &str = "bs-call";

/// One simulation scenario: dynamics plus payoff. Cheap to clone (the
/// trait objects are shared), so backends can own one.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Registry key, e.g. `"bs-call"`, `"ou-asian"`, `"cir-digital"`.
    pub name: String,
    pub sde: Arc<dyn Sde>,
    pub payoff: Arc<dyn Payoff>,
}

impl Scenario {
    /// The default scenario for a problem — the seed engine's hard-coded
    /// behavior (drift form from `problem.drift`, European call at the
    /// problem's strike), reproduced bitwise.
    pub fn from_problem(p: &Problem) -> Scenario {
        Scenario {
            name: DEFAULT_SCENARIO.to_string(),
            sde: Arc::new(BlackScholes::from_problem(p)),
            payoff: Arc::new(EuropeanCall {
                strike: p.strike as f32,
            }),
        }
    }

    /// Whether this is the default scenario (the only one the AOT/XLA
    /// artifacts are lowered for).
    pub fn is_default(&self) -> bool {
        self.name == DEFAULT_SCENARIO
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_scenario_mirrors_problem() {
        let p = Problem::default();
        let sc = Scenario::from_problem(&p);
        assert!(sc.is_default());
        assert_eq!(sc.name, "bs-call");
        assert_eq!(sc.sde.s0(), p.s0 as f32);
        // additive drift by default: a(s) independent of s
        assert_eq!(sc.sde.drift(1.0), sc.sde.drift(5.0));
        // payoff kinks at the problem strike
        assert_eq!(sc.payoff.value(&[3.0, 2.9]), 0.0);
        assert!((sc.payoff.value(&[3.0, 3.5]) - 0.5).abs() < 1e-7);
    }

    #[test]
    fn clone_shares_components() {
        let sc = Scenario::from_problem(&Problem::default());
        let cl = sc.clone();
        assert_eq!(cl.name, sc.name);
        assert_eq!(cl.sde.drift(2.0), sc.sde.drift(2.0));
    }
}
