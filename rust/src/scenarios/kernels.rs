//! Statically dispatched scenario kernels: one **monomorphized**
//! `value_and_grad` / `coupled_value_and_grad` / `loss_only`
//! instantiation per registered `SDE_KEYS x PAYOFF_KEYS` combination,
//! selected **once per dispatch** by string key instead of paying a
//! `dyn Sde` / `dyn Payoff` virtual call per step per path.
//!
//! Construction: zero-sized *ctor marker* types ([`SdeCtor`] /
//! [`PayoffCtor`]) encode how the registry builds each component from the
//! [`Problem`] (`bs` and `gbm` are both [`BlackScholes`], differing only
//! in their constructor — a plain type parameter could not distinguish
//! them). The `entry!` macro instantiates the generic kernel bodies for
//! every pair and coerces the resulting fn items into plain fn pointers,
//! so [`KERNELS`] is a flat `static` table with no allocation, no
//! `dyn`, and no lazy initialization.
//!
//! Each entry carries **two** kernel sets:
//!
//! * `scalar` — the streaming scalar body
//!   ([`crate::engine::objective`]). Static dispatch of the *same*
//!   generic body performs the identical f32 operations in identical
//!   order as the `dyn` path (rustc has no fast-math), so scalar kernels
//!   are **bit-identical** to the dynamic reference — the `bs-call`
//!   bitwise anchors hold through the rerouted backend.
//! * `lanes` — the lane-blocked SIMD body ([`crate::engine::lanes`]),
//!   8 paths per block. It reassociates f32 reductions and uses a
//!   polynomial `exp`, so it is selected only under the scenario's
//!   `*-simd` variant key ([`resolve`]) and validated against the scalar
//!   reference with relative tolerances (`tests/kernel_suite.rs`).

use crate::hedging::Problem;

use super::payoff::{
    AsianCall, DigitalCall, DownAndInPut, EuropeanCall, EuropeanPut,
    LookbackCall, Payoff, UpAndOutCall,
};
use super::registry::{DOWN_BARRIER_MULT, UP_BARRIER_MULT};
use super::sde::{BlackScholes, CoxIngersollRoss, Heston, OrnsteinUhlenbeck, Sde};
use crate::engine::{lanes, objective};

/// How a registry SDE key builds its concrete dynamics. Implemented by
/// zero-sized marker types so `bs` and `gbm` (same concrete type,
/// different constructor) monomorphize distinct kernels.
pub trait SdeCtor {
    type S: Sde;
    const DIM: usize;
    fn build(p: &Problem) -> Self::S;
}

/// How a registry payoff key builds its concrete payoff — strike and
/// barrier placement exactly as [`super::registry::build_scenario`].
pub trait PayoffCtor {
    type P: Payoff;
    fn build(p: &Problem) -> Self::P;
}

/// `bs`: the problem's own drift form.
pub struct BsKey;
/// `gbm`: forced geometric drift.
pub struct GbmKey;
/// `ou`: Ornstein–Uhlenbeck.
pub struct OuKey;
/// `cir`: Cox–Ingersoll–Ross.
pub struct CirKey;
/// `heston`: 2-factor stochastic vol.
pub struct HestonKey;

impl SdeCtor for BsKey {
    type S = BlackScholes;
    const DIM: usize = 1;
    fn build(p: &Problem) -> BlackScholes {
        BlackScholes::from_problem(p)
    }
}
impl SdeCtor for GbmKey {
    type S = BlackScholes;
    const DIM: usize = 1;
    fn build(p: &Problem) -> BlackScholes {
        BlackScholes::geometric(p)
    }
}
impl SdeCtor for OuKey {
    type S = OrnsteinUhlenbeck;
    const DIM: usize = 1;
    fn build(p: &Problem) -> OrnsteinUhlenbeck {
        OrnsteinUhlenbeck::from_problem(p)
    }
}
impl SdeCtor for CirKey {
    type S = CoxIngersollRoss;
    const DIM: usize = 1;
    fn build(p: &Problem) -> CoxIngersollRoss {
        CoxIngersollRoss::from_problem(p)
    }
}
impl SdeCtor for HestonKey {
    type S = Heston;
    const DIM: usize = 2;
    fn build(p: &Problem) -> Heston {
        Heston::from_problem(p)
    }
}

/// `call`.
pub struct CallKey;
/// `put`.
pub struct PutKey;
/// `asian`.
pub struct AsianKey;
/// `lookback`.
pub struct LookbackKey;
/// `digital`.
pub struct DigitalKey;
/// `uo-call`.
pub struct UoCallKey;
/// `di-put`.
pub struct DiPutKey;

impl PayoffCtor for CallKey {
    type P = EuropeanCall;
    fn build(p: &Problem) -> EuropeanCall {
        EuropeanCall {
            strike: p.strike as f32,
        }
    }
}
impl PayoffCtor for PutKey {
    type P = EuropeanPut;
    fn build(p: &Problem) -> EuropeanPut {
        EuropeanPut {
            strike: p.strike as f32,
        }
    }
}
impl PayoffCtor for AsianKey {
    type P = AsianCall;
    fn build(p: &Problem) -> AsianCall {
        AsianCall {
            strike: p.strike as f32,
        }
    }
}
impl PayoffCtor for LookbackKey {
    type P = LookbackCall;
    fn build(_p: &Problem) -> LookbackCall {
        LookbackCall
    }
}
impl PayoffCtor for DigitalKey {
    type P = DigitalCall;
    fn build(p: &Problem) -> DigitalCall {
        DigitalCall {
            strike: p.strike as f32,
        }
    }
}
impl PayoffCtor for UoCallKey {
    type P = UpAndOutCall;
    fn build(p: &Problem) -> UpAndOutCall {
        UpAndOutCall {
            strike: p.strike as f32,
            barrier: (p.s0 * UP_BARRIER_MULT) as f32,
        }
    }
}
impl PayoffCtor for DiPutKey {
    type P = DownAndInPut;
    fn build(p: &Problem) -> DownAndInPut {
        DownAndInPut {
            strike: p.strike as f32,
            barrier: (p.s0 * DOWN_BARRIER_MULT) as f32,
        }
    }
}

// -------------------------------------------------------------------------
// Generic kernel bodies — one monomorphization per (SdeCtor, PayoffCtor).
// -------------------------------------------------------------------------

fn scalar_vg<SK: SdeCtor, PK: PayoffCtor>(
    params: &[f32],
    dw: &[f32],
    batch: usize,
    n_steps: usize,
    problem: &Problem,
) -> (f64, Vec<f32>) {
    let sde = SK::build(problem);
    let payoff = PK::build(problem);
    objective::value_and_grad_impl(params, dw, batch, n_steps, problem, &sde, &payoff)
}

fn scalar_cvg<SK: SdeCtor, PK: PayoffCtor>(
    params: &[f32],
    dw_fine: &[f32],
    batch: usize,
    level: usize,
    problem: &Problem,
) -> (f64, Vec<f32>) {
    let sde = SK::build(problem);
    let payoff = PK::build(problem);
    objective::coupled_value_and_grad_impl(
        params, dw_fine, batch, level, problem, &sde, &payoff,
    )
}

fn scalar_loss<SK: SdeCtor, PK: PayoffCtor>(
    params: &[f32],
    dw: &[f32],
    batch: usize,
    n_steps: usize,
    problem: &Problem,
) -> f64 {
    let sde = SK::build(problem);
    let payoff = PK::build(problem);
    objective::loss_only_impl(params, dw, batch, n_steps, problem, &sde, &payoff)
}

fn lanes_vg<SK: SdeCtor, PK: PayoffCtor>(
    params: &[f32],
    dw: &[f32],
    batch: usize,
    n_steps: usize,
    problem: &Problem,
) -> (f64, Vec<f32>) {
    let sde = SK::build(problem);
    let payoff = PK::build(problem);
    lanes::value_and_grad(params, dw, batch, n_steps, problem, &sde, &payoff)
}

fn lanes_cvg<SK: SdeCtor, PK: PayoffCtor>(
    params: &[f32],
    dw_fine: &[f32],
    batch: usize,
    level: usize,
    problem: &Problem,
) -> (f64, Vec<f32>) {
    let sde = SK::build(problem);
    let payoff = PK::build(problem);
    lanes::coupled_value_and_grad(
        params, dw_fine, batch, level, problem, &sde, &payoff,
    )
}

fn lanes_loss<SK: SdeCtor, PK: PayoffCtor>(
    params: &[f32],
    dw: &[f32],
    batch: usize,
    n_steps: usize,
    problem: &Problem,
) -> f64 {
    let sde = SK::build(problem);
    let payoff = PK::build(problem);
    lanes::loss_only(params, dw, batch, n_steps, problem, &sde, &payoff)
}

// -------------------------------------------------------------------------
// The flat kernel table.
// -------------------------------------------------------------------------

/// The three objective entry points of one kernel variant, as plain fn
/// pointers. `value_and_grad` / `loss_only` take
/// `(params, dw, batch, n_steps, problem)`; `coupled_value_and_grad`
/// takes `(params, dw_fine, batch, level, problem)` — the signatures of
/// the [`crate::engine::objective`] entry points minus the scenario.
#[derive(Debug, Clone, Copy)]
pub struct KernelFns {
    pub value_and_grad: fn(&[f32], &[f32], usize, usize, &Problem) -> (f64, Vec<f32>),
    pub coupled_value_and_grad:
        fn(&[f32], &[f32], usize, usize, &Problem) -> (f64, Vec<f32>),
    pub loss_only: fn(&[f32], &[f32], usize, usize, &Problem) -> f64,
}

/// One registered scenario's monomorphized kernels.
#[derive(Debug, Clone, Copy)]
pub struct ScenarioKernel {
    /// Base registry key (never `-simd`-suffixed).
    pub name: &'static str,
    /// Brownian factor count of the dynamics.
    pub dim: usize,
    /// Bit-identical scalar kernels (streaming reference body).
    pub scalar: KernelFns,
    /// Lane-blocked SIMD kernels (tolerance-validated, `*-simd` keys).
    pub lanes: KernelFns,
}

macro_rules! entry {
    ($name:expr, $sde:ty, $payoff:ty) => {
        ScenarioKernel {
            name: $name,
            dim: <$sde as SdeCtor>::DIM,
            scalar: KernelFns {
                value_and_grad: scalar_vg::<$sde, $payoff>,
                coupled_value_and_grad: scalar_cvg::<$sde, $payoff>,
                loss_only: scalar_loss::<$sde, $payoff>,
            },
            lanes: KernelFns {
                value_and_grad: lanes_vg::<$sde, $payoff>,
                coupled_value_and_grad: lanes_cvg::<$sde, $payoff>,
                loss_only: lanes_loss::<$sde, $payoff>,
            },
        }
    };
}

macro_rules! sde_row {
    ($sde_key:literal, $sde:ty) => {
        [
            entry!(concat!($sde_key, "-call"), $sde, CallKey),
            entry!(concat!($sde_key, "-put"), $sde, PutKey),
            entry!(concat!($sde_key, "-asian"), $sde, AsianKey),
            entry!(concat!($sde_key, "-lookback"), $sde, LookbackKey),
            entry!(concat!($sde_key, "-digital"), $sde, DigitalKey),
            entry!(concat!($sde_key, "-uo-call"), $sde, UoCallKey),
            entry!(concat!($sde_key, "-di-put"), $sde, DiPutKey),
        ]
    };
}

/// Every registered scenario's static kernels, in
/// [`super::registry::all_scenario_names`] order (SDE-major). 5 SDE
/// ctors x 7 payoff ctors = 35 monomorphized kernel pairs.
pub static KERNELS: [[ScenarioKernel; 7]; 5] = [
    sde_row!("bs", BsKey),
    sde_row!("gbm", GbmKey),
    sde_row!("ou", OuKey),
    sde_row!("cir", CirKey),
    sde_row!("heston", HestonKey),
];

/// The static kernel registered under base key `name`; `None` for
/// unknown (or `-simd`-suffixed) keys.
pub fn kernel_for(name: &str) -> Option<&'static ScenarioKernel> {
    KERNELS
        .iter()
        .flat_map(|row| row.iter())
        .find(|k| k.name == name)
}

/// Resolve a scenario key — base (`"heston-uo-call"`) or SIMD variant
/// (`"heston-uo-call-simd"`) — to its static kernel and whether the
/// lane-blocked variant was requested.
pub fn resolve(name: &str) -> Option<(&'static ScenarioKernel, bool)> {
    match name.strip_suffix("-simd") {
        Some(base) => kernel_for(base).map(|k| (k, true)),
        None => kernel_for(name).map(|k| (k, false)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenarios::{all_scenario_names, build_scenario};

    #[test]
    fn kernel_table_matches_registry_exactly() {
        let names = all_scenario_names();
        let flat: Vec<&ScenarioKernel> =
            KERNELS.iter().flat_map(|row| row.iter()).collect();
        assert_eq!(flat.len(), names.len(), "one kernel per registry key");
        let p = Problem::default();
        for (k, name) in flat.iter().zip(&names) {
            assert_eq!(k.name, name.as_str(), "table order drifted");
            let sc = build_scenario(name, &p).unwrap();
            assert_eq!(k.dim, sc.sde.dim(), "{name}: dim mismatch");
        }
    }

    #[test]
    fn resolve_handles_simd_suffix_and_rejects_junk() {
        let (k, simd) = resolve("heston-uo-call").unwrap();
        assert_eq!((k.name, simd), ("heston-uo-call", false));
        let (k, simd) = resolve("heston-uo-call-simd").unwrap();
        assert_eq!((k.name, simd), ("heston-uo-call", true));
        for bad in ["bs-simd", "bs-call-simd-simd", "sabr-call", "", "-simd"] {
            assert!(resolve(bad).is_none(), "`{bad}` must not resolve");
        }
    }

    #[test]
    fn scalar_kernel_is_bitwise_identical_to_dynamic_reference() {
        use crate::engine::objective::{
            coupled_value_and_grad_scenario, loss_only_scenario,
        };
        use crate::engine::mlp::init_params;
        use crate::rng::{brownian::Purpose, BrownianSource};

        let p = Problem::default();
        let params = init_params(0);
        for name in ["bs-call", "ou-asian", "heston-uo-call"] {
            let k = kernel_for(name).unwrap();
            let sc = build_scenario(name, &p).unwrap();
            let batch = 12;
            let level = 2;
            let n = p.n_steps(level);
            let dw = BrownianSource::new(5).increments_multi(
                Purpose::Grad, 0, level as u32, 0, batch, n, p.dt(level), k.dim,
            );
            let (l1, g1) =
                (k.scalar.coupled_value_and_grad)(&params, &dw, batch, level, &p);
            let (l2, g2) =
                coupled_value_and_grad_scenario(&params, &dw, batch, level, &p, &sc);
            assert_eq!(l1, l2, "{name}: coupled loss drifted");
            assert_eq!(g1, g2, "{name}: coupled grad drifted");
            assert_eq!(
                (k.scalar.loss_only)(&params, &dw, batch, n, &p),
                loss_only_scenario(&params, &dw, batch, n, &p, &sc),
                "{name}: loss drifted"
            );
        }
    }
}
