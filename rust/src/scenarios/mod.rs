//! Pluggable simulation scenarios: SDE dynamics x streaming path payoffs.
//!
//! The paper's delayed-MLMC estimator only needs a sequential simulation
//! whose level variances decay (Assumption 2) — nothing ties it to the
//! Appendix-C Black–Scholes call. This module factors the scenario out of
//! the engine hot path:
//!
//! * [`Sde`] — a D-dimensional diffusion (`D <=` [`MAX_DIM`]) with
//!   per-factor drift/diffusion/Milstein coefficients and a correlation
//!   between the driving Brownian factors, i.e. everything the Milstein
//!   integrator ([`crate::engine::milstein`]) consumes. D = 1
//!   (Black–Scholes, OU, CIR) and D = 2 ([`sde::Heston`] stochastic vol)
//!   are registered;
//! * [`Payoff`] — a **streaming observer** (`init → observe → finish`
//!   over a tiny [`payoff::PathAccum`]) folded over the path by the
//!   objective ([`crate::engine::objective`]) one state at a time, so the
//!   native hot path never materializes a `batch x (n_steps + 1)` path
//!   buffer. Terminal, Asian, lookback, digital and barrier
//!   (up-and-out / down-and-in, hit-tracking in-stream) payoffs are
//!   registered;
//! * [`Scenario`] — one (SDE, payoff) pair; [`registry`] builds them from
//!   string keys like `"ou-asian"` or `"heston-uo-call"` (see
//!   `--scenario` on the `repro` CLI and the `scenario.name` TOML key);
//! * [`kernels`] — a static table of **monomorphized** objective kernels,
//!   one per registry key (plus a lane-blocked SIMD variant behind the
//!   `-simd` key suffix), so non-default scenarios pay zero dynamic
//!   dispatch in the per-step hot loop.
//!
//! The default [`DEFAULT_SCENARIO`] (`"bs-call"`) reproduces the seed
//! engine bit-for-bit — including through the D-generic + streaming
//! refactor, whose D = 1 fast path keeps the seed's exact f32 operation
//! order — so every pre-existing engine/dispatcher/trainer test doubles
//! as a regression anchor. Non-default scenarios run on the native
//! backend only — the AOT/XLA artifacts are lowered for the default
//! scenario.

pub mod kernels;
pub mod payoff;
pub mod registry;
pub mod scenario;
pub mod sde;

pub use kernels::{kernel_for, resolve as resolve_kernel, KernelFns, ScenarioKernel};
pub use payoff::{PathAccum, Payoff};
pub use registry::{
    all_scenario_names, build_scenario, build_scenario_or_err, PAYOFF_KEYS, SDE_KEYS,
};
pub use scenario::{Scenario, DEFAULT_SCENARIO};
pub use sde::{promote, Sde, State, MAX_DIM};
