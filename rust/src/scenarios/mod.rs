//! Pluggable simulation scenarios: SDE dynamics x path payoffs.
//!
//! The paper's delayed-MLMC estimator only needs a sequential simulation
//! whose level variances decay (Assumption 2) — nothing ties it to the
//! Appendix-C Black–Scholes call. This module factors the scenario out of
//! the engine hot path:
//!
//! * [`Sde`] — drift/diffusion/diffusion-derivative, i.e. everything the
//!   Milstein integrator ([`crate::engine::milstein`]) consumes;
//! * [`Payoff`] — a functional of the whole simulated path, consumed by
//!   the objective ([`crate::engine::objective`]);
//! * [`Scenario`] — one (SDE, payoff) pair; [`registry`] builds them from
//!   string keys like `"ou-asian"` (see `--scenario` on the `repro` CLI
//!   and the `scenario.name` TOML key).
//!
//! The default [`DEFAULT_SCENARIO`] (`"bs-call"`) reproduces the seed
//! engine bit-for-bit, so every pre-existing engine/dispatcher/trainer
//! test doubles as a regression anchor for this refactor. Non-default
//! scenarios run on the native backend only — the AOT/XLA artifacts are
//! lowered for the default scenario.

pub mod payoff;
pub mod registry;
pub mod scenario;
pub mod sde;

pub use payoff::Payoff;
pub use registry::{
    all_scenario_names, build_scenario, build_scenario_or_err, PAYOFF_KEYS, SDE_KEYS,
};
pub use scenario::{Scenario, DEFAULT_SCENARIO};
pub use sde::Sde;
