//! The [`Sde`] trait — everything the Milstein integrator needs from a
//! D-dimensional diffusion `dS_k = a_k(S) dt + b_k(S) dB_k` (`D <=`
//! [`MAX_DIM`], diagonal noise, optionally correlated drivers) — and the
//! registered dynamics.
//!
//! The per-factor scheme (strong order 1 for commutative noise):
//!
//! `S_k+ = clamp_k(S_k + a_k(S) dt + b_k(S) dW_k + 1/2 b_k(S)
//! (db_k/dS_k)(S) (dW_k^2 - dt))`
//!
//! The trait has two faces bridged by default methods:
//!
//! * the **scalar interface** (`s0`/`drift`/`diffusion`/`milstein_term`/
//!   `clamp`) — the seed-era 1-D API, what 1-D dynamics implement and
//!   what the monomorphized D=1 fast path of the integrator calls;
//! * the **factor interface** (`s0_state`/`drift_factor`/… over a
//!   [`State`] vector) — what multi-factor dynamics ([`Heston`])
//!   implement and the generic D-loop calls.
//!
//! Each face's defaults delegate to the other, so a concrete SDE
//! implements exactly one of them (implementing neither would recurse —
//! don't). Factor 0 is by convention the *traded price* — the component
//! the hedging MLP observes and every payoff reads.
//!
//! Implementations may override [`Sde::milstein_term`] when the product
//! `1/2 b b'` has a cheaper or numerically preferable closed form — the
//! Black–Scholes dynamics do exactly that to stay **bit-identical** with
//! the seed engine's inlined `half_s2 * s` (f32 multiplication is not
//! associative, so the factoring matters for the regression anchors).

use crate::hedging::{Drift, Problem};

/// Maximum number of state factors any registered SDE may use. Kept as a
/// small fixed constant so per-path state lives in registers, never on
/// the heap.
pub const MAX_DIM: usize = 2;

/// One simulation state: the active factors occupy `0..dim`, inactive
/// slots are zero.
pub type State = [f32; MAX_DIM];

/// Lift a scalar price into a [`State`] (factor 0 set, rest zero).
#[inline]
pub fn promote(s: f32) -> State {
    let mut st = [0.0f32; MAX_DIM];
    st[0] = s;
    st
}

/// A D-dimensional SDE in Milstein normal form with diagonal noise. All
/// coefficients are f32 — the whole simulation hot path is f32,
/// mirroring the Pallas kernel.
pub trait Sde: std::fmt::Debug + Send + Sync {
    /// Registry key fragment (e.g. `"bs"`, `"ou"`, `"cir"`, `"heston"`).
    fn name(&self) -> &'static str;

    /// Number of active state factors (`1..=MAX_DIM`); each factor has
    /// its own driving Brownian motion.
    fn dim(&self) -> usize {
        1
    }

    // --- scalar interface (factor 0; the seed-era 1-D API) ------------

    /// Initial state `S_0` (factor 0).
    fn s0(&self) -> f32 {
        self.s0_state()[0]
    }

    /// Drift coefficient `a(s)`.
    fn drift(&self, s: f32) -> f32 {
        self.drift_factor(&promote(s), 0)
    }

    /// Diffusion coefficient `b(s)`.
    fn diffusion(&self, s: f32) -> f32 {
        self.diffusion_factor(&promote(s), 0)
    }

    /// Diffusion derivative `b'(s)` (the Milstein correction input).
    /// Deliberately **required** — defaulting it to zero would silently
    /// degrade a forgetful new 1-D dynamics to Euler. Multi-factor
    /// dynamics that implement `milstein_factor` directly return 0 here.
    fn diffusion_dv(&self, s: f32) -> f32;

    /// The Milstein correction factor `1/2 b(s) b'(s)`; override when a
    /// closed form avoids re-association or division by zero.
    fn milstein_term(&self, s: f32) -> f32 {
        0.5 * self.diffusion(s) * self.diffusion_dv(s)
    }

    /// Post-step state projection (e.g. full truncation for square-root
    /// processes). Identity by default.
    fn clamp(&self, s: f32) -> f32 {
        s
    }

    // --- factor interface (the D-dimensional generalization) ----------

    /// Initial state vector (inactive factors zero).
    fn s0_state(&self) -> State {
        promote(self.s0())
    }

    /// Drift coefficient `a_k(S)` of factor `k`.
    fn drift_factor(&self, s: &State, _k: usize) -> f32 {
        self.drift(s[0])
    }

    /// Diffusion coefficient `b_k(S)` of factor `k` (diagonal noise:
    /// factor `k` is driven by `dB_k` only).
    fn diffusion_factor(&self, s: &State, _k: usize) -> f32 {
        self.diffusion(s[0])
    }

    /// Milstein correction factor `1/2 b_k (db_k/dS_k)` of factor `k`.
    fn milstein_factor(&self, s: &State, _k: usize) -> f32 {
        self.milstein_term(s[0])
    }

    /// Post-step projection of factor `k`.
    fn clamp_factor(&self, v: f32, _k: usize) -> f32 {
        self.clamp(v)
    }

    /// Correlation `rho` between the factor-0 and factor-1 Brownian
    /// drivers (the integrator maps independent raw increments through
    /// the 2x2 Cholesky factor `[[1, 0], [rho, sqrt(1 - rho^2)]]`).
    /// Ignored for `dim() == 1`.
    fn correlation(&self) -> f32 {
        0.0
    }
}

/// Black–Scholes dynamics `dS = a dt + sigma S dB` with either the
/// paper's additive drift `a = mu` or true GBM `a = mu S`.
///
/// This is the seed engine's hard-coded SDE, factored behind the trait
/// with the exact same f32 coefficient groupings (`sigma * s`,
/// `half_s2 * s`) so the default scenario reproduces the seed numbers
/// bitwise.
#[derive(Debug, Clone, Copy)]
pub struct BlackScholes {
    pub mu: f32,
    pub sigma: f32,
    pub s0: f32,
    /// Precomputed `0.5 * sigma^2`, matching the seed's operation order.
    half_s2: f32,
    pub geometric: bool,
}

impl BlackScholes {
    pub fn new(mu: f32, sigma: f32, s0: f32, geometric: bool) -> Self {
        BlackScholes {
            mu,
            sigma,
            s0,
            half_s2: 0.5 * sigma * sigma,
            geometric,
        }
    }

    /// The problem's own dynamics (drift form taken from `problem.drift`).
    pub fn from_problem(p: &Problem) -> Self {
        BlackScholes::new(
            p.mu as f32,
            p.sigma as f32,
            p.s0 as f32,
            p.drift == Drift::Geometric,
        )
    }

    /// Force true GBM regardless of the problem's drift setting.
    pub fn geometric(p: &Problem) -> Self {
        BlackScholes::new(p.mu as f32, p.sigma as f32, p.s0 as f32, true)
    }
}

impl Sde for BlackScholes {
    fn name(&self) -> &'static str {
        if self.geometric {
            "gbm"
        } else {
            "bs"
        }
    }

    fn s0(&self) -> f32 {
        self.s0
    }

    fn drift(&self, s: f32) -> f32 {
        if self.geometric {
            self.mu * s
        } else {
            self.mu
        }
    }

    fn diffusion(&self, s: f32) -> f32 {
        self.sigma * s
    }

    fn diffusion_dv(&self, _s: f32) -> f32 {
        self.sigma
    }

    fn milstein_term(&self, s: f32) -> f32 {
        // NOT the default `0.5 * (sigma*s) * sigma`: the seed engine
        // computes `(0.5*sigma*sigma) * s`, and f32 products re-associate
        // differently. This keeps the default scenario bit-identical.
        self.half_s2 * s
    }
}

/// Ornstein–Uhlenbeck / Vasicek mean-reverting dynamics
/// `dS = kappa (theta - S) dt + sigma dB` (additive noise, so the
/// Milstein correction vanishes and the scheme reduces to Euler–Maruyama,
/// which is already strong order 1 for additive noise).
#[derive(Debug, Clone, Copy)]
pub struct OrnsteinUhlenbeck {
    pub kappa: f32,
    pub theta: f32,
    pub sigma: f32,
    pub s0: f32,
}

impl OrnsteinUhlenbeck {
    pub fn new(kappa: f32, theta: f32, sigma: f32, s0: f32) -> Self {
        OrnsteinUhlenbeck { kappa, theta, sigma, s0 }
    }

    /// Mean-revert around the problem's `s0` with its `sigma` as the
    /// absolute volatility (the problem gives no kappa; 1.5 keeps the
    /// relaxation time well inside the unit maturity).
    pub fn from_problem(p: &Problem) -> Self {
        OrnsteinUhlenbeck::new(1.5, p.s0 as f32, p.sigma as f32, p.s0 as f32)
    }
}

impl Sde for OrnsteinUhlenbeck {
    fn name(&self) -> &'static str {
        "ou"
    }

    fn s0(&self) -> f32 {
        self.s0
    }

    fn drift(&self, s: f32) -> f32 {
        self.kappa * (self.theta - s)
    }

    fn diffusion(&self, _s: f32) -> f32 {
        self.sigma
    }

    fn diffusion_dv(&self, _s: f32) -> f32 {
        0.0
    }

    fn milstein_term(&self, _s: f32) -> f32 {
        0.0
    }
}

/// Cox–Ingersoll–Ross square-root dynamics
/// `dS = kappa (theta - S) dt + sigma sqrt(S) dB`, discretized with full
/// truncation (coefficients evaluated at `max(S, 0)`, state clamped to
/// `>= 0` after each step).
///
/// `1/2 b b' = sigma^2 / 4` exactly, so the Milstein correction is a
/// constant and never divides by `sqrt(S)`.
#[derive(Debug, Clone, Copy)]
pub struct CoxIngersollRoss {
    pub kappa: f32,
    pub theta: f32,
    pub sigma: f32,
    pub s0: f32,
    /// Precomputed `sigma^2 / 4`.
    quarter_s2: f32,
}

impl CoxIngersollRoss {
    pub fn new(kappa: f32, theta: f32, sigma: f32, s0: f32) -> Self {
        CoxIngersollRoss {
            kappa,
            theta,
            sigma,
            s0,
            quarter_s2: 0.25 * sigma * sigma,
        }
    }

    /// Revert around the problem's `s0`. With the paper defaults
    /// (`s0 = 3`, `sigma = 1`, `kappa = 1.5`) the Feller condition
    /// `2 kappa theta >= sigma^2` holds with a wide margin, so paths stay
    /// strictly positive with overwhelming probability.
    pub fn from_problem(p: &Problem) -> Self {
        CoxIngersollRoss::new(1.5, p.s0 as f32, p.sigma as f32, p.s0 as f32)
    }
}

impl Sde for CoxIngersollRoss {
    fn name(&self) -> &'static str {
        "cir"
    }

    fn s0(&self) -> f32 {
        self.s0
    }

    fn drift(&self, s: f32) -> f32 {
        self.kappa * (self.theta - s)
    }

    fn diffusion(&self, s: f32) -> f32 {
        self.sigma * s.max(0.0).sqrt()
    }

    fn diffusion_dv(&self, s: f32) -> f32 {
        0.5 * self.sigma / s.max(1e-12).sqrt()
    }

    fn milstein_term(&self, _s: f32) -> f32 {
        self.quarter_s2
    }

    fn clamp(&self, s: f32) -> f32 {
        s.max(0.0)
    }
}

/// Heston stochastic-volatility dynamics (the canonical 2-factor model):
///
/// `dS = mu S dt + sqrt(v) S dW_1`
/// `dv = kappa (theta - v) dt + xi sqrt(v) dW_2`,  `corr(dW_1, dW_2) = rho`
///
/// discretized with **full truncation**: every `sqrt(v)` reads
/// `max(v, 0)` and the variance factor is clamped to `>= 0` after each
/// step (the price factor is left unclamped, like the seed
/// Black–Scholes engine). The per-factor Milstein corrections are the
/// diagonal ones — `1/2 v S` for the price, `xi^2 / 4` for the variance
/// (constant, like CIR) — without the cross-factor Lévy-area terms, the
/// standard simplification in the MLMC literature; the level coupling
/// still decays, which is all Assumption 2 needs (verified empirically
/// by the scenario suite).
#[derive(Debug, Clone, Copy)]
pub struct Heston {
    pub mu: f32,
    pub kappa: f32,
    pub theta: f32,
    /// Vol-of-vol.
    pub xi: f32,
    /// Driver correlation (negative = equity leverage effect).
    pub rho: f32,
    pub s0: f32,
    pub v0: f32,
    /// Precomputed `xi^2 / 4` (the variance factor's Milstein constant).
    quarter_xi2: f32,
}

impl Heston {
    pub fn new(
        mu: f32,
        kappa: f32,
        theta: f32,
        xi: f32,
        rho: f32,
        s0: f32,
        v0: f32,
    ) -> Self {
        assert!(rho.abs() <= 1.0, "correlation must be in [-1, 1]");
        Heston {
            mu,
            kappa,
            theta,
            xi,
            rho,
            s0,
            v0,
            quarter_xi2: 0.25 * xi * xi,
        }
    }

    /// Registry defaults: the problem's `mu` as a geometric drift,
    /// initial/long-run variance `sigma^2` (so the initial volatility
    /// matches the problem's `sigma`), `kappa = 1.5` (relaxation well
    /// inside the unit maturity, like the OU/CIR registrations),
    /// `xi = 0.5`, `rho = -0.7` (equity-style leverage). With the paper
    /// defaults (`sigma = 1`) the Feller condition `2 kappa theta >=
    /// xi^2` holds with a wide margin.
    pub fn from_problem(p: &Problem) -> Self {
        let v0 = (p.sigma * p.sigma) as f32;
        Heston::new(p.mu as f32, 1.5, v0, 0.5, -0.7, p.s0 as f32, v0)
    }
}

impl Sde for Heston {
    fn name(&self) -> &'static str {
        "heston"
    }

    fn dim(&self) -> usize {
        2
    }

    /// The scalar Milstein input is unused: the factor interface below
    /// supplies the per-factor corrections in closed form.
    fn diffusion_dv(&self, _s: f32) -> f32 {
        0.0
    }

    fn s0_state(&self) -> State {
        [self.s0, self.v0]
    }

    fn drift_factor(&self, s: &State, k: usize) -> f32 {
        if k == 0 {
            self.mu * s[0]
        } else {
            self.kappa * (self.theta - s[1])
        }
    }

    fn diffusion_factor(&self, s: &State, k: usize) -> f32 {
        let vol = s[1].max(0.0).sqrt();
        if k == 0 {
            vol * s[0]
        } else {
            self.xi * vol
        }
    }

    fn milstein_factor(&self, s: &State, k: usize) -> f32 {
        if k == 0 {
            // 1/2 * (sqrt(v) S) * d(sqrt(v) S)/dS = 1/2 v S
            0.5 * s[1].max(0.0) * s[0]
        } else {
            self.quarter_xi2
        }
    }

    fn clamp_factor(&self, v: f32, k: usize) -> f32 {
        if k == 1 {
            v.max(0.0)
        } else {
            v
        }
    }

    fn correlation(&self) -> f32 {
        self.rho
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bs_matches_seed_coefficient_grouping() {
        let p = Problem::default();
        let bs = BlackScholes::from_problem(&p);
        let s = 2.7f32;
        let sigma = p.sigma as f32;
        let half_s2 = 0.5 * sigma * sigma;
        assert_eq!(bs.diffusion(s), sigma * s);
        assert_eq!(bs.milstein_term(s), half_s2 * s);
        assert_eq!(bs.drift(s), p.mu as f32); // additive default
        assert_eq!(BlackScholes::geometric(&p).drift(s), p.mu as f32 * s);
    }

    #[test]
    fn ou_has_no_milstein_correction() {
        let ou = OrnsteinUhlenbeck::from_problem(&Problem::default());
        assert_eq!(ou.milstein_term(1.0), 0.0);
        assert_eq!(ou.diffusion(0.5), ou.diffusion(5.0)); // additive noise
        // mean reversion: drift pulls toward theta
        assert!(ou.drift(ou.theta + 1.0) < 0.0);
        assert!(ou.drift(ou.theta - 1.0) > 0.0);
    }

    #[test]
    fn cir_truncation_and_constant_correction() {
        let cir = CoxIngersollRoss::from_problem(&Problem::default());
        assert_eq!(cir.diffusion(-0.5), 0.0); // full truncation
        assert_eq!(cir.clamp(-0.3), 0.0);
        assert_eq!(cir.clamp(0.3), 0.3);
        let want = 0.25 * cir.sigma * cir.sigma;
        assert_eq!(cir.milstein_term(4.0), want);
        assert_eq!(cir.milstein_term(0.0), want); // no division blow-up
        // closed form agrees with 1/2 b b' where both are defined
        let s = 2.0f32;
        let direct = 0.5 * cir.diffusion(s) * cir.diffusion_dv(s);
        assert!((direct - want).abs() < 1e-6);
    }

    #[test]
    fn cir_feller_condition_holds_for_defaults() {
        let cir = CoxIngersollRoss::from_problem(&Problem::default());
        assert!(2.0 * cir.kappa * cir.theta >= cir.sigma * cir.sigma);
    }

    #[test]
    fn scalar_sdes_bridge_to_the_factor_interface() {
        // The factor-interface defaults must delegate factor 0 to the
        // scalar methods — the D-generic integrator then sees exactly
        // the seed coefficients for every 1-D dynamics.
        let p = Problem::default();
        let bs = BlackScholes::from_problem(&p);
        assert_eq!(bs.dim(), 1);
        assert_eq!(bs.s0_state(), promote(bs.s0()));
        let st = promote(2.7);
        assert_eq!(bs.drift_factor(&st, 0), bs.drift(2.7));
        assert_eq!(bs.diffusion_factor(&st, 0), bs.diffusion(2.7));
        assert_eq!(bs.milstein_factor(&st, 0), bs.milstein_term(2.7));
        assert_eq!(bs.correlation(), 0.0);
        let cir = CoxIngersollRoss::from_problem(&p);
        assert_eq!(cir.clamp_factor(-0.4, 0), 0.0);
    }

    #[test]
    fn heston_factor_structure() {
        let p = Problem::default();
        let h = Heston::from_problem(&p);
        assert_eq!(h.dim(), 2);
        assert_eq!(h.name(), "heston");
        assert_eq!(h.s0_state(), [p.s0 as f32, (p.sigma * p.sigma) as f32]);
        assert!(h.correlation() < 0.0 && h.correlation() >= -1.0);
        // Feller condition for the registry defaults
        assert!(2.0 * h.kappa * h.theta >= h.xi * h.xi);

        let s = [3.0f32, 0.64];
        // price factor: geometric drift, sqrt(v) S diffusion, 1/2 v S term
        assert_eq!(h.drift_factor(&s, 0), h.mu * 3.0);
        assert_eq!(h.diffusion_factor(&s, 0), 0.64f32.sqrt() * 3.0);
        assert_eq!(h.milstein_factor(&s, 0), 0.5 * 0.64 * 3.0);
        // variance factor: mean reversion, xi sqrt(v), constant xi^2/4
        assert_eq!(h.drift_factor(&s, 1), h.kappa * (h.theta - 0.64));
        assert_eq!(h.diffusion_factor(&s, 1), h.xi * 0.64f32.sqrt());
        assert_eq!(h.milstein_factor(&s, 1), 0.25 * h.xi * h.xi);
    }

    #[test]
    fn heston_full_truncation() {
        let h = Heston::from_problem(&Problem::default());
        // negative variance: coefficients read v+ = 0, state clamps to 0
        let s = [3.0f32, -0.5];
        assert_eq!(h.diffusion_factor(&s, 0), 0.0);
        assert_eq!(h.diffusion_factor(&s, 1), 0.0);
        assert_eq!(h.milstein_factor(&s, 0), 0.0);
        assert_eq!(h.clamp_factor(-0.5, 1), 0.0);
        assert_eq!(h.clamp_factor(0.5, 1), 0.5);
        // the price factor is never clamped (matches the seed BS engine)
        assert_eq!(h.clamp_factor(-1.0, 0), -1.0);
        // the milstein constant never divides by sqrt(v)
        assert_eq!(h.milstein_factor(&s, 1), 0.25 * h.xi * h.xi);
    }

    #[test]
    #[should_panic(expected = "correlation")]
    fn heston_rejects_out_of_range_rho() {
        Heston::new(1.0, 1.5, 1.0, 0.5, -1.5, 3.0, 1.0);
    }
}
