//! The [`Sde`] trait — everything the Milstein integrator needs from a
//! 1-D diffusion `dS = a(S) dt + b(S) dB` — and the registered dynamics.
//!
//! The scheme (strong order 1):
//!
//! `S+ = clamp(S + a(S) dt + b(S) dW + 1/2 b(S) b'(S) (dW^2 - dt))`
//!
//! Implementations may override [`Sde::milstein_term`] when the product
//! `1/2 b b'` has a cheaper or numerically preferable closed form — the
//! Black–Scholes dynamics do exactly that to stay **bit-identical** with
//! the seed engine's inlined `half_s2 * s` (f32 multiplication is not
//! associative, so the factoring matters for the regression anchors).

use crate::hedging::{Drift, Problem};

/// A 1-D SDE in Milstein normal form. All coefficients are f32 — the
/// whole simulation hot path is f32, mirroring the Pallas kernel.
pub trait Sde: std::fmt::Debug + Send + Sync {
    /// Registry key fragment (e.g. `"bs"`, `"ou"`, `"cir"`).
    fn name(&self) -> &'static str;

    /// Initial state `S_0`.
    fn s0(&self) -> f32;

    /// Drift coefficient `a(s)`.
    fn drift(&self, s: f32) -> f32;

    /// Diffusion coefficient `b(s)`.
    fn diffusion(&self, s: f32) -> f32;

    /// Diffusion derivative `b'(s)` (the Milstein correction input).
    fn diffusion_dv(&self, s: f32) -> f32;

    /// The Milstein correction factor `1/2 b(s) b'(s)`; override when a
    /// closed form avoids re-association or division by zero.
    fn milstein_term(&self, s: f32) -> f32 {
        0.5 * self.diffusion(s) * self.diffusion_dv(s)
    }

    /// Post-step state projection (e.g. full truncation for square-root
    /// processes). Identity by default.
    fn clamp(&self, s: f32) -> f32 {
        s
    }
}

/// Black–Scholes dynamics `dS = a dt + sigma S dB` with either the
/// paper's additive drift `a = mu` or true GBM `a = mu S`.
///
/// This is the seed engine's hard-coded SDE, factored behind the trait
/// with the exact same f32 coefficient groupings (`sigma * s`,
/// `half_s2 * s`) so the default scenario reproduces the seed numbers
/// bitwise.
#[derive(Debug, Clone, Copy)]
pub struct BlackScholes {
    pub mu: f32,
    pub sigma: f32,
    pub s0: f32,
    /// Precomputed `0.5 * sigma^2`, matching the seed's operation order.
    half_s2: f32,
    pub geometric: bool,
}

impl BlackScholes {
    pub fn new(mu: f32, sigma: f32, s0: f32, geometric: bool) -> Self {
        BlackScholes {
            mu,
            sigma,
            s0,
            half_s2: 0.5 * sigma * sigma,
            geometric,
        }
    }

    /// The problem's own dynamics (drift form taken from `problem.drift`).
    pub fn from_problem(p: &Problem) -> Self {
        BlackScholes::new(
            p.mu as f32,
            p.sigma as f32,
            p.s0 as f32,
            p.drift == Drift::Geometric,
        )
    }

    /// Force true GBM regardless of the problem's drift setting.
    pub fn geometric(p: &Problem) -> Self {
        BlackScholes::new(p.mu as f32, p.sigma as f32, p.s0 as f32, true)
    }
}

impl Sde for BlackScholes {
    fn name(&self) -> &'static str {
        if self.geometric {
            "gbm"
        } else {
            "bs"
        }
    }

    fn s0(&self) -> f32 {
        self.s0
    }

    fn drift(&self, s: f32) -> f32 {
        if self.geometric {
            self.mu * s
        } else {
            self.mu
        }
    }

    fn diffusion(&self, s: f32) -> f32 {
        self.sigma * s
    }

    fn diffusion_dv(&self, _s: f32) -> f32 {
        self.sigma
    }

    fn milstein_term(&self, s: f32) -> f32 {
        // NOT the default `0.5 * (sigma*s) * sigma`: the seed engine
        // computes `(0.5*sigma*sigma) * s`, and f32 products re-associate
        // differently. This keeps the default scenario bit-identical.
        self.half_s2 * s
    }
}

/// Ornstein–Uhlenbeck / Vasicek mean-reverting dynamics
/// `dS = kappa (theta - S) dt + sigma dB` (additive noise, so the
/// Milstein correction vanishes and the scheme reduces to Euler–Maruyama,
/// which is already strong order 1 for additive noise).
#[derive(Debug, Clone, Copy)]
pub struct OrnsteinUhlenbeck {
    pub kappa: f32,
    pub theta: f32,
    pub sigma: f32,
    pub s0: f32,
}

impl OrnsteinUhlenbeck {
    pub fn new(kappa: f32, theta: f32, sigma: f32, s0: f32) -> Self {
        OrnsteinUhlenbeck { kappa, theta, sigma, s0 }
    }

    /// Mean-revert around the problem's `s0` with its `sigma` as the
    /// absolute volatility (the problem gives no kappa; 1.5 keeps the
    /// relaxation time well inside the unit maturity).
    pub fn from_problem(p: &Problem) -> Self {
        OrnsteinUhlenbeck::new(1.5, p.s0 as f32, p.sigma as f32, p.s0 as f32)
    }
}

impl Sde for OrnsteinUhlenbeck {
    fn name(&self) -> &'static str {
        "ou"
    }

    fn s0(&self) -> f32 {
        self.s0
    }

    fn drift(&self, s: f32) -> f32 {
        self.kappa * (self.theta - s)
    }

    fn diffusion(&self, _s: f32) -> f32 {
        self.sigma
    }

    fn diffusion_dv(&self, _s: f32) -> f32 {
        0.0
    }

    fn milstein_term(&self, _s: f32) -> f32 {
        0.0
    }
}

/// Cox–Ingersoll–Ross square-root dynamics
/// `dS = kappa (theta - S) dt + sigma sqrt(S) dB`, discretized with full
/// truncation (coefficients evaluated at `max(S, 0)`, state clamped to
/// `>= 0` after each step).
///
/// `1/2 b b' = sigma^2 / 4` exactly, so the Milstein correction is a
/// constant and never divides by `sqrt(S)`.
#[derive(Debug, Clone, Copy)]
pub struct CoxIngersollRoss {
    pub kappa: f32,
    pub theta: f32,
    pub sigma: f32,
    pub s0: f32,
    /// Precomputed `sigma^2 / 4`.
    quarter_s2: f32,
}

impl CoxIngersollRoss {
    pub fn new(kappa: f32, theta: f32, sigma: f32, s0: f32) -> Self {
        CoxIngersollRoss {
            kappa,
            theta,
            sigma,
            s0,
            quarter_s2: 0.25 * sigma * sigma,
        }
    }

    /// Revert around the problem's `s0`. With the paper defaults
    /// (`s0 = 3`, `sigma = 1`, `kappa = 1.5`) the Feller condition
    /// `2 kappa theta >= sigma^2` holds with a wide margin, so paths stay
    /// strictly positive with overwhelming probability.
    pub fn from_problem(p: &Problem) -> Self {
        CoxIngersollRoss::new(1.5, p.s0 as f32, p.sigma as f32, p.s0 as f32)
    }
}

impl Sde for CoxIngersollRoss {
    fn name(&self) -> &'static str {
        "cir"
    }

    fn s0(&self) -> f32 {
        self.s0
    }

    fn drift(&self, s: f32) -> f32 {
        self.kappa * (self.theta - s)
    }

    fn diffusion(&self, s: f32) -> f32 {
        self.sigma * s.max(0.0).sqrt()
    }

    fn diffusion_dv(&self, s: f32) -> f32 {
        0.5 * self.sigma / s.max(1e-12).sqrt()
    }

    fn milstein_term(&self, _s: f32) -> f32 {
        self.quarter_s2
    }

    fn clamp(&self, s: f32) -> f32 {
        s.max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bs_matches_seed_coefficient_grouping() {
        let p = Problem::default();
        let bs = BlackScholes::from_problem(&p);
        let s = 2.7f32;
        let sigma = p.sigma as f32;
        let half_s2 = 0.5 * sigma * sigma;
        assert_eq!(bs.diffusion(s), sigma * s);
        assert_eq!(bs.milstein_term(s), half_s2 * s);
        assert_eq!(bs.drift(s), p.mu as f32); // additive default
        assert_eq!(BlackScholes::geometric(&p).drift(s), p.mu as f32 * s);
    }

    #[test]
    fn ou_has_no_milstein_correction() {
        let ou = OrnsteinUhlenbeck::from_problem(&Problem::default());
        assert_eq!(ou.milstein_term(1.0), 0.0);
        assert_eq!(ou.diffusion(0.5), ou.diffusion(5.0)); // additive noise
        // mean reversion: drift pulls toward theta
        assert!(ou.drift(ou.theta + 1.0) < 0.0);
        assert!(ou.drift(ou.theta - 1.0) > 0.0);
    }

    #[test]
    fn cir_truncation_and_constant_correction() {
        let cir = CoxIngersollRoss::from_problem(&Problem::default());
        assert_eq!(cir.diffusion(-0.5), 0.0); // full truncation
        assert_eq!(cir.clamp(-0.3), 0.0);
        assert_eq!(cir.clamp(0.3), 0.3);
        let want = 0.25 * cir.sigma * cir.sigma;
        assert_eq!(cir.milstein_term(4.0), want);
        assert_eq!(cir.milstein_term(0.0), want); // no division blow-up
        // closed form agrees with 1/2 b b' where both are defined
        let s = 2.0f32;
        let direct = 0.5 * cir.diffusion(s) * cir.diffusion_dv(s);
        assert!((direct - want).abs() < 1e-6);
    }

    #[test]
    fn cir_feller_condition_holds_for_defaults() {
        let cir = CoxIngersollRoss::from_problem(&Problem::default());
        assert!(2.0 * cir.kappa * cir.theta >= cir.sigma * cir.sigma);
    }
}
