//! The [`Payoff`] trait — a functional of the whole simulated path — and
//! the registered payoffs.
//!
//! The objective's residual is `r = payoff(path) - gains - p0`; the path
//! is exogenous (stop-gradient), so a payoff only ever contributes a
//! *value*, never a parameter gradient of its own. That is what makes the
//! engine generalization cheap: any path functional slots in.
//!
//! Payoffs receive the full state row `S_0 ..= S_T` (`n_steps + 1`
//! points). Path-dependent payoffs (Asian, lookback) are evaluated on the
//! grid they are simulated on, so fine and coarse evaluations of one
//! coupled sample legitimately differ — exactly the discretization error
//! MLMC telescopes over.

use crate::hedging::payoff::{call_payoff, put_payoff};

/// A path functional `payoff(S_0 ..= S_T)`.
pub trait Payoff: std::fmt::Debug + Send + Sync {
    /// Registry key fragment (e.g. `"call"`, `"asian"`).
    fn name(&self) -> &'static str;

    /// Evaluate on one state row `path[n_steps + 1]` (includes `S_0`).
    fn value(&self, path: &[f32]) -> f32;
}

/// European call `max(S_T - K, 0)` — the paper's instrument. Delegates to
/// [`call_payoff`] so the default scenario stays bit-identical with the
/// seed objective.
#[derive(Debug, Clone, Copy)]
pub struct EuropeanCall {
    pub strike: f32,
}

impl Payoff for EuropeanCall {
    fn name(&self) -> &'static str {
        "call"
    }

    fn value(&self, path: &[f32]) -> f32 {
        call_payoff(path[path.len() - 1], self.strike)
    }
}

/// European put `max(K - S_T, 0)`.
#[derive(Debug, Clone, Copy)]
pub struct EuropeanPut {
    pub strike: f32,
}

impl Payoff for EuropeanPut {
    fn name(&self) -> &'static str {
        "put"
    }

    fn value(&self, path: &[f32]) -> f32 {
        put_payoff(path[path.len() - 1], self.strike)
    }
}

/// Arithmetic-average Asian call `max(mean(S_1..S_T) - K, 0)`, averaged
/// over the simulation grid's monitoring points (excluding `S_0`).
#[derive(Debug, Clone, Copy)]
pub struct AsianCall {
    pub strike: f32,
}

impl Payoff for AsianCall {
    fn name(&self) -> &'static str {
        "asian"
    }

    fn value(&self, path: &[f32]) -> f32 {
        let n = path.len() - 1;
        let avg = path[1..].iter().sum::<f32>() / n as f32;
        call_payoff(avg, self.strike)
    }
}

/// Floating-strike lookback call `S_T - min(S_0..S_T)` (non-negative by
/// construction).
#[derive(Debug, Clone, Copy)]
pub struct LookbackCall;

impl Payoff for LookbackCall {
    fn name(&self) -> &'static str {
        "lookback"
    }

    fn value(&self, path: &[f32]) -> f32 {
        let min = path.iter().fold(f32::INFINITY, |m, &v| m.min(v));
        path[path.len() - 1] - min
    }
}

/// Cash-or-nothing digital call `1{S_T > K}` — discontinuous, so its
/// level-variance decay exponent `b` is markedly weaker than the smooth
/// payoffs' (the classic hard case of the MLMC literature); the scenario
/// sweep surfaces that.
#[derive(Debug, Clone, Copy)]
pub struct DigitalCall {
    pub strike: f32,
}

impl Payoff for DigitalCall {
    fn name(&self) -> &'static str {
        "digital"
    }

    fn value(&self, path: &[f32]) -> f32 {
        if path[path.len() - 1] > self.strike {
            1.0
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PATH: [f32; 5] = [3.0, 2.0, 4.0, 1.5, 3.5];

    #[test]
    fn european_uses_terminal_value_only() {
        assert_eq!(EuropeanCall { strike: 3.0 }.value(&PATH), 0.5);
        assert_eq!(EuropeanPut { strike: 3.0 }.value(&PATH), 0.0);
        assert_eq!(EuropeanPut { strike: 4.0 }.value(&PATH), 0.5);
    }

    #[test]
    fn asian_averages_excluding_s0() {
        // mean(2, 4, 1.5, 3.5) = 2.75
        assert_eq!(AsianCall { strike: 2.0 }.value(&PATH), 0.75);
        assert_eq!(AsianCall { strike: 3.0 }.value(&PATH), 0.0);
    }

    #[test]
    fn lookback_is_terminal_minus_running_min() {
        assert_eq!(LookbackCall.value(&PATH), 3.5 - 1.5);
        // monotone path: min is S_0
        assert_eq!(LookbackCall.value(&[1.0, 2.0, 3.0]), 2.0);
        // non-negative even when terminal is the minimum
        assert_eq!(LookbackCall.value(&[3.0, 2.0, 1.0]), 0.0);
    }

    #[test]
    fn digital_is_an_indicator() {
        assert_eq!(DigitalCall { strike: 3.0 }.value(&PATH), 1.0);
        assert_eq!(DigitalCall { strike: 4.0 }.value(&PATH), 0.0);
        assert_eq!(DigitalCall { strike: 3.5 }.value(&PATH), 0.0); // strict
    }

    #[test]
    fn call_matches_seed_inline_formula() {
        // The seed objective computed `(row[n] - K).max(0.0)` inline; the
        // trait must reproduce it exactly.
        for s in [0.0f32, 1.7, 3.0, 8.25] {
            let path = [3.0, s];
            let want = (s - 3.0f32).max(0.0);
            assert_eq!(EuropeanCall { strike: 3.0 }.value(&path), want);
        }
    }
}
