//! The [`Payoff`] trait — a **streaming observer** folded over the
//! simulated path — and the registered payoffs.
//!
//! The objective's residual is `r = payoff(path) - gains - p0`; the path
//! is exogenous (stop-gradient), so a payoff only ever contributes a
//! *value*, never a parameter gradient of its own. That is what makes the
//! engine generalization cheap: any path functional slots in.
//!
//! # Streaming protocol (`init → observe → finish`)
//!
//! The engine never materializes paths: the integrator produces one state
//! at a time and the payoff folds it online —
//!
//! 1. [`Payoff::init`] at `S_0` returns a fresh [`PathAccum`];
//! 2. [`Payoff::observe`] folds each post-step state `t = 1..=n_steps`;
//! 3. [`Payoff::finish`] maps the accumulator to the payoff value.
//!
//! [`PathAccum`] is a small fixed `Copy` struct (a running aggregate, the
//! latest price, a barrier-hit flag), so observing costs a few registers
//! per path and the hot path allocates nothing per sample. The
//! accumulation order equals the seed's left-to-right full-path folds, so
//! every streaming value is bit-identical to the old materialized
//! `value(path)` — which survives as a provided method *implemented on
//! top of the observer* for tests and materialized-path diagnostics.
//!
//! Payoffs observe the **price factor** (`state[0]`) on the grid they are
//! simulated on, so fine and coarse evaluations of one coupled sample
//! legitimately differ — exactly the discretization error MLMC telescopes
//! over. Barrier payoffs make that concrete: a fine path can cross the
//! barrier at a grid point the coarse path never sees.

use super::sde::{promote, State};
use crate::hedging::payoff::{call_payoff, put_payoff};

/// Streaming per-path accumulator: one running aggregate, the latest
/// price, and a barrier-hit flag. Fixed-size and `Copy` so the engine
/// keeps it in registers; each payoff uses the fields it needs.
#[derive(Debug, Clone, Copy, Default)]
pub struct PathAccum {
    /// Running aggregate (sum for Asian, running min for lookback, …).
    pub acc: f32,
    /// Latest observed price `S_t` (the terminal after the last observe).
    pub last: f32,
    /// Whether a barrier has been touched so far (monitored on the grid,
    /// including `S_0`).
    pub hit: bool,
}

/// A path functional, consumed as a streaming observer.
pub trait Payoff: std::fmt::Debug + Send + Sync {
    /// Registry key fragment (e.g. `"call"`, `"asian"`, `"uo-call"`).
    fn name(&self) -> &'static str;

    /// Start one path at its initial state. The default tracks the
    /// terminal price only — all a terminal payoff (call, put, digital)
    /// needs, so those implement just `finish`.
    fn init(&self, s0: &State) -> PathAccum {
        PathAccum {
            last: s0[0],
            ..PathAccum::default()
        }
    }

    /// Fold the post-step state of step `t` (`1..=n_steps`); the default
    /// keeps `acc.last` on the latest price. The grid position is part
    /// of the observer contract even though the current payoffs ignore
    /// it: time-dependent functionals (discrete monitoring windows,
    /// forward-start strikes) need `(t, n_steps)` and the integrator
    /// already has both in hand.
    fn observe(&self, acc: &mut PathAccum, _t: usize, _n_steps: usize, state: &State) {
        acc.last = state[0];
    }

    /// The payoff value once every step has been observed.
    fn finish(&self, acc: &PathAccum, n_steps: usize) -> f32;

    /// Materialized-path evaluation on one price row `path[n_steps + 1]`
    /// (includes `S_0`) — the reference semantics, implemented by
    /// replaying the row through the streaming observer.
    fn value(&self, path: &[f32]) -> f32 {
        let n_steps = path.len() - 1;
        let mut acc = self.init(&promote(path[0]));
        for (t, &s) in path.iter().enumerate().skip(1) {
            self.observe(&mut acc, t, n_steps, &promote(s));
        }
        self.finish(&acc, n_steps)
    }
}

/// European call `max(S_T - K, 0)` — the paper's instrument. Delegates to
/// [`call_payoff`] so the default scenario stays bit-identical with the
/// seed objective.
#[derive(Debug, Clone, Copy)]
pub struct EuropeanCall {
    pub strike: f32,
}

impl Payoff for EuropeanCall {
    fn name(&self) -> &'static str {
        "call"
    }

    fn finish(&self, acc: &PathAccum, _n: usize) -> f32 {
        call_payoff(acc.last, self.strike)
    }
}

/// European put `max(K - S_T, 0)`.
#[derive(Debug, Clone, Copy)]
pub struct EuropeanPut {
    pub strike: f32,
}

impl Payoff for EuropeanPut {
    fn name(&self) -> &'static str {
        "put"
    }

    fn finish(&self, acc: &PathAccum, _n: usize) -> f32 {
        put_payoff(acc.last, self.strike)
    }
}

/// Arithmetic-average Asian call `max(mean(S_1..S_T) - K, 0)`, averaged
/// over the simulation grid's monitoring points (excluding `S_0`). The
/// running sum accumulates in grid order — the same left-to-right f32
/// fold as the seed's materialized `path[1..].iter().sum()`.
#[derive(Debug, Clone, Copy)]
pub struct AsianCall {
    pub strike: f32,
}

impl Payoff for AsianCall {
    fn name(&self) -> &'static str {
        "asian"
    }

    fn init(&self, _s0: &State) -> PathAccum {
        PathAccum::default()
    }

    fn observe(&self, acc: &mut PathAccum, _t: usize, _n: usize, state: &State) {
        acc.acc += state[0];
    }

    fn finish(&self, acc: &PathAccum, n_steps: usize) -> f32 {
        call_payoff(acc.acc / n_steps as f32, self.strike)
    }
}

/// Floating-strike lookback call `S_T - min(S_0..S_T)` (non-negative by
/// construction).
#[derive(Debug, Clone, Copy)]
pub struct LookbackCall;

impl Payoff for LookbackCall {
    fn name(&self) -> &'static str {
        "lookback"
    }

    fn init(&self, s0: &State) -> PathAccum {
        PathAccum {
            acc: s0[0],
            last: s0[0],
            hit: false,
        }
    }

    fn observe(&self, acc: &mut PathAccum, _t: usize, _n: usize, state: &State) {
        acc.acc = acc.acc.min(state[0]);
        acc.last = state[0];
    }

    fn finish(&self, acc: &PathAccum, _n: usize) -> f32 {
        acc.last - acc.acc
    }
}

/// Cash-or-nothing digital call `1{S_T > K}` — discontinuous, so its
/// level-variance decay exponent `b` is markedly weaker than the smooth
/// payoffs' (the classic hard case of the MLMC literature); the scenario
/// sweep surfaces that.
#[derive(Debug, Clone, Copy)]
pub struct DigitalCall {
    pub strike: f32,
}

impl Payoff for DigitalCall {
    fn name(&self) -> &'static str {
        "digital"
    }

    fn finish(&self, acc: &PathAccum, _n: usize) -> f32 {
        if acc.last > self.strike {
            1.0
        } else {
            0.0
        }
    }
}

/// Up-and-out barrier call: `1{max_t S_t < B} * max(S_T - K, 0)` —
/// knocked out the moment the price touches the barrier from below. The
/// hit is tracked *inside* the streaming fold (including at `S_0`), which
/// is exactly what the materialized engine could not express without
/// keeping the whole path.
#[derive(Debug, Clone, Copy)]
pub struct UpAndOutCall {
    pub strike: f32,
    pub barrier: f32,
}

impl Payoff for UpAndOutCall {
    fn name(&self) -> &'static str {
        "uo-call"
    }

    fn init(&self, s0: &State) -> PathAccum {
        PathAccum {
            acc: 0.0,
            last: s0[0],
            hit: s0[0] >= self.barrier,
        }
    }

    fn observe(&self, acc: &mut PathAccum, _t: usize, _n: usize, state: &State) {
        acc.hit |= state[0] >= self.barrier;
        acc.last = state[0];
    }

    fn finish(&self, acc: &PathAccum, _n: usize) -> f32 {
        if acc.hit {
            0.0
        } else {
            call_payoff(acc.last, self.strike)
        }
    }
}

/// Down-and-in barrier put: `1{min_t S_t <= B} * max(K - S_T, 0)` —
/// worthless unless the price touches the barrier from above at some
/// monitoring point (including `S_0`).
#[derive(Debug, Clone, Copy)]
pub struct DownAndInPut {
    pub strike: f32,
    pub barrier: f32,
}

impl Payoff for DownAndInPut {
    fn name(&self) -> &'static str {
        "di-put"
    }

    fn init(&self, s0: &State) -> PathAccum {
        PathAccum {
            acc: 0.0,
            last: s0[0],
            hit: s0[0] <= self.barrier,
        }
    }

    fn observe(&self, acc: &mut PathAccum, _t: usize, _n: usize, state: &State) {
        acc.hit |= state[0] <= self.barrier;
        acc.last = state[0];
    }

    fn finish(&self, acc: &PathAccum, _n: usize) -> f32 {
        if acc.hit {
            put_payoff(acc.last, self.strike)
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PATH: [f32; 5] = [3.0, 2.0, 4.0, 1.5, 3.5];

    /// Drive a payoff through the streaming protocol directly (what the
    /// engine does), independent of the provided `value` replay.
    fn stream(p: &dyn Payoff, path: &[f32]) -> f32 {
        let n = path.len() - 1;
        let mut acc = p.init(&promote(path[0]));
        for t in 1..=n {
            p.observe(&mut acc, t, n, &promote(path[t]));
        }
        p.finish(&acc, n)
    }

    #[test]
    fn european_uses_terminal_value_only() {
        assert_eq!(EuropeanCall { strike: 3.0 }.value(&PATH), 0.5);
        assert_eq!(EuropeanPut { strike: 3.0 }.value(&PATH), 0.0);
        assert_eq!(EuropeanPut { strike: 4.0 }.value(&PATH), 0.5);
    }

    #[test]
    fn asian_averages_excluding_s0() {
        // mean(2, 4, 1.5, 3.5) = 2.75
        assert_eq!(AsianCall { strike: 2.0 }.value(&PATH), 0.75);
        assert_eq!(AsianCall { strike: 3.0 }.value(&PATH), 0.0);
    }

    #[test]
    fn lookback_is_terminal_minus_running_min() {
        assert_eq!(LookbackCall.value(&PATH), 3.5 - 1.5);
        // monotone path: min is S_0
        assert_eq!(LookbackCall.value(&[1.0, 2.0, 3.0]), 2.0);
        // non-negative even when terminal is the minimum
        assert_eq!(LookbackCall.value(&[3.0, 2.0, 1.0]), 0.0);
    }

    #[test]
    fn digital_is_an_indicator() {
        assert_eq!(DigitalCall { strike: 3.0 }.value(&PATH), 1.0);
        assert_eq!(DigitalCall { strike: 4.0 }.value(&PATH), 0.0);
        assert_eq!(DigitalCall { strike: 3.5 }.value(&PATH), 0.0); // strict
    }

    #[test]
    fn call_matches_seed_inline_formula() {
        // The seed objective computed `(row[n] - K).max(0.0)` inline; the
        // trait must reproduce it exactly.
        for s in [0.0f32, 1.7, 3.0, 8.25] {
            let path = [3.0, s];
            let want = (s - 3.0f32).max(0.0);
            assert_eq!(EuropeanCall { strike: 3.0 }.value(&path), want);
        }
    }

    #[test]
    fn streaming_fold_matches_value_replay() {
        // `value` is defined as a replay of the observer, but check the
        // protocol plumbing explicitly for every registered payoff.
        let payoffs: Vec<Box<dyn Payoff>> = vec![
            Box::new(EuropeanCall { strike: 3.0 }),
            Box::new(EuropeanPut { strike: 3.0 }),
            Box::new(AsianCall { strike: 2.5 }),
            Box::new(LookbackCall),
            Box::new(DigitalCall { strike: 3.0 }),
            Box::new(UpAndOutCall { strike: 3.0, barrier: 4.5 }),
            Box::new(DownAndInPut { strike: 3.0, barrier: 1.75 }),
        ];
        for p in &payoffs {
            assert_eq!(
                stream(p.as_ref(), &PATH),
                p.value(&PATH),
                "{} streams differently",
                p.name()
            );
        }
    }

    #[test]
    fn up_and_out_knocks_out_on_touch() {
        let uo = UpAndOutCall { strike: 3.0, barrier: 4.0 };
        // PATH touches 4.0 at t = 2 -> knocked out despite S_T = 3.5 > K
        assert_eq!(uo.value(&PATH), 0.0);
        // barrier above the path maximum -> plain call
        let safe = UpAndOutCall { strike: 3.0, barrier: 100.0 };
        assert_eq!(safe.value(&PATH), 0.5);
    }

    #[test]
    fn barrier_hit_exactly_at_s0() {
        // S_0 on the barrier: up-and-out is knocked out at inception …
        let uo = UpAndOutCall { strike: 1.0, barrier: 3.0 };
        assert_eq!(uo.value(&PATH), 0.0);
        // … and down-and-in is knocked in at inception.
        let di = DownAndInPut { strike: 4.0, barrier: 3.0 };
        assert_eq!(di.value(&PATH), 0.5); // put_payoff(3.5, 4.0)
    }

    #[test]
    fn barrier_hit_on_the_final_step() {
        // The terminal observation itself must count as a monitoring
        // point: path peaks only at S_T.
        let path = [3.0f32, 3.2, 3.4, 5.0];
        let uo = UpAndOutCall { strike: 3.0, barrier: 5.0 };
        assert_eq!(uo.value(&path), 0.0, "terminal touch must knock out");
        let down = [3.0f32, 2.8, 2.6, 1.0];
        let di = DownAndInPut { strike: 3.0, barrier: 1.0 };
        assert_eq!(di.value(&down), 2.0, "terminal touch must knock in");
    }

    #[test]
    fn fine_path_hits_while_coarse_path_misses() {
        // One coupled sample, two grids: the fine grid visits an
        // excursion above the barrier that the 2x-coarser grid skips —
        // the legitimate discretization difference MLMC telescopes over.
        let fine = [3.0f32, 4.6, 3.1, 3.2, 3.5];
        let coarse = [3.0f32, 3.1, 3.5]; // every second point
        let uo = UpAndOutCall { strike: 3.0, barrier: 4.5 };
        assert_eq!(uo.value(&fine), 0.0, "fine path crossed the barrier");
        assert_eq!(
            uo.value(&coarse),
            0.5,
            "coarse path never saw the excursion"
        );
    }

    #[test]
    fn down_and_in_requires_the_hit() {
        let di = DownAndInPut { strike: 3.0, barrier: 1.75 };
        // PATH dips to 1.5 <= 1.75 at t = 3 -> knocked in, put is OTM at
        // S_T = 3.5 -> 0, but via the *hit* branch
        assert_eq!(di.value(&PATH), 0.0);
        let di_deep = DownAndInPut { strike: 4.0, barrier: 1.75 };
        assert_eq!(di_deep.value(&PATH), 0.5);
        // barrier below the path minimum -> never knocked in
        let never = DownAndInPut { strike: 4.0, barrier: 1.0 };
        assert_eq!(never.value(&PATH), 0.0);
    }
}
