//! String-keyed scenario registry: `"<sde>-<payoff>"` keys over the full
//! cross product of registered dynamics and payoffs (the key splits at
//! the *first* dash, so payoff keys may themselves be dashed:
//! `"heston-uo-call"` is the `heston` dynamics under the `uo-call`
//! payoff).
//!
//! | SDE key  | dynamics | dim |
//! |----------|----------|-----|
//! | `bs`     | Black–Scholes with the problem's drift form (the default) | 1 |
//! | `gbm`    | Black–Scholes forced geometric (true GBM) | 1 |
//! | `ou`     | Ornstein–Uhlenbeck/Vasicek mean reversion | 1 |
//! | `cir`    | Cox–Ingersoll–Ross square-root diffusion | 1 |
//! | `heston` | Heston stochastic vol (correlated price/variance factors, full truncation) | 2 |
//!
//! | payoff key | functional |
//! |------------|------------|
//! | `call`     | `max(S_T - K, 0)` |
//! | `put`      | `max(K - S_T, 0)` |
//! | `asian`    | arithmetic-average Asian call |
//! | `lookback` | floating-strike lookback call |
//! | `digital`  | cash-or-nothing `1{S_T > K}` |
//! | `uo-call`  | up-and-out barrier call, barrier `1.5 s0` (knock-out tracked in-stream) |
//! | `di-put`   | down-and-in barrier put, barrier `0.5 s0` (knock-in tracked in-stream) |
//!
//! Scenario parameters (strike, `s0`, `sigma`, drift form) come from the
//! [`Problem`], so one TOML `[problem]` section configures every scenario
//! consistently; kappa/theta for the mean-reverting families, the Heston
//! vol-of-vol/correlation, and the barrier multiples are fixed registry
//! defaults documented on their constructors.

use std::sync::Arc;

use crate::hedging::Problem;

use super::payoff::{
    AsianCall, DigitalCall, DownAndInPut, EuropeanCall, EuropeanPut,
    LookbackCall, Payoff, UpAndOutCall,
};
use super::scenario::Scenario;
use super::sde::{BlackScholes, CoxIngersollRoss, Heston, OrnsteinUhlenbeck, Sde};

/// Registered SDE keys (first key is the default family).
pub const SDE_KEYS: &[&str] = &["bs", "gbm", "ou", "cir", "heston"];

/// Registered payoff keys (first key is the default payoff).
pub const PAYOFF_KEYS: &[&str] = &[
    "call", "put", "asian", "lookback", "digital", "uo-call", "di-put",
];

/// Barrier placement relative to `s0` for the registry's barrier payoffs
/// (up-and-out above, down-and-in below). Chosen so both barriers are
/// touched with non-trivial probability under the paper's Appendix-C
/// volatility, keeping the knock branches statistically alive in tests
/// and sweeps.
pub const UP_BARRIER_MULT: f64 = 1.5;
pub const DOWN_BARRIER_MULT: f64 = 0.5;

/// Every registered scenario name — the `SDE_KEYS x PAYOFF_KEYS` cross
/// product, default first.
pub fn all_scenario_names() -> Vec<String> {
    let mut names = Vec::with_capacity(SDE_KEYS.len() * PAYOFF_KEYS.len());
    for sde in SDE_KEYS {
        for payoff in PAYOFF_KEYS {
            names.push(format!("{sde}-{payoff}"));
        }
    }
    names
}

/// [`build_scenario`], erroring with the registered keys listed — the
/// one message every consumer (config validation, trainer, sweeps)
/// shows for an unknown key.
pub fn build_scenario_or_err(name: &str, problem: &Problem) -> anyhow::Result<Scenario> {
    build_scenario(name, problem).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown scenario `{name}` (registered: {})",
            all_scenario_names().join(", ")
        )
    })
}

/// Build the scenario registered under `name` for `problem`; `None` for
/// unknown keys.
///
/// Every base key also registers a `-simd` variant (`"heston-uo-call-simd"`)
/// selecting the lane-blocked kernels (see [`super::kernels::resolve`]):
/// same dynamics and payoff — the returned [`Scenario`] components are
/// identical — but the native backend routes its hot path through the
/// 8-wide lane engine, which reassociates f32 reductions and is therefore
/// validated by tolerance rather than bitwise.
pub fn build_scenario(name: &str, problem: &Problem) -> Option<Scenario> {
    let base = name.strip_suffix("-simd").unwrap_or(name);
    let (sde_key, payoff_key) = base.split_once('-')?;
    let sde: Arc<dyn Sde> = match sde_key {
        "bs" => Arc::new(BlackScholes::from_problem(problem)),
        "gbm" => Arc::new(BlackScholes::geometric(problem)),
        "ou" => Arc::new(OrnsteinUhlenbeck::from_problem(problem)),
        "cir" => Arc::new(CoxIngersollRoss::from_problem(problem)),
        "heston" => Arc::new(Heston::from_problem(problem)),
        _ => return None,
    };
    let strike = problem.strike as f32;
    let payoff: Arc<dyn Payoff> = match payoff_key {
        "call" => Arc::new(EuropeanCall { strike }),
        "put" => Arc::new(EuropeanPut { strike }),
        "asian" => Arc::new(AsianCall { strike }),
        "lookback" => Arc::new(LookbackCall),
        "digital" => Arc::new(DigitalCall { strike }),
        "uo-call" => Arc::new(UpAndOutCall {
            strike,
            barrier: (problem.s0 * UP_BARRIER_MULT) as f32,
        }),
        "di-put" => Arc::new(DownAndInPut {
            strike,
            barrier: (problem.s0 * DOWN_BARRIER_MULT) as f32,
        }),
        _ => return None,
    };
    Some(Scenario {
        name: name.to_string(),
        sde,
        payoff,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenarios::DEFAULT_SCENARIO;

    #[test]
    fn cross_product_is_registered() {
        let names = all_scenario_names();
        assert_eq!(names.len(), SDE_KEYS.len() * PAYOFF_KEYS.len());
        assert!(names.len() >= 12, "need >= 3 SDEs x >= 4 payoffs");
        assert_eq!(names[0], DEFAULT_SCENARIO);
        let p = Problem::default();
        for name in &names {
            let sc = build_scenario(name, &p)
                .unwrap_or_else(|| panic!("`{name}` did not build"));
            assert_eq!(&sc.name, name);
        }
    }

    #[test]
    fn unknown_keys_rejected() {
        let p = Problem::default();
        assert!(build_scenario("sabr-call", &p).is_none());
        assert!(build_scenario("bs-barrier", &p).is_none());
        assert!(build_scenario("bscall", &p).is_none());
        assert!(build_scenario("", &p).is_none());
    }

    #[test]
    fn heston_and_barrier_scenarios_resolve() {
        let p = Problem::default();
        for name in ["heston-call", "heston-put", "heston-uo-call"] {
            let sc = build_scenario(name, &p)
                .unwrap_or_else(|| panic!("`{name}` did not build"));
            assert_eq!(sc.sde.dim(), 2, "{name}");
            assert_ne!(sc.sde.correlation(), 0.0, "{name}");
        }
        let uo = build_scenario("bs-uo-call", &p).unwrap();
        assert_eq!(uo.payoff.name(), "uo-call");
        let di = build_scenario("gbm-di-put", &p).unwrap();
        assert_eq!(di.payoff.name(), "di-put");
        // barrier placement: knocked out at 1.5 s0, knocked in at 0.5 s0
        let up = (p.s0 * UP_BARRIER_MULT) as f32;
        let s0 = p.s0 as f32;
        assert_eq!(uo.payoff.value(&[s0, up, s0 + 1.0]), 0.0);
        assert!(uo.payoff.value(&[s0, s0, s0 + 1.0]) > 0.0);
        let down = (p.s0 * DOWN_BARRIER_MULT) as f32;
        assert!(di.payoff.value(&[s0, down, s0 - 1.0]) > 0.0);
        assert_eq!(di.payoff.value(&[s0, s0, s0 - 1.0]), 0.0);
    }

    #[test]
    fn default_key_matches_from_problem() {
        let p = Problem::default();
        let from_registry = build_scenario(DEFAULT_SCENARIO, &p).unwrap();
        let from_problem = Scenario::from_problem(&p);
        assert!(from_registry.is_default());
        // identical dynamics and payoff at sample points
        for s in [0.5f32, 3.0, 7.25] {
            assert_eq!(from_registry.sde.drift(s), from_problem.sde.drift(s));
            assert_eq!(
                from_registry.sde.diffusion(s),
                from_problem.sde.diffusion(s)
            );
            assert_eq!(
                from_registry.sde.milstein_term(s),
                from_problem.sde.milstein_term(s)
            );
            let path = [3.0, s];
            assert_eq!(
                from_registry.payoff.value(&path),
                from_problem.payoff.value(&path)
            );
        }
    }

    #[test]
    fn gbm_key_forces_geometric_drift() {
        let p = Problem::default(); // additive drift
        let gbm = build_scenario("gbm-call", &p).unwrap();
        assert_ne!(gbm.sde.drift(1.0), gbm.sde.drift(5.0));
    }
}
