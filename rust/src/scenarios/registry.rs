//! String-keyed scenario registry: `"<sde>-<payoff>"` keys over the full
//! cross product of registered dynamics and payoffs.
//!
//! | SDE key | dynamics |
//! |---------|----------|
//! | `bs`    | Black–Scholes with the problem's drift form (the default) |
//! | `gbm`   | Black–Scholes forced geometric (true GBM) |
//! | `ou`    | Ornstein–Uhlenbeck/Vasicek mean reversion |
//! | `cir`   | Cox–Ingersoll–Ross square-root diffusion |
//!
//! | payoff key | functional |
//! |------------|------------|
//! | `call`     | `max(S_T - K, 0)` |
//! | `put`      | `max(K - S_T, 0)` |
//! | `asian`    | arithmetic-average Asian call |
//! | `lookback` | floating-strike lookback call |
//! | `digital`  | cash-or-nothing `1{S_T > K}` |
//!
//! Scenario parameters (strike, `s0`, `sigma`, drift form) come from the
//! [`Problem`], so one TOML `[problem]` section configures every scenario
//! consistently; kappa/theta for the mean-reverting families are fixed
//! registry defaults documented on their constructors.

use std::sync::Arc;

use crate::hedging::Problem;

use super::payoff::{
    AsianCall, DigitalCall, EuropeanCall, EuropeanPut, LookbackCall, Payoff,
};
use super::scenario::Scenario;
use super::sde::{BlackScholes, CoxIngersollRoss, OrnsteinUhlenbeck, Sde};

/// Registered SDE keys (first key is the default family).
pub const SDE_KEYS: &[&str] = &["bs", "gbm", "ou", "cir"];

/// Registered payoff keys (first key is the default payoff).
pub const PAYOFF_KEYS: &[&str] = &["call", "put", "asian", "lookback", "digital"];

/// Every registered scenario name — the `SDE_KEYS x PAYOFF_KEYS` cross
/// product, default first.
pub fn all_scenario_names() -> Vec<String> {
    let mut names = Vec::with_capacity(SDE_KEYS.len() * PAYOFF_KEYS.len());
    for sde in SDE_KEYS {
        for payoff in PAYOFF_KEYS {
            names.push(format!("{sde}-{payoff}"));
        }
    }
    names
}

/// [`build_scenario`], erroring with the registered keys listed — the
/// one message every consumer (config validation, trainer, sweeps)
/// shows for an unknown key.
pub fn build_scenario_or_err(name: &str, problem: &Problem) -> anyhow::Result<Scenario> {
    build_scenario(name, problem).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown scenario `{name}` (registered: {})",
            all_scenario_names().join(", ")
        )
    })
}

/// Build the scenario registered under `name` for `problem`; `None` for
/// unknown keys.
pub fn build_scenario(name: &str, problem: &Problem) -> Option<Scenario> {
    let (sde_key, payoff_key) = name.split_once('-')?;
    let sde: Arc<dyn Sde> = match sde_key {
        "bs" => Arc::new(BlackScholes::from_problem(problem)),
        "gbm" => Arc::new(BlackScholes::geometric(problem)),
        "ou" => Arc::new(OrnsteinUhlenbeck::from_problem(problem)),
        "cir" => Arc::new(CoxIngersollRoss::from_problem(problem)),
        _ => return None,
    };
    let strike = problem.strike as f32;
    let payoff: Arc<dyn Payoff> = match payoff_key {
        "call" => Arc::new(EuropeanCall { strike }),
        "put" => Arc::new(EuropeanPut { strike }),
        "asian" => Arc::new(AsianCall { strike }),
        "lookback" => Arc::new(LookbackCall),
        "digital" => Arc::new(DigitalCall { strike }),
        _ => return None,
    };
    Some(Scenario {
        name: name.to_string(),
        sde,
        payoff,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenarios::DEFAULT_SCENARIO;

    #[test]
    fn cross_product_is_registered() {
        let names = all_scenario_names();
        assert_eq!(names.len(), SDE_KEYS.len() * PAYOFF_KEYS.len());
        assert!(names.len() >= 12, "need >= 3 SDEs x >= 4 payoffs");
        assert_eq!(names[0], DEFAULT_SCENARIO);
        let p = Problem::default();
        for name in &names {
            let sc = build_scenario(name, &p)
                .unwrap_or_else(|| panic!("`{name}` did not build"));
            assert_eq!(&sc.name, name);
        }
    }

    #[test]
    fn unknown_keys_rejected() {
        let p = Problem::default();
        assert!(build_scenario("heston-call", &p).is_none());
        assert!(build_scenario("bs-barrier", &p).is_none());
        assert!(build_scenario("bscall", &p).is_none());
        assert!(build_scenario("", &p).is_none());
    }

    #[test]
    fn default_key_matches_from_problem() {
        let p = Problem::default();
        let from_registry = build_scenario(DEFAULT_SCENARIO, &p).unwrap();
        let from_problem = Scenario::from_problem(&p);
        assert!(from_registry.is_default());
        // identical dynamics and payoff at sample points
        for s in [0.5f32, 3.0, 7.25] {
            assert_eq!(from_registry.sde.drift(s), from_problem.sde.drift(s));
            assert_eq!(
                from_registry.sde.diffusion(s),
                from_problem.sde.diffusion(s)
            );
            assert_eq!(
                from_registry.sde.milstein_term(s),
                from_problem.sde.milstein_term(s)
            );
            let path = [3.0, s];
            assert_eq!(
                from_registry.payoff.value(&path),
                from_problem.payoff.value(&path)
            );
        }
    }

    #[test]
    fn gbm_key_forces_geometric_drift() {
        let p = Problem::default(); // additive drift
        let gbm = build_scenario("gbm-call", &p).unwrap();
        assert_ne!(gbm.sde.drift(1.0), gbm.sde.drift(5.0));
    }
}
