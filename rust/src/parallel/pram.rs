//! Finite-processor PRAM simulation (work-time scheduling).
//!
//! The paper's parallel complexity assumes an unbounded machine; Brent's
//! theorem gives the finite-`P` execution time
//! `T_P <= work / P + depth`. This module simulates greedy list
//! scheduling of level jobs onto `P` processors so the crossover behaviour
//! (how many processors before DMLMC's advantage saturates) can be swept —
//! used by `examples/complexity_table.rs` and the ablation bench.

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

use super::cost::CostModel;

/// One processor's running load, ordered by `(load, index)` — the heap
/// pops the least-loaded processor, ties broken by the smallest index,
/// which is exactly the `min_by`-over-a-slice "first minimum" rule of the
/// expanded LPT reference (loads are finite and non-negative, so
/// `total_cmp` agrees with `partial_cmp` on every comparison made).
#[derive(Debug, Clone, Copy, PartialEq)]
struct Slot {
    load: f64,
    idx: usize,
}

impl Eq for Slot {}

impl Ord for Slot {
    fn cmp(&self, other: &Self) -> Ordering {
        self.load
            .total_cmp(&other.load)
            .then(self.idx.cmp(&other.idx))
    }
}

impl PartialOrd for Slot {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// A unit of schedulable work: one level refresh (N_l parallel samples,
/// each of depth `2^{c l}`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LevelJob {
    pub level: usize,
    pub n_samples: usize,
}

/// Greedy work-time scheduler over `P` identical processors.
#[derive(Debug, Clone, Copy)]
pub struct PramMachine {
    pub processors: usize,
    pub model: CostModel,
}

impl PramMachine {
    pub fn new(processors: usize, model: CostModel) -> Self {
        assert!(processors > 0, "need at least one processor");
        PramMachine { processors, model }
    }

    /// Makespan of one SGD step that runs `jobs` concurrently.
    ///
    /// Each sample is an indivisible sequential task of length
    /// `2^{c l}`; samples are independent. Greedy longest-processing-time
    /// scheduling is within 4/3 of optimal; exactness is irrelevant here —
    /// we need the *scaling*, which LPT preserves.
    pub fn step_makespan(&self, jobs: &[LevelJob]) -> f64 {
        // All samples of one job share the length `2^{c l}`, so LPT never
        // needs one task per sample (level-0 jobs used to materialize and
        // sort 500+ identical entries): sort the per-job (length, count)
        // groups longest-first and assign counts greedily. Equal-length
        // tasks are interchangeable, so this is bit-identical to the
        // expanded sort — including the first-min tie-breaking.
        //
        // The least-loaded processor comes from a binary heap keyed by
        // `(load, index)` — O(S log P) over S samples instead of the old
        // O(S x P) scan, which dominated for large-N level-0 jobs. The
        // heap performs the *identical assignment sequence* (same argmin,
        // same first-min tie-break via the index key), so every
        // per-processor f64 load accumulates in the same order and the
        // result is bit-exact with the expanded reference (guarded by
        // `counting_schedule_matches_expansion_bitwise`).
        let mut groups: Vec<(f64, usize)> = jobs
            .iter()
            .filter(|j| j.n_samples > 0)
            .map(|j| (self.model.sample_cost(j.level), j.n_samples))
            .collect();
        groups.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let mut heap: BinaryHeap<Reverse<Slot>> = (0..self.processors)
            .map(|idx| Reverse(Slot { load: 0.0, idx }))
            .collect();
        for (len, count) in groups {
            for _ in 0..count {
                // assign to least-loaded processor (first-min on ties)
                let Reverse(mut slot) = heap.pop().expect("processors > 0");
                slot.load += len;
                heap.push(Reverse(slot));
            }
        }
        heap.into_iter()
            .map(|Reverse(s)| s.load)
            .fold(0.0, f64::max)
    }

    /// Brent's-theorem lower bound for the same step.
    pub fn brent_bound(&self, jobs: &[LevelJob]) -> f64 {
        let work: f64 = jobs
            .iter()
            .map(|j| self.model.level_work(j.level, j.n_samples))
            .sum();
        let depth = jobs
            .iter()
            .map(|j| self.model.sample_cost(j.level))
            .fold(0.0, f64::max);
        (work / self.processors as f64).max(depth)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn machine(p: usize) -> PramMachine {
        PramMachine::new(p, CostModel::new(1.0))
    }

    #[test]
    fn single_processor_is_total_work() {
        let m = machine(1);
        let jobs = [LevelJob { level: 2, n_samples: 3 }];
        assert_eq!(m.step_makespan(&jobs), 12.0);
    }

    #[test]
    fn unbounded_processors_hit_depth() {
        let m = machine(10_000);
        let jobs = [
            LevelJob { level: 6, n_samples: 2 },
            LevelJob { level: 0, n_samples: 500 },
        ];
        assert_eq!(m.step_makespan(&jobs), 64.0);
    }

    #[test]
    fn makespan_within_brent_bounds() {
        let m = machine(7);
        let jobs = [
            LevelJob { level: 0, n_samples: 40 },
            LevelJob { level: 2, n_samples: 11 },
            LevelJob { level: 5, n_samples: 2 },
        ];
        let ms = m.step_makespan(&jobs);
        let lb = m.brent_bound(&jobs);
        assert!(ms >= lb - 1e-9, "makespan {ms} < lower bound {lb}");
        assert!(ms <= 2.0 * lb, "makespan {ms} not within 2x of bound {lb}");
    }

    #[test]
    fn more_processors_never_slower() {
        let jobs = [
            LevelJob { level: 1, n_samples: 9 },
            LevelJob { level: 3, n_samples: 4 },
        ];
        let mut prev = f64::INFINITY;
        for p in [1, 2, 4, 8, 16, 64] {
            let ms = machine(p).step_makespan(&jobs);
            assert!(ms <= prev + 1e-9, "P={p}: {ms} > {prev}");
            prev = ms;
        }
    }

    #[test]
    fn saturation_at_depth() {
        // Beyond enough processors the makespan can't fall below the
        // longest single task — the parallel-complexity floor the paper's
        // delayed estimator attacks.
        let jobs = [LevelJob { level: 4, n_samples: 10 }];
        let depth = 16.0;
        assert_eq!(machine(100_000).step_makespan(&jobs), depth);
    }

    #[test]
    #[should_panic(expected = "at least one")]
    fn zero_processors_panics() {
        PramMachine::new(0, CostModel::new(1.0));
    }

    #[test]
    fn heap_schedule_handles_large_sample_counts() {
        // The O(S log P) heap makes very large N cheap; identical unit
        // tasks spread perfectly evenly, so the makespan is exact.
        let m = machine(8);
        let jobs = [LevelJob { level: 0, n_samples: 1_000_000 }];
        assert_eq!(m.step_makespan(&jobs), 125_000.0);
    }

    /// The pre-optimization LPT: expand one task per sample and sort.
    fn makespan_expanded_reference(m: &PramMachine, jobs: &[LevelJob]) -> f64 {
        let mut tasks: Vec<f64> = Vec::new();
        for j in jobs {
            let len = m.model.sample_cost(j.level);
            tasks.extend(std::iter::repeat(len).take(j.n_samples));
        }
        tasks.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let mut loads = vec![0.0f64; m.processors];
        for t in tasks {
            let (idx, _) = loads
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            loads[idx] += t;
        }
        loads.into_iter().fold(0.0, f64::max)
    }

    #[test]
    fn counting_schedule_matches_expansion_bitwise() {
        use crate::testkit::{check, Config, Gen};
        // Random c (irregular float lengths), random duplicate levels and
        // counts: the grouped schedule must equal the expanded one to the
        // last bit, including tie-breaking.
        check("grouped LPT == expanded LPT", Config { cases: 200, seed: 0x9A }, |g: &mut Gen| {
            let m = PramMachine::new(g.usize(1, 9), CostModel::new(g.f64(0.0, 2.0)));
            let n_jobs = g.usize(0, 6);
            let jobs: Vec<LevelJob> = (0..n_jobs)
                .map(|_| LevelJob {
                    level: g.usize(0, 6),
                    n_samples: g.usize(0, 40),
                })
                .collect();
            let fast = m.step_makespan(&jobs);
            let slow = makespan_expanded_reference(&m, &jobs);
            if fast.to_bits() != slow.to_bits() {
                return Err(format!("{fast} != {slow} for {jobs:?}"));
            }
            Ok(())
        });
    }
}
