//! PRAM-style cost accounting — the "massively parallel computer" of the
//! paper, as a model rather than actual hardware (DESIGN.md §2).

pub mod cost;
pub mod pram;

pub use cost::{CostModel, StepCost};
pub use pram::{LevelJob, PramMachine};
