//! Work/depth cost model (paper Assumption 1).
//!
//! One level-`l` gradient sample costs `2^{c l}` work units and — being a
//! sequential simulation — also `2^{c l}` *depth* (parallel complexity).
//! Samples within a level and different levels are mutually independent,
//! so on an unbounded machine a step's parallel complexity is the **max**
//! depth over the level jobs it runs, while its standard complexity is the
//! **sum** of work over all samples (Table 1's accounting).

/// Cost model parameterised by the cost-growth exponent `c`.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    pub c: f64,
}

impl CostModel {
    pub fn new(c: f64) -> Self {
        CostModel { c }
    }

    /// Work (= depth) units of ONE level-`l` coupled gradient sample.
    pub fn sample_cost(&self, level: usize) -> f64 {
        2f64.powf(self.c * level as f64)
    }

    /// Standard complexity of refreshing level `l` with `n_l` samples.
    pub fn level_work(&self, level: usize, n_l: usize) -> f64 {
        n_l as f64 * self.sample_cost(level)
    }
}

/// Accumulated cost of one SGD step.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StepCost {
    /// Total work units (standard complexity).
    pub work: f64,
    /// Critical-path depth units (parallel complexity).
    pub depth: f64,
}

impl StepCost {
    /// Cost of a step that refreshes `jobs = [(level, n_samples)]`
    /// concurrently: work adds up, depth is the max over jobs.
    pub fn from_jobs(model: &CostModel, jobs: &[(usize, usize)]) -> StepCost {
        let mut work = 0.0;
        let mut depth: f64 = 0.0;
        for &(level, n) in jobs {
            work += model.level_work(level, n);
            depth = depth.max(model.sample_cost(level));
        }
        StepCost { work, depth }
    }

    pub fn add(&mut self, other: StepCost) {
        self.work += other.work;
        self.depth += other.depth;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_cost_exponential() {
        let m = CostModel::new(1.0);
        assert_eq!(m.sample_cost(0), 1.0);
        assert_eq!(m.sample_cost(6), 64.0);
        let m2 = CostModel::new(2.0);
        assert_eq!(m2.sample_cost(3), 64.0);
    }

    #[test]
    fn step_cost_sum_vs_max() {
        let m = CostModel::new(1.0);
        let cost = StepCost::from_jobs(&m, &[(0, 100), (3, 10), (6, 1)]);
        assert_eq!(cost.work, 100.0 + 80.0 + 64.0);
        assert_eq!(cost.depth, 64.0); // max depth: the level-6 job
    }

    #[test]
    fn empty_step_is_free() {
        let m = CostModel::new(1.0);
        let cost = StepCost::from_jobs(&m, &[]);
        assert_eq!(cost, StepCost::default());
    }

    #[test]
    fn accumulate() {
        let mut total = StepCost::default();
        total.add(StepCost { work: 2.0, depth: 1.0 });
        total.add(StepCost { work: 3.0, depth: 4.0 });
        assert_eq!(total.work, 5.0);
        assert_eq!(total.depth, 5.0); // depths add ACROSS steps (sequential)
    }

    #[test]
    fn naive_vs_dmlmc_average_depth() {
        // Average per-step depth of the delayed schedule (refresh level l
        // every 2^l steps, c = d = 1) over a long horizon approaches
        // sum_l 2^{(c-d)l} * ... — concretely, far below naive's 2^lmax.
        let m = CostModel::new(1.0);
        let lmax = 6usize;
        let t_total = 1 << 10;
        let mut dmlmc_depth = 0.0;
        for t in 0..t_total {
            let jobs: Vec<(usize, usize)> = (0..=lmax)
                .filter(|&l| t % (1usize << l) == 0)
                .map(|l| (l, 1))
                .collect();
            dmlmc_depth += StepCost::from_jobs(&m, &jobs).depth;
        }
        let naive_depth = t_total as f64 * m.sample_cost(lmax);
        let speedup = naive_depth / dmlmc_depth;
        // theory: 64 / (sum over refreshed maxima) ~ 64 / ~3 ≈ 21; allow wide band
        assert!(speedup > 10.0, "speedup {speedup}");
    }
}
