//! Empirical verification of the paper's Assumptions 1–3 (Figure 1).
//!
//! Given per-level series of `E||grad Delta_l F_hat||^2` (variance proxy,
//! Assumption 2) or pathwise smoothness (Assumption 3), fit the decay
//! exponent by least-squares on `log2`: if `y_l ≈ A 2^{-r l}` then
//! `log2 y_l` is affine in `l` with slope `-r`.

use crate::metrics::Welford;

/// Mean/std series over levels, as plotted in Figure 1.
#[derive(Debug, Clone, Default)]
pub struct DecaySeries {
    /// One entry per level `l = 0..=lmax`: (mean, std) over snapshots.
    pub per_level: Vec<(f64, f64)>,
}

impl DecaySeries {
    /// Aggregate raw per-snapshot samples: `samples[l]` holds the values
    /// observed at level `l` across optimization snapshots. Uses the ONE
    /// shared streaming accumulator ([`Welford`]) — the same one behind
    /// the live estimator gauges — rather than a private two-pass copy.
    pub fn from_samples(samples: &[Vec<f64>]) -> DecaySeries {
        let per_level = samples
            .iter()
            .map(|vals| {
                let mut w = Welford::new();
                for &v in vals {
                    w.push(v);
                }
                (w.mean(), w.std())
            })
            .collect();
        DecaySeries { per_level }
    }

    /// Fitted decay exponent `r` (positive = decaying), via least squares
    /// of `log2(mean_l)` against `l`, skipping level 0 (the paper's decay
    /// assumptions only constrain the slope across coupled levels l >= 1).
    pub fn fitted_rate(&self) -> f64 {
        let pts: Vec<(f64, f64)> = self
            .per_level
            .iter()
            .enumerate()
            .skip(1)
            .filter(|(_, (m, _))| *m > 0.0)
            .map(|(l, (m, _))| (l as f64, m.log2()))
            .collect();
        -fit_slope(&pts)
    }
}

/// Least-squares slope of `y` against `x`.
pub fn fit_slope(pts: &[(f64, f64)]) -> f64 {
    let n = pts.len() as f64;
    if pts.len() < 2 {
        return 0.0;
    }
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return 0.0;
    }
    (n * sxy - sx * sy) / denom
}

/// Fit `y_l ≈ A 2^{-r l}` on `(level, value)` pairs; returns `r`.
pub fn fit_decay_rate(level_values: &[(usize, f64)]) -> f64 {
    let pts: Vec<(f64, f64)> = level_values
        .iter()
        .filter(|(_, v)| *v > 0.0)
        .map(|(l, v)| (*l as f64, v.log2()))
        .collect();
    -fit_slope(&pts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_slope_exact_line() {
        let pts: Vec<(f64, f64)> =
            (0..10).map(|i| (i as f64, 3.0 - 2.0 * i as f64)).collect();
        assert!((fit_slope(&pts) + 2.0).abs() < 1e-12);
    }

    #[test]
    fn recovers_exact_decay() {
        // y_l = 5 * 2^{-1.8 l}
        let vals: Vec<(usize, f64)> = (0..=6)
            .map(|l| (l, 5.0 * 2f64.powf(-1.8 * l as f64)))
            .collect();
        let r = fit_decay_rate(&vals);
        assert!((r - 1.8).abs() < 1e-9, "r = {r}");
    }

    #[test]
    fn recovers_noisy_decay() {
        // multiplicative noise should not move the slope much.
        let vals: Vec<(usize, f64)> = (0..=6)
            .map(|l| {
                let noise = 1.0 + 0.1 * ((l * 2654435761) % 7) as f64 / 7.0;
                (l, 3.0 * 2f64.powf(-2.0 * l as f64) * noise)
            })
            .collect();
        let r = fit_decay_rate(&vals);
        assert!((r - 2.0).abs() < 0.15, "r = {r}");
    }

    #[test]
    fn series_aggregation() {
        let s = DecaySeries::from_samples(&[
            vec![4.0, 4.0],
            vec![1.0, 3.0],
            vec![1.0],
        ]);
        assert_eq!(s.per_level[0], (4.0, 0.0));
        assert_eq!(s.per_level[1].0, 2.0);
        assert!(s.per_level[1].1 > 0.9);
    }

    #[test]
    fn series_pins_the_shared_welford_values_bitwise() {
        // Regression pin for the accumulator dedup: the series must
        // produce EXACTLY what the shared Welford produces (the same
        // accumulator behind the estimator gauges), bit for bit.
        let samples = vec![vec![2.0, 4.0, 6.0], vec![1.5, -0.25, 3.0], vec![]];
        let s = DecaySeries::from_samples(&samples);
        assert_eq!(s.per_level[0], (4.0, (8.0f64 / 3.0).sqrt()));
        let mut w = crate::metrics::Welford::new();
        for &v in &samples[1] {
            w.push(v);
        }
        assert_eq!(s.per_level[1], (w.mean(), w.std()));
        // empty level: zero-count accumulator, (0, 0) exactly
        assert_eq!(s.per_level[2], (0.0, 0.0));
    }

    #[test]
    fn fitted_rate_skips_level0() {
        // level 0 wildly off the line must not corrupt the fit.
        let mut samples = vec![vec![1000.0]];
        for l in 1..=6 {
            samples.push(vec![8.0 * 2f64.powf(-1.5 * l as f64)]);
        }
        let r = DecaySeries::from_samples(&samples).fitted_rate();
        assert!((r - 1.5).abs() < 1e-9, "r = {r}");
    }

    #[test]
    fn degenerate_inputs_dont_panic() {
        assert_eq!(fit_decay_rate(&[]), 0.0);
        assert_eq!(fit_decay_rate(&[(0, 1.0)]), 0.0);
        assert_eq!(fit_slope(&[(1.0, 1.0), (1.0, 2.0)]), 0.0);
    }
}
