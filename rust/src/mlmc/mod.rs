//! MLMC machinery: optimal per-level sample allocation (paper Appendix A),
//! the Table-1 theory formulas, and the empirical estimators behind the
//! Figure-1 assumption checks.

pub mod allocation;
pub mod assumptions;
pub mod estimator;
pub mod theory;

pub use allocation::LevelAllocation;
pub use assumptions::{fit_decay_rate, DecaySeries};
pub use estimator::MlmcEstimator;
pub use theory::TheoryRow;
