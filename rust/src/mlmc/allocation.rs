//! Optimal per-level sample allocation (paper §2 and Appendix A.2).
//!
//! Minimising the estimator variance `sum_l V_l / N_l` under the cost
//! budget `sum_l C_l N_l = C_total` with `V_l = M 2^{-bl}`,
//! `C_l = C 2^{cl}` yields `N_l ∝ sqrt(V_l / C_l) = 2^{-(b+c)l/2}`; the
//! paper normalises against an *effective batch size* `N`:
//!
//! `N_l = ceil( 2^{-(b+c)l/2} / sum_k 2^{-(b+c)k/2} * N )`.

/// Per-level sample counts for an MLMC estimator.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LevelAllocation {
    /// `N_l` for `l = 0..=lmax`.
    pub n_per_level: Vec<usize>,
}

impl LevelAllocation {
    /// The paper's allocation for effective batch size `n`, variance decay
    /// `b` and cost growth `c` (requires `b > c` for the `O(1/N)` rate).
    pub fn paper(lmax: usize, n: usize, b: f64, c: f64) -> Self {
        let weights: Vec<f64> = (0..=lmax)
            .map(|l| 2f64.powf(-(b + c) * l as f64 / 2.0))
            .collect();
        LevelAllocation::from_weights(&weights, n)
    }

    /// Normalise arbitrary non-negative per-level weights against the
    /// effective batch size `n`: `N_l = ceil(w_l / Σw * N)`, clamped to
    /// `>= 1`. [`LevelAllocation::paper`] is the special case
    /// `w_l = 2^{-(b+c)l/2}`; [`crate::policy::AdaptivePolicy`] feeds in
    /// the Giles weights `sqrt(V̂_l / Ĉ_l)` measured from live telemetry.
    pub fn from_weights(weights: &[f64], n: usize) -> Self {
        assert!(n > 0, "effective batch size must be positive");
        assert!(!weights.is_empty(), "need at least level 0");
        assert!(
            weights.iter().all(|w| w.is_finite() && *w >= 0.0),
            "weights must be finite and non-negative"
        );
        let z: f64 = weights.iter().sum();
        assert!(z > 0.0, "at least one weight must be positive");
        let n_per_level = weights
            .iter()
            .map(|w| ((w / z) * n as f64).ceil().max(1.0) as usize)
            .collect();
        LevelAllocation { n_per_level }
    }

    /// Uniform allocation (naive-style; used by ablations).
    pub fn uniform(lmax: usize, n_each: usize) -> Self {
        LevelAllocation {
            n_per_level: vec![n_each.max(1); lmax + 1],
        }
    }

    pub fn lmax(&self) -> usize {
        self.n_per_level.len() - 1
    }

    pub fn n(&self, level: usize) -> usize {
        self.n_per_level[level]
    }

    /// Total standard cost in work units, `sum_l N_l 2^{c l}`.
    pub fn standard_cost(&self, c: f64) -> f64 {
        self.n_per_level
            .iter()
            .enumerate()
            .map(|(l, &nl)| nl as f64 * 2f64.powf(c * l as f64))
            .sum()
    }

    /// Estimator variance bound `sum_l M 2^{-bl} / N_l` (up to `M`).
    pub fn variance_bound(&self, b: f64) -> f64 {
        self.n_per_level
            .iter()
            .enumerate()
            .map(|(l, &nl)| 2f64.powf(-b * l as f64) / nl as f64)
            .sum()
    }

    /// Round every level count *up* to a multiple of the backend's chunk
    /// size (artifacts are lowered with fixed chunk batches).
    pub fn round_to_chunks(&self, chunk_sizes: &[usize]) -> LevelAllocation {
        assert_eq!(chunk_sizes.len(), self.n_per_level.len());
        LevelAllocation {
            n_per_level: self
                .n_per_level
                .iter()
                .zip(chunk_sizes)
                .map(|(&nl, &ch)| nl.div_ceil(ch) * ch)
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_allocation_is_geometric() {
        // With b + c = 2.8, N_l should decay roughly 2^{-1.4} per level.
        let a = LevelAllocation::paper(6, 1024, 1.8, 1.0);
        assert_eq!(a.lmax(), 6);
        for l in 0..6 {
            let ratio = a.n(l) as f64 / a.n(l + 1) as f64;
            assert!(
                ratio >= 1.0,
                "allocation must be non-increasing: {:?}",
                a.n_per_level
            );
        }
        // Level 0 dominates: gets more than half the nominal budget share.
        assert!(a.n(0) > a.n(6) * 8);
    }

    #[test]
    fn every_level_gets_at_least_one() {
        let a = LevelAllocation::paper(6, 4, 1.8, 1.0);
        assert!(a.n_per_level.iter().all(|&n| n >= 1));
    }

    #[test]
    fn totals_close_to_n() {
        let n = 1 << 12;
        let a = LevelAllocation::paper(6, n, 1.8, 1.0);
        let total: usize = a.n_per_level.iter().sum();
        // ceil() rounding inflates by at most lmax+1.
        assert!(total >= n && total <= n + 7, "total {total}");
    }

    #[test]
    fn standard_cost_is_o_of_n_when_b_gt_c() {
        // Doubling N should roughly double the cost (O(N) complexity).
        let a1 = LevelAllocation::paper(6, 1 << 10, 1.8, 1.0);
        let a2 = LevelAllocation::paper(6, 1 << 11, 1.8, 1.0);
        let r = a2.standard_cost(1.0) / a1.standard_cost(1.0);
        assert!((r - 2.0).abs() < 0.3, "cost ratio {r}");
    }

    #[test]
    fn variance_bound_scales_inverse_n() {
        let a1 = LevelAllocation::paper(6, 1 << 10, 1.8, 1.0);
        let a2 = LevelAllocation::paper(6, 1 << 12, 1.8, 1.0);
        let r = a1.variance_bound(1.8) / a2.variance_bound(1.8);
        assert!((r - 4.0).abs() < 0.8, "variance ratio {r}");
    }

    #[test]
    fn chunk_rounding_rounds_up() {
        let a = LevelAllocation {
            n_per_level: vec![100, 10, 3],
        };
        let r = a.round_to_chunks(&[64, 8, 8]);
        assert_eq!(r.n_per_level, vec![128, 16, 8]);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_budget_panics() {
        LevelAllocation::paper(3, 0, 1.8, 1.0);
    }

    #[test]
    fn from_weights_normalizes_and_clamps() {
        let a = LevelAllocation::from_weights(&[3.0, 1.0, 0.0], 100);
        assert_eq!(a.n_per_level, vec![75, 25, 1]);
        // paper() is the geometric-weights special case, bit for bit
        let weights: Vec<f64> =
            (0..=6).map(|l| 2f64.powf(-2.8 * l as f64 / 2.0)).collect();
        assert_eq!(
            LevelAllocation::from_weights(&weights, 1024),
            LevelAllocation::paper(6, 1024, 1.8, 1.0)
        );
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn from_weights_rejects_nan() {
        LevelAllocation::from_weights(&[1.0, f64::NAN], 10);
    }

    #[test]
    #[should_panic(expected = "at least one weight")]
    fn from_weights_rejects_all_zero() {
        LevelAllocation::from_weights(&[0.0, 0.0], 10);
    }
}
