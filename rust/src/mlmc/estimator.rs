//! Assembly of MLMC gradient estimates from per-level components.
//!
//! A level component `grad Delta_l F_MLMC` is itself an average over
//! `N_l / chunk` backend executions (artifacts are lowered with a fixed
//! chunk batch); [`ChunkAccumulator`] maintains that running mean without
//! intermediate allocation, and [`MlmcEstimator`] sums the level means
//! into the final estimator `sum_l grad Delta_l` (paper §2).

/// Running mean of equally-weighted gradient chunks.
#[derive(Debug, Clone)]
pub struct ChunkAccumulator {
    sum: Vec<f32>,
    loss_sum: f64,
    count: usize,
}

impl ChunkAccumulator {
    pub fn new(dim: usize) -> Self {
        ChunkAccumulator {
            sum: vec![0.0; dim],
            loss_sum: 0.0,
            count: 0,
        }
    }

    /// Add one chunk's mean gradient (and its loss value).
    pub fn add(&mut self, loss: f64, grad: &[f32]) {
        assert_eq!(grad.len(), self.sum.len(), "gradient dim mismatch");
        for (a, &g) in self.sum.iter_mut().zip(grad) {
            *a += g;
        }
        self.loss_sum += loss;
        self.count += 1;
    }

    pub fn count(&self) -> usize {
        self.count
    }

    /// Mean over added chunks: `(mean loss, mean gradient)`.
    pub fn finish(self) -> (f64, Vec<f32>) {
        assert!(self.count > 0, "no chunks accumulated");
        let inv = 1.0 / self.count as f64;
        let mut grad = self.sum;
        for g in &mut grad {
            *g = (*g as f64 * inv) as f32;
        }
        (self.loss_sum * inv, grad)
    }
}

/// Sums per-level component gradients into the MLMC estimator.
#[derive(Debug, Clone)]
pub struct MlmcEstimator {
    grad: Vec<f32>,
    loss: f64,
    levels_added: usize,
}

impl MlmcEstimator {
    pub fn new(dim: usize) -> Self {
        MlmcEstimator {
            grad: vec![0.0; dim],
            loss: 0.0,
            levels_added: 0,
        }
    }

    /// Add the level-`l` component `grad Delta_l F` (already chunk-averaged).
    pub fn add_level(&mut self, loss_delta: f64, grad_delta: &[f32]) {
        assert_eq!(grad_delta.len(), self.grad.len(), "gradient dim mismatch");
        for (a, &g) in self.grad.iter_mut().zip(grad_delta) {
            *a += g;
        }
        self.loss += loss_delta;
        self.levels_added += 1;
    }

    pub fn levels_added(&self) -> usize {
        self.levels_added
    }

    /// The assembled estimator: telescoped loss and gradient.
    pub fn finish(self) -> (f64, Vec<f32>) {
        (self.loss, self.grad)
    }
}

/// Euclidean norm of a gradient (diagnostics / recorder).
pub fn grad_norm(grad: &[f32]) -> f64 {
    grad.iter().map(|&g| (g as f64) * (g as f64)).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_mean_is_exact() {
        let mut acc = ChunkAccumulator::new(3);
        acc.add(1.0, &[1.0, 0.0, 2.0]);
        acc.add(3.0, &[3.0, 4.0, 0.0]);
        let (loss, grad) = acc.finish();
        assert_eq!(loss, 2.0);
        assert_eq!(grad, vec![2.0, 2.0, 1.0]);
    }

    #[test]
    fn estimator_telescopes_levels() {
        let mut est = MlmcEstimator::new(2);
        est.add_level(0.5, &[1.0, -1.0]);
        est.add_level(-0.125, &[0.25, 0.5]);
        let (loss, grad) = est.finish();
        assert_eq!(loss, 0.375);
        assert_eq!(grad, vec![1.25, -0.5]);
        }

    #[test]
    fn single_chunk_identity() {
        let mut acc = ChunkAccumulator::new(2);
        acc.add(7.0, &[1.5, -2.5]);
        let (loss, grad) = acc.finish();
        assert_eq!(loss, 7.0);
        assert_eq!(grad, vec![1.5, -2.5]);
    }

    #[test]
    fn grad_norm_euclidean() {
        assert!((grad_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert_eq!(grad_norm(&[]), 0.0);
    }

    #[test]
    #[should_panic(expected = "dim mismatch")]
    fn dim_mismatch_panics() {
        let mut acc = ChunkAccumulator::new(2);
        acc.add(0.0, &[1.0]);
    }

    #[test]
    #[should_panic(expected = "no chunks")]
    fn empty_accumulator_panics() {
        ChunkAccumulator::new(1).finish();
    }
}
