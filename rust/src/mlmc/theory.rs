//! Table 1 of the paper as executable formulas: convergence-rate,
//! standard-complexity and parallel-complexity leading terms for the three
//! methods, plus the closed-form constants of Theorem 1.
//!
//! These are used by `examples/complexity_table.rs` and
//! `rust/benches/table1.rs` to print the theory column next to the
//! measured column.

/// The three optimization methods compared throughout the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MethodKind {
    Naive,
    Mlmc,
    Dmlmc,
}

impl MethodKind {
    pub fn name(self) -> &'static str {
        match self {
            MethodKind::Naive => "Naive SGD",
            MethodKind::Mlmc => "MLMC + SGD",
            MethodKind::Dmlmc => "Delayed MLMC + SGD (ours)",
        }
    }
}

/// One row of Table 1, instantiated for concrete `(T, N, M, lmax, b, c, d)`.
#[derive(Debug, Clone)]
pub struct TheoryRow {
    pub method: MethodKind,
    /// Leading convergence-rate term (without constants):
    /// naive/MLMC `1/T + (M/N)(·)`, delayed `logT/T · lmax + (M/N) lmax`.
    pub convergence: f64,
    /// Total standard complexity over T iterations, in work units.
    pub complexity: f64,
    /// Total parallel complexity over T iterations, in depth units.
    pub parallel: f64,
}

/// Parameters of the comparison.
#[derive(Debug, Clone, Copy)]
pub struct TheoryParams {
    pub t: f64,
    pub n: f64,
    pub m: f64,
    pub lmax: usize,
    pub b: f64,
    pub c: f64,
    pub d: f64,
}

/// `sum_{l=0}^{lmax} 2^{a l}` (the recurring geometric sums of the paper).
pub fn geom_sum(a: f64, lmax: usize) -> f64 {
    (0..=lmax).map(|l| 2f64.powf(a * l as f64)).sum()
}

impl TheoryRow {
    pub fn compute(method: MethodKind, p: &TheoryParams) -> TheoryRow {
        let l = p.lmax as f64;
        let two_cl = 2f64.powf(p.c * l);
        match method {
            MethodKind::Naive => TheoryRow {
                method,
                convergence: 1.0 / p.t + (p.m / p.n) * (l + 1.0),
                complexity: p.n * p.t * two_cl,
                parallel: p.t * two_cl,
            },
            MethodKind::Mlmc => TheoryRow {
                method,
                convergence: 1.0 / p.t + p.m / p.n,
                complexity: p.n * p.t,
                parallel: p.t * two_cl,
            },
            MethodKind::Dmlmc => TheoryRow {
                method,
                convergence: (p.t.ln() / p.t + p.m / p.n) * (l + 1.0),
                complexity: p.n * p.t,
                parallel: p.t * geom_sum(p.c - p.d, p.lmax),
            },
        }
    }

    /// All three rows.
    pub fn table(p: &TheoryParams) -> Vec<TheoryRow> {
        [MethodKind::Naive, MethodKind::Mlmc, MethodKind::Dmlmc]
            .into_iter()
            .map(|m| TheoryRow::compute(m, p))
            .collect()
    }
}

/// `M'` of Theorem 1: the MLMC gradient-variance bound
/// `M/N (sum 2^{-(b+c)l/2})(sum 2^{-(b-c)l/2})`.
pub fn m_prime(m: f64, n: f64, b: f64, c: f64, lmax: usize) -> f64 {
    (m / n) * geom_sum(-(b + c) / 2.0, lmax) * geom_sum(-(b - c) / 2.0, lmax)
}

/// Theorem 1's step-size ceiling:
/// `alpha_0 <= min(1/(8L), beta/L)` with
/// `beta = 1 / (12 (lmax+1) (sum_l 2^{-dl}) log(2T+1))`.
pub fn theorem1_step_size(l_smooth: f64, d: f64, lmax: usize, t: usize) -> f64 {
    let geo_inf = 1.0 / (1.0 - 2f64.powf(-d)); // sum_{l=0}^inf 2^{-dl}
    let beta = 1.0
        / (12.0 * (lmax as f64 + 1.0) * geo_inf * (2.0 * t as f64 + 1.0).ln());
    (1.0 / (8.0 * l_smooth)).min(beta / l_smooth)
}

/// Theorem 1's bound on the average squared gradient norm after T steps.
pub fn theorem1_bound(
    f0_minus_finf: f64,
    alpha0: f64,
    t: usize,
    m_prime: f64,
    lmax: usize,
) -> f64 {
    8.0 * f0_minus_finf / (alpha0 * t as f64)
        + (24.0 * lmax as f64 + 24.5) * m_prime
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> TheoryParams {
        TheoryParams {
            t: 1000.0,
            n: 1024.0,
            m: 1.0,
            lmax: 6,
            b: 1.8,
            c: 1.0,
            d: 1.0,
        }
    }

    #[test]
    fn geom_sum_closed_form() {
        assert!((geom_sum(0.0, 6) - 7.0).abs() < 1e-12);
        assert!((geom_sum(1.0, 2) - 7.0).abs() < 1e-12); // 1+2+4
        assert!((geom_sum(-1.0, 2) - 1.75).abs() < 1e-12); // 1+1/2+1/4
    }

    #[test]
    fn table1_ordering_standard_complexity() {
        // naive >> mlmc == dmlmc in standard complexity.
        let rows = TheoryRow::table(&params());
        assert!(rows[0].complexity > 10.0 * rows[1].complexity);
        assert_eq!(rows[1].complexity, rows[2].complexity);
    }

    #[test]
    fn table1_ordering_parallel_complexity() {
        // naive == mlmc >> dmlmc in parallel complexity (c = d = 1 gives
        // the lmax+1 vs 2^lmax gap).
        let rows = TheoryRow::table(&params());
        assert_eq!(rows[0].parallel, rows[1].parallel);
        let speedup = rows[1].parallel / rows[2].parallel;
        // 2^6 / 7 ≈ 9.1
        assert!(speedup > 8.0 && speedup < 10.0, "speedup {speedup}");
    }

    #[test]
    fn dmlmc_parallel_regimes() {
        // c < d: O(1) per step; c > d: still exponential in lmax.
        let mut p = params();
        p.d = 2.0;
        let fast = TheoryRow::compute(MethodKind::Dmlmc, &p).parallel / p.t;
        p.d = 0.5;
        let slow = TheoryRow::compute(MethodKind::Dmlmc, &p).parallel / p.t;
        assert!(fast < 2.1, "c<d per-step cost should be O(1): {fast}");
        assert!(slow > 10.0, "c>d per-step cost grows: {slow}");
    }

    #[test]
    fn m_prime_shrinks_with_n() {
        let a = m_prime(1.0, 1024.0, 1.8, 1.0, 6);
        let b = m_prime(1.0, 4096.0, 1.8, 1.0, 6);
        assert!((a / b - 4.0).abs() < 1e-9);
    }

    #[test]
    fn theorem1_step_size_decreases_with_t() {
        let a = theorem1_step_size(10.0, 1.0, 6, 100);
        let b = theorem1_step_size(10.0, 1.0, 6, 10_000);
        assert!(b < a);
        assert!(a <= 1.0 / 80.0 + 1e-12);
    }

    #[test]
    fn theorem1_bound_decays_then_floors() {
        let mp = m_prime(1.0, 1024.0, 1.8, 1.0, 6);
        let early = theorem1_bound(1.0, 1e-3, 100, mp, 6);
        let late = theorem1_bound(1.0, 1e-3, 100_000, mp, 6);
        assert!(late < early);
        // floor = (24 lmax + 24.5) M'
        assert!(late >= (24.0 * 6.0 + 24.5) * mp);
    }
}
