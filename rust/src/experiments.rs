//! Experiment drivers — the reusable logic behind the `repro` CLI, the
//! examples and the per-figure benches. Each paper table/figure has one
//! driver here (DESIGN.md §3 experiment index).
//!
//! All drivers hang off ONE entry point, [`ExperimentRunner`]: a named
//! configuration (+ `--out-dir` + quiet flag) whose methods run the
//! experiments and whose [`artifacts`](ExperimentRunner::artifacts)
//! hands out the run-scoped [`RunArtifacts`] writer every output goes
//! through — no experiment hand-rolls its own JSON/CSV path. The table
//! renderers are associated functions of the runner for the same
//! reason; a unit test (and a CI grep) pins that this module exports no
//! top-level `pub fn` that could bypass the runner.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{Backend, ExperimentConfig};
use crate::coordinator::{
    run_jobs_pool_with_report, FleetCoordinator, LevelJobSpec, Method, Trainer,
    TrainerBuilder,
};
use crate::exec::WorkerPool;
use crate::hedging::bs_call_price;
use crate::metrics::aggregate::AggregatedCurve;
use crate::metrics::{aggregate_curves, LearningCurve, RunArtifacts, Welford};
use crate::mlmc::theory::{TheoryParams, TheoryRow};
use crate::mlmc::{fit_decay_rate, DecaySeries};
use crate::obs::{MetricsServer, ServeState, TraceSink};
use crate::parallel::{CostModel, LevelJob, PramMachine};
use crate::rng::{brownian::Purpose, BrownianSource};
use crate::runtime::{GradBackend, NativeBackend};
use crate::scenarios::build_scenario_or_err;

// ---------------------------------------------------------------------------
// Result rows (one struct per table/figure)
// ---------------------------------------------------------------------------

/// Figure-1 output: per-level series + fitted decay exponents.
#[derive(Debug, Clone)]
pub struct Figure1 {
    /// `E||grad Delta_l F_hat||^2` per level (mean, std over snapshots).
    pub grad_norms: DecaySeries,
    /// Pathwise smoothness per level (mean, std over snapshots).
    pub smoothness: DecaySeries,
    /// Fitted variance-decay exponent (paper: b ≈ 2).
    pub b_hat: f64,
    /// Fitted smoothness-decay exponent (paper: d ≈ 1).
    pub d_hat: f64,
}

/// One measured row of Table 1.
#[derive(Debug, Clone)]
pub struct MeasuredRow {
    pub method: Method,
    pub final_loss: f64,
    pub std_cost: f64,
    pub par_cost: f64,
    /// Average per-iteration parallel depth.
    pub avg_depth: f64,
}

/// One row of the scenario sweep: the fitted variance-decay exponent and
/// the measured MLMC vs delayed-MLMC parallel cost for one scenario.
#[derive(Debug, Clone)]
pub struct ScenarioRow {
    pub name: String,
    /// Fitted decay exponent of `E||grad Delta_l F_hat||^2` at the
    /// initial parameters (Assumption 2's `b`).
    pub b_hat: f64,
    /// Whether the fitted decay supports Assumption 2 (`b_hat > c`).
    pub assumption_ok: bool,
    /// Total parallel cost of the standard-MLMC run.
    pub mlmc_par: f64,
    /// Total parallel cost of the delayed-MLMC run.
    pub dmlmc_par: f64,
    /// `mlmc_par / dmlmc_par` — the paper's parallel-complexity advantage.
    pub par_ratio: f64,
    /// Final held-out loss of the delayed-MLMC run.
    pub final_loss: f64,
}

/// One (method, worker count) cell of the parallel sweep: what the pool
/// *measured* on this machine next to what the PRAM model *predicts* for
/// the same schedule at the same P. All wall-clock fields are seconds.
#[derive(Debug, Clone)]
pub struct ParallelCell {
    pub method: Method,
    pub workers: usize,
    pub steps: usize,
    /// Mean measured per-step makespan (seconds) over the training run.
    pub measured_mean_s: f64,
    /// Total measured makespan (seconds).
    pub measured_total_s: f64,
    /// Pool utilization: busy / (P x makespan), in [0, 1].
    pub utilization: f64,
    /// Mean per-step dispatch overhead (seconds): measured makespan minus
    /// the busiest worker — the executor's fixed per-step cost, which the
    /// resident pool amortizes relative to spawn-per-dispatch.
    pub overhead_mean_s: f64,
    /// Mean per-step makespan predicted by greedy LPT on the PRAM model
    /// (`PramMachine::step_makespan`), in model work units.
    pub pram_makespan: f64,
    /// Mean per-step Brent lower bound (`max(work/P, depth)`), in model
    /// work units.
    pub brent_bound: f64,
    pub final_loss: f64,
}

/// Resident-vs-scoped spawn-overhead comparison on a **light**
/// (level-0-only) dispatch — the typical DMLMC step after warmup, where
/// the refresh is one small job and per-step executor overhead dominates
/// the measured makespan. This is the number that shows the resident
/// pool's win directly instead of asserting it.
#[derive(Debug, Clone)]
pub struct ExecOverheadComparison {
    pub workers: usize,
    /// Measured dispatches per mode (one extra warmup dispatch per mode
    /// is excluded from the means).
    pub steps: usize,
    pub resident_overhead_mean_s: f64,
    pub scoped_overhead_mean_s: f64,
    pub resident_makespan_mean_s: f64,
    pub scoped_makespan_mean_s: f64,
    /// OS threads spawned over the whole run: `workers` for the resident
    /// pool, ~`(steps + 1) x min(workers, tasks)` for the scoped one.
    pub resident_threads_spawned: usize,
    pub scoped_threads_spawned: usize,
}

/// One (fleet size, worker count) cell of the fleet sweep: aggregate
/// serving throughput of one resident pool multiplexing `fleet_size`
/// independent DMLMC trainers.
#[derive(Debug, Clone)]
pub struct FleetCell {
    pub fleet_size: usize,
    pub workers: usize,
    /// Scenario name of each submitted problem (round-robin over the
    /// requested scenario list).
    pub problems: Vec<String>,
    pub steps_per_problem: usize,
    /// `fleet_size x steps_per_problem`.
    pub total_steps: usize,
    /// Fleet ticks (multiplexed dispatches) it took to drain.
    pub ticks: usize,
    /// Wall-clock seconds from first submit to drained.
    pub wall_s: f64,
    /// Aggregate SGD steps per second across the whole fleet.
    pub steps_per_sec: f64,
    /// Completed problems per second.
    pub problems_per_sec: f64,
    /// Shared-pool utilization over the drain: busy / (P x makespan).
    pub utilization: f64,
    /// Mean makespan of one multiplexed dispatch (seconds).
    pub mean_step_makespan_s: f64,
}

/// One mode row of the fixed-vs-adaptive allocation ablation
/// (`repro adaptive-sweep`): the same DMLMC problem trained once under
/// the offline-theory [`crate::policy::FixedPolicy`] and once under the
/// telemetry-fed [`crate::policy::AdaptivePolicy`], compared on
/// wall-clock-to-target-loss and measured parallel cost per step.
#[derive(Debug, Clone)]
pub struct AdaptiveCell {
    /// `"fixed"` or `"adaptive"`.
    pub mode: String,
    pub steps: usize,
    /// Final held-out loss of this mode's run.
    pub final_loss: f64,
    /// The shared target: the WORSE of the two final losses, so both
    /// modes reach it by construction and the wall-clock comparison is
    /// apples-to-apples.
    pub target_loss: f64,
    /// Wall-clock seconds from the first step to the first eval point at
    /// or below `target_loss`.
    pub wall_clock_to_target_s: f64,
    /// Mean model parallel cost (depth) per step — the paper's
    /// per-iteration parallel complexity, as the run actually scheduled
    /// it.
    pub mean_parallel_cost: f64,
    /// Mean measured per-step makespan (seconds) on the pool.
    pub mean_step_makespan_s: f64,
    /// Decisions the policy adopted over the run (0 for fixed).
    pub adaptations: u64,
}

/// Output of the overhead-bounded tracing benchmark (`repro trace`):
/// the same DMLMC training run with tracing off and on, plus the shape
/// of the exported trace. Wall-clock fields are seconds.
#[derive(Debug, Clone)]
pub struct TraceBench {
    pub workers: usize,
    pub steps: usize,
    pub repeats: usize,
    /// Best (min over repeats) mean per-step makespan, tracing off.
    pub untraced_mean_makespan_s: f64,
    /// Best (min over repeats) mean per-step makespan, tracing on.
    pub traced_mean_makespan_s: f64,
    /// `traced / untraced` of the two best means — the bounded-overhead
    /// headline (min-of-means is robust to scheduler noise).
    pub overhead_ratio: f64,
    /// Best (min over repeats) mean per-step makespan with tracing on
    /// AND a concurrent `/metrics` poller scraping the live registry
    /// over HTTP for the whole run (the scrape-under-load row).
    pub scraped_mean_makespan_s: f64,
    /// `scraped / untraced` of the two best means.
    pub scrape_overhead_ratio: f64,
    /// Successful `/metrics` fetches across the scraped repeats (>= 1
    /// by construction: a final fetch happens after each run).
    pub scrapes_total: usize,
    /// Retained `task` spans per worker track in the exported trace.
    pub spans_per_worker: Vec<usize>,
    /// Coordinator-track spans (`step` + `dispatch`).
    pub coordinator_spans: usize,
    /// Spans evicted by ring capacity (0 at bench sizes).
    pub dropped_spans: usize,
    /// Where `trace.json` landed.
    pub trace_path: PathBuf,
    /// Where `metrics.prom` landed.
    pub metrics_path: PathBuf,
}

/// One scenario cell of the hot-path bench (`repro hotpath-bench`):
/// the scalar monomorphized kernel vs its 8-wide lane-blocked twin, on
/// identical Brownian inputs through `value_and_grad` — the per-chunk
/// unit of work the trainer's hot loop is made of.
#[derive(Debug, Clone)]
pub struct HotpathCell {
    pub scenario: String,
    /// Paths per kernel invocation (the timed unit).
    pub batch: usize,
    /// Fine-grid steps per path at the benched level.
    pub n_steps: usize,
    /// Median throughput of the scalar kernel (paths/second).
    pub scalar_paths_per_sec: f64,
    /// Median throughput of the lane-blocked kernel (paths/second).
    pub lanes_paths_per_sec: f64,
    /// `lanes_paths_per_sec / scalar_paths_per_sec`.
    pub speedup: f64,
}

// ---------------------------------------------------------------------------
// Private helpers
// ---------------------------------------------------------------------------

/// Diagnostic chunks accumulated per (snapshot, level) — the per-sample
/// second moments are heavy-tailed, so one 32-sample chunk is far too
/// noisy for a slope fit (measured: b̂ swings 0.9 ↔ 1.4 at 32 vs 512
/// samples). 4 chunks x diag batch is the accuracy/runtime sweet spot.
const DIAG_CHUNKS: u32 = 4;

/// Chunks averaged per (level) when fitting `b_hat` — same reasoning as
/// [`DIAG_CHUNKS`]: per-sample second moments are heavy-tailed.
const SWEEP_CHUNKS: u32 = 4;

/// Overhead bound `trace_bench` asserts: the traced run's best mean
/// makespan must stay within `factor x untraced + floor`. The factor is
/// generous and the floor absorbs scheduler noise at sub-millisecond
/// step sizes — the point is catching *pathological* overhead (the
/// recorder accidentally landing on the worker hot path), not winning a
/// microbenchmark.
const TRACE_OVERHEAD_FACTOR: f64 = 2.0;
const TRACE_OVERHEAD_FLOOR_S: f64 = 0.002;

/// Overhead bound for the scrape-under-load row: a concurrent `/metrics`
/// poller adds a reader thread and registry read-locks, so the bound is
/// looser than plain tracing — but still tight enough to catch a scrape
/// that blocks the coordinator's publishes (a write-starved `RwLock`
/// would blow straight through it).
const SCRAPE_OVERHEAD_FACTOR: f64 = 3.0;
const SCRAPE_OVERHEAD_FLOOR_S: f64 = 0.005;

/// One blocking `/metrics` fetch against a [`MetricsServer`]; `Some`
/// (with the whole response) only on a 200.
fn scrape_metrics(addr: std::net::SocketAddr) -> Option<String> {
    use std::io::{Read, Write};
    let mut conn = std::net::TcpStream::connect(addr).ok()?;
    conn.write_all(b"GET /metrics HTTP/1.1\r\nHost: bench\r\n\r\n")
        .ok()?;
    let mut text = String::new();
    conn.read_to_string(&mut text).ok()?;
    text.starts_with("HTTP/1.1 200").then_some(text)
}

/// The PRAM jobs of step `t` under `method` — the same workload the pool
/// executes, expressed in samples for the counting scheduler.
fn pram_jobs(tr: &Trainer, method: Method, t: u64) -> Vec<LevelJob> {
    match method {
        Method::Naive => vec![LevelJob {
            level: tr.cfg.problem.lmax,
            n_samples: tr.naive_chunks() * tr.backend().naive_chunk(),
        }],
        _ => tr
            .jobs_for_step(t)
            .iter()
            .map(|j| LevelJob {
                level: j.level,
                n_samples: j.n_chunks * tr.backend().grad_chunk(j.level),
            })
            .collect(),
    }
}

/// Fit the variance-decay exponent `b` for one scenario backend at the
/// given parameters (levels `1..=lmax`, the decay-constrained range).
fn fit_b_hat(
    backend: &NativeBackend,
    cfg: &ExperimentConfig,
    params: &[f32],
) -> Result<f64> {
    let src = BrownianSource::new(0xB0);
    let mut level_means = Vec::new();
    for level in 1..=cfg.problem.lmax {
        let n = cfg.problem.n_steps(level);
        let batch = backend.diag_chunk();
        let mut w = Welford::new();
        for chunk in 0..SWEEP_CHUNKS {
            let dw = src.increments_multi(
                Purpose::Diagnostic,
                0,
                level as u32,
                chunk,
                batch,
                n,
                cfg.problem.dt(level),
                backend.n_factors(),
            );
            for v in backend.grad_norms_chunk(level, params, &dw)? {
                w.push(v as f64);
            }
        }
        level_means.push((level, w.mean()));
    }
    Ok(fit_decay_rate(&level_means))
}

// ---------------------------------------------------------------------------
// ExperimentRunner — the one front door
// ---------------------------------------------------------------------------

/// The experiment front door: a configuration + output directory +
/// verbosity, with one method per paper table/figure (and the serving
/// benchmarks). Construct with [`new`](Self::new), adjust with the
/// builder-style [`out_dir`](Self::out_dir) / [`quiet`](Self::quiet),
/// then call the experiment you want; write its outputs through
/// [`artifacts`](Self::artifacts).
///
/// ```no_run
/// use dmlmc::config::ExperimentConfig;
/// use dmlmc::experiments::ExperimentRunner;
///
/// let cfg = ExperimentConfig::smoke();
/// let runner = ExperimentRunner::new(&cfg).quiet(true);
/// let (theory, measured) = runner.table1()?;
/// let arts = runner.artifacts("table1")?;
/// arts.write_text(
///     "table1.txt",
///     &ExperimentRunner::render_table1(&theory, &measured),
/// )?;
/// # anyhow::Ok(())
/// ```
pub struct ExperimentRunner {
    cfg: ExperimentConfig,
    out_dir: PathBuf,
    quiet: bool,
}

impl ExperimentRunner {
    /// A runner over `cfg` writing under `artifacts/` (override with
    /// [`out_dir`](Self::out_dir)), verbose by default.
    pub fn new(cfg: &ExperimentConfig) -> ExperimentRunner {
        ExperimentRunner {
            cfg: cfg.clone(),
            out_dir: PathBuf::from("artifacts"),
            quiet: false,
        }
    }

    /// Root directory named runs land under (the CLI's `--out-dir`).
    pub fn out_dir(mut self, dir: impl Into<PathBuf>) -> ExperimentRunner {
        self.out_dir = dir.into();
        self
    }

    /// Suppress per-run progress on stderr.
    pub fn quiet(mut self, quiet: bool) -> ExperimentRunner {
        self.quiet = quiet;
        self
    }

    /// The runner's configuration.
    pub fn cfg(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// The shared artifacts writer for a named run: everything one
    /// experiment writes goes through this (into `<out_dir>/<run>/`).
    pub fn artifacts(&self, run: &str) -> Result<RunArtifacts> {
        RunArtifacts::create(&self.out_dir, run).map_err(|e| {
            anyhow::anyhow!(
                "create run dir {}/{run}: {e}",
                self.out_dir.display()
            )
        })
    }

    // -- Figure 2: learning curves of the three methods -----------------

    /// All runs for one method over `cfg.train.n_seeds` seeds.
    pub fn method_curves(&self, method: Method) -> Result<Vec<LearningCurve>> {
        let mut curves = Vec::new();
        for seed in 0..self.cfg.train.n_seeds as u64 {
            let mut tr = Trainer::from_config(&self.cfg, method, seed)?;
            let curve = tr.run()?;
            if !self.quiet {
                eprintln!(
                    "  {method} seed {seed}: loss {:.4} -> {:.4} (par cost {:.0})",
                    curve.points.first().map(|p| p.loss).unwrap_or(f64::NAN),
                    curve.final_loss().unwrap_or(f64::NAN),
                    curve.points.last().map(|p| p.par_cost).unwrap_or(0.0),
                );
            }
            curves.push(curve);
        }
        Ok(curves)
    }

    /// The full Figure-2 experiment: 3 methods x n_seeds, aggregated.
    pub fn figure2(
        &self,
    ) -> Result<Vec<(Method, Vec<LearningCurve>, AggregatedCurve)>> {
        let mut out = Vec::new();
        for method in Method::all() {
            if !self.quiet {
                eprintln!(
                    "figure2: running {method} x{} seeds",
                    self.cfg.train.n_seeds
                );
            }
            let curves = self.method_curves(method)?;
            let agg = aggregate_curves(&curves).map_err(anyhow::Error::msg)?;
            out.push((method, curves, agg));
        }
        Ok(out)
    }

    // -- Figure 1: assumption decay diagnostics --------------------------

    /// Reproduce Figure 1: track the decay diagnostics at parameter
    /// snapshots taken along a (DMLMC) optimization trajectory.
    pub fn figure1(&self, snapshots: usize) -> Result<Figure1> {
        let cfg = &self.cfg;
        let mut tr = Trainer::from_config(cfg, Method::Dmlmc, 0)?;
        let lmax = cfg.problem.lmax;
        let src = BrownianSource::new(0xF1);
        let mut norm_samples: Vec<Vec<f64>> = vec![Vec::new(); lmax + 1];
        let mut smooth_samples: Vec<Vec<f64>> = vec![Vec::new(); lmax + 1];

        let snap_every = (cfg.train.steps / snapshots.max(1)).max(1) as u64;
        for t in 0..cfg.train.steps as u64 {
            let params_before = tr.params.clone();
            tr.step(t)?;
            if t % snap_every == 0 {
                let params_after = tr.params.clone();
                for level in 0..=lmax {
                    let batch = tr.backend().diag_chunk();
                    let n = cfg.problem.n_steps(level);
                    let mut w = Welford::new();
                    let mut ws = Welford::new();
                    for chunk in 0..DIAG_CHUNKS {
                        let dw = src.increments_multi(
                            Purpose::Diagnostic,
                            t,
                            level as u32,
                            chunk,
                            batch,
                            n,
                            cfg.problem.dt(level),
                            tr.backend().n_factors(),
                        );
                        let norms = tr.backend().grad_norms_chunk(
                            level,
                            &params_before,
                            &dw,
                        )?;
                        for v in &norms {
                            w.push(*v as f64);
                        }
                        // pathwise smoothness between consecutive iterates
                        let vals = tr.backend().smoothness_chunk(
                            level,
                            &params_before,
                            &params_after,
                            &dw,
                        )?;
                        for v in &vals {
                            ws.push(*v as f64);
                        }
                    }
                    norm_samples[level].push(w.mean());
                    smooth_samples[level].push(ws.mean());
                }
                if !self.quiet {
                    eprintln!("figure1: snapshot at step {t}");
                }
            }
        }

        let grad_norms = DecaySeries::from_samples(&norm_samples);
        let smoothness = DecaySeries::from_samples(&smooth_samples);
        // Assumption 2: E||grad Delta_l||^2 <= M 2^{-bl}  -> slope = b.
        let b_hat = grad_norms.fitted_rate();
        // Assumption 3: Lipschitz constant decays 2^{-dl}   -> slope = d.
        let d_hat = smoothness.fitted_rate();
        Ok(Figure1 {
            grad_norms,
            smoothness,
            b_hat,
            d_hat,
        })
    }

    // -- Table 1: theory vs measured complexity accounting ---------------

    /// Table 1: run each method for `cfg.train.steps` steps (single seed)
    /// and account costs; pair with the theory formulas.
    pub fn table1(&self) -> Result<(Vec<TheoryRow>, Vec<MeasuredRow>)> {
        let cfg = &self.cfg;
        let theory = TheoryRow::table(&TheoryParams {
            t: cfg.train.steps as f64,
            n: cfg.mlmc.n_effective as f64,
            m: 1.0,
            lmax: cfg.problem.lmax,
            b: cfg.mlmc.b,
            c: cfg.mlmc.c,
            d: cfg.mlmc.d,
        });
        let mut measured = Vec::new();
        for method in Method::all() {
            let mut tr = Trainer::from_config(cfg, method, 0)?;
            let curve = tr.run()?;
            let cost = tr.cumulative_cost();
            measured.push(MeasuredRow {
                method,
                final_loss: curve.final_loss().unwrap_or(f64::NAN),
                std_cost: cost.work,
                par_cost: cost.depth,
                avg_depth: cost.depth / cfg.train.steps as f64,
            });
        }
        Ok((theory, measured))
    }

    // -- Black–Scholes validation (geometric drift) ----------------------

    /// Train under the *martingale* GBM (`geometric` drift, `mu = 0`) and
    /// compare the learned price `p0` with the Black–Scholes closed form —
    /// the external correctness anchor for the whole stack.
    ///
    /// Under `mu = 0`, `S` is a martingale, so `E[∫ H dS] = 0` for **any**
    /// strategy `H`; the optimal `p0` of the quadratic hedging objective
    /// is therefore exactly `E[max(S_T − K, 0)] = BS(s0, K, sigma, T)`
    /// whatever the MLP has learned — a sharp anchor that does not
    /// require the hedge itself to have converged.
    pub fn validate_bs(&self) -> Result<(f64, f64)> {
        use crate::engine::mlp::OFF_P0;
        let mut cfg = self.cfg.clone();
        cfg.problem.drift = crate::hedging::Drift::Geometric;
        cfg.problem.mu = 0.0;
        // The anchor is the Black–Scholes CALL closed form, so the
        // scenario must be the default whatever the caller configured.
        cfg.scenario = crate::scenarios::DEFAULT_SCENARIO.to_string();
        // The validation problem differs from the one the artifacts were
        // lowered for (drift/mu), so it always runs on the native engine —
        // which the cross-check tests pin to the HLO numerics anyway.
        cfg.runtime.backend = crate::config::Backend::Native;
        let mut tr = Trainer::from_config(&cfg, Method::Mlmc, 0)?;
        tr.run()?;
        let p0 = tr.params[OFF_P0] as f64;
        let bs = bs_call_price(
            cfg.problem.s0,
            cfg.problem.strike,
            cfg.problem.sigma,
            cfg.problem.maturity,
        );
        Ok((p0, bs))
    }

    // -- Delay-exponent ablation -----------------------------------------

    /// Sweep the delay exponent `d`: per value, final loss and total
    /// costs.
    pub fn sweep_delay(&self, ds: &[f64]) -> Result<Vec<(f64, MeasuredRow)>> {
        let mut rows = Vec::new();
        for &d in ds {
            let mut c = self.cfg.clone();
            c.mlmc.d = d;
            let mut tr = Trainer::from_config(&c, Method::Dmlmc, 0)?;
            let curve = tr.run()?;
            let cost = tr.cumulative_cost();
            rows.push((
                d,
                MeasuredRow {
                    method: Method::Dmlmc,
                    final_loss: curve.final_loss().unwrap_or(f64::NAN),
                    std_cost: cost.work,
                    par_cost: cost.depth,
                    avg_depth: cost.depth / c.train.steps as f64,
                },
            ));
        }
        Ok(rows)
    }

    /// Average per-step depth predicted by the cost model for a schedule —
    /// used to check measured against `sum_l 2^{(c-d)l}`.
    pub fn predicted_avg_depth(&self, horizon: u64) -> f64 {
        let cfg = &self.cfg;
        let sched =
            crate::coordinator::DelayedSchedule::new(cfg.problem.lmax, cfg.mlmc.d);
        let model = CostModel::new(cfg.mlmc.c);
        let mut total = 0.0;
        for t in 0..horizon {
            let depth = sched
                .levels_due(t)
                .into_iter()
                .map(|l| model.sample_cost(l))
                .fold(0.0, f64::max);
            total += depth;
        }
        total / horizon as f64
    }

    // -- Scenario sweep ---------------------------------------------------

    /// For every named scenario: fit `b_hat` (Assumption 2), then run one
    /// standard-MLMC and one delayed-MLMC training and compare total
    /// parallel cost — demonstrating the paper's parallel-complexity
    /// advantage is scenario-generic. Always runs on the native backend.
    pub fn scenario_sweep(&self, names: &[String]) -> Result<Vec<ScenarioRow>> {
        let mut rows = Vec::new();
        for name in names {
            let mut c = self.cfg.clone();
            c.scenario = name.clone();
            c.runtime.backend = Backend::Native;
            let scenario = build_scenario_or_err(name, &c.problem)?;
            let backend = NativeBackend::with_scenario(c.problem, scenario);
            let params = crate::engine::mlp::init_params(0);
            let b_hat = fit_b_hat(&backend, &c, &params)?;

            let mut mlmc = Trainer::from_config(&c, Method::Mlmc, 0)?;
            mlmc.run()?;
            let mut dmlmc = Trainer::from_config(&c, Method::Dmlmc, 0)?;
            let curve = dmlmc.run()?;
            let mlmc_par = mlmc.cumulative_cost().depth;
            let dmlmc_par = dmlmc.cumulative_cost().depth;
            let row = ScenarioRow {
                name: name.clone(),
                b_hat,
                assumption_ok: b_hat > c.mlmc.c,
                mlmc_par,
                dmlmc_par,
                par_ratio: mlmc_par / dmlmc_par,
                final_loss: curve.final_loss().unwrap_or(f64::NAN),
            };
            if !self.quiet {
                eprintln!(
                    "scenario_sweep: {name:<14} b_hat {b_hat:>6.2}  par ratio {:.2}",
                    row.par_ratio
                );
            }
            rows.push(row);
        }
        Ok(rows)
    }

    // -- Parallel sweep: measured pool vs the PRAM model ------------------

    /// For every `P` in `workers` x every method: train on the native
    /// backend with a `P`-worker pool, and record the measured per-step
    /// makespan next to the PRAM-predicted one for the identical
    /// schedule. This is the experiment that turns the paper's
    /// parallel-complexity gap (DMLMC's per-iteration depth ~ O(1) vs
    /// MLMC's O(2^lmax)) into wall-clock numbers.
    pub fn parallel_sweep(&self, workers: &[usize]) -> Result<Vec<ParallelCell>> {
        anyhow::ensure!(!workers.is_empty(), "need at least one worker count");
        let mut cells = Vec::new();
        for &p in workers {
            anyhow::ensure!(p > 0, "worker counts must be positive (got {p})");
            for method in Method::all() {
                let mut c = self.cfg.clone();
                c.runtime.backend = Backend::Native;
                c.execution.workers = p;
                let mut tr = Trainer::from_config(&c, method, 0)?;
                // Model predictions first: jobs_for_step is pure, so the
                // schedule can be replayed without running anything.
                let machine = PramMachine::new(p, CostModel::new(c.mlmc.c));
                let mut pram_total = 0.0;
                let mut brent_total = 0.0;
                for t in 0..c.train.steps as u64 {
                    let jobs = pram_jobs(&tr, method, t);
                    pram_total += machine.step_makespan(&jobs);
                    brent_total += machine.brent_bound(&jobs);
                }
                let curve = tr.run()?;
                let stats = tr
                    .exec_stats()
                    .expect("native backend always pools")
                    .clone();
                let steps = c.train.steps as f64;
                let cell = ParallelCell {
                    method,
                    workers: p,
                    steps: c.train.steps,
                    measured_mean_s: stats.mean_makespan(),
                    measured_total_s: stats.total_makespan(),
                    utilization: stats.utilization(),
                    overhead_mean_s: stats.mean_dispatch_overhead(),
                    pram_makespan: pram_total / steps,
                    brent_bound: brent_total / steps,
                    final_loss: curve.final_loss().unwrap_or(f64::NAN),
                };
                if !self.quiet {
                    eprintln!(
                        "parallel_sweep: {method:<6} P={p}  measured {:.6} s/step  \
                         ovh {:.6} s  pram {:.0}  util {:.0}%",
                        cell.measured_mean_s,
                        cell.overhead_mean_s,
                        cell.pram_makespan,
                        cell.utilization * 100.0
                    );
                }
                cells.push(cell);
            }
        }
        Ok(cells)
    }

    // -- Exec bench: resident vs scoped spawn overhead --------------------

    /// Run the same light (level-0-only) dispatch `steps` times through a
    /// resident pool and through a scoped (spawn-per-dispatch) pool, and
    /// report the mean per-step dispatch overhead and makespan of each.
    /// Results of the two modes are bit-identical (same LPT queue, same
    /// fixed-order reduction); only the executor's fixed cost differs.
    pub fn exec_overhead_compare(
        &self,
        workers: usize,
        steps: usize,
    ) -> Result<ExecOverheadComparison> {
        let cfg = &self.cfg;
        anyhow::ensure!(workers > 0, "need at least one worker");
        anyhow::ensure!(steps > 0, "need at least one measured step");
        let scenario = build_scenario_or_err(&cfg.scenario, &cfg.problem)?;
        let backend: Arc<NativeBackend> =
            Arc::new(NativeBackend::with_scenario(cfg.problem, scenario));
        let src = BrownianSource::new(0);
        let params = crate::engine::mlp::init_params(0);
        // The DMLMC steady-state light step: refresh level 0 only.
        let n_chunks = cfg
            .mlmc
            .n_effective
            .div_ceil(backend.grad_chunk(0))
            .max(1);
        let jobs = vec![LevelJobSpec { level: 0, n_chunks }];
        let measure = |pool: &mut WorkerPool| -> Result<(f64, f64)> {
            // warmup dispatch: first-touch costs (page faults, thread starts)
            run_jobs_pool_with_report(&backend, &src, 0, &params, &jobs, pool)?;
            let mut overhead = 0.0;
            let mut makespan = 0.0;
            for t in 1..=steps as u64 {
                let (_, report) = run_jobs_pool_with_report(
                    &backend, &src, t, &params, &jobs, pool,
                )?;
                overhead += report.dispatch_overhead().as_secs_f64();
                makespan += report.makespan.as_secs_f64();
            }
            Ok((overhead / steps as f64, makespan / steps as f64))
        };
        let mut resident = WorkerPool::new(workers);
        let (resident_overhead_mean_s, resident_makespan_mean_s) =
            measure(&mut resident)?;
        let mut scoped = WorkerPool::new_scoped(workers);
        let (scoped_overhead_mean_s, scoped_makespan_mean_s) =
            measure(&mut scoped)?;
        Ok(ExecOverheadComparison {
            workers,
            steps,
            resident_overhead_mean_s,
            scoped_overhead_mean_s,
            resident_makespan_mean_s,
            scoped_makespan_mean_s,
            resident_threads_spawned: resident.threads_spawned(),
            scoped_threads_spawned: scoped.threads_spawned(),
        })
    }

    // -- Hot-path bench: scalar vs lane-blocked kernels -------------------

    /// Benchmark the statically dispatched scalar kernel against its
    /// lane-blocked SIMD twin for each named scenario: one
    /// `value_and_grad` invocation over a `batch`-path Brownian batch is
    /// the timed unit, identical inputs for both kernels (same
    /// counter-addressed increments, so the comparison is pure kernel
    /// cost). Reports median paths/second per side and the speedup —
    /// the artifact behind `BENCH_hotpath.json`.
    pub fn hotpath_bench(
        &self,
        scenarios: &[String],
        batch: usize,
    ) -> Result<Vec<HotpathCell>> {
        anyhow::ensure!(!scenarios.is_empty(), "need at least one scenario");
        anyhow::ensure!(batch > 0, "batch must be positive");
        let cfg = &self.cfg;
        // A mid-depth grid: long enough that the per-step lane math (not
        // per-call setup) dominates, short enough to iterate quickly.
        let level = cfg.problem.lmax.min(2);
        let n_steps = cfg.problem.n_steps(level);
        let dt = cfg.problem.dt(level);
        let src = BrownianSource::new(0xB2);
        let params = crate::engine::mlp::init_params(0);
        // Short windows: ~35 scenarios x 2 kernels must stay benchable;
        // medians over many short iterations are stable enough here.
        let harness = crate::bench::Harness {
            warmup: Duration::from_millis(30),
            measure: Duration::from_millis(200),
            min_iters: 5,
            max_iters: 100_000,
        };
        let mut cells = Vec::new();
        for name in scenarios {
            let kernel = crate::scenarios::kernel_for(name).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown scenario `{name}` (repro scenarios lists the keys)"
                )
            })?;
            let dw = src.increments_multi(
                Purpose::Diagnostic,
                0,
                level as u32,
                0,
                batch,
                n_steps,
                dt,
                kernel.dim,
            );
            let side = |label: &str, f: fn(
                &[f32],
                &[f32],
                usize,
                usize,
                &crate::hedging::Problem,
            )
                -> (f64, Vec<f32>)|
             -> f64 {
                let s = harness.run(&format!("hotpath/{name}/{label}"), || {
                    crate::bench::black_box(f(
                        &params,
                        &dw,
                        batch,
                        n_steps,
                        &cfg.problem,
                    ));
                });
                batch as f64 / s.median.as_secs_f64().max(1e-12)
            };
            let scalar_paths_per_sec = side("scalar", kernel.scalar.value_and_grad);
            let lanes_paths_per_sec = side("lanes", kernel.lanes.value_and_grad);
            let cell = HotpathCell {
                scenario: name.clone(),
                batch,
                n_steps,
                scalar_paths_per_sec,
                lanes_paths_per_sec,
                speedup: lanes_paths_per_sec / scalar_paths_per_sec.max(1e-12),
            };
            if !self.quiet {
                eprintln!(
                    "hotpath_bench: {name:<22} scalar {:>12.0} p/s  lanes {:>12.0} p/s  \
                     x{:.2}",
                    cell.scalar_paths_per_sec, cell.lanes_paths_per_sec, cell.speedup
                );
            }
            cells.push(cell);
        }
        Ok(cells)
    }

    // -- Fleet sweep: serving throughput vs fleet size --------------------

    /// For every fleet size `F` x every worker count `P`: build a fresh
    /// [`FleetCoordinator`] over a `P`-worker pool, submit `F`
    /// independent DMLMC problems (round-robin over `scenarios`, seeds
    /// `0..F`, `steps` steps each, native backend), drain it, and record
    /// aggregate serving throughput. This is the serving-layer companion
    /// to [`parallel_sweep`](Self::parallel_sweep): the paper's freed
    /// per-iteration depth only pays off if another problem's chunks can
    /// fill the idle workers, and these cells measure exactly that.
    pub fn fleet_sweep(
        &self,
        fleet_sizes: &[usize],
        workers: &[usize],
        scenarios: &[String],
        steps: usize,
    ) -> Result<Vec<FleetCell>> {
        anyhow::ensure!(!fleet_sizes.is_empty(), "need at least one fleet size");
        anyhow::ensure!(!workers.is_empty(), "need at least one worker count");
        anyhow::ensure!(!scenarios.is_empty(), "need at least one scenario");
        anyhow::ensure!(steps > 0, "need at least one step per problem");
        let mut cells = Vec::new();
        for &f in fleet_sizes {
            anyhow::ensure!(f > 0, "fleet sizes must be positive (got {f})");
            for &p in workers {
                anyhow::ensure!(p > 0, "worker counts must be positive (got {p})");
                let mut fleet = FleetCoordinator::new(p);
                let t0 = Instant::now();
                let mut problems = Vec::with_capacity(f);
                for i in 0..f {
                    let name = &scenarios[i % scenarios.len()];
                    let mut c = self.cfg.clone();
                    // Fleet sessions need a shareable (native) backend even
                    // for the default scenario.
                    c.runtime.backend = Backend::Native;
                    fleet.submit(
                        &format!("{name}#{i}"),
                        TrainerBuilder::new(&c)
                            .method(Method::Dmlmc)
                            .seed(i as u64)
                            .scenario(name)
                            .steps(steps),
                    )?;
                    problems.push(name.clone());
                }
                let runs = fleet.drain()?;
                let wall_s = t0.elapsed().as_secs_f64().max(1e-12);
                let stats = fleet.exec_stats();
                let total_steps = runs.len() * steps;
                let cell = FleetCell {
                    fleet_size: f,
                    workers: p,
                    problems,
                    steps_per_problem: steps,
                    total_steps,
                    ticks: fleet.ticks(),
                    wall_s,
                    steps_per_sec: total_steps as f64 / wall_s,
                    problems_per_sec: f as f64 / wall_s,
                    utilization: stats.utilization(),
                    mean_step_makespan_s: stats.mean_makespan(),
                };
                if !self.quiet {
                    eprintln!(
                        "fleet_sweep: F={f} P={p}  {:.1} steps/s  util {:.0}%  \
                         ({} ticks)",
                        cell.steps_per_sec,
                        cell.utilization * 100.0,
                        cell.ticks
                    );
                }
                cells.push(cell);
            }
        }
        Ok(cells)
    }

    // -- Adaptive sweep: fixed vs telemetry-fed allocation ----------------

    /// The fixed-vs-adaptive allocation ablation (`BENCH_adaptive.json`):
    /// train the same DMLMC problem once under the frozen offline-theory
    /// policy and once under the adaptive policy (`[adaptive]` cadence
    /// from the runner's config), recording wall-clock at every eval
    /// point. The shared target loss is the worse of the two final
    /// losses, so both rows report a finite wall-clock-to-target and the
    /// column compares like for like.
    pub fn adaptive_sweep(&self) -> Result<Vec<AdaptiveCell>> {
        struct ModeRun {
            /// (elapsed seconds, held-out loss) at each eval point.
            evals: Vec<(f64, f64)>,
            mean_parallel_cost: f64,
            mean_step_makespan_s: f64,
            adaptations: u64,
        }
        let mut c = self.cfg.clone();
        c.runtime.backend = Backend::Native;
        let steps = c.train.steps;
        anyhow::ensure!(steps > 0, "need at least one training step");
        let run = |adaptive: bool| -> Result<ModeRun> {
            let mut tr = TrainerBuilder::new(&c)
                .method(Method::Dmlmc)
                .seed(0)
                .adaptive(adaptive)
                .build()?;
            let t0 = Instant::now();
            let mut evals = Vec::new();
            for t in 0..steps as u64 {
                tr.step(t)?;
                let next = t + 1;
                if next % c.train.eval_every as u64 == 0 || next == steps as u64
                {
                    evals.push((t0.elapsed().as_secs_f64(), tr.eval_loss()?));
                }
            }
            Ok(ModeRun {
                evals,
                mean_parallel_cost: tr.cumulative_cost().depth / steps as f64,
                mean_step_makespan_s: tr
                    .exec_stats()
                    .expect("native backend always pools")
                    .mean_makespan(),
                adaptations: tr.adaptations(),
            })
        };
        let fixed = run(false)?;
        let adaptive = run(true)?;
        let final_of =
            |m: &ModeRun| m.evals.last().map(|e| e.1).unwrap_or(f64::NAN);
        let target_loss = final_of(&fixed).max(final_of(&adaptive));
        let cell = |mode: &str, m: &ModeRun| AdaptiveCell {
            mode: mode.to_string(),
            steps,
            final_loss: final_of(m),
            target_loss,
            wall_clock_to_target_s: m
                .evals
                .iter()
                .find(|e| e.1 <= target_loss)
                .map(|e| e.0)
                .unwrap_or(f64::NAN),
            mean_parallel_cost: m.mean_parallel_cost,
            mean_step_makespan_s: m.mean_step_makespan_s,
            adaptations: m.adaptations,
        };
        let cells = vec![cell("fixed", &fixed), cell("adaptive", &adaptive)];
        if !self.quiet {
            for r in &cells {
                eprintln!(
                    "adaptive_sweep: {:<8} loss {:.4}  to-target {:.4} s  \
                     par/step {:.1}  ({} adaptations)",
                    r.mode,
                    r.final_loss,
                    r.wall_clock_to_target_s,
                    r.mean_parallel_cost,
                    r.adaptations
                );
            }
        }
        Ok(cells)
    }

    // -- Trace bench: traced-vs-untraced overhead + trace export ----------

    /// Run the same DMLMC training `repeats` times with tracing off and
    /// on. Per repeat, assert — bitwise — that tracing never changed the
    /// trained parameters; across repeats, compare the best mean per-step
    /// makespans and assert the traced one stays within
    /// `2x untraced + 2 ms` (see [`TRACE_OVERHEAD_FACTOR`] /
    /// [`TRACE_OVERHEAD_FLOOR_S`] — the recorder only runs
    /// coordinator-side, so anything worse means it leaked onto the
    /// worker hot path). The last traced run's trace is exported through
    /// [`TraceSink`] into the `trace` run directory.
    pub fn trace_bench(&self, workers: usize, repeats: usize) -> Result<TraceBench> {
        anyhow::ensure!(workers > 0, "need at least one worker");
        anyhow::ensure!(repeats > 0, "need at least one repeat");
        let mut c = self.cfg.clone();
        c.runtime.backend = Backend::Native;
        c.execution.workers = workers;
        let steps = c.train.steps;
        let run = |trace: bool| -> Result<(f64, Vec<f32>, Trainer)> {
            let mut tr = TrainerBuilder::new(&c)
                .method(Method::Dmlmc)
                .seed(0)
                .trace(trace)
                .build()?;
            tr.run()?;
            let mean = tr
                .exec_stats()
                .expect("native backend always pools")
                .mean_makespan();
            let params = tr.params.clone();
            Ok((mean, params, tr))
        };
        // Scrape-under-load: the same traced run with an ephemeral
        // MetricsServer attached to the live registry and a poller
        // thread fetching /metrics for the whole run.
        let run_scraped = || -> Result<(f64, Vec<f32>, usize)> {
            use std::sync::atomic::{AtomicBool, Ordering};
            let mut tr = TrainerBuilder::new(&c)
                .method(Method::Dmlmc)
                .seed(0)
                .trace(true)
                .build()?;
            let registry = tr
                .recorder()
                .expect("traced trainer has a recorder")
                .shared_metrics();
            let mut server = MetricsServer::start(
                Arc::new(ServeState::new(registry)),
                0,
            )?;
            let addr = server.addr();
            let stop = Arc::new(AtomicBool::new(false));
            let stop_poll = stop.clone();
            let poller = std::thread::spawn(move || -> usize {
                let mut n = 0;
                while !stop_poll.load(Ordering::SeqCst) {
                    if scrape_metrics(addr).is_some() {
                        n += 1;
                    }
                    std::thread::sleep(Duration::from_micros(200));
                }
                n
            });
            tr.run()?;
            let mean = tr
                .exec_stats()
                .expect("native backend always pools")
                .mean_makespan();
            // A guaranteed post-run scrape: the estimator gauges the
            // traced steps published must be live on the HTTP surface.
            let text = scrape_metrics(addr)
                .ok_or_else(|| anyhow::anyhow!("post-run /metrics scrape failed"))?;
            anyhow::ensure!(
                text.contains("dmlmc_level_variance")
                    && text.contains("obs_spans_dropped_total"),
                "live scrape is missing estimator/drop gauge families"
            );
            stop.store(true, Ordering::SeqCst);
            let scrapes = 1 + poller.join().unwrap_or(0);
            server.shutdown();
            Ok((mean, tr.params.clone(), scrapes))
        };
        let mut untraced_best = f64::INFINITY;
        let mut traced_best = f64::INFINITY;
        let mut scraped_best = f64::INFINITY;
        let mut scrapes_total = 0;
        let mut last = None;
        for _ in 0..repeats {
            let (plain_mean, plain_params, _) = run(false)?;
            let (traced_mean, traced_params, tr) = run(true)?;
            anyhow::ensure!(
                plain_params == traced_params,
                "tracing changed the trained parameters"
            );
            let (scraped_mean, scraped_params, scrapes) = run_scraped()?;
            anyhow::ensure!(
                plain_params == scraped_params,
                "concurrent scraping changed the trained parameters"
            );
            untraced_best = untraced_best.min(plain_mean);
            traced_best = traced_best.min(traced_mean);
            scraped_best = scraped_best.min(scraped_mean);
            scrapes_total += scrapes;
            last = Some(tr);
            if !self.quiet {
                eprintln!(
                    "trace: untraced {plain_mean:.6} s/step  traced \
                     {traced_mean:.6} s/step  scraped {scraped_mean:.6} \
                     s/step ({scrapes} fetches)"
                );
            }
        }
        let mut tr = last.expect("repeats >= 1");
        // Every worker track must carry at least one task span before
        // the export claims per-worker coverage; top up with extra steps
        // if the LPT queue starved a worker over the measured horizon
        // (the params comparison already happened above, so these steps
        // only fatten the trace).
        let mut t = steps as u64;
        while tr
            .recorder()
            .is_some_and(|r| r.worker_span_counts().iter().any(|&n| n == 0))
            && t < steps as u64 + 64
        {
            tr.step(t)?;
            t += 1;
        }
        let rec = tr.take_recorder().expect("traced trainer has a recorder");
        let arts = self.artifacts("trace")?;
        let (trace_path, metrics_path) = TraceSink::new(&arts)
            .write(&rec)
            .map_err(|e| anyhow::anyhow!("write trace artifacts: {e}"))?;
        let overhead_ratio = traced_best / untraced_best.max(1e-12);
        anyhow::ensure!(
            traced_best
                <= untraced_best * TRACE_OVERHEAD_FACTOR + TRACE_OVERHEAD_FLOOR_S,
            "tracing overhead out of bounds: traced {traced_best:.6} s/step vs \
             untraced {untraced_best:.6} s/step (bound: {TRACE_OVERHEAD_FACTOR}x \
             + {TRACE_OVERHEAD_FLOOR_S}s)"
        );
        let scrape_overhead_ratio = scraped_best / untraced_best.max(1e-12);
        anyhow::ensure!(
            scraped_best
                <= untraced_best * SCRAPE_OVERHEAD_FACTOR + SCRAPE_OVERHEAD_FLOOR_S,
            "scrape-under-load overhead out of bounds: scraped \
             {scraped_best:.6} s/step vs untraced {untraced_best:.6} s/step \
             (bound: {SCRAPE_OVERHEAD_FACTOR}x + {SCRAPE_OVERHEAD_FLOOR_S}s)"
        );
        Ok(TraceBench {
            workers,
            steps,
            repeats,
            untraced_mean_makespan_s: untraced_best,
            traced_mean_makespan_s: traced_best,
            overhead_ratio,
            scraped_mean_makespan_s: scraped_best,
            scrape_overhead_ratio,
            scrapes_total,
            spans_per_worker: rec.worker_span_counts(),
            coordinator_spans: rec.coordinator_spans().len(),
            dropped_spans: rec.dropped_total(),
            trace_path,
            metrics_path,
        })
    }

    // -- Renderers (all wall-clock columns in SECONDS) --------------------

    /// Render the combined Table 1 as text (CLI + EXPERIMENTS.md).
    pub fn render_table1(theory: &[TheoryRow], measured: &[MeasuredRow]) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<28} {:>14} {:>14} {:>14} {:>14} {:>12}\n",
            "method", "theory work", "meas. work", "theory depth", "meas. depth",
            "final loss"
        ));
        for (t, m) in theory.iter().zip(measured) {
            out.push_str(&format!(
                "{:<28} {:>14.3e} {:>14.3e} {:>14.3e} {:>14.3e} {:>12.4}\n",
                t.method.name(),
                t.complexity,
                m.std_cost,
                t.parallel,
                m.par_cost,
                m.final_loss
            ));
        }
        out
    }

    /// Render the scenario sweep as text (CLI +
    /// `examples/scenario_sweep.rs`).
    pub fn render_scenario_table(rows: &[ScenarioRow]) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<16} {:>8} {:>8} {:>14} {:>14} {:>10} {:>12}\n",
            "scenario", "b_hat", "A2 ok", "mlmc par", "dmlmc par", "ratio",
            "final loss"
        ));
        for r in rows {
            out.push_str(&format!(
                "{:<16} {:>8.2} {:>8} {:>14.0} {:>14.0} {:>10.2} {:>12.4}\n",
                r.name,
                r.b_hat,
                if r.assumption_ok { "yes" } else { "NO" },
                r.mlmc_par,
                r.dmlmc_par,
                r.par_ratio,
                r.final_loss
            ));
        }
        out
    }

    /// Render the parallel sweep as text. Wall-clock columns are seconds
    /// (same unit as the `ParallelCell` fields — pinned by a golden
    /// test). Speedups are relative to the same method's cell at the
    /// smallest swept worker count, for measured and predicted makespans
    /// alike — the unit-free comparison between the pool and the PRAM
    /// model.
    pub fn render_parallel_table(cells: &[ParallelCell]) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<8} {:>4} {:>14} {:>10} {:>10} {:>12} {:>10} {:>8} {:>12}\n",
            "method", "P", "meas s/step", "meas spdup", "ovh s", "pram pred",
            "pram spdup", "util", "final loss"
        ));
        let baseline = |m: Method| {
            cells
                .iter()
                .filter(|c| c.method == m)
                .min_by_key(|c| c.workers)
        };
        for c in cells {
            let (ms, ps) = baseline(c.method)
                .map(|b| {
                    (
                        b.measured_mean_s / c.measured_mean_s.max(1e-12),
                        b.pram_makespan / c.pram_makespan.max(1e-12),
                    )
                })
                .unwrap_or((f64::NAN, f64::NAN));
            out.push_str(&format!(
                "{:<8} {:>4} {:>14.6} {:>10.2} {:>10.6} {:>12.0} {:>10.2} \
                 {:>7.0}% {:>12.4}\n",
                c.method.name(),
                c.workers,
                c.measured_mean_s,
                ms,
                c.overhead_mean_s,
                c.pram_makespan,
                ps,
                c.utilization * 100.0,
                c.final_loss
            ));
        }
        out
    }

    /// Render the resident-vs-scoped comparison as text (CLI
    /// `repro exec-bench`). Wall-clock columns are seconds (pinned by a
    /// golden test).
    pub fn render_exec_comparison(cmp: &ExecOverheadComparison) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "exec overhead, light (level-0-only) dispatch, P = {}, {} steps:\n",
            cmp.workers, cmp.steps
        ));
        out.push_str(&format!(
            "{:<10} {:>14} {:>14} {:>16}\n",
            "mode", "ovh s/step", "mksp s/step", "threads spawned"
        ));
        out.push_str(&format!(
            "{:<10} {:>14.6} {:>14.6} {:>16}\n",
            "resident",
            cmp.resident_overhead_mean_s,
            cmp.resident_makespan_mean_s,
            cmp.resident_threads_spawned
        ));
        out.push_str(&format!(
            "{:<10} {:>14.6} {:>14.6} {:>16}\n",
            "scoped",
            cmp.scoped_overhead_mean_s,
            cmp.scoped_makespan_mean_s,
            cmp.scoped_threads_spawned
        ));
        let ratio = if cmp.resident_overhead_mean_s > 0.0 {
            cmp.scoped_overhead_mean_s / cmp.resident_overhead_mean_s
        } else {
            f64::INFINITY
        };
        out.push_str(&format!(
            "scoped / resident overhead ratio: {ratio:.2}x\n"
        ));
        out
    }

    /// Render the trace bench as text (CLI `repro trace`). Wall-clock
    /// columns are seconds.
    pub fn render_trace_bench(b: &TraceBench) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "trace bench, P = {}, {} steps x {} repeats:\n",
            b.workers, b.steps, b.repeats
        ));
        out.push_str(&format!(
            "{:<10} {:>16}\n",
            "mode", "mksp s/step"
        ));
        out.push_str(&format!(
            "{:<10} {:>16.6}\n",
            "untraced", b.untraced_mean_makespan_s
        ));
        out.push_str(&format!(
            "{:<10} {:>16.6}\n",
            "traced", b.traced_mean_makespan_s
        ));
        out.push_str(&format!(
            "{:<10} {:>16.6}\n",
            "scraped", b.scraped_mean_makespan_s
        ));
        out.push_str(&format!(
            "traced / untraced overhead ratio: {:.2}x\n",
            b.overhead_ratio
        ));
        out.push_str(&format!(
            "scraped / untraced overhead ratio: {:.2}x ({} /metrics fetches)\n",
            b.scrape_overhead_ratio, b.scrapes_total
        ));
        out.push_str(&format!(
            "spans: coordinator {}, per worker {:?}, dropped {}\n",
            b.coordinator_spans, b.spans_per_worker, b.dropped_spans
        ));
        out.push_str(&format!(
            "trace:   {}\nmetrics: {}\n",
            b.trace_path.display(),
            b.metrics_path.display()
        ));
        out
    }

    /// Render the hot-path bench as text (CLI `repro hotpath-bench`).
    /// Throughput columns are paths/second.
    pub fn render_hotpath_table(cells: &[HotpathCell]) -> String {
        let mut out = String::new();
        out.push_str(
            "hot path: scalar vs lane-blocked kernels (value_and_grad)\n",
        );
        out.push_str(&format!(
            "{:<22} {:>6} {:>6} {:>14} {:>14} {:>8}\n",
            "scenario", "batch", "steps", "scalar p/s", "lanes p/s", "speedup"
        ));
        for c in cells {
            out.push_str(&format!(
                "{:<22} {:>6} {:>6} {:>14.0} {:>14.0} {:>7.2}x\n",
                c.scenario,
                c.batch,
                c.n_steps,
                c.scalar_paths_per_sec,
                c.lanes_paths_per_sec,
                c.speedup
            ));
        }
        out
    }

    /// Render the fixed-vs-adaptive ablation as text (CLI
    /// `repro adaptive-sweep`). Wall-clock columns are seconds.
    pub fn render_adaptive_table(cells: &[AdaptiveCell]) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<10} {:>6} {:>12} {:>12} {:>14} {:>14} {:>12} {:>8}\n",
            "mode", "steps", "final loss", "target", "to-target s",
            "par cost/step", "mksp s/step", "adapts"
        ));
        for c in cells {
            out.push_str(&format!(
                "{:<10} {:>6} {:>12.4} {:>12.4} {:>14.6} {:>14.2} {:>12.6} \
                 {:>8}\n",
                c.mode,
                c.steps,
                c.final_loss,
                c.target_loss,
                c.wall_clock_to_target_s,
                c.mean_parallel_cost,
                c.mean_step_makespan_s,
                c.adaptations
            ));
        }
        out
    }

    /// Render the fleet sweep as text (CLI `repro fleet-sweep`).
    pub fn render_fleet_table(cells: &[FleetCell]) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<6} {:>4} {:>8} {:>12} {:>12} {:>14} {:>8} {:>8}\n",
            "fleet", "P", "steps", "steps/s", "problems/s", "mksp s/step",
            "util", "ticks"
        ));
        for c in cells {
            out.push_str(&format!(
                "{:<6} {:>4} {:>8} {:>12.1} {:>12.2} {:>14.6} {:>7.0}% {:>8}\n",
                c.fleet_size,
                c.workers,
                c.total_steps,
                c.steps_per_sec,
                c.problems_per_sec,
                c.mean_step_makespan_s,
                c.utilization * 100.0,
                c.ticks
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::smoke();
        cfg.train.steps = 8;
        cfg.train.eval_every = 8;
        cfg.train.n_seeds = 2;
        cfg.mlmc.n_effective = 32;
        cfg
    }

    fn runner() -> ExperimentRunner {
        ExperimentRunner::new(&cfg()).quiet(true)
    }

    #[test]
    fn figure2_produces_all_methods() {
        let out = runner().figure2().unwrap();
        assert_eq!(out.len(), 3);
        for (_, curves, agg) in &out {
            assert_eq!(curves.len(), 2);
            assert_eq!(agg.n_runs, 2);
            assert!(!agg.steps.is_empty());
        }
        // DMLMC total parallel cost strictly below MLMC's.
        let par = |m: Method| {
            out.iter()
                .find(|(mm, _, _)| *mm == m)
                .unwrap()
                .2
                .par_cost
                .last()
                .copied()
                .unwrap()
        };
        assert!(par(Method::Dmlmc) < par(Method::Mlmc));
    }

    #[test]
    fn table1_measured_matches_theory_shape() {
        let mut c = cfg();
        c.train.steps = 16;
        let (theory, measured) =
            ExperimentRunner::new(&c).quiet(true).table1().unwrap();
        assert_eq!(theory.len(), 3);
        assert_eq!(measured.len(), 3);
        // naive work >> mlmc work; mlmc depth > dmlmc depth.
        assert!(measured[0].std_cost > measured[1].std_cost);
        assert!(measured[1].par_cost > measured[2].par_cost);
        let txt = ExperimentRunner::render_table1(&theory, &measured);
        assert!(txt.contains("Naive"));
        assert!(txt.lines().count() >= 4);
    }

    #[test]
    fn predicted_avg_depth_matches_geom_sum_scale() {
        let c = cfg();
        let pred = ExperimentRunner::new(&c)
            .quiet(true)
            .predicted_avg_depth(1 << 12);
        // With c = d = 1 the exact average of max-due-level costs is
        // sum over l of 2^l * P(max due level = l) — bounded by lmax+1
        // and far below 2^lmax.
        assert!(pred > 1.0);
        assert!(pred < 2f64.powi(c.problem.lmax as i32));
    }

    #[test]
    fn scenario_sweep_covers_names_and_shows_parallel_advantage() {
        let mut c = cfg();
        c.train.steps = 6;
        c.train.eval_every = 6;
        c.mlmc.n_effective = 32;
        c.train.dmlmc_warmup = 0;
        // spans D = 1 and D = 2 dynamics plus a barrier payoff — the
        // acceptance surface of the multi-factor/streaming refactor
        let names: Vec<String> = ["bs-call", "ou-asian", "heston-call", "gbm-uo-call"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let rows = ExperimentRunner::new(&c)
            .quiet(true)
            .scenario_sweep(&names)
            .unwrap();
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.b_hat.is_finite(), "{}: b_hat {}", r.name, r.b_hat);
            assert!(
                r.dmlmc_par < r.mlmc_par,
                "{}: dmlmc par {} !< mlmc par {}",
                r.name,
                r.dmlmc_par,
                r.mlmc_par
            );
            assert!(r.final_loss.is_finite());
        }
        // smooth default scenario must show clear variance decay
        assert!(rows[0].b_hat > 0.5, "bs-call b_hat {}", rows[0].b_hat);
        let txt = ExperimentRunner::render_scenario_table(&rows);
        assert!(txt.contains("ou-asian"));
        assert!(txt.lines().count() >= 4);
    }

    #[test]
    fn scenario_sweep_rejects_unknown_names() {
        let names = vec!["nope-call".to_string()];
        assert!(runner().scenario_sweep(&names).is_err());
    }

    #[test]
    fn parallel_sweep_produces_all_cells_with_model_and_measurement() {
        let mut c = cfg();
        c.train.steps = 6;
        c.train.eval_every = 6;
        c.train.dmlmc_warmup = 0;
        let cells = ExperimentRunner::new(&c)
            .quiet(true)
            .parallel_sweep(&[1, 2])
            .unwrap();
        assert_eq!(cells.len(), 6); // 2 worker counts x 3 methods
        for cell in &cells {
            assert!(cell.measured_mean_s >= 0.0);
            assert!(cell.measured_total_s.is_finite());
            assert!(cell.overhead_mean_s >= 0.0);
            assert!(cell.overhead_mean_s <= cell.measured_mean_s + 1e-12);
            assert!(cell.final_loss.is_finite(), "{}", cell.method);
            assert!((0.0..=1.0).contains(&cell.utilization));
            // LPT makespan can never beat Brent's lower bound
            assert!(
                cell.pram_makespan >= cell.brent_bound - 1e-9,
                "{} P={}: pram {} < brent {}",
                cell.method,
                cell.workers,
                cell.pram_makespan,
                cell.brent_bound
            );
        }
        // The paper's claim at the model level, per cell: DMLMC's
        // predicted per-step makespan is below standard MLMC's.
        let pram = |m: Method, p: usize| {
            cells
                .iter()
                .find(|c| c.method == m && c.workers == p)
                .unwrap()
                .pram_makespan
        };
        for p in [1usize, 2] {
            assert!(
                pram(Method::Dmlmc, p) < pram(Method::Mlmc, p),
                "P={p}: dmlmc pram {} !< mlmc pram {}",
                pram(Method::Dmlmc, p),
                pram(Method::Mlmc, p)
            );
        }
        let txt = ExperimentRunner::render_parallel_table(&cells);
        assert!(txt.contains("dmlmc"));
        assert!(txt.contains("ovh s"));
        assert!(txt.lines().count() >= 7);
    }

    #[test]
    fn parallel_sweep_rejects_bad_worker_lists() {
        assert!(runner().parallel_sweep(&[]).is_err());
        assert!(runner().parallel_sweep(&[0]).is_err());
    }

    #[test]
    fn render_parallel_table_golden_seconds() {
        // Pins the seconds-everywhere contract: values land in the table
        // exactly as the ParallelCell fields (no unit rescaling).
        let cells = vec![
            ParallelCell {
                method: Method::Mlmc,
                workers: 1,
                steps: 8,
                measured_mean_s: 0.002,
                measured_total_s: 0.016,
                utilization: 1.0,
                overhead_mean_s: 0.0005,
                pram_makespan: 128.0,
                brent_bound: 100.0,
                final_loss: 0.5,
            },
            ParallelCell {
                method: Method::Mlmc,
                workers: 2,
                steps: 8,
                measured_mean_s: 0.001,
                measured_total_s: 0.008,
                utilization: 0.75,
                overhead_mean_s: 0.00025,
                pram_makespan: 64.0,
                brent_bound: 50.0,
                final_loss: 0.25,
            },
        ];
        let expected = "\
method      P    meas s/step meas spdup      ovh s    pram pred pram spdup     util   final loss
mlmc        1       0.002000       1.00   0.000500          128       1.00     100%       0.5000
mlmc        2       0.001000       2.00   0.000250           64       2.00      75%       0.2500
";
        assert_eq!(ExperimentRunner::render_parallel_table(&cells), expected);
    }

    #[test]
    fn exec_comparison_renders_both_modes() {
        let cmp = ExecOverheadComparison {
            workers: 4,
            steps: 16,
            resident_overhead_mean_s: 10e-6,
            scoped_overhead_mean_s: 60e-6,
            resident_makespan_mean_s: 1e-3,
            scoped_makespan_mean_s: 1.05e-3,
            resident_threads_spawned: 4,
            scoped_threads_spawned: 68,
        };
        let txt = ExperimentRunner::render_exec_comparison(&cmp);
        assert!(txt.contains("resident"));
        assert!(txt.contains("scoped"));
        assert!(txt.contains("6.00x"), "{txt}");
    }

    #[test]
    fn render_exec_comparison_golden_seconds() {
        let cmp = ExecOverheadComparison {
            workers: 4,
            steps: 16,
            resident_overhead_mean_s: 10e-6,
            scoped_overhead_mean_s: 60e-6,
            resident_makespan_mean_s: 1e-3,
            scoped_makespan_mean_s: 1.05e-3,
            resident_threads_spawned: 4,
            scoped_threads_spawned: 68,
        };
        let expected = "\
exec overhead, light (level-0-only) dispatch, P = 4, 16 steps:
mode           ovh s/step    mksp s/step  threads spawned
resident         0.000010       0.001000                4
scoped           0.000060       0.001050               68
scoped / resident overhead ratio: 6.00x
";
        assert_eq!(ExperimentRunner::render_exec_comparison(&cmp), expected);
    }

    #[test]
    fn exec_overhead_compare_rejects_degenerate_inputs() {
        assert!(runner().exec_overhead_compare(0, 4).is_err());
        assert!(runner().exec_overhead_compare(2, 0).is_err());
    }

    #[test]
    fn sweep_delay_monotone_depth() {
        let rows = runner().sweep_delay(&[0.5, 1.0, 2.0]).unwrap();
        assert_eq!(rows.len(), 3);
        // larger d => fewer refreshes => lower parallel cost.
        assert!(rows[0].1.par_cost >= rows[1].1.par_cost);
        assert!(rows[1].1.par_cost >= rows[2].1.par_cost);
    }

    #[test]
    fn fleet_sweep_reports_throughput_cells() {
        let mut c = cfg();
        c.train.eval_every = 4;
        let scenarios: Vec<String> = ["bs-call", "heston-uo-call"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let cells = ExperimentRunner::new(&c)
            .quiet(true)
            .fleet_sweep(&[1, 2], &[2], &scenarios, 4)
            .unwrap();
        assert_eq!(cells.len(), 2);
        for cell in &cells {
            assert_eq!(cell.total_steps, cell.fleet_size * 4);
            assert_eq!(cell.problems.len(), cell.fleet_size);
            assert!(cell.wall_s > 0.0);
            assert!(cell.steps_per_sec > 0.0);
            assert!(cell.problems_per_sec > 0.0);
            assert!((0.0..=1.0).contains(&cell.utilization));
            assert!(cell.mean_step_makespan_s >= 0.0);
            // one multiplexed dispatch per step when all sessions share
            // the same horizon
            assert_eq!(cell.ticks, 4);
        }
        // round-robin scenario assignment
        assert_eq!(cells[1].problems, scenarios);
        let txt = ExperimentRunner::render_fleet_table(&cells);
        assert!(txt.contains("steps/s"));
        assert!(txt.contains("mksp s/step"));
        assert!(txt.lines().count() >= 3);
    }

    #[test]
    fn fleet_sweep_rejects_degenerate_inputs() {
        let sc = vec!["bs-call".to_string()];
        let r = runner();
        assert!(r.fleet_sweep(&[], &[1], &sc, 4).is_err());
        assert!(r.fleet_sweep(&[1], &[], &sc, 4).is_err());
        assert!(r.fleet_sweep(&[0], &[1], &sc, 4).is_err());
        assert!(r.fleet_sweep(&[1], &[0], &sc, 4).is_err());
        assert!(r.fleet_sweep(&[1], &[1], &[], 4).is_err());
        assert!(r.fleet_sweep(&[1], &[1], &sc, 0).is_err());
    }

    #[test]
    fn adaptive_sweep_compares_both_modes_against_one_target() {
        let mut c = cfg();
        c.train.steps = 12;
        c.train.eval_every = 4;
        c.adaptive.adapt_every = 4;
        let rows = ExperimentRunner::new(&c)
            .quiet(true)
            .adaptive_sweep()
            .unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].mode, "fixed");
        assert_eq!(rows[1].mode, "adaptive");
        assert_eq!(rows[0].target_loss, rows[1].target_loss);
        assert_eq!(rows[0].adaptations, 0, "fixed never adapts");
        for r in &rows {
            assert_eq!(r.steps, 12);
            assert!(r.final_loss.is_finite(), "{}", r.mode);
            // the target is the worse final loss, so BOTH modes reach it
            assert!(
                r.wall_clock_to_target_s.is_finite(),
                "{}: never reached the shared target",
                r.mode
            );
            assert!(r.mean_parallel_cost > 0.0);
            assert!(r.mean_step_makespan_s >= 0.0);
        }
        let txt = ExperimentRunner::render_adaptive_table(&rows);
        assert!(txt.contains("fixed"));
        assert!(txt.contains("adaptive"));
        assert!(txt.contains("to-target s"));
        assert!(txt.lines().count() >= 3);
    }

    #[test]
    fn trace_bench_exports_a_parseable_trace_with_full_coverage() {
        use crate::util::json::Json;
        let tmp = std::env::temp_dir()
            .join(format!("dmlmc_trace_bench_{}", std::process::id()));
        let mut c = cfg();
        c.train.steps = 6;
        c.train.eval_every = 6;
        let b = ExperimentRunner::new(&c)
            .quiet(true)
            .out_dir(&tmp)
            .trace_bench(2, 1)
            .unwrap();
        assert_eq!(b.workers, 2);
        assert_eq!(b.steps, 6);
        assert!(b.untraced_mean_makespan_s >= 0.0);
        assert!(b.traced_mean_makespan_s >= 0.0);
        assert!(b.overhead_ratio.is_finite());
        // scrape-under-load row: at least the guaranteed post-run fetch
        // per repeat, finite bounded overhead
        assert!(b.scraped_mean_makespan_s >= 0.0);
        assert!(b.scrape_overhead_ratio.is_finite());
        assert!(b.scrapes_total >= 1, "{}", b.scrapes_total);
        // >= 1 span per worker track (the top-up loop guarantees it)
        assert_eq!(b.spans_per_worker.len(), 2);
        assert!(b.spans_per_worker.iter().all(|&n| n > 0), "{:?}", b.spans_per_worker);
        // 6 steps x (step + dispatch) at minimum
        assert!(b.coordinator_spans >= 12);
        assert_eq!(b.dropped_spans, 0);
        // the exported trace round-trips through the strict parser
        let text = std::fs::read_to_string(&b.trace_path).unwrap();
        let doc = Json::parse(text.trim()).unwrap();
        assert!(doc.get("traceEvents").unwrap().as_arr().unwrap().len() > 12);
        let prom = std::fs::read_to_string(&b.metrics_path).unwrap();
        assert!(prom.contains("dmlmc_steps_total"));
        let txt = ExperimentRunner::render_trace_bench(&b);
        assert!(txt.contains("untraced"));
        assert!(txt.contains("overhead ratio"));
        assert!(txt.contains("scraped"));
        assert!(txt.contains("/metrics fetches"));
        let _ = std::fs::remove_dir_all(&tmp);
    }

    #[test]
    fn trace_bench_rejects_degenerate_inputs() {
        assert!(runner().trace_bench(0, 1).is_err());
        assert!(runner().trace_bench(2, 0).is_err());
    }

    #[test]
    fn runner_hands_out_run_scoped_artifacts() {
        let tmp = std::env::temp_dir()
            .join(format!("dmlmc_runner_{}", std::process::id()));
        let r = ExperimentRunner::new(&cfg()).quiet(true).out_dir(&tmp);
        let arts = r.artifacts("unit").unwrap();
        assert_eq!(arts.dir(), tmp.join("unit"));
        assert_eq!(arts.run(), "unit");
        let _ = std::fs::remove_dir_all(&tmp);
    }

    #[test]
    fn hotpath_bench_produces_speedup_cells_and_rejects_junk() {
        let r = runner();
        let names = vec!["bs-call".to_string(), "heston-uo-call".to_string()];
        let cells = r.hotpath_bench(&names, 64).unwrap();
        assert_eq!(cells.len(), 2);
        for (c, name) in cells.iter().zip(&names) {
            assert_eq!(&c.scenario, name);
            assert_eq!(c.batch, 64);
            assert!(c.n_steps > 0);
            assert!(c.scalar_paths_per_sec > 0.0, "{name}");
            assert!(c.lanes_paths_per_sec > 0.0, "{name}");
            assert!(c.speedup.is_finite() && c.speedup > 0.0, "{name}");
            let ratio = c.lanes_paths_per_sec / c.scalar_paths_per_sec;
            assert!((c.speedup - ratio).abs() < 1e-9 * ratio.max(1.0));
        }
        let table = ExperimentRunner::render_hotpath_table(&cells);
        assert!(table.contains("heston-uo-call"));
        assert!(table.contains("speedup"));
        assert!(table.contains('x'));
        // degenerate inputs rejected
        assert!(r.hotpath_bench(&[], 64).is_err());
        assert!(r.hotpath_bench(&names, 0).is_err());
        assert!(r
            .hotpath_bench(&["sabr-call".to_string()], 64)
            .is_err());
    }

    #[test]
    fn no_top_level_pub_fn_bypasses_the_runner() {
        // The deny-list contract (also enforced by a CI grep): every
        // experiment entry point lives on ExperimentRunner, so this
        // module's top level exports types only.
        let src = include_str!("experiments.rs");
        let offenders: Vec<&str> =
            src.lines().filter(|l| l.starts_with("pub fn ")).collect();
        assert!(
            offenders.is_empty(),
            "top-level pub fns bypass ExperimentRunner: {offenders:?}"
        );
    }
}
