//! # dmlmc — Delayed Multilevel Monte Carlo for SGD
//!
//! A production-oriented reproduction of *“On the Parallel Complexity of
//! Multilevel Monte Carlo in Stochastic Gradient Descent”* (Kei Ishikawa,
//! 2023) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L1 (Pallas, build time)** — fused Milstein path kernel and hedging
//!   MLP forward/backward kernels (`python/compile/kernels/`), lowered
//!   with `interpret=True` so the CPU PJRT runtime executes plain HLO.
//! * **L2 (JAX, build time)** — the deep-hedging objective and its
//!   per-level coupled gradients, AOT-lowered to HLO *text* artifacts by
//!   `python/compile/aot.py` (`make artifacts`).
//! * **L3 (rust, run time — this crate)** — the paper's contribution:
//!   the delayed-MLMC SGD coordinator ([`coordinator`]), which refreshes
//!   the level-ℓ gradient component only every `⌊2^{dℓ}⌋` steps and reuses
//!   the cached component otherwise (Algorithm 1), plus every substrate it
//!   needs: the PJRT runtime ([`runtime`]), a pure-rust verification
//!   engine ([`engine`]), MLMC allocation/diagnostics ([`mlmc`]),
//!   counter-based RNG ([`rng`]), optimizers ([`optim`]), the PRAM cost
//!   model ([`parallel`]), metrics ([`metrics`]) and configuration
//!   ([`config`]).
//!
//! Python never runs on the training hot path: after `make artifacts` the
//! `repro` binary (and all examples/benches) are self-contained.
//!
//! ## Quickstart
//!
//! ```no_run
//! use dmlmc::config::ExperimentConfig;
//! use dmlmc::coordinator::{Method, Trainer};
//!
//! let cfg = ExperimentConfig::default_paper();
//! let mut trainer = Trainer::from_config(&cfg, Method::Dmlmc, 0).unwrap();
//! let curve = trainer.run().unwrap();
//! println!("final loss {:.4}", curve.points.last().unwrap().loss);
//! ```

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod experiments;
pub mod hedging;
pub mod metrics;
pub mod mlmc;
pub mod optim;
pub mod parallel;
pub mod rng;
pub mod runtime;
pub mod testkit;
pub mod util;

pub use config::ExperimentConfig;
pub use coordinator::{Method, Trainer};
