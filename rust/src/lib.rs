//! # dmlmc — Delayed Multilevel Monte Carlo for SGD
//!
//! A production-oriented reproduction of *“On the Parallel Complexity of
//! Multilevel Monte Carlo in Stochastic Gradient Descent”* (Kei Ishikawa,
//! 2023) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L1 (Pallas, build time)** — fused Milstein path kernel and hedging
//!   MLP forward/backward kernels (`python/compile/kernels/`), lowered
//!   with `interpret=True` so the CPU PJRT runtime executes plain HLO.
//! * **L2 (JAX, build time)** — the deep-hedging objective and its
//!   per-level coupled gradients, AOT-lowered to HLO *text* artifacts by
//!   `python/compile/aot.py` (`make artifacts`).
//! * **L3 (rust, run time — this crate)** — the paper's contribution:
//!   the delayed-MLMC SGD coordinator ([`coordinator`]), which refreshes
//!   the level-ℓ gradient component only every `⌊2^{dℓ}⌋` steps and reuses
//!   the cached component otherwise (Algorithm 1), plus every substrate it
//!   needs: the PJRT runtime ([`runtime`]), a pure-rust verification
//!   engine ([`engine`]), MLMC allocation/diagnostics ([`mlmc`]),
//!   counter-based RNG ([`rng`]), optimizers ([`optim`]), the PRAM cost
//!   model ([`parallel`]), metrics ([`metrics`]) and configuration
//!   ([`config`]).
//!
//! Python never runs on the training hot path: after `make artifacts` the
//! `repro` binary (and all examples/benches) are self-contained.
//!
//! ## Scenarios
//!
//! The engine is generic over a [`scenarios::Scenario`] — a
//! D-dimensional SDE dynamics ([`scenarios::Sde`], `D <=`
//! [`scenarios::MAX_DIM`]: Black–Scholes, Ornstein–Uhlenbeck,
//! Cox–Ingersoll–Ross, and 2-factor Heston stochastic vol with
//! correlated Brownian drivers) paired with a **streaming** path payoff
//! ([`scenarios::Payoff`], an `init → observe → finish` observer:
//! European call/put, Asian, lookback, digital, and up-and-out /
//! down-and-in barriers with in-stream hit-tracking). The simulation
//! spine streams: the integrator ([`engine::milstein::fold_path`]) hands
//! each state to the objective and the payoff observer online, so the
//! native hot path never allocates a `batch x (n_steps + 1)` path buffer
//! (`cargo bench --bench hotpath` tracks materialized vs streaming
//! paths/sec in `BENCH_scenarios.json`). Scenarios are selected by
//! string key (`"ou-asian"`, `"heston-uo-call"`, …) via the
//! `scenario.name` TOML key or the `--scenario` CLI flag, and run on the
//! native backend; the default `"bs-call"` scenario reproduces the seed
//! engine bit-for-bit — through the D-generic + streaming refactor — and
//! is the only one the XLA artifacts cover. The `repro scenario-sweep`
//! subcommand (and `examples/scenario_sweep.rs`) fits each scenario's
//! variance-decay exponent `b` (Assumption 2) and tabulates the MLMC vs
//! delayed-MLMC parallel cost.
//!
//! ## Performance
//!
//! The native hot path is **statically dispatched**: every registry key
//! owns a monomorphized `value_and_grad` / `coupled_value_and_grad` /
//! `loss_only` triple in a flat table ([`scenarios::kernels::KERNELS`]),
//! selected once per dispatch — non-default scenarios pay zero `dyn
//! Sde`/`dyn Payoff` virtual calls per step. Each entry carries two
//! kernel sets:
//!
//! * **scalar** — the streaming reference body. Monomorphizing the same
//!   generic code performs identical f32 operations in identical order,
//!   so scalar kernels are *bit-identical* to dynamic dispatch and the
//!   seed's `bs-call` bitwise anchors hold through the rerouted backend.
//! * **lanes** — the lane-blocked SIMD body ([`engine::lanes`]):
//!   `LANES = 8` paths integrate simultaneously over `[f32; 8]` blocks
//!   (Brownian increments transposed lane-major by
//!   [`rng::brownian::lane_block`]; MLP rows forwarded/backpropagated 8
//!   at a time), which the autovectorizer maps onto AVX/NEON. Lane
//!   kernels **reassociate** f32 reductions and use a polynomial `exp`,
//!   so they register under the scenario's `-simd` variant key
//!   (`"heston-uo-call-simd"`; `--simd` / `[execution] simd` selects it)
//!   and are *tolerance-validated* against the scalar reference per
//!   scenario (`tests/kernel_suite.rs`: relative 1e-3 on loss, 5e-3 on
//!   gradient components) instead of claiming bitwise equality they
//!   cannot have.
//!
//! `repro hotpath-bench` (`make bench-hotpath`) times scalar vs lane
//! kernels per scenario and writes paths/sec + speedup per cell to
//! `BENCH_hotpath.json`. `--pin-cores` / `[execution] pin_cores`
//! additionally pins pool workers round-robin to CPU cores
//! ([`exec::affinity`], Linux `sched_setaffinity`, best-effort no-op
//! elsewhere) with the worker→core map reported per dispatch in
//! [`exec::StepExecReport`]; pinning never changes results.
//!
//! ## Parallel execution
//!
//! Beyond *modeling* parallel cost ([`parallel`]), the crate *executes*
//! it: [`exec::WorkerPool`] shards each step's level jobs into per-chunk
//! tasks, schedules them longest-first over `P` **resident** worker
//! threads — spawned once at pool construction, parked on a condvar
//! between dispatches, joined on `Drop` — and reduces results in fixed
//! chunk order, so the assembled gradient is **bit-identical to
//! sequential dispatch for every worker count** (the counter-based
//! [`rng`] makes each chunk a pure function of its address). Dispatch
//! closures are `'static`: the trainer holds shareable backends behind
//! an `Arc` (`GradBackend::into_shared`) and each dispatch captures
//! `Arc`-cloned backend/params snapshots. The pool is the default
//! execution path for shareable backends (the native engine;
//! `execution.workers` in TOML / `--workers` on the CLI, 0 = one per
//! core); the PJRT runtime's `!Send` handles keep it on sequential
//! dispatch. `repro parallel-sweep` sweeps P x method, records measured
//! per-step makespan and dispatch overhead (makespan minus max worker
//! busy) next to the PRAM model's
//! [`parallel::PramMachine::step_makespan`] prediction, and emits
//! `BENCH_parallel.json` — including a resident-vs-scoped
//! (spawn-per-dispatch) overhead comparison (`repro exec-bench`, `make
//! bench-exec`) that prices the executor's fixed cost on DMLMC's light
//! level-0-only steps.
//!
//! ## Serving fleet
//!
//! One resident pool can serve **many** trainers:
//! [`coordinator::FleetCoordinator`] multiplexes N independent sessions
//! over a single `P`-worker pool, batching every running session's due
//! chunk tasks into **one dispatch per fleet tick** (fair-share: each
//! tick advances every running session by one SGD step) with
//! backpressure when oversubscribed. Per-problem bit-exactness survives
//! the sharing — each session's gradient is reduced from its own task
//! group in fixed chunk order, so its whole trajectory is bit-identical
//! to a solo run at every fleet size and worker count. Sessions are
//! submitted as configured [`coordinator::TrainerBuilder`]s and observed
//! through `submit` / `poll` / `tick` / `drain`:
//!
//! ```no_run
//! use dmlmc::config::{Backend, ExperimentConfig};
//! use dmlmc::coordinator::{FleetCoordinator, Method, TrainerBuilder};
//!
//! let mut cfg = ExperimentConfig::default_paper();
//! cfg.runtime.backend = Backend::Native;
//! let mut fleet = FleetCoordinator::new(4);
//! let a = fleet.submit("bs", TrainerBuilder::new(&cfg).method(Method::Dmlmc)).unwrap();
//! let b = fleet
//!     .submit("heston", TrainerBuilder::new(&cfg).scenario("heston-uo-call"))
//!     .unwrap();
//! let runs = fleet.drain().unwrap(); // tick() until every session is Done
//! assert_eq!(runs.len(), 2);
//! let _ = (a, b, fleet.poll(a));
//! ```
//!
//! `repro fleet-sweep` (`make bench-fleet`) sweeps fleet size x workers
//! and writes aggregate throughput (steps/sec, problems/sec, pool
//! utilization) to `BENCH_fleet.json`. Experiment entry points live on
//! [`experiments::ExperimentRunner`], whose named runs write under a
//! common `--out-dir` via [`metrics::RunArtifacts`].
//!
//! ## Observability
//!
//! The execution stack is traceable end to end ([`obs`]): with
//! `--trace` (or `[observability] trace = true` in the TOML) the trainer
//! and fleet coordinator carry an [`obs::Recorder`] that materializes
//! per-task `task` spans (level / group / chunk / session attrs) and
//! coordinator `dispatch` / `step` / `tick` / `session` spans into
//! bounded per-track rings, alongside an [`obs::Registry`] of counters,
//! gauges and latency histograms. Everything is ingested
//! **coordinator-side** from the [`exec::StepExecReport`] telemetry each
//! dispatch already returns — the worker hot path records nothing new —
//! and tracing is off by default, so an untraced run pays zero cost.
//! [`obs::TraceSink`] exports a run's timeline as Chrome trace-event
//! JSON (`trace.json`, loadable in Perfetto / `chrome://tracing`, one
//! track per stable worker index plus a coordinator track) and the
//! metrics as Prometheus text exposition (`metrics.prom`). `repro
//! trace` (`make trace`) runs the same DMLMC training traced and
//! untraced — plus a third run scraped concurrently over HTTP — asserts
//! all trajectories are bit-identical and the makespan overheads
//! bounded, and emits `BENCH_obs.json`.
//!
//! ### Live scraping: `repro serve`
//!
//! `repro serve` (`make serve-smoke`) keeps a traced
//! [`coordinator::FleetCoordinator`] resident and exposes it over a
//! dependency-free `std::net::TcpListener` HTTP/1.1 server
//! ([`obs::MetricsServer`], `--port` / `[observability] serve_port`,
//! port 0 picks an ephemeral one):
//!
//! * `GET /metrics` — Prometheus text exposition straight from the live
//!   [`obs::SharedRegistry`], rendered by the *same* code that writes
//!   `metrics.prom`. Alongside the execution counters it carries the
//!   estimator-statistics gauges ([`obs::EstimatorStats`]) — per-level
//!   gradient-difference variance (`dmlmc_level_variance`), measured
//!   cost, staleness / refresh age, sample and refresh counts — each
//!   labeled `level="l"` and `session="<id>"`, plus fleet gauges
//!   (`fleet_sessions_active`, `fleet_pool_utilization`) and the
//!   span-ring drop counters (`obs_spans_dropped_total`).
//! * `GET /status` — fleet-level JSON: tick count, worker count,
//!   active/pending/done sessions and per-session progress.
//! * `GET /sessions/<id>` — one session's JSON: step progress, last
//!   loss, and the per-level layout with live estimator statistics.
//!
//! Sessions come from `[serve]` in the TOML (`sessions` trainers seeded
//! `seed0 + i`, see `configs/serve.toml`); SIGINT (or `--max-ticks`)
//! shuts down gracefully, writing `status.json`, `trace.json` and
//! `metrics.prom` through [`metrics::RunArtifacts`]. Serving is pure
//! observation: the scrape thread only ever reads the shared registry,
//! so every session's trajectory stays bit-identical to its solo run
//! (pinned in `tests/obs_serve.rs`, with the scraped gauges checked
//! against a directly computed Welford).
//!
//! ```sh
//! repro serve --config configs/serve.toml --port 9184 &
//! curl -s localhost:9184/metrics | grep dmlmc_level_variance
//! curl -s localhost:9184/status
//! kill -INT %1   # graceful: final artifacts land in the run dir
//! ```
//!
//! ## Adaptive allocation
//!
//! Every level/sample/delay decision lives in one layer ([`policy`]):
//! the [`policy::AllocationPolicy`] trait maps an estimator-telemetry
//! snapshot ([`obs::EstimatorSnapshot`]) to an
//! [`policy::AllocationDecision`] — per-level sample counts
//! ([`mlmc::LevelAllocation`]), the delayed-refresh schedule
//! ([`coordinator::DelayedSchedule`]) and the effective batch size. The
//! trainer derives its chunk layout from the decision and never reads an
//! allocation constant from the config directly (a CI deny-grep pins
//! this). Two implementations ship:
//!
//! * [`policy::FixedPolicy`] (default) — the paper's offline-theory
//!   constants, bit-identical to every pre-policy release (pinned by
//!   `tests/policy_regression.rs`).
//! * [`policy::AdaptivePolicy`] — re-solves the Giles allocation
//!   `N_l ∝ sqrt(V̂_l / Ĉ_l)` and the refresh periods from the live
//!   per-level variance/cost gauges on a configurable cadence, with
//!   per-level hysteresis and clamps so the decision stream is a
//!   deterministic function of the telemetry stream.
//!
//! Enable with `--adaptive` (or `[adaptive] enabled = true` in TOML;
//! `adapt_every`, `min_refreshes`, `hysteresis`, `max_period` tune the
//! cadence and damping — see `configs/adaptive.toml`). Fleet sessions
//! re-observe independently at tick boundaries, so each adapts to its
//! own problem. The active decision is scrape-visible during
//! `repro serve` as the `dmlmc_alloc_n{level="l"}` /
//! `dmlmc_refresh_period{level="l"}` gauges, and `repro adaptive-sweep`
//! (`make bench-adaptive`) measures the fixed-vs-adaptive ablation
//! (wall-clock to target loss, per-step parallel cost) into
//! `BENCH_adaptive.json`.
//!
//! ## Quickstart
//!
//! ```no_run
//! use dmlmc::config::ExperimentConfig;
//! use dmlmc::coordinator::{Method, Trainer};
//!
//! let cfg = ExperimentConfig::default_paper();
//! let mut trainer = Trainer::from_config(&cfg, Method::Dmlmc, 0).unwrap();
//! let curve = trainer.run().unwrap();
//! println!("final loss {:.4}", curve.points.last().unwrap().loss);
//! ```

// Deliberate idioms of the numeric kernels (explicit index loops over
// row-major buffers, wide RNG addressing signatures, `new()` constructors
// without `Default`) that clippy's style lints would otherwise flag under
// the CI's `-D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::new_without_default
)]

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod exec;
pub mod experiments;
pub mod hedging;
pub mod metrics;
pub mod mlmc;
pub mod obs;
pub mod optim;
pub mod parallel;
pub mod policy;
pub mod rng;
pub mod runtime;
pub mod scenarios;
pub mod testkit;
pub mod util;

pub use config::ExperimentConfig;
pub use coordinator::{FleetCoordinator, Method, Trainer, TrainerBuilder};
pub use experiments::ExperimentRunner;
pub use metrics::RunArtifacts;
pub use policy::{AdaptivePolicy, AllocationDecision, AllocationPolicy, FixedPolicy};
pub use scenarios::Scenario;
