//! First-order optimizers over the flat parameter vector.
//!
//! The paper's analysis is for constant-step SGD (Theorem 1); SGD with
//! momentum and Adam are provided for the extension experiments and
//! ablations. All optimizers mutate the parameter vector in place and are
//! deterministic.

/// Common interface: one update from a gradient.
pub trait Optimizer {
    /// Apply one step, mutating `params` given `grad`.
    fn step(&mut self, params: &mut [f32], grad: &[f32]);

    /// Current learning rate (after any schedule).
    fn lr(&self) -> f64;

    fn name(&self) -> &'static str;
}

/// Plain SGD: `x <- x - alpha g` (Algorithm 1's update).
#[derive(Debug, Clone)]
pub struct Sgd {
    pub lr: f64,
}

impl Sgd {
    pub fn new(lr: f64) -> Self {
        assert!(lr > 0.0, "learning rate must be positive");
        Sgd { lr }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), grad.len(), "param/grad dim mismatch");
        let lr = self.lr as f32;
        for (p, &g) in params.iter_mut().zip(grad) {
            *p -= lr * g;
        }
    }

    fn lr(&self) -> f64 {
        self.lr
    }

    fn name(&self) -> &'static str {
        "sgd"
    }
}

/// SGD with classical (heavy-ball) momentum.
#[derive(Debug, Clone)]
pub struct Momentum {
    pub lr: f64,
    pub beta: f64,
    velocity: Vec<f32>,
}

impl Momentum {
    pub fn new(lr: f64, beta: f64) -> Self {
        assert!((0.0..1.0).contains(&beta), "beta must be in [0,1)");
        Momentum {
            lr,
            beta,
            velocity: Vec::new(),
        }
    }
}

impl Optimizer for Momentum {
    fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), grad.len(), "param/grad dim mismatch");
        if self.velocity.is_empty() {
            self.velocity = vec![0.0; params.len()];
        }
        let (lr, beta) = (self.lr as f32, self.beta as f32);
        for ((p, v), &g) in params.iter_mut().zip(&mut self.velocity).zip(grad) {
            *v = beta * *v + g;
            *p -= lr * *v;
        }
    }

    fn lr(&self) -> f64 {
        self.lr
    }

    fn name(&self) -> &'static str {
        "momentum"
    }
}

/// Adam (Kingma & Ba 2015) with bias correction.
#[derive(Debug, Clone)]
pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    pub fn new(lr: f64) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: Vec::new(),
            v: Vec::new(),
            t: 0,
        }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), grad.len(), "param/grad dim mismatch");
        if self.m.is_empty() {
            self.m = vec![0.0; params.len()];
            self.v = vec![0.0; params.len()];
        }
        self.t += 1;
        let b1 = self.beta1 as f32;
        let b2 = self.beta2 as f32;
        let bc1 = 1.0 - (self.beta1).powi(self.t as i32);
        let bc2 = 1.0 - (self.beta2).powi(self.t as i32);
        let lr = self.lr;
        for i in 0..params.len() {
            let g = grad[i];
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g;
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * g * g;
            let mhat = self.m[i] as f64 / bc1;
            let vhat = self.v[i] as f64 / bc2;
            params[i] -= (lr * mhat / (vhat.sqrt() + self.eps)) as f32;
        }
    }

    fn lr(&self) -> f64 {
        self.lr
    }

    fn name(&self) -> &'static str {
        "adam"
    }
}

/// Build an optimizer by name (config/CLI plumbing).
pub fn by_name(name: &str, lr: f64) -> Option<Box<dyn Optimizer>> {
    match name {
        "sgd" => Some(Box::new(Sgd::new(lr))),
        "momentum" => Some(Box::new(Momentum::new(lr, 0.9))),
        "adam" => Some(Box::new(Adam::new(lr))),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Quadratic bowl f(x) = 0.5 ||x||^2, grad = x.
    fn converges(opt: &mut dyn Optimizer, steps: usize) -> f64 {
        let mut x = vec![1.0f32, -2.0, 0.5];
        for _ in 0..steps {
            let g = x.clone();
            opt.step(&mut x, &g);
        }
        x.iter().map(|&v| (v as f64).powi(2)).sum::<f64>().sqrt()
    }

    #[test]
    fn sgd_step_formula() {
        let mut x = vec![1.0f32, 2.0];
        Sgd::new(0.5).step(&mut x, &[0.2, -0.4]);
        assert_eq!(x, vec![0.9, 2.2]);
    }

    #[test]
    fn all_optimizers_converge_on_quadratic() {
        assert!(converges(&mut Sgd::new(0.1), 200) < 1e-6);
        assert!(converges(&mut Momentum::new(0.05, 0.9), 400) < 1e-6);
        assert!(converges(&mut Adam::new(0.05), 800) < 1e-3);
    }

    #[test]
    fn momentum_accelerates_vs_sgd() {
        let slow = converges(&mut Sgd::new(0.01), 100);
        let fast = converges(&mut Momentum::new(0.01, 0.9), 100);
        assert!(fast < slow, "momentum {fast} vs sgd {slow}");
    }

    #[test]
    fn adam_invariant_to_grad_scale() {
        // Adam's first step is ~lr * sign(g), independent of |g|.
        let mut a = Adam::new(0.1);
        let mut b = Adam::new(0.1);
        let mut xa = vec![0.0f32];
        let mut xb = vec![0.0f32];
        a.step(&mut xa, &[1e-3]);
        b.step(&mut xb, &[1e3]);
        assert!((xa[0] - xb[0]).abs() < 1e-4, "{} vs {}", xa[0], xb[0]);
    }

    #[test]
    fn by_name_resolves() {
        assert!(by_name("sgd", 0.1).is_some());
        assert!(by_name("momentum", 0.1).is_some());
        assert!(by_name("adam", 0.1).is_some());
        assert!(by_name("lbfgs", 0.1).is_none());
    }

    #[test]
    #[should_panic(expected = "dim mismatch")]
    fn dim_mismatch_panics() {
        Sgd::new(0.1).step(&mut [0.0, 1.0], &[1.0]);
    }
}
