//! The SGD training loop for all three methods (Algorithm 1 + baselines).
//!
//! Per step:
//! 1. determine the due level jobs (method-dependent),
//! 2. dispatch them (fresh Brownian streams addressed by step/level/chunk),
//! 3. update the gradient cache (DMLMC) or assemble directly,
//! 4. account standard/parallel cost (work = sum, depth = max),
//! 5. optimizer update,
//! 6. on the eval cadence, measure the held-out loss F_lmax on a FIXED
//!    evaluation set (same across steps, methods and seeds — the
//!    learning-curve y-axis of Figure 2).

use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::cache::GradientCache;
use super::dispatcher::{
    run_jobs, run_jobs_pool_with_report, LevelJobSpec, LevelResult,
};
use super::method::Method;
use super::scheduler::DelayedSchedule;
use crate::config::{Backend, ExperimentConfig};
use crate::engine;
use crate::exec::{ChunkTask, ExecStats, SpawnMode, WorkerPool};
use crate::metrics::{CurvePoint, LearningCurve};
use crate::mlmc::estimator::{grad_norm, ChunkAccumulator};
use crate::obs::{estimator, EstimatorStats, GroupMeta, Recorder};
use crate::optim::{self, Optimizer};
use crate::parallel::{CostModel, StepCost};
use crate::policy::{AllocationDecision, AllocationPolicy};
use crate::rng::{brownian::Purpose, BrownianSource};
use crate::runtime::{GradBackend, NativeBackend, SharedBackend, XlaRuntime};

/// How the trainer holds its backend. Shareable backends (the native
/// engine) live behind an `Arc` so the resident pool's `'static` dispatch
/// closures can co-own them; `!Send` backends (PJRT — raw C pointers)
/// stay boxed and dispatch sequentially. Decided once at construction via
/// [`GradBackend::into_shared`].
enum BackendHandle {
    Shared(SharedBackend),
    Local(Box<dyn GradBackend>),
}

impl BackendHandle {
    fn as_dyn(&self) -> &dyn GradBackend {
        match self {
            BackendHandle::Shared(b) => &**b,
            BackendHandle::Local(b) => &**b,
        }
    }

    fn shared(&self) -> Option<&SharedBackend> {
        match self {
            BackendHandle::Shared(b) => Some(b),
            BackendHandle::Local(_) => None,
        }
    }
}

/// One training run: a method, a seed, a backend, a config.
pub struct Trainer {
    pub cfg: ExperimentConfig,
    pub method: Method,
    pub seed: u64,
    backend: BackendHandle,
    /// The allocation policy every level/sample/delay decision comes
    /// from ([`crate::policy`]; `Arc`-shared so fleet sessions can hold
    /// one policy). The trainer itself never reads an allocation
    /// constant from the config.
    policy: Arc<dyn AllocationPolicy>,
    /// The decision currently in force. `chunks_per_level`,
    /// `naive_chunks` and (for DMLMC) `schedule` are pure derivations of
    /// it, re-derived whenever [`Self::maybe_adapt`] adopts a new one.
    decision: AllocationDecision,
    /// Re-observe the policy every this many steps (0 = never — the
    /// fixed-policy default).
    adapt_every: u64,
    /// Decisions adopted so far (excludes held/no-change observations).
    adaptations: u64,
    schedule: DelayedSchedule,
    cache: GradientCache,
    /// Chunks (not samples) to run per level refresh.
    chunks_per_level: Vec<usize>,
    /// Chunks per naive refresh.
    naive_chunks: usize,
    optimizer: Box<dyn Optimizer>,
    src: BrownianSource,
    cost_model: CostModel,
    /// Chunk-sharded resident execution pool — `Some` for shareable
    /// (`Arc`-held) backends (the default path; bit-identical to
    /// sequential dispatch), `None` for `!Send` backends (PJRT), which
    /// always dispatch sequentially. The pool's worker threads are
    /// spawned once here and live until the trainer drops.
    pool: Option<WorkerPool>,
    /// Span recorder + metrics registry — `Some` only when tracing is
    /// enabled ([`crate::config::ObsConfig::trace`]). All ingestion is
    /// coordinator-side, after a dispatch returns: the worker hot path
    /// never sees this field.
    recorder: Option<Recorder>,
    /// Live per-level estimator statistics (variance / cost / staleness
    /// Welfords) — always on: a handful of float updates per refresh,
    /// fed from [`Self::apply_level_results`] so solo and fleet steps
    /// record through the same funnel. Published as labeled gauges when
    /// a recorder is present; queryable either way.
    estimator: EstimatorStats,
    pub params: Vec<f32>,
    cumulative: StepCost,
    steps_done: u64,
}

/// Named-setter construction of a [`Trainer`] — the public build path
/// (the old positional `Trainer::new(cfg, method, seed, backend)` is
/// gone). Every setter is optional; `build()` validates the assembled
/// config and fails with a descriptive error instead of a silently
/// misordered argument list.
///
/// ```no_run
/// # use dmlmc::config::ExperimentConfig;
/// # use dmlmc::coordinator::{Method, TrainerBuilder};
/// let mut trainer = TrainerBuilder::new(&ExperimentConfig::smoke())
///     .method(Method::Dmlmc)
///     .seed(7)
///     .scenario("heston-uo-call")
///     .steps(32)
///     .workers(4)
///     .build()?;
/// trainer.run()?;
/// # anyhow::Ok(())
/// ```
pub struct TrainerBuilder {
    cfg: ExperimentConfig,
    method: Method,
    seed: u64,
    backend: Option<Box<dyn GradBackend>>,
    policy: Option<Arc<dyn AllocationPolicy>>,
    local_pool: bool,
}

impl TrainerBuilder {
    /// Start from a config; method defaults to DMLMC, seed to 0.
    pub fn new(cfg: &ExperimentConfig) -> Self {
        TrainerBuilder {
            cfg: cfg.clone(),
            method: Method::Dmlmc,
            seed: 0,
            backend: None,
            policy: None,
            local_pool: true,
        }
    }

    pub fn method(mut self, method: Method) -> Self {
        self.method = method;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Select a scenario by registry key (`repro scenarios` lists them).
    /// A non-default scenario implies the native backend — the XLA
    /// artifacts are lowered for the default scenario only.
    pub fn scenario(mut self, name: &str) -> Self {
        self.cfg.scenario = name.to_string();
        if name != crate::scenarios::DEFAULT_SCENARIO {
            self.cfg.runtime.backend = Backend::Native;
        }
        self
    }

    /// Training horizon (SGD steps).
    pub fn steps(mut self, steps: usize) -> Self {
        self.cfg.train.steps = steps;
        self
    }

    /// Worker threads of the trainer's own execution pool (0 = one per
    /// core). Irrelevant under a fleet, which supplies the shared pool.
    pub fn workers(mut self, workers: usize) -> Self {
        self.cfg.execution.workers = workers;
        self
    }

    /// Route the native hot path through the lane-blocked SIMD kernels
    /// (equivalent to `--simd` / `[execution] simd = true`): the
    /// backend is built for the scenario's `-simd` registry key.
    /// Reassociates f32 reductions — tolerance-validated, not bitwise.
    pub fn simd(mut self, enabled: bool) -> Self {
        self.cfg.execution.simd = enabled;
        self
    }

    /// Pin the pool's workers round-robin to CPU cores (equivalent to
    /// `--pin-cores` / `[execution] pin_cores = true`). Best-effort and
    /// numerics-neutral; placement lands in `StepExecReport`.
    pub fn pin_cores(mut self, enabled: bool) -> Self {
        self.cfg.execution.pin_cores = enabled;
        self
    }

    /// Inject an explicit backend (dependency injection for tests)
    /// instead of building one from the config.
    pub fn backend(mut self, backend: Box<dyn GradBackend>) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Inject an explicit allocation policy instead of deriving one from
    /// the config (`[adaptive]` → [`crate::policy::from_config`]).
    pub fn policy(mut self, policy: Arc<dyn AllocationPolicy>) -> Self {
        self.policy = Some(policy);
        self
    }

    /// Route allocation through the adaptive policy (equivalent to
    /// `--adaptive` / `[adaptive] enabled = true`).
    pub fn adaptive(mut self, enabled: bool) -> Self {
        self.cfg.adaptive.enabled = enabled;
        self
    }

    /// Re-observe cadence of the adaptive policy in steps (equivalent to
    /// `[adaptive] adapt_every`; only meaningful with `adaptive(true)`).
    pub fn adapt_every(mut self, steps: usize) -> Self {
        self.cfg.adaptive.adapt_every = steps;
        self
    }

    /// Arbitrary config tweak — escape hatch for knobs without a named
    /// setter (learning rate, eval cadence, `n_effective`, ...).
    pub fn tune(mut self, f: impl FnOnce(&mut ExperimentConfig)) -> Self {
        f(&mut self.cfg);
        self
    }

    /// Skip the per-trainer resident pool. Used by the fleet: its
    /// sessions dispatch through the ONE shared coordinator pool, so a
    /// private P-thread pool per trainer would be dead weight.
    pub fn without_local_pool(mut self) -> Self {
        self.local_pool = false;
        self
    }

    /// Enable span tracing (equivalent to `--trace` or
    /// `[observability] trace = true`): the built trainer owns a
    /// [`Recorder`] and ingests every pooled dispatch into it.
    pub fn trace(mut self, enabled: bool) -> Self {
        self.cfg.observability.trace = enabled;
        self
    }

    /// Validate and build. Errors on an invalid config, an unknown
    /// optimizer/scenario, a non-default scenario pinned to the XLA
    /// backend, or an engine/backend parameter-count mismatch.
    pub fn build(self) -> Result<Trainer> {
        let TrainerBuilder { cfg, method, seed, backend, policy, local_pool } = self;
        cfg.validate().map_err(|e| anyhow!(e))?;
        let backend: Box<dyn GradBackend> = match backend {
            Some(b) => b,
            None => match cfg.runtime.backend {
                Backend::Native => {
                    // `[execution] simd` appends the `-simd` key suffix,
                    // routing the backend onto the lane-blocked kernels
                    // (see `NativeBackend::is_simd`).
                    let scenario = crate::scenarios::build_scenario_or_err(
                        &cfg.effective_scenario(),
                        &cfg.problem,
                    )?;
                    Box::new(NativeBackend::with_scenario(cfg.problem, scenario))
                }
                Backend::Xla => {
                    anyhow::ensure!(
                        cfg.scenario == crate::scenarios::DEFAULT_SCENARIO,
                        "scenario `{}` needs --backend native: the artifacts \
                         are lowered for the default scenario only",
                        cfg.scenario
                    );
                    let rt = XlaRuntime::load(&cfg.runtime.artifacts_dir)?;
                    anyhow::ensure!(
                        rt.manifest().problem == cfg.problem,
                        "artifacts were lowered for a different problem than \
                         the config requests; re-run `make artifacts`"
                    );
                    rt.warmup()?;
                    Box::new(rt)
                }
            },
        };
        // Decide the ownership model up front: shareable backends go
        // behind an Arc (resident-pool dispatch), the rest stay boxed
        // (sequential dispatch).
        let backend = match backend.into_shared() {
            Ok(shared) => BackendHandle::Shared(shared),
            Err(local) => BackendHandle::Local(local),
        };
        let problem = *backend.as_dyn().problem();
        let lmax = problem.lmax;

        // Every level/sample/delay decision comes from the policy layer;
        // the executable chunk layout is a pure derivation of its output.
        let policy = policy.unwrap_or_else(|| crate::policy::from_config(&cfg));
        let decision = policy.initial(lmax);
        let chunk_sizes: Vec<usize> =
            (0..=lmax).map(|l| backend.as_dyn().grad_chunk(l)).collect();
        let (chunks_per_level, naive_chunks) =
            layout_from(&decision, &chunk_sizes, backend.as_dyn().naive_chunk());

        let schedule = match method {
            // Algorithm 1 runs the policy's delayed schedule; the
            // baselines refresh every level every step regardless.
            Method::Dmlmc => decision.schedule.clone(),
            _ => DelayedSchedule::every_step(lmax),
        };
        let adapt_every = if cfg.adaptive.enabled {
            cfg.adaptive.adapt_every as u64
        } else {
            0
        };
        let optimizer = optim::by_name(&cfg.train.optimizer, cfg.train.lr)
            .ok_or_else(|| anyhow!("unknown optimizer `{}`", cfg.train.optimizer))?;
        let params = engine::mlp::init_params(seed);
        let n_params = backend.as_dyn().n_params();
        anyhow::ensure!(
            params.len() == n_params,
            "backend n_params {n_params} != engine {}",
            params.len()
        );
        let pool = if local_pool {
            backend.shared().map(|_| {
                WorkerPool::with_options(
                    cfg.execution.resolved_workers(),
                    SpawnMode::Resident,
                    cfg.execution.pin_cores,
                )
            })
        } else {
            None
        };
        let cost_model = CostModel::new(cfg.mlmc.c);
        let recorder = if cfg.observability.trace {
            let workers = pool.as_ref().map(|p| p.workers()).unwrap_or(0);
            let mut rec =
                Recorder::with_capacity(workers, cfg.observability.ring_capacity);
            rec.metrics_mut().set_gauge("dmlmc_pool_workers", workers as f64);
            Some(rec)
        } else {
            None
        };

        Ok(Trainer {
            cfg,
            method,
            seed,
            estimator: EstimatorStats::new(lmax + 1),
            cache: GradientCache::new(lmax, n_params),
            chunks_per_level,
            naive_chunks,
            schedule,
            optimizer,
            src: BrownianSource::new(seed),
            cost_model,
            pool,
            recorder,
            backend,
            params,
            cumulative: StepCost::default(),
            steps_done: 0,
        })
    }
}

/// Derive the executable chunk layout from a policy decision: per-level
/// chunk counts (the allocation rounded up to the backend's chunk sizes)
/// and the chunks of a naive finest-grid refresh. Pure function of
/// (decision, backend geometry) — re-run whenever a new decision is
/// adopted, so the layout can never drift from the decision in force.
fn layout_from(
    decision: &AllocationDecision,
    chunk_sizes: &[usize],
    naive_chunk: usize,
) -> (Vec<usize>, usize) {
    let rounded = decision.allocation.round_to_chunks(chunk_sizes);
    let chunks_per_level: Vec<usize> = (0..chunk_sizes.len())
        .map(|l| rounded.n(l) / chunk_sizes[l])
        .collect();
    let naive_chunks = decision.n_effective.div_ceil(naive_chunk).max(1);
    (chunks_per_level, naive_chunks)
}

impl Trainer {
    /// Build the backend from the config (`xla` loads artifacts,
    /// `native` runs the pure-rust engine under the configured scenario).
    /// Thin wrapper over [`TrainerBuilder`] for the common case.
    pub fn from_config(cfg: &ExperimentConfig, method: Method, seed: u64) -> Result<Trainer> {
        TrainerBuilder::new(cfg).method(method).seed(seed).build()
    }

    /// The level jobs step `t` must run.
    pub fn jobs_for_step(&self, t: u64) -> Vec<LevelJobSpec> {
        let all_levels = |tr: &Trainer| -> Vec<LevelJobSpec> {
            (0..=tr.backend.as_dyn().problem().lmax)
                .map(|level| LevelJobSpec {
                    level,
                    n_chunks: tr.chunks_per_level[level],
                })
                .collect()
        };
        match self.method {
            Method::Naive => vec![],
            Method::Mlmc => all_levels(self),
            // Warmup: full refresh for the first few steps (see
            // TrainConfig::dmlmc_warmup), then Algorithm 1's schedule.
            Method::Dmlmc if t < self.cfg.train.dmlmc_warmup as u64 => all_levels(self),
            Method::Dmlmc => self
                .schedule
                .levels_due(t)
                .into_iter()
                .map(|level| LevelJobSpec {
                    level,
                    n_chunks: self.chunks_per_level[level],
                })
                .collect(),
        }
    }

    /// Run one SGD step; returns (step cost, gradient norm).
    ///
    /// Split-phase under the hood: the *compute* half produces the step's
    /// level results (pooled or sequential), the *apply* half
    /// ([`Self::apply_level_results`] / [`Self::apply_naive_result`])
    /// updates cache, cost accounting and parameters. The fleet drives
    /// the same apply half after its own multiplexed dispatch, so solo
    /// and fleet execution share one numeric path by construction.
    ///
    /// On the adaptation cadence the policy is re-observed *before* the
    /// step's jobs are planned ([`Self::maybe_adapt`]), so a new
    /// decision takes effect from this step's dispatch onward.
    pub fn step(&mut self, t: u64) -> Result<(StepCost, f64)> {
        self.maybe_adapt(t);
        let step_start = self.recorder.as_ref().map(|r| r.now());
        match self.method {
            Method::Naive => {
                let (loss_est, grad) = self.naive_gradient(t)?;
                let _ = loss_est; // estimator value; eval uses held-out loss
                let out = self.apply_naive_result(t, grad);
                self.record_step_span(t, step_start);
                Ok(out)
            }
            Method::Mlmc | Method::Dmlmc => {
                let jobs = self.jobs_for_step(t);
                let (results, report) = if let (Some(shared), Some(pool)) =
                    (self.backend.shared(), self.pool.as_mut())
                {
                    let (results, report) = run_jobs_pool_with_report(
                        shared,
                        &self.src,
                        t,
                        &self.params,
                        &jobs,
                        pool,
                    )?;
                    (results, Some(report))
                } else {
                    let results = run_jobs(
                        self.backend.as_dyn(),
                        &self.src,
                        t,
                        &self.params,
                        &jobs,
                    )?;
                    (results, None)
                };
                if let (Some(rec), Some(report)) =
                    (self.recorder.as_mut(), report.as_ref())
                {
                    let groups: Vec<GroupMeta> = jobs
                        .iter()
                        .map(|j| GroupMeta { level: j.level, session: None })
                        .collect();
                    rec.ingest_dispatch(
                        report,
                        step_start.unwrap_or_default(),
                        &groups,
                    );
                }
                if let Some(report) = report.as_ref() {
                    // Measured per-task cost per level (group g ran
                    // jobs[g]) — estimator telemetry, traced or not.
                    for stat in &report.per_task {
                        if let Some(job) = jobs.get(stat.group) {
                            self.estimator
                                .record_cost(job.level, stat.busy.as_secs_f64());
                        }
                    }
                }
                let out = self.apply_level_results(t, results);
                self.record_step_span(t, step_start);
                Ok(out)
            }
        }
    }

    /// Close the coordinator `step` span (started at `start`) and bump
    /// the step counter. No-op when tracing is off.
    fn record_step_span(&mut self, t: u64, start: Option<Duration>) {
        if let (Some(rec), Some(start)) = (self.recorder.as_mut(), start) {
            {
                let mut m = rec.metrics_mut();
                m.inc("dmlmc_steps_total", 1);
                self.estimator.publish(&mut m, None, t);
                estimator::publish_decision(
                    &mut m,
                    None,
                    &self.decision.allocation.n_per_level,
                    self.schedule.periods(),
                );
            }
            rec.record("step", start, vec![("step", t as f64)]);
        }
    }

    /// Re-observe the policy on the adaptation cadence and, when it
    /// returns a materially different decision, adopt it: re-derive the
    /// chunk layout and (for DMLMC) swap in the new delayed schedule.
    /// No-op when the cadence is 0 (fixed policy, the default) and at
    /// `t = 0`, where the initial decision is already in force. Called
    /// at the top of the solo [`Self::step`] and by the fleet right
    /// before it plans a session's jobs — the same point of the step —
    /// so solo and fleet adaptive trajectories coincide.
    pub(crate) fn maybe_adapt(&mut self, t: u64) {
        if self.adapt_every == 0 || t == 0 || t % self.adapt_every != 0 {
            return;
        }
        let snap = self.estimator.observe(t);
        let next = self.policy.observe(&snap, &self.decision);
        if next.same_as(&self.decision) {
            return;
        }
        let lmax = self.backend.as_dyn().problem().lmax;
        let chunk_sizes: Vec<usize> =
            (0..=lmax).map(|l| self.backend.as_dyn().grad_chunk(l)).collect();
        let (chunks_per_level, naive_chunks) =
            layout_from(&next, &chunk_sizes, self.backend.as_dyn().naive_chunk());
        self.chunks_per_level = chunks_per_level;
        self.naive_chunks = naive_chunks;
        if self.method == Method::Dmlmc {
            self.schedule = next.schedule.clone();
        }
        self.decision = next;
        self.adaptations += 1;
    }

    /// Apply half of a MLMC/DMLMC step: account cost from the level
    /// results, refresh the gradient cache, assemble the estimator and
    /// take the optimizer step. Returns (step cost, gradient norm).
    /// `pub(crate)`: the fleet calls this with results it computed on the
    /// shared pool.
    pub(crate) fn apply_level_results(
        &mut self,
        t: u64,
        results: Vec<LevelResult>,
    ) -> (StepCost, f64) {
        let cost_jobs: Vec<(usize, usize)> =
            results.iter().map(|r| (r.level, r.n_samples)).collect();
        let cost = StepCost::from_jobs(&self.cost_model, &cost_jobs);
        for r in &results {
            self.estimator
                .record_refresh(r.level, t, r.n_samples, &r.grad);
        }
        self.install(t, results);
        let (_loss_est, grad) = self.cache.assemble();
        self.finish_step(t, cost, grad)
    }

    /// Apply half of a naive step: cost for `naive_chunks` finest-grid
    /// chunks, then the optimizer step on the reduced gradient.
    pub(crate) fn apply_naive_result(&mut self, t: u64, grad: Vec<f32>) -> (StepCost, f64) {
        let lmax = self.backend.as_dyn().problem().lmax;
        let n_samples = self.naive_chunks * self.backend.as_dyn().naive_chunk();
        let cost = StepCost::from_jobs(&self.cost_model, &[(lmax, n_samples)]);
        self.finish_step(t, cost, grad)
    }

    /// The shared tail of every step: norm, clip, optimizer update,
    /// cumulative cost. One definition — solo and fleet execution cannot
    /// drift apart here.
    fn finish_step(&mut self, t: u64, cost: StepCost, grad: Vec<f32>) -> (StepCost, f64) {
        let gnorm = grad_norm(&grad);
        let grad = self.clip(grad, gnorm);
        self.optimizer.step(&mut self.params, &grad);
        self.cumulative.add(cost);
        self.steps_done = t + 1;
        (cost, gnorm)
    }

    /// Global-norm gradient clipping (no-op when `clip_norm == 0`).
    fn clip(&self, mut grad: Vec<f32>, norm: f64) -> Vec<f32> {
        let clip = self.cfg.train.clip_norm;
        if clip > 0.0 && norm > clip {
            let scale = (clip / norm) as f32;
            for g in &mut grad {
                *g *= scale;
            }
        }
        grad
    }

    fn install(&mut self, t: u64, results: Vec<LevelResult>) {
        for r in results {
            self.cache.update(r.level, t, r.loss_delta, r.grad);
        }
    }

    /// The naive finest-grid gradient. Chunks are independent (same
    /// counter-based addressing as the level jobs), so they run on the
    /// pool when one exists; the chunk-ordered reduction keeps the result
    /// bit-identical to the sequential loop.
    fn naive_gradient(&mut self, t: u64) -> Result<(f64, Vec<f32>)> {
        let problem = *self.backend.as_dyn().problem();
        let lmax = problem.lmax;
        let batch = self.backend.as_dyn().naive_chunk();
        let n_steps = problem.n_steps(lmax);
        let dt = problem.dt(lmax);
        let n_factors = self.backend.as_dyn().n_factors();
        let n_chunks = self.naive_chunks;
        let src = self.src;
        if let (Some(shared), Some(pool)) =
            (self.backend.shared(), self.pool.as_mut())
        {
            // finest grid only, no coupling — no coarse half in the weight
            let weight = batch as f64 * n_steps as f64;
            let tasks: Vec<ChunkTask> = (0..n_chunks)
                .map(|chunk| ChunkTask { group: 0, chunk, level: lmax, weight })
                .collect();
            // The resident workers need a 'static job: co-own the backend
            // and snapshot the parameters for this dispatch.
            let backend = shared.clone();
            let params_snap: Arc<[f32]> = Arc::from(self.params.as_slice());
            let dispatch_start = self.recorder.as_ref().map(|r| r.now());
            let (mut reduced, report) =
                pool.execute(&tasks, 1, move |task: &ChunkTask| {
                    let dw = src.increments_multi(
                        Purpose::Grad,
                        t,
                        lmax as u32,
                        task.chunk as u32,
                        batch,
                        n_steps,
                        dt,
                        n_factors,
                    );
                    backend.grad_naive_chunk(&params_snap, &dw)
                })?;
            if let (Some(rec), Some(start)) =
                (self.recorder.as_mut(), dispatch_start)
            {
                rec.ingest_dispatch(
                    &report,
                    start,
                    &[GroupMeta { level: lmax, session: None }],
                );
            }
            let (loss, grad) = reduced.pop().expect("one reduction group");
            return Ok((loss, grad));
        }
        let mut acc = ChunkAccumulator::new(self.backend.as_dyn().n_params());
        for chunk in 0..n_chunks {
            let dw = src.increments_multi(
                Purpose::Grad,
                t,
                lmax as u32,
                chunk as u32,
                batch,
                n_steps,
                dt,
                n_factors,
            );
            let (loss, grad) = self
                .backend
                .as_dyn()
                .grad_naive_chunk(&self.params, &dw)?;
            acc.add(loss, &grad);
        }
        let (loss, grad) = acc.finish();
        Ok((loss, grad))
    }

    /// Held-out loss on the FIXED evaluation set (chunk-averaged).
    pub fn eval_loss(&self) -> Result<f64> {
        let be = self.backend.as_dyn();
        let lmax = be.problem().lmax;
        let batch = be.eval_chunk();
        let n_steps = be.problem().n_steps(lmax);
        let dt = be.problem().dt(lmax);
        let mut total = 0.0;
        for chunk in 0..self.cfg.train.eval_chunks.max(1) {
            // Purpose::Eval + step 0: the same batch at every evaluation.
            let dw = self.src.increments_multi(
                Purpose::Eval,
                0,
                lmax as u32,
                chunk as u32,
                batch,
                n_steps,
                dt,
                be.n_factors(),
            );
            total += be.loss_eval_chunk(&self.params, &dw)?;
        }
        Ok(total / self.cfg.train.eval_chunks.max(1) as f64)
    }

    /// Full training run, recording the learning curve.
    pub fn run(&mut self) -> Result<LearningCurve> {
        let mut curve = LearningCurve::new(self.method.name(), self.seed);
        let loss0 = self.eval_loss()?;
        curve.push(CurvePoint {
            step: 0,
            loss: loss0,
            std_cost: 0.0,
            par_cost: 0.0,
            grad_norm: 0.0,
        });
        for t in 0..self.cfg.train.steps as u64 {
            let (_, gnorm) = self.step(t)?;
            let next = t + 1;
            if next % self.cfg.train.eval_every as u64 == 0
                || next == self.cfg.train.steps as u64
            {
                let loss = self.eval_loss()?;
                curve.push(CurvePoint {
                    step: next as usize,
                    loss,
                    std_cost: self.cumulative.work,
                    par_cost: self.cumulative.depth,
                    grad_norm: gnorm,
                });
            }
        }
        Ok(curve)
    }

    /// Cumulative cost so far.
    pub fn cumulative_cost(&self) -> StepCost {
        self.cumulative
    }

    /// Read-only access to the backend (diagnostics drivers).
    pub fn backend(&self) -> &dyn GradBackend {
        self.backend.as_dyn()
    }

    /// Per-level chunk counts (N_l rounded to chunks) — introspection for
    /// the complexity table and tests.
    pub fn chunks_per_level(&self) -> &[usize] {
        &self.chunks_per_level
    }

    /// Chunks a naive refresh runs (`ceil(N / naive_chunk)`).
    pub fn naive_chunks(&self) -> usize {
        self.naive_chunks
    }

    /// Per-level refresh periods in force — the decision's schedule for
    /// DMLMC, the every-step schedule for the baselines.
    pub fn schedule_periods(&self) -> &[u64] {
        self.schedule.periods()
    }

    /// Measured execution telemetry (per-step makespans, per-worker busy
    /// time, utilization) — `None` when the backend dispatches
    /// sequentially (no pool).
    pub fn exec_stats(&self) -> Option<&ExecStats> {
        self.pool.as_ref().map(|p| p.stats())
    }

    /// The pool's worker count, when pooled dispatch is active.
    pub fn exec_workers(&self) -> Option<usize> {
        self.pool.as_ref().map(|p| p.workers())
    }

    /// Live per-level estimator statistics (variance / cost / staleness
    /// accumulated from every `apply_level_results`). Always available,
    /// traced or not.
    pub fn estimator(&self) -> &EstimatorStats {
        &self.estimator
    }

    /// Mutable estimator access — the fleet feeds measured per-task
    /// cost from its multiplexed dispatch report here.
    pub(crate) fn estimator_mut(&mut self) -> &mut EstimatorStats {
        &mut self.estimator
    }

    /// The allocation decision currently in force (the policy's output;
    /// chunk layout and DMLMC schedule are derived from it).
    pub fn decision(&self) -> &AllocationDecision {
        &self.decision
    }

    /// Display name of the allocation policy (`"fixed"` / `"adaptive"`).
    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Decisions adopted by [`Self::maybe_adapt`] so far (held /
    /// no-change observations don't count).
    pub fn adaptations(&self) -> u64 {
        self.adaptations
    }

    /// The span recorder — `Some` only when tracing is enabled.
    pub fn recorder(&self) -> Option<&Recorder> {
        self.recorder.as_ref()
    }

    /// Mutable recorder access, for drivers that add their own
    /// coordinator spans or metrics around the training loop.
    pub fn recorder_mut(&mut self) -> Option<&mut Recorder> {
        self.recorder.as_mut()
    }

    /// Detach the recorder, e.g. to export its trace after the trainer
    /// (and its pool) is gone. Subsequent steps record nothing.
    pub fn take_recorder(&mut self) -> Option<Recorder> {
        self.recorder.take()
    }

    /// Co-ownable backend handle (`None` for `!Send` backends). The
    /// fleet requires this: its multiplexed dispatch closures co-own
    /// every session's backend.
    pub(crate) fn shared_backend(&self) -> Option<SharedBackend> {
        self.backend.shared().cloned()
    }

    /// This trainer's Brownian stream (counter-based; `Copy`). The fleet
    /// addresses each session's chunk batches through this, exactly like
    /// the solo dispatch path.
    pub(crate) fn brownian_src(&self) -> BrownianSource {
        self.src
    }

    /// The estimator the *next* step would use from the current cache
    /// (Algorithm 1's `∇F̂_DMLMC` with components at their `τ_l`).
    /// Only meaningful after at least one step; panics for `Naive`.
    pub fn assembled_gradient(&self) -> (f64, Vec<f32>) {
        assert!(
            self.method != Method::Naive,
            "naive SGD keeps no gradient cache"
        );
        self.cache.assemble()
    }

    /// Compute a *fresh* full-MLMC gradient at the current parameters
    /// (all levels resampled with the given stream seed) — the unbiased
    /// reference the delayed estimator is compared against in the
    /// ablation bench.
    pub fn fresh_mlmc_gradient(&self, stream_seed: u64) -> Result<(f64, Vec<f32>)> {
        let lmax = self.backend.as_dyn().problem().lmax;
        let jobs: Vec<LevelJobSpec> = (0..=lmax)
            .map(|level| LevelJobSpec {
                level,
                n_chunks: self.chunks_per_level[level],
            })
            .collect();
        let src = BrownianSource::new(stream_seed);
        let results = run_jobs(
            self.backend.as_dyn(),
            &src,
            u64::MAX - 1,
            &self.params,
            &jobs,
        )?;
        let mut grad = vec![0.0f32; self.backend.as_dyn().n_params()];
        let mut loss = 0.0;
        for r in results {
            loss += r.loss_delta;
            for (a, &g) in grad.iter_mut().zip(&r.grad) {
                *a += g;
            }
        }
        Ok((loss, grad))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn smoke_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::smoke();
        cfg.train.steps = 8;
        cfg.train.eval_every = 4;
        cfg
    }

    fn trainer(method: Method) -> Trainer {
        Trainer::from_config(&smoke_cfg(), method, 0).unwrap()
    }

    #[test]
    fn dmlmc_jobs_follow_schedule_after_warmup() {
        let mut cfg = smoke_cfg();
        cfg.train.dmlmc_warmup = 0;
        let tr = Trainer::from_config(&cfg, Method::Dmlmc, 0).unwrap();
        let lmax = tr.cfg.problem.lmax;
        // t = 0: every level due.
        assert_eq!(tr.jobs_for_step(0).len(), lmax + 1);
        // t = 1: only level 0.
        let j1 = tr.jobs_for_step(1);
        assert_eq!(j1.len(), 1);
        assert_eq!(j1[0].level, 0);
        // t = 2: levels 0 and 1.
        let j2: Vec<usize> = tr.jobs_for_step(2).iter().map(|j| j.level).collect();
        assert_eq!(j2, vec![0, 1]);
    }

    #[test]
    fn dmlmc_warmup_refreshes_everything() {
        let mut cfg = smoke_cfg();
        cfg.train.dmlmc_warmup = 4;
        let tr = Trainer::from_config(&cfg, Method::Dmlmc, 0).unwrap();
        let lmax = tr.cfg.problem.lmax;
        for t in 0..4 {
            assert_eq!(tr.jobs_for_step(t).len(), lmax + 1, "warmup step {t}");
        }
        // first post-warmup step follows the schedule again
        assert!(tr.jobs_for_step(5).len() < lmax + 1);
    }

    #[test]
    fn mlmc_refreshes_all_levels_every_step() {
        let tr = trainer(Method::Mlmc);
        for t in 0..5 {
            assert_eq!(tr.jobs_for_step(t).len(), tr.cfg.problem.lmax + 1);
        }
    }

    #[test]
    fn training_reduces_loss() {
        let mut cfg = smoke_cfg();
        cfg.train.steps = 30;
        cfg.train.eval_every = 30;
        cfg.train.lr = 0.1;
        let mut tr = Trainer::from_config(&cfg, Method::Dmlmc, 0).unwrap();
        let curve = tr.run().unwrap();
        let first = curve.points.first().unwrap().loss;
        let last = curve.points.last().unwrap().loss;
        assert!(
            last < first,
            "loss should decrease: {first} -> {last}"
        );
    }

    #[test]
    fn dmlmc_parallel_cost_below_mlmc() {
        let mut a = trainer(Method::Mlmc);
        let mut b = trainer(Method::Dmlmc);
        for t in 0..8 {
            a.step(t).unwrap();
            b.step(t).unwrap();
        }
        let ca = a.cumulative_cost();
        let cb = b.cumulative_cost();
        assert!(
            cb.depth < ca.depth,
            "dmlmc depth {} !< mlmc depth {}",
            cb.depth,
            ca.depth
        );
        // standard complexity of dmlmc is also <= mlmc (skipped levels)
        assert!(cb.work <= ca.work);
    }

    #[test]
    fn naive_parallel_cost_equals_mlmc_depth_per_step() {
        let mut a = trainer(Method::Naive);
        let mut b = trainer(Method::Mlmc);
        let (ca, _) = a.step(0).unwrap();
        let (cb, _) = b.step(0).unwrap();
        assert_eq!(ca.depth, cb.depth); // both 2^{c lmax}
        assert!(ca.work > cb.work); // naive does N samples at lmax
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut tr = Trainer::from_config(&smoke_cfg(), Method::Dmlmc, seed).unwrap();
            tr.run().unwrap()
        };
        let a = run(3);
        let b = run(3);
        let c = run(4);
        assert_eq!(a.points.len(), b.points.len());
        for (pa, pb) in a.points.iter().zip(&b.points) {
            assert_eq!(pa.loss, pb.loss);
        }
        assert!(a
            .points
            .iter()
            .zip(&c.points)
            .any(|(pa, pc)| pa.loss != pc.loss));
    }

    #[test]
    fn curve_grid_is_method_independent() {
        // Figure-2 aggregation relies on a common eval grid.
        let a = trainer(Method::Naive);
        let b = trainer(Method::Dmlmc);
        assert_eq!(a.cfg.train.eval_every, b.cfg.train.eval_every);
        let mut ta = trainer(Method::Mlmc);
        let curve = ta.run().unwrap();
        let steps: Vec<usize> = curve.points.iter().map(|p| p.step).collect();
        assert_eq!(steps, vec![0, 4, 8]);
    }

    #[test]
    fn grad_clipping_bounds_update_norm() {
        let mut cfg = smoke_cfg();
        cfg.train.clip_norm = 0.01; // absurdly tight: every step clips
        cfg.train.lr = 0.1;
        let mut tr = Trainer::from_config(&cfg, Method::Mlmc, 0).unwrap();
        let before = tr.params.clone();
        tr.step(0).unwrap();
        let delta: f64 = tr
            .params
            .iter()
            .zip(&before)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        // ||update|| <= lr * clip (plus f32 slack)
        assert!(delta <= 0.1 * 0.01 * 1.01, "update norm {delta}");
    }

    #[test]
    fn non_default_scenario_trains_on_native_backend() {
        let mut cfg = smoke_cfg();
        cfg.scenario = "ou-asian".to_string();
        let mut tr = Trainer::from_config(&cfg, Method::Dmlmc, 0).unwrap();
        let curve = tr.run().unwrap();
        assert!(curve.points.iter().all(|p| p.loss.is_finite()));
        // scenario actually changes the objective
        let mut dflt = Trainer::from_config(&smoke_cfg(), Method::Dmlmc, 0).unwrap();
        let base = dflt.run().unwrap();
        assert_ne!(
            curve.points.last().unwrap().loss,
            base.points.last().unwrap().loss
        );
    }

    #[test]
    fn two_factor_scenario_trains_end_to_end() {
        // Heston (dim 2): the whole stack — dispatcher, cache, eval —
        // must route factor-major increments and stay finite.
        let mut cfg = smoke_cfg();
        cfg.scenario = "heston-call".to_string();
        let mut tr = Trainer::from_config(&cfg, Method::Dmlmc, 0).unwrap();
        assert_eq!(tr.backend().n_factors(), 2);
        let curve = tr.run().unwrap();
        assert!(curve.points.iter().all(|p| p.loss.is_finite()));
        // naive method exercises the finest-grid entry point too
        let mut cfg2 = smoke_cfg();
        cfg2.scenario = "heston-uo-call".to_string();
        cfg2.train.steps = 2;
        let mut tr2 = Trainer::from_config(&cfg2, Method::Naive, 0).unwrap();
        let curve2 = tr2.run().unwrap();
        assert!(curve2.points.iter().all(|p| p.loss.is_finite()));
    }

    #[test]
    fn non_default_scenario_rejected_on_xla_backend() {
        let mut cfg = smoke_cfg();
        cfg.scenario = "cir-call".to_string();
        cfg.runtime.backend = Backend::Xla;
        let err = Trainer::from_config(&cfg, Method::Dmlmc, 0).unwrap_err();
        assert!(format!("{err:#}").contains("native"));
    }

    #[test]
    fn curves_are_bitwise_invariant_to_worker_count() {
        // The pool's fixed-order reduction makes the whole trajectory —
        // not just one gradient — independent of P, for every method
        // (naive exercises the pooled finest-grid path).
        for method in [Method::Naive, Method::Mlmc, Method::Dmlmc] {
            let run = |workers: usize| {
                let mut cfg = smoke_cfg();
                cfg.train.steps = 6;
                cfg.train.eval_every = 2;
                cfg.execution.workers = workers;
                let mut tr = Trainer::from_config(&cfg, method, 1).unwrap();
                let curve = tr.run().unwrap();
                assert_eq!(tr.exec_workers(), Some(workers));
                (curve, tr.params.clone())
            };
            let (c1, p1) = run(1);
            for workers in [2usize, 3] {
                let (c, p) = run(workers);
                assert_eq!(p, p1, "{method}: params differ at P={workers}");
                for (a, b) in c.points.iter().zip(&c1.points) {
                    assert_eq!(a.loss, b.loss, "{method} P={workers}");
                    assert_eq!(a.grad_norm, b.grad_norm, "{method} P={workers}");
                }
            }
        }
    }

    #[test]
    fn exec_stats_cover_every_step() {
        let mut cfg = smoke_cfg();
        cfg.train.steps = 5;
        cfg.execution.workers = 2;
        let mut tr = Trainer::from_config(&cfg, Method::Dmlmc, 0).unwrap();
        tr.run().unwrap();
        let stats = tr.exec_stats().expect("native backend pools");
        assert_eq!(stats.steps, 5);
        assert_eq!(stats.makespans.len(), 5);
        assert_eq!(stats.busy_per_worker.len(), 2);
        assert!(stats.tasks > 0);
        let util = stats.utilization();
        assert!((0.0..=1.0).contains(&util), "utilization {util}");
    }

    #[test]
    fn builder_named_setters_mirror_from_config() {
        // from_config IS the builder — same knobs, same trajectory.
        let cfg = smoke_cfg();
        let mut a = Trainer::from_config(&cfg, Method::Dmlmc, 3).unwrap();
        let mut b = TrainerBuilder::new(&cfg).method(Method::Dmlmc).seed(3).build().unwrap();
        let ca = a.run().unwrap();
        let cb = b.run().unwrap();
        for (pa, pb) in ca.points.iter().zip(&cb.points) {
            assert_eq!(pa.loss, pb.loss);
        }
        assert_eq!(a.params, b.params);
    }

    #[test]
    fn builder_scenario_setter_implies_native_backend() {
        let mut cfg = smoke_cfg();
        cfg.runtime.backend = Backend::Xla; // would reject a non-default scenario
        let tr = TrainerBuilder::new(&cfg)
            .method(Method::Dmlmc)
            .scenario("heston-call")
            .steps(2)
            .build()
            .unwrap();
        assert_eq!(tr.backend().n_factors(), 2);
        assert_eq!(tr.cfg.scenario, "heston-call");
    }

    #[test]
    fn builder_tune_and_steps_land_in_config() {
        let tr = TrainerBuilder::new(&smoke_cfg())
            .steps(5)
            .workers(2)
            .tune(|c| c.train.lr = 0.123)
            .build()
            .unwrap();
        assert_eq!(tr.cfg.train.steps, 5);
        assert_eq!(tr.exec_workers(), Some(2));
        assert_eq!(tr.cfg.train.lr, 0.123);
    }

    #[test]
    fn builder_without_local_pool_dispatches_sequentially() {
        let mut tr = TrainerBuilder::new(&smoke_cfg())
            .method(Method::Mlmc)
            .without_local_pool()
            .build()
            .unwrap();
        assert!(tr.exec_workers().is_none());
        assert!(tr.shared_backend().is_some(), "backend is still shareable");
        // still steps fine through the sequential path
        tr.step(0).unwrap();
        assert!(tr.cumulative_cost().depth > 0.0);
    }

    #[test]
    fn tracing_records_spans_without_changing_the_trajectory() {
        let run = |trace: bool| {
            let mut cfg = smoke_cfg();
            cfg.train.steps = 4;
            cfg.execution.workers = 2;
            cfg.observability.trace = trace;
            let mut tr = Trainer::from_config(&cfg, Method::Dmlmc, 1).unwrap();
            let curve = tr.run().unwrap();
            let rec = tr.take_recorder();
            (curve, tr.params.clone(), rec)
        };
        let (c_off, p_off, rec_off) = run(false);
        let (c_on, p_on, rec_on) = run(true);
        assert!(rec_off.is_none(), "tracing is off by default");
        let rec = rec_on.expect("tracing enabled builds a recorder");
        // bitwise: enabling tracing never changes a gradient
        assert_eq!(p_on, p_off, "tracing changed the parameters");
        for (a, b) in c_on.points.iter().zip(&c_off.points) {
            assert_eq!(a.loss, b.loss);
            assert_eq!(a.grad_norm, b.grad_norm);
        }
        // 4 steps => 4 `step` + 4 `dispatch` spans on the coordinator track
        let names: Vec<&str> =
            rec.coordinator_spans().iter().map(|s| s.name).collect();
        assert_eq!(names.iter().filter(|n| **n == "step").count(), 4);
        assert_eq!(names.iter().filter(|n| **n == "dispatch").count(), 4);
        assert_eq!(rec.metrics().counter("dmlmc_steps_total"), 4);
        assert!(rec.metrics().counter("dmlmc_tasks_dispatched_total") > 0);
        assert_eq!(rec.metrics().gauge("dmlmc_pool_workers"), Some(2.0));
        let task_spans: usize = rec.worker_span_counts().iter().sum();
        assert!(task_spans > 0, "worker tracks must carry task spans");
    }

    #[test]
    fn tracing_ingests_naive_pooled_dispatches() {
        let mut cfg = smoke_cfg();
        cfg.train.steps = 2;
        cfg.execution.workers = 2;
        cfg.observability.trace = true;
        let mut tr = Trainer::from_config(&cfg, Method::Naive, 0).unwrap();
        for t in 0..2 {
            tr.step(t).unwrap();
        }
        let chunks = tr.naive_chunks();
        let rec = tr.take_recorder().unwrap();
        assert_eq!(rec.metrics().counter("dmlmc_dispatches_total"), 2);
        assert_eq!(
            rec.metrics().counter("dmlmc_tasks_dispatched_total") as usize,
            2 * chunks
        );
        assert_eq!(rec.metrics().counter("dmlmc_steps_total"), 2);
    }

    #[test]
    fn builder_trace_setter_enables_the_recorder() {
        let mut tr = TrainerBuilder::new(&smoke_cfg())
            .method(Method::Mlmc)
            .steps(1)
            .workers(2)
            .trace(true)
            .build()
            .unwrap();
        assert!(tr.recorder().is_some());
        tr.step(0).unwrap();
        assert_eq!(
            tr.recorder().unwrap().metrics().counter("dmlmc_steps_total"),
            1
        );
        assert!(tr.take_recorder().is_some());
        assert!(tr.recorder().is_none(), "take_recorder detaches");
    }

    #[test]
    fn simd_execution_trains_and_tracks_the_scalar_trajectory() {
        // `[execution] simd` must route the SAME scenario through the
        // lane kernels: the trajectory is tolerance-close (lane kernels
        // reassociate f32 reductions), finite throughout, and actually
        // produced under the `-simd` registry key.
        let mut cfg = smoke_cfg();
        cfg.scenario = "heston-uo-call".to_string();
        cfg.train.steps = 4;
        cfg.train.eval_every = 2;
        let mut scalar = Trainer::from_config(&cfg, Method::Dmlmc, 0).unwrap();
        let c_scalar = scalar.run().unwrap();
        let mut simd = TrainerBuilder::new(&cfg)
            .method(Method::Dmlmc)
            .simd(true)
            .build()
            .unwrap();
        assert_eq!(simd.cfg.effective_scenario(), "heston-uo-call-simd");
        let c_simd = simd.run().unwrap();
        assert_eq!(c_simd.points.len(), c_scalar.points.len());
        for (a, b) in c_simd.points.iter().zip(&c_scalar.points) {
            assert!(a.loss.is_finite());
            let tol = 5e-2 * b.loss.abs().max(1.0);
            assert!(
                (a.loss - b.loss).abs() <= tol,
                "step {}: simd loss {} vs scalar {}",
                a.step,
                a.loss,
                b.loss
            );
        }
    }

    #[test]
    fn pin_cores_is_bitwise_invariant_and_reported() {
        // Pinning touches thread placement only — the trajectory must be
        // bit-identical with it on or off.
        let run = |pin: bool| {
            let mut cfg = smoke_cfg();
            cfg.train.steps = 4;
            cfg.execution.workers = 2;
            let mut tr = TrainerBuilder::new(&cfg)
                .method(Method::Dmlmc)
                .pin_cores(pin)
                .build()
                .unwrap();
            tr.run().unwrap();
            tr.params.clone()
        };
        assert_eq!(run(true), run(false), "pin_cores changed the numbers");
    }

    #[test]
    fn allocation_covers_effective_batch() {
        let tr = trainer(Method::Mlmc);
        let total: usize = tr
            .chunks_per_level()
            .iter()
            .enumerate()
            .map(|(l, &ch)| ch * tr.backend().grad_chunk(l))
            .sum();
        assert!(total >= tr.decision().n_effective);
    }

    #[test]
    fn default_policy_is_fixed_and_never_adapts() {
        let mut tr = trainer(Method::Dmlmc);
        assert_eq!(tr.policy_name(), "fixed");
        tr.run().unwrap();
        assert_eq!(tr.adaptations(), 0);
    }

    #[test]
    fn injected_fixed_policy_matches_the_default_path_bitwise() {
        let cfg = smoke_cfg();
        let mut a = Trainer::from_config(&cfg, Method::Dmlmc, 2).unwrap();
        let mut b = TrainerBuilder::new(&cfg)
            .method(Method::Dmlmc)
            .seed(2)
            .policy(Arc::new(crate::policy::FixedPolicy::from_config(&cfg)))
            .build()
            .unwrap();
        let ca = a.run().unwrap();
        let cb = b.run().unwrap();
        for (pa, pb) in ca.points.iter().zip(&cb.points) {
            assert_eq!(pa.loss, pb.loss);
            assert_eq!(pa.grad_norm, pb.grad_norm);
        }
        assert_eq!(a.params, b.params);
    }

    #[test]
    fn builder_adaptive_knobs_land_in_config() {
        let tr = TrainerBuilder::new(&smoke_cfg())
            .adaptive(true)
            .adapt_every(4)
            .build()
            .unwrap();
        assert!(tr.cfg.adaptive.enabled);
        assert_eq!(tr.cfg.adaptive.adapt_every, 4);
        assert_eq!(tr.policy_name(), "adaptive");
    }

    #[test]
    fn adaptive_run_is_deterministic_without_wall_clock_costs() {
        // Sequential dispatch records no measured task costs, so the
        // adaptive policy sees model-fed telemetry only and the whole
        // trajectory — including adopted decisions — is reproducible.
        let run = || {
            let mut cfg = smoke_cfg();
            cfg.train.steps = 16;
            cfg.train.eval_every = 8;
            let mut tr = TrainerBuilder::new(&cfg)
                .method(Method::Dmlmc)
                .seed(1)
                .adaptive(true)
                .adapt_every(4)
                .without_local_pool()
                .build()
                .unwrap();
            let curve = tr.run().unwrap();
            let decision = tr.decision().clone();
            (curve, tr.params.clone(), tr.adaptations(), decision)
        };
        let (ca, pa, na, da) = run();
        let (cb, pb, nb, db) = run();
        assert_eq!(pa, pb, "adaptive trajectory must be reproducible");
        assert_eq!(na, nb);
        assert!(da.same_as(&db));
        for (a, b) in ca.points.iter().zip(&cb.points) {
            assert_eq!(a.loss, b.loss);
        }
        // the decision invariants hold whatever the policy adopted
        assert_eq!(da.schedule.period(0), 1);
        assert!(da.allocation.n_per_level.iter().all(|&n| n >= 1));
        assert_eq!(da.n_effective, 64);
    }

    #[test]
    fn adaptive_layout_tracks_the_adopted_decision() {
        let mut cfg = smoke_cfg();
        cfg.train.steps = 16;
        let mut tr = TrainerBuilder::new(&cfg)
            .method(Method::Dmlmc)
            .adaptive(true)
            .adapt_every(4)
            .without_local_pool()
            .build()
            .unwrap();
        for t in 0..16 {
            tr.step(t).unwrap();
            // layout is always the pure derivation of the decision
            let chunk_sizes: Vec<usize> = (0..=tr.cfg.problem.lmax)
                .map(|l| tr.backend().grad_chunk(l))
                .collect();
            let rounded = tr.decision().allocation.round_to_chunks(&chunk_sizes);
            for (l, &ch) in tr.chunks_per_level().iter().enumerate() {
                assert_eq!(ch * chunk_sizes[l], rounded.n(l), "level {l}");
            }
            // DMLMC schedule mirrors the decision's schedule
            assert_eq!(tr.schedule.periods(), tr.decision().schedule.periods());
        }
    }
}
