//! The three optimization methods compared in the paper (Table 1, Fig 2).

/// Gradient-estimation strategy for the SGD loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// `∇F̂_naive`: average of N finest-grid samples per step.
    Naive,
    /// `∇F̂_MLMC`: all level components refreshed every step (paper §2).
    Mlmc,
    /// `∇F̂_DMLMC`: level `l` refreshed every `⌊2^{dl}⌋` steps, cached
    /// otherwise (paper §3, Algorithm 1 — the contribution).
    Dmlmc,
}

impl Method {
    pub fn parse(s: &str) -> Option<Method> {
        match s {
            "naive" => Some(Method::Naive),
            "mlmc" => Some(Method::Mlmc),
            "dmlmc" | "delayed" => Some(Method::Dmlmc),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Method::Naive => "naive",
            Method::Mlmc => "mlmc",
            Method::Dmlmc => "dmlmc",
        }
    }

    pub fn all() -> [Method; 3] {
        [Method::Naive, Method::Mlmc, Method::Dmlmc]
    }
}

impl std::fmt::Display for Method {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for m in Method::all() {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("delayed"), Some(Method::Dmlmc));
        assert_eq!(Method::parse("sgd"), None);
    }

    #[test]
    fn display_matches_name() {
        assert_eq!(format!("{}", Method::Dmlmc), "dmlmc");
    }
}
