//! Per-level gradient-component cache — the "recycling" half of
//! Algorithm 1.
//!
//! Stores the most recent `∇Δ_l F̂_MLMC(x_{τ_l}, ξ_{τ_l,l})` per level and
//! assembles the delayed estimator `∇F̂_DMLMC = Σ_l ∇Δ_l F̂^{(τ_l)}` on
//! demand. Tracks refresh steps so staleness is auditable.

/// One cached level component.
#[derive(Debug, Clone)]
struct Slot {
    loss_delta: f64,
    grad: Vec<f32>,
    /// Step at which this component was computed (τ_l).
    refreshed_at: u64,
}

/// Cache of the `lmax + 1` level components.
#[derive(Debug, Clone)]
pub struct GradientCache {
    dim: usize,
    slots: Vec<Option<Slot>>,
}

/// Assembly was attempted while one or more level slots had never been
/// refreshed — the estimator `Σ_l ∇Δ_l` would silently drop those
/// levels' contributions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AssembleError {
    /// Levels whose slot was never populated.
    pub missing_levels: Vec<usize>,
}

impl std::fmt::Display for AssembleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "cache has unpopulated levels {:?}: every level must be \
             refreshed once (the schedule refreshes all levels at t = 0) \
             before the delayed estimator can be assembled",
            self.missing_levels
        )
    }
}

impl std::error::Error for AssembleError {}

impl GradientCache {
    pub fn new(lmax: usize, dim: usize) -> Self {
        GradientCache {
            dim,
            slots: vec![None; lmax + 1],
        }
    }

    pub fn lmax(&self) -> usize {
        self.slots.len() - 1
    }

    /// Install a freshly computed component for `level`.
    pub fn update(&mut self, level: usize, step: u64, loss_delta: f64, grad: Vec<f32>) {
        assert_eq!(grad.len(), self.dim, "gradient dim mismatch");
        if let Some(prev) = &self.slots[level] {
            assert!(
                step >= prev.refreshed_at,
                "refresh steps must be monotone per level"
            );
        }
        self.slots[level] = Some(Slot {
            loss_delta,
            grad,
            refreshed_at: step,
        });
    }

    /// Is every level populated (true after the first step, which
    /// refreshes everything since `t = 0 ≡ 0` mod every period)?
    pub fn is_complete(&self) -> bool {
        self.slots.iter().all(|s| s.is_some())
    }

    /// Steps since level `level` was refreshed, as of `now`.
    pub fn staleness(&self, level: usize, now: u64) -> Option<u64> {
        self.slots[level].as_ref().map(|s| now - s.refreshed_at)
    }

    /// Refresh step of `level` (τ_l), if populated.
    pub fn refreshed_at(&self, level: usize) -> Option<u64> {
        self.slots[level].as_ref().map(|s| s.refreshed_at)
    }

    /// Assemble the delayed MLMC estimator from the cached components:
    /// `(Σ_l Δloss_l, Σ_l ∇Δ_l)`, or a typed [`AssembleError`] naming
    /// every level whose slot was never refreshed.
    pub fn try_assemble(&self) -> Result<(f64, Vec<f32>), AssembleError> {
        let missing_levels: Vec<usize> = self
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_none())
            .map(|(l, _)| l)
            .collect();
        if !missing_levels.is_empty() {
            return Err(AssembleError { missing_levels });
        }
        let mut grad = vec![0.0f32; self.dim];
        let mut loss = 0.0;
        for slot in self.slots.iter().flatten() {
            loss += slot.loss_delta;
            for (g, &s) in grad.iter_mut().zip(&slot.grad) {
                *g += s;
            }
        }
        Ok((loss, grad))
    }

    /// Panicking form of [`GradientCache::try_assemble`] for callers that
    /// have already guaranteed completeness (the trainer refreshes all
    /// levels at `t = 0` before ever assembling).
    pub fn assemble(&self) -> (f64, Vec<f32>) {
        match self.try_assemble() {
            Ok(out) => out,
            Err(e) => panic!("{e}"),
        }
    }

    /// Max staleness across levels (diagnostics / metrics).
    pub fn max_staleness(&self, now: u64) -> u64 {
        (0..=self.lmax())
            .filter_map(|l| self.staleness(l, now))
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled(lmax: usize, dim: usize) -> GradientCache {
        let mut c = GradientCache::new(lmax, dim);
        for l in 0..=lmax {
            c.update(l, 0, 1.0, vec![l as f32; dim]);
        }
        c
    }

    #[test]
    fn assemble_sums_components() {
        let c = filled(2, 3);
        let (loss, grad) = c.assemble();
        assert_eq!(loss, 3.0);
        assert_eq!(grad, vec![3.0, 3.0, 3.0]); // 0 + 1 + 2
    }

    #[test]
    fn staleness_tracks_refresh() {
        let mut c = filled(2, 1);
        c.update(1, 4, 0.0, vec![0.0]);
        assert_eq!(c.staleness(0, 6), Some(6));
        assert_eq!(c.staleness(1, 6), Some(2));
        assert_eq!(c.max_staleness(6), 6);
        assert_eq!(c.refreshed_at(1), Some(4));
    }

    #[test]
    fn incomplete_cache_reports() {
        let mut c = GradientCache::new(3, 2);
        assert!(!c.is_complete());
        assert_eq!(c.staleness(0, 5), None);
        for l in 0..=3 {
            c.update(l, 0, 0.0, vec![0.0, 0.0]);
        }
        assert!(c.is_complete());
    }

    #[test]
    #[should_panic(expected = "unpopulated")]
    fn assemble_incomplete_panics() {
        GradientCache::new(1, 1).assemble();
    }

    #[test]
    fn try_assemble_names_exactly_the_missing_levels() {
        let mut c = GradientCache::new(3, 2);
        c.update(0, 0, 1.0, vec![1.0, 1.0]);
        c.update(2, 0, 2.0, vec![2.0, 2.0]);
        let err = c.try_assemble().unwrap_err();
        assert_eq!(err.missing_levels, vec![1, 3]);
        let msg = err.to_string();
        assert!(msg.contains("unpopulated"), "{msg}");
        assert!(msg.contains("[1, 3]"), "{msg}");
        // the error type is a real std error
        let _: &dyn std::error::Error = &err;
        // filling the gaps turns the same cache assemblable
        c.update(1, 0, 0.0, vec![0.0, 0.0]);
        c.update(3, 0, 0.0, vec![0.0, 0.0]);
        let (loss, grad) = c.try_assemble().unwrap();
        assert_eq!(loss, 3.0);
        assert_eq!(grad, vec![3.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "monotone")]
    fn non_monotone_refresh_panics() {
        let mut c = filled(1, 1);
        c.update(0, 5, 0.0, vec![0.0]);
        c.update(0, 3, 0.0, vec![0.0]);
    }

    #[test]
    #[should_panic(expected = "dim mismatch")]
    fn wrong_dim_panics() {
        let mut c = GradientCache::new(1, 2);
        c.update(0, 0, 0.0, vec![0.0]);
    }

    #[test]
    fn update_replaces_component() {
        let mut c = filled(1, 2);
        c.update(0, 7, -2.0, vec![10.0, 10.0]);
        let (loss, grad) = c.assemble();
        assert_eq!(loss, -1.0); // -2 + 1
        assert_eq!(grad, vec![11.0, 11.0]);
    }
}
