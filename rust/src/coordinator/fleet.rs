//! Multi-problem serving fleet: ONE resident [`WorkerPool`] multiplexing
//! N independent [`Trainer`]s.
//!
//! The paper's point (arXiv:2310.02402) is that delayed MLMC shrinks
//! *per-iteration parallel complexity*; that win only compounds when the
//! freed worker slots are immediately reusable — i.e. when many
//! independent SGD problems share one parallel machine (one hedging
//! problem per portfolio, the ROADMAP's production shape). This module
//! is that sharing layer:
//!
//! * **Sessions** — [`FleetCoordinator::submit`] takes a
//!   [`TrainerBuilder`] per problem and returns a [`SessionId`] handle;
//!   [`poll`](FleetCoordinator::poll) reports progress,
//!   [`drain`](FleetCoordinator::drain) runs everything to completion
//!   and returns per-session [`FleetRun`]s.
//! * **Cross-problem batching** — each [`tick`](FleetCoordinator::tick)
//!   co-schedules one SGD step from *every* running session into a
//!   single pool dispatch: every session's due level jobs are sharded
//!   into [`ChunkTask`]s with the usual coupled-row-work LPT weights,
//!   rebased onto globally unique group indices, and pushed through the
//!   shared LPT queue together — same-level chunks of different problems
//!   interleave freely across the `P` workers.
//! * **Fair-share + backpressure** — one step per running session per
//!   tick is fair-share by construction (no session can starve another);
//!   `max_active` bounds how many sessions step concurrently (the rest
//!   queue and are admitted as others finish) and `max_pending` makes
//!   `submit` fail fast when the fleet is oversubscribed.
//! * **Per-problem bit-exactness** — a session's chunk batches are pure
//!   functions of its own `(seed, step, level, chunk)` address
//!   (counter-based RNG), its groups are reduced independently in
//!   ascending chunk order ([`WorkerPool::execute`]), and the apply half
//!   of the step is the same [`Trainer`] code path as a solo run. Every
//!   problem's gradient — and hence its whole trajectory — is
//!   bit-identical to its solo sequential run at every fleet size and
//!   worker count, chaos delays included (tested in
//!   `tests/fleet_exec.rs`).
//! * **Per-problem telemetry** — the shared dispatch's
//!   [`StepExecReport`] is re-attributed per session via
//!   [`StepExecReport::slice_groups`], so each problem sees its own
//!   busy time, task counts and share-of-fleet utilization per step.
//!
//! ```no_run
//! use dmlmc::config::ExperimentConfig;
//! use dmlmc::coordinator::{FleetCoordinator, Method, TrainerBuilder};
//!
//! let cfg = ExperimentConfig::smoke();
//! let mut fleet = FleetCoordinator::new(4); // one pool, 4 workers
//! let a = fleet.submit("bs", TrainerBuilder::new(&cfg).method(Method::Dmlmc))?;
//! let b = fleet.submit(
//!     "heston",
//!     TrainerBuilder::new(&cfg).method(Method::Dmlmc).scenario("heston-uo-call"),
//! )?;
//! while fleet.poll(a).is_some_and(|s| !s.is_done()) {
//!     fleet.tick()?; // one co-scheduled step of every running session
//! }
//! let runs = fleet.drain()?; // finish b (and any others), collect results
//! assert_eq!(runs.len(), 2);
//! # let _ = b;
//! # anyhow::Ok(())
//! ```

use std::ops::Range;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use super::dispatcher::{chunk_tasks, grad_chunk_at, LevelJobSpec, LevelResult};
use super::method::Method;
use super::trainer::{Trainer, TrainerBuilder};
use crate::exec::{ChunkTask, ExecStats, StepExecReport, WorkerPool};
use crate::hedging::Problem;
use crate::metrics::{CurvePoint, LearningCurve};
use crate::obs::{estimator, GroupMeta, LevelSnapshot, Recorder};
use crate::rng::{brownian::Purpose, BrownianSource};
use crate::runtime::SharedBackend;

/// Opaque handle to a submitted session, returned by
/// [`FleetCoordinator::submit`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct SessionId(pub usize);

/// Where a session is in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionState {
    /// Submitted, waiting for an admission slot (`max_active`).
    Queued,
    /// Stepping — participates in every tick's shared dispatch.
    Running,
    /// All steps done; result available via [`FleetCoordinator::drain`].
    Done,
}

/// Snapshot of one session's progress ([`FleetCoordinator::poll`]).
#[derive(Debug, Clone)]
pub struct SessionStatus {
    pub id: SessionId,
    pub name: String,
    pub state: SessionState,
    /// Steps completed so far.
    pub steps_done: u64,
    /// Total steps this session will run.
    pub steps_total: u64,
}

impl SessionStatus {
    pub fn is_done(&self) -> bool {
        self.state == SessionState::Done
    }
}

/// Deep per-session snapshot for the serving surface
/// ([`FleetCoordinator::session_detail`], rendered as
/// `GET /sessions/<id>` by `repro serve`): progress, last evaluated
/// loss, the per-level chunk layout, and the live estimator statistics.
#[derive(Debug, Clone)]
pub struct SessionDetail {
    pub status: SessionStatus,
    pub method: Method,
    pub seed: u64,
    /// Effective scenario key (with any `-simd` suffix applied).
    pub scenario: String,
    /// Loss at the most recent eval point (`None` before admission).
    pub last_loss: Option<f64>,
    /// Chunks per level refresh (the level layout).
    pub chunks_per_level: Vec<usize>,
    /// Per-level estimator statistics at the session's current step.
    pub levels: Vec<LevelSnapshot>,
}

/// One finished session's results, handed out by
/// [`FleetCoordinator::drain`].
#[derive(Debug, Clone)]
pub struct FleetRun {
    pub id: SessionId,
    pub name: String,
    pub method: Method,
    pub seed: u64,
    /// The learning curve, on the same eval grid as a solo
    /// [`Trainer::run`] (bit-identical to it, in fact).
    pub curve: LearningCurve,
    /// Final model parameters.
    pub final_params: Vec<f32>,
    /// Per-step, per-problem execution reports: this session's slice of
    /// each shared dispatch (its tasks/busy time under the shared
    /// makespan).
    pub reports: Vec<StepExecReport>,
}

/// What one pool task needs to know about the session it came from. One
/// entry per reduction group; the dispatch closure routes `task.group`
/// here. Everything is owned/`Copy`/`Arc` because the resident workers
/// need a `'static` job.
struct GroupCtx {
    backend: SharedBackend,
    problem: Problem,
    src: BrownianSource,
    step: u64,
    params: Arc<[f32]>,
    kind: GroupKind,
}

enum GroupKind {
    /// A level job's chunks — routed through the dispatcher's
    /// [`grad_chunk_at`], exactly like solo pooled dispatch.
    Coupled,
    /// A naive finest-grid refresh — mirrors `Trainer::naive_gradient`'s
    /// pooled path (no coupling, so no coarse half).
    Naive { batch: usize, n_steps: usize, dt: f64 },
}

/// One session's share of a tick: which global groups are its, and how
/// to turn their reductions back into a step.
struct Plan {
    sess: usize,
    groups: Range<usize>,
    /// `Some(jobs)` for MLMC/DMLMC (one group per level job), `None` for
    /// a naive session (one group total).
    jobs: Option<Vec<LevelJobSpec>>,
}

struct Session {
    id: SessionId,
    name: String,
    trainer: Trainer,
    backend: SharedBackend,
    src: BrownianSource,
    /// Next step to run.
    t: u64,
    steps: u64,
    curve: LearningCurve,
    reports: Vec<StepExecReport>,
    state: SessionState,
    /// Recorder-epoch offset at which the session was admitted — `Some`
    /// only under tracing; closes the `session` span at `Done`.
    admitted_at: Option<Duration>,
}

/// The serving fleet: one resident [`WorkerPool`] shared by N trainers.
/// See the module docs for the scheduling/bit-exactness contract.
pub struct FleetCoordinator {
    pool: WorkerPool,
    sessions: Vec<Session>,
    next_id: usize,
    max_active: usize,
    max_pending: usize,
    ticks: usize,
    /// Span recorder + metrics registry — `Some` only after
    /// [`enable_tracing`](Self::enable_tracing). Ingestion happens
    /// coordinator-side after each multiplexed dispatch returns.
    recorder: Option<Recorder>,
}

impl FleetCoordinator {
    /// A fleet over a fresh resident pool of `workers` threads, with no
    /// admission/submission limits (see [`with_limits`](Self::with_limits)).
    pub fn new(workers: usize) -> Self {
        Self::with_limits(workers, usize::MAX, usize::MAX)
    }

    /// Like [`new`](Self::new) with explicit oversubscription bounds:
    /// at most `max_active` sessions step concurrently (the rest queue),
    /// and `submit` errors once `max_pending` sessions are queued or
    /// running (backpressure — callers must drain before submitting
    /// more).
    pub fn with_limits(workers: usize, max_active: usize, max_pending: usize) -> Self {
        FleetCoordinator {
            pool: WorkerPool::new(workers),
            sessions: Vec::new(),
            next_id: 0,
            max_active: max_active.max(1),
            max_pending: max_pending.max(1),
            ticks: 0,
            recorder: None,
        }
    }

    /// Enable span tracing: subsequent ticks record `tick`, `dispatch`
    /// and `session` spans plus per-task spans on the shared pool's
    /// worker tracks, each attributed to its owning session. Idempotent;
    /// retrieve the trace with [`take_recorder`](Self::take_recorder).
    pub fn enable_tracing(&mut self) {
        if self.recorder.is_none() {
            let mut rec = Recorder::new(self.pool.workers());
            {
                let mut m = rec.metrics_mut();
                m.set_gauge("dmlmc_pool_workers", self.pool.workers() as f64);
                // Fleet gauges exist (at rest) from the first scrape.
                Self::publish_fleet_gauges(&mut m, &self.sessions, None);
            }
            self.recorder = Some(rec);
        }
    }

    /// The span recorder — `Some` only after
    /// [`enable_tracing`](Self::enable_tracing).
    pub fn recorder(&self) -> Option<&Recorder> {
        self.recorder.as_ref()
    }

    /// Detach the recorder for export; subsequent ticks record nothing.
    pub fn take_recorder(&mut self) -> Option<Recorder> {
        self.recorder.take()
    }

    /// The shared pool's worker count.
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Cumulative execution stats of the shared pool (one record per
    /// fleet tick — a tick is one multiplexed dispatch).
    pub fn exec_stats(&self) -> &ExecStats {
        self.pool.stats()
    }

    /// Ticks executed so far.
    pub fn ticks(&self) -> usize {
        self.ticks
    }

    /// Sessions not yet done (queued + running).
    pub fn pending_sessions(&self) -> usize {
        self.sessions
            .iter()
            .filter(|s| s.state != SessionState::Done)
            .count()
    }

    /// Forward deterministic chaos-delay injection to the shared pool
    /// (scheduling perturbation for determinism tests; 0 disables).
    pub fn set_chaos_delays(&mut self, seed: u64, max_micros: u64) {
        self.pool.set_chaos_delays(seed, max_micros);
    }

    /// Submit a problem to the fleet. The builder is forced to
    /// [`TrainerBuilder::without_local_pool`] — fleet sessions dispatch
    /// through the ONE shared pool. Errors when the builder fails, when
    /// the backend is not shareable (PJRT), or when the fleet is
    /// oversubscribed (`max_pending`).
    pub fn submit(&mut self, name: &str, builder: TrainerBuilder) -> Result<SessionId> {
        let pending = self.pending_sessions();
        if pending >= self.max_pending {
            if let Some(rec) = self.recorder.as_mut() {
                rec.metrics_mut().inc("dmlmc_sessions_rejected_total", 1);
            }
            bail!(
                "fleet oversubscribed: {pending} sessions queued/running >= \
                 max_pending {}; drain (or poll to completion) before \
                 submitting more",
                self.max_pending
            );
        }
        let trainer = builder.without_local_pool().build()?;
        let backend = trainer.shared_backend().ok_or_else(|| {
            anyhow!(
                "fleet sessions need a shareable backend (native engine): the \
                 PJRT runtime's !Send handles cannot co-own the shared pool's \
                 'static dispatch closures"
            )
        })?;
        let id = SessionId(self.next_id);
        self.next_id += 1;
        let steps = trainer.cfg.train.steps as u64;
        let src = trainer.brownian_src();
        let curve = LearningCurve::new(trainer.method.name(), trainer.seed);
        self.sessions.push(Session {
            id,
            name: name.to_string(),
            trainer,
            backend,
            src,
            t: 0,
            steps,
            curve,
            reports: Vec::new(),
            state: SessionState::Queued,
            admitted_at: None,
        });
        Ok(id)
    }

    fn status_of(s: &Session) -> SessionStatus {
        SessionStatus {
            id: s.id,
            name: s.name.clone(),
            state: s.state,
            steps_done: s.t,
            steps_total: s.steps,
        }
    }

    /// Progress snapshot for a session; `None` once drained (or never
    /// submitted).
    pub fn poll(&self, id: SessionId) -> Option<SessionStatus> {
        self.sessions
            .iter()
            .find(|s| s.id == id)
            .map(Self::status_of)
    }

    /// Progress snapshots for every session still held by the fleet
    /// (submission order) — the `/status` listing of `repro serve`.
    pub fn statuses(&self) -> Vec<SessionStatus> {
        self.sessions.iter().map(Self::status_of).collect()
    }

    /// Deep snapshot of one session (progress + level layout + live
    /// estimator statistics); `None` once drained or never submitted.
    pub fn session_detail(&self, id: SessionId) -> Option<SessionDetail> {
        self.sessions.iter().find(|s| s.id == id).map(|s| SessionDetail {
            status: Self::status_of(s),
            method: s.trainer.method,
            seed: s.trainer.seed,
            scenario: s.trainer.cfg.effective_scenario(),
            last_loss: s.curve.points.last().map(|p| p.loss),
            chunks_per_level: s.trainer.chunks_per_level().to_vec(),
            levels: s.trainer.estimator().snapshot(s.t.saturating_sub(1)),
        })
    }

    /// Admit queued sessions (submission order) while there is an
    /// `max_active` slot free; each admission records the step-0 eval
    /// point, exactly like [`Trainer::run`]'s preamble.
    fn admit(&mut self) -> Result<()> {
        let now = self.recorder.as_ref().map(|r| r.now());
        let mut running = self
            .sessions
            .iter()
            .filter(|s| s.state == SessionState::Running)
            .count();
        for i in 0..self.sessions.len() {
            if running >= self.max_active {
                break;
            }
            if self.sessions[i].state != SessionState::Queued {
                continue;
            }
            let loss0 = self.sessions[i].trainer.eval_loss()?;
            let s = &mut self.sessions[i];
            s.curve.push(CurvePoint {
                step: 0,
                loss: loss0,
                std_cost: 0.0,
                par_cost: 0.0,
                grad_norm: 0.0,
            });
            let sid = s.id.0 as f64;
            if s.steps == 0 {
                s.state = SessionState::Done;
                if let Some(rec) = self.recorder.as_mut() {
                    rec.metrics_mut().inc("dmlmc_sessions_admitted_total", 1);
                    rec.record_span(
                        "session",
                        now.unwrap_or_default(),
                        Duration::ZERO,
                        vec![("session", sid), ("steps", 0.0)],
                    );
                }
                continue;
            }
            s.state = SessionState::Running;
            s.admitted_at = now;
            if let Some(rec) = self.recorder.as_mut() {
                rec.metrics_mut().inc("dmlmc_sessions_admitted_total", 1);
            }
            running += 1;
        }
        Ok(())
    }

    /// Run one fleet tick: admit what fits, co-schedule one SGD step
    /// from every running session into a single shared-pool dispatch,
    /// then apply each session's reductions through the regular trainer
    /// step tail. Returns the number of sessions stepped (0 when
    /// nothing is running).
    ///
    /// On error (a failing chunk task) no session is advanced.
    pub fn tick(&mut self) -> Result<usize> {
        self.admit()?;
        let tick_start = self.recorder.as_ref().map(|r| r.now());

        // Plan: shard every running session's due work, rebasing group
        // indices so the multiplexed dispatch reduces each problem's
        // groups independently (the bit-exactness invariant).
        let mut tasks: Vec<ChunkTask> = Vec::new();
        let mut ctxs: Vec<GroupCtx> = Vec::new();
        let mut metas: Vec<GroupMeta> = Vec::new();
        let mut plans: Vec<Plan> = Vec::new();
        // Per group: (owning session index, Some(level) for a coupled
        // level job / None for naive) — routes measured per-task cost
        // back to the owning session's estimator statistics.
        let mut group_owner: Vec<(usize, Option<usize>)> = Vec::new();
        for (idx, s) in self.sessions.iter_mut().enumerate() {
            if s.state != SessionState::Running {
                continue;
            }
            let t = s.t;
            // Re-observe the session's policy at the same point of the
            // step a solo trainer would (before job planning), so fleet
            // adaptation applies from this tick's dispatch onward.
            s.trainer.maybe_adapt(t);
            let params: Arc<[f32]> = Arc::from(s.trainer.params.as_slice());
            let problem = *s.backend.problem();
            let base = ctxs.len();
            match s.trainer.method {
                Method::Naive => {
                    let batch = s.backend.naive_chunk();
                    let n_steps = problem.n_steps(problem.lmax);
                    // finest grid only, no coupling — no coarse half
                    let weight = batch as f64 * n_steps as f64;
                    for chunk in 0..s.trainer.naive_chunks() {
                        tasks.push(ChunkTask {
                            group: base,
                            chunk,
                            level: problem.lmax,
                            weight,
                        });
                    }
                    ctxs.push(GroupCtx {
                        backend: s.backend.clone(),
                        problem,
                        src: s.src,
                        step: t,
                        params,
                        kind: GroupKind::Naive {
                            batch,
                            n_steps,
                            dt: problem.dt(problem.lmax),
                        },
                    });
                    metas.push(GroupMeta {
                        level: problem.lmax,
                        session: Some(s.id.0 as u64),
                    });
                    group_owner.push((idx, None));
                    plans.push(Plan { sess: idx, groups: base..base + 1, jobs: None });
                }
                Method::Mlmc | Method::Dmlmc => {
                    let jobs = s.trainer.jobs_for_step(t);
                    let mut local = chunk_tasks(&*s.backend, &problem, &jobs);
                    for task in &mut local {
                        task.group += base;
                    }
                    tasks.extend(local);
                    for job in &jobs {
                        ctxs.push(GroupCtx {
                            backend: s.backend.clone(),
                            problem,
                            src: s.src,
                            step: t,
                            params: params.clone(),
                            kind: GroupKind::Coupled,
                        });
                        metas.push(GroupMeta {
                            level: job.level,
                            session: Some(s.id.0 as u64),
                        });
                        group_owner.push((idx, Some(job.level)));
                    }
                    plans.push(Plan {
                        sess: idx,
                        groups: base..base + jobs.len(),
                        jobs: Some(jobs),
                    });
                }
            }
        }
        if plans.is_empty() {
            return Ok(0);
        }

        // One dispatch for the whole fleet tick. The closure routes each
        // task to its group's session context; per-group reduction in
        // ascending chunk order happens inside the pool, per problem.
        let n_groups = ctxs.len();
        let (reduced, report) =
            self.pool.execute(&tasks, n_groups, move |task: &ChunkTask| {
                let ctx = &ctxs[task.group];
                match ctx.kind {
                    GroupKind::Coupled => grad_chunk_at(
                        &*ctx.backend,
                        &ctx.problem,
                        &ctx.src,
                        ctx.step,
                        task.level,
                        task.chunk,
                        &ctx.params,
                    ),
                    GroupKind::Naive { batch, n_steps, dt } => {
                        let dw = ctx.src.increments_multi(
                            Purpose::Grad,
                            ctx.step,
                            task.level as u32,
                            task.chunk as u32,
                            batch,
                            n_steps,
                            dt,
                            ctx.backend.n_factors(),
                        );
                        ctx.backend.grad_naive_chunk(&ctx.params, &dw)
                    }
                }
            })?;
        if let (Some(rec), Some(start)) = (self.recorder.as_mut(), tick_start) {
            rec.ingest_dispatch(&report, start, &metas);
        }
        // Attribute measured per-task cost to each owning session's
        // estimator statistics (coupled level jobs only, mirroring the
        // solo trainer path: naive finest-grid tasks carry no
        // level-difference meaning).
        for stat in &report.per_task {
            if let (sess, Some(level)) = group_owner[stat.group] {
                self.sessions[sess]
                    .trainer
                    .estimator_mut()
                    .record_cost(level, stat.busy.as_secs_f64());
            }
        }
        let mut reduced: Vec<Option<(f64, Vec<f32>)>> =
            reduced.into_iter().map(Some).collect();

        // Apply: each session consumes its group range through the same
        // step tail a solo trainer runs, and records its slice of the
        // shared dispatch report.
        let mut stepped = 0;
        for plan in plans {
            let s = &mut self.sessions[plan.sess];
            let t = s.t;
            let per_problem = report.slice_groups(plan.groups.clone());
            let (_cost, gnorm) = match plan.jobs {
                Some(jobs) => {
                    let results: Vec<LevelResult> = jobs
                        .iter()
                        .zip(plan.groups.clone())
                        .map(|(&spec, group)| {
                            let (loss_delta, grad) =
                                reduced[group].take().expect("group reduced once");
                            LevelResult {
                                level: spec.level,
                                loss_delta,
                                grad,
                                n_samples: spec.n_chunks
                                    * s.backend.grad_chunk(spec.level),
                            }
                        })
                        .collect();
                    s.trainer.apply_level_results(t, results)
                }
                None => {
                    let (_loss, grad) = reduced[plan.groups.start]
                        .take()
                        .expect("group reduced once");
                    s.trainer.apply_naive_result(t, grad)
                }
            };
            s.reports.push(per_problem);
            let next = t + 1;
            s.t = next;
            stepped += 1;
            let eval_every = s.trainer.cfg.train.eval_every as u64;
            if next % eval_every == 0 || next == s.steps {
                let loss = s.trainer.eval_loss()?;
                let cum = s.trainer.cumulative_cost();
                s.curve.push(CurvePoint {
                    step: next as usize,
                    loss,
                    std_cost: cum.work,
                    par_cost: cum.depth,
                    grad_norm: gnorm,
                });
            }
            if next >= s.steps {
                s.state = SessionState::Done;
                let sid = s.id.0 as f64;
                let total = s.steps as f64;
                let admitted = s.admitted_at.take();
                if let Some(rec) = self.recorder.as_mut() {
                    // Session span: admission to completion, closed now.
                    let start = admitted.unwrap_or_default();
                    let dur = rec.now().saturating_sub(start);
                    rec.record_span(
                        "session",
                        start,
                        dur,
                        vec![("session", sid), ("steps", total)],
                    );
                }
            }
        }
        let tick_idx = self.ticks as f64;
        if let (Some(rec), Some(start)) = (self.recorder.as_mut(), tick_start) {
            {
                let mut m = rec.metrics_mut();
                m.inc("dmlmc_ticks_total", 1);
                Self::publish_fleet_gauges(&mut m, &self.sessions, Some(&report));
                // Per-session estimator statistics, attributed by a
                // `session="<id>"` label so N sessions share one scrape.
                for s in &self.sessions {
                    if s.state == SessionState::Queued {
                        continue;
                    }
                    let sid = s.id.0.to_string();
                    s.trainer
                        .estimator()
                        .publish(&mut m, Some(&sid), s.t.saturating_sub(1));
                    estimator::publish_decision(
                        &mut m,
                        Some(&sid),
                        &s.trainer.decision().allocation.n_per_level,
                        s.trainer.schedule_periods(),
                    );
                }
            }
            rec.record(
                "tick",
                start,
                vec![("tick", tick_idx), ("sessions", stepped as f64)],
            );
        }
        self.ticks += 1;
        Ok(stepped)
    }

    /// Fleet-level gauges: session states and, when a dispatch report is
    /// in hand, the pool utilization of the last tick (sum of worker
    /// busy over makespan x workers).
    fn publish_fleet_gauges(
        m: &mut crate::obs::Registry,
        sessions: &[Session],
        report: Option<&StepExecReport>,
    ) {
        m.describe("fleet_sessions_active", "Sessions currently stepping.");
        m.describe("fleet_sessions_pending", "Sessions queued for admission.");
        m.describe("fleet_sessions_done", "Sessions completed and awaiting drain.");
        m.describe(
            "fleet_pool_utilization",
            "Worker busy fraction of the last tick's shared dispatch.",
        );
        let count = |state: SessionState| {
            sessions.iter().filter(|s| s.state == state).count() as f64
        };
        m.set_gauge("fleet_sessions_active", count(SessionState::Running));
        m.set_gauge("fleet_sessions_pending", count(SessionState::Queued));
        m.set_gauge("fleet_sessions_done", count(SessionState::Done));
        if let Some(report) = report {
            let busy: f64 = report.workers.iter().map(|w| w.busy.as_secs_f64()).sum();
            let denom =
                report.makespan.as_secs_f64() * report.workers.len().max(1) as f64;
            let util = if denom > 0.0 { (busy / denom).min(1.0) } else { 0.0 };
            m.set_gauge("fleet_pool_utilization", util);
        }
    }

    /// Tick until every session is done, then hand out all results (the
    /// fleet is empty afterwards; handles from before the drain no
    /// longer poll). Results are in submission order.
    pub fn drain(&mut self) -> Result<Vec<FleetRun>> {
        while self.sessions.iter().any(|s| s.state != SessionState::Done) {
            let stepped = self.tick()?;
            if stepped == 0
                && self.sessions.iter().any(|s| s.state != SessionState::Done)
            {
                bail!("fleet made no progress with unfinished sessions");
            }
        }
        Ok(self
            .sessions
            .drain(..)
            .map(|s| FleetRun {
                id: s.id,
                name: s.name,
                method: s.trainer.method,
                seed: s.trainer.seed,
                final_params: s.trainer.params.clone(),
                curve: s.curve,
                reports: s.reports,
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::smoke();
        cfg.train.steps = 4;
        cfg.train.eval_every = 2;
        cfg
    }

    #[test]
    fn two_session_fleet_matches_solo_runs_bitwise() {
        let cfg = cfg();
        let mut solo_a = Trainer::from_config(&cfg, Method::Dmlmc, 1).unwrap();
        let curve_a = solo_a.run().unwrap();
        let mut solo_b = Trainer::from_config(&cfg, Method::Mlmc, 2).unwrap();
        let curve_b = solo_b.run().unwrap();

        let mut fleet = FleetCoordinator::new(3);
        let a = fleet
            .submit("a", TrainerBuilder::new(&cfg).method(Method::Dmlmc).seed(1))
            .unwrap();
        let b = fleet
            .submit("b", TrainerBuilder::new(&cfg).method(Method::Mlmc).seed(2))
            .unwrap();
        let runs = fleet.drain().unwrap();
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].id, a);
        assert_eq!(runs[1].id, b);
        assert_eq!(runs[0].final_params, solo_a.params);
        assert_eq!(runs[1].final_params, solo_b.params);
        for (p, q) in runs[0].curve.points.iter().zip(&curve_a.points) {
            assert_eq!(p.loss, q.loss);
            assert_eq!(p.grad_norm, q.grad_norm);
        }
        for (p, q) in runs[1].curve.points.iter().zip(&curve_b.points) {
            assert_eq!(p.loss, q.loss);
        }
    }

    #[test]
    fn per_problem_reports_cover_every_step() {
        let cfg = cfg();
        let mut fleet = FleetCoordinator::new(2);
        fleet
            .submit("a", TrainerBuilder::new(&cfg).method(Method::Dmlmc).seed(0))
            .unwrap();
        fleet
            .submit("b", TrainerBuilder::new(&cfg).method(Method::Naive).seed(0))
            .unwrap();
        let runs = fleet.drain().unwrap();
        for run in &runs {
            assert_eq!(run.reports.len(), cfg.train.steps);
            for r in &run.reports {
                assert!(r.n_tasks > 0, "{}: empty per-problem report", run.name);
                let executed: usize = r.workers.iter().map(|w| w.tasks).sum();
                assert_eq!(executed, r.n_tasks);
            }
        }
        // every tick was one shared dispatch
        assert_eq!(fleet.exec_stats().steps, cfg.train.steps);
        assert_eq!(fleet.ticks(), cfg.train.steps);
    }

    #[test]
    fn poll_tracks_lifecycle_and_drain_empties() {
        let cfg = cfg();
        let mut fleet = FleetCoordinator::new(2);
        let id = fleet
            .submit("a", TrainerBuilder::new(&cfg).method(Method::Dmlmc))
            .unwrap();
        let st = fleet.poll(id).unwrap();
        assert_eq!(st.state, SessionState::Queued);
        assert_eq!(st.steps_total, cfg.train.steps as u64);
        fleet.tick().unwrap();
        let st = fleet.poll(id).unwrap();
        assert_eq!(st.state, SessionState::Running);
        assert_eq!(st.steps_done, 1);
        fleet.drain().unwrap();
        assert!(fleet.poll(id).is_none(), "drained handles no longer poll");
        assert_eq!(fleet.pending_sessions(), 0);
    }

    #[test]
    fn backpressure_rejects_oversubscription_and_admission_queues() {
        let cfg = cfg();
        let mut fleet = FleetCoordinator::with_limits(2, 1, 2);
        let a = fleet
            .submit("a", TrainerBuilder::new(&cfg).method(Method::Dmlmc))
            .unwrap();
        let b = fleet
            .submit("b", TrainerBuilder::new(&cfg).method(Method::Dmlmc))
            .unwrap();
        let err = fleet
            .submit("c", TrainerBuilder::new(&cfg).method(Method::Dmlmc))
            .unwrap_err();
        assert!(format!("{err:#}").contains("oversubscribed"), "{err:#}");
        // max_active = 1: b stays queued while a runs...
        fleet.tick().unwrap();
        assert_eq!(fleet.poll(a).unwrap().state, SessionState::Running);
        assert_eq!(fleet.poll(b).unwrap().state, SessionState::Queued);
        // ...and is admitted once a finishes.
        let runs = fleet.drain().unwrap();
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[1].curve.points.first().unwrap().step, 0);
    }

    #[test]
    fn traced_fleet_records_tick_and_session_spans() {
        let cfg = cfg();
        let mut fleet = FleetCoordinator::with_limits(2, 1, 2);
        fleet.enable_tracing();
        fleet
            .submit("a", TrainerBuilder::new(&cfg).method(Method::Dmlmc).seed(1))
            .unwrap();
        fleet
            .submit("b", TrainerBuilder::new(&cfg).method(Method::Naive).seed(2))
            .unwrap();
        let err = fleet
            .submit("c", TrainerBuilder::new(&cfg).method(Method::Dmlmc))
            .unwrap_err();
        assert!(format!("{err:#}").contains("oversubscribed"));
        let runs = fleet.drain().unwrap();
        assert_eq!(runs.len(), 2);
        let ticks = fleet.ticks();
        let rec = fleet.take_recorder().unwrap();
        assert_eq!(rec.metrics().counter("dmlmc_sessions_admitted_total"), 2);
        assert_eq!(rec.metrics().counter("dmlmc_sessions_rejected_total"), 1);
        assert_eq!(rec.metrics().counter("dmlmc_ticks_total") as usize, ticks);
        let names: Vec<&str> =
            rec.coordinator_spans().iter().map(|s| s.name).collect();
        // max_active = 1: a runs ticks 0..4, b ticks 4..8 — serial.
        assert_eq!(names.iter().filter(|n| **n == "tick").count(), 8);
        assert_eq!(names.iter().filter(|n| **n == "dispatch").count(), 8);
        assert_eq!(names.iter().filter(|n| **n == "session").count(), 2);
        // task spans carry their owning session's id
        let attributed = (0..rec.workers()).any(|w| {
            rec.worker_spans(w)
                .iter()
                .any(|s| s.args.iter().any(|&(k, _)| k == "session"))
        });
        assert!(attributed, "no task span attributed to a session");
    }

    #[test]
    fn tracing_leaves_fleet_trajectories_bitwise_unchanged() {
        let cfg = cfg();
        let run = |trace: bool| {
            let mut fleet = FleetCoordinator::new(3);
            if trace {
                fleet.enable_tracing();
            }
            fleet
                .submit("a", TrainerBuilder::new(&cfg).method(Method::Dmlmc).seed(1))
                .unwrap();
            fleet.drain().unwrap().remove(0)
        };
        let plain = run(false);
        let traced = run(true);
        assert_eq!(plain.final_params, traced.final_params);
        for (p, q) in plain.curve.points.iter().zip(&traced.curve.points) {
            assert_eq!(p.loss, q.loss);
            assert_eq!(p.grad_norm, q.grad_norm);
        }
    }

    #[test]
    fn adaptive_session_publishes_decision_gauges_and_stays_finite() {
        let mut cfg = cfg();
        cfg.train.steps = 8;
        cfg.train.eval_every = 4;
        cfg.adaptive.enabled = true;
        cfg.adaptive.adapt_every = 2;
        let mut fleet = FleetCoordinator::new(2);
        fleet.enable_tracing();
        let id = fleet
            .submit("a", TrainerBuilder::new(&cfg).method(Method::Dmlmc).seed(1))
            .unwrap();
        while fleet.poll(id).is_some_and(|s| !s.is_done()) {
            fleet.tick().unwrap();
        }
        // decisions applied at tick boundaries keep the session healthy
        let detail = fleet.session_detail(id).unwrap();
        assert!(detail.chunks_per_level.iter().sum::<usize>() > 0);
        let rec = fleet.take_recorder().unwrap();
        let text = rec.metrics().render_prometheus();
        assert!(
            text.contains("dmlmc_alloc_n{level=\"0\",session=\"0\"}"),
            "allocation gauge missing:\n{text}"
        );
        assert!(
            text.contains("dmlmc_refresh_period{level=\"0\",session=\"0\"} 1"),
            "period gauge missing:\n{text}"
        );
        let runs = fleet.drain().unwrap();
        assert!(runs[0].curve.points.iter().all(|p| p.loss.is_finite()));
    }

    #[test]
    fn fixed_policy_fleet_ticks_never_adapt() {
        let cfg = cfg();
        let mut fleet = FleetCoordinator::new(2);
        let id = fleet
            .submit("a", TrainerBuilder::new(&cfg).method(Method::Dmlmc))
            .unwrap();
        while fleet.poll(id).is_some_and(|s| !s.is_done()) {
            fleet.tick().unwrap();
        }
        let layouts: Vec<usize> =
            fleet.session_detail(id).unwrap().chunks_per_level;
        let solo = Trainer::from_config(&cfg, Method::Dmlmc, 0).unwrap();
        assert_eq!(layouts, solo.chunks_per_level().to_vec());
        fleet.drain().unwrap();
    }

    #[test]
    fn empty_fleet_tick_is_a_noop() {
        let mut fleet = FleetCoordinator::new(2);
        assert_eq!(fleet.tick().unwrap(), 0);
        assert_eq!(fleet.exec_stats().steps, 0, "no idle dispatch recorded");
        assert!(fleet.drain().unwrap().is_empty());
    }
}
