//! L3 coordinator — the paper's contribution (Algorithm 1).
//!
//! SGD where the level-`l` coupled gradient component is *refreshed* only
//! every `⌊2^{dl}⌋` steps ([`scheduler::DelayedSchedule`]) and otherwise
//! reused from [`cache::GradientCache`]; refreshes for the due levels are
//! independent jobs ([`dispatcher`]) whose parallel cost is accounted as
//! the max depth over the concurrently running levels
//! ([`crate::parallel::cost`]) and — on shareable (`Arc`-held) backends —
//! actually executed across P resident workers by the chunk-sharded pool
//! ([`crate::exec`]), bit-identically to sequential dispatch.
//! [`trainer::Trainer`] ties it
//! together and also implements the two baselines (naive SGD, standard
//! MLMC SGD); trainers are built through [`trainer::TrainerBuilder`]
//! (named setters) or the [`Trainer::from_config`] shorthand.
//!
//! On top of the single-trainer loop sits the **serving fleet**
//! ([`fleet::FleetCoordinator`]): one resident worker pool multiplexing
//! N independent trainers with cross-problem batching, fair-share
//! ticks, backpressure, and per-problem bit-exactness (each session's
//! trajectory is bit-identical to its solo run).

pub mod cache;
pub mod dispatcher;
pub mod fleet;
pub mod method;
pub mod scheduler;
pub mod trainer;

pub use cache::GradientCache;
pub use dispatcher::{
    run_jobs, run_jobs_pool, run_jobs_pool_with_report, run_jobs_threaded,
    LevelJobSpec, LevelResult,
};
pub use fleet::{
    FleetCoordinator, FleetRun, SessionDetail, SessionId, SessionState, SessionStatus,
};
pub use method::Method;
pub use scheduler::DelayedSchedule;
pub use trainer::{Trainer, TrainerBuilder};
