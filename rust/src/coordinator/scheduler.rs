//! The delayed-refresh schedule of Algorithm 1.
//!
//! Level `l` is refreshed at step `t` iff `t ≡ 0 (mod ⌊2^{dl}⌋)`; between
//! refreshes the cached component from `τ_l(t) = t - (t mod ⌊2^{dl}⌋)` is
//! reused. `d` is the delay exponent (paper: `d = 1`, matched to the
//! smoothness decay of Assumption 3). `d = 0` degenerates to standard
//! MLMC (every level refreshed every step).

/// Refresh schedule for levels `0..=lmax`.
#[derive(Debug, Clone)]
pub struct DelayedSchedule {
    periods: Vec<u64>,
    pub d: f64,
}

impl DelayedSchedule {
    pub fn new(lmax: usize, d: f64) -> Self {
        assert!(d >= 0.0, "delay exponent must be non-negative");
        let periods = (0..=lmax)
            .map(|l| (2f64.powf(d * l as f64).floor() as u64).max(1))
            .collect();
        DelayedSchedule { periods, d }
    }

    /// Standard MLMC: refresh everything every step.
    pub fn every_step(lmax: usize) -> Self {
        DelayedSchedule::new(lmax, 0.0)
    }

    /// Build from explicit per-level periods (one per level `0..=lmax`,
    /// each clamped to `>= 1`; level 0 is forced to period 1 so it stays
    /// due every step). This is the [`crate::policy`] entry point: an
    /// adaptive policy hands back measured periods instead of the
    /// `⌊2^{dl}⌋` theory curve. `d` is kept purely as a diagnostic label
    /// and is reported as the exponent that matches `periods[1]` (or 0
    /// for a single-level / every-step schedule).
    pub fn with_periods(periods: Vec<u64>) -> Self {
        assert!(!periods.is_empty(), "need at least level 0");
        let mut periods: Vec<u64> = periods.iter().map(|&p| p.max(1)).collect();
        periods[0] = 1;
        let d = if periods.len() > 1 {
            (periods[1] as f64).log2()
        } else {
            0.0
        };
        DelayedSchedule { periods, d }
    }

    pub fn lmax(&self) -> usize {
        self.periods.len() - 1
    }

    /// `⌊2^{dl}⌋` (clamped to >= 1).
    pub fn period(&self, level: usize) -> u64 {
        self.periods[level]
    }

    /// All per-level periods (what [`crate::policy`] decisions compare
    /// and the gauges publish).
    pub fn periods(&self) -> &[u64] {
        &self.periods
    }

    /// Does step `t` refresh level `level`?
    pub fn is_due(&self, t: u64, level: usize) -> bool {
        t % self.period(level) == 0
    }

    /// The most recent refresh step `τ_l(t) <= t`.
    pub fn tau(&self, t: u64, level: usize) -> u64 {
        t - t % self.period(level)
    }

    /// All levels due at step `t` (level 0 is always due).
    pub fn levels_due(&self, t: u64) -> Vec<usize> {
        (0..=self.lmax()).filter(|&l| self.is_due(t, l)).collect()
    }

    /// Average number of refreshes of level `l` per step over a horizon —
    /// the `2^{-dl}` factor in the paper's average parallel complexity.
    pub fn refresh_rate(&self, level: usize) -> f64 {
        1.0 / self.period(level) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_periods_d1() {
        let s = DelayedSchedule::new(6, 1.0);
        for l in 0..=6 {
            assert_eq!(s.period(l), 1u64 << l);
        }
    }

    #[test]
    fn fractional_d_floors() {
        let s = DelayedSchedule::new(4, 0.5);
        // floor(2^{0.5 l}) = 1, 1, 2, 2, 4
        assert_eq!(
            (0..=4).map(|l| s.period(l)).collect::<Vec<_>>(),
            vec![1, 1, 2, 2, 4]
        );
    }

    #[test]
    fn d_zero_is_standard_mlmc() {
        let s = DelayedSchedule::every_step(6);
        for t in 0..100 {
            assert_eq!(s.levels_due(t).len(), 7);
        }
    }

    #[test]
    fn level0_always_due() {
        let s = DelayedSchedule::new(6, 1.3);
        for t in 0..1000 {
            assert!(s.is_due(t, 0));
        }
    }

    #[test]
    fn tau_properties() {
        let s = DelayedSchedule::new(6, 1.0);
        for t in 0..500u64 {
            for l in 0..=6 {
                let tau = s.tau(t, l);
                let p = s.period(l);
                assert!(tau <= t);
                assert!(t - tau < p, "staleness must be < period");
                assert_eq!(tau % p, 0, "tau must be a refresh step");
                // Paper's bound: t - floor(2^{dl}) <= tau <= t
                assert!(t.saturating_sub(p) <= tau);
            }
        }
    }

    #[test]
    fn due_iff_tau_equals_t() {
        let s = DelayedSchedule::new(5, 1.0);
        for t in 0..200u64 {
            for l in 0..=5 {
                assert_eq!(s.is_due(t, l), s.tau(t, l) == t);
            }
        }
    }

    #[test]
    fn refresh_rate_matches_period() {
        let s = DelayedSchedule::new(6, 1.0);
        assert_eq!(s.refresh_rate(0), 1.0);
        assert_eq!(s.refresh_rate(6), 1.0 / 64.0);
    }

    #[test]
    fn with_periods_clamps_and_forces_level0() {
        let s = DelayedSchedule::with_periods(vec![7, 0, 3]);
        assert_eq!(
            (0..=2).map(|l| s.period(l)).collect::<Vec<_>>(),
            vec![1, 1, 3]
        );
        assert_eq!(s.lmax(), 2);
        for t in 0..100 {
            assert!(s.is_due(t, 0));
        }
    }

    #[test]
    #[should_panic(expected = "level 0")]
    fn with_periods_rejects_empty() {
        DelayedSchedule::with_periods(vec![]);
    }

    /// Mid-run reconfiguration: replacing the schedule at an arbitrary
    /// step `t` must keep `tau`/`is_due` consistent — `tau <= t`,
    /// staleness below the *new* period, level 0 still due every step,
    /// and every level due again within one new period of the swap (no
    /// level starves). Property-style over fractional `d` and arbitrary
    /// period replacements.
    #[test]
    fn reconfiguration_keeps_tau_and_is_due_consistent() {
        let ds = [0.3, 0.5, 1.0, 1.3, 1.7];
        let replacements: [&[u64]; 4] = [
            &[1, 1, 2, 3, 5, 8, 13],
            &[1, 4, 4, 4, 4, 4, 4],
            &[9, 2, 2, 64, 1, 1, 7], // level-0 entry is overridden to 1
            &[1, 1, 1, 1, 1, 1, 1],
        ];
        for &d in &ds {
            let old = DelayedSchedule::new(6, d);
            for new_periods in replacements {
                let new = DelayedSchedule::with_periods(new_periods.to_vec());
                // swap at a spread of steps, including ones where high
                // levels are mid-period under the old schedule
                for swap_t in [0u64, 1, 3, 17, 64, 100] {
                    for l in 0..=new.lmax() {
                        let p = new.period(l);
                        // every level comes due within one new period
                        let next_due = (swap_t..swap_t + p)
                            .find(|&t| new.is_due(t, l));
                        assert!(
                            next_due.is_some(),
                            "level {l} starves after swap at {swap_t}"
                        );
                        for t in swap_t..swap_t + 2 * p {
                            let tau = new.tau(t, l);
                            assert!(tau <= t);
                            assert!(t - tau < p, "staleness must be < period");
                            assert_eq!(tau % p, 0);
                            assert_eq!(new.is_due(t, l), tau == t);
                        }
                    }
                    // level 0 is always due under any replacement
                    assert!(new.is_due(swap_t, 0));
                    // old and new schedules agree on the invariant shape
                    assert!(old.tau(swap_t, 0) == swap_t);
                }
            }
        }
    }

    #[test]
    fn average_due_count_matches_theory() {
        // Over a long horizon, the average number of due levels per step
        // is sum_l 2^{-dl}.
        let s = DelayedSchedule::new(6, 1.0);
        let horizon = 1u64 << 12;
        let total: usize = (0..horizon).map(|t| s.levels_due(t).len()).sum();
        let avg = total as f64 / horizon as f64;
        let theory: f64 = (0..=6).map(|l| 0.5f64.powi(l)).sum();
        assert!((avg - theory).abs() < 0.01, "avg {avg} vs theory {theory}");
    }
}
