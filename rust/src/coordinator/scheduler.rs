//! The delayed-refresh schedule of Algorithm 1.
//!
//! Level `l` is refreshed at step `t` iff `t ≡ 0 (mod ⌊2^{dl}⌋)`; between
//! refreshes the cached component from `τ_l(t) = t - (t mod ⌊2^{dl}⌋)` is
//! reused. `d` is the delay exponent (paper: `d = 1`, matched to the
//! smoothness decay of Assumption 3). `d = 0` degenerates to standard
//! MLMC (every level refreshed every step).

/// Refresh schedule for levels `0..=lmax`.
#[derive(Debug, Clone)]
pub struct DelayedSchedule {
    periods: Vec<u64>,
    pub d: f64,
}

impl DelayedSchedule {
    pub fn new(lmax: usize, d: f64) -> Self {
        assert!(d >= 0.0, "delay exponent must be non-negative");
        let periods = (0..=lmax)
            .map(|l| (2f64.powf(d * l as f64).floor() as u64).max(1))
            .collect();
        DelayedSchedule { periods, d }
    }

    /// Standard MLMC: refresh everything every step.
    pub fn every_step(lmax: usize) -> Self {
        DelayedSchedule::new(lmax, 0.0)
    }

    pub fn lmax(&self) -> usize {
        self.periods.len() - 1
    }

    /// `⌊2^{dl}⌋` (clamped to >= 1).
    pub fn period(&self, level: usize) -> u64 {
        self.periods[level]
    }

    /// Does step `t` refresh level `level`?
    pub fn is_due(&self, t: u64, level: usize) -> bool {
        t % self.period(level) == 0
    }

    /// The most recent refresh step `τ_l(t) <= t`.
    pub fn tau(&self, t: u64, level: usize) -> u64 {
        t - t % self.period(level)
    }

    /// All levels due at step `t` (level 0 is always due).
    pub fn levels_due(&self, t: u64) -> Vec<usize> {
        (0..=self.lmax()).filter(|&l| self.is_due(t, l)).collect()
    }

    /// Average number of refreshes of level `l` per step over a horizon —
    /// the `2^{-dl}` factor in the paper's average parallel complexity.
    pub fn refresh_rate(&self, level: usize) -> f64 {
        1.0 / self.period(level) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_periods_d1() {
        let s = DelayedSchedule::new(6, 1.0);
        for l in 0..=6 {
            assert_eq!(s.period(l), 1u64 << l);
        }
    }

    #[test]
    fn fractional_d_floors() {
        let s = DelayedSchedule::new(4, 0.5);
        // floor(2^{0.5 l}) = 1, 1, 2, 2, 4
        assert_eq!(
            (0..=4).map(|l| s.period(l)).collect::<Vec<_>>(),
            vec![1, 1, 2, 2, 4]
        );
    }

    #[test]
    fn d_zero_is_standard_mlmc() {
        let s = DelayedSchedule::every_step(6);
        for t in 0..100 {
            assert_eq!(s.levels_due(t).len(), 7);
        }
    }

    #[test]
    fn level0_always_due() {
        let s = DelayedSchedule::new(6, 1.3);
        for t in 0..1000 {
            assert!(s.is_due(t, 0));
        }
    }

    #[test]
    fn tau_properties() {
        let s = DelayedSchedule::new(6, 1.0);
        for t in 0..500u64 {
            for l in 0..=6 {
                let tau = s.tau(t, l);
                let p = s.period(l);
                assert!(tau <= t);
                assert!(t - tau < p, "staleness must be < period");
                assert_eq!(tau % p, 0, "tau must be a refresh step");
                // Paper's bound: t - floor(2^{dl}) <= tau <= t
                assert!(t.saturating_sub(p) <= tau);
            }
        }
    }

    #[test]
    fn due_iff_tau_equals_t() {
        let s = DelayedSchedule::new(5, 1.0);
        for t in 0..200u64 {
            for l in 0..=5 {
                assert_eq!(s.is_due(t, l), s.tau(t, l) == t);
            }
        }
    }

    #[test]
    fn refresh_rate_matches_period() {
        let s = DelayedSchedule::new(6, 1.0);
        assert_eq!(s.refresh_rate(0), 1.0);
        assert_eq!(s.refresh_rate(6), 1.0 / 64.0);
    }

    #[test]
    fn average_due_count_matches_theory() {
        // Over a long horizon, the average number of due levels per step
        // is sum_l 2^{-dl}.
        let s = DelayedSchedule::new(6, 1.0);
        let horizon = 1u64 << 12;
        let total: usize = (0..horizon).map(|t| s.levels_due(t).len()).sum();
        let avg = total as f64 / horizon as f64;
        let theory: f64 = (0..=6).map(|l| 0.5f64.powi(l)).sum();
        assert!((avg - theory).abs() < 0.01, "avg {avg} vs theory {theory}");
    }
}
