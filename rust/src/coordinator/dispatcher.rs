//! Level-refresh dispatch.
//!
//! A step's due levels are independent jobs (independent Brownian
//! streams, shared read-only parameters), so they can run concurrently.
//! Two execution strategies with *identical* results (tested):
//!
//! * [`run_jobs`] — sequential; works with any backend, including the
//!   PJRT runtime (whose handles are `!Send` — raw C pointers);
//! * [`run_jobs_threaded`] — scoped threads, one per level, for `Sync`
//!   backends (the native engine). Demonstrates the real concurrency the
//!   PRAM cost model accounts for.
//!
//! Determinism across strategies comes from counter-based RNG: the batch
//! for `(step, level, chunk)` is a pure function of its address, not of
//! execution order.

use anyhow::Result;

use crate::hedging::Problem;
use crate::mlmc::estimator::ChunkAccumulator;
use crate::rng::{brownian::Purpose, BrownianSource};
use crate::runtime::GradBackend;

/// One level-refresh job: accumulate `n_chunks` chunks at `level`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LevelJobSpec {
    pub level: usize,
    pub n_chunks: usize,
}

/// The refreshed component for one level.
#[derive(Debug, Clone)]
pub struct LevelResult {
    pub level: usize,
    pub loss_delta: f64,
    pub grad: Vec<f32>,
    /// Samples consumed (chunks * chunk batch) — cost accounting input.
    pub n_samples: usize,
}

/// Execute one level job (chunk loop + averaging).
fn run_one<B: GradBackend + ?Sized>(
    backend: &B,
    problem: &Problem,
    src: &BrownianSource,
    step: u64,
    params: &[f32],
    spec: LevelJobSpec,
) -> Result<LevelResult> {
    let batch = backend.grad_chunk(spec.level);
    let n_steps = problem.n_steps(spec.level);
    let dt = problem.dt(spec.level);
    let n_factors = backend.n_factors();
    let mut acc = ChunkAccumulator::new(backend.n_params());
    for chunk in 0..spec.n_chunks {
        let dw = src.increments_multi(
            Purpose::Grad,
            step,
            spec.level as u32,
            chunk as u32,
            batch,
            n_steps,
            dt,
            n_factors,
        );
        let (loss, grad) = backend.grad_coupled_chunk(spec.level, params, &dw)?;
        acc.add(loss, &grad);
    }
    let (loss_delta, grad) = acc.finish();
    Ok(LevelResult {
        level: spec.level,
        loss_delta,
        grad,
        n_samples: spec.n_chunks * batch,
    })
}

/// Sequential dispatch (any backend). Results ordered like `jobs`.
pub fn run_jobs<B: GradBackend + ?Sized>(
    backend: &B,
    src: &BrownianSource,
    step: u64,
    params: &[f32],
    jobs: &[LevelJobSpec],
) -> Result<Vec<LevelResult>> {
    let problem = *backend.problem();
    jobs.iter()
        .map(|&spec| run_one(backend, &problem, src, step, params, spec))
        .collect()
}

/// Threaded dispatch: one scoped thread per level job (for `Sync`
/// backends). Produces bit-identical results to [`run_jobs`].
pub fn run_jobs_threaded<B: GradBackend + Sync>(
    backend: &B,
    src: &BrownianSource,
    step: u64,
    params: &[f32],
    jobs: &[LevelJobSpec],
) -> Result<Vec<LevelResult>> {
    let problem = *backend.problem();
    let handles: Vec<Result<LevelResult>> = std::thread::scope(|scope| {
        let mut joins = Vec::with_capacity(jobs.len());
        for &spec in jobs {
            let problem = &problem;
            joins.push(scope.spawn(move || {
                run_one(backend, problem, src, step, params, spec)
            }));
        }
        joins
            .into_iter()
            .map(|j| j.join().expect("level job panicked"))
            .collect()
    });
    handles.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::mlp::init_params;
    use crate::hedging::Problem;
    use crate::runtime::NativeBackend;

    fn setup() -> (NativeBackend, BrownianSource, Vec<f32>) {
        (
            NativeBackend::new(Problem::default()),
            BrownianSource::new(42),
            init_params(0),
        )
    }

    fn jobs() -> Vec<LevelJobSpec> {
        vec![
            LevelJobSpec { level: 0, n_chunks: 2 },
            LevelJobSpec { level: 1, n_chunks: 1 },
            LevelJobSpec { level: 3, n_chunks: 1 },
        ]
    }

    #[test]
    fn sequential_results_are_sane() {
        let (b, src, params) = setup();
        let out = run_jobs(&b, &src, 0, &params, &jobs()).unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].level, 0);
        assert_eq!(out[0].n_samples, 2 * b.grad_chunk(0));
        assert!(out.iter().all(|r| r.loss_delta.is_finite()));
        // higher level components are smaller (Assumption 2)
        let n0: f64 = out[0].grad.iter().map(|&g| (g as f64).powi(2)).sum();
        let n3: f64 = out[2].grad.iter().map(|&g| (g as f64).powi(2)).sum();
        assert!(n3 < n0);
    }

    #[test]
    fn threaded_matches_sequential_bitwise() {
        let (b, src, params) = setup();
        let seq = run_jobs(&b, &src, 7, &params, &jobs()).unwrap();
        let thr = run_jobs_threaded(&b, &src, 7, &params, &jobs()).unwrap();
        for (a, c) in seq.iter().zip(&thr) {
            assert_eq!(a.level, c.level);
            assert_eq!(a.loss_delta, c.loss_delta);
            assert_eq!(a.grad, c.grad, "level {} grads differ", a.level);
        }
    }

    #[test]
    fn distinct_steps_get_distinct_samples() {
        let (b, src, params) = setup();
        let spec = [LevelJobSpec { level: 1, n_chunks: 1 }];
        let a = run_jobs(&b, &src, 0, &params, &spec).unwrap();
        let c = run_jobs(&b, &src, 1, &params, &spec).unwrap();
        assert_ne!(a[0].grad, c[0].grad);
    }

    #[test]
    fn empty_jobs_ok() {
        let (b, src, params) = setup();
        assert!(run_jobs(&b, &src, 0, &params, &[]).unwrap().is_empty());
    }
}
