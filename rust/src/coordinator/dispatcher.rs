//! Level-refresh dispatch.
//!
//! A step's due levels are independent jobs (independent Brownian
//! streams, shared read-only parameters), so they can run concurrently.
//! Three execution strategies with *identical* results (tested):
//!
//! * [`run_jobs`] — sequential; works with any backend, including the
//!   PJRT runtime (whose handles are `!Send` — raw C pointers);
//! * [`run_jobs_pool`] — the chunk-sharded worker pool
//!   ([`crate::exec::WorkerPool`]): every job is split into per-chunk
//!   tasks, LPT-scheduled over P workers, and reduced in fixed chunk
//!   order — bit-identical to [`run_jobs`] for every worker count. The
//!   default path for `Sync` backends (the native engine).
//! * [`run_jobs_threaded`] — the historical one-scoped-thread-per-level
//!   strategy, now a thin wrapper over the pool with `workers = n_jobs`
//!   (one concurrency code path instead of two).
//!
//! Determinism across strategies comes from counter-based RNG: the batch
//! for `(step, level, chunk)` is a pure function of its address, not of
//! execution order.

use anyhow::Result;

use crate::exec::{ChunkTask, StepExecReport, WorkerPool};
use crate::hedging::Problem;
use crate::mlmc::estimator::ChunkAccumulator;
use crate::rng::{brownian::Purpose, BrownianSource};
use crate::runtime::GradBackend;

/// One level-refresh job: accumulate `n_chunks` chunks at `level`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LevelJobSpec {
    pub level: usize,
    pub n_chunks: usize,
}

/// The refreshed component for one level.
#[derive(Debug, Clone)]
pub struct LevelResult {
    pub level: usize,
    pub loss_delta: f64,
    pub grad: Vec<f32>,
    /// Samples consumed (chunks * chunk batch) — cost accounting input.
    pub n_samples: usize,
}

/// One chunk of one level job: generate the addressed Brownian batch and
/// run the coupled value-and-grad. The single definition of the
/// `(step, level, chunk)` -> dw -> gradient mapping — both the sequential
/// loop and the pool closure go through here, so the pool-vs-sequential
/// bit-identity can never drift apart at this layer.
fn grad_chunk_at<B: GradBackend + ?Sized>(
    backend: &B,
    problem: &Problem,
    src: &BrownianSource,
    step: u64,
    level: usize,
    chunk: usize,
    params: &[f32],
) -> Result<(f64, Vec<f32>)> {
    let batch = backend.grad_chunk(level);
    let dw = src.increments_multi(
        Purpose::Grad,
        step,
        level as u32,
        chunk as u32,
        batch,
        problem.n_steps(level),
        problem.dt(level),
        backend.n_factors(),
    );
    backend.grad_coupled_chunk(level, params, &dw)
}

/// Execute one level job (chunk loop + averaging).
fn run_one<B: GradBackend + ?Sized>(
    backend: &B,
    problem: &Problem,
    src: &BrownianSource,
    step: u64,
    params: &[f32],
    spec: LevelJobSpec,
) -> Result<LevelResult> {
    let mut acc = ChunkAccumulator::new(backend.n_params());
    for chunk in 0..spec.n_chunks {
        let (loss, grad) =
            grad_chunk_at(backend, problem, src, step, spec.level, chunk, params)?;
        acc.add(loss, &grad);
    }
    let (loss_delta, grad) = acc.finish();
    Ok(LevelResult {
        level: spec.level,
        loss_delta,
        grad,
        n_samples: spec.n_chunks * backend.grad_chunk(spec.level),
    })
}

/// Sequential dispatch (any backend). Results ordered like `jobs`.
pub fn run_jobs<B: GradBackend + ?Sized>(
    backend: &B,
    src: &BrownianSource,
    step: u64,
    params: &[f32],
    jobs: &[LevelJobSpec],
) -> Result<Vec<LevelResult>> {
    let problem = *backend.problem();
    jobs.iter()
        .map(|&spec| run_one(backend, &problem, src, step, params, spec))
        .collect()
}

/// Shard `jobs` into per-chunk pool tasks. The LPT weight is the chunk's
/// row-work `batch x n_steps` — the same `2^{c l}`-shaped cost the PRAM
/// model assigns per sample (for c = 1), so the pool's greedy schedule
/// mirrors the modeled one.
fn chunk_tasks<B: GradBackend + ?Sized>(
    backend: &B,
    problem: &Problem,
    jobs: &[LevelJobSpec],
) -> Vec<ChunkTask> {
    let mut tasks = Vec::new();
    for (group, &spec) in jobs.iter().enumerate() {
        let weight = backend.grad_chunk(spec.level) as f64
            * problem.n_steps(spec.level) as f64;
        for chunk in 0..spec.n_chunks {
            tasks.push(ChunkTask {
                group,
                chunk,
                level: spec.level,
                weight,
            });
        }
    }
    tasks
}

/// Pooled dispatch with execution telemetry: shard into chunk tasks, run
/// on the pool, reduce bit-exactly (see [`crate::exec`]). Results ordered
/// like `jobs`; the report carries measured makespan and per-worker busy
/// time for this step.
pub fn run_jobs_pool_with_report<B: GradBackend + Sync + ?Sized>(
    backend: &B,
    src: &BrownianSource,
    step: u64,
    params: &[f32],
    jobs: &[LevelJobSpec],
    pool: &mut WorkerPool,
) -> Result<(Vec<LevelResult>, StepExecReport)> {
    let problem = *backend.problem();
    let tasks = chunk_tasks(backend, &problem, jobs);
    let (reduced, report) = pool.execute(&tasks, jobs.len(), |t| {
        grad_chunk_at(backend, &problem, src, step, t.level, t.chunk, params)
    })?;
    let results = jobs
        .iter()
        .zip(reduced)
        .map(|(&spec, (loss_delta, grad))| LevelResult {
            level: spec.level,
            loss_delta,
            grad,
            n_samples: spec.n_chunks * backend.grad_chunk(spec.level),
        })
        .collect();
    Ok((results, report))
}

/// Pooled dispatch (telemetry discarded). Bit-identical to [`run_jobs`]
/// for every worker count.
pub fn run_jobs_pool<B: GradBackend + Sync + ?Sized>(
    backend: &B,
    src: &BrownianSource,
    step: u64,
    params: &[f32],
    jobs: &[LevelJobSpec],
    pool: &mut WorkerPool,
) -> Result<Vec<LevelResult>> {
    run_jobs_pool_with_report(backend, src, step, params, jobs, pool)
        .map(|(results, _)| results)
}

/// Threaded dispatch with the historical *worker count* (one worker per
/// level job), as a thin wrapper over the pool. Note the granularity is
/// the pool's, not the old per-level one: tasks are per-chunk and
/// LPT-ordered, so one level's chunks may spread across several workers.
/// Results are bit-identical to [`run_jobs`] either way.
pub fn run_jobs_threaded<B: GradBackend + Sync>(
    backend: &B,
    src: &BrownianSource,
    step: u64,
    params: &[f32],
    jobs: &[LevelJobSpec],
) -> Result<Vec<LevelResult>> {
    let mut pool = WorkerPool::new(jobs.len().max(1));
    run_jobs_pool(backend, src, step, params, jobs, &mut pool)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::mlp::init_params;
    use crate::hedging::Problem;
    use crate::runtime::NativeBackend;

    fn setup() -> (NativeBackend, BrownianSource, Vec<f32>) {
        (
            NativeBackend::new(Problem::default()),
            BrownianSource::new(42),
            init_params(0),
        )
    }

    fn jobs() -> Vec<LevelJobSpec> {
        vec![
            LevelJobSpec { level: 0, n_chunks: 2 },
            LevelJobSpec { level: 1, n_chunks: 1 },
            LevelJobSpec { level: 3, n_chunks: 1 },
        ]
    }

    #[test]
    fn sequential_results_are_sane() {
        let (b, src, params) = setup();
        let out = run_jobs(&b, &src, 0, &params, &jobs()).unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].level, 0);
        assert_eq!(out[0].n_samples, 2 * b.grad_chunk(0));
        assert!(out.iter().all(|r| r.loss_delta.is_finite()));
        // higher level components are smaller (Assumption 2)
        let n0: f64 = out[0].grad.iter().map(|&g| (g as f64).powi(2)).sum();
        let n3: f64 = out[2].grad.iter().map(|&g| (g as f64).powi(2)).sum();
        assert!(n3 < n0);
    }

    #[test]
    fn threaded_matches_sequential_bitwise() {
        let (b, src, params) = setup();
        let seq = run_jobs(&b, &src, 7, &params, &jobs()).unwrap();
        let thr = run_jobs_threaded(&b, &src, 7, &params, &jobs()).unwrap();
        for (a, c) in seq.iter().zip(&thr) {
            assert_eq!(a.level, c.level);
            assert_eq!(a.loss_delta, c.loss_delta);
            assert_eq!(a.grad, c.grad, "level {} grads differ", a.level);
        }
    }

    #[test]
    fn pool_matches_sequential_bitwise_for_every_worker_count() {
        let (b, src, params) = setup();
        let seq = run_jobs(&b, &src, 7, &params, &jobs()).unwrap();
        for workers in [1usize, 2, 3, 8] {
            let mut pool = WorkerPool::new(workers);
            let out =
                run_jobs_pool(&b, &src, 7, &params, &jobs(), &mut pool).unwrap();
            for (a, c) in seq.iter().zip(&out) {
                assert_eq!(a.level, c.level, "P={workers}");
                assert_eq!(a.loss_delta, c.loss_delta, "P={workers}");
                assert_eq!(a.grad, c.grad, "P={workers} level {}", a.level);
                assert_eq!(a.n_samples, c.n_samples, "P={workers}");
            }
        }
    }

    #[test]
    fn pool_report_accounts_every_chunk() {
        let (b, src, params) = setup();
        let mut pool = WorkerPool::new(2);
        let (_, report) =
            run_jobs_pool_with_report(&b, &src, 0, &params, &jobs(), &mut pool)
                .unwrap();
        // jobs() has 2 + 1 + 1 = 4 chunks
        assert_eq!(report.n_tasks, 4);
        let executed: usize = report.workers.iter().map(|w| w.tasks).sum();
        assert_eq!(executed, 4);
        assert!(report.makespan.as_secs_f64() > 0.0);
        assert!(report.utilization() > 0.0 && report.utilization() <= 1.0);
    }

    #[test]
    fn chunk_tasks_shard_and_weight_by_level() {
        let (b, _, _) = setup();
        let problem = *b.problem();
        let tasks = chunk_tasks(&b, &problem, &jobs());
        assert_eq!(tasks.len(), 4);
        assert_eq!(tasks[0], ChunkTask {
            group: 0,
            chunk: 0,
            level: 0,
            weight: (b.grad_chunk(0) * problem.n_steps(0)) as f64,
        });
        // The chunk policy keeps batch x n_steps at 512 rows for levels
        // <= 4 (uniform chunks), so only deep levels outweigh them.
        let deep = chunk_tasks(
            &b,
            &problem,
            &[LevelJobSpec { level: 6, n_chunks: 1 }],
        );
        assert!(deep[0].weight > tasks[0].weight);
    }

    #[test]
    fn distinct_steps_get_distinct_samples() {
        let (b, src, params) = setup();
        let spec = [LevelJobSpec { level: 1, n_chunks: 1 }];
        let a = run_jobs(&b, &src, 0, &params, &spec).unwrap();
        let c = run_jobs(&b, &src, 1, &params, &spec).unwrap();
        assert_ne!(a[0].grad, c[0].grad);
    }

    #[test]
    fn empty_jobs_ok() {
        let (b, src, params) = setup();
        assert!(run_jobs(&b, &src, 0, &params, &[]).unwrap().is_empty());
        let mut pool = WorkerPool::new(2);
        assert!(run_jobs_pool(&b, &src, 0, &params, &[], &mut pool)
            .unwrap()
            .is_empty());
    }
}
