//! Level-refresh dispatch.
//!
//! A step's due levels are independent jobs (independent Brownian
//! streams, shared read-only parameters), so they can run concurrently.
//! Three execution strategies with *identical* results (tested):
//!
//! * [`run_jobs`] — sequential; works with any backend, including the
//!   PJRT runtime (whose handles are `!Send` — raw C pointers);
//! * [`run_jobs_pool`] — the chunk-sharded **resident** worker pool
//!   ([`crate::exec::WorkerPool`]): every job is split into per-chunk
//!   tasks, LPT-scheduled over P parked-between-dispatches workers, and
//!   reduced in fixed chunk order — bit-identical to [`run_jobs`] for
//!   every worker count. The default path for shareable backends (the
//!   native engine, via `GradBackend::into_shared`). The pool workers
//!   are `'static`, so the dispatch closure captures `Arc`-cloned
//!   backend/params snapshots rather than scope-borrowed references.
//! * [`run_jobs_threaded`] — the historical "threaded" entry point, a
//!   thin wrapper over [`run_jobs_pool`] on a **caller-supplied** pool
//!   (one concurrency code path instead of two; a fresh pool per call
//!   used to silently drop the accumulated [`crate::exec::ExecStats`]).
//!
//! Determinism across strategies comes from counter-based RNG: the batch
//! for `(step, level, chunk)` is a pure function of its address, not of
//! execution order.

use std::sync::Arc;

use anyhow::Result;

use crate::exec::{ChunkTask, StepExecReport, WorkerPool};
use crate::hedging::Problem;
use crate::mlmc::estimator::ChunkAccumulator;
use crate::rng::{brownian::Purpose, BrownianSource};
use crate::runtime::GradBackend;

/// One level-refresh job: accumulate `n_chunks` chunks at `level`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LevelJobSpec {
    pub level: usize,
    pub n_chunks: usize,
}

/// The refreshed component for one level.
#[derive(Debug, Clone)]
pub struct LevelResult {
    pub level: usize,
    pub loss_delta: f64,
    pub grad: Vec<f32>,
    /// Samples consumed (chunks * chunk batch) — cost accounting input.
    pub n_samples: usize,
}

/// One chunk of one level job: generate the addressed Brownian batch and
/// run the coupled value-and-grad. The single definition of the
/// `(step, level, chunk)` -> dw -> gradient mapping — the sequential
/// loop, the pool closure and the fleet's multiplexed dispatch all go
/// through here, so bit-identity across strategies can never drift apart
/// at this layer.
pub(crate) fn grad_chunk_at<B: GradBackend + ?Sized>(
    backend: &B,
    problem: &Problem,
    src: &BrownianSource,
    step: u64,
    level: usize,
    chunk: usize,
    params: &[f32],
) -> Result<(f64, Vec<f32>)> {
    let batch = backend.grad_chunk(level);
    let dw = src.increments_multi(
        Purpose::Grad,
        step,
        level as u32,
        chunk as u32,
        batch,
        problem.n_steps(level),
        problem.dt(level),
        backend.n_factors(),
    );
    backend.grad_coupled_chunk(level, params, &dw)
}

/// Execute one level job (chunk loop + averaging).
fn run_one<B: GradBackend + ?Sized>(
    backend: &B,
    problem: &Problem,
    src: &BrownianSource,
    step: u64,
    params: &[f32],
    spec: LevelJobSpec,
) -> Result<LevelResult> {
    let mut acc = ChunkAccumulator::new(backend.n_params());
    for chunk in 0..spec.n_chunks {
        let (loss, grad) =
            grad_chunk_at(backend, problem, src, step, spec.level, chunk, params)?;
        acc.add(loss, &grad);
    }
    let (loss_delta, grad) = acc.finish();
    Ok(LevelResult {
        level: spec.level,
        loss_delta,
        grad,
        n_samples: spec.n_chunks * backend.grad_chunk(spec.level),
    })
}

/// Sequential dispatch (any backend). Results ordered like `jobs`.
pub fn run_jobs<B: GradBackend + ?Sized>(
    backend: &B,
    src: &BrownianSource,
    step: u64,
    params: &[f32],
    jobs: &[LevelJobSpec],
) -> Result<Vec<LevelResult>> {
    let problem = *backend.problem();
    jobs.iter()
        .map(|&spec| run_one(backend, &problem, src, step, params, spec))
        .collect()
}

/// Shard `jobs` into per-chunk pool tasks. The LPT weight is the chunk's
/// *coupled* row-work `batch x (n_steps(l) + n_steps(l-1))`: a level-`l >
/// 0` chunk simulates both the fine and the coarse grid of every coupled
/// sample, so both halves count (weighting by the fine grid alone
/// under-weights coupled levels ~1.5x relative to level 0, skewing the
/// greedy schedule and the measured-vs-PRAM comparison). Level 0 has no
/// coarse half. Weights only order the queue — results are bit-identical
/// regardless. `pub(crate)` so the fleet can shard each trainer's jobs
/// with the exact same weights before rebasing group indices.
pub(crate) fn chunk_tasks<B: GradBackend + ?Sized>(
    backend: &B,
    problem: &Problem,
    jobs: &[LevelJobSpec],
) -> Vec<ChunkTask> {
    let mut tasks = Vec::new();
    for (group, &spec) in jobs.iter().enumerate() {
        let coarse_steps = if spec.level > 0 {
            problem.n_steps(spec.level - 1)
        } else {
            0
        };
        let weight = backend.grad_chunk(spec.level) as f64
            * (problem.n_steps(spec.level) + coarse_steps) as f64;
        for chunk in 0..spec.n_chunks {
            tasks.push(ChunkTask {
                group,
                chunk,
                level: spec.level,
                weight,
            });
        }
    }
    tasks
}

/// Pooled dispatch with execution telemetry: shard into chunk tasks, run
/// on the (resident) pool, reduce bit-exactly (see [`crate::exec`]).
/// Results ordered like `jobs`; the report carries measured makespan,
/// per-worker busy time and dispatch overhead for this step.
///
/// The backend arrives as an `Arc` because the pool's resident workers
/// need a `'static` job: the dispatch closure captures an `Arc` clone of
/// the backend plus copied/`Arc`-snapshotted inputs (`Problem` and
/// `BrownianSource` are `Copy`; `params` is snapshotted once per
/// dispatch).
pub fn run_jobs_pool_with_report<B>(
    backend: &Arc<B>,
    src: &BrownianSource,
    step: u64,
    params: &[f32],
    jobs: &[LevelJobSpec],
    pool: &mut WorkerPool,
) -> Result<(Vec<LevelResult>, StepExecReport)>
where
    B: GradBackend + Send + Sync + ?Sized + 'static,
{
    let problem = *backend.problem();
    let tasks = chunk_tasks(&**backend, &problem, jobs);
    let shared = backend.clone();
    let src = *src;
    let params_snap: Arc<[f32]> = Arc::from(params);
    let (reduced, report) = pool.execute(&tasks, jobs.len(), move |t: &ChunkTask| {
        grad_chunk_at(
            &*shared,
            &problem,
            &src,
            step,
            t.level,
            t.chunk,
            &params_snap,
        )
    })?;
    let results = jobs
        .iter()
        .zip(reduced)
        .map(|(&spec, (loss_delta, grad))| LevelResult {
            level: spec.level,
            loss_delta,
            grad,
            n_samples: spec.n_chunks * backend.grad_chunk(spec.level),
        })
        .collect();
    Ok((results, report))
}

/// Pooled dispatch (telemetry discarded). Bit-identical to [`run_jobs`]
/// for every worker count.
pub fn run_jobs_pool<B>(
    backend: &Arc<B>,
    src: &BrownianSource,
    step: u64,
    params: &[f32],
    jobs: &[LevelJobSpec],
    pool: &mut WorkerPool,
) -> Result<Vec<LevelResult>>
where
    B: GradBackend + Send + Sync + ?Sized + 'static,
{
    run_jobs_pool_with_report(backend, src, step, params, jobs, pool)
        .map(|(results, _)| results)
}

/// The historical "threaded" entry point, as a thin wrapper over the
/// pool. The pool is **caller-supplied** (it used to build a fresh
/// `WorkerPool` per call, which silently dropped the `ExecStats`
/// accumulated across calls — telemetry now survives in `pool.stats()`).
/// Note the granularity is the pool's, not the old per-level one: tasks
/// are per-chunk and LPT-ordered, so one level's chunks may spread
/// across several workers. Results are bit-identical to [`run_jobs`]
/// either way.
pub fn run_jobs_threaded<B>(
    backend: &Arc<B>,
    src: &BrownianSource,
    step: u64,
    params: &[f32],
    jobs: &[LevelJobSpec],
    pool: &mut WorkerPool,
) -> Result<Vec<LevelResult>>
where
    B: GradBackend + Send + Sync + ?Sized + 'static,
{
    run_jobs_pool(backend, src, step, params, jobs, pool)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::mlp::init_params;
    use crate::hedging::Problem;
    use crate::runtime::NativeBackend;

    fn setup() -> (Arc<NativeBackend>, BrownianSource, Vec<f32>) {
        (
            Arc::new(NativeBackend::new(Problem::default())),
            BrownianSource::new(42),
            init_params(0),
        )
    }

    fn jobs() -> Vec<LevelJobSpec> {
        vec![
            LevelJobSpec { level: 0, n_chunks: 2 },
            LevelJobSpec { level: 1, n_chunks: 1 },
            LevelJobSpec { level: 3, n_chunks: 1 },
        ]
    }

    #[test]
    fn sequential_results_are_sane() {
        let (b, src, params) = setup();
        let out = run_jobs(&*b, &src, 0, &params, &jobs()).unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].level, 0);
        assert_eq!(out[0].n_samples, 2 * b.grad_chunk(0));
        assert!(out.iter().all(|r| r.loss_delta.is_finite()));
        // higher level components are smaller (Assumption 2)
        let n0: f64 = out[0].grad.iter().map(|&g| (g as f64).powi(2)).sum();
        let n3: f64 = out[2].grad.iter().map(|&g| (g as f64).powi(2)).sum();
        assert!(n3 < n0);
    }

    #[test]
    fn threaded_matches_sequential_bitwise() {
        let (b, src, params) = setup();
        let seq = run_jobs(&*b, &src, 7, &params, &jobs()).unwrap();
        let mut pool = WorkerPool::new(jobs().len());
        let thr =
            run_jobs_threaded(&b, &src, 7, &params, &jobs(), &mut pool).unwrap();
        for (a, c) in seq.iter().zip(&thr) {
            assert_eq!(a.level, c.level);
            assert_eq!(a.loss_delta, c.loss_delta);
            assert_eq!(a.grad, c.grad, "level {} grads differ", a.level);
        }
    }

    #[test]
    fn threaded_stats_survive_consecutive_calls() {
        // Regression: run_jobs_threaded used to build a fresh WorkerPool
        // per call, silently dropping the ExecStats accumulated so far.
        let (b, src, params) = setup();
        let mut pool = WorkerPool::new(2);
        for step in 0..3 {
            run_jobs_threaded(&b, &src, step, &params, &jobs(), &mut pool)
                .unwrap();
        }
        assert_eq!(pool.stats().steps, 3);
        assert_eq!(pool.stats().tasks, 3 * 4); // jobs() has 4 chunks
        assert_eq!(pool.stats().makespans.len(), 3);
        assert_eq!(pool.stats().overheads.len(), 3);
    }

    #[test]
    fn pool_matches_sequential_bitwise_for_every_worker_count() {
        let (b, src, params) = setup();
        let seq = run_jobs(&*b, &src, 7, &params, &jobs()).unwrap();
        for workers in [1usize, 2, 3, 8] {
            let mut pool = WorkerPool::new(workers);
            let out =
                run_jobs_pool(&b, &src, 7, &params, &jobs(), &mut pool).unwrap();
            for (a, c) in seq.iter().zip(&out) {
                assert_eq!(a.level, c.level, "P={workers}");
                assert_eq!(a.loss_delta, c.loss_delta, "P={workers}");
                assert_eq!(a.grad, c.grad, "P={workers} level {}", a.level);
                assert_eq!(a.n_samples, c.n_samples, "P={workers}");
            }
        }
    }

    #[test]
    fn pool_report_accounts_every_chunk() {
        let (b, src, params) = setup();
        let mut pool = WorkerPool::new(2);
        let (_, report) =
            run_jobs_pool_with_report(&b, &src, 0, &params, &jobs(), &mut pool)
                .unwrap();
        // jobs() has 2 + 1 + 1 = 4 chunks. Assert on task *accounting*,
        // never on wall-clock positivity: under a coarse clock a fast
        // dispatch can legitimately measure a zero makespan.
        assert_eq!(report.n_tasks, 4);
        let executed: usize = report.workers.iter().map(|w| w.tasks).sum();
        assert_eq!(executed, 4);
        assert_eq!(report.workers.len(), 2);
        assert!(report.utilization() <= 1.0);
        assert!(report.makespan >= report.dispatch_overhead());
        assert_eq!(pool.stats().steps, 1);
        assert_eq!(pool.stats().tasks, 4);
    }

    #[test]
    fn chunk_tasks_shard_and_weight_by_level() {
        let (b, _, _) = setup();
        let problem = *b.problem();
        let tasks = chunk_tasks(&*b, &problem, &jobs());
        assert_eq!(tasks.len(), 4);
        // level 0 has no coarse half
        assert_eq!(tasks[0], ChunkTask {
            group: 0,
            chunk: 0,
            level: 0,
            weight: (b.grad_chunk(0) * problem.n_steps(0)) as f64,
        });
        // deep coupled levels outweigh level-0 chunks
        let deep = chunk_tasks(
            &*b,
            &problem,
            &[LevelJobSpec { level: 6, n_chunks: 1 }],
        );
        assert!(deep[0].weight > tasks[0].weight);
    }

    #[test]
    fn chunk_task_weight_counts_both_coupled_grids() {
        // Pin the per-level weight formula: batch x (n_steps(l) +
        // n_steps(l-1)) for l > 0, batch x n_steps(0) at the base level.
        let (b, _, _) = setup();
        let problem = *b.problem();
        for level in 0..=problem.lmax {
            let t = chunk_tasks(
                &*b,
                &problem,
                &[LevelJobSpec { level, n_chunks: 1 }],
            );
            let coarse = if level > 0 { problem.n_steps(level - 1) } else { 0 };
            let want =
                (b.grad_chunk(level) * (problem.n_steps(level) + coarse)) as f64;
            assert_eq!(t[0].weight, want, "level {level}");
        }
        // With the uniform 512-fine-row chunk policy (levels <= 4), a
        // coupled chunk carries exactly 1.5x the row-work of a level-0
        // chunk — the imbalance the old fine-grid-only weight ignored.
        let l0 = chunk_tasks(&*b, &problem, &[LevelJobSpec { level: 0, n_chunks: 1 }]);
        let l2 = chunk_tasks(&*b, &problem, &[LevelJobSpec { level: 2, n_chunks: 1 }]);
        assert_eq!(
            (b.grad_chunk(2) * problem.n_steps(2)) as f64,
            l0[0].weight,
            "chunk policy changed: fine rows no longer uniform"
        );
        assert_eq!(l2[0].weight, 1.5 * l0[0].weight);
    }

    #[test]
    fn distinct_steps_get_distinct_samples() {
        let (b, src, params) = setup();
        let spec = [LevelJobSpec { level: 1, n_chunks: 1 }];
        let a = run_jobs(&*b, &src, 0, &params, &spec).unwrap();
        let c = run_jobs(&*b, &src, 1, &params, &spec).unwrap();
        assert_ne!(a[0].grad, c[0].grad);
    }

    #[test]
    fn empty_jobs_ok() {
        let (b, src, params) = setup();
        assert!(run_jobs(&*b, &src, 0, &params, &[]).unwrap().is_empty());
        let mut pool = WorkerPool::new(2);
        assert!(run_jobs_pool(&b, &src, 0, &params, &[], &mut pool)
            .unwrap()
            .is_empty());
    }
}
