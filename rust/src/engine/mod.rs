//! Pure-Rust verification engine: the same model as the AOT artifacts
//! (Milstein paths -> hedging MLP -> squared hedging error, and its
//! gradient), hand-written with no JAX/XLA in the loop.
//!
//! Roles:
//! * **cross-validation** — integration tests feed identical increments to
//!   this engine and to the compiled HLO and require matching loss/grad
//!   (`rust/tests/integration_engine_vs_hlo.rs`);
//! * **native backend** — `--backend native` runs the whole training stack
//!   without artifacts (CI without Python, portability);
//! * **benchmarks** — a baseline the runtime's hot path is compared to.

//! The integrator and objective are generic over a
//! [`crate::scenarios::Scenario`] — a D-dimensional SDE dynamics
//! (D <= [`crate::scenarios::MAX_DIM`], correlated Brownian drivers)
//! paired with a **streaming** path payoff (`init → observe → finish`
//! observers; the hot path never materializes a path buffer). The plain
//! entry points run the problem's default Black–Scholes-call scenario
//! bit-identically to the seed engine.
//!
//! [`lanes`] is the SIMD-friendly twin of [`objective`]: 8 paths per
//! lane block, MLP rows forwarded/backpropagated 8 at a time, selected
//! via `*-simd` scenario keys (see [`crate::scenarios::kernels`]).

pub mod lanes;
pub mod milstein;
pub mod mlp;
pub mod objective;

pub use milstein::{
    fold_path, simulate_paths, simulate_paths_sde, terminal_values,
    terminal_values_sde,
};
pub use mlp::{MlpParams, HIDDEN, N_IN, N_PARAMS};
pub use objective::{
    coupled_value_and_grad, coupled_value_and_grad_scenario, loss_only,
    loss_only_scenario, value_and_grad, value_and_grad_scenario,
};
