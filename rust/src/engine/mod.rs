//! Pure-Rust verification engine: the same model as the AOT artifacts
//! (Milstein paths -> hedging MLP -> squared hedging error, and its
//! gradient), hand-written with no JAX/XLA in the loop.
//!
//! Roles:
//! * **cross-validation** — integration tests feed identical increments to
//!   this engine and to the compiled HLO and require matching loss/grad
//!   (`rust/tests/integration_engine_vs_hlo.rs`);
//! * **native backend** — `--backend native` runs the whole training stack
//!   without artifacts (CI without Python, portability);
//! * **benchmarks** — a baseline the runtime's hot path is compared to.

pub mod milstein;
pub mod mlp;
pub mod objective;

pub use milstein::simulate_paths;
pub use mlp::{MlpParams, HIDDEN, N_IN, N_PARAMS};
pub use objective::{coupled_value_and_grad, loss_only, value_and_grad};
