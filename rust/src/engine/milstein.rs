//! Milstein SDE integrator — Rust mirror of the L1 Pallas kernel
//! (`python/compile/kernels/milstein.py`) and its jnp oracle, generalized
//! to D-dimensional dynamics and **streaming** consumption.
//!
//! Per-factor scheme for `dS_k = a_k(S) dt + b_k(S) dB_k` (strong order 1
//! for commutative noise):
//!
//! `S_k+ = S_k + a_k(S) dt + b_k(S) dW_k + 1/2 b_k(S) b_k'(S) (dW_k^2 - dt)`
//!
//! computed in f32 with the same operation order as the kernel so the
//! cross-check tests can use tight tolerances.
//!
//! The core is [`fold_path`]: it integrates ONE path and hands every
//! state (including `S_0`) to a visitor closure, so consumers — the
//! streaming objective, terminal-value diagnostics, payoff observers —
//! fold the path online and nothing allocates a `batch x (n_steps + 1)`
//! buffer. The D = 1 branch is written out scalar so concrete-SDE
//! callers monomorphize to the seed engine's exact inner loop
//! (bit-identical f32 operation order); the D >= 2 branch applies the
//! driver correlation (`dW_1 = rho dW_0^raw + sqrt(1 - rho^2) dW_1^raw`)
//! and updates the factors jointly from the pre-step state.
//!
//! Increment batches are **factor-major** `dw[n_factors, batch, n_steps]`
//! (see [`crate::rng::BrownianSource::increments_multi`]); for D = 1 that
//! is exactly the seed's row-major `[batch, n_steps]` layout, so every
//! seed-era call site is untouched. [`simulate_paths`] /
//! [`simulate_paths_sde`] still materialize price rows for diagnostics,
//! cross-checks and tests — implemented on top of the fold.

use crate::hedging::Problem;
use crate::scenarios::sde::{BlackScholes, State, MAX_DIM};
use crate::scenarios::Sde;

/// The per-factor increment rows of sample `b` in a factor-major batch
/// `dw[dim, batch, n_steps]`; inactive factor slots get empty slices.
/// Pass `&rows[..dim]` to [`fold_path`].
#[inline]
pub fn factor_rows<'a>(
    dw: &'a [f32],
    dim: usize,
    batch: usize,
    n_steps: usize,
    b: usize,
) -> [&'a [f32]; MAX_DIM] {
    let mut rows: [&[f32]; MAX_DIM] = [&[]; MAX_DIM];
    for (k, row) in rows.iter_mut().enumerate().take(dim) {
        let off = (k * batch + b) * n_steps;
        *row = &dw[off..off + n_steps];
    }
    rows
}

/// Integrate one path of `sde` and hand every state to `visit(t, state)`
/// for `t = 0..=n_steps` (`t = 0` is the initial state). `rows[k]` is the
/// factor-`k` increment row (`n_steps` entries); `rows.len()` must equal
/// `sde.dim()`.
///
/// Generic (`S: Sde + ?Sized`) so concrete-SDE callers monomorphize and
/// keep the seed engine's inlined inner loop, while `&dyn Sde` callers
/// (the scenario objective) dispatch dynamically.
#[inline]
pub fn fold_path<S: Sde + ?Sized, F: FnMut(usize, &State)>(
    sde: &S,
    rows: &[&[f32]],
    n_steps: usize,
    dt: f32,
    mut visit: F,
) {
    let dim = sde.dim();
    debug_assert_eq!(rows.len(), dim, "one increment row per factor");
    let mut s = sde.s0_state();
    visit(0, &s);
    if dim == 1 {
        // Monomorphized scalar fast path: the seed recurrence, same f32
        // operation order (the bitwise regression anchors pin this).
        // Slicing to n_steps makes a too-short row panic, exactly like
        // the generic branch's indexing would.
        let row = &rows[0][..n_steps];
        let mut x = s[0];
        for (t, &dwt) in row.iter().enumerate() {
            let drift = sde.drift(x);
            let diff = sde.diffusion(x);
            let corr = sde.milstein_term(x);
            x = sde.clamp(x + drift * dt + diff * dwt + corr * (dwt * dwt - dt));
            s[0] = x;
            visit(t + 1, &s);
        }
    } else {
        let rho = sde.correlation();
        let orth = (1.0 - rho * rho).max(0.0).sqrt();
        let mut next = [0.0f32; MAX_DIM];
        for t in 0..n_steps {
            for k in 0..dim {
                // Correlate factor k >= 1 drivers with factor 0's raw
                // increments (2x2 Cholesky; linear, so it commutes with
                // the MLMC pairwise coarsening of the raw factors).
                let dwt = if k == 0 {
                    rows[0][t]
                } else {
                    rho * rows[0][t] + orth * rows[k][t]
                };
                let a = sde.drift_factor(&s, k);
                let b = sde.diffusion_factor(&s, k);
                let m = sde.milstein_factor(&s, k);
                next[k] = sde.clamp_factor(
                    s[k] + a * dt + b * dwt + m * (dwt * dwt - dt),
                    k,
                );
            }
            s[..dim].copy_from_slice(&next[..dim]);
            visit(t + 1, &s);
        }
    }
}

/// Simulate `batch` **price rows** (factor 0) of `sde` over `n_steps`
/// from factor-major increments `dw[dim, batch, n_steps]`; returns
/// row-major `s[batch, n_steps + 1]` (including `S_0`).
///
/// Materializing entry point — kept for diagnostics, HLO cross-checks and
/// tests; the objective hot path streams via [`fold_path`] instead.
pub fn simulate_paths_sde<S: Sde + ?Sized>(
    dw: &[f32],
    batch: usize,
    n_steps: usize,
    sde: &S,
    maturity: f64,
) -> Vec<f32> {
    let dim = sde.dim();
    assert_eq!(dw.len(), dim * batch * n_steps, "dw shape mismatch");
    let dt = (maturity / n_steps as f64) as f32;
    let mut out = vec![0.0f32; batch * (n_steps + 1)];
    for b in 0..batch {
        let rows = factor_rows(dw, dim, batch, n_steps, b);
        let row_s = &mut out[b * (n_steps + 1)..(b + 1) * (n_steps + 1)];
        fold_path(sde, &rows[..dim], n_steps, dt, |t, st| {
            row_s[t] = st[0];
        });
    }
    out
}

/// Simulate the problem's own Black–Scholes dynamics (the default
/// scenario) — the seed engine's entry point, preserved bitwise.
pub fn simulate_paths(
    dw: &[f32],
    batch: usize,
    n_steps: usize,
    problem: &Problem,
) -> Vec<f32> {
    let sde = BlackScholes::from_problem(problem);
    simulate_paths_sde(dw, batch, n_steps, &sde, problem.maturity)
}

/// Terminal prices only, via the streaming core — no per-path buffer is
/// ever allocated (the old implementation materialized the full
/// `batch x (n_steps + 1)` grid just to read its last column).
pub fn terminal_values_sde<S: Sde + ?Sized>(
    dw: &[f32],
    batch: usize,
    n_steps: usize,
    sde: &S,
    maturity: f64,
) -> Vec<f32> {
    let dim = sde.dim();
    assert_eq!(dw.len(), dim * batch * n_steps, "dw shape mismatch");
    let dt = (maturity / n_steps as f64) as f32;
    (0..batch)
        .map(|b| {
            let rows = factor_rows(dw, dim, batch, n_steps, b);
            let mut last = 0.0f32;
            fold_path(sde, &rows[..dim], n_steps, dt, |_, st| {
                last = st[0];
            });
            last
        })
        .collect()
}

/// [`terminal_values_sde`] under the problem's own Black–Scholes
/// dynamics (convenience for diagnostics/cross-checks).
pub fn terminal_values(
    dw: &[f32],
    batch: usize,
    n_steps: usize,
    problem: &Problem,
) -> Vec<f32> {
    let sde = BlackScholes::from_problem(problem);
    terminal_values_sde(dw, batch, n_steps, &sde, problem.maturity)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hedging::Drift;
    use crate::rng::{brownian::Purpose, BrownianSource};
    use crate::scenarios::sde::Heston;

    fn problem() -> Problem {
        Problem::default()
    }

    #[test]
    fn generic_sde_dispatch_matches_seed_loop_bitwise() {
        // The trait-dispatched integrator must reproduce the seed
        // engine's inlined Black–Scholes recurrence EXACTLY (f32 products
        // regrouped differently would drift in the last bit).
        for drift in [Drift::Additive, Drift::Geometric] {
            let p = Problem { drift, ..problem() };
            let batch = 16;
            let n = 32;
            let dw = BrownianSource::new(7).increments(
                Purpose::Diagnostic, 0, 0, 0, batch, n, p.maturity / n as f64,
            );
            let got = simulate_paths(&dw, batch, n, &p);

            // seed recurrence, written out inline
            let dt = (p.maturity / n as f64) as f32;
            let mu = p.mu as f32;
            let sigma = p.sigma as f32;
            let half_s2 = 0.5 * sigma * sigma;
            let geometric = drift == Drift::Geometric;
            let mut want = vec![0.0f32; batch * (n + 1)];
            for b in 0..batch {
                let row_dw = &dw[b * n..(b + 1) * n];
                let mut s = p.s0 as f32;
                want[b * (n + 1)] = s;
                for (t, &dwt) in row_dw.iter().enumerate() {
                    let a = if geometric { mu * s } else { mu };
                    s = s + a * dt + sigma * s * dwt
                        + half_s2 * s * (dwt * dwt - dt);
                    want[b * (n + 1) + t + 1] = s;
                }
            }
            assert_eq!(got, want, "drift {drift:?} not bit-identical");
        }
    }

    #[test]
    fn terminal_values_match_materialized_last_column_bitwise() {
        // The streaming terminal path must be the same recurrence as the
        // materializing one — last column, to the bit.
        let p = problem();
        let batch = 32;
        let n = 64;
        let dw = BrownianSource::new(3).increments(
            Purpose::Diagnostic, 0, 0, 0, batch, n, p.maturity / n as f64,
        );
        let s = simulate_paths(&dw, batch, n, &p);
        let term = terminal_values(&dw, batch, n, &p);
        for b in 0..batch {
            assert_eq!(term[b], s[b * (n + 1) + n], "path {b}");
        }
    }

    #[test]
    fn cir_paths_stay_non_negative() {
        use crate::scenarios::sde::CoxIngersollRoss;
        // Stress the truncation: tiny s0 relative to the noise.
        let sde = CoxIngersollRoss::new(1.5, 0.05, 1.0, 0.05);
        let batch = 64;
        let n = 64;
        let dw = BrownianSource::new(11).increments(
            Purpose::Diagnostic, 0, 0, 0, batch, n, 1.0 / n as f64,
        );
        let s = simulate_paths_sde(&dw, batch, n, &sde, 1.0);
        assert!(s.iter().all(|&v| v >= 0.0 && v.is_finite()));
    }

    #[test]
    fn heston_variance_stays_non_negative_across_levels() {
        // Full truncation must keep the variance factor >= 0 on every
        // grid the MLMC estimator simulates — stress with a high
        // vol-of-vol that violates Feller (2 kappa theta < xi^2).
        let sde = Heston::new(1.0, 1.5, 0.04, 1.0, -0.7, 3.0, 0.04);
        let src = BrownianSource::new(13);
        let p = problem();
        for level in 0..=4usize {
            let n = p.n_steps(level);
            let batch = 128;
            let dw = src.increments_multi(
                Purpose::Diagnostic, 0, level as u32, 0, batch, n,
                p.dt(level), sde.dim(),
            );
            let dt = p.dt(level) as f32;
            for b in 0..batch {
                let rows = factor_rows(&dw, sde.dim(), batch, n, b);
                fold_path(&sde, &rows[..sde.dim()], n, dt, |t, st| {
                    assert!(
                        st[1] >= 0.0 && st[1].is_finite(),
                        "level {level} path {b} step {t}: v = {}",
                        st[1]
                    );
                    assert!(st[0].is_finite());
                });
            }
        }
    }

    #[test]
    fn heston_correlation_induces_price_vol_comovement() {
        // rho < 0 must show up in the *simulated dynamics*: per-step
        // price moves and variance moves, measured on states produced by
        // fold_path, are negatively correlated. (The exact mixing is
        // pinned bitwise by the handwritten-recurrence test below; this
        // checks the end-to-end statistical effect and its sign.)
        let sde = Heston::from_problem(&problem());
        let batch = 2000;
        let n = 16;
        let dw = BrownianSource::new(5).increments_multi(
            Purpose::Diagnostic, 0, 0, 0, batch, n, 1.0 / n as f64, 2,
        );
        let dt = 1.0f32 / n as f32;
        let mut num = 0.0f64;
        let mut d0 = 0.0f64;
        let mut d1 = 0.0f64;
        for b in 0..batch {
            let rows = factor_rows(&dw, 2, batch, n, b);
            let mut prev = [0.0f32; 2];
            fold_path(&sde, &rows[..2], n, dt, |t, st| {
                if t > 0 {
                    let ds = (st[0] - prev[0]) as f64;
                    let dv = (st[1] - prev[1]) as f64;
                    num += ds * dv;
                    d0 += ds * ds;
                    d1 += dv * dv;
                }
                prev = *st;
            });
        }
        let realized = num / (d0 * d1).sqrt();
        assert!(
            realized < -0.3,
            "price/vol comovement too weak for rho = {}: {realized}",
            sde.rho
        );
    }

    #[test]
    fn heston_matches_handwritten_two_factor_recurrence_bitwise() {
        // Pins the generic D=2 loop — INCLUDING the Cholesky correlation
        // placement (factor 0 raw, factor 1 = rho*raw0 + orth*raw1) and
        // the pre-step-state coefficient evaluation — against an inline
        // reference with real noise. A sign/placement bug in the
        // correlation mixing flips these states and fails bitwise.
        let sde = Heston::new(1.0, 1.5, 1.0, 0.5, -0.7, 3.0, 1.0);
        let n = 32;
        let dt = 1.0f32 / n as f32;
        let dw = BrownianSource::new(41).increments_multi(
            Purpose::Diagnostic, 0, 0, 0, 1, n, 1.0 / n as f64, 2,
        );
        let rows = factor_rows(&dw, 2, 1, n, 0);
        let mut got = Vec::new();
        fold_path(&sde, &rows[..2], n, dt, |_, st| got.push(*st));

        let rho = sde.rho;
        let orth = (1.0 - rho * rho).max(0.0).sqrt();
        let mut s = 3.0f32;
        let mut v = 1.0f32;
        let mut want = vec![[s, v]];
        for t in 0..n {
            let dw0 = rows[0][t];
            let dw1 = rho * rows[0][t] + orth * rows[1][t];
            let vol = v.max(0.0).sqrt();
            let s_next = s + (sde.mu * s) * dt
                + (vol * s) * dw0
                + (0.5 * v.max(0.0) * s) * (dw0 * dw0 - dt);
            let v_next = (v + (sde.kappa * (sde.theta - v)) * dt
                + (sde.xi * vol) * dw1
                + (0.25 * sde.xi * sde.xi) * (dw1 * dw1 - dt))
                .max(0.0);
            s = s_next;
            v = v_next;
            want.push([s, v]);
        }
        assert_eq!(got, want, "2-factor recurrence drifted");
    }

    #[test]
    fn heston_zero_noise_recurrence() {
        // dW = 0 for both factors: deterministic Milstein drift steps.
        let sde = Heston::new(1.0, 1.5, 1.0, 0.5, -0.7, 3.0, 1.0);
        let n = 8;
        let dt = 1.0 / n as f32;
        let dw = vec![0.0f32; 2 * n];
        let rows = factor_rows(&dw, 2, 1, n, 0);
        let mut states = Vec::new();
        fold_path(&sde, &rows[..2], n, dt, |_, st| states.push(*st));
        assert_eq!(states.len(), n + 1);
        let mut s = 3.0f32;
        let mut v = 1.0f32;
        for t in 0..n {
            let s_next = s + sde.mu * s * dt
                - 0.5 * v.max(0.0) * s * dt;
            let v_next =
                (v + sde.kappa * (sde.theta - v) * dt - 0.25 * sde.xi * sde.xi * dt)
                    .max(0.0);
            s = s_next;
            v = v_next;
            assert!((states[t + 1][0] - s).abs() < 1e-6, "step {t} price");
            assert!((states[t + 1][1] - v).abs() < 1e-6, "step {t} var");
        }
    }

    #[test]
    fn initial_value_and_shape() {
        let p = problem();
        let dw = vec![0.1f32; 3 * 4];
        let s = simulate_paths(&dw, 3, 4, &p);
        assert_eq!(s.len(), 3 * 5);
        for b in 0..3 {
            assert_eq!(s[b * 5], p.s0 as f32);
        }
    }

    #[test]
    fn zero_noise_recurrence() {
        // dW = 0: S+ = S + mu dt - 1/2 sigma^2 S dt (additive drift).
        let p = problem();
        let n = 8;
        let dw = vec![0.0f32; n];
        let s = simulate_paths(&dw, 1, n, &p);
        let dt = (p.maturity / n as f64) as f32;
        let mut want = p.s0 as f32;
        for t in 0..n {
            want = want + p.mu as f32 * dt
                - 0.5 * (p.sigma as f32).powi(2) * want * dt;
            assert!((s[t + 1] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn geometric_zero_noise() {
        let p = Problem {
            drift: Drift::Geometric,
            ..problem()
        };
        let n = 4;
        let s = simulate_paths(&vec![0.0; n], 1, n, &p);
        let dt = (p.maturity / n as f64) as f32;
        let mut want = p.s0 as f32;
        for t in 0..n {
            want = want + p.mu as f32 * want * dt
                - 0.5 * (p.sigma as f32).powi(2) * want * dt;
            assert!((s[t + 1] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn strong_convergence_of_coupling() {
        // MSE between fine and coarse terminal values must shrink ~4x per
        // level for a strong-order-1 scheme (Assumption 2 with b ~ 2).
        let p = problem();
        let src = BrownianSource::new(99);
        let batch = 2000;
        let mut errs = Vec::new();
        for level in 1..=5usize {
            let n = p.n_steps(level);
            let dw = src.increments(
                Purpose::Diagnostic, 0, level as u32, 0, batch, n, p.dt(level),
            );
            let fine = terminal_values(&dw, batch, n, &p);
            let dwc = BrownianSource::coarsen(&dw, batch, n);
            let coarse = terminal_values(&dwc, batch, n / 2, &p);
            let mse = fine
                .iter()
                .zip(&coarse)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                / batch as f64;
            errs.push(mse);
        }
        for w in errs.windows(2) {
            assert!(w[1] < w[0] * 0.6, "errors not decaying: {errs:?}");
        }
    }

    #[test]
    fn heston_coupling_decays() {
        // Fine/coarse terminal-price MSE under the 2-factor dynamics with
        // per-factor coarsening of the raw increments.
        let sde = Heston::from_problem(&problem());
        let p = problem();
        let src = BrownianSource::new(23);
        let batch = 2000;
        let mut errs = Vec::new();
        for level in 1..=4usize {
            let n = p.n_steps(level);
            let dw = src.increments_multi(
                Purpose::Diagnostic, 0, level as u32, 0, batch, n,
                p.dt(level), 2,
            );
            let fine = terminal_values_sde(&dw, batch, n, &sde, p.maturity);
            let dwc = BrownianSource::coarsen_multi(&dw, 2, batch, n);
            let coarse =
                terminal_values_sde(&dwc, batch, n / 2, &sde, p.maturity);
            let mse = fine
                .iter()
                .zip(&coarse)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                / batch as f64;
            errs.push(mse);
        }
        for w in errs.windows(2) {
            assert!(w[1] < w[0] * 0.75, "heston MSE not decaying: {errs:?}");
        }
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn shape_mismatch_panics() {
        simulate_paths(&[0.0; 7], 2, 4, &problem());
    }
}
