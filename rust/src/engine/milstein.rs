//! Milstein SDE integrator — Rust mirror of the L1 Pallas kernel
//! (`python/compile/kernels/milstein.py`) and its jnp oracle.
//!
//! Scheme for `dS = a(S) dt + b(S) dB` (strong order 1):
//!
//! `S+ = S + a(S) dt + b(S) dW + 1/2 b(S) b'(S) (dW^2 - dt)`
//!
//! computed in f32 with the same operation order as the kernel so the
//! cross-check tests can use tight tolerances. The coefficients come from
//! an [`Sde`]; the [`simulate_paths`] entry point wraps the problem's own
//! Black–Scholes dynamics and is bit-identical to the pre-scenario
//! engine (the SDE returns the seed's exact f32 coefficient groupings).

use crate::hedging::Problem;
use crate::scenarios::sde::BlackScholes;
use crate::scenarios::Sde;

/// Simulate `batch` paths of `sde` over `n_steps` from row-major
/// increments `dw[batch, n_steps]`; returns row-major
/// `s[batch, n_steps + 1]` (including `S_0`).
///
/// Generic (`S: Sde + ?Sized`) so concrete-SDE callers like
/// [`simulate_paths`] monomorphize and keep the seed engine's inlined
/// inner loop, while `&dyn Sde` callers (the scenario objective) still
/// dispatch dynamically.
pub fn simulate_paths_sde<S: Sde + ?Sized>(
    dw: &[f32],
    batch: usize,
    n_steps: usize,
    sde: &S,
    maturity: f64,
) -> Vec<f32> {
    assert_eq!(dw.len(), batch * n_steps, "dw shape mismatch");
    let dt = (maturity / n_steps as f64) as f32;
    let mut out = vec![0.0f32; batch * (n_steps + 1)];
    for b in 0..batch {
        let row_dw = &dw[b * n_steps..(b + 1) * n_steps];
        let row_s = &mut out[b * (n_steps + 1)..(b + 1) * (n_steps + 1)];
        let mut s = sde.s0();
        row_s[0] = s;
        for (t, &dwt) in row_dw.iter().enumerate() {
            let drift = sde.drift(s);
            let diff = sde.diffusion(s);
            let corr = sde.milstein_term(s);
            s = sde.clamp(s + drift * dt + diff * dwt + corr * (dwt * dwt - dt));
            row_s[t + 1] = s;
        }
    }
    out
}

/// Simulate the problem's own Black–Scholes dynamics (the default
/// scenario) — the seed engine's entry point, preserved bitwise.
pub fn simulate_paths(
    dw: &[f32],
    batch: usize,
    n_steps: usize,
    problem: &Problem,
) -> Vec<f32> {
    let sde = BlackScholes::from_problem(problem);
    simulate_paths_sde(dw, batch, n_steps, &sde, problem.maturity)
}

/// Terminal values only (convenience for diagnostics/cross-checks).
pub fn terminal_values(
    dw: &[f32],
    batch: usize,
    n_steps: usize,
    problem: &Problem,
) -> Vec<f32> {
    let s = simulate_paths(dw, batch, n_steps, problem);
    (0..batch).map(|b| s[b * (n_steps + 1) + n_steps]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hedging::Drift;
    use crate::rng::{brownian::Purpose, BrownianSource};

    fn problem() -> Problem {
        Problem::default()
    }

    #[test]
    fn generic_sde_dispatch_matches_seed_loop_bitwise() {
        // The trait-dispatched integrator must reproduce the seed
        // engine's inlined Black–Scholes recurrence EXACTLY (f32 products
        // regrouped differently would drift in the last bit).
        for drift in [Drift::Additive, Drift::Geometric] {
            let p = Problem { drift, ..problem() };
            let batch = 16;
            let n = 32;
            let dw = BrownianSource::new(7).increments(
                Purpose::Diagnostic, 0, 0, 0, batch, n, p.maturity / n as f64,
            );
            let got = simulate_paths(&dw, batch, n, &p);

            // seed recurrence, written out inline
            let dt = (p.maturity / n as f64) as f32;
            let mu = p.mu as f32;
            let sigma = p.sigma as f32;
            let half_s2 = 0.5 * sigma * sigma;
            let geometric = drift == Drift::Geometric;
            let mut want = vec![0.0f32; batch * (n + 1)];
            for b in 0..batch {
                let row_dw = &dw[b * n..(b + 1) * n];
                let mut s = p.s0 as f32;
                want[b * (n + 1)] = s;
                for (t, &dwt) in row_dw.iter().enumerate() {
                    let a = if geometric { mu * s } else { mu };
                    s = s + a * dt + sigma * s * dwt
                        + half_s2 * s * (dwt * dwt - dt);
                    want[b * (n + 1) + t + 1] = s;
                }
            }
            assert_eq!(got, want, "drift {drift:?} not bit-identical");
        }
    }

    #[test]
    fn cir_paths_stay_non_negative() {
        use crate::scenarios::sde::CoxIngersollRoss;
        // Stress the truncation: tiny s0 relative to the noise.
        let sde = CoxIngersollRoss::new(1.5, 0.05, 1.0, 0.05);
        let batch = 64;
        let n = 64;
        let dw = BrownianSource::new(11).increments(
            Purpose::Diagnostic, 0, 0, 0, batch, n, 1.0 / n as f64,
        );
        let s = simulate_paths_sde(&dw, batch, n, &sde, 1.0);
        assert!(s.iter().all(|&v| v >= 0.0 && v.is_finite()));
    }

    #[test]
    fn initial_value_and_shape() {
        let p = problem();
        let dw = vec![0.1f32; 3 * 4];
        let s = simulate_paths(&dw, 3, 4, &p);
        assert_eq!(s.len(), 3 * 5);
        for b in 0..3 {
            assert_eq!(s[b * 5], p.s0 as f32);
        }
    }

    #[test]
    fn zero_noise_recurrence() {
        // dW = 0: S+ = S + mu dt - 1/2 sigma^2 S dt (additive drift).
        let p = problem();
        let n = 8;
        let dw = vec![0.0f32; n];
        let s = simulate_paths(&dw, 1, n, &p);
        let dt = (p.maturity / n as f64) as f32;
        let mut want = p.s0 as f32;
        for t in 0..n {
            want = want + p.mu as f32 * dt
                - 0.5 * (p.sigma as f32).powi(2) * want * dt;
            assert!((s[t + 1] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn geometric_zero_noise() {
        let p = Problem {
            drift: Drift::Geometric,
            ..problem()
        };
        let n = 4;
        let s = simulate_paths(&vec![0.0; n], 1, n, &p);
        let dt = (p.maturity / n as f64) as f32;
        let mut want = p.s0 as f32;
        for t in 0..n {
            want = want + p.mu as f32 * want * dt
                - 0.5 * (p.sigma as f32).powi(2) * want * dt;
            assert!((s[t + 1] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn strong_convergence_of_coupling() {
        // MSE between fine and coarse terminal values must shrink ~4x per
        // level for a strong-order-1 scheme (Assumption 2 with b ~ 2).
        let p = problem();
        let src = BrownianSource::new(99);
        let batch = 2000;
        let mut errs = Vec::new();
        for level in 1..=5usize {
            let n = p.n_steps(level);
            let dw = src.increments(
                Purpose::Diagnostic, 0, level as u32, 0, batch, n, p.dt(level),
            );
            let fine = terminal_values(&dw, batch, n, &p);
            let dwc = BrownianSource::coarsen(&dw, batch, n);
            let coarse = terminal_values(&dwc, batch, n / 2, &p);
            let mse = fine
                .iter()
                .zip(&coarse)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                / batch as f64;
            errs.push(mse);
        }
        for w in errs.windows(2) {
            assert!(w[1] < w[0] * 0.6, "errors not decaying: {errs:?}");
        }
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn shape_mismatch_panics() {
        simulate_paths(&[0.0; 7], 2, 4, &problem());
    }
}
