//! Lane-blocked objective hot path: [`LANES`] paths integrated
//! simultaneously through explicit `[f32; LANES]` arrays (no nightly
//! `std::simd`), with the hedging MLP forwarded/backpropagated
//! [`LANES`] residual rows per call ([`super::mlp::forward_rows8`] /
//! [`super::mlp::backward_rows8`]).
//!
//! # Layout
//!
//! The batch is cut into `batch / LANES` blocks of consecutive paths.
//! Per block, [`crate::rng::BrownianSource::lane_block`] transposes the
//! factor-major increments into step-major lane rows
//! (`dw[(k * n_steps + t) * LANES + l]`), so the integrator, the gains
//! accumulation and the streaming payoff observers all sweep contiguous
//! 8-wide vectors in their inner loops. The `batch % LANES` remainder
//! paths fold through the **scalar** body
//! ([`super::objective::accumulate_range`]) — no duplicated arithmetic.
//!
//! # Numerical contract
//!
//! Per lane, the SDE recurrence performs the *same f32 operations in the
//! same order* as the scalar [`super::milstein::fold_path`], so path
//! states — and with them every payoff observation, including barrier
//! hits and digital indicator flips — are **bit-identical** to the
//! scalar reference. What differs: the MLP uses the branchless polynomial
//! `exp` (relative error ~1e-6) and the parameter gradients are
//! lane-summed (f32 reassociation). That is why these kernels register
//! under `*-simd` scenario keys with tolerance-based validation
//! ([`crate::scenarios::kernels`]) instead of joining the bitwise
//! anchors.
//!
//! Entry points are generic over **concrete** `S: Sde, P: Payoff` so the
//! static kernel registry monomorphizes one instantiation per scenario —
//! no virtual call anywhere in the per-step loop.

use super::mlp::{
    backward_rows8, forward_rows8, MlpParams, RowTape8, LANES, N_PARAMS, OFF_P0,
};
use super::objective::accumulate_range;
use crate::hedging::Problem;
use crate::rng::BrownianSource;
use crate::scenarios::payoff::PathAccum;
use crate::scenarios::sde::{State, MAX_DIM};
use crate::scenarios::{Payoff, Sde};

/// Loss + gradient of the mean objective on one grid — lane-blocked
/// mirror of [`super::objective::value_and_grad_scenario`] over concrete
/// scenario components.
pub fn value_and_grad<S: Sde, P: Payoff>(
    params: &[f32],
    dw: &[f32],
    batch: usize,
    n_steps: usize,
    problem: &Problem,
    sde: &S,
    payoff: &P,
) -> (f64, Vec<f32>) {
    let mut grad = vec![0.0f32; N_PARAMS];
    let total = accumulate_lanes(
        params, dw, batch, n_steps, problem, sde, payoff, 1.0, &mut grad,
    );
    (total / batch as f64, grad)
}

/// Coupled `Delta_l` loss + gradient from fine-grid increments —
/// lane-blocked mirror of
/// [`super::objective::coupled_value_and_grad_scenario`].
pub fn coupled_value_and_grad<S: Sde, P: Payoff>(
    params: &[f32],
    dw_fine: &[f32],
    batch: usize,
    level: usize,
    problem: &Problem,
    sde: &S,
    payoff: &P,
) -> (f64, Vec<f32>) {
    let n_fine = problem.n_steps(level);
    let mut grad = vec![0.0f32; N_PARAMS];
    let mut loss = accumulate_lanes(
        params, dw_fine, batch, n_fine, problem, sde, payoff, 1.0, &mut grad,
    ) / batch as f64;
    if level > 0 {
        let dw_coarse =
            BrownianSource::coarsen_multi(dw_fine, sde.dim(), batch, n_fine);
        loss -= accumulate_lanes(
            params, &dw_coarse, batch, n_fine / 2, problem, sde, payoff, -1.0,
            &mut grad,
        ) / batch as f64;
    }
    (loss, grad)
}

/// Loss only — lane-blocked mirror of
/// [`super::objective::loss_only_scenario`]. Integration and MLP forward
/// run lane-blocked; the remainder reuses the scalar gradient body with a
/// scratch gradient (at most `LANES - 1` paths, negligible).
pub fn loss_only<S: Sde, P: Payoff>(
    params: &[f32],
    dw: &[f32],
    batch: usize,
    n_steps: usize,
    problem: &Problem,
    sde: &S,
    payoff: &P,
) -> f64 {
    let dim = sde.dim();
    assert_eq!(dw.len(), dim * batch * n_steps, "dw shape mismatch");
    let p = MlpParams::new(params);
    let dt = (problem.maturity / n_steps as f64) as f32;
    let dt_grid = problem.maturity as f32 / n_steps as f32;
    let n_blocks = batch / LANES;
    let mut lane_dw = vec![0.0f32; dim * n_steps * LANES];
    let mut total = 0.0f64;
    for blk in 0..n_blocks {
        BrownianSource::lane_block(
            dw, dim, batch, n_steps, blk * LANES, LANES, &mut lane_dw,
        );
        let r = integrate_block(
            &p, &lane_dw, n_steps, dt, dt_grid, sde, payoff, &mut NoTapes,
        );
        for l in 0..LANES {
            total += (r[l] as f64) * (r[l] as f64);
        }
    }
    let rem_start = n_blocks * LANES;
    if rem_start < batch {
        let mut scratch = vec![0.0f32; N_PARAMS];
        total += accumulate_range(
            params, dw, batch, n_steps, problem, sde, payoff, 1.0,
            &mut scratch, rem_start, batch,
        );
    }
    total / batch as f64
}

/// Shared lane-blocked fwd+bwd, the mirror of the scalar
/// [`accumulate_range`] over the whole batch: returns the raw `sum r^2`
/// and accumulates `sign * grad` into `grad`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn accumulate_lanes<S: Sde, P: Payoff>(
    params: &[f32],
    dw: &[f32],
    batch: usize,
    n_steps: usize,
    problem: &Problem,
    sde: &S,
    payoff: &P,
    sign: f32,
    grad: &mut [f32],
) -> f64 {
    let dim = sde.dim();
    assert_eq!(dw.len(), dim * batch * n_steps, "dw shape mismatch");
    let p = MlpParams::new(params);
    let dt = (problem.maturity / n_steps as f64) as f32;
    let dt_grid = problem.maturity as f32 / n_steps as f32;
    let inv_b = 1.0f32 / batch as f32;

    let n_blocks = batch / LANES;
    let mut lane_dw = vec![0.0f32; dim * n_steps * LANES];
    let mut rec = TapeRecorder {
        tapes: Vec::with_capacity(n_steps),
        ds: vec![[0.0f32; LANES]; n_steps],
    };
    let mut total = 0.0f64;
    for blk in 0..n_blocks {
        BrownianSource::lane_block(
            dw, dim, batch, n_steps, blk * LANES, LANES, &mut lane_dw,
        );
        rec.tapes.clear();
        let r = integrate_block(
            &p, &lane_dw, n_steps, dt, dt_grid, sde, payoff, &mut rec,
        );
        let mut dr = [0.0f32; LANES];
        for l in 0..LANES {
            total += (r[l] as f64) * (r[l] as f64);
            dr[l] = sign * 2.0 * r[l] * inv_b;
            grad[OFF_P0] += -dr[l];
        }
        for n in 0..n_steps {
            let mut g = [0.0f32; LANES];
            for l in 0..LANES {
                g[l] = -dr[l] * rec.ds[n][l];
            }
            backward_rows8(&p, &rec.tapes[n], &g, grad);
        }
    }

    let rem_start = n_blocks * LANES;
    if rem_start < batch {
        total += accumulate_range(
            params, dw, batch, n_steps, problem, sde, payoff, sign, grad,
            rem_start, batch,
        );
    }
    total
}

/// What [`integrate_block`] records per step: the gradient path keeps the
/// MLP tapes + price increments, the loss-only path keeps nothing.
trait StepSink {
    fn record(&mut self, t: usize, ds: &[f32; LANES], tape: Option<RowTape8>);
}

struct TapeRecorder {
    tapes: Vec<RowTape8>,
    ds: Vec<[f32; LANES]>,
}

impl StepSink for TapeRecorder {
    #[inline]
    fn record(&mut self, t: usize, ds: &[f32; LANES], tape: Option<RowTape8>) {
        self.ds[t - 1] = *ds;
        if let Some(tape) = tape {
            self.tapes.push(tape);
        }
    }
}

struct NoTapes;

impl StepSink for NoTapes {
    #[inline]
    fn record(&mut self, _t: usize, _ds: &[f32; LANES], _tape: Option<RowTape8>) {}
}

/// Integrate one block of [`LANES`] paths from step-major lane increments
/// (`lane_dw[(k * n_steps + t) * LANES + l]`), streaming the MLP forward
/// pass, gains and payoff observers exactly like the scalar fold, and
/// returning the per-lane residuals `r = payoff - gains - p0`.
///
/// The tape for step `t < n_steps` (and the price increment of step
/// `t >= 1`) goes to `sink` — the forward tape of the last state is never
/// produced, mirroring the scalar `t < n_steps` guard.
#[allow(clippy::too_many_arguments)]
#[inline]
fn integrate_block<S: Sde, P: Payoff, K: StepSink>(
    p: &MlpParams,
    lane_dw: &[f32],
    n_steps: usize,
    dt: f32,
    dt_grid: f32,
    sde: &S,
    payoff: &P,
    sink: &mut K,
) -> [f32; LANES] {
    let dim = sde.dim();
    let s0 = sde.s0_state();
    // Current state, factor-major lane vectors.
    let mut x = [[0.0f32; LANES]; MAX_DIM];
    for k in 0..dim {
        for l in 0..LANES {
            x[k][l] = s0[k];
        }
    }
    let mut acc = [PathAccum::default(); LANES];
    for a in acc.iter_mut() {
        *a = payoff.init(&s0);
    }
    let mut gains = [0.0f32; LANES];
    let mut prev = x[0];
    let (mut pending_h, tape) = forward_rows8(p, 0.0, &x[0]);
    let mut pending_tape = Some(tape);

    let (rho, orth) = if dim > 1 {
        let rho = sde.correlation();
        (rho, (1.0 - rho * rho).max(0.0).sqrt())
    } else {
        (0.0, 0.0)
    };

    for t in 1..=n_steps {
        let row0 = &lane_dw[(t - 1) * LANES..t * LANES];
        if dim == 1 {
            // Per lane: the scalar fold's exact recurrence and f32
            // operation order — lane states stay bit-identical to the
            // scalar reference.
            for l in 0..LANES {
                let xv = x[0][l];
                let dwt = row0[l];
                let drift = sde.drift(xv);
                let diff = sde.diffusion(xv);
                let corr = sde.milstein_term(xv);
                x[0][l] = sde.clamp(
                    xv + drift * dt + diff * dwt + corr * (dwt * dwt - dt),
                );
            }
        } else {
            for l in 0..LANES {
                let mut st: State = [0.0; MAX_DIM];
                for k in 0..dim {
                    st[k] = x[k][l];
                }
                for k in 0..dim {
                    let dwt = if k == 0 {
                        row0[l]
                    } else {
                        let raw = lane_dw[(k * n_steps + t - 1) * LANES + l];
                        rho * row0[l] + orth * raw
                    };
                    let a = sde.drift_factor(&st, k);
                    let b = sde.diffusion_factor(&st, k);
                    let m = sde.milstein_factor(&st, k);
                    x[k][l] = sde.clamp_factor(
                        st[k] + a * dt + b * dwt + m * (dwt * dwt - dt),
                        k,
                    );
                }
            }
        }

        let mut ds = [0.0f32; LANES];
        for l in 0..LANES {
            let s_t = x[0][l];
            let d = s_t - prev[l];
            ds[l] = d;
            gains[l] += pending_h[l] * d;
            let mut st: State = [0.0; MAX_DIM];
            st[0] = s_t;
            for k in 1..dim {
                st[k] = x[k][l];
            }
            payoff.observe(&mut acc[l], t, n_steps, &st);
            prev[l] = s_t;
        }
        let tape = if t < n_steps {
            let (h, tape) = forward_rows8(p, t as f32 * dt_grid, &x[0]);
            pending_h = h;
            Some(tape)
        } else {
            None
        };
        sink.record(t, &ds, pending_tape.take());
        pending_tape = tape;
    }

    let mut r = [0.0f32; LANES];
    for l in 0..LANES {
        r[l] = payoff.finish(&acc[l], n_steps) - gains[l] - p.p0();
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::mlp::init_params;
    use crate::engine::objective::{
        coupled_value_and_grad_scenario, loss_only_scenario,
        value_and_grad_scenario,
    };
    use crate::rng::{brownian::Purpose, BrownianSource};
    use crate::scenarios::build_scenario;
    use crate::scenarios::payoff::{EuropeanCall, UpAndOutCall};
    use crate::scenarios::sde::{BlackScholes, Heston};

    fn rel_close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * a.abs().max(b.abs()).max(1.0)
    }

    fn grads_close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() <= tol * x.abs().max(y.abs()).max(1.0),
                "grad[{i}]: lane {x} vs scalar {y}"
            );
        }
    }

    #[test]
    fn lane_bs_call_matches_scalar_within_tolerance() {
        // batch = 19 exercises two full blocks + a 3-path remainder.
        let prob = Problem::default();
        let params = init_params(0);
        let sde = BlackScholes::from_problem(&prob);
        let payoff = EuropeanCall {
            strike: prob.strike as f32,
        };
        let sc = build_scenario("bs-call", &prob).unwrap();
        let batch = 19;
        let n = prob.n_steps(2);
        let dw = BrownianSource::new(7)
            .increments(Purpose::Grad, 0, 2, 0, batch, n, prob.dt(2));
        let (ll, gl) =
            value_and_grad(&params, &dw, batch, n, &prob, &sde, &payoff);
        let (ls, gs) = value_and_grad_scenario(&params, &dw, batch, n, &prob, &sc);
        assert!(rel_close(ll, ls, 1e-4), "loss {ll} vs {ls}");
        grads_close(&gl, &gs, 1e-3);
        let lo = loss_only(&params, &dw, batch, n, &prob, &sde, &payoff);
        assert!(rel_close(lo, ll, 1e-9), "loss_only {lo} vs {ll}");
    }

    #[test]
    fn lane_coupled_heston_barrier_matches_scalar_within_tolerance() {
        let prob = Problem::default();
        let params = init_params(3);
        let sde = Heston::from_problem(&prob);
        let payoff = UpAndOutCall {
            strike: prob.strike as f32,
            barrier: (prob.s0 * crate::scenarios::registry::UP_BARRIER_MULT) as f32,
        };
        let sc = build_scenario("heston-uo-call", &prob).unwrap();
        let batch = 27;
        for level in [0usize, 2] {
            let n = prob.n_steps(level);
            let dw = BrownianSource::new(13).increments_multi(
                Purpose::Grad, 0, level as u32, 0, batch, n, prob.dt(level), 2,
            );
            let (ll, gl) = coupled_value_and_grad(
                &params, &dw, batch, level, &prob, &sde, &payoff,
            );
            let (ls, gs) = coupled_value_and_grad_scenario(
                &params, &dw, batch, level, &prob, &sc,
            );
            assert!(rel_close(ll, ls, 1e-3), "l{level}: loss {ll} vs {ls}");
            grads_close(&gl, &gs, 5e-3);
        }
    }

    #[test]
    fn lane_batch_smaller_than_block_is_pure_scalar_fallback() {
        // batch < LANES: the whole batch is remainder, which routes
        // through the scalar body — results must be bit-identical.
        let prob = Problem::default();
        let params = init_params(1);
        let sde = BlackScholes::from_problem(&prob);
        let payoff = EuropeanCall {
            strike: prob.strike as f32,
        };
        let sc = build_scenario("bs-call", &prob).unwrap();
        let batch = LANES - 1;
        let n = prob.n_steps(1);
        let dw = BrownianSource::new(3)
            .increments(Purpose::Grad, 0, 1, 0, batch, n, prob.dt(1));
        let (ll, gl) =
            value_and_grad(&params, &dw, batch, n, &prob, &sde, &payoff);
        let (ls, gs) = value_and_grad_scenario(&params, &dw, batch, n, &prob, &sc);
        assert_eq!(ll, ls);
        assert_eq!(gl, gs);
    }
}
