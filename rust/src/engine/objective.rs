//! Deep-hedging objective and its gradient — the native mirror of the L2
//! JAX model (`python/compile/model.py`), generalized over a
//! [`Scenario`] (SDE dynamics x path payoff).
//!
//! Loss on one grid:  `L = mean_i r_i^2` with per-path residual
//! `r_i = payoff(S_i) - sum_n H(t_n, S_in) (S_i,n+1 - S_in) - p0`
//! (the default scenario's payoff is `max(S_i(T) - K, 0)`).
//!
//! The gradient is assembled by hand:
//! `dL/dr_i = 2 r_i / B`, `dr_i/dp0 = -1`, `dr_i/dH_in = -dS_in`, and the
//! MLP rows are backpropagated with [`super::mlp::backward_row`]. The path
//! `S` is exogenous (independent of the parameters), exactly as in the JAX
//! model (`stop_gradient` on the path) — which is also why any payoff
//! slots in: it contributes a residual value, never its own gradient.
//!
//! # Streaming hot path
//!
//! The objective **streams**: each path is integrated step by step
//! ([`super::milstein::fold_path`]) while the hedging MLP forward pass,
//! the gains accumulation and the payoff observer
//! (`init → observe → finish`, see [`crate::scenarios::payoff`]) fold the
//! states online. The only per-call scratch is `O(n_steps)` (the reused
//! forward tapes and the price-increment row the backward pass needs) —
//! the seed engine's `batch x (n_steps + 1)` path materialization is
//! gone from the hot path. Every per-sample f32 operation has the same
//! inputs and order as the materialized seed loop, so the default
//! scenario's loss/gradients are **bit-identical** (anchored by the
//! regression tests below).
//!
//! The `*_scenario` entry points take an explicit [`Scenario`]; the plain
//! entry points run the problem's default scenario. Increment batches are
//! factor-major `dw[dim, batch, n_steps]` with `dim = sde.dim()` — for
//! the 1-D dynamics exactly the seed layout.

use super::milstein::{factor_rows, fold_path};
use super::mlp::{backward_row, forward_row, MlpParams, N_PARAMS, OFF_P0};
use crate::hedging::Problem;
use crate::rng::BrownianSource;
use crate::scenarios::payoff::{EuropeanCall, PathAccum};
use crate::scenarios::sde::BlackScholes;
use crate::scenarios::{Payoff, Scenario, Sde};

/// Loss + gradient of the mean objective on one grid.
///
/// `dw` is row-major `[batch, n_steps]`. Returns `(loss, grad[N_PARAMS])`.
/// Runs the default scenario through *concrete* SDE/payoff types, so the
/// inner loop stays monomorphized exactly like the seed engine.
pub fn value_and_grad(
    params: &[f32],
    dw: &[f32],
    batch: usize,
    n_steps: usize,
    problem: &Problem,
) -> (f64, Vec<f32>) {
    let sde = BlackScholes::from_problem(problem);
    let payoff = EuropeanCall {
        strike: problem.strike as f32,
    };
    value_and_grad_impl(params, dw, batch, n_steps, problem, &sde, &payoff)
}

/// [`value_and_grad`] under an explicit scenario (dynamic dispatch).
pub fn value_and_grad_scenario(
    params: &[f32],
    dw: &[f32],
    batch: usize,
    n_steps: usize,
    problem: &Problem,
    scenario: &Scenario,
) -> (f64, Vec<f32>) {
    value_and_grad_impl(
        params,
        dw,
        batch,
        n_steps,
        problem,
        &*scenario.sde,
        &*scenario.payoff,
    )
}

pub(crate) fn value_and_grad_impl<S: Sde + ?Sized, P: Payoff + ?Sized>(
    params: &[f32],
    dw: &[f32],
    batch: usize,
    n_steps: usize,
    problem: &Problem,
    sde: &S,
    payoff: &P,
) -> (f64, Vec<f32>) {
    let mut grad = vec![0.0f32; N_PARAMS];
    let loss = accumulate_value_and_grad(
        params, dw, batch, n_steps, problem, sde, payoff, 1.0, &mut grad,
    );
    (loss, grad)
}

/// Loss + gradient of the mean *coupled* objective
/// `Delta_l F = F_l - F_{l-1}` from fine-grid increments (level >= 1), or
/// plain `F_0` at level 0. Monomorphized default scenario, like
/// [`value_and_grad`].
pub fn coupled_value_and_grad(
    params: &[f32],
    dw_fine: &[f32],
    batch: usize,
    level: usize,
    problem: &Problem,
) -> (f64, Vec<f32>) {
    let sde = BlackScholes::from_problem(problem);
    let payoff = EuropeanCall {
        strike: problem.strike as f32,
    };
    coupled_value_and_grad_impl(params, dw_fine, batch, level, problem, &sde, &payoff)
}

/// [`coupled_value_and_grad`] under an explicit scenario (dynamic
/// dispatch).
pub fn coupled_value_and_grad_scenario(
    params: &[f32],
    dw_fine: &[f32],
    batch: usize,
    level: usize,
    problem: &Problem,
    scenario: &Scenario,
) -> (f64, Vec<f32>) {
    coupled_value_and_grad_impl(
        params,
        dw_fine,
        batch,
        level,
        problem,
        &*scenario.sde,
        &*scenario.payoff,
    )
}

pub(crate) fn coupled_value_and_grad_impl<S: Sde + ?Sized, P: Payoff + ?Sized>(
    params: &[f32],
    dw_fine: &[f32],
    batch: usize,
    level: usize,
    problem: &Problem,
    sde: &S,
    payoff: &P,
) -> (f64, Vec<f32>) {
    let n_fine = problem.n_steps(level);
    let mut grad = vec![0.0f32; N_PARAMS];
    let mut loss = accumulate_value_and_grad(
        params, dw_fine, batch, n_fine, problem, sde, payoff, 1.0, &mut grad,
    );
    if level > 0 {
        let dw_coarse =
            BrownianSource::coarsen_multi(dw_fine, sde.dim(), batch, n_fine);
        loss += accumulate_value_and_grad(
            params, &dw_coarse, batch, n_fine / 2, problem, sde, payoff, -1.0, &mut grad,
        );
    }
    (loss, grad)
}

/// Loss only (no gradient) — evaluation batches. Monomorphized default
/// scenario, like [`value_and_grad`].
pub fn loss_only(
    params: &[f32],
    dw: &[f32],
    batch: usize,
    n_steps: usize,
    problem: &Problem,
) -> f64 {
    let sde = BlackScholes::from_problem(problem);
    let payoff = EuropeanCall {
        strike: problem.strike as f32,
    };
    loss_only_impl(params, dw, batch, n_steps, problem, &sde, &payoff)
}

/// [`loss_only`] under an explicit scenario (dynamic dispatch).
pub fn loss_only_scenario(
    params: &[f32],
    dw: &[f32],
    batch: usize,
    n_steps: usize,
    problem: &Problem,
    scenario: &Scenario,
) -> f64 {
    loss_only_impl(
        params,
        dw,
        batch,
        n_steps,
        problem,
        &*scenario.sde,
        &*scenario.payoff,
    )
}

pub(crate) fn loss_only_impl<S: Sde + ?Sized, P: Payoff + ?Sized>(
    params: &[f32],
    dw: &[f32],
    batch: usize,
    n_steps: usize,
    problem: &Problem,
    sde: &S,
    payoff: &P,
) -> f64 {
    let dim = sde.dim();
    assert_eq!(dw.len(), dim * batch * n_steps, "dw shape mismatch");
    let p = MlpParams::new(params);
    let dt = (problem.maturity / n_steps as f64) as f32;
    let dt_grid = problem.maturity as f32 / n_steps as f32;
    let mut total = 0.0f64;
    for b in 0..batch {
        let rows = factor_rows(dw, dim, batch, n_steps, b);
        // Streamed per-path fold: MLP forward + gains + payoff observer,
        // one state at a time — no path buffer.
        let mut gains = 0.0f32;
        let mut acc = PathAccum::default();
        let mut pending_h = 0.0f32;
        let mut prev = 0.0f32;
        fold_path(sde, &rows[..dim], n_steps, dt, |t, st| {
            let s_t = st[0];
            if t == 0 {
                acc = payoff.init(st);
            } else {
                gains += pending_h * (s_t - prev);
                payoff.observe(&mut acc, t, n_steps, st);
            }
            if t < n_steps {
                pending_h = forward_row(&p, [t as f32 * dt_grid, s_t]).0;
            }
            prev = s_t;
        });
        let payoff_v = payoff.finish(&acc, n_steps);
        let r = payoff_v - gains - p.p0();
        total += (r as f64) * (r as f64);
    }
    total / batch as f64
}

/// Shared fwd+bwd over one grid, scaling the contribution by `sign`
/// (+1 fine term, -1 coarse term). Returns `sign * loss` and accumulates
/// `sign * grad` into `grad`.
///
/// Streams each path through [`fold_path`]: the forward tapes and the
/// per-step price increments (which the backward pass replays) are the
/// only scratch, both `O(n_steps)` and reused across the batch — the
/// path itself is never materialized. Identical f32 operations in
/// identical order as the seed's materialize-then-read loop.
fn accumulate_value_and_grad<S: Sde + ?Sized, P: Payoff + ?Sized>(
    params: &[f32],
    dw: &[f32],
    batch: usize,
    n_steps: usize,
    problem: &Problem,
    sde: &S,
    payoff: &P,
    sign: f32,
    grad: &mut [f32],
) -> f64 {
    let total = accumulate_range(
        params, dw, batch, n_steps, problem, sde, payoff, sign, grad, 0, batch,
    );
    sign as f64 * total / batch as f64
}

/// The inner body of [`accumulate_value_and_grad`] over the path range
/// `b_start..b_end` of the batch, returning the **raw** `sum r^2` over
/// that range (unsigned, unnormalized — the caller owns the
/// `sign / batch` scaling so partial-range callers compose). `batch`
/// still names the full batch: it fixes the `dw` stride and the
/// `1 / batch` gradient scale.
///
/// `pub(crate)` so the lane-blocked kernels ([`super::lanes`]) can fold
/// the `batch % LANES` remainder paths through the *scalar* body — one
/// residual loop, no duplicated arithmetic.
#[allow(clippy::too_many_arguments)]
pub(crate) fn accumulate_range<S: Sde + ?Sized, P: Payoff + ?Sized>(
    params: &[f32],
    dw: &[f32],
    batch: usize,
    n_steps: usize,
    problem: &Problem,
    sde: &S,
    payoff: &P,
    sign: f32,
    grad: &mut [f32],
    b_start: usize,
    b_end: usize,
) -> f64 {
    let dim = sde.dim();
    assert_eq!(dw.len(), dim * batch * n_steps, "dw shape mismatch");
    debug_assert!(b_start <= b_end && b_end <= batch);
    let p = MlpParams::new(params);
    let dt = (problem.maturity / n_steps as f64) as f32;
    let dt_grid = problem.maturity as f32 / n_steps as f32;
    let inv_b = 1.0f32 / batch as f32;

    // Scratch reuse: one row of tapes + price increments per path.
    let mut tapes = Vec::with_capacity(n_steps);
    let mut ds = vec![0.0f32; n_steps];
    let mut total = 0.0f64;
    for b in b_start..b_end {
        let rows = factor_rows(dw, dim, batch, n_steps, b);
        tapes.clear();
        let mut gains = 0.0f32;
        let mut acc = PathAccum::default();
        let mut pending_h = 0.0f32;
        let mut prev = 0.0f32;
        fold_path(sde, &rows[..dim], n_steps, dt, |t, st| {
            let s_t = st[0];
            if t == 0 {
                acc = payoff.init(st);
            } else {
                let d = s_t - prev;
                ds[t - 1] = d;
                gains += pending_h * d;
                payoff.observe(&mut acc, t, n_steps, st);
            }
            if t < n_steps {
                let (h, tape) = forward_row(&p, [t as f32 * dt_grid, s_t]);
                pending_h = h;
                tapes.push(tape);
            }
            prev = s_t;
        });
        let payoff_v = payoff.finish(&acc, n_steps);
        let r = payoff_v - gains - p.p0();
        total += (r as f64) * (r as f64);

        // Backward: dL/dr = 2 r / B (scaled by sign).
        let dr = sign * 2.0 * r * inv_b;
        grad[OFF_P0] += -dr;
        for n in 0..n_steps {
            let g_h = -dr * ds[n];
            backward_row(&p, &tapes[n], g_h, grad);
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::milstein::simulate_paths;
    use crate::engine::mlp::init_params;
    use crate::rng::{brownian::Purpose, BrownianSource};

    fn setup(level: usize, batch: usize) -> (Problem, Vec<f32>, Vec<f32>) {
        let prob = Problem::default();
        let params = init_params(0);
        let n = prob.n_steps(level);
        let dw = BrownianSource::new(11).increments(
            Purpose::Grad, 0, level as u32, 0, batch, n, prob.dt(level),
        );
        (prob, params, dw)
    }

    #[test]
    fn loss_only_matches_value_and_grad() {
        let (prob, params, dw) = setup(1, 16);
        let n = prob.n_steps(1);
        let (loss, _) = value_and_grad(&params, &dw, 16, n, &prob);
        let loss2 = loss_only(&params, &dw, 16, n, &prob);
        assert!((loss - loss2).abs() < 1e-9);
    }

    #[test]
    fn grad_matches_finite_differences() {
        let (prob, mut params, dw) = setup(1, 8);
        let (_, grad) = coupled_value_and_grad(&params, &dw, 8, 1, &prob);
        let eps = 1e-3f32;
        for &i in &[0usize, 40, 100, 700, OFF_P0 - 1, OFF_P0] {
            let orig = params[i];
            params[i] = orig + eps;
            let (lp, _) = coupled_value_and_grad(&params, &dw, 8, 1, &prob);
            params[i] = orig - eps;
            let (lm, _) = coupled_value_and_grad(&params, &dw, 8, 1, &prob);
            params[i] = orig;
            let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
            assert!(
                (grad[i] - fd).abs() < 5e-3 * fd.abs().max(1.0),
                "param {i}: analytic {} vs fd {fd}",
                grad[i]
            );
        }
    }

    #[test]
    fn p0_grad_is_minus_two_mean_residual() {
        // Closed form anchor: dL/dp0 = -2 E[r].
        let (prob, params, dw) = setup(0, 32);
        let n = prob.n_steps(0);
        let (_, grad) = value_and_grad(&params, &dw, 32, n, &prob);
        // compute residual mean directly
        let p = MlpParams::new(&params);
        let s = simulate_paths(&dw, 32, n, &prob);
        let dtg = prob.maturity as f32 / n as f32;
        let mut mean_r = 0.0f64;
        for b in 0..32 {
            let row = &s[b * (n + 1)..(b + 1) * (n + 1)];
            let mut gains = 0.0f32;
            for t in 0..n {
                gains += forward_row(&p, [t as f32 * dtg, row[t]]).0
                    * (row[t + 1] - row[t]);
            }
            let r = (row[n] - prob.strike as f32).max(0.0) - gains - p.p0();
            mean_r += r as f64;
        }
        mean_r /= 32.0;
        assert!(
            (grad[OFF_P0] as f64 + 2.0 * mean_r).abs() < 1e-5,
            "{} vs {}",
            grad[OFF_P0],
            -2.0 * mean_r
        );
    }

    #[test]
    fn coupled_level0_equals_plain() {
        let (prob, params, dw) = setup(0, 16);
        let n = prob.n_steps(0);
        let (l1, g1) = coupled_value_and_grad(&params, &dw, 16, 0, &prob);
        let (l2, g2) = value_and_grad(&params, &dw, 16, n, &prob);
        assert_eq!(l1, l2);
        assert_eq!(g1, g2);
    }

    #[test]
    fn coupled_magnitude_decays_with_level() {
        // E||grad Delta_l F_hat||^2 (per-sample!) shrinks with l — the
        // empirical Assumption 2. The norm of the *batch-mean* gradient
        // is too noisy to be monotone; the per-sample second moment is.
        let prob = Problem::default();
        let params = init_params(0);
        let src = BrownianSource::new(5);
        let mut moments = Vec::new();
        for level in [1usize, 3, 5] {
            let n = prob.n_steps(level);
            let samples = 128;
            let dw = src.increments(
                Purpose::Grad, 0, level as u32, 0, samples, n, prob.dt(level),
            );
            let mut acc = 0.0f64;
            for s in 0..samples {
                let row = &dw[s * n..(s + 1) * n];
                let (_, g) = coupled_value_and_grad(&params, row, 1, level, &prob);
                acc += g.iter().map(|&x| (x as f64).powi(2)).sum::<f64>();
            }
            moments.push(acc / samples as f64);
        }
        assert!(
            moments[2] < moments[1] && moments[1] < moments[0],
            "{moments:?}"
        );
    }

    #[test]
    fn default_scenario_is_bitwise_identical_to_plain_entry_points() {
        let (prob, params, dw) = setup(2, 16);
        let sc = Scenario::from_problem(&prob);
        let (l1, g1) = coupled_value_and_grad(&params, &dw, 16, 2, &prob);
        let (l2, g2) =
            coupled_value_and_grad_scenario(&params, &dw, 16, 2, &prob, &sc);
        assert_eq!(l1, l2);
        assert_eq!(g1, g2);
        let n = prob.n_steps(2);
        assert_eq!(
            loss_only(&params, &dw, 16, n, &prob),
            loss_only_scenario(&params, &dw, 16, n, &prob, &sc)
        );
    }

    #[test]
    fn non_default_scenarios_produce_finite_coupled_grads() {
        let (prob, params, dw) = setup(2, 8);
        for name in [
            "ou-asian",
            "cir-lookback",
            "gbm-digital",
            "bs-put",
            "bs-uo-call",
            "gbm-di-put",
        ] {
            let sc = crate::scenarios::build_scenario(name, &prob).unwrap();
            let (loss, grad) =
                coupled_value_and_grad_scenario(&params, &dw, 8, 2, &prob, &sc);
            assert!(loss.is_finite(), "{name}: loss {loss}");
            assert!(
                grad.iter().all(|g| g.is_finite()),
                "{name}: non-finite gradient"
            );
        }
    }

    #[test]
    fn heston_scenarios_produce_finite_coupled_grads_at_every_level() {
        // 2-factor dw: factor-major [2, batch, n]. Every level must yield
        // finite coupled losses/gradients (acceptance criterion for the
        // multi-factor core).
        let prob = Problem::default();
        let params = init_params(0);
        let src = BrownianSource::new(31);
        for name in ["heston-call", "heston-put", "heston-uo-call"] {
            let sc = crate::scenarios::build_scenario(name, &prob).unwrap();
            assert_eq!(sc.sde.dim(), 2);
            for level in 0..=prob.lmax {
                let n = prob.n_steps(level);
                let batch = 8;
                let dw = src.increments_multi(
                    Purpose::Grad, 0, level as u32, 0, batch, n,
                    prob.dt(level), 2,
                );
                let (loss, grad) = coupled_value_and_grad_scenario(
                    &params, &dw, batch, level, &prob, &sc,
                );
                assert!(loss.is_finite(), "{name} l{level}: loss {loss}");
                assert!(
                    grad.iter().all(|g| g.is_finite()),
                    "{name} l{level}: non-finite gradient"
                );
                if level == 0 {
                    assert!(
                        grad.iter().any(|&g| g != 0.0),
                        "{name}: all-zero level-0 gradient"
                    );
                }
            }
        }
    }

    #[test]
    fn streaming_loss_matches_materialized_reference_bitwise() {
        // The streaming objective performs the same f32 operations in the
        // same order as materialize-then-read; the f64 loss must agree to
        // the last bit, for the default scenario and for path-dependent
        // payoffs on every 1-D dynamics.
        let prob = Problem::default();
        let params = init_params(0);
        let p = MlpParams::new(&params);
        let src = BrownianSource::new(77);
        for name in ["bs-call", "ou-asian", "cir-lookback", "bs-uo-call"] {
            let sc = crate::scenarios::build_scenario(name, &prob).unwrap();
            let batch = 16;
            let n = prob.n_steps(2);
            let dw = src.increments(
                Purpose::Grad, 0, 2, 0, batch, n, prob.dt(2),
            );
            let got = loss_only_scenario(&params, &dw, batch, n, &prob, &sc);

            // materialized reference: full path buffer, then payoff reads
            let s = crate::engine::milstein::simulate_paths_sde(
                &dw, batch, n, &*sc.sde, prob.maturity,
            );
            let dtg = prob.maturity as f32 / n as f32;
            let mut want = 0.0f64;
            for b in 0..batch {
                let row = &s[b * (n + 1)..(b + 1) * (n + 1)];
                let mut gains = 0.0f32;
                for t in 0..n {
                    gains += forward_row(&p, [t as f32 * dtg, row[t]]).0
                        * (row[t + 1] - row[t]);
                }
                let r = sc.payoff.value(row) - gains - p.p0();
                want += (r as f64) * (r as f64);
            }
            want /= batch as f64;
            assert_eq!(got, want, "{name}: streaming loss drifted");
        }
    }

    #[test]
    fn telescoping_sum_matches_finest_loss() {
        // sum_l Delta_l(x, same path) == F_lmax(x, path).
        let prob = Problem {
            lmax: 3,
            ..Problem::default()
        };
        let params = init_params(1);
        let batch = 8;
        let n_max = prob.n_steps(prob.lmax);
        let dw_fine = BrownianSource::new(2).increments(
            Purpose::Grad, 0, 0, 0, batch, n_max, prob.dt(prob.lmax),
        );
        let total = loss_only(&params, &dw_fine, batch, n_max, &prob);
        let mut acc = 0.0;
        let mut dw = dw_fine.clone();
        for level in (0..=prob.lmax).rev() {
            let (l, _) = coupled_value_and_grad(&params, &dw, batch, level, &prob);
            acc += l;
            if level > 0 {
                dw = BrownianSource::coarsen(&dw, batch, prob.n_steps(level));
            }
        }
        assert!((acc - total).abs() < 1e-5, "{acc} vs {total}");
    }
}
