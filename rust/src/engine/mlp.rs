//! The hedging-strategy MLP (2 -> 32 -> 32 -> 1, SiLU/SiLU/sigmoid) with a
//! hand-written backward pass — Rust mirror of the L1 Pallas kernels in
//! `python/compile/kernels/mlp.py`.
//!
//! The trainable state is ONE flat `f32` vector with the same layout as
//! the python side (`problem.MlpArch.sizes`):
//!
//! `[ w1(2x32) | b1(32) | w2(32x32) | b2(32) | w3(32x1) | b3(1) | p0(1) ]`
//!
//! so parameter buffers can be passed to either backend unchanged.

pub const N_IN: usize = 2;
pub const HIDDEN: usize = 32;

pub const OFF_W1: usize = 0;
pub const OFF_B1: usize = OFF_W1 + N_IN * HIDDEN;
pub const OFF_W2: usize = OFF_B1 + HIDDEN;
pub const OFF_B2: usize = OFF_W2 + HIDDEN * HIDDEN;
pub const OFF_W3: usize = OFF_B2 + HIDDEN;
pub const OFF_B3: usize = OFF_W3 + HIDDEN;
pub const OFF_P0: usize = OFF_B3 + 1;
pub const N_PARAMS: usize = OFF_P0 + 1;

/// Typed view over the flat parameter vector.
#[derive(Debug, Clone, Copy)]
pub struct MlpParams<'a> {
    flat: &'a [f32],
}

impl<'a> MlpParams<'a> {
    pub fn new(flat: &'a [f32]) -> Self {
        assert_eq!(flat.len(), N_PARAMS, "param vector must be {N_PARAMS} long");
        MlpParams { flat }
    }

    /// `w1[i][j]`, i in 0..N_IN, j in 0..HIDDEN (row-major, like jnp).
    #[inline]
    pub fn w1(&self, i: usize, j: usize) -> f32 {
        self.flat[OFF_W1 + i * HIDDEN + j]
    }

    #[inline]
    pub fn b1(&self, j: usize) -> f32 {
        self.flat[OFF_B1 + j]
    }

    #[inline]
    pub fn w2(&self, i: usize, j: usize) -> f32 {
        self.flat[OFF_W2 + i * HIDDEN + j]
    }

    #[inline]
    pub fn b2(&self, j: usize) -> f32 {
        self.flat[OFF_B2 + j]
    }

    #[inline]
    pub fn w3(&self, i: usize) -> f32 {
        self.flat[OFF_W3 + i]
    }

    #[inline]
    pub fn b3(&self) -> f32 {
        self.flat[OFF_B3]
    }

    #[inline]
    pub fn p0(&self) -> f32 {
        self.flat[OFF_P0]
    }

    /// Contiguous row `w1[i][0..HIDDEN]` (SIMD-friendly accessor).
    #[inline]
    pub fn w1_row(&self, i: usize) -> &[f32] {
        &self.flat[OFF_W1 + i * HIDDEN..OFF_W1 + (i + 1) * HIDDEN]
    }

    /// Contiguous row `w2[j][0..HIDDEN]` (SIMD-friendly accessor).
    #[inline]
    pub fn w2_row(&self, j: usize) -> &[f32] {
        &self.flat[OFF_W2 + j * HIDDEN..OFF_W2 + (j + 1) * HIDDEN]
    }

    /// Contiguous `w3[0..HIDDEN]`.
    #[inline]
    pub fn w3_col(&self) -> &[f32] {
        &self.flat[OFF_W3..OFF_W3 + HIDDEN]
    }

    /// Contiguous `b1`/`b2` rows.
    #[inline]
    pub fn b1_row(&self) -> &[f32] {
        &self.flat[OFF_B1..OFF_B1 + HIDDEN]
    }

    #[inline]
    pub fn b2_row(&self) -> &[f32] {
        &self.flat[OFF_B2..OFF_B2 + HIDDEN]
    }
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[inline]
fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

/// d/dx silu(x) = sig(x) (1 + x (1 - sig(x))).
#[inline]
fn dsilu(x: f32) -> f32 {
    let s = sigmoid(x);
    s * (1.0 + x * (1.0 - s))
}

/// Saved forward state for one row (pre-activations), fed to `backward_row`.
#[derive(Debug, Clone)]
pub struct RowTape {
    pub x: [f32; N_IN],
    pub z1: [f32; HIDDEN],
    pub z2: [f32; HIDDEN],
    pub z3: f32,
}

/// Forward one feature row, returning the holding H in [0,1] + the tape.
///
/// Loop structure is deliberately SIMD-friendly: every inner loop walks a
/// *contiguous* weight row with a broadcast scalar, so LLVM auto-
/// vectorizes the 32-wide fused multiply-adds (measured ~1.5x over the
/// naive k-outer/j-inner order — EXPERIMENTS.md §Perf).
#[inline]
pub fn forward_row(p: &MlpParams, x: [f32; N_IN]) -> (f32, RowTape) {
    let mut z1 = [0.0f32; HIDDEN];
    let (w1_0, w1_1, b1) = (p.w1_row(0), p.w1_row(1), p.b1_row());
    for j in 0..HIDDEN {
        // N_IN = 2: unrolled dot product over contiguous rows.
        z1[j] = x[0] * w1_0[j] + x[1] * w1_1[j] + b1[j];
    }
    let mut h1 = [0.0f32; HIDDEN];
    for j in 0..HIDDEN {
        h1[j] = silu(z1[j]);
    }
    // z2 = b2 + h1 @ w2: accumulate one broadcast h1[j] times the
    // contiguous row w2[j][*] at a time.
    let mut z2 = [0.0f32; HIDDEN];
    z2.copy_from_slice(p.b2_row());
    for j in 0..HIDDEN {
        let h1j = h1[j];
        let row = p.w2_row(j);
        for k in 0..HIDDEN {
            z2[k] += h1j * row[k];
        }
    }
    let mut z3 = p.b3();
    let w3 = p.w3_col();
    for k in 0..HIDDEN {
        z3 += silu(z2[k]) * w3[k];
    }
    (sigmoid(z3), RowTape { x, z1, z2, z3 })
}

/// Forward only (no tape) — used by inference-style consumers.
#[inline]
pub fn holding(p: &MlpParams, t: f32, s: f32) -> f32 {
    forward_row(p, [t, s]).0
}

/// Backpropagate upstream `g = dL/dH` through one row, accumulating the
/// parameter gradient into `grad` (flat layout, same as params).
///
/// Each sigmoid is evaluated once per activation and reused for both the
/// SiLU value and its derivative (`exp` dominates this kernel —
/// EXPERIMENTS.md §Perf), and all inner loops walk contiguous rows.
pub fn backward_row(p: &MlpParams, tape: &RowTape, g: f32, grad: &mut [f32]) {
    debug_assert_eq!(grad.len(), N_PARAMS);
    let y = sigmoid(tape.z3);
    let dz3 = g * y * (1.0 - y);

    // layer 3: h2 = silu(z2), dz2 = w3 * dz3 * dsilu(z2), sharing sigmoid.
    let w3 = p.w3_col();
    let mut dz2 = [0.0f32; HIDDEN];
    for k in 0..HIDDEN {
        let z = tape.z2[k];
        let s = sigmoid(z);
        let h2 = z * s; // silu(z2)
        let ds = s * (1.0 + z * (1.0 - s)); // dsilu(z2)
        grad[OFF_W3 + k] += h2 * dz3;
        dz2[k] = w3[k] * dz3 * ds;
    }
    grad[OFF_B3] += dz3;

    // layer 2: h1 once (sigmoid shared with the layer-1 pass below).
    let mut h1 = [0.0f32; HIDDEN];
    let mut sig1 = [0.0f32; HIDDEN];
    for j in 0..HIDDEN {
        let s = sigmoid(tape.z1[j]);
        sig1[j] = s;
        h1[j] = tape.z1[j] * s;
    }
    let mut dh1 = [0.0f32; HIDDEN];
    for j in 0..HIDDEN {
        let mut acc = 0.0f32;
        let h1j = h1[j];
        let w2 = p.w2_row(j);
        let grow = &mut grad[OFF_W2 + j * HIDDEN..OFF_W2 + (j + 1) * HIDDEN];
        for k in 0..HIDDEN {
            grow[k] += h1j * dz2[k];
            acc += w2[k] * dz2[k];
        }
        dh1[j] = acc;
    }
    for k in 0..HIDDEN {
        grad[OFF_B2 + k] += dz2[k];
    }

    // layer 1 (sigmoid reused from sig1).
    for j in 0..HIDDEN {
        let (z, s) = (tape.z1[j], sig1[j]);
        let dz1 = dh1[j] * s * (1.0 + z * (1.0 - s));
        grad[OFF_W1 + j] += tape.x[0] * dz1; // w1[0][j]
        grad[OFF_W1 + HIDDEN + j] += tape.x[1] * dz1; // w1[1][j]
        grad[OFF_B1 + j] += dz1;
    }
}

// ---------------------------------------------------------------------------
// Lane-blocked kernels: LANES residual rows per call
// ---------------------------------------------------------------------------

/// Paths integrated per lane block by the SIMD hot path
/// ([`crate::engine::lanes`]). 8 f32 lanes = one AVX2 register; on
/// narrower ISAs LLVM splits the lane loops into two 4-wide halves.
pub const LANES: usize = 8;

/// Branchless polynomial `exp` for the lane kernels: `exp(x) = 2^f *
/// exp2(r)` with `t = x log2(e)`, `f = floor(t)`, `r = t - f in [0, 1)`,
/// `exp2(r)` a degree-7 Taylor polynomial (coefficients `ln(2)^i / i!`)
/// and the `2^f` scale assembled directly in the exponent bits. Relative
/// error ~1e-6 over the clamped range — far inside the lane kernels'
/// validation tolerance, and (unlike libm's `exp`) fully unrollable and
/// auto-vectorizable because it has no branches or table loads.
///
/// Only the `*-simd` kernel variants use this; the scalar kernels keep
/// libm `exp` so the bitwise anchors never move.
#[inline(always)]
fn fast_exp(x: f32) -> f32 {
    let t = x.clamp(-87.0, 88.0) * std::f32::consts::LOG2_E;
    let f = t.floor();
    let r = t - f;
    const C1: f32 = 0.693_147_2;
    const C2: f32 = 0.240_226_5;
    const C3: f32 = 0.055_504_1;
    const C4: f32 = 0.009_618_13;
    const C5: f32 = 0.001_333_355_8;
    const C6: f32 = 1.540_353e-4;
    const C7: f32 = 1.525_273e-5;
    let p = 1.0
        + r * (C1 + r * (C2 + r * (C3 + r * (C4 + r * (C5 + r * (C6 + r * C7))))));
    // 2^f via the IEEE-754 exponent field: f in [-126, 127] after clamp.
    let scale = f32::from_bits((((f as i32) + 127) << 23) as u32);
    p * scale
}

#[inline(always)]
fn fast_sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + fast_exp(-x))
}

#[inline(always)]
fn fast_silu(x: f32) -> f32 {
    x * fast_sigmoid(x)
}

/// Saved forward state for one lane block of [`LANES`] rows, laid out
/// **lane-major** (`[hidden][lane]`) so every backward inner loop is an
/// 8-wide contiguous sweep. The time feature is shared by construction —
/// all lanes of a block sit on the same grid step — so only the price
/// lane vector is stored per row.
#[derive(Debug, Clone)]
pub struct RowTape8 {
    /// Shared time feature `t` of the block (`x[0]` of every lane).
    pub t: f32,
    /// Per-lane price feature (`x[1]`).
    pub s: [f32; LANES],
    pub z1: [[f32; LANES]; HIDDEN],
    pub z2: [[f32; LANES]; HIDDEN],
    pub z3: [f32; LANES],
}

/// Forward [`LANES`] feature rows at once (shared time `t`, per-lane
/// price `s`), returning the holdings and the lane-major tape. Uses
/// [`fast_exp`]-based activations and reassociates the layer reductions
/// across lanes, so outputs agree with [`forward_row`] only to relative
/// tolerance — this is the `*-simd` kernel path, never the scalar one.
#[inline]
pub fn forward_rows8(p: &MlpParams, t: f32, s: &[f32; LANES]) -> ([f32; LANES], RowTape8) {
    let (w1_0, w1_1, b1) = (p.w1_row(0), p.w1_row(1), p.b1_row());
    let mut z1 = [[0.0f32; LANES]; HIDDEN];
    for j in 0..HIDDEN {
        let base = t * w1_0[j] + b1[j];
        let w = w1_1[j];
        for l in 0..LANES {
            z1[j][l] = base + s[l] * w;
        }
    }
    let mut h1 = [[0.0f32; LANES]; HIDDEN];
    for j in 0..HIDDEN {
        for l in 0..LANES {
            h1[j][l] = fast_silu(z1[j][l]);
        }
    }
    // z2 = b2 + h1 @ w2, j-outer / k-mid / lane-inner: the innermost loop
    // is a contiguous 8-wide FMA with both operands broadcast or linear.
    let b2 = p.b2_row();
    let mut z2 = [[0.0f32; LANES]; HIDDEN];
    for k in 0..HIDDEN {
        for l in 0..LANES {
            z2[k][l] = b2[k];
        }
    }
    for j in 0..HIDDEN {
        let row = p.w2_row(j);
        let hj = h1[j];
        for k in 0..HIDDEN {
            let w = row[k];
            for l in 0..LANES {
                z2[k][l] += hj[l] * w;
            }
        }
    }
    let w3 = p.w3_col();
    let mut z3 = [p.b3(); LANES];
    for k in 0..HIDDEN {
        let w = w3[k];
        for l in 0..LANES {
            z3[l] += fast_silu(z2[k][l]) * w;
        }
    }
    let mut y = [0.0f32; LANES];
    for l in 0..LANES {
        y[l] = fast_sigmoid(z3[l]);
    }
    (y, RowTape8 { t, s: *s, z1, z2, z3 })
}

/// Backpropagate per-lane upstream gradients `g = dL/dH` through one lane
/// block, accumulating the **lane-summed** parameter gradient into
/// `grad`. Mirrors [`backward_row`]'s structure with the lane dimension
/// innermost; parameter accumulation order across lanes differs from
/// running [`backward_row`] 8 times, which is exactly the f32
/// reassociation the `*-simd` kernel keys declare.
pub fn backward_rows8(p: &MlpParams, tape: &RowTape8, g: &[f32; LANES], grad: &mut [f32]) {
    debug_assert_eq!(grad.len(), N_PARAMS);
    let mut dz3 = [0.0f32; LANES];
    for l in 0..LANES {
        let y = fast_sigmoid(tape.z3[l]);
        dz3[l] = g[l] * y * (1.0 - y);
    }

    // layer 3: silu(z2) and dsilu(z2) share one sigmoid per lane.
    let w3 = p.w3_col();
    let mut dz2 = [[0.0f32; LANES]; HIDDEN];
    for k in 0..HIDDEN {
        let w = w3[k];
        let mut gw3 = 0.0f32;
        for l in 0..LANES {
            let z = tape.z2[k][l];
            let s = fast_sigmoid(z);
            gw3 += z * s * dz3[l]; // silu(z2) * dz3
            dz2[k][l] = w * dz3[l] * (s * (1.0 + z * (1.0 - s)));
        }
        grad[OFF_W3 + k] += gw3;
    }
    let mut db3 = 0.0f32;
    for l in 0..LANES {
        db3 += dz3[l];
    }
    grad[OFF_B3] += db3;

    // layer 2: h1/sig1 once (shared with the layer-1 pass below).
    let mut h1 = [[0.0f32; LANES]; HIDDEN];
    let mut sig1 = [[0.0f32; LANES]; HIDDEN];
    for j in 0..HIDDEN {
        for l in 0..LANES {
            let s = fast_sigmoid(tape.z1[j][l]);
            sig1[j][l] = s;
            h1[j][l] = tape.z1[j][l] * s;
        }
    }
    let mut dh1 = [[0.0f32; LANES]; HIDDEN];
    for j in 0..HIDDEN {
        let w2 = p.w2_row(j);
        let hj = h1[j];
        let grow = &mut grad[OFF_W2 + j * HIDDEN..OFF_W2 + (j + 1) * HIDDEN];
        for k in 0..HIDDEN {
            let w = w2[k];
            let mut gw = 0.0f32;
            for l in 0..LANES {
                gw += hj[l] * dz2[k][l];
                dh1[j][l] += w * dz2[k][l];
            }
            grow[k] += gw;
        }
    }
    for k in 0..HIDDEN {
        let mut gb = 0.0f32;
        for l in 0..LANES {
            gb += dz2[k][l];
        }
        grad[OFF_B2 + k] += gb;
    }

    // layer 1: the shared time feature factors out of the lane sum.
    for j in 0..HIDDEN {
        let mut gw0 = 0.0f32;
        let mut gw1 = 0.0f32;
        let mut gb = 0.0f32;
        for l in 0..LANES {
            let (z, s) = (tape.z1[j][l], sig1[j][l]);
            let dz1 = dh1[j][l] * s * (1.0 + z * (1.0 - s));
            gw0 += dz1;
            gw1 += tape.s[l] * dz1;
            gb += dz1;
        }
        grad[OFF_W1 + j] += tape.t * gw0; // w1[0][j]
        grad[OFF_W1 + HIDDEN + j] += gw1; // w1[1][j]
        grad[OFF_B1 + j] += gb;
    }
}

/// He-style initialisation identical to `python/compile/model.py` in
/// *layout* (weights ~ N(0, 2/fan_in), biases and p0 zero) but using the
/// native Philox stream. For bit-identical starts across backends, load
/// `artifacts/init_params.bin` instead.
pub fn init_params(seed: u64) -> Vec<f32> {
    use crate::rng::NormalStream;
    let mut out = vec![0.0f32; N_PARAMS];
    let stream = NormalStream::new(seed, 0xDEAD_BEEF);
    let mut noise = vec![0.0f32; N_IN * HIDDEN + HIDDEN * HIDDEN + HIDDEN];
    stream.fill(&mut noise);
    let mut k = 0;
    let scale1 = (2.0f32 / N_IN as f32).sqrt();
    for v in &mut out[OFF_W1..OFF_W1 + N_IN * HIDDEN] {
        *v = noise[k] * scale1;
        k += 1;
    }
    let scale2 = (2.0f32 / HIDDEN as f32).sqrt();
    for v in &mut out[OFF_W2..OFF_W2 + HIDDEN * HIDDEN] {
        *v = noise[k] * scale2;
        k += 1;
    }
    for v in &mut out[OFF_W3..OFF_W3 + HIDDEN] {
        *v = noise[k] * scale2;
        k += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(seed: u64) -> Vec<f32> {
        init_params(seed)
    }

    #[test]
    fn layout_totals() {
        assert_eq!(N_PARAMS, 2 * 32 + 32 + 32 * 32 + 32 + 32 + 1 + 1);
        assert_eq!(N_PARAMS, 1186);
    }

    #[test]
    fn forward_in_unit_interval() {
        let p = params(0);
        let view = MlpParams::new(&p);
        for i in 0..50 {
            let h = holding(&view, i as f32 * 0.02, 1.0 + i as f32 * 0.1);
            assert!((0.0..=1.0).contains(&h), "h = {h}");
        }
    }

    #[test]
    fn backward_matches_finite_differences() {
        let mut p = params(3);
        let x = [0.4f32, 2.7];
        // d(sin(H))/dparam via tape, vs central differences.
        let f = |pv: &[f32]| -> f64 {
            let (h, _) = forward_row(&MlpParams::new(pv), x);
            (h as f64).sin()
        };
        let (h, tape) = forward_row(&MlpParams::new(&p), x);
        let g_up = (h as f64).cos() as f32; // d sin(H)/dH
        let mut grad = vec![0.0f32; N_PARAMS];
        backward_row(&MlpParams::new(&p), &tape, g_up, &mut grad);

        let eps = 1e-3f32;
        // Spot-check a spread of parameter indices from every block.
        for &i in &[0usize, 5, OFF_B1 + 3, OFF_W2 + 40, OFF_B2 + 7, OFF_W3 + 10, OFF_B3] {
            let orig = p[i];
            p[i] = orig + eps;
            let fp = f(&p);
            p[i] = orig - eps;
            let fm = f(&p);
            p[i] = orig;
            let fd = ((fp - fm) / (2.0 * eps as f64)) as f32;
            assert!(
                (grad[i] - fd).abs() < 2e-3 * fd.abs().max(1.0),
                "param {i}: analytic {} vs fd {fd}",
                grad[i]
            );
        }
    }

    #[test]
    fn p0_not_touched_by_mlp_backward() {
        let p = params(1);
        let (_, tape) = forward_row(&MlpParams::new(&p), [0.1, 3.0]);
        let mut grad = vec![0.0f32; N_PARAMS];
        backward_row(&MlpParams::new(&p), &tape, 1.0, &mut grad);
        assert_eq!(grad[OFF_P0], 0.0);
    }

    #[test]
    fn grad_accumulates_across_rows() {
        let p = params(2);
        let view = MlpParams::new(&p);
        let mut g1 = vec![0.0f32; N_PARAMS];
        let (_, t1) = forward_row(&view, [0.0, 3.0]);
        backward_row(&view, &t1, 1.0, &mut g1);
        let mut g2 = g1.clone();
        let (_, t2) = forward_row(&view, [0.5, 2.0]);
        backward_row(&view, &t2, 1.0, &mut g2);
        // after the 2nd row, gradient must change (accumulate).
        assert!(g1.iter().zip(&g2).any(|(a, b)| a != b));
    }

    #[test]
    fn init_is_deterministic_with_zero_biases() {
        let a = params(7);
        let b = params(7);
        assert_eq!(a, b);
        assert_ne!(a, params(8));
        assert_eq!(a[OFF_B1], 0.0);
        assert_eq!(a[OFF_P0], 0.0);
        assert!(a[OFF_W1] != 0.0);
    }
}
