//! Standard-normal sampling on top of the Philox block function.
//!
//! Box–Muller over pairs of uniform lanes: each 128-bit Philox block
//! yields four u32 lanes -> two uniforms-pairs -> four N(0,1) draws.

use super::philox::Philox4x32;

/// Addressable stream of standard normals: draw `i` of stream `stream`
/// is a pure function of `(seed, stream, i)`.
#[derive(Debug, Clone, Copy)]
pub struct NormalStream {
    gen: Philox4x32,
    stream: u64,
}

impl NormalStream {
    pub fn new(seed: u64, stream: u64) -> Self {
        NormalStream {
            gen: Philox4x32::new(seed),
            stream,
        }
    }

    /// Fill `out` with i.i.d. N(0,1) samples (positions `0..out.len()` of
    /// this stream — stable regardless of call granularity).
    pub fn fill(&self, out: &mut [f32]) {
        let n = out.len();
        let mut i = 0;
        let mut block_idx = 0u64;
        while i < n {
            let z = self.quad(block_idx);
            let take = (n - i).min(4);
            out[i..i + take].copy_from_slice(&z[..take]);
            i += take;
            block_idx += 1;
        }
    }

    /// Four normals from block `block_idx` of this stream.
    #[inline]
    pub fn quad(&self, block_idx: u64) -> [f32; 4] {
        let u = self.gen.block_at(self.stream, block_idx);
        let (z0, z1) = box_muller(u[0], u[1]);
        let (z2, z3) = box_muller(u[2], u[3]);
        [z0, z1, z2, z3]
    }
}

/// Map two u32 lanes to two N(0,1) draws.
///
/// `u1` is mapped into (0, 1] so the log never sees zero. Single
/// precision throughout: the output is consumed as f32 increments whose
/// Monte Carlo error floor (>= 2^-11 at our batch sizes) dwarfs the
/// ~2^-24 rounding of f32 ln/cos/sin, and f32 transcendentals cut the
/// hot-path RNG cost ~2x (see EXPERIMENTS.md §Perf).
#[inline]
pub fn box_muller(a: u32, b: u32) -> (f32, f32) {
    // (a + 1) / 2^32  in (0, 1]
    let u1 = (a as f32 + 1.0) * (1.0 / 4294967296.0);
    let u2 = b as f32 * (1.0 / 4294967296.0);
    let r = (-2.0 * u1.ln()).sqrt();
    let theta = 2.0 * std::f32::consts::PI * u2;
    (r * theta.cos(), r * theta.sin())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(seed: u64, stream: u64, n: usize) -> Vec<f32> {
        let s = NormalStream::new(seed, stream);
        let mut v = vec![0.0; n];
        s.fill(&mut v);
        v
    }

    #[test]
    fn deterministic_and_stream_independent() {
        assert_eq!(sample(1, 0, 64), sample(1, 0, 64));
        assert_ne!(sample(1, 0, 64), sample(1, 1, 64));
        assert_ne!(sample(1, 0, 64), sample(2, 0, 64));
    }

    #[test]
    fn prefix_stability() {
        // Drawing 10 then 100 must agree on the first 10 — required for
        // chunked generation to be order-independent.
        let short = sample(9, 3, 10);
        let long = sample(9, 3, 100);
        assert_eq!(short[..], long[..10]);
    }

    #[test]
    fn moments_match_standard_normal() {
        let v = sample(1234, 0, 200_000);
        let n = v.len() as f64;
        let mean = v.iter().map(|&x| x as f64).sum::<f64>() / n;
        let var = v.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
        let skew = v.iter().map(|&x| (x as f64 - mean).powi(3)).sum::<f64>()
            / n
            / var.powf(1.5);
        let kurt = v.iter().map(|&x| (x as f64 - mean).powi(4)).sum::<f64>()
            / n
            / var.powi(2);
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
        assert!(skew.abs() < 0.03, "skew {skew}");
        assert!((kurt - 3.0).abs() < 0.1, "kurt {kurt}");
    }

    #[test]
    fn no_nan_or_inf() {
        for &x in sample(0, 0, 10_000).iter() {
            assert!(x.is_finite());
        }
    }

    #[test]
    fn tails_present() {
        // With 200k draws we expect |z| > 3 about 0.27% of the time.
        let v = sample(77, 0, 200_000);
        let big = v.iter().filter(|x| x.abs() > 3.0).count();
        assert!(big > 200 && big < 900, "tail count {big}");
    }
}
