//! Counter-based random number generation for reproducible, splittable
//! Monte Carlo streams.
//!
//! The coordinator needs independent Brownian-increment batches per
//! `(SGD step, level, chunk)` that are (a) reproducible across runs and
//! backends, (b) order-independent — a level refreshed concurrently must
//! see the same numbers as one refreshed sequentially. A counter-based
//! generator (Philox4x32-10, Salmon et al. 2011 — the same family JAX's
//! `threefry`/`rbg` PRNGs come from) gives exactly that: the stream is a
//! pure function of `(key, counter)`.

pub mod brownian;
pub mod normal;
pub mod philox;

pub use brownian::BrownianSource;
pub use normal::NormalStream;
pub use philox::Philox4x32;
