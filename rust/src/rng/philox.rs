//! Philox4x32-10 counter-based PRNG (Salmon et al., SC'11).
//!
//! A pure function `(key: 2×u32, counter: 4×u32) -> 4×u32` passing
//! BigCrush; 10 rounds of multiply-hi/lo mixing. Chosen over a stateful
//! generator because MLMC needs *splittable* streams addressed by
//! `(step, level, chunk, lane)` — see [`crate::rng`].

const PHILOX_M0: u32 = 0xD251_1F53;
const PHILOX_M1: u32 = 0xCD9E_8D57;
const PHILOX_W0: u32 = 0x9E37_79B9;
const PHILOX_W1: u32 = 0xBB67_AE85;
const ROUNDS: usize = 10;

/// Stateless Philox4x32-10 block function with a fixed key.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Philox4x32 {
    key: [u32; 2],
}

impl Philox4x32 {
    /// Build a generator from a 64-bit seed (split into the 2×u32 key).
    pub fn new(seed: u64) -> Self {
        Philox4x32 {
            key: [seed as u32, (seed >> 32) as u32],
        }
    }

    /// One Philox block: encrypt a 128-bit counter into 4 random u32s.
    #[inline]
    pub fn block(&self, counter: [u32; 4]) -> [u32; 4] {
        let mut ctr = counter;
        let mut key = self.key;
        for _ in 0..ROUNDS {
            ctr = round(ctr, key);
            key[0] = key[0].wrapping_add(PHILOX_W0);
            key[1] = key[1].wrapping_add(PHILOX_W1);
        }
        ctr
    }

    /// Convenience: counter assembled from two u64 coordinates.
    #[inline]
    pub fn block_at(&self, hi: u64, lo: u64) -> [u32; 4] {
        self.block([
            lo as u32,
            (lo >> 32) as u32,
            hi as u32,
            (hi >> 32) as u32,
        ])
    }
}

#[inline]
fn round(ctr: [u32; 4], key: [u32; 2]) -> [u32; 4] {
    let p0 = (PHILOX_M0 as u64).wrapping_mul(ctr[0] as u64);
    let p1 = (PHILOX_M1 as u64).wrapping_mul(ctr[2] as u64);
    let hi0 = (p0 >> 32) as u32;
    let lo0 = p0 as u32;
    let hi1 = (p1 >> 32) as u32;
    let lo1 = p1 as u32;
    [hi1 ^ ctr[1] ^ key[0], lo1, hi0 ^ ctr[3] ^ key[1], lo0]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_answer_zero_key_zero_ctr() {
        // Reference value from the Random123 distribution (philox4x32-10,
        // key = {0,0}, ctr = {0,0,0,0}).
        let g = Philox4x32::new(0);
        assert_eq!(
            g.block([0, 0, 0, 0]),
            [0x6627_e8d5, 0xe169_c58d, 0xbc57_ac4c, 0x9b00_dbd8]
        );
    }

    #[test]
    fn known_answer_ff_pattern() {
        // Cross-checked against an independent (python, bignum) Philox
        // implementation: all-ones key and counter.
        let g = Philox4x32 {
            key: [0xffff_ffff, 0xffff_ffff],
        };
        assert_eq!(
            g.block([0xffff_ffff; 4]),
            [0x408f_276d, 0x41c8_3b0e, 0xa20b_c7c6, 0x6d54_51fd]
        );
    }

    #[test]
    fn deterministic_and_key_sensitive() {
        let a = Philox4x32::new(42).block_at(1, 2);
        let b = Philox4x32::new(42).block_at(1, 2);
        let c = Philox4x32::new(43).block_at(1, 2);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn counter_sensitivity() {
        let g = Philox4x32::new(7);
        assert_ne!(g.block_at(0, 0), g.block_at(0, 1));
        assert_ne!(g.block_at(0, 1), g.block_at(1, 0));
    }

    #[test]
    fn output_is_well_distributed() {
        // Cheap uniformity check: mean of 4096 u32 lanes ~ 2^31.
        let g = Philox4x32::new(123);
        let mut sum = 0u64;
        let n = 1024;
        for i in 0..n {
            for v in g.block_at(0, i) {
                sum += v as u64;
            }
        }
        let mean = sum as f64 / (4 * n) as f64;
        let expected = (u32::MAX as f64) / 2.0;
        assert!((mean - expected).abs() < expected * 0.02, "mean {mean}");
    }
}
