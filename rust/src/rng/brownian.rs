//! Brownian-increment batches with MLMC stream addressing and coupling.
//!
//! The MLMC estimator needs, per `(SGD step, level, chunk)`, a fresh batch
//! of increments `dW[batch, n_steps]` with `dW ~ N(0, dt)`, where the
//! *fine* and *coarse* grids of one coupled sample share a Brownian path.
//! Sharing is by construction: the coarse increments are the pairwise sums
//! of the fine ones (done inside the lowered HLO / the native engine), so
//! this module only ever produces fine-grid increments.
//!
//! Stream addressing (`stream = hash(step, level, chunk, purpose)`) keeps
//! every batch independent yet fully reproducible, matching footnote 7 of
//! the paper: refresh samples are independent across time and levels.
//!
//! # Multi-factor batches
//!
//! Multi-factor SDEs (Heston-style stochastic vol) drive each state
//! factor with its own Brownian motion. [`BrownianSource::increments_multi`]
//! produces a factor-major batch `dW[n_factors, batch, n_steps]` of
//! *independent* factor blocks, each addressed by `(purpose, step, level,
//! chunk, factor)`; the factor-0 block is bit-identical to the 1-factor
//! [`BrownianSource::increments`] batch of the same address, so the
//! default scenario's streams never move. Cross-factor correlation is a
//! *linear* map applied inside the integrator (Cholesky of the 2x2
//! correlation matrix), which commutes with pairwise summation — so the
//! MLMC coupling coarsens each factor block independently
//! ([`BrownianSource::coarsen_multi`]), exactly as today per factor.

use super::normal::NormalStream;

/// Purpose tag mixed into the stream id, so e.g. evaluation batches can
/// never collide with gradient batches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Purpose {
    Grad,
    Eval,
    Diagnostic,
}

impl Purpose {
    fn tag(self) -> u64 {
        match self {
            Purpose::Grad => 0x01,
            Purpose::Eval => 0x02,
            Purpose::Diagnostic => 0x03,
        }
    }
}

/// Factory for Brownian increment batches, keyed by a run seed.
#[derive(Debug, Clone, Copy)]
pub struct BrownianSource {
    seed: u64,
}

impl BrownianSource {
    pub fn new(seed: u64) -> Self {
        BrownianSource { seed }
    }

    /// Stable stream id for `(purpose, step, level, chunk, factor)`.
    ///
    /// SplitMix64-style mixing keeps distinct coordinates statistically
    /// independent even though they are structured (small integers).
    /// `factor` is mixed in multiplicatively so factor 0 leaves the
    /// pre-factor stream id untouched — the 1-factor addresses (and with
    /// them every seed-era batch) are bit-stable.
    fn stream_id(purpose: Purpose, step: u64, level: u32, chunk: u32, factor: u32) -> u64 {
        let mut x = purpose.tag()
            ^ step.wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ ((level as u64) << 48)
            ^ ((chunk as u64) << 32)
            ^ (factor as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93);
        x ^= x >> 30;
        x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x ^= x >> 27;
        x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
        x ^= x >> 31;
        x
    }

    /// Row-major `dW[batch, n_steps]` with `dW ~ N(0, dt)` on the fine
    /// grid of the addressed batch (the single-factor case).
    pub fn increments(
        &self,
        purpose: Purpose,
        step: u64,
        level: u32,
        chunk: u32,
        batch: usize,
        n_steps: usize,
        dt: f64,
    ) -> Vec<f32> {
        self.increments_multi(purpose, step, level, chunk, batch, n_steps, dt, 1)
    }

    /// Factor-major `dW[n_factors, batch, n_steps]`: `n_factors`
    /// independent Brownian factor blocks for one addressed batch. The
    /// factor-0 block is bit-identical to [`BrownianSource::increments`]
    /// at the same address.
    pub fn increments_multi(
        &self,
        purpose: Purpose,
        step: u64,
        level: u32,
        chunk: u32,
        batch: usize,
        n_steps: usize,
        dt: f64,
        n_factors: usize,
    ) -> Vec<f32> {
        assert!(n_factors >= 1, "need at least one factor");
        let block = batch * n_steps;
        let mut out = vec![0.0f32; n_factors * block];
        let scale = (dt as f32).sqrt();
        for k in 0..n_factors {
            let stream = Self::stream_id(purpose, step, level, chunk, k as u32);
            let ns = NormalStream::new(self.seed, stream);
            let dst = &mut out[k * block..(k + 1) * block];
            ns.fill(dst);
            for v in dst.iter_mut() {
                *v *= scale;
            }
        }
        out
    }

    /// Transpose `lanes` consecutive paths (starting at `b0`) of a
    /// factor-major batch `dw[n_factors, batch, n_steps]` into the
    /// **lane-blocked** layout the SIMD hot path consumes:
    /// `out[(k * n_steps + t) * lanes + l]` is the factor-`k`, step-`t`
    /// increment of path `b0 + l`. Each (factor, step) pair's lane vector
    /// is contiguous, so the lane integrator ([`crate::engine::lanes`])
    /// loads one `lanes`-wide row per factor per step instead of striding
    /// across `n_steps`-long path rows.
    ///
    /// Pure reshuffle — every f32 is copied untouched, so lane kernels see
    /// bit-identical increments to the scalar path they shadow.
    pub fn lane_block(
        dw: &[f32],
        n_factors: usize,
        batch: usize,
        n_steps: usize,
        b0: usize,
        lanes: usize,
        out: &mut [f32],
    ) {
        assert_eq!(dw.len(), n_factors * batch * n_steps, "shape mismatch");
        assert!(b0 + lanes <= batch, "lane block out of range");
        assert_eq!(out.len(), n_factors * n_steps * lanes, "out shape mismatch");
        for k in 0..n_factors {
            for l in 0..lanes {
                let row = &dw[(k * batch + b0 + l) * n_steps..][..n_steps];
                for (t, &v) in row.iter().enumerate() {
                    out[(k * n_steps + t) * lanes + l] = v;
                }
            }
        }
    }

    /// Pairwise-sum fine increments onto the next-coarser grid
    /// (row-major `[batch, n]` -> `[batch, n/2]`) — the MLMC coupling,
    /// mirrored from `python/compile/kernels/ref.py::coarsen_increments`.
    pub fn coarsen(dw_fine: &[f32], batch: usize, n_fine: usize) -> Vec<f32> {
        assert_eq!(dw_fine.len(), batch * n_fine, "shape mismatch");
        assert!(n_fine % 2 == 0, "fine grid must have even #steps");
        let n_coarse = n_fine / 2;
        let mut out = vec![0.0f32; batch * n_coarse];
        for b in 0..batch {
            let row = &dw_fine[b * n_fine..(b + 1) * n_fine];
            let dst = &mut out[b * n_coarse..(b + 1) * n_coarse];
            for (k, d) in dst.iter_mut().enumerate() {
                *d = row[2 * k] + row[2 * k + 1];
            }
        }
        out
    }

    /// [`BrownianSource::coarsen`] of a factor-major multi-factor batch
    /// `dW[n_factors, batch, n_fine]` — every factor block is coarsened
    /// independently (the coupling is per-driver). Bit-identical to
    /// `coarsen` for `n_factors == 1`.
    pub fn coarsen_multi(
        dw_fine: &[f32],
        n_factors: usize,
        batch: usize,
        n_fine: usize,
    ) -> Vec<f32> {
        if n_factors == 1 {
            // the common (default-scenario) case: no intermediate buffer
            return Self::coarsen(dw_fine, batch, n_fine);
        }
        assert_eq!(
            dw_fine.len(),
            n_factors * batch * n_fine,
            "shape mismatch"
        );
        let mut out = Vec::with_capacity(n_factors * batch * n_fine / 2);
        for k in 0..n_factors {
            let block = &dw_fine[k * batch * n_fine..(k + 1) * batch * n_fine];
            out.extend_from_slice(&Self::coarsen(block, batch, n_fine));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproducible_across_instances() {
        let a = BrownianSource::new(5).increments(Purpose::Grad, 10, 2, 0, 4, 8, 0.125);
        let b = BrownianSource::new(5).increments(Purpose::Grad, 10, 2, 0, 4, 8, 0.125);
        assert_eq!(a, b);
    }

    #[test]
    fn distinct_addresses_give_distinct_batches() {
        let src = BrownianSource::new(5);
        let base = src.increments(Purpose::Grad, 10, 2, 0, 4, 8, 0.125);
        for other in [
            src.increments(Purpose::Grad, 11, 2, 0, 4, 8, 0.125),
            src.increments(Purpose::Grad, 10, 3, 0, 4, 8, 0.125),
            src.increments(Purpose::Grad, 10, 2, 1, 4, 8, 0.125),
            src.increments(Purpose::Eval, 10, 2, 0, 4, 8, 0.125),
        ] {
            assert_ne!(base, other);
        }
    }

    #[test]
    fn variance_scales_with_dt() {
        let src = BrownianSource::new(0);
        let dt = 0.01;
        let v = src.increments(Purpose::Grad, 0, 0, 0, 1000, 64, dt);
        let var =
            v.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / v.len() as f64;
        assert!((var - dt).abs() < dt * 0.05, "var {var} vs dt {dt}");
    }

    #[test]
    fn coarsen_sums_pairs_and_preserves_total() {
        let dw = vec![1.0, 2.0, 3.0, 4.0, 10.0, 20.0, 30.0, 40.0];
        let c = BrownianSource::coarsen(&dw, 2, 4);
        assert_eq!(c, vec![3.0, 7.0, 30.0, 70.0]);
        // per-row totals preserved
        assert_eq!(c[0] + c[1], dw[..4].iter().sum::<f32>());
    }

    #[test]
    fn coarsened_variance_doubles() {
        // Var(dW_coarse) = 2 dt — Brownian increments add in variance.
        let src = BrownianSource::new(3);
        let dt = 0.05;
        let fine = src.increments(Purpose::Grad, 1, 1, 0, 2000, 16, dt);
        let coarse = BrownianSource::coarsen(&fine, 2000, 16);
        let var = coarse.iter().map(|&x| (x as f64).powi(2)).sum::<f64>()
            / coarse.len() as f64;
        assert!((var - 2.0 * dt).abs() < 2.0 * dt * 0.05, "var {var}");
    }

    #[test]
    #[should_panic(expected = "even")]
    fn coarsen_rejects_odd_grid() {
        BrownianSource::coarsen(&[1.0, 2.0, 3.0], 1, 3);
    }

    #[test]
    fn factor0_block_bit_identical_to_single_factor() {
        // The multi-factor generalization must not move the seed-era
        // streams: factor 0 of any D reproduces the 1-factor batch.
        let src = BrownianSource::new(17);
        let single = src.increments(Purpose::Grad, 3, 2, 1, 4, 8, 0.125);
        for n_factors in [1usize, 2] {
            let multi = src.increments_multi(
                Purpose::Grad, 3, 2, 1, 4, 8, 0.125, n_factors,
            );
            assert_eq!(multi.len(), n_factors * 4 * 8);
            assert_eq!(&multi[..4 * 8], &single[..], "D = {n_factors}");
        }
    }

    #[test]
    fn factor_blocks_are_distinct_and_correctly_scaled() {
        let src = BrownianSource::new(9);
        let dt = 0.02;
        let multi =
            src.increments_multi(Purpose::Grad, 0, 1, 0, 500, 32, dt, 2);
        let (a, b) = multi.split_at(500 * 32);
        assert_ne!(a, b, "factor blocks must be independent draws");
        for block in [a, b] {
            let var = block.iter().map(|&x| (x as f64).powi(2)).sum::<f64>()
                / block.len() as f64;
            assert!((var - dt).abs() < dt * 0.05, "var {var} vs dt {dt}");
        }
        // cross-factor sample correlation ~ 0 (raw factors are independent;
        // any rho is applied later, inside the integrator)
        let corr = a
            .iter()
            .zip(b)
            .map(|(&x, &y)| (x as f64) * (y as f64))
            .sum::<f64>()
            / (a.len() as f64 * dt);
        assert!(corr.abs() < 0.05, "raw factor correlation {corr}");
    }

    #[test]
    fn lane_block_is_a_pure_transpose() {
        // out[(k*n + t)*L + l] == dw[(k*batch + b0 + l)*n + t], bit for bit.
        let src = BrownianSource::new(21);
        let (batch, n, lanes, b0) = (11usize, 6usize, 4usize, 5usize);
        let dw = src.increments_multi(Purpose::Grad, 2, 1, 0, batch, n, 0.1, 2);
        let mut out = vec![0.0f32; 2 * n * lanes];
        BrownianSource::lane_block(&dw, 2, batch, n, b0, lanes, &mut out);
        for k in 0..2 {
            for t in 0..n {
                for l in 0..lanes {
                    assert_eq!(
                        out[(k * n + t) * lanes + l],
                        dw[(k * batch + b0 + l) * n + t],
                        "factor {k} step {t} lane {l}"
                    );
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn lane_block_rejects_out_of_range_block() {
        let dw = vec![0.0f32; 8 * 4];
        let mut out = vec![0.0f32; 4 * 4];
        BrownianSource::lane_block(&dw, 1, 8, 4, 5, 4, &mut out);
    }

    #[test]
    fn coarsen_multi_is_per_factor_coarsen() {
        let src = BrownianSource::new(2);
        let fine = src.increments_multi(Purpose::Grad, 0, 1, 0, 3, 8, 0.1, 2);
        let coarse = BrownianSource::coarsen_multi(&fine, 2, 3, 8);
        assert_eq!(coarse.len(), 2 * 3 * 4);
        for k in 0..2 {
            let want =
                BrownianSource::coarsen(&fine[k * 24..(k + 1) * 24], 3, 8);
            assert_eq!(&coarse[k * 12..(k + 1) * 12], &want[..], "factor {k}");
        }
        // single-factor coarsen_multi is bit-identical to coarsen
        let single = src.increments(Purpose::Grad, 0, 1, 0, 3, 8, 0.1);
        assert_eq!(
            BrownianSource::coarsen_multi(&single, 1, 3, 8),
            BrownianSource::coarsen(&single, 3, 8)
        );
    }
}
