//! Micro-benchmark harness (criterion substitute, offline build).
//!
//! `cargo bench` targets are declared with `harness = false` and drive
//! this module: warmup, timed iterations, and robust summary statistics
//! (median / mean / p10 / p90 over per-iteration wall times), printed in a
//! stable machine-grepable format:
//!
//! `BENCH <name> iters=<n> median=<t> mean=<t> p10=<t> p90=<t>`

use std::time::{Duration, Instant};

use crate::exec::stats::percentile;

/// One benchmark runner with fixed warmup/measure budgets.
#[derive(Debug, Clone, Copy)]
pub struct Harness {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for Harness {
    fn default() -> Self {
        Harness {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            min_iters: 5,
            max_iters: 10_000,
        }
    }
}

/// Summary statistics of one benchmark.
#[derive(Debug, Clone)]
pub struct Summary {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub p10: Duration,
    pub p90: Duration,
}

impl Summary {
    pub fn report(&self) -> String {
        format!(
            "BENCH {} iters={} median={:?} mean={:?} p10={:?} p90={:?}",
            self.name, self.iters, self.median, self.mean, self.p10, self.p90
        )
    }
}

/// Fold raw per-iteration wall times into a [`Summary`] using the
/// crate's ONE percentile definition ([`percentile`], nearest-rank) —
/// the same "p90" the run manifests ([`crate::metrics`]) and the obs
/// histogram summaries report, so a number labeled p90 means the same
/// thing in `BENCH` lines and on disk. Panics on an empty sample set
/// (a bench that measured nothing is a harness bug, not a statistic).
pub fn summarize(name: &str, samples: &[Duration]) -> Summary {
    assert!(!samples.is_empty(), "no samples to summarize");
    let secs: Vec<f64> = samples.iter().map(|d| d.as_secs_f64()).collect();
    let iters = samples.len();
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    let pct = |q: f64| Duration::from_secs_f64(percentile(&secs, q));
    Summary {
        name: name.to_string(),
        iters,
        median: pct(0.5),
        mean,
        p10: pct(0.1),
        p90: pct(0.9),
    }
}

impl Harness {
    /// Quick preset for expensive end-to-end benches.
    pub fn quick() -> Self {
        Harness {
            warmup: Duration::from_millis(100),
            measure: Duration::from_millis(800),
            min_iters: 3,
            max_iters: 1000,
        }
    }

    /// Benchmark `f`, which must consume its result via [`black_box`].
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Summary {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            f();
        }
        // Measure.
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while (start.elapsed() < self.measure || samples.len() < self.min_iters)
            && samples.len() < self.max_iters
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        let s = summarize(name, &samples);
        println!("{}", s.report());
        s
    }
}

/// Prevent the optimizer from eliding a computation (criterion-style).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_harness() -> Harness {
        Harness {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(30),
            min_iters: 3,
            max_iters: 100,
        }
    }

    #[test]
    fn runs_and_reports() {
        let mut acc = 0u64;
        let s = fast_harness().run("noop", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(s.iters >= 3);
        assert!(s.report().contains("BENCH noop"));
        assert!(s.p10 <= s.median && s.median <= s.p90);
    }

    #[test]
    fn distinguishes_cheap_from_expensive() {
        let h = fast_harness();
        let cheap = h.run("cheap", || {
            black_box(1 + 1);
        });
        let expensive = h.run("expensive", || {
            let mut v: f64 = 0.0;
            for i in 0..20_000 {
                v += black_box(i as f64).sqrt();
            }
            black_box(v);
        });
        assert!(expensive.median > cheap.median);
    }

    #[test]
    fn summary_percentiles_pin_to_the_shared_definition() {
        // Regression pin for the dedupe: bench summaries must keep using
        // exec::stats::percentile (nearest-rank), not a private variant.
        let samples: Vec<Duration> = [4u64, 1, 3, 2, 5]
            .iter()
            .map(|&ms| Duration::from_millis(ms))
            .collect();
        let s = summarize("pin", &samples);
        let secs: Vec<f64> = samples.iter().map(|d| d.as_secs_f64()).collect();
        for (got, q) in [(s.median, 0.5), (s.p10, 0.1), (s.p90, 0.9)] {
            assert_eq!(got, Duration::from_secs_f64(percentile(&secs, q)));
        }
        assert_eq!(s.median, Duration::from_millis(3));
        // nearest-rank: p10 of 5 samples is the smallest element
        assert_eq!(s.p10, Duration::from_millis(1));
        assert_eq!(s.p90, Duration::from_millis(5));
        assert_eq!(s.mean, Duration::from_millis(3));
        assert_eq!(s.iters, 5);
    }

    #[test]
    #[should_panic(expected = "no samples")]
    fn summarize_rejects_empty_input() {
        summarize("empty", &[]);
    }

    #[test]
    fn respects_max_iters() {
        let h = Harness {
            warmup: Duration::from_millis(1),
            measure: Duration::from_secs(10),
            min_iters: 1,
            max_iters: 7,
        };
        let s = h.run("capped", || {
            black_box(2 * 2);
        });
        assert_eq!(s.iters, 7);
    }
}
