//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! (HLO text + `manifest.json`) and executes them on the CPU PJRT client.
//!
//! This is the only module that touches the `xla` crate; everything above
//! it (the coordinator) talks through [`GradBackend`], which the pure-rust
//! [`crate::engine`] also implements — so the whole stack can run with or
//! without artifacts.

pub mod backend;
pub mod buffers;
pub mod manifest;
pub mod xla_rt;

pub use backend::{GradBackend, NativeBackend};
pub use manifest::{EntryKind, EntryMeta, Manifest};
pub use xla_rt::XlaRuntime;
