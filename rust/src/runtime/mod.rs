//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! (HLO text + `manifest.json`) and executes them on the CPU PJRT client.
//!
//! This is the only module that touches the `xla` crate; everything above
//! it (the coordinator) talks through [`GradBackend`], which the pure-rust
//! [`crate::engine`] also implements — so the whole stack can run with or
//! without artifacts.
//!
//! The `xla` bindings crate is not published on crates.io, so the PJRT
//! path is behind the `xla` cargo feature (see `rust/Cargo.toml`); the
//! default build substitutes [`xla_stub`], whose `XlaRuntime::load`
//! errors with a pointer at `--backend native`. All consumers compile
//! either way.

pub mod backend;
#[cfg(feature = "xla")]
pub mod buffers;
pub mod manifest;
#[cfg(feature = "xla")]
pub mod xla_rt;
#[cfg(not(feature = "xla"))]
pub mod xla_stub;

pub use backend::{GradBackend, NativeBackend, SharedBackend};
pub use manifest::{EntryKind, EntryMeta, Manifest};
#[cfg(feature = "xla")]
pub use xla_rt::XlaRuntime;
#[cfg(not(feature = "xla"))]
pub use xla_stub::XlaRuntime;
