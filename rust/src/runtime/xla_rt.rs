//! The XLA/PJRT backend: compiles the HLO-text artifacts once and serves
//! gradient/loss executions from the compiled cache.
//!
//! Pattern follows `/opt/xla-example/load_hlo`: text -> `HloModuleProto`
//! (the parser reassigns 64-bit ids) -> `XlaComputation` -> `compile` on
//! the CPU `PjRtClient` -> `execute` with `Literal` args; outputs arrive
//! as a 1-tuple (the AOT path lowers with `return_tuple=True`).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::backend::GradBackend;
use super::buffers::{literal_f32, to_scalar_f32, to_vec_f32};
use super::manifest::{EntryKind, Manifest};
use crate::hedging::Problem;

/// PJRT runtime over one artifact directory.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    /// Lazily compiled executables, keyed by entry name.
    exes: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl XlaRuntime {
    /// Load the manifest and bring up the CPU PJRT client. Compilation of
    /// individual entries is lazy (first use) unless [`warmup`] is called.
    pub fn load(artifacts_dir: &Path) -> Result<XlaRuntime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(XlaRuntime {
            client,
            manifest,
            exes: RefCell::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Eagerly compile the training-hot-path entries (per-level grads,
    /// naive grad, loss eval) so the first SGD step pays no compile cost.
    pub fn warmup(&self) -> Result<()> {
        let names: Vec<String> = self
            .manifest
            .entries
            .iter()
            .filter(|e| {
                matches!(
                    e.kind,
                    EntryKind::GradCoupled | EntryKind::GradNaive | EntryKind::LossEval
                )
            })
            .map(|e| e.name.clone())
            .collect();
        for name in names {
            self.ensure_compiled(&name)?;
        }
        Ok(())
    }

    fn ensure_compiled(&self, name: &str) -> Result<()> {
        if self.exes.borrow().contains_key(name) {
            return Ok(());
        }
        let entry = self.manifest.entry(name)?;
        let path = self.manifest.dir.join(&entry.path);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling `{name}`: {e:?}"))?;
        self.exes.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an entry with f32 literals built from flat slices shaped by
    /// the manifest; returns the tuple elements as literals.
    pub fn execute(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<xla::Literal>> {
        self.ensure_compiled(name)?;
        let entry = self.manifest.entry(name)?;
        if inputs.len() != entry.inputs.len() {
            bail!(
                "entry `{name}` takes {} inputs, got {}",
                entry.inputs.len(),
                inputs.len()
            );
        }
        let mut lits = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs.iter().zip(&entry.inputs) {
            lits.push(
                literal_f32(data, dims)
                    .with_context(|| format!("building input for `{name}`"))?,
            );
        }
        let exes = self.exes.borrow();
        let exe = exes.get(name).expect("ensured above");
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("executing `{name}`: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching `{name}` output: {e:?}"))?;
        // AOT lowers with return_tuple=True: single tuple of outputs.
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("untupling `{name}` output: {e:?}"))?;
        if parts.len() != entry.outputs.len() {
            bail!(
                "entry `{name}` declared {} outputs, produced {}",
                entry.outputs.len(),
                parts.len()
            );
        }
        Ok(parts)
    }

    fn value_and_grad(&self, name: &str, params: &[f32], dw: &[f32]) -> Result<(f64, Vec<f32>)> {
        let parts = self.execute(name, &[params, dw])?;
        let loss = to_scalar_f32(&parts[0])? as f64;
        let grad = to_vec_f32(&parts[1])?;
        Ok((loss, grad))
    }
}

impl GradBackend for XlaRuntime {
    fn n_params(&self) -> usize {
        self.manifest.n_params
    }

    fn into_shared(
        self: Box<Self>,
    ) -> std::result::Result<super::backend::SharedBackend, Box<dyn GradBackend>> {
        // PJRT client/executable handles are raw C pointers (!Send):
        // this runtime cannot cross threads, so it stays boxed and the
        // trainer dispatches sequentially.
        Err(self)
    }

    fn problem(&self) -> &Problem {
        &self.manifest.problem
    }

    fn grad_chunk(&self, level: usize) -> usize {
        self.manifest
            .grad_entry(level)
            .map(|e| e.batch)
            .expect("validated manifest has all levels")
    }

    fn naive_chunk(&self) -> usize {
        self.manifest
            .entry_of_kind(EntryKind::GradNaive)
            .map(|e| e.batch)
            .expect("validated manifest has grad_naive")
    }

    fn eval_chunk(&self) -> usize {
        self.manifest
            .entry_of_kind(EntryKind::LossEval)
            .map(|e| e.batch)
            .expect("validated manifest has loss_eval")
    }

    fn diag_chunk(&self) -> usize {
        self.manifest
            .entry_of_kind(EntryKind::GradNorms)
            .map(|e| e.batch)
            .unwrap_or(32)
    }

    fn grad_coupled_chunk(
        &self,
        level: usize,
        params: &[f32],
        dw: &[f32],
    ) -> Result<(f64, Vec<f32>)> {
        let name = self.manifest.grad_entry(level)?.name.clone();
        self.value_and_grad(&name, params, dw)
    }

    fn grad_naive_chunk(&self, params: &[f32], dw: &[f32]) -> Result<(f64, Vec<f32>)> {
        let name = self
            .manifest
            .entry_of_kind(EntryKind::GradNaive)?
            .name
            .clone();
        self.value_and_grad(&name, params, dw)
    }

    fn loss_eval_chunk(&self, params: &[f32], dw: &[f32]) -> Result<f64> {
        let name = self
            .manifest
            .entry_of_kind(EntryKind::LossEval)?
            .name
            .clone();
        let parts = self.execute(&name, &[params, dw])?;
        Ok(to_scalar_f32(&parts[0])? as f64)
    }

    fn grad_norms_chunk(
        &self,
        level: usize,
        params: &[f32],
        dw: &[f32],
    ) -> Result<Vec<f32>> {
        let name = self
            .manifest
            .diag_entry(EntryKind::GradNorms, level)?
            .name
            .clone();
        let parts = self.execute(&name, &[params, dw])?;
        to_vec_f32(&parts[0])
    }

    fn smoothness_chunk(
        &self,
        level: usize,
        params1: &[f32],
        params2: &[f32],
        dw: &[f32],
    ) -> Result<Vec<f32>> {
        let name = self
            .manifest
            .diag_entry(EntryKind::Smoothness, level)?
            .name
            .clone();
        let parts = self.execute(&name, &[params1, params2, dw])?;
        to_vec_f32(&parts[0])
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

impl XlaRuntime {
    /// Fine/coarse terminal path values (engine cross-checks).
    pub fn path_eval(&self, level: usize, dw: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let name = self
            .manifest
            .diag_entry(EntryKind::PathEval, level)?
            .name
            .clone();
        let parts = self.execute(&name, &[dw])?;
        Ok((to_vec_f32(&parts[0])?, to_vec_f32(&parts[1])?))
    }
}
