//! Literal/buffer plumbing between flat `f32` slices and the PJRT API.

use anyhow::{anyhow, bail, Result};

/// Build an f32 literal of the given dims from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let expect: usize = dims.iter().product();
    if data.len() != expect {
        bail!("literal data has {} elems, dims {:?} need {expect}", data.len(), dims);
    }
    let lit = xla::Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(lit);
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims_i64)
        .map_err(|e| anyhow!("reshape to {dims:?}: {e:?}"))
}

/// Extract a flat f32 vector from a literal.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e:?}"))
}

/// Extract a scalar f32 from a (rank-0) literal.
pub fn to_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>()
        .map_err(|e| anyhow!("literal scalar: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_1d() {
        let lit = literal_f32(&[1.0, 2.0, 3.0], &[3]).unwrap();
        assert_eq!(to_vec_f32(&lit).unwrap(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn roundtrip_2d() {
        let lit = literal_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(to_vec_f32(&lit).unwrap().len(), 6);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_f32(&[1.0, 2.0, 3.0], &[2, 2]).is_err());
    }

    #[test]
    fn scalar_extraction() {
        let lit = xla::Literal::scalar(7.5f32);
        assert_eq!(to_scalar_f32(&lit).unwrap(), 7.5);
    }
}
