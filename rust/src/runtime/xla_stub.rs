//! Build-gated stand-in for [`XlaRuntime`] when the `xla` cargo feature
//! is off (the default — the PJRT `xla` bindings crate is not published
//! on crates.io; see `rust/Cargo.toml`).
//!
//! [`XlaRuntime::load`] fails with an actionable message, so every
//! consumer (trainer, benches, integration tests) compiles unchanged and
//! degrades to the native backend / a skip. The remaining methods are
//! statically unreachable: the struct is uninhabited, so no instance can
//! ever exist to call them on.

use std::path::Path;

use anyhow::{bail, Result};

use super::backend::GradBackend;
use super::manifest::Manifest;
use crate::hedging::Problem;

/// Uninhabited placeholder for the PJRT runtime.
pub struct XlaRuntime {
    never: std::convert::Infallible,
}

impl XlaRuntime {
    /// Always errors: the binary was built without the `xla` feature.
    pub fn load(artifacts_dir: &Path) -> Result<XlaRuntime> {
        bail!(
            "cannot load artifacts from `{}`: this build has no PJRT \
             runtime (compiled without the `xla` cargo feature); use \
             `--backend native`, or add the xla bindings crate and build \
             with `--features xla`",
            artifacts_dir.display()
        )
    }

    pub fn manifest(&self) -> &Manifest {
        match self.never {}
    }

    pub fn platform(&self) -> String {
        match self.never {}
    }

    pub fn warmup(&self) -> Result<()> {
        match self.never {}
    }
}

impl GradBackend for XlaRuntime {
    fn n_params(&self) -> usize {
        match self.never {}
    }

    fn into_shared(
        self: Box<Self>,
    ) -> std::result::Result<super::backend::SharedBackend, Box<dyn GradBackend>> {
        // Mirrors the real runtime: PJRT handles are !Send, so the
        // backend stays boxed and dispatches sequentially. (Unreachable
        // here — the stub is uninhabited — but the contract must match.)
        Err(self)
    }

    fn problem(&self) -> &Problem {
        match self.never {}
    }

    fn grad_chunk(&self, _level: usize) -> usize {
        match self.never {}
    }

    fn naive_chunk(&self) -> usize {
        match self.never {}
    }

    fn eval_chunk(&self) -> usize {
        match self.never {}
    }

    fn diag_chunk(&self) -> usize {
        match self.never {}
    }

    fn grad_coupled_chunk(
        &self,
        _level: usize,
        _params: &[f32],
        _dw: &[f32],
    ) -> Result<(f64, Vec<f32>)> {
        match self.never {}
    }

    fn grad_naive_chunk(&self, _params: &[f32], _dw: &[f32]) -> Result<(f64, Vec<f32>)> {
        match self.never {}
    }

    fn loss_eval_chunk(&self, _params: &[f32], _dw: &[f32]) -> Result<f64> {
        match self.never {}
    }

    fn grad_norms_chunk(
        &self,
        _level: usize,
        _params: &[f32],
        _dw: &[f32],
    ) -> Result<Vec<f32>> {
        match self.never {}
    }

    fn smoothness_chunk(
        &self,
        _level: usize,
        _params1: &[f32],
        _params2: &[f32],
        _dw: &[f32],
    ) -> Result<Vec<f32>> {
        match self.never {}
    }

    fn name(&self) -> &'static str {
        match self.never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_fails_with_actionable_message() {
        let err = XlaRuntime::load(Path::new("artifacts")).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("xla"), "{msg}");
        assert!(msg.contains("native"), "{msg}");
    }
}
