//! `artifacts/manifest.json` — the contract between the python compile
//! path and the rust runtime. Parsed strictly: a malformed or
//! out-of-date manifest should fail loudly at startup, not at step 514.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::hedging::Problem;
use crate::util::json::Json;

/// What a lowered entry point computes (mirrors `aot.py` kinds).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EntryKind {
    /// `(params, dw[B, n_l]) -> (dloss, grad)` — MLMC unit of work.
    GradCoupled,
    /// `(params, dw[B, n_max]) -> (loss, grad)` — naive baseline.
    GradNaive,
    /// `(params, dw[B, n_max]) -> (loss,)` — held-out evaluation.
    LossEval,
    /// `(params, dw) -> (norms[B],)` — Figure 1 left.
    GradNorms,
    /// `(params1, params2, dw) -> (vals[B],)` — Figure 1 right.
    Smoothness,
    /// `(dw) -> (fine_T[B], coarse_T[B])` — engine cross-check.
    PathEval,
}

impl EntryKind {
    pub fn parse(s: &str) -> Option<EntryKind> {
        Some(match s {
            "grad_coupled" => EntryKind::GradCoupled,
            "grad_naive" => EntryKind::GradNaive,
            "loss_eval" => EntryKind::LossEval,
            "grad_norms" => EntryKind::GradNorms,
            "smoothness" => EntryKind::Smoothness,
            "path_eval" => EntryKind::PathEval,
            _ => return None,
        })
    }
}

/// One lowered entry point.
#[derive(Debug, Clone)]
pub struct EntryMeta {
    pub name: String,
    pub kind: EntryKind,
    /// HLO text file, relative to the artifact dir.
    pub path: PathBuf,
    pub level: Option<usize>,
    /// Chunk batch the artifact was lowered with.
    pub batch: usize,
    pub n_steps: usize,
    /// Input shapes (all f32).
    pub inputs: Vec<Vec<usize>>,
    /// Output shapes (all f32).
    pub outputs: Vec<Vec<usize>>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub problem: Problem,
    pub n_params: usize,
    pub entries: Vec<EntryMeta>,
    pub init_params_file: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`?)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;

        let version = j
            .field("format_version")
            .map_err(|e| anyhow!("{e}"))?
            .as_i64()
            .unwrap_or(-1);
        if version != 1 {
            bail!("unsupported manifest format_version {version}");
        }

        let problem = Problem::from_manifest(j.field("problem").map_err(|e| anyhow!("{e}"))?)
            .map_err(|e| anyhow!("manifest problem: {e}"))?;
        let n_params = j
            .field("n_params")
            .map_err(|e| anyhow!("{e}"))?
            .as_usize()
            .ok_or_else(|| anyhow!("n_params must be an integer"))?;
        let init_params_file = dir.join(
            j.field("init_params")
                .map_err(|e| anyhow!("{e}"))?
                .as_str()
                .ok_or_else(|| anyhow!("init_params must be a string"))?,
        );

        let mut entries = Vec::new();
        for ej in j
            .field("entries")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("entries must be an array"))?
        {
            entries.push(parse_entry(ej)?);
        }
        let m = Manifest {
            dir: dir.to_path_buf(),
            problem,
            n_params,
            entries,
            init_params_file,
        };
        m.validate()?;
        Ok(m)
    }

    /// Structural consistency beyond per-field parsing.
    pub fn validate(&self) -> Result<()> {
        for l in 0..=self.problem.lmax {
            self.grad_entry(l).with_context(|| {
                format!("manifest is missing grad_coupled for level {l}")
            })?;
        }
        self.entry_of_kind(EntryKind::GradNaive)?;
        self.entry_of_kind(EntryKind::LossEval)?;
        for e in &self.entries {
            match e.kind {
                EntryKind::GradCoupled | EntryKind::GradNaive => {
                    if e.inputs.len() != 2
                        || e.inputs[0] != vec![self.n_params]
                        || e.inputs[1] != vec![e.batch, e.n_steps]
                        || e.outputs.len() != 2
                        || e.outputs[1] != vec![self.n_params]
                    {
                        bail!("entry `{}` has inconsistent shapes", e.name);
                    }
                }
                EntryKind::LossEval => {
                    if e.outputs.len() != 1 || !e.outputs[0].is_empty() {
                        bail!("entry `{}` must output one scalar", e.name);
                    }
                }
                _ => {}
            }
            if let Some(level) = e.level {
                if matches!(e.kind, EntryKind::GradCoupled)
                    && e.n_steps != self.problem.n_steps(level)
                {
                    bail!(
                        "entry `{}`: n_steps {} != problem grid {}",
                        e.name,
                        e.n_steps,
                        self.problem.n_steps(level)
                    );
                }
            }
        }
        Ok(())
    }

    pub fn entry(&self, name: &str) -> Result<&EntryMeta> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow!("no entry `{name}` in manifest"))
    }

    pub fn entry_of_kind(&self, kind: EntryKind) -> Result<&EntryMeta> {
        self.entries
            .iter()
            .find(|e| e.kind == kind)
            .ok_or_else(|| anyhow!("no entry of kind {kind:?} in manifest"))
    }

    /// The `grad_coupled` entry for a level.
    pub fn grad_entry(&self, level: usize) -> Result<&EntryMeta> {
        self.entries
            .iter()
            .find(|e| e.kind == EntryKind::GradCoupled && e.level == Some(level))
            .ok_or_else(|| anyhow!("no grad_coupled entry for level {level}"))
    }

    pub fn diag_entry(&self, kind: EntryKind, level: usize) -> Result<&EntryMeta> {
        self.entries
            .iter()
            .find(|e| e.kind == kind && e.level == Some(level))
            .ok_or_else(|| anyhow!("no {kind:?} entry for level {level}"))
    }

    /// Initial parameter vector lowered by `aot.py` (bit-identical to the
    /// python `init_params(0)`).
    pub fn load_init_params(&self) -> Result<Vec<f32>> {
        let raw = std::fs::read(&self.init_params_file).with_context(|| {
            format!("reading {}", self.init_params_file.display())
        })?;
        if raw.len() != self.n_params * 4 {
            bail!(
                "init_params has {} bytes, expected {}",
                raw.len(),
                self.n_params * 4
            );
        }
        Ok(raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }
}

fn parse_entry(j: &Json) -> Result<EntryMeta> {
    let name = j
        .field("name")
        .map_err(|e| anyhow!("{e}"))?
        .as_str()
        .ok_or_else(|| anyhow!("entry name must be a string"))?
        .to_string();
    let kind_s = j
        .field("kind")
        .map_err(|e| anyhow!("entry `{name}`: {e}"))?
        .as_str()
        .ok_or_else(|| anyhow!("entry `{name}`: kind must be a string"))?;
    let kind = EntryKind::parse(kind_s)
        .ok_or_else(|| anyhow!("entry `{name}`: unknown kind `{kind_s}`"))?;
    let shapes = |key: &str| -> Result<Vec<Vec<usize>>> {
        j.field(key)
            .map_err(|e| anyhow!("entry `{name}`: {e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("entry `{name}`: {key} must be an array"))?
            .iter()
            .map(|io| {
                Ok(io
                    .field("shape")
                    .map_err(|e| anyhow!("entry `{name}`: {e}"))?
                    .as_arr()
                    .ok_or_else(|| anyhow!("entry `{name}`: shape must be array"))?
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect())
            })
            .collect()
    };
    Ok(EntryMeta {
        kind,
        path: PathBuf::from(
            j.field("path")
                .map_err(|e| anyhow!("entry `{name}`: {e}"))?
                .as_str()
                .ok_or_else(|| anyhow!("entry `{name}`: path must be a string"))?,
        ),
        level: j.get("level").and_then(|v| v.as_usize()),
        batch: j
            .get("batch")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow!("entry `{name}`: missing batch"))?,
        n_steps: j
            .get("n_steps")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow!("entry `{name}`: missing n_steps"))?,
        inputs: shapes("inputs")?,
        outputs: shapes("outputs")?,
        name,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn loads_real_manifest_if_built() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.n_params, 1186);
        assert_eq!(m.problem.lmax, 6);
        assert!(m.entries.len() >= 9);
        let g3 = m.grad_entry(3).unwrap();
        assert_eq!(g3.n_steps, 32);
        let init = m.load_init_params().unwrap();
        assert_eq!(init.len(), 1186);
        // biases at the tail are zero-initialised
        assert_eq!(init[1185], 0.0);
    }

    #[test]
    fn missing_dir_is_helpful_error() {
        let err = Manifest::load(Path::new("/nonexistent/prefix")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn rejects_bad_version() {
        let dir = std::env::temp_dir().join(format!("dmlmc_m_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format_version": 99, "problem": {}, "n_params": 1,
                "init_params": "x.bin", "entries": []}"#,
        )
        .unwrap();
        let err = Manifest::load(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("format_version"));
    }

    #[test]
    fn entry_kind_parse_total() {
        for s in [
            "grad_coupled",
            "grad_naive",
            "loss_eval",
            "grad_norms",
            "smoothness",
            "path_eval",
        ] {
            assert!(EntryKind::parse(s).is_some(), "{s}");
        }
        assert!(EntryKind::parse("nope").is_none());
    }
}
