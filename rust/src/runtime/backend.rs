//! [`GradBackend`] — the coordinator's view of "something that can compute
//! level gradients". Two implementations:
//!
//! * [`XlaRuntime`](super::XlaRuntime) — AOT HLO artifacts on PJRT (the
//!   production path, Python-free at run time);
//! * [`NativeBackend`] — the pure-rust [`crate::engine`] (verification,
//!   CI without artifacts, and the threaded-dispatch demonstrations).
//!
//! All `*_chunk` methods operate on ONE chunk whose batch size the backend
//! dictates (`grad_chunk(level)` etc.); the coordinator accumulates chunks
//! to reach the `N_l` allocation.

use std::sync::Arc;

use anyhow::Result;

use crate::engine;
use crate::hedging::Problem;
use crate::scenarios::kernels::{self, KernelFns, ScenarioKernel};
use crate::scenarios::Scenario;

/// A thread-safe backend handle the resident pool's `'static` dispatch
/// jobs can co-own — the `Ok` side of [`GradBackend::into_shared`].
pub type SharedBackend = Arc<dyn GradBackend + Send + Sync>;

/// Gradient/loss execution interface (one chunk at a time).
pub trait GradBackend {
    fn n_params(&self) -> usize;

    fn problem(&self) -> &Problem;

    /// Chunk batch for `grad_coupled` at `level`.
    fn grad_chunk(&self, level: usize) -> usize;

    /// Chunk batch for the naive (finest-grid) gradient.
    fn naive_chunk(&self) -> usize;

    /// Chunk batch for held-out loss evaluation.
    fn eval_chunk(&self) -> usize;

    /// Chunk batch for the per-sample diagnostics (Figure 1).
    fn diag_chunk(&self) -> usize;

    /// Brownian factors every `dw` batch must carry (the scenario SDE's
    /// dimension). Callers generate factor-major
    /// `dw[n_factors, batch, n_steps]` via
    /// [`crate::rng::BrownianSource::increments_multi`]; for the default
    /// 1-D scenarios this is exactly the seed layout.
    fn n_factors(&self) -> usize {
        1
    }

    /// Convert this boxed backend into a shared (`Arc`) handle — the gate
    /// for pooled dispatch ([`crate::exec::WorkerPool`]). The resident
    /// pool's workers outlive any one dispatch, so its job closures are
    /// `'static` and must capture an owned `Arc` of the backend instead
    /// of a scope-borrowed reference. `Ok` shares the backend (the native
    /// engine: plain data, `Send + Sync`); `Err` hands the box back for
    /// backends that cannot cross threads (the PJRT runtime's handles are
    /// `!Send` raw C pointers) — those dispatch sequentially.
    fn into_shared(
        self: Box<Self>,
    ) -> std::result::Result<SharedBackend, Box<dyn GradBackend>>;

    /// One chunk of the coupled objective `Delta_l F` value-and-grad.
    /// `dw` is factor-major `[n_factors, grad_chunk(level),
    /// n_steps(level)]` fine-grid increments. Returns
    /// `(loss_delta, grad[n_params])`.
    fn grad_coupled_chunk(
        &self,
        level: usize,
        params: &[f32],
        dw: &[f32],
    ) -> Result<(f64, Vec<f32>)>;

    /// One chunk of the naive finest-grid value-and-grad.
    fn grad_naive_chunk(&self, params: &[f32], dw: &[f32]) -> Result<(f64, Vec<f32>)>;

    /// One chunk of the held-out loss at the finest grid.
    fn loss_eval_chunk(&self, params: &[f32], dw: &[f32]) -> Result<f64>;

    /// Per-sample `||grad Delta_l F_hat||^2` (Figure 1 left).
    fn grad_norms_chunk(
        &self,
        level: usize,
        params: &[f32],
        dw: &[f32],
    ) -> Result<Vec<f32>>;

    /// Per-sample pathwise smoothness between two parameter vectors
    /// (Figure 1 right).
    fn smoothness_chunk(
        &self,
        level: usize,
        params1: &[f32],
        params2: &[f32],
        dw: &[f32],
    ) -> Result<Vec<f32>>;

    fn name(&self) -> &'static str;
}

/// Chunk-size policy shared with `python/compile/problem.py::GRAD_CHUNK`.
/// Sized so each PJRT execution is compute- rather than dispatch-bound
/// (B*n = 512 rows uniformly for levels <= 4; see EXPERIMENTS.md §Perf).
pub fn default_grad_chunk(level: usize) -> usize {
    match level {
        0 => 128,
        1 => 64,
        2 => 32,
        3 => 16,
        _ => 8,
    }
}

/// Pure-rust backend over [`crate::engine`], running one
/// [`Scenario`] (the default scenario unless built with
/// [`NativeBackend::with_scenario`]). This is the only backend that can
/// run non-default scenarios — the XLA artifacts are lowered for the
/// default scenario alone.
///
/// The hot chunk methods (`grad_coupled_chunk`, `grad_naive_chunk`,
/// `loss_eval_chunk`) dispatch through the **static kernel registry**
/// ([`crate::scenarios::kernels`]): the scenario key is resolved once at
/// construction to a monomorphized kernel (lane-blocked when the key
/// carries the `-simd` suffix), so the per-step loop pays no virtual
/// calls. Static dispatch of the same generic body performs identical
/// f32 operations in identical order, keeping the `bs-call` bitwise
/// anchors intact. The per-sample diagnostics keep the `dyn` scenario
/// path — they are not on the training hot path.
#[derive(Debug, Clone)]
pub struct NativeBackend {
    problem: Problem,
    scenario: Scenario,
    kernel: Option<&'static ScenarioKernel>,
    simd: bool,
}

impl NativeBackend {
    pub fn new(problem: Problem) -> Self {
        let scenario = Scenario::from_problem(&problem);
        Self::with_scenario(problem, scenario)
    }

    pub fn with_scenario(problem: Problem, scenario: Scenario) -> Self {
        let (kernel, simd) = match kernels::resolve(&scenario.name) {
            Some((k, simd)) => (Some(k), simd),
            None => (None, false),
        };
        NativeBackend {
            problem,
            scenario,
            kernel,
            simd,
        }
    }

    pub fn scenario(&self) -> &Scenario {
        &self.scenario
    }

    /// Whether the hot path runs a monomorphized kernel from the static
    /// registry (true for every registry-built scenario; false only for
    /// hand-assembled [`Scenario`] values with unregistered names, which
    /// fall back to `dyn` dispatch).
    pub fn has_static_kernel(&self) -> bool {
        self.kernel.is_some()
    }

    /// Whether the lane-blocked (`-simd` key) kernel variant is selected.
    pub fn is_simd(&self) -> bool {
        self.simd
    }

    /// The kernel set the hot chunk methods dispatch through.
    fn kernel_fns(&self) -> Option<&'static KernelFns> {
        self.kernel
            .map(|k| if self.simd { &k.lanes } else { &k.scalar })
    }

    /// The increments of sample `b` from a factor-major `dw[dim, batch,
    /// n]` batch, as a `[dim, 1, n]` batch the engine can run with
    /// `batch = 1` (the per-sample diagnostics). For `dim == 1` the
    /// sample's row is already contiguous and is borrowed zero-copy; only
    /// `dim > 1` gathers the non-contiguous factor rows into `buf`.
    fn sample_rows<'a>(
        dw: &'a [f32],
        dim: usize,
        batch: usize,
        n: usize,
        b: usize,
        buf: &'a mut Vec<f32>,
    ) -> &'a [f32] {
        if dim == 1 {
            return &dw[b * n..(b + 1) * n];
        }
        buf.clear();
        let rows = crate::engine::milstein::factor_rows(dw, dim, batch, n, b);
        for row in rows.iter().take(dim) {
            buf.extend_from_slice(row);
        }
        buf
    }
}

impl GradBackend for NativeBackend {
    fn n_params(&self) -> usize {
        engine::N_PARAMS
    }

    fn problem(&self) -> &Problem {
        &self.problem
    }

    fn grad_chunk(&self, level: usize) -> usize {
        default_grad_chunk(level)
    }

    fn naive_chunk(&self) -> usize {
        8
    }

    fn eval_chunk(&self) -> usize {
        256
    }

    fn diag_chunk(&self) -> usize {
        32
    }

    fn n_factors(&self) -> usize {
        self.scenario.sde.dim()
    }

    fn into_shared(
        self: Box<Self>,
    ) -> std::result::Result<SharedBackend, Box<dyn GradBackend>> {
        Ok(Arc::new(*self))
    }

    fn grad_coupled_chunk(
        &self,
        level: usize,
        params: &[f32],
        dw: &[f32],
    ) -> Result<(f64, Vec<f32>)> {
        let batch = self.grad_chunk(level);
        if let Some(fns) = self.kernel_fns() {
            return Ok((fns.coupled_value_and_grad)(
                params,
                dw,
                batch,
                level,
                &self.problem,
            ));
        }
        Ok(engine::coupled_value_and_grad_scenario(
            params,
            dw,
            batch,
            level,
            &self.problem,
            &self.scenario,
        ))
    }

    fn grad_naive_chunk(&self, params: &[f32], dw: &[f32]) -> Result<(f64, Vec<f32>)> {
        let n = self.problem.n_steps(self.problem.lmax);
        if let Some(fns) = self.kernel_fns() {
            return Ok((fns.value_and_grad)(
                params,
                dw,
                self.naive_chunk(),
                n,
                &self.problem,
            ));
        }
        Ok(engine::value_and_grad_scenario(
            params,
            dw,
            self.naive_chunk(),
            n,
            &self.problem,
            &self.scenario,
        ))
    }

    fn loss_eval_chunk(&self, params: &[f32], dw: &[f32]) -> Result<f64> {
        let n = self.problem.n_steps(self.problem.lmax);
        if let Some(fns) = self.kernel_fns() {
            return Ok((fns.loss_only)(
                params,
                dw,
                self.eval_chunk(),
                n,
                &self.problem,
            ));
        }
        Ok(engine::loss_only_scenario(
            params,
            dw,
            self.eval_chunk(),
            n,
            &self.problem,
            &self.scenario,
        ))
    }

    fn grad_norms_chunk(
        &self,
        level: usize,
        params: &[f32],
        dw: &[f32],
    ) -> Result<Vec<f32>> {
        let n = self.problem.n_steps(level);
        let batch = self.diag_chunk();
        let dim = self.n_factors();
        anyhow::ensure!(dw.len() == dim * batch * n, "diag dw shape mismatch");
        let mut out = Vec::with_capacity(batch);
        let mut buf = Vec::with_capacity(dim * n);
        for b in 0..batch {
            let row = Self::sample_rows(dw, dim, batch, n, b, &mut buf);
            let (_, g) = engine::coupled_value_and_grad_scenario(
                params,
                row,
                1,
                level,
                &self.problem,
                &self.scenario,
            );
            out.push(g.iter().map(|&x| x * x).sum::<f32>());
        }
        Ok(out)
    }

    fn smoothness_chunk(
        &self,
        level: usize,
        params1: &[f32],
        params2: &[f32],
        dw: &[f32],
    ) -> Result<Vec<f32>> {
        let n = self.problem.n_steps(level);
        let batch = self.diag_chunk();
        let dim = self.n_factors();
        anyhow::ensure!(dw.len() == dim * batch * n, "diag dw shape mismatch");
        let dx = params1
            .iter()
            .zip(params2)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
            .max(1e-12);
        let mut out = Vec::with_capacity(batch);
        let mut buf = Vec::with_capacity(dim * n);
        for b in 0..batch {
            let row = Self::sample_rows(dw, dim, batch, n, b, &mut buf);
            let (_, g1) = engine::coupled_value_and_grad_scenario(
                params1,
                row,
                1,
                level,
                &self.problem,
                &self.scenario,
            );
            let (_, g2) = engine::coupled_value_and_grad_scenario(
                params2,
                row,
                1,
                level,
                &self.problem,
                &self.scenario,
            );
            let dg = g1
                .iter()
                .zip(&g2)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            out.push((dg / dx) as f32);
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::mlp::init_params;
    use crate::rng::{brownian::Purpose, BrownianSource};

    fn backend() -> NativeBackend {
        NativeBackend::new(Problem::default())
    }

    fn dw_for(b: &NativeBackend, level: usize, batch: usize) -> Vec<f32> {
        let n = b.problem().n_steps(level);
        BrownianSource::new(0).increments(
            Purpose::Grad,
            0,
            level as u32,
            0,
            batch,
            n,
            b.problem().dt(level),
        )
    }

    #[test]
    fn grad_chunk_policy_matches_python() {
        let b = backend();
        assert_eq!(b.grad_chunk(0), 128);
        assert_eq!(b.grad_chunk(1), 64);
        assert_eq!(b.grad_chunk(2), 32);
        assert_eq!(b.grad_chunk(3), 16);
        for l in 4..=6 {
            assert_eq!(b.grad_chunk(l), 8);
        }
    }

    #[test]
    fn grad_coupled_has_right_dim() {
        let b = backend();
        let params = init_params(0);
        let dw = dw_for(&b, 1, b.grad_chunk(1));
        let (loss, grad) = b.grad_coupled_chunk(1, &params, &dw).unwrap();
        assert!(loss.is_finite());
        assert_eq!(grad.len(), b.n_params());
        assert!(grad.iter().any(|&g| g != 0.0));
    }

    #[test]
    fn grad_norms_positive_and_sized() {
        let b = backend();
        let params = init_params(0);
        let dw = dw_for(&b, 2, b.diag_chunk());
        let norms = b.grad_norms_chunk(2, &params, &dw).unwrap();
        assert_eq!(norms.len(), b.diag_chunk());
        assert!(norms.iter().all(|&v| v >= 0.0 && v.is_finite()));
    }

    #[test]
    fn smoothness_zero_for_identical_params() {
        let b = backend();
        let params = init_params(0);
        let dw = dw_for(&b, 1, b.diag_chunk());
        let vals = b.smoothness_chunk(1, &params, &params, &dw).unwrap();
        assert!(vals.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn default_backend_runs_the_default_scenario_bitwise() {
        use crate::scenarios::{build_scenario, DEFAULT_SCENARIO};
        let problem = Problem::default();
        let plain = NativeBackend::new(problem);
        let explicit = NativeBackend::with_scenario(
            problem,
            build_scenario(DEFAULT_SCENARIO, &problem).unwrap(),
        );
        assert!(plain.scenario().is_default());
        let params = init_params(0);
        let dw = dw_for(&plain, 2, plain.grad_chunk(2));
        let (l1, g1) = plain.grad_coupled_chunk(2, &params, &dw).unwrap();
        let (l2, g2) = explicit.grad_coupled_chunk(2, &params, &dw).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(g1, g2);
    }

    #[test]
    fn heston_backend_runs_two_factor_chunks() {
        use crate::scenarios::build_scenario;
        let problem = Problem::default();
        let b = NativeBackend::with_scenario(
            problem,
            build_scenario("heston-call", &problem).unwrap(),
        );
        assert_eq!(b.n_factors(), 2);
        let params = init_params(0);
        let level = 2;
        let n = problem.n_steps(level);
        let dw = BrownianSource::new(0).increments_multi(
            Purpose::Grad, 0, level as u32, 0, b.grad_chunk(level), n,
            problem.dt(level), b.n_factors(),
        );
        let (loss, grad) = b.grad_coupled_chunk(level, &params, &dw).unwrap();
        assert!(loss.is_finite());
        assert!(grad.iter().all(|g| g.is_finite()));
        // per-sample diagnostics extract non-contiguous factor rows
        let dwd = BrownianSource::new(1).increments_multi(
            Purpose::Diagnostic, 0, level as u32, 0, b.diag_chunk(), n,
            problem.dt(level), b.n_factors(),
        );
        let norms = b.grad_norms_chunk(level, &params, &dwd).unwrap();
        assert_eq!(norms.len(), b.diag_chunk());
        assert!(norms.iter().all(|&v| v >= 0.0 && v.is_finite()));
    }

    #[test]
    fn native_backend_converts_into_a_shared_handle() {
        let b: Box<dyn GradBackend> = Box::new(backend());
        let shared = b.into_shared().ok().expect("native engine is Send + Sync");
        assert_eq!(shared.name(), "native");
        // the shared handle is the same backend: identical chunk policy
        assert_eq!(shared.grad_chunk(0), 128);
        // and it clones freely across dispatch closures
        let clone = shared.clone();
        assert_eq!(clone.n_params(), shared.n_params());
        // non-default (2-factor) scenarios share too
        let h: Box<dyn GradBackend> = Box::new(NativeBackend::with_scenario(
            Problem::default(),
            crate::scenarios::build_scenario("heston-call", &Problem::default()).unwrap(),
        ));
        assert!(h.into_shared().is_ok());
    }

    #[test]
    fn registry_scenarios_resolve_static_kernels_and_custom_names_fall_back() {
        use crate::scenarios::build_scenario;
        let problem = Problem::default();
        for name in ["bs-call", "heston-uo-call", "cir-digital-simd"] {
            let b = NativeBackend::with_scenario(
                problem,
                build_scenario(name, &problem).unwrap(),
            );
            assert!(b.has_static_kernel(), "{name} should hit the table");
            assert_eq!(b.is_simd(), name.ends_with("-simd"), "{name}");
        }
        // hand-assembled scenario with an unregistered name: dyn fallback
        let mut sc = Scenario::from_problem(&problem);
        sc.name = "custom-thing".to_string();
        let b = NativeBackend::with_scenario(problem, sc);
        assert!(!b.has_static_kernel());
        let params = init_params(0);
        let dw = dw_for(&b, 1, b.grad_chunk(1));
        let (loss, _) = b.grad_coupled_chunk(1, &params, &dw).unwrap();
        assert!(loss.is_finite());
    }

    #[test]
    fn simd_backend_matches_scalar_backend_within_tolerance() {
        use crate::scenarios::build_scenario;
        let problem = Problem::default();
        let scalar = NativeBackend::with_scenario(
            problem,
            build_scenario("heston-uo-call", &problem).unwrap(),
        );
        let simd = NativeBackend::with_scenario(
            problem,
            build_scenario("heston-uo-call-simd", &problem).unwrap(),
        );
        assert!(simd.is_simd() && !scalar.is_simd());
        assert_eq!(simd.n_factors(), 2);
        let params = init_params(0);
        let level = 2;
        let n = problem.n_steps(level);
        let dw = BrownianSource::new(4).increments_multi(
            Purpose::Grad, 0, level as u32, 0, scalar.grad_chunk(level), n,
            problem.dt(level), 2,
        );
        let (l1, g1) = scalar.grad_coupled_chunk(level, &params, &dw).unwrap();
        let (l2, g2) = simd.grad_coupled_chunk(level, &params, &dw).unwrap();
        assert!(
            (l1 - l2).abs() <= 1e-3 * l1.abs().max(1.0),
            "loss {l1} vs {l2}"
        );
        for (i, (&a, &b)) in g1.iter().zip(&g2).enumerate() {
            assert!(
                (a - b).abs() <= 5e-3 * a.abs().max(b.abs()).max(1.0),
                "grad[{i}]: {a} vs {b}"
            );
        }
    }

    #[test]
    fn non_default_scenario_changes_the_objective() {
        use crate::scenarios::build_scenario;
        let problem = Problem::default();
        let default = NativeBackend::new(problem);
        let asian = NativeBackend::with_scenario(
            problem,
            build_scenario("bs-asian", &problem).unwrap(),
        );
        let params = init_params(0);
        let dw = dw_for(&default, 1, default.grad_chunk(1));
        let (l1, _) = default.grad_coupled_chunk(1, &params, &dw).unwrap();
        let (l2, _) = asian.grad_coupled_chunk(1, &params, &dw).unwrap();
        assert_ne!(l1, l2, "asian payoff should move the coupled loss");
    }
}
