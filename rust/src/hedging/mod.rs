//! The deep-hedging problem definition (paper Appendix C) and its
//! analytic validation substrate (Black–Scholes closed form).

pub mod blackscholes;
pub mod payoff;
pub mod problem;

pub use blackscholes::bs_call_price;
pub use payoff::call_payoff;
pub use problem::{Drift, Problem};
