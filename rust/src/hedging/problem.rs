//! Deep-hedging problem instance — the Rust mirror of
//! `python/compile/problem.py::HedgingProblem`.
//!
//! Paper Appendix C values: mu = 1, sigma = 1, K = 3, lmax = 6; `s0` is
//! not given in the paper, we use the at-the-money convention `s0 = K`.
//! The same struct is populated from `artifacts/manifest.json` by the
//! runtime so the Rust side can never drift from what was lowered.

use crate::util::json::{Json, JsonError};

/// SDE drift form.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Drift {
    /// `dS = mu dt + sigma S dB` — the paper's Appendix-C SDE as written.
    Additive,
    /// `dS = mu S dt + sigma S dB` — true GBM; lets the learned `p0` be
    /// validated against the Black–Scholes closed form.
    Geometric,
}

impl Drift {
    pub fn parse(s: &str) -> Option<Drift> {
        match s {
            "additive" => Some(Drift::Additive),
            "geometric" => Some(Drift::Geometric),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Drift::Additive => "additive",
            Drift::Geometric => "geometric",
        }
    }
}

/// Deep-hedging problem instance (paper Appendix C).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Problem {
    pub mu: f64,
    pub sigma: f64,
    pub strike: f64,
    pub s0: f64,
    pub maturity: f64,
    /// Steps at level 0; level `l` uses `n0 * 2^l`.
    pub n0: usize,
    pub lmax: usize,
    pub drift: Drift,
}

impl Default for Problem {
    fn default() -> Self {
        Problem {
            mu: 1.0,
            sigma: 1.0,
            strike: 3.0,
            s0: 3.0,
            maturity: 1.0,
            n0: 4,
            lmax: 6,
            drift: Drift::Additive,
        }
    }
}

impl Problem {
    /// Number of Milstein steps on the level-`level` grid.
    pub fn n_steps(&self, level: usize) -> usize {
        self.n0 << level
    }

    pub fn dt(&self, level: usize) -> f64 {
        self.maturity / self.n_steps(level) as f64
    }

    /// Parse from the `problem` object of `artifacts/manifest.json`.
    pub fn from_manifest(j: &Json) -> Result<Problem, JsonError> {
        let f = |k: &str| -> Result<f64, JsonError> {
            j.field(k)?
                .as_f64()
                .ok_or_else(|| JsonError(format!("problem.{k}: not a number")))
        };
        let drift_s = j
            .field("drift")?
            .as_str()
            .ok_or_else(|| JsonError("problem.drift: not a string".into()))?;
        Ok(Problem {
            mu: f("mu")?,
            sigma: f("sigma")?,
            strike: f("strike")?,
            s0: f("s0")?,
            maturity: f("maturity")?,
            n0: f("n0")? as usize,
            lmax: f("lmax")? as usize,
            drift: Drift::parse(drift_s)
                .ok_or_else(|| JsonError(format!("unknown drift `{drift_s}`")))?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn level_grids_double() {
        let p = Problem::default();
        assert_eq!(p.n_steps(0), 4);
        assert_eq!(p.n_steps(6), 256);
        assert!((p.dt(1) - 0.125).abs() < 1e-12);
    }

    #[test]
    fn from_manifest_roundtrip() {
        let j = Json::parse(
            r#"{"mu":1.0,"sigma":1.0,"strike":3.0,"s0":3.0,"maturity":1.0,
                "n0":4,"lmax":6,"drift":"additive"}"#,
        )
        .unwrap();
        let p = Problem::from_manifest(&j).unwrap();
        assert_eq!(p, Problem::default());
    }

    #[test]
    fn from_manifest_rejects_bad_drift() {
        let j = Json::parse(
            r#"{"mu":1,"sigma":1,"strike":3,"s0":3,"maturity":1,
                "n0":4,"lmax":6,"drift":"weird"}"#,
        )
        .unwrap();
        assert!(Problem::from_manifest(&j).is_err());
    }

    #[test]
    fn drift_parse_roundtrip() {
        for d in [Drift::Additive, Drift::Geometric] {
            assert_eq!(Drift::parse(d.name()), Some(d));
        }
        assert_eq!(Drift::parse("x"), None);
    }
}
