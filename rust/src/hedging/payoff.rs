//! Option payoffs.

/// European call payoff `max(S_T - K, 0)` (the instrument hedged in the
/// paper's experiment).
#[inline]
pub fn call_payoff(s_t: f32, strike: f32) -> f32 {
    (s_t - strike).max(0.0)
}

/// European put payoff `max(K - S_T, 0)` — used by tests for put-call
/// parity style checks and by the extension examples.
#[inline]
pub fn put_payoff(s_t: f32, strike: f32) -> f32 {
    (strike - s_t).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn call_kinks_at_strike() {
        assert_eq!(call_payoff(2.0, 3.0), 0.0);
        assert_eq!(call_payoff(3.0, 3.0), 0.0);
        assert_eq!(call_payoff(4.5, 3.0), 1.5);
    }

    #[test]
    fn put_is_mirror() {
        assert_eq!(put_payoff(2.0, 3.0), 1.0);
        assert_eq!(put_payoff(4.0, 3.0), 0.0);
    }

    #[test]
    fn put_call_parity_of_payoffs() {
        // call - put = S - K pointwise.
        for s in [0.0f32, 1.7, 3.0, 8.25] {
            let lhs = call_payoff(s, 3.0) - put_payoff(s, 3.0);
            assert!((lhs - (s - 3.0)).abs() < 1e-6);
        }
    }
}
