//! Black–Scholes closed form — the analytic validation substrate.
//!
//! Under the `geometric` drift (true GBM) and continuous hedging, the
//! learned option price `p0` must converge to the Black–Scholes value
//! *regardless of the drift mu* (complete market / perfect replication).
//! The `validate` subcommand and the end-to-end tests use this as an
//! external anchor that the whole stack — kernels, AOT, runtime,
//! coordinator — optimizes the right objective.

/// Standard normal CDF via `erf`.
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Error function, Abramowitz & Stegun 7.1.26 rational approximation
/// (|error| < 1.5e-7 — far below our Monte Carlo noise floor).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let poly = t
        * (0.254_829_592
            + t * (-0.284_496_736
                + t * (1.421_413_741 + t * (-1.453_152_027 + t * 1.061_405_429))));
    sign * (1.0 - poly * (-x * x).exp())
}

/// Black–Scholes price of a European call with zero interest rate.
///
/// `bs_call_price(s0, k, sigma, t)` = `s0 N(d1) - k N(d2)`.
pub fn bs_call_price(s0: f64, strike: f64, sigma: f64, maturity: f64) -> f64 {
    if maturity <= 0.0 || sigma <= 0.0 {
        return (s0 - strike).max(0.0);
    }
    let vol = sigma * maturity.sqrt();
    let d1 = ((s0 / strike).ln() + 0.5 * sigma * sigma * maturity) / vol;
    let d2 = d1 - vol;
    s0 * norm_cdf(d1) - strike * norm_cdf(d2)
}

/// Black–Scholes delta (the exact hedging strategy H(t, s) for GBM) —
/// used to sanity-check what the MLP should be learning.
pub fn bs_call_delta(s: f64, strike: f64, sigma: f64, tau: f64) -> f64 {
    if tau <= 0.0 {
        return if s > strike { 1.0 } else { 0.0 };
    }
    let vol = sigma * tau.sqrt();
    let d1 = ((s / strike).ln() + 0.5 * sigma * sigma * tau) / vol;
    norm_cdf(d1)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tolerances: A&S 7.1.26 guarantees |error| <= 1.5e-7 only.
    #[test]
    fn erf_known_values() {
        assert!((erf(0.0)).abs() < 1.5e-7);
        assert!((erf(1.0) - 0.842_700_79).abs() < 1.5e-7);
        assert!((erf(-1.0) + 0.842_700_79).abs() < 1.5e-7);
        assert!((erf(3.0) - 0.999_977_91).abs() < 1.5e-7);
    }

    #[test]
    fn norm_cdf_symmetry() {
        for x in [0.3, 1.1, 2.7] {
            assert!((norm_cdf(x) + norm_cdf(-x) - 1.0).abs() < 1.5e-7);
        }
        assert!((norm_cdf(0.0) - 0.5).abs() < 1.5e-7);
    }

    #[test]
    fn bs_atm_price_paper_params() {
        // s0 = K = 3, sigma = 1, T = 1: ATM call with 100% vol.
        // Known closed-form: p = s0 (N(sigma/2) - N(-sigma/2)) = 3*(2N(0.5)-1).
        let p = bs_call_price(3.0, 3.0, 1.0, 1.0);
        let want = 3.0 * (2.0 * norm_cdf(0.5) - 1.0);
        assert!((p - want).abs() < 1e-9, "{p} vs {want}");
        assert!((p - 1.149).abs() < 1e-3); // numeric anchor
    }

    #[test]
    fn price_bounds_and_monotonicity() {
        // price in [max(s0-k,0), s0]; increasing in sigma and maturity.
        let p = bs_call_price(3.0, 3.0, 0.5, 1.0);
        assert!(p > 0.0 && p < 3.0);
        assert!(bs_call_price(3.0, 3.0, 0.8, 1.0) > p);
        assert!(bs_call_price(3.0, 3.0, 0.5, 2.0) > p);
        assert!(bs_call_price(4.0, 3.0, 1e-9, 1e-9) - 1.0 < 1e-6);
    }

    #[test]
    fn delta_limits() {
        assert!(bs_call_delta(10.0, 3.0, 1.0, 0.01) > 0.99); // deep ITM
        assert!(bs_call_delta(0.5, 3.0, 1.0, 0.01) < 0.01); // deep OTM
        let atm = bs_call_delta(3.0, 3.0, 1.0, 1.0);
        assert!(atm > 0.5 && atm < 0.8, "{atm}");
    }
}
