//! Minimal JSON parser + writer (serde_json substitute, offline build).
//!
//! Supports the full JSON grammar (RFC 8259): objects, arrays, strings
//! with escapes, numbers, booleans, null. Used to read
//! `artifacts/manifest.json` and to write run metadata / experiment
//! outputs. Not performance-critical: parsing happens once at startup.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that reports *which* key was missing.
    pub fn field(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError(format!("missing field `{key}`")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }
}

/// Parse / access error with a human-readable message.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError(pub String);

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json: {}", self.0)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal (expected `{word}`)")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling for non-BMP chars.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c =
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(cp)
                        };
                        out.push(ch.ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => {
                    return Err(self.err("control character in string"))
                }
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences verbatim.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    self.pos = end;
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------------------
// writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Convenience builder for writing run metadata.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse(r#""a\nb""#).unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_i64(), Some(2));
        assert!(arr[2].get("b").unwrap().is_null());
    }

    #[test]
    fn parses_unicode_escapes() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".into())
        );
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("tru").is_err());
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"arr":[1,2.5,"s"],"b":false,"n":null}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn field_error_names_key() {
        let v = Json::parse("{}").unwrap();
        let e = v.field("missing").unwrap_err();
        assert!(e.0.contains("missing"));
    }

    #[test]
    fn accessor_type_mismatches_are_none() {
        let v = Json::parse("[1]").unwrap();
        assert!(v.get("x").is_none());
        assert!(v.as_str().is_none());
        assert_eq!(Json::parse("1.5").unwrap().as_i64(), None);
    }
}
