//! Minimal TOML-subset parser (toml-crate substitute, offline build).
//!
//! Supports what `configs/*.toml` needs: `[section]` / `[a.b]` headers,
//! `key = value` with strings, integers, floats, booleans, and flat arrays,
//! plus `#` comments. Keys are flattened to dotted paths
//! (`section.key`), which is how [`crate::config`] consumes them.

use std::collections::BTreeMap;
use std::fmt;

/// A TOML scalar / array value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct TomlError(pub String);

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml: {}", self.0)
    }
}

impl std::error::Error for TomlError {}

/// A parsed document: dotted-path key -> value.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err(lineno, "unterminated section header"))?
                    .trim();
                if name.is_empty() {
                    return Err(err(lineno, "empty section name"));
                }
                section = name.to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| err(lineno, "expected `key = value`"))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(err(lineno, "empty key"));
            }
            let value = parse_value(line[eq + 1..].trim())
                .map_err(|m| err(lineno, &m))?;
            let path = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            if doc.entries.insert(path.clone(), value).is_some() {
                return Err(err(lineno, &format!("duplicate key `{path}`")));
            }
        }
        Ok(doc)
    }

    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.entries.get(path)
    }

    /// All keys under a dotted prefix (e.g. `"train"` -> `train.*`).
    pub fn section_keys(&self, prefix: &str) -> Vec<&str> {
        let pat = format!("{prefix}.");
        self.entries
            .keys()
            .filter(|k| k.starts_with(&pat))
            .map(|k| k.as_str())
            .collect()
    }
}

fn err(lineno: usize, msg: &str) -> TomlError {
    TomlError(format!("line {}: {msg}", lineno + 1))
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        if inner.contains('"') {
            return Err("embedded quote in basic string".into());
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let items = split_top_level(inner)?
            .into_iter()
            .map(|part| parse_value(part.trim()))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(TomlValue::Arr(items));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let cleaned = s.replace('_', "");
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = cleaned.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value `{s}`"))
}

/// Split an array body on commas that are not inside strings.
fn split_top_level(s: &str) -> Result<Vec<&str>, String> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if in_str {
        return Err("unterminated string in array".into());
    }
    parts.push(&s[start..]);
    Ok(parts)
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
# experiment config
title = "paper"

[mlmc]
b = 1.8
c = 1.0
n_effective = 1_024
levels = [0, 1, 2]

[train]
method = "dmlmc"   # inline comment
lr = 1e-2
adaptive = false
"#;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(DOC).unwrap();
        assert_eq!(doc.get("title").unwrap().as_str(), Some("paper"));
        assert_eq!(doc.get("mlmc.b").unwrap().as_f64(), Some(1.8));
        assert_eq!(doc.get("mlmc.n_effective").unwrap().as_i64(), Some(1024));
        assert_eq!(doc.get("train.method").unwrap().as_str(), Some("dmlmc"));
        assert_eq!(doc.get("train.lr").unwrap().as_f64(), Some(0.01));
        assert_eq!(doc.get("train.adaptive").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn parses_arrays() {
        let doc = TomlDoc::parse(DOC).unwrap();
        match doc.get("mlmc.levels").unwrap() {
            TomlValue::Arr(a) => {
                assert_eq!(a.len(), 3);
                assert_eq!(a[2].as_i64(), Some(2));
            }
            other => panic!("expected array, got {other:?}"),
        }
    }

    #[test]
    fn section_keys_lists_prefix() {
        let doc = TomlDoc::parse(DOC).unwrap();
        let keys = doc.section_keys("train");
        assert!(keys.contains(&"train.method"));
        assert!(!keys.contains(&"mlmc.b"));
    }

    #[test]
    fn comment_inside_string_is_kept() {
        let doc = TomlDoc::parse("k = \"a # b\"").unwrap();
        assert_eq!(doc.get("k").unwrap().as_str(), Some("a # b"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(TomlDoc::parse("[unclosed").is_err());
        assert!(TomlDoc::parse("novalue =").is_err());
        assert!(TomlDoc::parse("= 3").is_err());
        assert!(TomlDoc::parse("k = zzz").is_err());
        assert!(TomlDoc::parse("k = 1\nk = 2").is_err());
    }

    #[test]
    fn int_vs_float_distinction() {
        let doc = TomlDoc::parse("a = 3\nb = 3.0").unwrap();
        assert_eq!(doc.get("a").unwrap(), &TomlValue::Int(3));
        assert_eq!(doc.get("b").unwrap(), &TomlValue::Float(3.0));
    }
}
