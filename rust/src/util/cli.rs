//! Declarative command-line parser (clap substitute, offline build).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean switches,
//! defaults, required arguments and auto-generated `--help` text — the
//! subset the `repro` binary and the examples need.
//!
//! # Scenario selection (`--scenario`)
//!
//! Every training/experiment subcommand of `repro` accepts
//! `--scenario <sde>-<payoff>` (default `bs-call`), resolved against
//! [`crate::scenarios::registry`]; `repro scenarios` lists the keys
//! (the key splits at the *first* dash, so dashed payoff keys like
//! `uo-call` compose: `heston-uo-call`). SDE keys cover 1-D dynamics
//! (`bs`, `gbm`, `ou`, `cir`) and the 2-factor `heston` stochastic-vol
//! model; payoff keys cover terminal (`call`, `put`, `digital`),
//! path-dependent (`asian`, `lookback`) and barrier (`uo-call` up-and-out,
//! `di-put` down-and-in) functionals, all evaluated as streaming
//! observers. A non-default scenario implies `--backend native` when no
//! backend is pinned by `--backend` or an explicit `runtime.backend` key
//! in the `--config` TOML (the XLA artifacts only cover the default; a
//! pinned `xla` backend is rejected loudly). The equivalent TOML (see
//! `configs/scenario_ou_asian.toml` and
//! `configs/scenario_heston_barrier.toml`):
//!
//! ```toml
//! [scenario]
//! name = "heston-uo-call"  # Heston stochastic vol, up-and-out call
//!
//! [runtime]
//! backend = "native"       # required for non-default scenarios
//!
//! [problem]
//! sigma = 1.0              # scenario parameters come from [problem]
//! strike = 3.0
//! ```
//!
//! CLI equivalent: `repro train --scenario heston-uo-call --method dmlmc`.
//!
//! # Parallel execution (`--workers`, `repro parallel-sweep`)
//!
//! Training/experiment subcommands accept `--workers <n>` (TOML:
//! `execution.workers`): the worker-thread count of the chunk-sharded
//! **resident** execution pool ([`crate::exec::WorkerPool`] — threads
//! spawned once per trainer, parked between dispatches). `0` (the
//! default) means one worker per available core; `1` runs a single
//! pooled worker. Gradients are **bit-identical for every worker
//! count** — the pool reduces per-chunk results in a fixed order, and
//! the counter-based RNG makes each chunk a pure function of its
//! `(step, level, chunk)` address — so `--workers` is purely a
//! throughput knob. It applies to shareable backends (`--backend
//! native`); the PJRT runtime's `!Send` handles always dispatch
//! sequentially.
//!
//! `repro parallel-sweep` measures the pool against the PRAM cost model:
//! it trains every method at each `P` in `--workers <comma list>`
//! (default `1,2,4,8` — on this one subcommand the flag is a list),
//! prints measured vs predicted per-step makespan, per-step dispatch
//! overhead and utilization, and writes `BENCH_parallel.json` (per-cell
//! `dispatch_overhead_mean_s` plus a resident-vs-scoped `exec_compare`
//! row). `repro exec-bench` (`make bench-exec`) isolates that
//! comparison: the same light level-0-only dispatch through a resident
//! and a spawn-per-dispatch pool. Examples:
//!
//! ```text
//! repro parallel-sweep --workers 1,2,4,8 --steps 48 --n-effective 256
//! repro exec-bench --workers 4 --steps 64
//! ```
//!
//! # Serving fleet (`repro fleet-sweep`)
//!
//! One resident pool can serve many trainers at once
//! ([`crate::coordinator::FleetCoordinator`]): sessions are submitted as
//! configured [`crate::coordinator::TrainerBuilder`]s, every fleet tick
//! batches all running sessions' chunk tasks into a single pool dispatch
//! (fair-share, one SGD step per session per tick), and each session's
//! gradient stays **bit-identical to its solo run** because its task
//! group reduces independently in fixed chunk order. In code:
//!
//! ```no_run
//! use dmlmc::config::{Backend, ExperimentConfig};
//! use dmlmc::coordinator::{FleetCoordinator, TrainerBuilder};
//!
//! let mut cfg = ExperimentConfig::default_paper();
//! cfg.runtime.backend = Backend::Native;
//! let mut fleet = FleetCoordinator::new(2);
//! let id = fleet.submit("bs", TrainerBuilder::new(&cfg)).unwrap();
//! while !fleet.poll(id).unwrap().is_done() {
//!     fleet.tick().unwrap();
//! }
//! let runs = fleet.drain().unwrap();
//! assert_eq!(runs[0].name, "bs");
//! ```
//!
//! `repro fleet-sweep` sweeps fleet size (`--fleet-sizes`, default
//! `1,2,4`; sessions cycle over `--scenarios`, default
//! `bs-call,heston-uo-call`) against `--workers` (comma list, default
//! `2`; like `parallel-sweep`, the list form is accepted here), prints
//! aggregate throughput per cell and writes `BENCH_fleet.json`
//! (steps/sec, problems/sec, pool utilization, mean per-step makespan).
//! Named experiment runs — this one included — land under
//! `--out-dir` (default `artifacts/`) in per-run directories managed by
//! [`crate::metrics::RunArtifacts`]; bench JSONs additionally keep a
//! top-level `./BENCH_*.json` alias for CI and `make bench-*`. Example:
//!
//! ```text
//! repro fleet-sweep --fleet-sizes 1,2,4 --workers 2,4 --steps 16
//! ```
//!
//! # Performance (`--simd`, `--pin-cores`, `repro hotpath-bench`)
//!
//! Every training/experiment subcommand accepts two hot-path knobs
//! (native backend only):
//!
//! * `--simd` (TOML: `[execution] simd = true`) routes the scenario
//!   through its lane-blocked SIMD kernel by selecting the `-simd`
//!   registry variant key (`heston-uo-call` → `heston-uo-call-simd`):
//!   8 paths integrate per `[f32; 8]` lane block and MLP rows run 8 at a
//!   time ([`crate::engine::lanes`]). Lane kernels reassociate f32
//!   reductions, so they are tolerance-validated against the scalar
//!   reference rather than bitwise; scalar runs (the default) stay
//!   bit-identical to the seed. Rejected loudly with `--backend xla`.
//! * `--pin-cores` (TOML: `[execution] pin_cores = true`) pins the pool's
//!   resident workers round-robin to CPU cores
//!   ([`crate::exec::affinity`]; Linux `sched_setaffinity`, best-effort
//!   no-op elsewhere or when the cpuset refuses). The worker→core map is
//!   reported per dispatch ([`crate::exec::StepExecReport`]) and pinning
//!   never changes numerics.
//!
//! `repro hotpath-bench` (`make bench-hotpath`) times one
//! `value_and_grad` chunk per scenario through both kernel variants and
//! writes `BENCH_hotpath.json` (paths/sec per variant + speedup per
//! cell; `--scenarios` comma list or `all`, `--batch` paths per call):
//!
//! ```text
//! repro train --scenario heston-uo-call --simd --pin-cores
//! repro hotpath-bench --scenarios all --batch 512
//! ```
//!
//! # Observability (`--trace`, `repro trace`)
//!
//! Every training/experiment subcommand accepts the `--trace` switch
//! (TOML: `[observability] trace = true`; `ring_capacity` bounds the
//! per-track span rings). Tracing is **off by default** — an untraced
//! run carries no recorder at all — and when enabled it is ingested
//! coordinator-side from the per-dispatch
//! [`crate::exec::StepExecReport`] telemetry, so the worker hot path
//! records nothing new and the trained parameters stay bit-identical
//! (pinned by test). A traced `repro train` exports two extra artifacts
//! into its run directory: `trace.json` — Chrome trace-event JSON
//! (load in Perfetto or `chrome://tracing`; one track per stable worker
//! index plus a coordinator track; `task` spans carry level / group /
//! chunk / session attrs, the coordinator track carries `dispatch` /
//! `step` / `tick` / `session` spans) — and `metrics.prom`, a
//! Prometheus text-exposition snapshot of the run's counters, gauges
//! and latency histograms ([`crate::obs::Registry`]).
//!
//! `repro trace` (`make trace`) is the overhead bench: it runs the same
//! DMLMC training with tracing off and on (`--repeats` pairs,
//! best-of-means compared), asserts the trajectories are bit-identical
//! and the traced makespan within a bounded factor of untraced, exports
//! the traced run's `trace.json` / `metrics.prom`, and writes
//! `BENCH_obs.json`. Examples:
//!
//! ```text
//! repro train --method dmlmc --trace
//! repro trace --workers 2 --steps 24 --repeats 2
//! ```
//!
//! `repro trace` additionally prices **scraping under load**: a third
//! run per repeat serves its live registry over HTTP and is polled
//! continuously while training; the scraped trajectory must stay
//! bit-identical and its makespan within a bounded factor of untraced
//! (`scrape_overhead_ratio` in `BENCH_obs.json`).
//!
//! # Live serving (`repro serve`)
//!
//! `repro serve` keeps a traced serving fleet resident and exposes it
//! over a dependency-free HTTP/1.1 server
//! ([`crate::obs::MetricsServer`]) for Prometheus-style collectors:
//! `GET /metrics` (text exposition, identical renderer to
//! `metrics.prom` — estimator gauges like `dmlmc_level_variance` per
//! `level`/`session`, fleet gauges, span-drop counters), `GET /status`
//! (fleet JSON: ticks, active/pending/done sessions, pool utilization)
//! and `GET /sessions/<id>` (per-session JSON: step, last loss,
//! per-level layout + estimator statistics). The port comes from
//! `--port` or `[observability] serve_port` (0 = ephemeral, printed on
//! startup); the session roster from `[serve]` (`sessions` trainers
//! seeded `seed0 + i` — see `configs/serve.toml`). The loop ticks the
//! fleet until SIGINT (or `--max-ticks`, handy for smoke tests), then
//! shuts down gracefully, writing `status.json` / `trace.json` /
//! `metrics.prom` into the run directory. Examples:
//!
//! ```text
//! repro serve --config configs/serve.toml
//! repro serve --port 9184 --sessions 2 --steps 256
//! repro serve --max-ticks 64 --port 0   # self-terminating smoke
//! ```
//!
//! # Adaptive allocation (`--adaptive`, `repro adaptive-sweep`)
//!
//! Every training/experiment subcommand accepts `--adaptive` (TOML:
//! `[adaptive] enabled = true` — see `configs/adaptive.toml`): instead
//! of holding the offline-theory constants for the whole run, the
//! trainer routes its level/sample/delay decisions through the policy
//! layer ([`crate::policy::AllocationPolicy`]). The default
//! [`crate::policy::FixedPolicy`] reproduces the paper constants
//! bit-identically (pinned by test); the
//! [`crate::policy::AdaptivePolicy`] re-derives per-level sample counts
//! (Giles-style `n_l ∝ sqrt(V_l / C_l)`) and refresh periods from the
//! live estimator telemetry every `adaptive.adapt_every` steps, with a
//! relative dead band (`hysteresis`) and hard clamps (`max_period`,
//! `min_refreshes`) so sparse or noisy gauges cannot whipsaw the
//! layout. Decisions are a pure function of the telemetry stream;
//! without pooled wall-clock cost samples (sequential dispatch) an
//! adaptive run is fully deterministic. Adopted decisions are
//! scrape-visible as `dmlmc_alloc_n` / `dmlmc_refresh_period` gauges
//! per `level` (and `session` under `repro serve`).
//!
//! `repro adaptive-sweep` (`make bench-adaptive`) is the ablation: the
//! same DMLMC training once fixed and once adaptive, compared on wall
//! clock to a shared target loss (the worse of the two finals) and on
//! measured parallel cost per step, written to `BENCH_adaptive.json`.
//! Examples:
//!
//! ```text
//! repro train --method dmlmc --adaptive
//! repro adaptive-sweep --steps 32 --config configs/adaptive.toml
//! ```

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "cli: {}", self.0)
    }
}

impl std::error::Error for CliError {}

/// One `--name <value>` option (or boolean switch).
#[derive(Debug, Clone)]
pub struct Opt {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub required: bool,
    pub switch: bool,
}

impl Opt {
    pub fn value(name: &'static str, help: &'static str) -> Self {
        Opt { name, help, default: None, required: false, switch: false }
    }

    pub fn required(name: &'static str, help: &'static str) -> Self {
        Opt { name, help, default: None, required: true, switch: false }
    }

    pub fn with_default(
        name: &'static str,
        help: &'static str,
        default: &'static str,
    ) -> Self {
        Opt { name, help, default: Some(default), required: false, switch: false }
    }

    pub fn switch(name: &'static str, help: &'static str) -> Self {
        Opt { name, help, default: None, required: false, switch: true }
    }
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn parse_f64(&self, name: &str) -> Result<Option<f64>, CliError> {
        self.get(name)
            .map(|v| {
                v.parse::<f64>()
                    .map_err(|_| CliError(format!("--{name}: not a number: `{v}`")))
            })
            .transpose()
    }

    pub fn parse_usize(&self, name: &str) -> Result<Option<usize>, CliError> {
        self.get(name)
            .map(|v| {
                v.parse::<usize>()
                    .map_err(|_| CliError(format!("--{name}: not an integer: `{v}`")))
            })
            .transpose()
    }
}

/// A command: name + options (+ optional subcommands).
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<Opt>,
    pub subcommands: Vec<Command>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, opts: Vec::new(), subcommands: Vec::new() }
    }

    pub fn opt(mut self, o: Opt) -> Self {
        self.opts.push(o);
        self
    }

    pub fn subcommand(mut self, c: Command) -> Self {
        self.subcommands.push(c);
        self
    }

    /// Render `--help` text.
    pub fn help(&self) -> String {
        let mut out = format!("{} — {}\n\nUSAGE:\n  {}", self.name, self.about, self.name);
        if !self.subcommands.is_empty() {
            out.push_str(" <SUBCOMMAND>");
        }
        if !self.opts.is_empty() {
            out.push_str(" [OPTIONS]");
        }
        out.push('\n');
        if !self.subcommands.is_empty() {
            out.push_str("\nSUBCOMMANDS:\n");
            for sc in &self.subcommands {
                out.push_str(&format!("  {:<14} {}\n", sc.name, sc.about));
            }
        }
        if !self.opts.is_empty() {
            out.push_str("\nOPTIONS:\n");
            for o in &self.opts {
                let mut line = format!("  --{}", o.name);
                if !o.switch {
                    line.push_str(" <v>");
                }
                let mut help = o.help.to_string();
                if let Some(d) = o.default {
                    help.push_str(&format!(" [default: {d}]"));
                }
                if o.required {
                    help.push_str(" [required]");
                }
                out.push_str(&format!("{line:<26} {help}\n"));
            }
        }
        out
    }

    /// Parse a raw arg vector (without argv[0]). Returns the matched
    /// subcommand name (or this command's name) and its [`Args`].
    pub fn parse(&self, argv: &[String]) -> Result<(String, Args), CliError> {
        if let Some(first) = argv.first() {
            if first == "--help" || first == "-h" {
                return Err(CliError(self.help()));
            }
            if let Some(sc) = self.subcommands.iter().find(|c| c.name == *first) {
                let (_, args) = sc.parse(&argv[1..])?;
                return Ok((sc.name.to_string(), args));
            }
            if !self.subcommands.is_empty() && !first.starts_with("--") {
                return Err(CliError(format!(
                    "unknown subcommand `{first}`\n\n{}",
                    self.help()
                )));
            }
        }
        let mut args = Args::default();
        // seed defaults
        for o in &self.opts {
            if let Some(d) = o.default {
                args.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Err(CliError(self.help()));
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| CliError(format!("unknown option `--{name}`")))?;
                if opt.switch {
                    if inline.is_some() {
                        return Err(CliError(format!("--{name} takes no value")));
                    }
                    args.switches.push(name.to_string());
                } else {
                    let val = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| {
                                    CliError(format!("--{name} needs a value"))
                                })?
                        }
                    };
                    args.values.insert(name.to_string(), val);
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        for o in &self.opts {
            if o.required && args.get(o.name).is_none() {
                return Err(CliError(format!("missing required option --{}", o.name)));
            }
        }
        Ok((self.name.to_string(), args))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("repro", "driver")
            .subcommand(
                Command::new("train", "run training")
                    .opt(Opt::with_default("method", "naive|mlmc|dmlmc", "dmlmc"))
                    .opt(Opt::value("steps", "T"))
                    .opt(Opt::switch("quiet", "no output"))
                    .opt(Opt::required("config", "config path")),
            )
            .subcommand(Command::new("table1", "emit table 1"))
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_with_options() {
        let (name, args) = cmd()
            .parse(&argv(&["train", "--config", "c.toml", "--steps=50", "--quiet"]))
            .unwrap();
        assert_eq!(name, "train");
        assert_eq!(args.get("config"), Some("c.toml"));
        assert_eq!(args.parse_usize("steps").unwrap(), Some(50));
        assert!(args.flag("quiet"));
        assert_eq!(args.get("method"), Some("dmlmc")); // default
    }

    #[test]
    fn missing_required_errors() {
        let e = cmd().parse(&argv(&["train"])).unwrap_err();
        assert!(e.0.contains("config"));
    }

    #[test]
    fn unknown_flag_and_subcommand_error() {
        assert!(cmd().parse(&argv(&["train", "--config", "c", "--nope", "1"])).is_err());
        assert!(cmd().parse(&argv(&["wat"])).is_err());
    }

    #[test]
    fn help_lists_subcommands() {
        let e = cmd().parse(&argv(&["--help"])).unwrap_err();
        assert!(e.0.contains("table1"));
        assert!(e.0.contains("train"));
    }

    #[test]
    fn bad_number_reports_flag() {
        let (_, args) = cmd()
            .parse(&argv(&["train", "--config", "c", "--steps", "abc"]))
            .unwrap();
        assert!(args.parse_usize("steps").is_err());
    }
}
