//! Small in-repo substrates for ecosystem crates that are unavailable in
//! this offline build environment (see Cargo.toml note and DESIGN.md §2).

pub mod cli;
pub mod json;
pub mod toml;
