//! Allocation policies: every level/sample/delay decision in one layer.
//!
//! The paper fixes the per-level sample counts `N_l` and the delayed
//! refresh periods `⌊2^{dl}⌋` offline from the Assumption-1/2 exponents
//! (§2). The MLMC-SGD allocation analysis in arXiv:1912.11900 and the
//! multilevel-learning construction in arXiv:2102.08734 show the optimal
//! allocation is a function of *measured* per-level variance and cost —
//! exactly what [`crate::obs::EstimatorStats`] tracks live. This module
//! closes that loop behind one trait so the trainer and fleet never own
//! allocation constants themselves:
//!
//! * [`AllocationPolicy`] — `observe(&EstimatorSnapshot, &current) ->
//!   AllocationDecision`. Policies are stateless (`Arc`-shareable across
//!   fleet sessions); any hysteresis state lives in the caller-held
//!   current decision, so the decision stream is a deterministic
//!   function of the telemetry stream.
//! * [`FixedPolicy`] — reproduces the offline-theory constants
//!   bit-identically (it calls the same [`LevelAllocation::paper`] /
//!   [`DelayedSchedule::new`] constructors with the same arguments the
//!   trainer used to call directly; `observe` is the identity). Pinned
//!   against pre-refactor goldens in `tests/policy_regression.rs`.
//! * [`AdaptivePolicy`] — recomputes the Giles-style allocation
//!   `N_l ∝ sqrt(V̂_l / Ĉ_l)` and the refresh periods from live
//!   variance/cost gauges, with per-level hysteresis and clamps
//!   (`[adaptive]` in TOML, `--adaptive` on the CLI).
//!
//! The active decision is scrape-visible: the trainer republishes it as
//! the `dmlmc_alloc_n{level}` / `dmlmc_refresh_period{level}` gauges
//! ([`crate::obs::estimator::publish_decision`]) next to the estimator
//! telemetry it was derived from.

pub mod adaptive;
pub mod fixed;

pub use adaptive::AdaptivePolicy;
pub use fixed::FixedPolicy;

use std::sync::Arc;

use crate::config::ExperimentConfig;
use crate::coordinator::DelayedSchedule;
use crate::mlmc::LevelAllocation;
use crate::obs::EstimatorSnapshot;

/// The complete output of an allocation policy: per-level sample counts,
/// the delayed-refresh schedule, and the effective batch size the naive
/// baseline shards. Everything downstream (chunk layout, job planning)
/// is derived from this value — the trainer never reads an allocation
/// constant from [`ExperimentConfig`] directly.
#[derive(Debug, Clone)]
pub struct AllocationDecision {
    pub allocation: LevelAllocation,
    pub schedule: DelayedSchedule,
    /// Effective batch size `N` (the naive baseline's budget; adaptive
    /// reallocation redistributes it across levels, never changes it).
    pub n_effective: usize,
}

impl AllocationDecision {
    pub fn lmax(&self) -> usize {
        self.allocation.lmax()
    }

    /// Decision equality on the integer outputs that drive execution
    /// (sample counts, periods, batch size) — the change detector for
    /// re-deriving the chunk layout and republishing gauges.
    pub fn same_as(&self, other: &AllocationDecision) -> bool {
        self.allocation == other.allocation
            && self.schedule.periods() == other.schedule.periods()
            && self.n_effective == other.n_effective
    }
}

/// A level/sample/delay decision procedure fed by estimator telemetry.
///
/// Implementations are shared immutably (`Arc<dyn AllocationPolicy>`)
/// between the trainer, the fleet coordinator (which re-observes each
/// session independently at tick boundaries) and tests.
pub trait AllocationPolicy: Send + Sync + std::fmt::Debug {
    /// Short label for benches and gauges (`"fixed"`, `"adaptive"`).
    fn name(&self) -> &'static str;

    /// The decision before any telemetry exists (build time, `t = 0`).
    fn initial(&self, lmax: usize) -> AllocationDecision;

    /// Re-evaluate against a telemetry snapshot. `current` is the
    /// decision in force; policies return it unchanged (cloned) when the
    /// telemetry does not justify a move, which is also how hysteresis
    /// composes: the dead band is relative to `current`, so identical
    /// telemetry streams always produce identical decision streams.
    fn observe(
        &self,
        snap: &EstimatorSnapshot,
        current: &AllocationDecision,
    ) -> AllocationDecision;
}

/// The policy a config asks for: [`AdaptivePolicy`] when
/// `[adaptive] enabled = true`, [`FixedPolicy`] otherwise. This is the
/// single place allocation constants leave [`ExperimentConfig`].
pub fn from_config(cfg: &ExperimentConfig) -> Arc<dyn AllocationPolicy> {
    if cfg.adaptive.enabled {
        Arc::new(AdaptivePolicy::from_config(cfg))
    } else {
        Arc::new(FixedPolicy::from_config(cfg))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_config_dispatches_on_the_adaptive_flag() {
        let mut cfg = ExperimentConfig::smoke();
        assert_eq!(from_config(&cfg).name(), "fixed");
        cfg.adaptive.enabled = true;
        assert_eq!(from_config(&cfg).name(), "adaptive");
    }

    #[test]
    fn same_as_compares_integer_outputs() {
        let p = FixedPolicy {
            b: 1.8,
            c: 1.0,
            d: 1.0,
            n_effective: 64,
        };
        let a = p.initial(3);
        let b = p.initial(3);
        assert!(a.same_as(&b));
        let mut c = a.clone();
        c.allocation.n_per_level[1] += 1;
        assert!(!a.same_as(&c));
        let mut d = a.clone();
        d.schedule = DelayedSchedule::with_periods(vec![1, 3, 4, 8]);
        assert!(!a.same_as(&d));
        let mut e = a.clone();
        e.n_effective = 65;
        assert!(!a.same_as(&e));
    }
}
