//! The offline-theory policy: the paper's constants, frozen.

use crate::config::ExperimentConfig;
use crate::coordinator::DelayedSchedule;
use crate::mlmc::LevelAllocation;
use crate::obs::EstimatorSnapshot;

use super::{AllocationDecision, AllocationPolicy};

/// Reproduces the pre-policy-layer behavior bit-identically: the
/// allocation is [`LevelAllocation::paper`]`(lmax, n_effective, b, c)`
/// and the schedule [`DelayedSchedule::new`]`(lmax, d)` — the exact
/// constructor calls (same arguments, same float operations) the trainer
/// used to make inline — and [`AllocationPolicy::observe`] is the
/// identity, so no amount of telemetry ever moves a decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FixedPolicy {
    /// Variance-decay exponent (Assumption 2).
    pub b: f64,
    /// Cost-growth exponent (Assumption 1).
    pub c: f64,
    /// Delay exponent of Algorithm 1.
    pub d: f64,
    /// Effective batch size `N`.
    pub n_effective: usize,
}

impl FixedPolicy {
    pub fn from_config(cfg: &ExperimentConfig) -> Self {
        FixedPolicy {
            b: cfg.mlmc.b,
            c: cfg.mlmc.c,
            d: cfg.mlmc.d,
            n_effective: cfg.mlmc.n_effective,
        }
    }
}

impl AllocationPolicy for FixedPolicy {
    fn name(&self) -> &'static str {
        "fixed"
    }

    fn initial(&self, lmax: usize) -> AllocationDecision {
        AllocationDecision {
            allocation: LevelAllocation::paper(lmax, self.n_effective, self.b, self.c),
            schedule: DelayedSchedule::new(lmax, self.d),
            n_effective: self.n_effective,
        }
    }

    fn observe(
        &self,
        _snap: &EstimatorSnapshot,
        current: &AllocationDecision,
    ) -> AllocationDecision {
        current.clone()
    }
}

#[cfg(test)]
mod tests {
    use crate::obs::EstimatorStats;

    use super::*;

    fn paper_policy() -> FixedPolicy {
        FixedPolicy {
            b: 1.8,
            c: 1.0,
            d: 1.0,
            n_effective: 1024,
        }
    }

    #[test]
    fn initial_matches_the_direct_constructors_bitwise() {
        let p = paper_policy();
        let dec = p.initial(6);
        assert_eq!(dec.allocation, LevelAllocation::paper(6, 1024, 1.8, 1.0));
        assert_eq!(
            dec.schedule.periods(),
            DelayedSchedule::new(6, 1.0).periods()
        );
        assert_eq!(dec.n_effective, 1024);
    }

    #[test]
    fn observe_is_the_identity() {
        let p = paper_policy();
        let dec = p.initial(6);
        // a telemetry stream that would move any adaptive policy
        let mut est = EstimatorStats::new(7);
        for l in 0..7 {
            for step in 0..4u64 {
                est.record_refresh(l, step, 8, &[100.0 * (l as f32 + 1.0)]);
                est.record_cost(l, 1e-3 * (l as f64 + 1.0));
            }
        }
        let out = p.observe(&est.observe(4), &dec);
        assert!(out.same_as(&dec));
    }

    #[test]
    fn from_config_copies_the_mlmc_constants() {
        let mut cfg = ExperimentConfig::smoke();
        cfg.mlmc.b = 2.0;
        cfg.mlmc.d = 1.5;
        cfg.mlmc.n_effective = 256;
        let p = FixedPolicy::from_config(&cfg);
        assert_eq!(
            p,
            FixedPolicy {
                b: 2.0,
                c: 1.0,
                d: 1.5,
                n_effective: 256
            }
        );
    }
}
