//! The measured-telemetry policy: Giles-style allocation from live
//! variance/cost gauges.

use crate::config::ExperimentConfig;
use crate::coordinator::DelayedSchedule;
use crate::mlmc::LevelAllocation;
use crate::obs::{EstimatorSnapshot, LevelSnapshot};

use super::{AllocationDecision, AllocationPolicy, FixedPolicy};

/// Recomputes the allocation from measured statistics, falling back to
/// the offline theory per level until that level has seen enough
/// refreshes:
///
/// * **Samples** — the variance-minimising `N_l ∝ sqrt(V̂_l / Ĉ_l)`
///   (Giles; arXiv:1912.11900 for the SGD setting), where `V̂_l` is the
///   mean per-refresh `‖∇Δ_l‖²` gauge and `Ĉ_l` the mean measured task
///   seconds (falling back to the `2^{cl}` cost model while no pooled
///   timing exists). Normalised against the *same* effective batch size
///   `N`, so adaptation redistributes the budget rather than growing it.
/// * **Periods** — the delay that matches the measured decay:
///   `p_l = round(sqrt(V̂_0 / V̂_l))`, the empirical analog of the
///   theory's `2^{dl}` under `V_l = M·2^{-bl}` with `d = b/2`; clamped
///   to `[1, max_period]` with level 0 forced due every step.
///
/// Both are wrapped in a per-level relative dead band (`hysteresis`), so
/// a value only moves when the recomputed target leaves the band around
/// the current decision. The policy is stateless — given the same
/// snapshot and the same current decision it always returns the same
/// decision, which keeps adaptive runs deterministic and
/// worker-count-invariant at the trajectory level wherever the underlying
/// telemetry is (model-fed costs are; wall-clock timings are consumed
/// only through the dead band, see `tests/policy_regression.rs`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaptivePolicy {
    /// The offline-theory fallback (also provides the initial decision).
    pub fallback: FixedPolicy,
    /// Gate: a level's measured statistics participate only after this
    /// many refreshes.
    pub min_refreshes: u64,
    /// Relative dead band on per-level sample counts and periods.
    pub hysteresis: f64,
    /// Upper clamp on any adapted refresh period (steps).
    pub max_period: u64,
}

impl AdaptivePolicy {
    pub fn from_config(cfg: &ExperimentConfig) -> Self {
        AdaptivePolicy {
            fallback: FixedPolicy::from_config(cfg),
            min_refreshes: cfg.adaptive.min_refreshes,
            hysteresis: cfg.adaptive.hysteresis,
            max_period: cfg.adaptive.max_period,
        }
    }

    /// Measured variance proxy for level `l`, if trustworthy.
    fn v_hat(&self, s: &LevelSnapshot) -> Option<f64> {
        if s.refreshes_total >= self.min_refreshes
            && s.mean_norm2.is_finite()
            && s.mean_norm2 > 0.0
        {
            Some(s.mean_norm2)
        } else {
            None
        }
    }

    /// Measured cost for level `l`, falling back to the `2^{cl}` model.
    fn c_hat(&self, s: &LevelSnapshot) -> f64 {
        if s.cost_mean_s.is_finite() && s.cost_mean_s > 0.0 {
            s.cost_mean_s
        } else {
            2f64.powf(self.fallback.c * s.level as f64)
        }
    }

    /// Is `target` outside the relative dead band around `current`?
    fn leaves_band(&self, current: u64, target: u64) -> bool {
        let cur = current.max(1) as f64;
        (target as f64 - cur).abs() / cur > self.hysteresis
    }
}

impl AllocationPolicy for AdaptivePolicy {
    fn name(&self) -> &'static str {
        "adaptive"
    }

    fn initial(&self, lmax: usize) -> AllocationDecision {
        self.fallback.initial(lmax)
    }

    fn observe(
        &self,
        snap: &EstimatorSnapshot,
        current: &AllocationDecision,
    ) -> AllocationDecision {
        let lmax = current.lmax();
        let levels: Vec<&LevelSnapshot> = (0..=lmax)
            .filter_map(|l| snap.levels.get(l))
            .collect();
        if levels.len() != lmax + 1 {
            return current.clone(); // snapshot layout mismatch: hold
        }

        // Giles weights sqrt(V_l / C_l), theory fallback per level.
        let weights: Vec<f64> = levels
            .iter()
            .map(|s| match self.v_hat(s) {
                Some(v) => (v / self.c_hat(s)).sqrt(),
                None => 2f64
                    .powf(-(self.fallback.b + self.fallback.c) * s.level as f64 / 2.0),
            })
            .collect();
        let target = LevelAllocation::from_weights(&weights, current.n_effective);
        let n_per_level: Vec<usize> = (0..=lmax)
            .map(|l| {
                let cur = current.allocation.n(l);
                if self.leaves_band(cur as u64, target.n(l) as u64) {
                    target.n(l)
                } else {
                    cur
                }
            })
            .collect();

        // Periods from the measured decay: sqrt(V_0 / V_l), held at the
        // current value while either endpoint lacks data.
        let v0 = self.v_hat(levels[0]);
        let periods: Vec<u64> = (0..=lmax)
            .map(|l| {
                let cur = current.schedule.period(l);
                let target = match (v0, self.v_hat(levels[l])) {
                    (Some(v0), Some(vl)) => {
                        ((v0 / vl).sqrt().round() as u64).clamp(1, self.max_period)
                    }
                    _ => cur,
                };
                if self.leaves_band(cur, target) {
                    target
                } else {
                    cur
                }
            })
            .collect();

        AllocationDecision {
            allocation: LevelAllocation { n_per_level },
            schedule: DelayedSchedule::with_periods(periods),
            n_effective: current.n_effective,
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::obs::EstimatorStats;

    use super::*;

    fn policy() -> AdaptivePolicy {
        AdaptivePolicy {
            fallback: FixedPolicy {
                b: 1.8,
                c: 1.0,
                d: 1.0,
                n_effective: 64,
            },
            min_refreshes: 2,
            hysteresis: 0.25,
            max_period: 64,
        }
    }

    /// Telemetry with exact geometric norm decay: level l sees constant
    /// `‖∇Δ_l‖² = 4^{-l}` (so V̂_0/V̂_l = 4^l and the measured period
    /// target is 2^l) and model costs only.
    fn geometric_telemetry(lmax: usize, refreshes: u64) -> EstimatorStats {
        let mut est = EstimatorStats::new(lmax + 1);
        for l in 0..=lmax {
            let norm = 0.5f32.powi(l as i32); // norm2 = 4^{-l}
            for step in 0..refreshes {
                est.record_refresh(l, step, 8, &[norm]);
            }
        }
        est
    }

    #[test]
    fn initial_is_the_theory_decision() {
        let p = policy();
        let dec = p.initial(4);
        assert_eq!(dec.allocation, LevelAllocation::paper(4, 64, 1.8, 1.0));
        assert_eq!(dec.schedule.periods(), DelayedSchedule::new(4, 1.0).periods());
    }

    #[test]
    fn insufficient_refreshes_hold_the_current_decision() {
        let p = policy();
        let dec = p.initial(4);
        let est = geometric_telemetry(4, 1); // below min_refreshes = 2
        let out = p.observe(&est.observe(1), &dec);
        assert!(out.same_as(&dec));
    }

    #[test]
    fn measured_decay_sets_periods_and_reallocates() {
        let p = policy();
        let dec = p.initial(4);
        let est = geometric_telemetry(4, 4);
        let out = p.observe(&est.observe(4), &dec);
        // period target sqrt(4^l) = 2^l matches theory d = 1 exactly, so
        // the schedule holds inside the dead band
        assert_eq!(out.schedule.periods(), dec.schedule.periods());
        // allocation follows sqrt(V/C) = sqrt(4^{-l} / 2^{l}); steeper
        // than the theory's 2^{-1.4 l}, so level 0 gains budget
        assert!(out.allocation.n(0) >= dec.allocation.n(0));
        assert!(out.allocation.n_per_level.iter().all(|&n| n >= 1));
        // the budget is redistributed, not changed
        assert_eq!(out.n_effective, dec.n_effective);
    }

    #[test]
    fn decisions_are_deterministic_given_the_telemetry() {
        let p = policy();
        let dec = p.initial(4);
        let est = geometric_telemetry(4, 4);
        let a = p.observe(&est.observe(4), &dec);
        let b = p.observe(&est.observe(4), &dec);
        assert!(a.same_as(&b));
    }

    #[test]
    fn hysteresis_damps_small_moves() {
        let mut p = policy();
        p.hysteresis = 0.9; // wide band: nothing short of 90% moves
        let dec = p.initial(4);
        let est = geometric_telemetry(4, 4);
        let out = p.observe(&est.observe(4), &dec);
        // period targets match theory; allocation moves are < 90% at
        // every level under this telemetry, so the decision holds whole
        assert_eq!(out.schedule.periods(), dec.schedule.periods());
    }

    #[test]
    fn periods_clamp_and_level0_stays_due() {
        let mut p = policy();
        p.max_period = 8;
        let dec = p.initial(6);
        // brutal decay: V_0/V_l explodes, targets want huge periods
        let mut est = EstimatorStats::new(7);
        for l in 0..=6usize {
            let norm = if l == 0 { 1.0f32 } else { 1e-4 };
            for step in 0..4u64 {
                est.record_refresh(l, step, 8, &[norm]);
            }
        }
        let out = p.observe(&est.observe(4), &dec);
        assert_eq!(out.schedule.period(0), 1);
        for l in 1..=6 {
            assert!(out.schedule.period(l) <= 8, "level {l} period clamped");
        }
    }

    #[test]
    fn layout_mismatch_holds_the_decision() {
        let p = policy();
        let dec = p.initial(6);
        let est = EstimatorStats::new(3); // narrower than the decision
        let out = p.observe(&est.observe(0), &dec);
        assert!(out.same_as(&dec));
    }
}
