//! The worker pool: greedy LPT execution of [`ChunkTask`]s over `P`
//! scoped std threads, with fixed-order (bit-exact) reduction.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::stats::{ExecStats, StepExecReport, WorkerStat};
use super::task::{lpt_order, ChunkTask};
use crate::mlmc::estimator::ChunkAccumulator;

/// Deterministic per-task sleep injection — a scheduling-perturbation
/// harness for determinism tests: whatever interleaving the sleeps force,
/// the reduced gradients must stay bit-identical.
#[derive(Debug, Clone, Copy)]
struct ChaosDelays {
    seed: u64,
    max_micros: u64,
}

impl ChaosDelays {
    /// splitmix64-style hash of (seed, task, worker) -> [0, max] µs.
    fn delay(&self, task: u64, worker: u64) -> Duration {
        let mut x = self
            .seed
            .wrapping_add(task.wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_add(worker.wrapping_mul(0xD1B5_4A32_D192_ED03));
        x ^= x >> 30;
        x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x ^= x >> 27;
        x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
        x ^= x >> 31;
        Duration::from_micros(x % (self.max_micros + 1))
    }
}

/// What one worker brings home from a dispatch.
struct WorkerOut {
    worker: usize,
    busy: Duration,
    results: Vec<(usize, Result<(f64, Vec<f32>)>)>,
}

/// Persistent chunk-execution runtime: `P` workers, an LPT-ordered shared
/// queue, and per-run [`ExecStats`]. See the module docs of
/// [`crate::exec`] for the design (sharding / scheduling / reduction).
#[derive(Debug)]
pub struct WorkerPool {
    workers: usize,
    chaos: Option<ChaosDelays>,
    stats: ExecStats,
}

impl WorkerPool {
    /// A pool with `workers >= 1` workers. One worker degenerates to
    /// sequential execution through the same code path (useful as the
    /// measured P = 1 baseline, executor overhead included).
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0, "need at least one worker");
        WorkerPool {
            workers,
            chaos: None,
            stats: ExecStats::new(workers),
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Cumulative stats over every dispatch this pool has run.
    pub fn stats(&self) -> &ExecStats {
        &self.stats
    }

    /// Inject a pseudorandom sleep of up to `max_micros` µs before every
    /// task, derived from `(seed, task, worker)` — perturbs the schedule
    /// without touching any numeric input. `max_micros = 0` disables.
    /// Test/debug facility: results must be invariant under it.
    pub fn set_chaos_delays(&mut self, seed: u64, max_micros: u64) {
        self.chaos = if max_micros == 0 {
            None
        } else {
            Some(ChaosDelays { seed, max_micros })
        };
    }

    /// Execute `tasks` across the workers and reduce each of the
    /// `n_groups` groups in ascending chunk order.
    ///
    /// `run` computes one chunk: it must be a pure function of the task's
    /// address (`group`/`chunk`/`level`) so execution order is
    /// irrelevant; the counter-based RNG gives the dispatcher exactly
    /// that. Returns one `(mean loss, mean gradient)` per group — the
    /// fold is the same `ChunkAccumulator` sequence the sequential
    /// dispatcher performs, so the result is bit-identical to sequential
    /// execution for every worker count.
    ///
    /// Errors: the error of the lowest-indexed failing task is returned
    /// (deterministic whichever worker hit it first). Panics in `run`
    /// propagate.
    pub fn execute<F>(
        &mut self,
        tasks: &[ChunkTask],
        n_groups: usize,
        run: F,
    ) -> Result<(Vec<(f64, Vec<f32>)>, StepExecReport)>
    where
        F: Fn(&ChunkTask) -> Result<(f64, Vec<f32>)> + Sync,
    {
        debug_assert!(tasks.iter().all(|t| t.group < n_groups));
        let started = Instant::now();

        let mut worker_outs: Vec<WorkerOut> = if tasks.is_empty() {
            // Nothing to run: report an idle dispatch without paying the
            // thread-spawn cost (DMLMC steps where no level is due).
            (0..self.workers)
                .map(|worker| WorkerOut {
                    worker,
                    busy: Duration::ZERO,
                    results: Vec::new(),
                })
                .collect()
        } else {
            let order = lpt_order(tasks);
            let cursor = AtomicUsize::new(0);
            let chaos = self.chaos;
            let order_ref = &order;
            let cursor_ref = &cursor;
            let run_ref = &run;
            // An oversubscribed pool (workers > tasks) spawns only as
            // many threads as there are tasks; the unspawned workers
            // still appear in the report (idle, zero busy) so worker
            // indices stay stable.
            let spawn_n = self.workers.min(tasks.len());
            let mut outs: Vec<WorkerOut> = std::thread::scope(|scope| {
                let mut joins = Vec::with_capacity(spawn_n);
                for worker in 0..spawn_n {
                    joins.push(scope.spawn(move || {
                        let mut out = WorkerOut {
                            worker,
                            busy: Duration::ZERO,
                            results: Vec::new(),
                        };
                        loop {
                            let slot = cursor_ref.fetch_add(1, Ordering::Relaxed);
                            if slot >= order_ref.len() {
                                break;
                            }
                            let idx = order_ref[slot];
                            if let Some(c) = chaos {
                                std::thread::sleep(
                                    c.delay(idx as u64, worker as u64),
                                );
                            }
                            let t0 = Instant::now();
                            let result = run_ref(&tasks[idx]);
                            out.busy += t0.elapsed();
                            out.results.push((idx, result));
                        }
                        out
                    }));
                }
                joins
                    .into_iter()
                    .map(|j| j.join().expect("pool worker panicked"))
                    .collect()
            });
            for worker in spawn_n..self.workers {
                outs.push(WorkerOut {
                    worker,
                    busy: Duration::ZERO,
                    results: Vec::new(),
                });
            }
            outs
        };
        let makespan = started.elapsed();

        // Scatter every task result into its pre-addressed slot; remember
        // the lowest-indexed error (deterministic across schedules).
        worker_outs.sort_by_key(|o| o.worker);
        let mut slots: Vec<Option<(f64, Vec<f32>)>> = vec![None; tasks.len()];
        let mut first_err: Option<(usize, anyhow::Error)> = None;
        let mut worker_stats = Vec::with_capacity(self.workers);
        for out in worker_outs {
            worker_stats.push(WorkerStat {
                worker: out.worker,
                busy: out.busy,
                tasks: out.results.len(),
            });
            for (idx, result) in out.results {
                match result {
                    Ok(v) => slots[idx] = Some(v),
                    Err(e) => {
                        if first_err.as_ref().map_or(true, |(i, _)| idx < *i) {
                            first_err = Some((idx, e));
                        }
                    }
                }
            }
        }
        if let Some((idx, err)) = first_err {
            let t = tasks[idx];
            return Err(err.context(format!(
                "pool task {idx} (group {}, level {}, chunk {}) failed",
                t.group, t.level, t.chunk
            )));
        }

        // Fixed-order reduction: groups in index order, chunks ascending —
        // the exact fold of the sequential dispatcher.
        let mut per_group: Vec<Vec<usize>> = vec![Vec::new(); n_groups];
        for (idx, t) in tasks.iter().enumerate() {
            per_group[t.group].push(idx);
        }
        let mut reduced = Vec::with_capacity(n_groups);
        for group in &mut per_group {
            group.sort_by_key(|&idx| tasks[idx].chunk);
            let dim = group
                .first()
                .and_then(|&idx| slots[idx].as_ref())
                .map(|(_, g)| g.len())
                .unwrap_or(0);
            let mut acc = ChunkAccumulator::new(dim);
            for &idx in group.iter() {
                let (loss, grad) = slots[idx].take().expect("task result missing");
                acc.add(loss, &grad);
            }
            // An empty group panics here ("no chunks accumulated"), just
            // like the sequential path's accumulator would.
            reduced.push(acc.finish());
        }

        let report = StepExecReport {
            workers: worker_stats,
            makespan,
            n_tasks: tasks.len(),
        };
        self.stats.record(&report);
        Ok((reduced, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A synthetic chunk: loss = group*100 + chunk, grad = [chunk, group]
    /// scaled — deterministic, distinguishable, order-sensitive to sum.
    fn run_synthetic(t: &ChunkTask) -> Result<(f64, Vec<f32>)> {
        let loss = t.group as f64 * 100.0 + t.chunk as f64;
        let grad = vec![
            (t.chunk as f32 + 1.0) * 0.1,
            (t.group as f32 + 1.0) * 0.25,
        ];
        Ok((loss, grad))
    }

    fn tasks(groups: &[usize]) -> Vec<ChunkTask> {
        let mut out = Vec::new();
        for (group, &n) in groups.iter().enumerate() {
            for chunk in 0..n {
                out.push(ChunkTask {
                    group,
                    chunk,
                    level: group,
                    weight: (group + 1) as f64,
                });
            }
        }
        out
    }

    /// Sequential reference: the exact fold `run_one` performs.
    fn sequential(groups: &[usize]) -> Vec<(f64, Vec<f32>)> {
        let ts = tasks(groups);
        let mut out = Vec::new();
        for (group, &n) in groups.iter().enumerate() {
            let mut acc = ChunkAccumulator::new(2);
            for chunk in 0..n {
                let t = ts
                    .iter()
                    .find(|t| t.group == group && t.chunk == chunk)
                    .unwrap();
                let (loss, grad) = run_synthetic(t).unwrap();
                acc.add(loss, &grad);
            }
            out.push(acc.finish());
        }
        out
    }

    #[test]
    fn matches_sequential_for_many_worker_counts() {
        let groups = [3usize, 1, 4, 2];
        let want = sequential(&groups);
        for workers in [1usize, 2, 3, 8, 16] {
            let mut pool = WorkerPool::new(workers);
            let (got, report) = pool
                .execute(&tasks(&groups), groups.len(), run_synthetic)
                .unwrap();
            assert_eq!(got.len(), want.len());
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.0, b.0, "loss differs at P={workers}");
                assert_eq!(a.1, b.1, "grad differs at P={workers}");
            }
            assert_eq!(report.n_tasks, 10);
            assert_eq!(report.workers.len(), workers);
            let tasks_run: usize = report.workers.iter().map(|w| w.tasks).sum();
            assert_eq!(tasks_run, 10);
        }
    }

    #[test]
    fn chaos_delays_do_not_change_results() {
        let groups = [2usize, 3];
        let want = sequential(&groups);
        for seed in [1u64, 2, 3] {
            let mut pool = WorkerPool::new(4);
            pool.set_chaos_delays(seed, 300);
            let (got, _) = pool
                .execute(&tasks(&groups), groups.len(), run_synthetic)
                .unwrap();
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.0, b.0);
                assert_eq!(a.1, b.1);
            }
        }
    }

    #[test]
    fn empty_dispatch_reports_idle() {
        let mut pool = WorkerPool::new(3);
        let (reduced, report) = pool.execute(&[], 0, run_synthetic).unwrap();
        assert!(reduced.is_empty());
        assert_eq!(report.n_tasks, 0);
        assert_eq!(report.utilization(), 0.0);
        assert_eq!(pool.stats().steps, 1);
    }

    #[test]
    fn lowest_indexed_error_wins() {
        let ts = tasks(&[4usize]);
        let mut pool = WorkerPool::new(4);
        let err = pool
            .execute(&ts, 1, |t| {
                if t.chunk >= 1 {
                    Err(anyhow::anyhow!("boom chunk {}", t.chunk))
                } else {
                    run_synthetic(t)
                }
            })
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("chunk 1"), "{msg}");
        assert!(msg.contains("pool task"), "{msg}");
    }

    #[test]
    fn stats_accumulate_across_dispatches() {
        let mut pool = WorkerPool::new(2);
        for _ in 0..3 {
            pool.execute(&tasks(&[2usize]), 1, run_synthetic).unwrap();
        }
        assert_eq!(pool.stats().steps, 3);
        assert_eq!(pool.stats().tasks, 6);
        assert_eq!(pool.stats().makespans.len(), 3);
        assert_eq!(pool.stats().busy_per_worker.len(), 2);
    }

    #[test]
    #[should_panic(expected = "at least one")]
    fn zero_workers_panics() {
        WorkerPool::new(0);
    }

    #[test]
    #[should_panic(expected = "no chunks")]
    fn empty_group_panics_like_sequential() {
        let mut pool = WorkerPool::new(2);
        // group 1 exists but has no tasks
        let ts = tasks(&[2usize]);
        let _ = pool.execute(&ts, 2, run_synthetic);
    }
}
