//! The worker pool: greedy LPT execution of [`ChunkTask`]s over `P`
//! **resident** worker threads, with fixed-order (bit-exact) reduction.
//!
//! Threads are spawned once at pool construction, park on a condvar
//! between dispatches, and are joined on `Drop` — no per-dispatch
//! `std::thread::scope`. The historical spawn-per-dispatch strategy is
//! kept as [`SpawnMode::Scoped`], the measured baseline of the
//! resident-vs-scoped overhead comparison (`repro exec-bench`,
//! `BENCH_parallel.json`).

use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::affinity;
use super::stats::{ExecStats, StepExecReport, TaskStat, WorkerStat};
use super::task::{lpt_order, ChunkTask};
use crate::mlmc::estimator::ChunkAccumulator;

/// The pool's unit-of-work closure: evaluated once per [`ChunkTask`].
/// `'static + Send + Sync` because resident workers outlive any one
/// dispatch — callers capture `Arc`-cloned backend/params snapshots, not
/// scope-borrowed references (see [`crate::coordinator::dispatcher`]).
type Job = Arc<dyn Fn(&ChunkTask) -> Result<(f64, Vec<f32>)> + Send + Sync>;

/// How the pool obtains its worker threads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpawnMode {
    /// `P` threads spawned once at construction, parked on a condvar
    /// between dispatches, joined on `Drop`. The default: per-dispatch
    /// cost is a wakeup, not a thread spawn — the regime that matters
    /// for DMLMC's light (level-0-only) steps.
    Resident,
    /// Spawn `min(P, n_tasks)` scoped threads per dispatch (the
    /// historical strategy). Kept as the measured baseline for the
    /// spawn-overhead comparison; results are bit-identical either way.
    Scoped,
}

/// Deterministic per-task sleep injection — a scheduling-perturbation
/// harness for determinism tests: whatever interleaving the sleeps force,
/// the reduced gradients must stay bit-identical.
#[derive(Debug, Clone, Copy)]
struct ChaosDelays {
    seed: u64,
    max_micros: u64,
}

impl ChaosDelays {
    /// splitmix64-style hash of (seed, task, worker) -> [0, max] µs.
    fn delay(&self, task: u64, worker: u64) -> Duration {
        let mut x = self
            .seed
            .wrapping_add(task.wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_add(worker.wrapping_mul(0xD1B5_4A32_D192_ED03));
        x ^= x >> 30;
        x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x ^= x >> 27;
        x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
        x ^= x >> 31;
        Duration::from_micros(x % (self.max_micros + 1))
    }
}

/// What one worker brings home from a dispatch.
struct WorkerOut {
    worker: usize,
    busy: Duration,
    /// `(task index, start offset from the dispatch epoch, execution
    /// time, result)` — the per-task timings feed [`TaskStat`], which
    /// the fleet needs to re-attribute one multiplexed dispatch back to
    /// its constituent problems and the observability layer renders as
    /// timeline spans.
    results: Vec<(usize, Duration, Duration, Result<(f64, Vec<f32>)>)>,
}

/// Everything the workers need for one dispatch, shared by `Arc` so it
/// outlives the `execute` stack frame from the workers' point of view.
struct Dispatch {
    tasks: Vec<ChunkTask>,
    /// LPT order over `tasks`; workers pull indices through `cursor`.
    order: Vec<usize>,
    cursor: AtomicUsize,
    chaos: Option<ChaosDelays>,
    /// The dispatch epoch: the instant `execute` began. Task start
    /// offsets are measured against it, so per-task records line up on
    /// one monotonic timeline per dispatch (comparable across runs —
    /// no absolute wall-clock leaks into reports).
    epoch: Instant,
    run: Job,
    /// Worker deposits `execute` waits for before reducing.
    expected: usize,
    outs: Mutex<Vec<WorkerOut>>,
    /// Signalled (under `outs`) when the last expected deposit lands.
    done: Condvar,
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// One worker's share of one dispatch: pull LPT-ordered task indices from
/// the shared cursor until the queue drains. A panic inside the job is
/// caught and recorded as that task's error — a resident worker must
/// survive the dispatch, or every later dispatch would deadlock waiting
/// for its deposit.
fn drain(worker: usize, d: &Dispatch) -> WorkerOut {
    let mut out = WorkerOut {
        worker,
        busy: Duration::ZERO,
        results: Vec::new(),
    };
    loop {
        let slot = d.cursor.fetch_add(1, Ordering::Relaxed);
        if slot >= d.order.len() {
            break;
        }
        let idx = d.order[slot];
        if let Some(c) = d.chaos {
            std::thread::sleep(c.delay(idx as u64, worker as u64));
        }
        let t0 = Instant::now();
        let start = t0.saturating_duration_since(d.epoch);
        let run = &*d.run;
        let task = &d.tasks[idx];
        let result = match catch_unwind(AssertUnwindSafe(|| run(task))) {
            Ok(r) => r,
            Err(payload) => Err(anyhow::anyhow!(
                "task panicked: {}",
                panic_message(payload)
            )),
        };
        let took = t0.elapsed();
        out.busy += took;
        out.results.push((idx, start, took, result));
    }
    out
}

/// Hand a finished worker's share back to `execute`; the last expected
/// deposit wakes the dispatcher.
fn deposit(d: &Dispatch, out: WorkerOut) {
    let mut outs = d.outs.lock().expect("pool mutex poisoned");
    outs.push(out);
    if outs.len() >= d.expected {
        d.done.notify_all();
    }
}

/// What the resident threads watch between dispatches.
struct RegistryState {
    /// Bumped once per dispatch; workers compare against their last seen
    /// value, so a notification missed while depositing is never lost.
    epoch: u64,
    dispatch: Option<Arc<Dispatch>>,
    shutdown: bool,
}

struct Registry {
    state: Mutex<RegistryState>,
    work: Condvar,
}

/// Shared worker→core map: slot `i` holds the core worker `i` actually
/// pinned to (`None` = unpinned — pinning off, refused, or unsupported).
type CoreMap = Arc<Mutex<Vec<Option<usize>>>>;

/// Pin the calling worker thread to its round-robin core and record the
/// outcome. Best-effort by contract: a refused mask leaves the slot
/// `None` and the worker running unpinned.
fn pin_worker(worker: usize, cores: &CoreMap) {
    let got = affinity::pin_current_thread(worker % affinity::available_cores());
    cores.lock().expect("pool mutex poisoned")[worker] = got;
}

/// A resident worker's whole life: pin (if asked), then wait for a new
/// epoch, drain the dispatch, deposit, repeat — until shutdown.
fn worker_main(worker: usize, registry: Arc<Registry>, pin: Option<CoreMap>) {
    if let Some(cores) = pin {
        pin_worker(worker, &cores);
    }
    let mut seen = 0u64;
    loop {
        let dispatch = {
            let mut st = registry.state.lock().expect("pool mutex poisoned");
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    break st
                        .dispatch
                        .clone()
                        .expect("epoch advanced without a dispatch");
                }
                st = registry.work.wait(st).expect("pool mutex poisoned");
            }
        };
        let out = drain(worker, &dispatch);
        deposit(&dispatch, out);
    }
}

/// Persistent chunk-execution runtime: `P` resident workers, an
/// LPT-ordered shared queue, and per-run [`ExecStats`]. See the module
/// docs of [`crate::exec`] for the design (sharding / scheduling /
/// reduction / residency).
pub struct WorkerPool {
    workers: usize,
    mode: SpawnMode,
    /// Pin worker `i` to core `i % available_cores()` (`[execution]
    /// pin_cores`): at spawn for resident workers, per-dispatch for
    /// scoped ones. Best-effort — see [`affinity::pin_current_thread`].
    pin_cores: bool,
    /// Achieved worker→core placement, copied into every
    /// [`WorkerStat::core`] the pool reports.
    core_map: CoreMap,
    chaos: Option<ChaosDelays>,
    stats: ExecStats,
    /// OS threads spawned over the pool's lifetime: `P` once for
    /// [`SpawnMode::Resident`], `min(P, n_tasks)` per dispatch for
    /// [`SpawnMode::Scoped`] — the observable the spawn-overhead bench
    /// and the spawn-once lifecycle tests key on.
    threads_spawned: usize,
    registry: Option<Arc<Registry>>,
    handles: Vec<JoinHandle<()>>,
}

impl fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("WorkerPool")
            .field("workers", &self.workers)
            .field("mode", &self.mode)
            .field("threads_spawned", &self.threads_spawned)
            .field("stats", &self.stats)
            .finish()
    }
}

impl WorkerPool {
    /// A resident pool with `workers >= 1` threads, spawned now and
    /// joined on `Drop`. One worker degenerates to sequential execution
    /// through the same code path (useful as the measured P = 1
    /// baseline, executor overhead included).
    pub fn new(workers: usize) -> Self {
        Self::with_mode(workers, SpawnMode::Resident)
    }

    /// The historical spawn-per-dispatch pool — the baseline side of the
    /// resident-vs-scoped overhead comparison.
    pub fn new_scoped(workers: usize) -> Self {
        Self::with_mode(workers, SpawnMode::Scoped)
    }

    pub fn with_mode(workers: usize, mode: SpawnMode) -> Self {
        Self::with_options(workers, mode, false)
    }

    /// The fully-general constructor: spawn mode plus core pinning.
    /// `pin_cores` pins worker `i` to core `i % available_cores()` —
    /// once at spawn for [`SpawnMode::Resident`], per dispatched thread
    /// for [`SpawnMode::Scoped`] — and the achieved placement surfaces
    /// as [`WorkerStat::core`] in every report. Pinning never changes
    /// results (the fixed-order reduction doesn't care where a chunk
    /// ran); it only steadies the per-core cache working set.
    pub fn with_options(workers: usize, mode: SpawnMode, pin_cores: bool) -> Self {
        assert!(workers > 0, "need at least one worker");
        let mut pool = WorkerPool {
            workers,
            mode,
            pin_cores,
            core_map: Arc::new(Mutex::new(vec![None; workers])),
            chaos: None,
            stats: ExecStats::new(workers),
            threads_spawned: 0,
            registry: None,
            handles: Vec::new(),
        };
        if mode == SpawnMode::Resident {
            let registry = Arc::new(Registry {
                state: Mutex::new(RegistryState {
                    epoch: 0,
                    dispatch: None,
                    shutdown: false,
                }),
                work: Condvar::new(),
            });
            for worker in 0..workers {
                let reg = registry.clone();
                let pin = pin_cores.then(|| pool.core_map.clone());
                let handle = std::thread::Builder::new()
                    .name(format!("dmlmc-pool-{worker}"))
                    .spawn(move || worker_main(worker, reg, pin))
                    .expect("failed to spawn pool worker");
                pool.handles.push(handle);
            }
            pool.threads_spawned = workers;
            pool.registry = Some(registry);
        }
        pool
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn mode(&self) -> SpawnMode {
        self.mode
    }

    /// Whether this pool round-robin-pins its workers to cores.
    pub fn pin_cores(&self) -> bool {
        self.pin_cores
    }

    /// OS threads spawned so far (lifetime total; constant == `workers`
    /// for a resident pool, grows per dispatch for a scoped one).
    pub fn threads_spawned(&self) -> usize {
        self.threads_spawned
    }

    /// Live resident worker threads (0 for a scoped pool).
    pub fn resident_threads(&self) -> usize {
        self.handles.len()
    }

    /// Cumulative stats over every dispatch this pool has run.
    pub fn stats(&self) -> &ExecStats {
        &self.stats
    }

    /// Inject a pseudorandom sleep of up to `max_micros` µs before every
    /// task, derived from `(seed, task, worker)` — perturbs the schedule
    /// without touching any numeric input. `max_micros = 0` disables.
    /// Test/debug facility: results must be invariant under it.
    pub fn set_chaos_delays(&mut self, seed: u64, max_micros: u64) {
        self.chaos = if max_micros == 0 {
            None
        } else {
            Some(ChaosDelays { seed, max_micros })
        };
    }

    /// Execute `tasks` across the workers and reduce each of the
    /// `n_groups` groups in ascending chunk order.
    ///
    /// `run` computes one chunk: it must be a pure function of the task's
    /// address (`group`/`chunk`/`level`) so execution order is
    /// irrelevant; the counter-based RNG gives the dispatcher exactly
    /// that. It is `'static` because resident workers outlive the
    /// dispatch — capture `Arc` clones, not borrows. Returns one
    /// `(mean loss, mean gradient)` per group — the fold is the same
    /// `ChunkAccumulator` sequence the sequential dispatcher performs, so
    /// the result is bit-identical to sequential execution for every
    /// worker count and both spawn modes.
    ///
    /// Errors: the error of the lowest-indexed failing task is returned
    /// (deterministic whichever worker hit it first). A panic inside
    /// `run` is caught and surfaces as that task's error — the pool
    /// itself survives and later dispatches proceed normally.
    pub fn execute<F>(
        &mut self,
        tasks: &[ChunkTask],
        n_groups: usize,
        run: F,
    ) -> Result<(Vec<(f64, Vec<f32>)>, StepExecReport)>
    where
        F: Fn(&ChunkTask) -> Result<(f64, Vec<f32>)> + Send + Sync + 'static,
    {
        let run: Job = Arc::new(run);
        debug_assert!(tasks.iter().all(|t| t.group < n_groups));
        let started = Instant::now();

        let mut worker_outs: Vec<WorkerOut> = if tasks.is_empty() {
            // Nothing to run: report an idle dispatch without waking (or
            // spawning) anything (DMLMC steps where no level is due).
            Vec::new()
        } else {
            let expected = match self.mode {
                SpawnMode::Resident => self.workers,
                // An oversubscribed scoped pool (workers > tasks) spawns
                // only as many threads as there are tasks.
                SpawnMode::Scoped => self.workers.min(tasks.len()),
            };
            let dispatch = Arc::new(Dispatch {
                tasks: tasks.to_vec(),
                order: lpt_order(tasks),
                cursor: AtomicUsize::new(0),
                chaos: self.chaos,
                epoch: started,
                run,
                expected,
                outs: Mutex::new(Vec::with_capacity(expected)),
                done: Condvar::new(),
            });
            match self.mode {
                SpawnMode::Resident => {
                    let registry = self
                        .registry
                        .as_ref()
                        .expect("resident pool has a registry");
                    {
                        let mut st =
                            registry.state.lock().expect("pool mutex poisoned");
                        st.epoch += 1;
                        st.dispatch = Some(dispatch.clone());
                    }
                    registry.work.notify_all();
                    let mut outs =
                        dispatch.outs.lock().expect("pool mutex poisoned");
                    while outs.len() < dispatch.expected {
                        outs = dispatch
                            .done
                            .wait(outs)
                            .expect("pool mutex poisoned");
                    }
                    let collected = std::mem::take(&mut *outs);
                    drop(outs);
                    // Release the job (and the backend/params Arcs it
                    // captured) now, not at the next dispatch.
                    registry
                        .state
                        .lock()
                        .expect("pool mutex poisoned")
                        .dispatch = None;
                    collected
                }
                SpawnMode::Scoped => {
                    self.threads_spawned += expected;
                    let pin = self.pin_cores;
                    let cores = &self.core_map;
                    std::thread::scope(|scope| {
                        for worker in 0..expected {
                            let d = dispatch.clone();
                            let cores = cores.clone();
                            scope.spawn(move || {
                                if pin {
                                    pin_worker(worker, &cores);
                                }
                                deposit(&d, drain(worker, &d))
                            });
                        }
                    });
                    let mut outs =
                        dispatch.outs.lock().expect("pool mutex poisoned");
                    std::mem::take(&mut *outs)
                }
            }
        };
        // Workers that deposited nothing (scoped: unspawned; empty
        // dispatch: everyone) still appear in the report (idle, zero
        // busy) so worker indices stay stable 0..P.
        let mut present = vec![false; self.workers];
        for out in &worker_outs {
            present[out.worker] = true;
        }
        for (worker, seen) in present.into_iter().enumerate() {
            if !seen {
                worker_outs.push(WorkerOut {
                    worker,
                    busy: Duration::ZERO,
                    results: Vec::new(),
                });
            }
        }
        let makespan = started.elapsed();

        // Scatter every task result into its pre-addressed slot; remember
        // the lowest-indexed error (deterministic across schedules).
        worker_outs.sort_by_key(|o| o.worker);
        // Snapshot the achieved placement once per dispatch. Taken after
        // every expected worker deposited, so resident workers' one-time
        // spawn pins are recorded by now; an *empty* dispatch right
        // after construction may race the spawn pins and report `None` —
        // consistent with pinning being best-effort metadata.
        let core_map = self
            .core_map
            .lock()
            .expect("pool mutex poisoned")
            .clone();
        let mut slots: Vec<Option<(f64, Vec<f32>)>> = vec![None; tasks.len()];
        let mut first_err: Option<(usize, anyhow::Error)> = None;
        let mut worker_stats = Vec::with_capacity(self.workers);
        let mut per_task: Vec<TaskStat> = Vec::with_capacity(tasks.len());
        for out in worker_outs {
            worker_stats.push(WorkerStat {
                worker: out.worker,
                busy: out.busy,
                tasks: out.results.len(),
                core: core_map.get(out.worker).copied().flatten(),
            });
            for (idx, start, took, result) in out.results {
                per_task.push(TaskStat {
                    task: idx,
                    group: tasks[idx].group,
                    worker: out.worker,
                    start,
                    busy: took,
                });
                match result {
                    Ok(v) => slots[idx] = Some(v),
                    Err(e) => {
                        if first_err.as_ref().map_or(true, |(i, _)| idx < *i) {
                            first_err = Some((idx, e));
                        }
                    }
                }
            }
        }
        per_task.sort_by_key(|t| t.task);
        if let Some((idx, err)) = first_err {
            let t = tasks[idx];
            return Err(err.context(format!(
                "pool task {idx} (group {}, level {}, chunk {}) failed",
                t.group, t.level, t.chunk
            )));
        }

        // Fixed-order reduction: groups in index order, chunks ascending —
        // the exact fold of the sequential dispatcher.
        let mut per_group: Vec<Vec<usize>> = vec![Vec::new(); n_groups];
        for (idx, t) in tasks.iter().enumerate() {
            per_group[t.group].push(idx);
        }
        let mut reduced = Vec::with_capacity(n_groups);
        for group in &mut per_group {
            group.sort_by_key(|&idx| tasks[idx].chunk);
            let dim = group
                .first()
                .and_then(|&idx| slots[idx].as_ref())
                .map(|(_, g)| g.len())
                .unwrap_or(0);
            let mut acc = ChunkAccumulator::new(dim);
            for &idx in group.iter() {
                let (loss, grad) = slots[idx].take().expect("task result missing");
                acc.add(loss, &grad);
            }
            // An empty group panics here ("no chunks accumulated"), just
            // like the sequential path's accumulator would.
            reduced.push(acc.finish());
        }

        let report = StepExecReport {
            workers: worker_stats,
            makespan,
            n_tasks: tasks.len(),
            per_task,
        };
        self.stats.record(&report);
        Ok((reduced, report))
    }
}

impl Drop for WorkerPool {
    /// Shut the resident threads down and join them. Never panics (a
    /// poisoned registry — a worker died mid-dispatch — still gets its
    /// shutdown flag set via `into_inner`).
    fn drop(&mut self) {
        if let Some(registry) = self.registry.take() {
            match registry.state.lock() {
                Ok(mut st) => st.shutdown = true,
                Err(poisoned) => poisoned.into_inner().shutdown = true,
            }
            registry.work.notify_all();
            for handle in self.handles.drain(..) {
                let _ = handle.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A synthetic chunk: loss = group*100 + chunk, grad = [chunk, group]
    /// scaled — deterministic, distinguishable, order-sensitive to sum.
    fn run_synthetic(t: &ChunkTask) -> Result<(f64, Vec<f32>)> {
        let loss = t.group as f64 * 100.0 + t.chunk as f64;
        let grad = vec![
            (t.chunk as f32 + 1.0) * 0.1,
            (t.group as f32 + 1.0) * 0.25,
        ];
        Ok((loss, grad))
    }

    fn tasks(groups: &[usize]) -> Vec<ChunkTask> {
        let mut out = Vec::new();
        for (group, &n) in groups.iter().enumerate() {
            for chunk in 0..n {
                out.push(ChunkTask {
                    group,
                    chunk,
                    level: group,
                    weight: (group + 1) as f64,
                });
            }
        }
        out
    }

    /// Sequential reference: the exact fold `run_one` performs.
    fn sequential(groups: &[usize]) -> Vec<(f64, Vec<f32>)> {
        let ts = tasks(groups);
        let mut out = Vec::new();
        for (group, &n) in groups.iter().enumerate() {
            let mut acc = ChunkAccumulator::new(2);
            for chunk in 0..n {
                let t = ts
                    .iter()
                    .find(|t| t.group == group && t.chunk == chunk)
                    .unwrap();
                let (loss, grad) = run_synthetic(t).unwrap();
                acc.add(loss, &grad);
            }
            out.push(acc.finish());
        }
        out
    }

    #[test]
    fn matches_sequential_for_many_worker_counts() {
        let groups = [3usize, 1, 4, 2];
        let want = sequential(&groups);
        for workers in [1usize, 2, 3, 8, 16] {
            let mut pool = WorkerPool::new(workers);
            let (got, report) = pool
                .execute(&tasks(&groups), groups.len(), run_synthetic)
                .unwrap();
            assert_eq!(got.len(), want.len());
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.0, b.0, "loss differs at P={workers}");
                assert_eq!(a.1, b.1, "grad differs at P={workers}");
            }
            assert_eq!(report.n_tasks, 10);
            assert_eq!(report.workers.len(), workers);
            let tasks_run: usize = report.workers.iter().map(|w| w.tasks).sum();
            assert_eq!(tasks_run, 10);
        }
    }

    #[test]
    fn scoped_mode_matches_resident_bitwise() {
        let groups = [2usize, 3, 1];
        let want = sequential(&groups);
        for workers in [1usize, 2, 4] {
            let mut pool = WorkerPool::new_scoped(workers);
            assert_eq!(pool.mode(), SpawnMode::Scoped);
            let (got, report) = pool
                .execute(&tasks(&groups), groups.len(), run_synthetic)
                .unwrap();
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.0, b.0, "scoped loss differs at P={workers}");
                assert_eq!(a.1, b.1, "scoped grad differs at P={workers}");
            }
            assert_eq!(report.workers.len(), workers);
        }
    }

    #[test]
    fn chaos_delays_do_not_change_results() {
        let groups = [2usize, 3];
        let want = sequential(&groups);
        for seed in [1u64, 2, 3] {
            let mut pool = WorkerPool::new(4);
            pool.set_chaos_delays(seed, 300);
            let (got, _) = pool
                .execute(&tasks(&groups), groups.len(), run_synthetic)
                .unwrap();
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.0, b.0);
                assert_eq!(a.1, b.1);
            }
        }
    }

    #[test]
    fn empty_dispatch_reports_idle() {
        let mut pool = WorkerPool::new(3);
        let (reduced, report) = pool.execute(&[], 0, run_synthetic).unwrap();
        assert!(reduced.is_empty());
        assert_eq!(report.n_tasks, 0);
        assert_eq!(report.utilization(), 0.0);
        assert_eq!(pool.stats().steps, 1);
    }

    #[test]
    fn lowest_indexed_error_wins() {
        let ts = tasks(&[4usize]);
        let mut pool = WorkerPool::new(4);
        let err = pool
            .execute(&ts, 1, |t: &ChunkTask| {
                if t.chunk >= 1 {
                    Err(anyhow::anyhow!("boom chunk {}", t.chunk))
                } else {
                    run_synthetic(t)
                }
            })
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("chunk 1"), "{msg}");
        assert!(msg.contains("pool task"), "{msg}");
    }

    #[test]
    fn stats_accumulate_across_dispatches() {
        let mut pool = WorkerPool::new(2);
        for _ in 0..3 {
            pool.execute(&tasks(&[2usize]), 1, run_synthetic).unwrap();
        }
        assert_eq!(pool.stats().steps, 3);
        assert_eq!(pool.stats().tasks, 6);
        assert_eq!(pool.stats().makespans.len(), 3);
        assert_eq!(pool.stats().busy_per_worker.len(), 2);
    }

    #[test]
    fn per_task_records_cover_every_task_with_its_group() {
        let groups = [2usize, 3, 1];
        for workers in [1usize, 3] {
            let mut pool = WorkerPool::new(workers);
            let ts = tasks(&groups);
            let (_, report) = pool.execute(&ts, groups.len(), run_synthetic).unwrap();
            assert_eq!(report.per_task.len(), ts.len());
            for (i, stat) in report.per_task.iter().enumerate() {
                assert_eq!(stat.task, i, "per_task sorted by task index");
                assert_eq!(stat.group, ts[i].group);
                assert!(stat.worker < workers);
            }
            // per-task times sum to the per-worker busy rollup
            let task_total: Duration = report.per_task.iter().map(|t| t.busy).sum();
            assert_eq!(task_total, report.busy_total());
            // slicing every group apart partitions the tasks
            let sliced: usize = (0..groups.len())
                .map(|g| report.slice_groups(g..g + 1).n_tasks)
                .sum();
            assert_eq!(sliced, ts.len());
        }
    }

    #[test]
    fn task_spans_nest_inside_the_dispatch_makespan() {
        // `start` is measured from the dispatch epoch and the makespan
        // is measured from the same epoch *after* the last deposit, so
        // every span must satisfy start + busy <= makespan — including
        // the spans a group slice carries through.
        let groups = [3usize, 2, 2, 1];
        for workers in [1usize, 4] {
            let mut pool = WorkerPool::new(workers);
            let (_, report) =
                pool.execute(&tasks(&groups), groups.len(), run_synthetic).unwrap();
            assert_eq!(report.per_task.len(), 8);
            for t in &report.per_task {
                assert!(
                    t.start + t.busy <= report.makespan,
                    "P={workers} task {} span [{:?} + {:?}] exceeds makespan {:?}",
                    t.task,
                    t.start,
                    t.busy,
                    report.makespan,
                );
            }
            // sliced spans keep their offsets and still nest
            let slice = report.slice_groups(1..3);
            assert_eq!(slice.per_task.len(), 4);
            for t in &slice.per_task {
                assert!(t.start + t.busy <= slice.makespan);
                let full = report.per_task.iter().find(|f| f.task == t.task).unwrap();
                assert_eq!(t.start, full.start);
            }
        }
    }

    #[test]
    fn task_spans_reconcile_with_worker_busy_bitwise() {
        // Trace/metric reconciliation: the summed `task` span durations
        // per worker must equal the WorkerStat::busy rollup bit-for-bit
        // in the same dispatch — across P in {1, 4}, with and without
        // chaos-perturbed schedules.
        let groups = [4usize, 3, 2, 1];
        for workers in [1usize, 4] {
            for chaos in [None, Some((7u64, 200u64))] {
                let mut pool = WorkerPool::new(workers);
                if let Some((seed, max_micros)) = chaos {
                    pool.set_chaos_delays(seed, max_micros);
                }
                let (_, report) =
                    pool.execute(&tasks(&groups), groups.len(), run_synthetic).unwrap();
                for w in &report.workers {
                    let span_sum: Duration = report
                        .per_task
                        .iter()
                        .filter(|t| t.worker == w.worker)
                        .map(|t| t.busy)
                        .sum();
                    assert_eq!(
                        span_sum, w.busy,
                        "P={workers} chaos={chaos:?} worker {} rollup drifted",
                        w.worker
                    );
                }
            }
        }
    }

    #[test]
    fn resident_pool_spawns_threads_once() {
        let mut pool = WorkerPool::new(3);
        assert_eq!(pool.mode(), SpawnMode::Resident);
        assert_eq!(pool.threads_spawned(), 3);
        assert_eq!(pool.resident_threads(), 3);
        for _ in 0..5 {
            pool.execute(&tasks(&[2usize, 1]), 2, run_synthetic).unwrap();
        }
        // spawn-once: dispatches reuse the same threads
        assert_eq!(pool.threads_spawned(), 3);
        assert_eq!(pool.resident_threads(), 3);
        assert_eq!(pool.stats().steps, 5);
    }

    #[test]
    fn scoped_pool_spawns_per_dispatch() {
        let mut pool = WorkerPool::new_scoped(2);
        assert_eq!(pool.threads_spawned(), 0);
        assert_eq!(pool.resident_threads(), 0);
        for _ in 0..3 {
            pool.execute(&tasks(&[2usize]), 1, run_synthetic).unwrap();
        }
        // min(P = 2, tasks = 2) fresh threads per dispatch
        assert_eq!(pool.threads_spawned(), 6);
    }

    #[test]
    fn panicking_task_reports_error_and_pool_survives() {
        let mut pool = WorkerPool::new(2);
        let err = pool
            .execute(&tasks(&[3usize]), 1, |t: &ChunkTask| {
                if t.chunk == 1 {
                    panic!("chunk exploded");
                }
                run_synthetic(t)
            })
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("panicked"), "{msg}");
        assert!(msg.contains("chunk exploded"), "{msg}");
        // the resident workers survive: the next dispatch must neither
        // deadlock nor misbehave
        let want = sequential(&[2usize]);
        let (got, _) = pool.execute(&tasks(&[2usize]), 1, run_synthetic).unwrap();
        assert_eq!(got[0].0, want[0].0);
        assert_eq!(got[0].1, want[0].1);
    }

    #[test]
    fn unpinned_pool_reports_no_cores() {
        let mut pool = WorkerPool::new(2);
        assert!(!pool.pin_cores());
        let (_, report) = pool.execute(&tasks(&[2usize]), 1, run_synthetic).unwrap();
        assert!(report.workers.iter().all(|w| w.core.is_none()));
    }

    #[test]
    fn pinned_pool_reports_round_robin_cores_and_stays_bitwise() {
        // Pinning must never perturb results, and whatever placement the
        // kernel granted must be the round-robin target. Success itself
        // is not asserted — a restricted cpuset (CI containers) may
        // refuse the mask, which legitimately degrades to `core: None`.
        let groups = [3usize, 2];
        let want = sequential(&groups);
        let spread = affinity::available_cores();
        for mode in [SpawnMode::Resident, SpawnMode::Scoped] {
            let mut pool = WorkerPool::with_options(2, mode, true);
            assert!(pool.pin_cores());
            let (got, report) = pool
                .execute(&tasks(&groups), groups.len(), run_synthetic)
                .unwrap();
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.0, b.0, "{mode:?} loss drifted under pinning");
                assert_eq!(a.1, b.1, "{mode:?} grad drifted under pinning");
            }
            for w in &report.workers {
                if let Some(core) = w.core {
                    assert_eq!(core, w.worker % spread, "{mode:?}");
                }
            }
        }
    }

    #[test]
    fn dropping_a_resident_pool_joins_cleanly() {
        let mut pool = WorkerPool::new(4);
        pool.execute(&tasks(&[3usize]), 1, run_synthetic).unwrap();
        drop(pool); // must not hang or panic
    }

    #[test]
    #[should_panic(expected = "at least one")]
    fn zero_workers_panics() {
        WorkerPool::new(0);
    }

    #[test]
    #[should_panic(expected = "no chunks")]
    fn empty_group_panics_like_sequential() {
        let mut pool = WorkerPool::new(2);
        // group 1 exists but has no tasks
        let ts = tasks(&[2usize]);
        let _ = pool.execute(&ts, 2, run_synthetic);
    }
}
