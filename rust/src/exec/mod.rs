//! Parallel execution runtime — a chunk-sharded worker pool with
//! bit-exact reduction.
//!
//! The paper's parallel-complexity claims were so far only *modeled*
//! ([`crate::parallel::pram`]); this module actually executes a step's
//! chunk workload across `P` OS threads and measures wall-clock makespan,
//! so the MLMC-vs-DMLMC gap becomes an observable number (`repro
//! parallel-sweep`, `BENCH_parallel.json`).
//!
//! # Design
//!
//! * **Sharding** — a step's level jobs are split into per-chunk
//!   [`ChunkTask`]s (one backend execution each). Chunks are the natural
//!   grain: they are pure functions of their address `(purpose, step,
//!   level, chunk)` thanks to the counter-based RNG, so execution order
//!   cannot change any result.
//! * **Scheduling** — tasks are sorted longest-processing-time first
//!   ([`lpt_order`], weight = `batch x n_steps`, the same greedy rule the
//!   PRAM model simulates) into a single shared queue; idle workers pull
//!   the next-heaviest task from an atomic cursor. A shared LPT queue IS
//!   greedy list scheduling: a worker that finishes early "steals" the
//!   work a static partition would have pinned elsewhere.
//! * **Reduction** — every task result lands in a pre-addressed slot
//!   `(group, chunk)`; after the join, the *main thread* folds each
//!   group's chunks in ascending chunk order through the same
//!   [`ChunkAccumulator`](crate::mlmc::estimator::ChunkAccumulator) the
//!   sequential path uses. Gradients are therefore **bit-identical to
//!   sequential dispatch for every worker count** (f32 addition is
//!   non-associative — order is pinned, not hoped for).
//! * **Observability** — each dispatch returns a [`StepExecReport`]:
//!   measured makespan, per-worker busy time and task counts keyed by
//!   *stable worker indices* `0..P` (not thread ids, which change across
//!   runs); [`ExecStats`] accumulates them over a training run.
//!
//! The pool object is persistent across steps (scheduling policy, chaos
//! knobs and cumulative stats live as long as the `Trainer`); the worker
//! threads themselves are scoped per dispatch because the backend borrow
//! is step-scoped — spawn cost is microseconds against millisecond-scale
//! chunk work, and `std::thread::scope` keeps the whole runtime
//! unsafe-free. Pinning / NUMA placement and a truly resident thread set
//! are follow-ups (see ROADMAP).

pub mod pool;
pub mod stats;
pub mod task;

pub use pool::WorkerPool;
pub use stats::{ExecStats, StepExecReport, WorkerStat};
pub use task::{lpt_order, ChunkTask};
