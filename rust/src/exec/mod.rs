//! Parallel execution runtime — a chunk-sharded worker pool with
//! bit-exact reduction.
//!
//! The paper's parallel-complexity claims were so far only *modeled*
//! ([`crate::parallel::pram`]); this module actually executes a step's
//! chunk workload across `P` OS threads and measures wall-clock makespan,
//! so the MLMC-vs-DMLMC gap becomes an observable number (`repro
//! parallel-sweep`, `BENCH_parallel.json`).
//!
//! # Design
//!
//! * **Sharding** — a step's level jobs are split into per-chunk
//!   [`ChunkTask`]s (one backend execution each). Chunks are the natural
//!   grain: they are pure functions of their address `(purpose, step,
//!   level, chunk)` thanks to the counter-based RNG, so execution order
//!   cannot change any result.
//! * **Scheduling** — tasks are sorted longest-processing-time first
//!   ([`lpt_order`], weight = the coupled row-work `batch x (n_steps(l) +
//!   n_steps(l-1))` — the chunk's true cost; the PRAM model's `2^{c l}`
//!   per-sample price has the same scaling, with the coarse half
//!   absorbed into Assumption 1's constant) into a single shared queue;
//!   idle workers pull the next-heaviest task from an atomic cursor. A
//!   shared LPT queue IS greedy list scheduling: a worker that finishes
//!   early "steals" the work a static partition would have pinned
//!   elsewhere.
//! * **Reduction** — every task result lands in a pre-addressed slot
//!   `(group, chunk)`; once every worker has deposited, the *main thread*
//!   folds each group's chunks in ascending chunk order through the same
//!   [`ChunkAccumulator`](crate::mlmc::estimator::ChunkAccumulator) the
//!   sequential path uses. Gradients are therefore **bit-identical to
//!   sequential dispatch for every worker count** (f32 addition is
//!   non-associative — order is pinned, not hoped for).
//! * **Residency** — the `P` worker threads are spawned **once** at pool
//!   construction, park on a condvar between dispatches, and are joined
//!   on `Drop` ([`SpawnMode::Resident`]). Dispatch closures are
//!   `'static`: they capture `Arc`-cloned backend/params snapshots
//!   (plumbed via `GradBackend::into_shared`, see
//!   [`crate::runtime::GradBackend`]), so `execute` is
//!   enqueue-tasks + wait-on-completion, not spawn + join. The historical
//!   spawn-per-dispatch strategy survives as [`SpawnMode::Scoped`] — the
//!   measured baseline of the spawn-overhead comparison (`repro
//!   exec-bench`, the `exec_compare` row of `BENCH_parallel.json`). A
//!   panicking task is caught and surfaces as that task's error; the
//!   pool survives for later dispatches.
//! * **Observability** — each dispatch returns a [`StepExecReport`]:
//!   measured makespan, per-worker busy time and task counts keyed by
//!   *stable worker indices* `0..P` (not thread ids, which change across
//!   runs), per-task [`TaskStat`] records carrying both a `start` offset
//!   from the dispatch epoch and a busy duration (so a multiplexed
//!   dispatch can be re-attributed per reduction group — the fleet's
//!   per-problem reports, [`StepExecReport::slice_groups`] — and so
//!   [`crate::obs::Recorder`] can materialize a span timeline without
//!   adding anything to the worker hot path), and the **dispatch
//!   overhead** (makespan minus max worker busy — the executor's fixed
//!   per-step cost); [`ExecStats`] accumulates them over a training run.
//! * **Multiplexing** — nothing in the pool is per-trainer: a dispatch
//!   is just tasks + groups, so [`crate::coordinator::fleet`] batches
//!   the due chunk tasks of N independent trainers into ONE dispatch per
//!   fleet tick (globally unique group indices per problem), and the
//!   fixed-order per-group reduction keeps every problem's gradient
//!   bit-identical to its solo run.
//!
//! * **Placement** — behind `[execution] pin_cores` (`--pin-cores`),
//!   each resident worker pins itself to core `i % available_cores()`
//!   at spawn via [`affinity::pin_current_thread`]
//!   (`sched_setaffinity(2)` on Linux, a no-op elsewhere), keeping the
//!   lane-blocked hot loops' cache working set resident across
//!   dispatches. Pinning is best-effort — a refused mask degrades to
//!   unpinned — and the achieved worker→core map is reported through
//!   [`WorkerStat::core`] in every [`StepExecReport`].

pub mod affinity;
pub mod pool;
pub mod stats;
pub mod task;

pub use pool::{SpawnMode, WorkerPool};
pub use stats::{ExecStats, StepExecReport, TaskStat, WorkerStat};
pub use task::{lpt_order, ChunkTask};
