//! Thread→core affinity: pin a worker thread to one CPU so the resident
//! pool's lane-blocked hot loops keep their L1/L2 working set warm
//! across dispatches instead of migrating between cores at the
//! scheduler's whim.
//!
//! Linux-only by design (`sched_setaffinity(2)` via a raw `extern "C"`
//! declaration — the crate's no-new-dependencies rule rules out `libc`);
//! every other platform gets a no-op that reports "not pinned". Pinning
//! is strictly best-effort: a restricted cpuset (containers, cgroups)
//! makes the syscall fail, and the pool must keep working unpinned —
//! callers observe the outcome through the returned `Option` and the
//! `core` field of [`super::stats::WorkerStat`], never through an error.

/// Cores available to this process — the modulus for the worker→core
/// round-robin ([`crate::exec::WorkerPool`] pins worker `i` to core
/// `i % available_cores()`). Falls back to 1 if the OS refuses to say.
pub fn available_cores() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Pin the **calling** thread to `core`. Returns `Some(core)` when the
/// kernel accepted the mask, `None` when it refused (or on non-Linux,
/// always). Best-effort: failure must degrade to "unpinned", not panic.
#[cfg(target_os = "linux")]
pub fn pin_current_thread(core: usize) -> Option<usize> {
    // Glibc's fixed cpu_set_t is 1024 bits = 16 x u64. Bigger masks need
    // the dynamic CPU_ALLOC API; 1024 CPUs is far beyond this crate's
    // deployment envelope, so indices past the mask just decline to pin.
    const MASK_WORDS: usize = 16;
    if core >= MASK_WORDS * 64 {
        return None;
    }
    let mut mask = [0u64; MASK_WORDS];
    mask[core / 64] = 1u64 << (core % 64);
    extern "C" {
        // pid 0 = the calling thread (per sched_setaffinity(2)).
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    let ret = unsafe {
        sched_setaffinity(0, MASK_WORDS * std::mem::size_of::<u64>(), mask.as_ptr())
    };
    if ret == 0 {
        Some(core)
    } else {
        None
    }
}

/// Non-Linux stub: never pins, always reports `None`.
#[cfg(not(target_os = "linux"))]
pub fn pin_current_thread(core: usize) -> Option<usize> {
    let _ = core;
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn at_least_one_core_is_available() {
        assert!(available_cores() >= 1);
    }

    #[test]
    fn pinning_is_best_effort_and_reports_the_core() {
        // A restricted cpuset may legitimately refuse core 0; the
        // contract is only that success echoes the requested core and
        // failure is a clean None (no panic, thread keeps running).
        let spread = available_cores();
        for core in 0..spread.min(4) {
            if let Some(c) = pin_current_thread(core) {
                assert_eq!(c, core);
            }
        }
    }

    #[test]
    fn out_of_mask_core_declines_to_pin() {
        assert_eq!(pin_current_thread(1 << 20), None);
    }
}
