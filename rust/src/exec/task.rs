//! The pool's unit of work: one chunk of one level job, plus the LPT
//! priority used to order the shared queue.

/// One schedulable chunk. `group` addresses the reduction slot (one group
/// per level job), `chunk` fixes the fold order within the group, `weight`
/// is the LPT priority (any monotone proxy for the chunk's runtime; the
/// dispatcher uses the coupled row-work `batch x (n_steps(l) +
/// n_steps(l-1))` — a level-`l > 0` chunk simulates both the fine and the
/// coarse grid of every sample, so both halves count. The PRAM model
/// prices a sample at `2^{c l}`, same scaling with the coarse half in
/// Assumption 1's constant).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChunkTask {
    /// Reduction group (index into the step's job list).
    pub group: usize,
    /// Chunk index within the group — the reduction order key.
    pub chunk: usize,
    /// Discretization level (diagnostics / RNG addressing).
    pub level: usize,
    /// LPT priority: larger runs earlier.
    pub weight: f64,
}

/// Longest-processing-time order over `tasks`: indices sorted by weight
/// descending, ties broken by `(group, chunk)` ascending so the schedule
/// itself is deterministic (results never depend on it — only worker
/// busy-time telemetry does).
pub fn lpt_order(tasks: &[ChunkTask]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..tasks.len()).collect();
    order.sort_by(|&a, &b| {
        tasks[b]
            .weight
            .total_cmp(&tasks[a].weight)
            .then(tasks[a].group.cmp(&tasks[b].group))
            .then(tasks[a].chunk.cmp(&tasks[b].chunk))
    });
    order
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(group: usize, chunk: usize, weight: f64) -> ChunkTask {
        ChunkTask { group, chunk, level: 0, weight }
    }

    #[test]
    fn heaviest_first() {
        let tasks = [task(0, 0, 1.0), task(0, 1, 8.0), task(1, 0, 4.0)];
        assert_eq!(lpt_order(&tasks), vec![1, 2, 0]);
    }

    #[test]
    fn ties_break_by_group_then_chunk() {
        let tasks = [task(1, 0, 2.0), task(0, 1, 2.0), task(0, 0, 2.0)];
        assert_eq!(lpt_order(&tasks), vec![2, 1, 0]);
    }

    #[test]
    fn empty_ok() {
        assert!(lpt_order(&[]).is_empty());
    }
}
