//! Execution telemetry: per-dispatch reports and per-run accumulation,
//! keyed by **stable worker indices** (0..P). Thread ids are deliberately
//! absent — they change across runs and would make run manifests
//! non-reproducible.

use std::time::Duration;

/// One worker's share of one dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerStat {
    /// Stable worker index (0..P), constant across dispatches and runs.
    pub worker: usize,
    /// Time spent executing tasks (excludes queue waits).
    pub busy: Duration,
    /// Tasks this worker executed.
    pub tasks: usize,
    /// CPU core this worker is pinned to (`[execution] pin_cores`):
    /// `Some(core)` when `sched_setaffinity` accepted the mask, `None`
    /// when pinning is off, refused, or unsupported on this platform.
    /// Stable across the dispatches of one pool — pinning happens once
    /// at worker spawn.
    pub core: Option<usize>,
}

/// One task's execution record within a dispatch. Kept alongside the
/// per-worker rollup so a **multiplexed** dispatch (several problems'
/// task groups sharing one pool dispatch, see
/// [`crate::coordinator::fleet`]) can be re-attributed per group after
/// the fact.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TaskStat {
    /// Index of the task in the dispatched slice.
    pub task: usize,
    /// Reduction group the task belonged to.
    pub group: usize,
    /// Stable worker index that executed it.
    pub worker: usize,
    /// Offset of this task's execution start from the **dispatch epoch**
    /// (the instant `execute` began). With `busy` this makes each record
    /// a real timeline span — `start + busy` never exceeds the dispatch
    /// makespan — which is what the observability layer
    /// ([`crate::obs`]) renders as one Chrome-trace slice per task.
    pub start: Duration,
    /// Execution time of this single task (excludes queue waits).
    pub busy: Duration,
}

/// Telemetry of one pool dispatch (= one SGD step's refresh workload).
#[derive(Debug, Clone)]
pub struct StepExecReport {
    /// Per-worker stats, indexed by stable worker id.
    pub workers: Vec<WorkerStat>,
    /// Wall-clock time from dispatch start to last task completion —
    /// the *measured* counterpart of `PramMachine::step_makespan`.
    pub makespan: Duration,
    /// Tasks dispatched.
    pub n_tasks: usize,
    /// Per-task records in ascending task-index order (one per executed
    /// task; empty groups contribute nothing).
    pub per_task: Vec<TaskStat>,
}

impl StepExecReport {
    /// Sum of worker busy times (the step's measured "work").
    pub fn busy_total(&self) -> Duration {
        self.workers.iter().map(|w| w.busy).sum()
    }

    /// Longest single-worker busy time in this dispatch.
    pub fn max_busy(&self) -> Duration {
        self.workers
            .iter()
            .map(|w| w.busy)
            .max()
            .unwrap_or(Duration::ZERO)
    }

    /// Executor overhead of this dispatch: measured makespan minus the
    /// busiest worker — wakeup/spawn/join/scatter cost that is *not*
    /// chunk work. This is the per-step fixed cost the resident pool
    /// amortizes away relative to spawn-per-dispatch (and what DMLMC's
    /// light level-0-only steps are most sensitive to).
    pub fn dispatch_overhead(&self) -> Duration {
        self.makespan.saturating_sub(self.max_busy())
    }

    /// `busy_total / (P x makespan)` in [0, 1] — how much of the pool's
    /// capacity the step actually used. 0 for an empty dispatch.
    pub fn utilization(&self) -> f64 {
        let span = self.makespan.as_secs_f64() * self.workers.len() as f64;
        if span > 0.0 {
            (self.busy_total().as_secs_f64() / span).min(1.0)
        } else {
            0.0
        }
    }

    /// Restrict this report to the tasks whose reduction `group` falls in
    /// `groups`: per-worker busy/task counts are recomputed from the
    /// [`TaskStat`] records while the makespan (the shared dispatch
    /// wall-clock) is kept. This is how the fleet derives **per-problem**
    /// reports out of one multiplexed dispatch; a slice's
    /// [`utilization`](Self::utilization) therefore reads as "share of
    /// the whole pool's capacity this problem used".
    pub fn slice_groups(&self, groups: std::ops::Range<usize>) -> StepExecReport {
        let per_task: Vec<TaskStat> = self
            .per_task
            .iter()
            .copied()
            .filter(|t| groups.contains(&t.group))
            .collect();
        let mut workers: Vec<WorkerStat> = self
            .workers
            .iter()
            .map(|w| WorkerStat {
                worker: w.worker,
                busy: Duration::ZERO,
                tasks: 0,
                core: w.core,
            })
            .collect();
        for t in &per_task {
            if let Some(w) = workers.iter_mut().find(|w| w.worker == t.worker) {
                w.busy += t.busy;
                w.tasks += 1;
            }
        }
        StepExecReport {
            workers,
            makespan: self.makespan,
            n_tasks: per_task.len(),
            per_task,
        }
    }
}

/// Cumulative execution stats over a run (one record per dispatch).
#[derive(Debug, Clone)]
pub struct ExecStats {
    /// Dispatches recorded (= SGD steps executed through the pool).
    pub steps: usize,
    /// Total tasks executed.
    pub tasks: usize,
    /// Cumulative busy time per stable worker index.
    pub busy_per_worker: Vec<Duration>,
    /// Measured makespan of each dispatch, in dispatch order (seconds).
    pub makespans: Vec<f64>,
    /// Dispatch overhead (makespan minus max worker busy) of each
    /// dispatch, in dispatch order (seconds).
    pub overheads: Vec<f64>,
}

impl ExecStats {
    pub fn new(workers: usize) -> Self {
        ExecStats {
            steps: 0,
            tasks: 0,
            busy_per_worker: vec![Duration::ZERO; workers],
            makespans: Vec::new(),
            overheads: Vec::new(),
        }
    }

    /// Fold one dispatch report into the running totals. A report may
    /// carry worker indices beyond this accumulator's current capacity
    /// (a pool and a pre-sized `ExecStats` can legitimately disagree —
    /// e.g. stats created before a pool was resized, or fed from a
    /// differently-sized pool); the per-worker table grows to fit
    /// instead of panicking on the index.
    pub fn record(&mut self, report: &StepExecReport) {
        self.steps += 1;
        self.tasks += report.n_tasks;
        for w in &report.workers {
            if w.worker >= self.busy_per_worker.len() {
                self.busy_per_worker.resize(w.worker + 1, Duration::ZERO);
            }
            self.busy_per_worker[w.worker] += w.busy;
        }
        self.makespans.push(report.makespan.as_secs_f64());
        self.overheads.push(report.dispatch_overhead().as_secs_f64());
    }

    /// Total measured makespan over all dispatches (seconds).
    pub fn total_makespan(&self) -> f64 {
        self.makespans.iter().sum()
    }

    /// Mean measured per-step makespan (seconds); 0 before any dispatch.
    pub fn mean_makespan(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.total_makespan() / self.steps as f64
        }
    }

    /// Mean per-dispatch executor overhead (seconds); 0 before any
    /// dispatch. See [`StepExecReport::dispatch_overhead`].
    pub fn mean_dispatch_overhead(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.overheads.iter().sum::<f64>() / self.steps as f64
        }
    }

    /// Nearest-rank percentile of the per-dispatch makespans (seconds).
    /// `q` in `[0, 1]`; 0 before any dispatch.
    pub fn makespan_percentile(&self, q: f64) -> f64 {
        percentile(&self.makespans, q)
    }

    /// Largest per-dispatch makespan (seconds); 0 before any dispatch.
    pub fn max_makespan(&self) -> f64 {
        self.makespans.iter().fold(0.0, f64::max)
    }

    /// Nearest-rank percentile of the per-dispatch overheads (seconds).
    pub fn overhead_percentile(&self, q: f64) -> f64 {
        percentile(&self.overheads, q)
    }

    /// Largest per-dispatch overhead (seconds); 0 before any dispatch.
    pub fn max_overhead(&self) -> f64 {
        self.overheads.iter().fold(0.0, f64::max)
    }

    /// Run-level utilization: total busy / (P x total makespan).
    pub fn utilization(&self) -> f64 {
        let span = self.total_makespan() * self.busy_per_worker.len() as f64;
        if span > 0.0 {
            let busy: f64 = self
                .busy_per_worker
                .iter()
                .map(|d| d.as_secs_f64())
                .sum();
            (busy / span).min(1.0)
        } else {
            0.0
        }
    }
}

/// Nearest-rank percentile: the smallest element such that at least
/// `q x len` elements are `<=` it. `q` is clamped to `[0, 1]`; the
/// empty input yields 0. One definition shared by the run-manifest
/// writer and the [`crate::obs`] histogram summaries so "p95" always
/// means the same thing on disk.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let q = q.clamp(0.0, 1.0);
    let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(busy_ms: &[u64], makespan_ms: u64) -> StepExecReport {
        StepExecReport {
            workers: busy_ms
                .iter()
                .enumerate()
                .map(|(worker, &ms)| WorkerStat {
                    worker,
                    busy: Duration::from_millis(ms),
                    tasks: 1,
                    core: None,
                })
                .collect(),
            makespan: Duration::from_millis(makespan_ms),
            n_tasks: busy_ms.len(),
            per_task: busy_ms
                .iter()
                .enumerate()
                .map(|(worker, &ms)| TaskStat {
                    task: worker,
                    group: worker,
                    worker,
                    start: Duration::ZERO,
                    busy: Duration::from_millis(ms),
                })
                .collect(),
        }
    }

    #[test]
    fn utilization_of_balanced_dispatch_is_high() {
        let r = report(&[10, 10], 10);
        assert!((r.utilization() - 1.0).abs() < 1e-9);
        assert_eq!(r.busy_total(), Duration::from_millis(20));
    }

    #[test]
    fn utilization_of_imbalanced_dispatch_is_half() {
        let r = report(&[10, 0], 10);
        assert!((r.utilization() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn dispatch_overhead_is_makespan_minus_max_busy() {
        let r = report(&[10, 4], 13);
        assert_eq!(r.max_busy(), Duration::from_millis(10));
        assert_eq!(r.dispatch_overhead(), Duration::from_millis(3));
        // overhead saturates at zero (busy can exceed a coarse makespan)
        let tight = report(&[10, 4], 8);
        assert_eq!(tight.dispatch_overhead(), Duration::ZERO);
        // accumulation
        let mut s = ExecStats::new(2);
        s.record(&report(&[10, 4], 13));
        s.record(&report(&[4, 8], 9));
        assert_eq!(s.overheads.len(), 2);
        assert!((s.mean_dispatch_overhead() - 0.002).abs() < 1e-9);
    }

    #[test]
    fn empty_dispatch_utilization_zero() {
        let r = report(&[0, 0], 0);
        assert_eq!(r.utilization(), 0.0);
    }

    #[test]
    fn stats_accumulate_per_worker() {
        let mut s = ExecStats::new(2);
        s.record(&report(&[10, 4], 10));
        s.record(&report(&[2, 8], 8));
        assert_eq!(s.steps, 2);
        assert_eq!(s.tasks, 4);
        assert_eq!(s.busy_per_worker[0], Duration::from_millis(12));
        assert_eq!(s.busy_per_worker[1], Duration::from_millis(12));
        assert!((s.total_makespan() - 0.018).abs() < 1e-9);
        assert!((s.mean_makespan() - 0.009).abs() < 1e-9);
        assert!(s.utilization() > 0.6 && s.utilization() <= 1.0);
    }

    #[test]
    fn slice_groups_reattributes_per_problem() {
        // Two workers, four tasks across groups 0..4 (helper assigns one
        // task per group). Slice out groups 1..3 and check the rollup.
        let full = StepExecReport {
            workers: vec![
                WorkerStat { worker: 0, busy: Duration::from_millis(30), tasks: 3, core: Some(0) },
                WorkerStat { worker: 1, busy: Duration::from_millis(10), tasks: 1, core: None },
            ],
            makespan: Duration::from_millis(40),
            n_tasks: 4,
            per_task: vec![
                TaskStat { task: 0, group: 0, worker: 0, start: Duration::ZERO, busy: Duration::from_millis(10) },
                TaskStat { task: 1, group: 1, worker: 0, start: Duration::from_millis(10), busy: Duration::from_millis(10) },
                TaskStat { task: 2, group: 2, worker: 1, start: Duration::from_millis(5), busy: Duration::from_millis(10) },
                TaskStat { task: 3, group: 3, worker: 0, start: Duration::from_millis(20), busy: Duration::from_millis(10) },
            ],
        };
        let slice = full.slice_groups(1..3);
        assert_eq!(slice.n_tasks, 2);
        assert_eq!(slice.makespan, full.makespan);
        assert_eq!(slice.workers.len(), 2);
        assert_eq!(slice.workers[0].tasks, 1);
        assert_eq!(slice.workers[0].busy, Duration::from_millis(10));
        assert_eq!(slice.workers[1].tasks, 1);
        // pinning metadata rides through the per-problem slice untouched
        assert_eq!(slice.workers[0].core, Some(0));
        assert_eq!(slice.workers[1].core, None);
        assert_eq!(slice.per_task.len(), 2);
        // the timeline offsets ride along through the slice untouched,
        // and sliced spans still nest inside the shared dispatch makespan
        assert_eq!(slice.per_task[0].start, Duration::from_millis(10));
        assert_eq!(slice.per_task[1].start, Duration::from_millis(5));
        for t in &slice.per_task {
            assert!(t.start + t.busy <= slice.makespan);
        }
        // utilization of a slice = problem busy / (P x shared makespan)
        assert!((slice.utilization() - 20.0 / 80.0).abs() < 1e-9);
        // slices over all groups partition the task records
        let rest: usize = [full.slice_groups(0..1), full.slice_groups(3..4)]
            .iter()
            .map(|r| r.n_tasks)
            .sum();
        assert_eq!(rest + slice.n_tasks, full.n_tasks);
    }

    #[test]
    fn fresh_stats_are_zero() {
        let s = ExecStats::new(3);
        assert_eq!(s.mean_makespan(), 0.0);
        assert_eq!(s.utilization(), 0.0);
        assert_eq!(s.busy_per_worker.len(), 3);
    }

    #[test]
    fn record_grows_for_worker_indices_beyond_capacity() {
        // Regression: a report carrying worker indices >= the stats'
        // capacity used to panic on `busy_per_worker[w.worker]`.
        let mut s = ExecStats::new(1);
        s.record(&report(&[5, 7, 3], 9));
        assert_eq!(s.busy_per_worker.len(), 3);
        assert_eq!(s.busy_per_worker[0], Duration::from_millis(5));
        assert_eq!(s.busy_per_worker[2], Duration::from_millis(3));
        // further records keep accumulating into the grown table
        s.record(&report(&[1, 1], 2));
        assert_eq!(s.busy_per_worker.len(), 3);
        assert_eq!(s.busy_per_worker[0], Duration::from_millis(6));
        assert_eq!(s.busy_per_worker[1], Duration::from_millis(8));
    }

    #[test]
    fn percentile_is_nearest_rank() {
        let xs = [4.0, 1.0, 3.0, 2.0, 5.0];
        assert_eq!(percentile(&xs, 0.5), 3.0);
        assert_eq!(percentile(&xs, 0.95), 5.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 5.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[7.0], 0.5), 7.0);
    }

    #[test]
    fn stats_expose_makespan_and_overhead_percentiles() {
        let mut s = ExecStats::new(2);
        s.record(&report(&[10, 4], 13)); // makespan 13ms, overhead 3ms
        s.record(&report(&[4, 8], 9)); // makespan 9ms, overhead 1ms
        s.record(&report(&[2, 2], 4)); // makespan 4ms, overhead 2ms
        assert!((s.makespan_percentile(0.5) - 0.009).abs() < 1e-12);
        assert!((s.max_makespan() - 0.013).abs() < 1e-12);
        assert!((s.overhead_percentile(0.5) - 0.002).abs() < 1e-12);
        assert!((s.max_overhead() - 0.003).abs() < 1e-12);
        let empty = ExecStats::new(2);
        assert_eq!(empty.makespan_percentile(0.95), 0.0);
        assert_eq!(empty.max_overhead(), 0.0);
    }
}
