//! Execution telemetry: per-dispatch reports and per-run accumulation,
//! keyed by **stable worker indices** (0..P). Thread ids are deliberately
//! absent — they change across runs and would make run manifests
//! non-reproducible.

use std::time::Duration;

/// One worker's share of one dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerStat {
    /// Stable worker index (0..P), constant across dispatches and runs.
    pub worker: usize,
    /// Time spent executing tasks (excludes queue waits).
    pub busy: Duration,
    /// Tasks this worker executed.
    pub tasks: usize,
}

/// Telemetry of one pool dispatch (= one SGD step's refresh workload).
#[derive(Debug, Clone)]
pub struct StepExecReport {
    /// Per-worker stats, indexed by stable worker id.
    pub workers: Vec<WorkerStat>,
    /// Wall-clock time from dispatch start to last task completion —
    /// the *measured* counterpart of `PramMachine::step_makespan`.
    pub makespan: Duration,
    /// Tasks dispatched.
    pub n_tasks: usize,
}

impl StepExecReport {
    /// Sum of worker busy times (the step's measured "work").
    pub fn busy_total(&self) -> Duration {
        self.workers.iter().map(|w| w.busy).sum()
    }

    /// Longest single-worker busy time in this dispatch.
    pub fn max_busy(&self) -> Duration {
        self.workers
            .iter()
            .map(|w| w.busy)
            .max()
            .unwrap_or(Duration::ZERO)
    }

    /// Executor overhead of this dispatch: measured makespan minus the
    /// busiest worker — wakeup/spawn/join/scatter cost that is *not*
    /// chunk work. This is the per-step fixed cost the resident pool
    /// amortizes away relative to spawn-per-dispatch (and what DMLMC's
    /// light level-0-only steps are most sensitive to).
    pub fn dispatch_overhead(&self) -> Duration {
        self.makespan.saturating_sub(self.max_busy())
    }

    /// `busy_total / (P x makespan)` in [0, 1] — how much of the pool's
    /// capacity the step actually used. 0 for an empty dispatch.
    pub fn utilization(&self) -> f64 {
        let span = self.makespan.as_secs_f64() * self.workers.len() as f64;
        if span > 0.0 {
            (self.busy_total().as_secs_f64() / span).min(1.0)
        } else {
            0.0
        }
    }
}

/// Cumulative execution stats over a run (one record per dispatch).
#[derive(Debug, Clone)]
pub struct ExecStats {
    /// Dispatches recorded (= SGD steps executed through the pool).
    pub steps: usize,
    /// Total tasks executed.
    pub tasks: usize,
    /// Cumulative busy time per stable worker index.
    pub busy_per_worker: Vec<Duration>,
    /// Measured makespan of each dispatch, in dispatch order (seconds).
    pub makespans: Vec<f64>,
    /// Dispatch overhead (makespan minus max worker busy) of each
    /// dispatch, in dispatch order (seconds).
    pub overheads: Vec<f64>,
}

impl ExecStats {
    pub fn new(workers: usize) -> Self {
        ExecStats {
            steps: 0,
            tasks: 0,
            busy_per_worker: vec![Duration::ZERO; workers],
            makespans: Vec::new(),
            overheads: Vec::new(),
        }
    }

    pub fn record(&mut self, report: &StepExecReport) {
        self.steps += 1;
        self.tasks += report.n_tasks;
        for w in &report.workers {
            self.busy_per_worker[w.worker] += w.busy;
        }
        self.makespans.push(report.makespan.as_secs_f64());
        self.overheads.push(report.dispatch_overhead().as_secs_f64());
    }

    /// Total measured makespan over all dispatches (seconds).
    pub fn total_makespan(&self) -> f64 {
        self.makespans.iter().sum()
    }

    /// Mean measured per-step makespan (seconds); 0 before any dispatch.
    pub fn mean_makespan(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.total_makespan() / self.steps as f64
        }
    }

    /// Mean per-dispatch executor overhead (seconds); 0 before any
    /// dispatch. See [`StepExecReport::dispatch_overhead`].
    pub fn mean_dispatch_overhead(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.overheads.iter().sum::<f64>() / self.steps as f64
        }
    }

    /// Run-level utilization: total busy / (P x total makespan).
    pub fn utilization(&self) -> f64 {
        let span = self.total_makespan() * self.busy_per_worker.len() as f64;
        if span > 0.0 {
            let busy: f64 = self
                .busy_per_worker
                .iter()
                .map(|d| d.as_secs_f64())
                .sum();
            (busy / span).min(1.0)
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(busy_ms: &[u64], makespan_ms: u64) -> StepExecReport {
        StepExecReport {
            workers: busy_ms
                .iter()
                .enumerate()
                .map(|(worker, &ms)| WorkerStat {
                    worker,
                    busy: Duration::from_millis(ms),
                    tasks: 1,
                })
                .collect(),
            makespan: Duration::from_millis(makespan_ms),
            n_tasks: busy_ms.len(),
        }
    }

    #[test]
    fn utilization_of_balanced_dispatch_is_high() {
        let r = report(&[10, 10], 10);
        assert!((r.utilization() - 1.0).abs() < 1e-9);
        assert_eq!(r.busy_total(), Duration::from_millis(20));
    }

    #[test]
    fn utilization_of_imbalanced_dispatch_is_half() {
        let r = report(&[10, 0], 10);
        assert!((r.utilization() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn dispatch_overhead_is_makespan_minus_max_busy() {
        let r = report(&[10, 4], 13);
        assert_eq!(r.max_busy(), Duration::from_millis(10));
        assert_eq!(r.dispatch_overhead(), Duration::from_millis(3));
        // overhead saturates at zero (busy can exceed a coarse makespan)
        let tight = report(&[10, 4], 8);
        assert_eq!(tight.dispatch_overhead(), Duration::ZERO);
        // accumulation
        let mut s = ExecStats::new(2);
        s.record(&report(&[10, 4], 13));
        s.record(&report(&[4, 8], 9));
        assert_eq!(s.overheads.len(), 2);
        assert!((s.mean_dispatch_overhead() - 0.002).abs() < 1e-9);
    }

    #[test]
    fn empty_dispatch_utilization_zero() {
        let r = report(&[0, 0], 0);
        assert_eq!(r.utilization(), 0.0);
    }

    #[test]
    fn stats_accumulate_per_worker() {
        let mut s = ExecStats::new(2);
        s.record(&report(&[10, 4], 10));
        s.record(&report(&[2, 8], 8));
        assert_eq!(s.steps, 2);
        assert_eq!(s.tasks, 4);
        assert_eq!(s.busy_per_worker[0], Duration::from_millis(12));
        assert_eq!(s.busy_per_worker[1], Duration::from_millis(12));
        assert!((s.total_makespan() - 0.018).abs() < 1e-9);
        assert!((s.mean_makespan() - 0.009).abs() < 1e-9);
        assert!(s.utilization() > 0.6 && s.utilization() <= 1.0);
    }

    #[test]
    fn fresh_stats_are_zero() {
        let s = ExecStats::new(3);
        assert_eq!(s.mean_makespan(), 0.0);
        assert_eq!(s.utilization(), 0.0);
        assert_eq!(s.busy_per_worker.len(), 3);
    }
}
