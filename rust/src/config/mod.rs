//! Experiment configuration: defaults = the paper's Appendix-C settings,
//! overridable from TOML files (`configs/*.toml`) and CLI flags.

use std::path::{Path, PathBuf};

use crate::hedging::{Drift, Problem};
use crate::scenarios::{self, DEFAULT_SCENARIO};
use crate::util::toml::{TomlDoc, TomlError};

/// Which gradient backend executes the level jobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// AOT-compiled HLO artifacts via PJRT (the production path).
    Xla,
    /// The pure-rust verification engine (no artifacts needed).
    Native,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "xla" => Some(Backend::Xla),
            "native" => Some(Backend::Native),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Backend::Xla => "xla",
            Backend::Native => "native",
        }
    }
}

/// MLMC estimator hyperparameters (paper §2–3).
#[derive(Debug, Clone, Copy)]
pub struct MlmcConfig {
    /// Variance-decay exponent (Assumption 2). Paper: b = 1.8.
    pub b: f64,
    /// Cost-growth exponent (Assumption 1). Paper: c = 1.
    pub c: f64,
    /// Delay exponent of Algorithm 1 (refresh level l every 2^{dl} steps).
    /// Paper: d = 1.
    pub d: f64,
    /// Effective batch size N.
    pub n_effective: usize,
}

impl Default for MlmcConfig {
    fn default() -> Self {
        MlmcConfig {
            b: 1.8,
            c: 1.0,
            d: 1.0,
            n_effective: 1024,
        }
    }
}

/// Training-loop settings.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f64,
    pub optimizer: String,
    /// Evaluate the held-out loss every this many steps.
    pub eval_every: usize,
    /// Number of eval chunks averaged per evaluation.
    pub eval_chunks: usize,
    /// Seeds for repeated runs (Figure 2 uses 10).
    pub n_seeds: usize,
    /// Gradient-norm clip (0 = off). Stabilises the delayed estimator's
    /// early phase, where stale high-level components meet large initial
    /// gradients (Theorem 1's step-size bound is conservative for the
    /// same reason).
    pub clip_norm: f64,
    /// DMLMC warmup: for the first `dmlmc_warmup` steps every level is
    /// refreshed (standard MLMC), then the delayed schedule takes over.
    /// Removes the early-phase positive-feedback between fast parameter
    /// motion and stale high-level components; costs are accounted
    /// honestly (warmup steps pay full MLMC depth).
    pub dmlmc_warmup: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 400,
            lr: 0.05,
            optimizer: "sgd".to_string(),
            eval_every: 10,
            eval_chunks: 1,
            n_seeds: 10,
            clip_norm: 0.0,
            dmlmc_warmup: 8,
        }
    }
}

/// Parallel-execution settings ([`crate::exec::WorkerPool`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ExecutionConfig {
    /// Worker threads for the chunk-sharded pool: `0` (the default) =
    /// auto (one per available core), `1` = a single pooled worker
    /// (sequential order, executor overhead included), `n > 1` = that
    /// many workers. The pool is the default execution path for `Sync`
    /// backends (the native engine); the PJRT runtime's `!Send` handles
    /// always dispatch sequentially regardless of this setting.
    /// Gradients are bit-identical for every value (tested).
    pub workers: usize,
    /// Route the native hot path through the lane-blocked SIMD kernels
    /// (`--simd` on the CLI): the scenario key gains a `-simd` suffix and
    /// dispatches through [`crate::scenarios::kernels`]' lane variants.
    /// SIMD kernels reassociate f32 reductions, so results match the
    /// scalar reference to relative tolerance instead of bitwise —
    /// which is why this is opt-in and rejected on the XLA backend.
    pub simd: bool,
    /// Pin each resident pool worker to a CPU core
    /// (`sched_setaffinity` on Linux, silent no-op elsewhere — see
    /// [`crate::exec::affinity`]). Worker `i` goes to core
    /// `i % available_cores`; the realized mapping is reported per
    /// worker in [`crate::exec::StepExecReport`].
    pub pin_cores: bool,
}

impl ExecutionConfig {
    /// The concrete worker count: `workers`, or the machine's available
    /// parallelism when 0 (falling back to 1 if that is unknowable).
    pub fn resolved_workers(&self) -> usize {
        match self.workers {
            0 => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            n => n,
        }
    }
}

/// Observability settings ([`crate::obs`]): span tracing + metrics.
/// Off by default — the execution path records exactly the telemetry it
/// always did unless tracing is enabled (`--trace` on the CLI, or
/// `[observability] trace = true` in TOML). Enabling tracing never
/// changes a gradient (pinned bitwise in `tests/obs_trace.rs`);
/// `repro trace` measures the makespan overhead it does cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ObsConfig {
    /// Record spans + metrics and write `trace.json` / `metrics.prom`
    /// into the run directory.
    pub trace: bool,
    /// Per-track span ring capacity; older spans are evicted (and
    /// counted as dropped) beyond it.
    pub ring_capacity: usize,
    /// TCP port for the `repro serve` scrape endpoint (`--port` on the
    /// CLI). `0` (the default) binds an ephemeral port, reported on
    /// startup; ignored by every other subcommand.
    pub serve_port: u16,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            trace: false,
            ring_capacity: crate::obs::DEFAULT_RING_CAPACITY,
            serve_port: 0,
        }
    }
}

/// `repro serve` fleet composition: how many DMLMC sessions the daemon
/// submits to its [`FleetCoordinator`](crate::coordinator::FleetCoordinator)
/// and the seed of the first one (session `i` gets `seed0 + i`, so the
/// fleet reproduces `sessions` independent solo runs bit-identically).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeConfig {
    pub sessions: usize,
    pub seed0: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            sessions: 2,
            seed0: 1,
        }
    }
}

/// Adaptive allocation settings ([`crate::policy`]). Off by default —
/// with `enabled = false` the trainer runs the offline-theory
/// [`FixedPolicy`](crate::policy::FixedPolicy) and trajectories are
/// bit-identical to every release before the policy layer existed
/// (pinned in `tests/policy_regression.rs`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaptiveConfig {
    /// Route allocation through [`crate::policy::AdaptivePolicy`]
    /// (`--adaptive` on the CLI, `[adaptive] enabled = true` in TOML).
    pub enabled: bool,
    /// Re-observe the estimator telemetry every this many steps
    /// (`--adapt-every`). Decisions between observations are frozen.
    pub adapt_every: usize,
    /// A level's measured variance/cost enters the decision only after
    /// this many refreshes; before that the offline-theory value holds.
    pub min_refreshes: u64,
    /// Relative-change dead band: a level's sample count or refresh
    /// period only moves when the recomputed value differs from the
    /// current one by more than this fraction. Damps gauge noise so the
    /// decision stream is a deterministic function of the telemetry.
    pub hysteresis: f64,
    /// Hard clamp on any adapted refresh period (steps). Guarantees no
    /// level starves regardless of what the variance gauges report.
    pub max_period: u64,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            enabled: false,
            adapt_every: 16,
            min_refreshes: 2,
            hysteresis: 0.25,
            max_period: 1024,
        }
    }
}

/// Runtime / IO settings.
#[derive(Debug, Clone)]
pub struct RuntimeConfig {
    pub backend: Backend,
    pub artifacts_dir: PathBuf,
    pub out_dir: PathBuf,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        RuntimeConfig {
            backend: Backend::Xla,
            artifacts_dir: PathBuf::from("artifacts"),
            out_dir: PathBuf::from("out"),
        }
    }
}

/// Everything an experiment needs.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub problem: Problem,
    pub mlmc: MlmcConfig,
    pub train: TrainConfig,
    pub runtime: RuntimeConfig,
    pub execution: ExecutionConfig,
    pub observability: ObsConfig,
    pub serve: ServeConfig,
    pub adaptive: AdaptiveConfig,
    /// Scenario registry key (`scenario.name` in TOML, `--scenario` on
    /// the CLI). The default `"bs-call"` is the seed behavior; anything
    /// else requires the native backend.
    pub scenario: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            problem: Problem::default(),
            mlmc: MlmcConfig::default(),
            train: TrainConfig::default(),
            runtime: RuntimeConfig::default(),
            execution: ExecutionConfig::default(),
            observability: ObsConfig::default(),
            serve: ServeConfig::default(),
            adaptive: AdaptiveConfig::default(),
            scenario: DEFAULT_SCENARIO.to_string(),
        }
    }
}

impl ExperimentConfig {
    /// The paper's Appendix-C experiment at full scale.
    pub fn default_paper() -> Self {
        ExperimentConfig::default()
    }

    /// Small preset for smoke tests / CI (few steps, few seeds).
    pub fn smoke() -> Self {
        let mut cfg = ExperimentConfig::default();
        cfg.train.steps = 20;
        cfg.train.eval_every = 5;
        cfg.train.n_seeds = 2;
        cfg.mlmc.n_effective = 64;
        cfg.runtime.backend = Backend::Native;
        cfg.train.dmlmc_warmup = 0; // tests exercise the pure schedule
        cfg
    }

    /// Load from a TOML file, starting from defaults.
    pub fn from_toml_file(path: &Path) -> Result<Self, TomlError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| TomlError(format!("{}: {e}", path.display())))?;
        Self::from_toml(&text)
    }

    /// Parse from TOML text, starting from defaults. Unknown keys are
    /// rejected (catches typos in experiment configs).
    pub fn from_toml(text: &str) -> Result<Self, TomlError> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = ExperimentConfig::default();
        for (key, _) in &doc.entries {
            if !KNOWN_KEYS.contains(&key.as_str()) {
                return Err(TomlError(format!("unknown config key `{key}`")));
            }
        }

        let getf = |k: &str| doc.get(k).and_then(|v| v.as_f64());
        let getu = |k: &str| doc.get(k).and_then(|v| v.as_usize());
        let gets = |k: &str| doc.get(k).and_then(|v| v.as_str());

        // [problem]
        if let Some(v) = getf("problem.mu") {
            cfg.problem.mu = v;
        }
        if let Some(v) = getf("problem.sigma") {
            cfg.problem.sigma = v;
        }
        if let Some(v) = getf("problem.strike") {
            cfg.problem.strike = v;
        }
        if let Some(v) = getf("problem.s0") {
            cfg.problem.s0 = v;
        }
        if let Some(v) = getf("problem.maturity") {
            cfg.problem.maturity = v;
        }
        if let Some(v) = getu("problem.n0") {
            cfg.problem.n0 = v;
        }
        if let Some(v) = getu("problem.lmax") {
            cfg.problem.lmax = v;
        }
        if let Some(s) = gets("problem.drift") {
            cfg.problem.drift = Drift::parse(s)
                .ok_or_else(|| TomlError(format!("unknown drift `{s}`")))?;
        }

        // [mlmc]
        if let Some(v) = getf("mlmc.b") {
            cfg.mlmc.b = v;
        }
        if let Some(v) = getf("mlmc.c") {
            cfg.mlmc.c = v;
        }
        if let Some(v) = getf("mlmc.d") {
            cfg.mlmc.d = v;
        }
        if let Some(v) = getu("mlmc.n_effective") {
            cfg.mlmc.n_effective = v;
        }

        // [train]
        if let Some(v) = getu("train.steps") {
            cfg.train.steps = v;
        }
        if let Some(v) = getf("train.lr") {
            cfg.train.lr = v;
        }
        if let Some(s) = gets("train.optimizer") {
            cfg.train.optimizer = s.to_string();
        }
        if let Some(v) = getu("train.eval_every") {
            cfg.train.eval_every = v;
        }
        if let Some(v) = getu("train.eval_chunks") {
            cfg.train.eval_chunks = v;
        }
        if let Some(v) = getu("train.n_seeds") {
            cfg.train.n_seeds = v;
        }
        if let Some(v) = getf("train.clip_norm") {
            cfg.train.clip_norm = v;
        }
        if let Some(v) = getu("train.dmlmc_warmup") {
            cfg.train.dmlmc_warmup = v;
        }

        // [scenario]
        if let Some(s) = gets("scenario.name") {
            cfg.scenario = s.to_string();
        }

        // [execution]
        if let Some(v) = getu("execution.workers") {
            cfg.execution.workers = v;
        }
        if let Some(v) = doc.get("execution.simd").and_then(|v| v.as_bool()) {
            cfg.execution.simd = v;
        }
        if let Some(v) = doc.get("execution.pin_cores").and_then(|v| v.as_bool()) {
            cfg.execution.pin_cores = v;
        }

        // [observability]
        if let Some(v) = doc.get("observability.trace").and_then(|v| v.as_bool()) {
            cfg.observability.trace = v;
        }
        if let Some(v) = getu("observability.ring_capacity") {
            if v == 0 {
                return Err(TomlError(
                    "observability.ring_capacity must be positive".into(),
                ));
            }
            cfg.observability.ring_capacity = v;
        }
        if let Some(v) = getu("observability.serve_port") {
            if v > u16::MAX as usize {
                return Err(TomlError(format!(
                    "observability.serve_port must fit in a u16 (got {v})"
                )));
            }
            cfg.observability.serve_port = v as u16;
        }

        // [serve]
        if let Some(v) = getu("serve.sessions") {
            if v == 0 {
                return Err(TomlError("serve.sessions must be positive".into()));
            }
            cfg.serve.sessions = v;
        }
        if let Some(v) = getu("serve.seed0") {
            cfg.serve.seed0 = v as u64;
        }

        // [adaptive]
        if let Some(v) = doc.get("adaptive.enabled").and_then(|v| v.as_bool()) {
            cfg.adaptive.enabled = v;
        }
        if let Some(v) = getu("adaptive.adapt_every") {
            cfg.adaptive.adapt_every = v;
        }
        if let Some(v) = getu("adaptive.min_refreshes") {
            cfg.adaptive.min_refreshes = v as u64;
        }
        if let Some(v) = getf("adaptive.hysteresis") {
            cfg.adaptive.hysteresis = v;
        }
        if let Some(v) = getu("adaptive.max_period") {
            cfg.adaptive.max_period = v as u64;
        }

        // [runtime]
        if let Some(s) = gets("runtime.backend") {
            cfg.runtime.backend = Backend::parse(s)
                .ok_or_else(|| TomlError(format!("unknown backend `{s}`")))?;
        }
        if let Some(s) = gets("runtime.artifacts_dir") {
            cfg.runtime.artifacts_dir = PathBuf::from(s);
        }
        if let Some(s) = gets("runtime.out_dir") {
            cfg.runtime.out_dir = PathBuf::from(s);
        }

        // Only the override-independent constraints here: the CLI may
        // still change the backend, so the scenario/backend pairing is
        // deferred to the post-override `validate()`.
        cfg.validate_core().map_err(TomlError)?;
        Ok(cfg)
    }

    /// Full validation (run after every override source has been
    /// applied): the core constraints plus the scenario/backend pairing.
    pub fn validate(&self) -> Result<(), String> {
        self.validate_core()?;
        if self.scenario != DEFAULT_SCENARIO && self.runtime.backend == Backend::Xla {
            return Err(format!(
                "scenario `{}` requires `runtime.backend = \"native\"` \
                 (the XLA artifacts are lowered for the default \
                 `{DEFAULT_SCENARIO}` scenario only)",
                self.scenario
            ));
        }
        if self.execution.simd && self.runtime.backend == Backend::Xla {
            return Err(
                "`[execution] simd` requires `runtime.backend = \"native\"` \
                 (the lane-blocked kernels live in the native engine)"
                    .into(),
            );
        }
        Ok(())
    }

    /// The scenario key the native backend should actually run:
    /// `scenario`, suffixed with `-simd` when `[execution] simd` asks for
    /// the lane-blocked kernels (idempotent if the key already carries
    /// the suffix). The `-simd` variant of every registered key resolves
    /// by construction, so this never invalidates a validated config.
    pub fn effective_scenario(&self) -> String {
        if self.execution.simd && !self.scenario.ends_with("-simd") {
            format!("{}-simd", self.scenario)
        } else {
            self.scenario.clone()
        }
    }

    /// Sanity constraints (paper requirements and practical limits) that
    /// hold regardless of later CLI overrides.
    fn validate_core(&self) -> Result<(), String> {
        if self.mlmc.b <= self.mlmc.c {
            return Err(format!(
                "Assumption 2 requires b > c (got b = {}, c = {})",
                self.mlmc.b, self.mlmc.c
            ));
        }
        if self.train.lr <= 0.0 {
            return Err("learning rate must be positive".into());
        }
        if self.train.steps == 0 || self.train.eval_every == 0 {
            return Err("steps and eval_every must be positive".into());
        }
        if self.problem.n0 == 0 || self.problem.n0 % 2 != 0 {
            return Err("n0 must be a positive even number".into());
        }
        if self.mlmc.n_effective == 0 {
            return Err("n_effective must be positive".into());
        }
        if self.train.clip_norm < 0.0 {
            return Err("clip_norm must be non-negative (0 disables)".into());
        }
        if self.adaptive.adapt_every == 0 {
            return Err("adaptive.adapt_every must be positive".into());
        }
        if !(0.0..1.0).contains(&self.adaptive.hysteresis) {
            return Err(format!(
                "adaptive.hysteresis must be in [0, 1) (got {})",
                self.adaptive.hysteresis
            ));
        }
        if self.adaptive.max_period == 0 {
            return Err("adaptive.max_period must be positive".into());
        }
        scenarios::build_scenario_or_err(&self.scenario, &self.problem)
            .map_err(|e| e.to_string())?;
        Ok(())
    }
}

const KNOWN_KEYS: &[&str] = &[
    "problem.mu",
    "problem.sigma",
    "problem.strike",
    "problem.s0",
    "problem.maturity",
    "problem.n0",
    "problem.lmax",
    "problem.drift",
    "mlmc.b",
    "mlmc.c",
    "mlmc.d",
    "mlmc.n_effective",
    "train.steps",
    "train.lr",
    "train.optimizer",
    "train.eval_every",
    "train.eval_chunks",
    "train.n_seeds",
    "train.clip_norm",
    "train.dmlmc_warmup",
    "scenario.name",
    "execution.workers",
    "execution.simd",
    "execution.pin_cores",
    "observability.trace",
    "observability.ring_capacity",
    "observability.serve_port",
    "serve.sessions",
    "serve.seed0",
    "adaptive.enabled",
    "adaptive.adapt_every",
    "adaptive.min_refreshes",
    "adaptive.hysteresis",
    "adaptive.max_period",
    "runtime.backend",
    "runtime.artifacts_dir",
    "runtime.out_dir",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let cfg = ExperimentConfig::default_paper();
        assert_eq!(cfg.mlmc.b, 1.8);
        assert_eq!(cfg.mlmc.c, 1.0);
        assert_eq!(cfg.mlmc.d, 1.0);
        assert_eq!(cfg.problem.lmax, 6);
        assert_eq!(cfg.problem.strike, 3.0);
        assert_eq!(cfg.train.n_seeds, 10);
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn toml_overrides() {
        let cfg = ExperimentConfig::from_toml(
            r#"
[mlmc]
d = 1.5
n_effective = 256

[train]
steps = 50
lr = 0.01

[runtime]
backend = "native"
"#,
        )
        .unwrap();
        assert_eq!(cfg.mlmc.d, 1.5);
        assert_eq!(cfg.mlmc.n_effective, 256);
        assert_eq!(cfg.train.steps, 50);
        assert_eq!(cfg.runtime.backend, Backend::Native);
        // untouched defaults survive
        assert_eq!(cfg.mlmc.b, 1.8);
    }

    #[test]
    fn unknown_key_rejected() {
        let e = ExperimentConfig::from_toml("[train]\nstepz = 10").unwrap_err();
        assert!(e.0.contains("stepz"));
    }

    #[test]
    fn validation_rules() {
        assert!(ExperimentConfig::from_toml("[mlmc]\nb = 0.5").is_err()); // b <= c
        assert!(ExperimentConfig::from_toml("[train]\nlr = -1.0").is_err());
        assert!(ExperimentConfig::from_toml("[train]\nsteps = 0").is_err());
        assert!(ExperimentConfig::from_toml("[problem]\nn0 = 3").is_err());
    }

    #[test]
    fn scenario_defaults_and_toml_override() {
        let cfg = ExperimentConfig::default();
        assert_eq!(cfg.scenario, DEFAULT_SCENARIO);
        assert!(cfg.validate().is_ok());

        let cfg = ExperimentConfig::from_toml(
            "[scenario]\nname = \"ou-asian\"\n\n[runtime]\nbackend = \"native\"",
        )
        .unwrap();
        assert_eq!(cfg.scenario, "ou-asian");
    }

    #[test]
    fn scenario_validation_rules() {
        // unknown key rejected with the registry listed
        let e = ExperimentConfig::from_toml(
            "[scenario]\nname = \"sabr-call\"\n\n[runtime]\nbackend = \"native\"",
        )
        .unwrap_err();
        assert!(e.0.contains("sabr-call"), "{}", e.0);
        assert!(e.0.contains("bs-call"), "{}", e.0);
        // A backend-silent TOML with a non-default scenario parses (the
        // CLI may still override the backend) but the full validate()
        // rejects the unresolved xla pairing.
        let cfg = ExperimentConfig::from_toml("[scenario]\nname = \"cir-digital\"")
            .unwrap();
        let e = cfg.validate().unwrap_err();
        assert!(e.contains("native"), "{e}");
        let mut fixed = cfg;
        fixed.runtime.backend = Backend::Native;
        assert!(fixed.validate().is_ok());
    }

    #[test]
    fn heston_and_barrier_scenarios_validate() {
        // multi-factor + dashed payoff keys resolve from TOML end to end
        let cfg = ExperimentConfig::from_toml(
            "[scenario]\nname = \"heston-uo-call\"\n\n[runtime]\nbackend = \"native\"",
        )
        .unwrap();
        assert_eq!(cfg.scenario, "heston-uo-call");
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn execution_workers_parse_and_resolve() {
        // default: auto
        let cfg = ExperimentConfig::default();
        assert_eq!(cfg.execution.workers, 0);
        assert!(cfg.execution.resolved_workers() >= 1);

        let cfg =
            ExperimentConfig::from_toml("[execution]\nworkers = 4").unwrap();
        assert_eq!(cfg.execution.workers, 4);
        assert_eq!(cfg.execution.resolved_workers(), 4);

        // explicit single worker stays single
        let one = ExecutionConfig {
            workers: 1,
            ..Default::default()
        };
        assert_eq!(one.resolved_workers(), 1);

        // typo'd key still rejected
        assert!(ExperimentConfig::from_toml("[execution]\nworkerz = 2").is_err());
    }

    #[test]
    fn execution_simd_and_pin_cores_parse_and_validate() {
        let cfg = ExperimentConfig::default();
        assert!(!cfg.execution.simd && !cfg.execution.pin_cores);
        assert_eq!(cfg.effective_scenario(), DEFAULT_SCENARIO);

        let cfg = ExperimentConfig::from_toml(
            "[execution]\nsimd = true\npin_cores = true\n\n\
             [runtime]\nbackend = \"native\"",
        )
        .unwrap();
        assert!(cfg.execution.simd && cfg.execution.pin_cores);
        assert_eq!(cfg.effective_scenario(), "bs-call-simd");
        assert!(cfg.validate().is_ok());

        // -simd suffixing is idempotent
        let mut simd = cfg.clone();
        simd.scenario = "heston-uo-call-simd".to_string();
        assert_eq!(simd.effective_scenario(), "heston-uo-call-simd");

        // simd on the XLA backend is rejected after overrides
        let mut bad = cfg;
        bad.runtime.backend = Backend::Xla;
        let e = bad.validate().unwrap_err();
        assert!(e.contains("simd"), "{e}");
    }

    #[test]
    fn simd_scenario_keys_validate_from_toml() {
        let cfg = ExperimentConfig::from_toml(
            "[scenario]\nname = \"cir-digital-simd\"\n\n\
             [runtime]\nbackend = \"native\"",
        )
        .unwrap();
        assert_eq!(cfg.scenario, "cir-digital-simd");
        assert!(cfg.validate().is_ok());
        // junk around the suffix still rejected
        assert!(ExperimentConfig::from_toml(
            "[scenario]\nname = \"bs-simd\"\n\n[runtime]\nbackend = \"native\"",
        )
        .is_err());
    }

    #[test]
    fn observability_defaults_off_and_parses() {
        let cfg = ExperimentConfig::default();
        assert!(!cfg.observability.trace);
        assert_eq!(
            cfg.observability.ring_capacity,
            crate::obs::DEFAULT_RING_CAPACITY
        );

        let cfg = ExperimentConfig::from_toml(
            "[observability]\ntrace = true\nring_capacity = 128",
        )
        .unwrap();
        assert!(cfg.observability.trace);
        assert_eq!(cfg.observability.ring_capacity, 128);

        assert!(
            ExperimentConfig::from_toml("[observability]\nring_capacity = 0")
                .is_err()
        );
        assert!(ExperimentConfig::from_toml("[observability]\ntracing = true")
            .is_err());
    }

    #[test]
    fn serve_settings_parse_and_validate() {
        let cfg = ExperimentConfig::default();
        assert_eq!(cfg.observability.serve_port, 0);
        assert_eq!(cfg.serve.sessions, 2);
        assert_eq!(cfg.serve.seed0, 1);

        let cfg = ExperimentConfig::from_toml(
            "[observability]\nserve_port = 9184\n\n[serve]\nsessions = 3\nseed0 = 7",
        )
        .unwrap();
        assert_eq!(cfg.observability.serve_port, 9184);
        assert_eq!(cfg.serve.sessions, 3);
        assert_eq!(cfg.serve.seed0, 7);

        assert!(
            ExperimentConfig::from_toml("[observability]\nserve_port = 70000")
                .is_err()
        );
        assert!(ExperimentConfig::from_toml("[serve]\nsessions = 0").is_err());
        assert!(ExperimentConfig::from_toml("[serve]\nseedz = 1").is_err());
    }

    #[test]
    fn adaptive_settings_parse_and_validate() {
        let cfg = ExperimentConfig::default();
        assert!(!cfg.adaptive.enabled);
        assert_eq!(cfg.adaptive.adapt_every, 16);
        assert_eq!(cfg.adaptive.min_refreshes, 2);
        assert_eq!(cfg.adaptive.hysteresis, 0.25);
        assert_eq!(cfg.adaptive.max_period, 1024);

        let cfg = ExperimentConfig::from_toml(
            "[adaptive]\nenabled = true\nadapt_every = 8\n\
             min_refreshes = 3\nhysteresis = 0.1\nmax_period = 64",
        )
        .unwrap();
        assert!(cfg.adaptive.enabled);
        assert_eq!(cfg.adaptive.adapt_every, 8);
        assert_eq!(cfg.adaptive.min_refreshes, 3);
        assert_eq!(cfg.adaptive.hysteresis, 0.1);
        assert_eq!(cfg.adaptive.max_period, 64);

        assert!(ExperimentConfig::from_toml("[adaptive]\nadapt_every = 0").is_err());
        assert!(
            ExperimentConfig::from_toml("[adaptive]\nhysteresis = 1.5").is_err()
        );
        assert!(ExperimentConfig::from_toml("[adaptive]\nmax_period = 0").is_err());
        // typo'd key still rejected
        assert!(ExperimentConfig::from_toml("[adaptive]\nenable = true").is_err());
    }

    #[test]
    fn backend_parse() {
        assert_eq!(Backend::parse("xla"), Some(Backend::Xla));
        assert_eq!(Backend::parse("native"), Some(Backend::Native));
        assert_eq!(Backend::parse("tpu"), None);
        assert_eq!(Backend::Xla.name(), "xla");
    }
}
