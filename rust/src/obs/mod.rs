//! Observability: span-level execution tracing + a metrics registry,
//! wired through the whole execution stack.
//!
//! The paper's claims are about *parallel complexity*, but post-hoc
//! averages (`ExecStats::mean_makespan`, utilization) cannot show *why*
//! a step was fast or a fleet tick stalled — which worker idled, which
//! level's chunk straggled, where the dispatch overhead went. This
//! module turns the telemetry the executor already measures into:
//!
//! * **Spans** ([`Span`], [`SpanRing`]) — timestamped slices of work on
//!   per-track bounded ring buffers: one track per stable worker index
//!   (`task` spans, with level/group/chunk/session attrs) plus a
//!   coordinator track (`dispatch`, `step`, `tick`, `session` spans).
//!   All offsets are monotonic from the run epoch, so traces are
//!   comparable across runs.
//! * **Metrics** ([`Registry`]) — named counters / gauges / histograms
//!   (tasks dispatched, steps ticked, sessions admitted/rejected,
//!   makespan and overhead distributions) with a Prometheus text
//!   exposition — the scrape surface for the future daemon mode.
//! * **Export** ([`Recorder`], [`TraceSink`]) — the recorder ingests
//!   [`StepExecReport`](crate::exec::StepExecReport)s coordinator-side
//!   (the worker hot path records nothing it didn't already); the sink
//!   drains it into a run directory as `trace.json` (Chrome trace-event
//!   JSON, loadable in Perfetto / `chrome://tracing`) and
//!   `metrics.prom`.
//!
//! Tracing is **off by default**: enable with `--trace` (or
//! `[observability] trace = true`), and see `repro trace` for the
//! overhead-bounded traced-vs-untraced comparison (`BENCH_obs.json`) —
//! enabling tracing never changes a gradient (pinned bitwise in
//! `tests/obs_trace.rs`).

pub mod metrics;
pub mod span;
pub mod trace;

pub use metrics::{Histogram, Registry};
pub use span::{Span, SpanRing, Track};
pub use trace::{GroupMeta, Recorder, TraceSink, DEFAULT_RING_CAPACITY};
