//! Observability: span-level execution tracing + a metrics registry,
//! wired through the whole execution stack.
//!
//! The paper's claims are about *parallel complexity*, but post-hoc
//! averages (`ExecStats::mean_makespan`, utilization) cannot show *why*
//! a step was fast or a fleet tick stalled — which worker idled, which
//! level's chunk straggled, where the dispatch overhead went. This
//! module turns the telemetry the executor already measures into:
//!
//! * **Spans** ([`Span`], [`SpanRing`]) — timestamped slices of work on
//!   per-track bounded ring buffers: one track per stable worker index
//!   (`task` spans, with level/group/chunk/session attrs) plus a
//!   coordinator track (`dispatch`, `step`, `tick`, `session` spans).
//!   All offsets are monotonic from the run epoch, so traces are
//!   comparable across runs.
//! * **Metrics** ([`Registry`]) — named counters / gauges / histograms
//!   with labeled series (tasks dispatched, steps ticked, sessions
//!   admitted/rejected, makespan and overhead distributions, span-ring
//!   drop counts) with a Prometheus text exposition, shared across
//!   threads through [`SharedRegistry`] — the live scrape surface of
//!   `repro serve`.
//! * **Estimator statistics** ([`EstimatorStats`]) — per-level Welford
//!   gauges for gradient-difference variance and measured cost, DMLMC
//!   staleness / refresh-age, and sample counts, recorded from
//!   `apply_level_results` in the trainer and attributed per session in
//!   the fleet. [`EstimatorStats::observe`] renders an owning
//!   [`EstimatorSnapshot`] — the input of the [`crate::policy`]
//!   allocation policies — and [`estimator::publish_decision`] makes
//!   every policy decision scrape-visible as the `dmlmc_alloc_n` /
//!   `dmlmc_refresh_period` gauges.
//! * **Export** ([`Recorder`], [`TraceSink`]) — the recorder ingests
//!   [`StepExecReport`](crate::exec::StepExecReport)s coordinator-side
//!   (the worker hot path records nothing it didn't already); the sink
//!   drains it into a run directory as `trace.json` (Chrome trace-event
//!   JSON, loadable in Perfetto / `chrome://tracing`) and
//!   `metrics.prom`.
//! * **Serving** ([`MetricsServer`]) — a dependency-free
//!   `std::net::TcpListener` HTTP/1.1 endpoint exposing `GET /metrics`
//!   (the identical Prometheus renderer), `GET /status` (fleet-level
//!   JSON) and `GET /sessions/<id>` (per-session JSON), run by the
//!   `repro serve` subcommand.
//!
//! Tracing is **off by default**: enable with `--trace` (or
//! `[observability] trace = true`), and see `repro trace` for the
//! overhead-bounded traced-vs-untraced comparison (`BENCH_obs.json`) —
//! enabling tracing never changes a gradient (pinned bitwise in
//! `tests/obs_trace.rs`).

pub mod estimator;
pub mod metrics;
pub mod serve;
pub mod span;
pub mod trace;

pub use estimator::{EstimatorSnapshot, EstimatorStats, LevelSnapshot, LevelStats};
pub use metrics::{Histogram, Registry};
pub use serve::{MetricsServer, ServeState};
pub use span::{Span, SpanRing, Track};
pub use trace::{GroupMeta, Recorder, SharedRegistry, TraceSink, DEFAULT_RING_CAPACITY};
