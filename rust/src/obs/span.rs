//! Span primitives: a timestamped slice of work on one track, and the
//! bounded ring buffer that holds them.
//!
//! Offsets are measured from the recorder's **run epoch** (one
//! `Instant` captured at recorder construction), never absolute wall
//! clock — traces from different runs line up at t = 0 and contain no
//! machine-local timestamps.

use std::collections::VecDeque;
use std::time::Duration;

/// Which timeline a span belongs to. Worker tracks are keyed by the
/// pool's **stable worker indices** (0..P, never thread ids), matching
/// every other piece of execution telemetry; the coordinator track
/// carries the dispatch/step/tick/session spans recorded outside the
/// pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Track {
    /// The coordinator timeline (dispatcher / trainer / fleet layers).
    Coordinator,
    /// One pool worker's timeline, by stable worker index.
    Worker(usize),
}

/// One completed span: `[start, start + dur)` on `track`, with a small
/// set of numeric attributes (level, group, chunk, session, ...).
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    /// Span kind: `"task"`, `"dispatch"`, `"step"`, `"tick"`,
    /// `"session"`.
    pub name: &'static str,
    pub track: Track,
    /// Offset from the recorder's run epoch.
    pub start: Duration,
    pub dur: Duration,
    /// Numeric attributes, rendered into the Chrome-trace `args` object.
    pub args: Vec<(&'static str, f64)>,
}

/// A bounded span buffer: pushing beyond capacity evicts the **oldest**
/// span and counts it as dropped, so a long run's memory stays bounded
/// while the trace keeps its most recent window (and is honest about
/// what it lost).
#[derive(Debug, Clone)]
pub struct SpanRing {
    cap: usize,
    spans: VecDeque<Span>,
    dropped: usize,
}

impl SpanRing {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "span ring needs capacity >= 1");
        SpanRing { cap, spans: VecDeque::new(), dropped: 0 }
    }

    pub fn push(&mut self, span: Span) {
        if self.spans.len() == self.cap {
            self.spans.pop_front();
            self.dropped += 1;
        }
        self.spans.push_back(span);
    }

    pub fn len(&self) -> usize {
        self.spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// Spans evicted so far (0 while the ring has never overflowed).
    pub fn dropped(&self) -> usize {
        self.dropped
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Oldest-to-newest iteration over the retained spans.
    pub fn iter(&self) -> impl Iterator<Item = &Span> {
        self.spans.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(ms: u64) -> Span {
        Span {
            name: "task",
            track: Track::Worker(0),
            start: Duration::from_millis(ms),
            dur: Duration::from_millis(1),
            args: vec![("level", 0.0)],
        }
    }

    #[test]
    fn ring_keeps_newest_and_counts_drops() {
        let mut ring = SpanRing::new(3);
        for ms in 0..5 {
            ring.push(span(ms));
        }
        assert_eq!(ring.len(), 3);
        assert_eq!(ring.dropped(), 2);
        let starts: Vec<u64> = ring.iter().map(|s| s.start.as_millis() as u64).collect();
        assert_eq!(starts, vec![2, 3, 4]);
    }

    #[test]
    fn ring_below_capacity_drops_nothing() {
        let mut ring = SpanRing::new(8);
        ring.push(span(0));
        ring.push(span(1));
        assert_eq!(ring.len(), 2);
        assert_eq!(ring.dropped(), 0);
        assert!(!ring.is_empty());
        assert_eq!(ring.capacity(), 8);
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn zero_capacity_panics() {
        SpanRing::new(0);
    }
}
