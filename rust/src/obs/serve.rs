//! The live scrape surface: a dependency-free `std::net::TcpListener`
//! HTTP/1.1 server behind `repro serve`.
//!
//! Three read-only endpoints:
//!
//! * `GET /metrics` — Prometheus text exposition, rendered by the
//!   *identical* [`Registry`](super::Registry) renderer that writes
//!   `metrics.prom`, straight from the live [`SharedRegistry`] (no
//!   snapshot copies, no drift between the scrape and the dump).
//! * `GET /status` — fleet-level JSON: active/pending/done sessions,
//!   tick count, pool utilization (whatever the serve loop last
//!   published via [`ServeState::set_status`]).
//! * `GET /sessions/<id>` — per-session JSON: step progress, last loss,
//!   and the per-level layout + estimator statistics.
//!
//! Malformed request lines get `400`, unknown paths (and unknown
//! session ids) get `404`. One short-lived connection per request
//! (`Connection: close`) — a scrape cadence of seconds against a
//! handful of collectors, not a general web server. The accept loop
//! runs on its own named thread; [`MetricsServer::shutdown`] flips a
//! flag and unblocks `accept` with a self-connect, so teardown is
//! deterministic (also run on `Drop`).

use std::collections::BTreeMap;
use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::util::json::Json;

use super::trace::SharedRegistry;

/// Everything the HTTP endpoints can answer from, shared between the
/// serve loop (writer) and the accept thread (reader). The registry is
/// live; status and session documents are published by the loop
/// whenever they change (typically once per fleet tick).
#[derive(Debug)]
pub struct ServeState {
    registry: SharedRegistry,
    status: RwLock<Json>,
    sessions: RwLock<BTreeMap<u64, Json>>,
}

impl ServeState {
    pub fn new(registry: SharedRegistry) -> Self {
        ServeState {
            registry,
            status: RwLock::new(Json::Obj(BTreeMap::new())),
            sessions: RwLock::new(BTreeMap::new()),
        }
    }

    pub fn registry(&self) -> &SharedRegistry {
        &self.registry
    }

    /// Publish the fleet-level `/status` document.
    pub fn set_status(&self, doc: Json) {
        *self.status.write().unwrap_or_else(|e| e.into_inner()) = doc;
    }

    /// Publish (or refresh) one session's `/sessions/<id>` document.
    pub fn set_session(&self, id: u64, doc: Json) {
        self.sessions
            .write()
            .unwrap_or_else(|e| e.into_inner())
            .insert(id, doc);
    }

    /// The current `/status` document as JSON text.
    pub fn status_json(&self) -> String {
        self.status
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .to_string()
    }

    /// One session's document as JSON text, if published.
    pub fn session_json(&self, id: u64) -> Option<String> {
        self.sessions
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .get(&id)
            .map(|d| d.to_string())
    }
}

/// Route one request line to `(status code, content type, body)`.
/// Factored out of the connection handler so routing is unit-testable
/// without sockets.
fn respond(state: &ServeState, request_line: &str) -> (u16, &'static str, String) {
    let mut parts = request_line.split_whitespace();
    let (method, path, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v), None) => (m, p, v),
        _ => return (400, "text/plain", "bad request\n".to_string()),
    };
    if method != "GET" || !version.starts_with("HTTP/") {
        return (400, "text/plain", "bad request\n".to_string());
    }
    match path {
        "/metrics" => (
            200,
            "text/plain; version=0.0.4; charset=utf-8",
            state.registry.render_prometheus(),
        ),
        "/status" => (200, "application/json", format!("{}\n", state.status_json())),
        _ => {
            if let Some(id) = path
                .strip_prefix("/sessions/")
                .and_then(|id| id.parse::<u64>().ok())
            {
                if let Some(doc) = state.session_json(id) {
                    return (200, "application/json", format!("{doc}\n"));
                }
            }
            (404, "text/plain", "not found\n".to_string())
        }
    }
}

fn handle_conn(state: &ServeState, stream: &mut TcpStream) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    // Read until the end of the request head (GET requests carry no
    // body); cap the read so a hostile client cannot balloon memory.
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 1024];
    loop {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            break;
        }
        buf.extend_from_slice(&chunk[..n]);
        if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > 8192 {
            break;
        }
    }
    if buf.is_empty() {
        return Ok(()); // bare connect/close (e.g. the shutdown poke)
    }
    let head = String::from_utf8_lossy(&buf);
    let request_line = head.lines().next().unwrap_or("");
    let (status, ctype, body) = respond(state, request_line);
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        _ => "Not Found",
    };
    let response = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {ctype}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(response.as_bytes())?;
    stream.flush()
}

/// The scrape server: owns the accept thread, answers until shut down.
#[derive(Debug)]
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `127.0.0.1:<port>` (`port` 0 picks an ephemeral port — the
    /// bound address is reported by [`Self::addr`]) and start the
    /// accept loop on a `dmlmc-serve` thread.
    pub fn start(state: Arc<ServeState>, port: u16) -> std::io::Result<MetricsServer> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = stop.clone();
        let handle = std::thread::Builder::new()
            .name("dmlmc-serve".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop_flag.load(Ordering::SeqCst) {
                        break;
                    }
                    if let Ok(mut stream) = conn {
                        // Per-connection errors (client hung up, slow
                        // reader timed out) never take the server down.
                        let _ = handle_conn(&state, &mut stream);
                    }
                }
            })?;
        Ok(MetricsServer {
            addr,
            stop,
            handle: Some(handle),
        })
    }

    /// The bound address (`127.0.0.1:<port>`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn port(&self) -> u16 {
        self.addr.port()
    }

    /// Stop accepting and join the accept thread. Idempotent; also run
    /// on `Drop`.
    pub fn shutdown(&mut self) {
        let Some(handle) = self.handle.take() else {
            return;
        };
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept call so the loop observes the flag.
        let _ = TcpStream::connect(self.addr);
        let _ = handle.join();
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::obj;

    fn state() -> Arc<ServeState> {
        let registry = SharedRegistry::new();
        registry.write().inc("dmlmc_steps_total", 3);
        let state = ServeState::new(registry);
        state.set_status(obj(vec![("sessions_active", Json::Num(2.0))]));
        state.set_session(4, obj(vec![("step", Json::Num(7.0))]));
        Arc::new(state)
    }

    #[test]
    fn routing_covers_endpoints_and_errors() {
        let s = state();
        let (code, ctype, body) = respond(&s, "GET /metrics HTTP/1.1");
        assert_eq!(code, 200);
        assert!(ctype.starts_with("text/plain"));
        assert!(body.contains("dmlmc_steps_total 3"));
        let (code, ctype, body) = respond(&s, "GET /status HTTP/1.1");
        assert_eq!((code, ctype), (200, "application/json"));
        assert_eq!(
            Json::parse(body.trim()).unwrap().get("sessions_active").unwrap().as_f64(),
            Some(2.0)
        );
        let (code, _, body) = respond(&s, "GET /sessions/4 HTTP/1.1");
        assert_eq!(code, 200);
        assert_eq!(
            Json::parse(body.trim()).unwrap().get("step").unwrap().as_usize(),
            Some(7)
        );
        assert_eq!(respond(&s, "GET /sessions/99 HTTP/1.1").0, 404);
        assert_eq!(respond(&s, "GET /nope HTTP/1.1").0, 404);
        assert_eq!(respond(&s, "POST /metrics HTTP/1.1").0, 400);
        assert_eq!(respond(&s, "garbage").0, 400);
        assert_eq!(respond(&s, "").0, 400);
    }

    #[test]
    fn server_answers_over_tcp_and_shuts_down() {
        let mut server = MetricsServer::start(state(), 0).unwrap();
        let addr = server.addr();
        let fetch = |path: &str| -> String {
            let mut conn = TcpStream::connect(addr).unwrap();
            conn.write_all(format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").as_bytes())
                .unwrap();
            let mut out = String::new();
            conn.read_to_string(&mut out).unwrap();
            out
        };
        let metrics = fetch("/metrics");
        assert!(metrics.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(metrics.contains("dmlmc_steps_total 3"));
        assert!(fetch("/definitely-not-here").starts_with("HTTP/1.1 404"));
        server.shutdown();
        server.shutdown(); // idempotent
        // live registry: writes after start are visible... (server is
        // down now; this just pins that SharedRegistry stayed usable)
        assert_eq!(
            ServeState::new(SharedRegistry::new()).session_json(0),
            None
        );
    }
}
