//! Estimator-statistics telemetry: live per-level statistics of the
//! (delayed) MLMC gradient estimator, the data feed for the adaptive
//! MLMC open item (sample allocation from *measured* variance/cost
//! instead of offline theory — the allocations in arXiv:1912.11900 and
//! the multilevel-learning construction in arXiv:2102.08734 both need
//! exactly these inputs).
//!
//! [`EstimatorStats`] is owned by every [`Trainer`](crate::coordinator::Trainer)
//! (always on — a handful of Welford updates per refresh, no
//! allocation) and fed from `apply_level_results`, the one funnel both
//! solo steps and fleet ticks run through. Per level `l` it tracks:
//!
//! * **gradient-difference variance** — a [`Welford`] accumulator over
//!   the per-refresh observations `‖∇Δ_l‖²` (squared L2 norm of the
//!   chunk-averaged level-difference gradient). Its population variance
//!   is the `dmlmc_level_variance` gauge; its mean estimates the decay
//!   Assumption 2 postulates and adaptive allocation consumes.
//! * **measured cost** — a [`Welford`] over per-task busy seconds at
//!   that level (fed post-dispatch from [`TaskStat`](crate::exec::TaskStat)
//!   timings, so it reflects wall-clock, not the model).
//! * **staleness / refresh age** — `now - τ_l` from the refresh steps
//!   recorded here (identical to `GradientCache::staleness` by
//!   construction: both see every refresh).
//! * **sample / refresh counts** — cumulative samples and refreshes.
//!
//! [`EstimatorStats::publish`] writes everything as labeled gauges
//! (`level="l"`, plus `session="<id>"` when the fleet attributes a
//! session) into a [`Registry`] under a caller-held write guard, so a
//! concurrent `/metrics` scrape sees a consistent snapshot.

use crate::metrics::welford::Welford;

use super::metrics::Registry;

/// Per-level accumulators (see module docs for definitions).
#[derive(Debug, Clone, Default)]
pub struct LevelStats {
    /// Welford over per-refresh `‖∇Δ_l‖²` observations.
    pub value_norm2: Welford,
    /// Welford over per-task measured busy seconds at this level.
    pub cost_seconds: Welford,
    /// Cumulative samples drawn at this level.
    pub samples_total: u64,
    /// Refreshes (cache installs) of this level.
    pub refreshes_total: u64,
    /// Step of the most recent refresh (τ_l).
    pub last_refresh_step: u64,
}

/// A rendered snapshot of one level's statistics, for the
/// `/sessions/<id>` serving surface and tests.
#[derive(Debug, Clone)]
pub struct LevelSnapshot {
    pub level: usize,
    pub refreshes_total: u64,
    pub samples_total: u64,
    /// Population variance of the `‖∇Δ_l‖²` observations.
    pub variance: f64,
    /// Mean of the `‖∇Δ_l‖²` observations.
    pub mean_norm2: f64,
    /// Mean measured busy seconds per task at this level (0 until a
    /// pooled dispatch reports timings).
    pub cost_mean_s: f64,
    /// `now - τ_l` at snapshot time.
    pub staleness: u64,
    pub last_refresh_step: u64,
}

/// A consistent all-levels view of the estimator telemetry at one step —
/// the input type of [`crate::policy::AllocationPolicy::observe`]. Cheap
/// to build (one [`LevelSnapshot`] per level, no locking) and owning, so
/// a policy can be evaluated without borrowing the live accumulators.
#[derive(Debug, Clone)]
pub struct EstimatorSnapshot {
    /// Step the snapshot was taken at (staleness is relative to it).
    pub now_step: u64,
    pub levels: Vec<LevelSnapshot>,
}

impl EstimatorSnapshot {
    pub fn n_levels(&self) -> usize {
        self.levels.len()
    }
}

/// Live per-level statistics of the (delayed) MLMC estimator.
#[derive(Debug, Clone)]
pub struct EstimatorStats {
    levels: Vec<LevelStats>,
}

impl EstimatorStats {
    /// Stats over levels `0..n_levels` (`lmax + 1`).
    pub fn new(n_levels: usize) -> Self {
        EstimatorStats {
            levels: vec![LevelStats::default(); n_levels],
        }
    }

    pub fn n_levels(&self) -> usize {
        self.levels.len()
    }

    pub fn level(&self, l: usize) -> &LevelStats {
        &self.levels[l]
    }

    /// Record one refresh of level `level` at step `step`: `grad` is the
    /// chunk-averaged level-difference gradient the cache installs,
    /// `n_samples` the samples that produced it.
    pub fn record_refresh(&mut self, level: usize, step: u64, n_samples: usize, grad: &[f32]) {
        let norm2: f64 = grad.iter().map(|&g| g as f64 * g as f64).sum();
        let s = &mut self.levels[level];
        s.value_norm2.push(norm2);
        s.samples_total += n_samples as u64;
        s.refreshes_total += 1;
        s.last_refresh_step = step;
    }

    /// Record one task's measured busy seconds at `level` (fed from the
    /// dispatch report; levels beyond the layout are ignored — a naive
    /// session's finest-grid tasks carry no level-difference meaning).
    pub fn record_cost(&mut self, level: usize, busy_seconds: f64) {
        if let Some(s) = self.levels.get_mut(level) {
            s.cost_seconds.push(busy_seconds);
        }
    }

    /// Staleness of `level` at `now_step` (0 before any refresh).
    pub fn staleness(&self, level: usize, now_step: u64) -> u64 {
        let s = &self.levels[level];
        if s.refreshes_total == 0 {
            0
        } else {
            now_step.saturating_sub(s.last_refresh_step)
        }
    }

    /// Render every level at `now_step`.
    pub fn snapshot(&self, now_step: u64) -> Vec<LevelSnapshot> {
        (0..self.levels.len())
            .map(|l| {
                let s = &self.levels[l];
                LevelSnapshot {
                    level: l,
                    refreshes_total: s.refreshes_total,
                    samples_total: s.samples_total,
                    variance: s.value_norm2.variance(),
                    mean_norm2: s.value_norm2.mean(),
                    cost_mean_s: s.cost_seconds.mean(),
                    staleness: self.staleness(l, now_step),
                    last_refresh_step: s.last_refresh_step,
                }
            })
            .collect()
    }

    /// Owning snapshot of every level at `now_step` — what the
    /// allocation policies observe.
    pub fn observe(&self, now_step: u64) -> EstimatorSnapshot {
        EstimatorSnapshot {
            now_step,
            levels: self.snapshot(now_step),
        }
    }

    /// Publish every level as labeled gauges into `m` (idempotent:
    /// gauges are set, never incremented, so republishing each step is
    /// safe). `session` adds a `session="<id>"` label to every series —
    /// how the fleet keeps N sessions' statistics apart in one registry.
    pub fn publish(&self, m: &mut Registry, session: Option<&str>, now_step: u64) {
        m.describe(
            "dmlmc_level_variance",
            "Population variance of per-refresh squared gradient-difference norms per level.",
        );
        m.describe(
            "dmlmc_level_grad_norm2_mean",
            "Mean per-refresh squared gradient-difference norm per level.",
        );
        m.describe(
            "dmlmc_level_cost_seconds_mean",
            "Mean measured busy seconds per task per level.",
        );
        m.describe("dmlmc_level_samples_total", "Cumulative samples per level.");
        m.describe(
            "dmlmc_level_refreshes_total",
            "Cumulative cache refreshes per level.",
        );
        m.describe(
            "dmlmc_level_staleness_steps",
            "Steps since the level's gradient component was refreshed (tau_l age).",
        );
        for snap in self.snapshot(now_step) {
            let level = snap.level.to_string();
            let mut labels: Vec<(&'static str, &str)> = vec![("level", &level)];
            if let Some(sid) = session {
                labels.push(("session", sid));
            }
            m.set_gauge_with("dmlmc_level_variance", &labels, snap.variance);
            m.set_gauge_with("dmlmc_level_grad_norm2_mean", &labels, snap.mean_norm2);
            m.set_gauge_with("dmlmc_level_cost_seconds_mean", &labels, snap.cost_mean_s);
            m.set_gauge_with(
                "dmlmc_level_samples_total",
                &labels,
                snap.samples_total as f64,
            );
            m.set_gauge_with(
                "dmlmc_level_refreshes_total",
                &labels,
                snap.refreshes_total as f64,
            );
            m.set_gauge_with(
                "dmlmc_level_staleness_steps",
                &labels,
                snap.staleness as f64,
            );
        }
    }
}

/// Publish the active allocation decision as labeled gauges:
/// `dmlmc_alloc_n{level}` (per-level sample count) and
/// `dmlmc_refresh_period{level}` (delayed-refresh period in steps).
/// Takes plain slices so the [`crate::policy`] decision types stay out
/// of the observability layer; `session` attributes the series in a
/// fleet registry exactly like [`EstimatorStats::publish`].
pub fn publish_decision(
    m: &mut Registry,
    session: Option<&str>,
    n_per_level: &[usize],
    periods: &[u64],
) {
    m.describe(
        "dmlmc_alloc_n",
        "Active per-level sample allocation N_l (policy decision).",
    );
    m.describe(
        "dmlmc_refresh_period",
        "Active delayed-refresh period in steps per level (policy decision).",
    );
    for (l, &nl) in n_per_level.iter().enumerate() {
        let level = l.to_string();
        let mut labels: Vec<(&'static str, &str)> = vec![("level", &level)];
        if let Some(sid) = session {
            labels.push(("session", sid));
        }
        m.set_gauge_with("dmlmc_alloc_n", &labels, nl as f64);
        if let Some(&p) = periods.get(l) {
            m.set_gauge_with("dmlmc_refresh_period", &labels, p as f64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_gauges_match_direct_computation() {
        let mut est = EstimatorStats::new(2);
        let grads = [vec![1.0f32, 2.0], vec![0.5, 0.5], vec![2.0, 0.0]];
        for (i, g) in grads.iter().enumerate() {
            est.record_refresh(0, i as u64, 8, g);
        }
        let mut direct = Welford::new();
        for g in &grads {
            direct.push(g.iter().map(|&x| x as f64 * x as f64).sum());
        }
        let s = est.level(0);
        assert_eq!(s.refreshes_total, 3);
        assert_eq!(s.samples_total, 24);
        assert_eq!(s.value_norm2.mean(), direct.mean());
        assert_eq!(s.value_norm2.variance(), direct.variance());
        // level 1 never refreshed
        assert_eq!(est.level(1).refreshes_total, 0);
        assert_eq!(est.staleness(1, 10), 0);
        assert_eq!(est.staleness(0, 10), 8);
    }

    #[test]
    fn publish_writes_labeled_gauges_with_and_without_session() {
        let mut est = EstimatorStats::new(1);
        est.record_refresh(0, 3, 16, &[3.0, 4.0]); // norm2 = 25
        est.record_cost(0, 0.5);
        est.record_cost(0, 1.5);
        let mut m = Registry::new();
        est.publish(&mut m, None, 5);
        assert_eq!(m.gauge_with("dmlmc_level_variance", &[("level", "0")]), Some(0.0));
        assert_eq!(
            m.gauge_with("dmlmc_level_grad_norm2_mean", &[("level", "0")]),
            Some(25.0)
        );
        assert_eq!(
            m.gauge_with("dmlmc_level_cost_seconds_mean", &[("level", "0")]),
            Some(1.0)
        );
        assert_eq!(
            m.gauge_with("dmlmc_level_staleness_steps", &[("level", "0")]),
            Some(2.0)
        );
        est.publish(&mut m, Some("7"), 5);
        assert_eq!(
            m.gauge_with(
                "dmlmc_level_samples_total",
                &[("level", "0"), ("session", "7")]
            ),
            Some(16.0)
        );
        let text = m.render_prometheus();
        assert!(text.contains("# HELP dmlmc_level_variance "));
        assert!(text.contains("dmlmc_level_variance{level=\"0\"} 0"));
        assert!(text.contains("dmlmc_level_variance{level=\"0\",session=\"7\"} 0"));
    }

    #[test]
    fn observe_wraps_the_per_level_snapshot() {
        let mut est = EstimatorStats::new(3);
        est.record_refresh(1, 4, 8, &[1.0, 0.0]);
        let snap = est.observe(6);
        assert_eq!(snap.now_step, 6);
        assert_eq!(snap.n_levels(), 3);
        assert_eq!(snap.levels[1].refreshes_total, 1);
        assert_eq!(snap.levels[1].staleness, 2);
        assert_eq!(snap.levels[0].refreshes_total, 0);
    }

    #[test]
    fn publish_decision_writes_alloc_and_period_gauges() {
        let mut m = Registry::new();
        publish_decision(&mut m, None, &[40, 16, 6], &[1, 2, 4]);
        assert_eq!(m.gauge_with("dmlmc_alloc_n", &[("level", "0")]), Some(40.0));
        assert_eq!(m.gauge_with("dmlmc_alloc_n", &[("level", "2")]), Some(6.0));
        assert_eq!(
            m.gauge_with("dmlmc_refresh_period", &[("level", "2")]),
            Some(4.0)
        );
        publish_decision(&mut m, Some("3"), &[10], &[1]);
        assert_eq!(
            m.gauge_with("dmlmc_alloc_n", &[("level", "0"), ("session", "3")]),
            Some(10.0)
        );
        let text = m.render_prometheus();
        assert!(text.contains("# HELP dmlmc_alloc_n "));
        assert!(text.contains("dmlmc_refresh_period{level=\"1\"} 2"));
    }

    #[test]
    fn cost_ignores_levels_outside_the_layout() {
        let mut est = EstimatorStats::new(2);
        est.record_cost(5, 1.0); // naive finest-grid task on a wider lmax
        assert_eq!(est.level(0).cost_seconds.count(), 0);
        assert_eq!(est.level(1).cost_seconds.count(), 0);
    }
}
