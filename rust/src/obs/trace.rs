//! The [`Recorder`]: one run's span rings + metrics registry, and the
//! [`TraceSink`] that drains them into a run directory as Chrome
//! trace-event JSON (`trace.json`) and a Prometheus text dump
//! (`metrics.prom`).
//!
//! # Cost model
//!
//! The pool's hot path records **nothing new** when tracing is on: the
//! two `Instant` reads per task that become span endpoints already
//! existed as [`TaskStat`] telemetry (every worker deposits its
//! per-task timings whether or not anyone looks). The recorder
//! materializes spans *coordinator-side*, after the dispatch returns,
//! by ingesting the [`StepExecReport`] into per-worker rings — no
//! locks, allocation or I/O are added to the worker threads, which is
//! why `repro trace` can assert a tight traced-vs-untraced makespan
//! bound.

use std::sync::{Arc, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::{Duration, Instant};

use crate::exec::{StepExecReport, TaskStat};
use crate::metrics::RunArtifacts;
use crate::util::json::{obj, Json};

use super::metrics::Registry;
use super::span::{Span, SpanRing, Track};

/// A thread-safe, shareable handle to a metrics [`Registry`].
///
/// The recorder owns one and mutates it through short-lived write
/// guards; [`Recorder::shared_metrics`] hands out clones so a scrape
/// thread ([`super::serve::MetricsServer`]) can render the exposition
/// concurrently with training. Lock poisoning is recovered (a panicked
/// writer never takes the scrape surface down with it).
#[derive(Debug, Clone, Default)]
pub struct SharedRegistry(Arc<RwLock<Registry>>);

impl SharedRegistry {
    pub fn new() -> Self {
        SharedRegistry::default()
    }

    pub fn read(&self) -> RwLockReadGuard<'_, Registry> {
        self.0.read().unwrap_or_else(|e| e.into_inner())
    }

    pub fn write(&self) -> RwLockWriteGuard<'_, Registry> {
        self.0.write().unwrap_or_else(|e| e.into_inner())
    }

    /// Render the Prometheus text exposition under a read guard.
    pub fn render_prometheus(&self) -> String {
        self.read().render_prometheus()
    }
}

/// Default per-track ring capacity: enough for every span of any bench
/// or CI run; long daemon-style runs wrap and count drops instead of
/// growing without bound.
pub const DEFAULT_RING_CAPACITY: usize = 65_536;

/// Per-reduction-group metadata for one dispatch ingest: the MLMC level
/// the group ran at and, for a multiplexed fleet dispatch, the session
/// that owns it.
#[derive(Debug, Clone, Copy)]
pub struct GroupMeta {
    pub level: usize,
    pub session: Option<u64>,
}

/// One run's trace + metrics state: a span ring per stable worker index,
/// a coordinator ring, and the metrics [`Registry`]. All offsets are
/// measured from the run epoch captured at construction.
#[derive(Debug)]
pub struct Recorder {
    epoch: Instant,
    worker_rings: Vec<SpanRing>,
    coord_ring: SpanRing,
    registry: SharedRegistry,
}

impl Recorder {
    pub fn new(workers: usize) -> Self {
        Self::with_capacity(workers, DEFAULT_RING_CAPACITY)
    }

    pub fn with_capacity(workers: usize, cap: usize) -> Self {
        let registry = SharedRegistry::new();
        {
            let mut m = registry.write();
            m.describe("dmlmc_dispatches_total", "Pool dispatches executed.");
            m.describe(
                "dmlmc_tasks_dispatched_total",
                "Chunk tasks executed across all dispatches.",
            );
            m.describe(
                "dmlmc_step_makespan_seconds",
                "Measured wall-clock makespan per dispatch.",
            );
            m.describe(
                "dmlmc_dispatch_overhead_seconds",
                "Dispatch makespan minus max worker busy time.",
            );
            m.describe(
                "obs_spans_dropped_total",
                "Spans evicted from bounded trace rings (per track and total).",
            );
        }
        let mut rec = Recorder {
            epoch: Instant::now(),
            worker_rings: (0..workers).map(|_| SpanRing::new(cap)).collect(),
            coord_ring: SpanRing::new(cap),
            registry,
        };
        rec.publish_drop_gauges(); // families exist (at 0) from the first scrape
        rec
    }

    /// Offset of "now" from the run epoch — capture one before a phase
    /// to use as that phase's span start.
    pub fn now(&self) -> Duration {
        self.epoch.elapsed()
    }

    pub fn workers(&self) -> usize {
        self.worker_rings.len()
    }

    /// Read access to the metrics registry. Returns a read guard
    /// (derefs to [`Registry`]); rebind it to a local before borrowing
    /// out of it (`let m = rec.metrics(); let h = m.histogram(..)`).
    pub fn metrics(&self) -> RwLockReadGuard<'_, Registry> {
        self.registry.read()
    }

    /// Write access to the metrics registry (short-lived write guard).
    pub fn metrics_mut(&mut self) -> RwLockWriteGuard<'_, Registry> {
        self.registry.write()
    }

    /// A shareable handle to the registry for concurrent scraping —
    /// clone it into the HTTP server thread ([`super::serve`]); the
    /// recorder keeps publishing through the same handle.
    pub fn shared_metrics(&self) -> SharedRegistry {
        self.registry.clone()
    }

    /// Record a coordinator-track span that started at `start` and ends
    /// now (`step`, `tick` — phases bracketed by the caller).
    pub fn record(
        &mut self,
        name: &'static str,
        start: Duration,
        args: Vec<(&'static str, f64)>,
    ) {
        let dur = self.now().saturating_sub(start);
        self.record_span(name, start, dur, args);
    }

    /// Record a coordinator-track span with an explicit duration
    /// (`session` spans reconstructed at session end).
    pub fn record_span(
        &mut self,
        name: &'static str,
        start: Duration,
        dur: Duration,
        args: Vec<(&'static str, f64)>,
    ) {
        self.coord_ring.push(Span {
            name,
            track: Track::Coordinator,
            start,
            dur,
            args,
        });
        if self.coord_ring.dropped() > 0 {
            self.publish_drop_gauges();
        }
    }

    /// Publish ring-eviction counts as `obs_spans_dropped_total` gauges
    /// (one per track plus the unlabeled total), so silently truncated
    /// traces are visible in every scrape and in `metrics.prom`.
    fn publish_drop_gauges(&mut self) {
        let coord = self.coord_ring.dropped();
        let per_worker: Vec<usize> = self.worker_rings.iter().map(|r| r.dropped()).collect();
        let total = coord + per_worker.iter().sum::<usize>();
        let mut m = self.registry.write();
        m.set_gauge("obs_spans_dropped_total", total as f64);
        m.set_gauge_with(
            "obs_spans_dropped_total",
            &[("track", "coordinator")],
            coord as f64,
        );
        for (w, dropped) in per_worker.iter().enumerate() {
            let track = format!("worker-{w}");
            m.set_gauge_with(
                "obs_spans_dropped_total",
                &[("track", &track)],
                *dropped as f64,
            );
        }
    }

    /// Ingest one dispatch: a `dispatch` span on the coordinator track
    /// (spanning the measured makespan), one `task` span per executed
    /// task on its worker's track, and the dispatch counters/histograms.
    ///
    /// `start` is the coordinator-track offset at which the dispatch
    /// began (capture [`Self::now`] right before calling the pool);
    /// per-task offsets from the report's dispatch epoch are rebased
    /// onto it. `groups[g]` describes reduction group `g`. The chunk
    /// attribute is recovered from task order: within a group, global
    /// task indices ascend with chunk index (how the dispatcher and the
    /// fleet build task slices).
    pub fn ingest_dispatch(
        &mut self,
        report: &StepExecReport,
        start: Duration,
        groups: &[GroupMeta],
    ) {
        {
            let mut m = self.registry.write();
            m.inc("dmlmc_dispatches_total", 1);
            m.inc("dmlmc_tasks_dispatched_total", report.n_tasks as u64);
            m.observe("dmlmc_step_makespan_seconds", report.makespan.as_secs_f64());
            m.observe(
                "dmlmc_dispatch_overhead_seconds",
                report.dispatch_overhead().as_secs_f64(),
            );
        }
        self.record_span(
            "dispatch",
            start,
            report.makespan,
            vec![
                ("n_tasks", report.n_tasks as f64),
                ("n_groups", groups.len() as f64),
                ("workers", report.workers.len() as f64),
            ],
        );
        let mut chunk_within_group = vec![0usize; groups.len()];
        for t in &report.per_task {
            let span = self.task_span(t, start, groups, &mut chunk_within_group);
            if t.worker >= self.worker_rings.len() {
                // A report from a wider pool than the recorder was sized
                // for: grow, mirroring ExecStats::record.
                let cap = self.coord_ring.capacity();
                self.worker_rings
                    .resize_with(t.worker + 1, || SpanRing::new(cap));
            }
            self.worker_rings[t.worker].push(span);
        }
        self.publish_drop_gauges();
    }

    fn task_span(
        &self,
        t: &TaskStat,
        dispatch_start: Duration,
        groups: &[GroupMeta],
        chunk_within_group: &mut [usize],
    ) -> Span {
        let mut args = vec![("group", t.group as f64)];
        if let Some(meta) = groups.get(t.group) {
            args.push(("level", meta.level as f64));
            if let Some(session) = meta.session {
                args.push(("session", session as f64));
            }
        }
        if let Some(c) = chunk_within_group.get_mut(t.group) {
            args.push(("chunk", *c as f64));
            *c += 1;
        }
        Span {
            name: "task",
            track: Track::Worker(t.worker),
            start: dispatch_start + t.start,
            dur: t.busy,
            args,
        }
    }

    pub fn coordinator_spans(&self) -> &SpanRing {
        &self.coord_ring
    }

    /// The ring of one worker track (empty ring reference semantics:
    /// panics for an index the recorder never saw — check
    /// [`Self::workers`] first).
    pub fn worker_spans(&self, worker: usize) -> &SpanRing {
        &self.worker_rings[worker]
    }

    /// Retained span count per worker track (index == worker).
    pub fn worker_span_counts(&self) -> Vec<usize> {
        self.worker_rings.iter().map(|r| r.len()).collect()
    }

    /// Spans evicted across all rings (0 unless a ring overflowed).
    pub fn dropped_total(&self) -> usize {
        self.coord_ring.dropped()
            + self.worker_rings.iter().map(|r| r.dropped()).sum::<usize>()
    }

    /// The whole trace as a Chrome trace-event JSON document (the
    /// object form: `{"traceEvents": [...]}`), loadable in Perfetto /
    /// `chrome://tracing`. Complete (`ph: "X"`) events, timestamps in
    /// microseconds from the run epoch; `tid` 0 is the coordinator
    /// track, `tid` w+1 is worker w — named via `thread_name` metadata
    /// events.
    pub fn chrome_trace(&self) -> Json {
        let mut events: Vec<Json> = Vec::new();
        events.push(obj(vec![
            ("name", Json::Str("process_name".into())),
            ("ph", Json::Str("M".into())),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(0.0)),
            ("args", obj(vec![("name", Json::Str("dmlmc".into()))])),
        ]));
        let thread_name = |tid: usize, name: String| {
            obj(vec![
                ("name", Json::Str("thread_name".into())),
                ("ph", Json::Str("M".into())),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(tid as f64)),
                ("args", obj(vec![("name", Json::Str(name))])),
            ])
        };
        events.push(thread_name(0, "coordinator".into()));
        for worker in 0..self.worker_rings.len() {
            events.push(thread_name(worker + 1, format!("worker-{worker}")));
        }
        let spans = self
            .coord_ring
            .iter()
            .chain(self.worker_rings.iter().flat_map(|r| r.iter()));
        for span in spans {
            let tid = match span.track {
                Track::Coordinator => 0,
                Track::Worker(w) => w + 1,
            };
            let args: Vec<(&str, Json)> = span
                .args
                .iter()
                .map(|&(k, v)| (k, Json::Num(v)))
                .collect();
            events.push(obj(vec![
                ("name", Json::Str(span.name.into())),
                ("ph", Json::Str("X".into())),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(tid as f64)),
                ("ts", Json::Num(span.start.as_secs_f64() * 1e6)),
                ("dur", Json::Num(span.dur.as_secs_f64() * 1e6)),
                ("args", obj(args)),
            ]));
        }
        obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::Str("ms".into())),
            ("droppedSpans", Json::Num(self.dropped_total() as f64)),
        ])
    }
}

/// Drains a [`Recorder`] into a run directory: `trace.json` (Chrome
/// trace-event JSON) and `metrics.prom` (Prometheus text exposition).
#[derive(Debug)]
pub struct TraceSink<'a> {
    artifacts: &'a RunArtifacts,
}

impl<'a> TraceSink<'a> {
    pub fn new(artifacts: &'a RunArtifacts) -> Self {
        TraceSink { artifacts }
    }

    /// Write both artifacts; returns `(trace.json path, metrics.prom
    /// path)`.
    pub fn write(
        &self,
        recorder: &Recorder,
    ) -> std::io::Result<(std::path::PathBuf, std::path::PathBuf)> {
        let trace = self
            .artifacts
            .write_text("trace.json", &format!("{}\n", recorder.chrome_trace()))?;
        let prom = self
            .artifacts
            .write_text("metrics.prom", &recorder.metrics().render_prometheus())?;
        Ok((trace, prom))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::WorkerStat;

    fn report() -> StepExecReport {
        StepExecReport {
            workers: vec![
                WorkerStat { worker: 0, busy: Duration::from_millis(20), tasks: 2, core: None },
                WorkerStat { worker: 1, busy: Duration::from_millis(10), tasks: 1, core: None },
            ],
            makespan: Duration::from_millis(25),
            n_tasks: 3,
            per_task: vec![
                TaskStat {
                    task: 0,
                    group: 0,
                    worker: 0,
                    start: Duration::ZERO,
                    busy: Duration::from_millis(10),
                },
                TaskStat {
                    task: 1,
                    group: 0,
                    worker: 1,
                    start: Duration::from_millis(2),
                    busy: Duration::from_millis(10),
                },
                TaskStat {
                    task: 2,
                    group: 1,
                    worker: 0,
                    start: Duration::from_millis(12),
                    busy: Duration::from_millis(10),
                },
            ],
        }
    }

    fn groups() -> Vec<GroupMeta> {
        vec![
            GroupMeta { level: 0, session: Some(7) },
            GroupMeta { level: 2, session: Some(7) },
        ]
    }

    #[test]
    fn ingest_fans_tasks_out_to_worker_tracks() {
        let mut rec = Recorder::new(2);
        rec.ingest_dispatch(&report(), Duration::from_millis(100), &groups());
        assert_eq!(rec.worker_span_counts(), vec![2, 1]);
        assert_eq!(rec.coordinator_spans().len(), 1);
        let dispatch = rec.coordinator_spans().iter().next().unwrap();
        assert_eq!(dispatch.name, "dispatch");
        assert_eq!(dispatch.start, Duration::from_millis(100));
        assert_eq!(dispatch.dur, Duration::from_millis(25));
        // task spans rebased onto the dispatch start, attrs in place
        let w0: Vec<&Span> = rec.worker_spans(0).iter().collect();
        assert_eq!(w0[0].start, Duration::from_millis(100));
        assert_eq!(w0[1].start, Duration::from_millis(112));
        let attr = |s: &Span, k: &str| {
            s.args.iter().find(|(n, _)| *n == k).map(|&(_, v)| v)
        };
        assert_eq!(attr(w0[0], "level"), Some(0.0));
        assert_eq!(attr(w0[0], "chunk"), Some(0.0));
        assert_eq!(attr(w0[1], "level"), Some(2.0));
        assert_eq!(attr(w0[1], "chunk"), Some(0.0));
        assert_eq!(attr(w0[1], "session"), Some(7.0));
        // second task of group 0 (on worker 1) is chunk 1
        let w1: Vec<&Span> = rec.worker_spans(1).iter().collect();
        assert_eq!(attr(w1[0], "chunk"), Some(1.0));
        // counters + histograms filled
        assert_eq!(rec.metrics().counter("dmlmc_dispatches_total"), 1);
        assert_eq!(rec.metrics().counter("dmlmc_tasks_dispatched_total"), 3);
        let m = rec.metrics();
        let h = m.histogram("dmlmc_step_makespan_seconds").unwrap();
        assert_eq!(h.count(), 1);
        assert!((h.max() - 0.025).abs() < 1e-12);
        // drop gauges exist at 0 from the very first scrape
        assert_eq!(m.gauge("obs_spans_dropped_total"), Some(0.0));
        assert_eq!(
            m.gauge_with("obs_spans_dropped_total", &[("track", "worker-1")]),
            Some(0.0)
        );
    }

    #[test]
    fn ring_overflow_surfaces_in_drop_gauges_and_exposition() {
        let mut rec = Recorder::with_capacity(1, 2);
        for _ in 0..3 {
            rec.ingest_dispatch(&report(), Duration::ZERO, &groups());
        }
        // worker 0 saw 6 task spans into a 2-slot ring -> 4 drops
        assert!(rec.dropped_total() > 0);
        let shared = rec.shared_metrics();
        let m = shared.read();
        assert_eq!(
            m.gauge("obs_spans_dropped_total"),
            Some(rec.dropped_total() as f64)
        );
        assert_eq!(
            m.gauge_with("obs_spans_dropped_total", &[("track", "worker-0")]),
            Some(rec.worker_spans(0).dropped() as f64)
        );
        let text = m.render_prometheus();
        assert!(text.contains("obs_spans_dropped_total{track=\"worker-0\"}"));
    }

    #[test]
    fn shared_registry_serves_reads_across_threads() {
        let mut rec = Recorder::new(2);
        rec.ingest_dispatch(&report(), Duration::ZERO, &groups());
        let shared = rec.shared_metrics();
        let t = std::thread::spawn(move || shared.render_prometheus());
        let text = t.join().unwrap();
        assert!(text.contains("dmlmc_tasks_dispatched_total 3"));
        // the recorder keeps publishing through the same handle
        rec.metrics_mut().inc("dmlmc_dispatches_total", 1);
        assert_eq!(rec.metrics().counter("dmlmc_dispatches_total"), 2);
    }

    #[test]
    fn ingest_reconciles_span_durations_with_worker_busy() {
        let mut rec = Recorder::new(2);
        let r = report();
        rec.ingest_dispatch(&r, Duration::ZERO, &groups());
        for w in &r.workers {
            let span_sum: Duration =
                rec.worker_spans(w.worker).iter().map(|s| s.dur).sum();
            assert_eq!(span_sum, w.busy, "worker {} rollup drifted", w.worker);
        }
    }

    #[test]
    fn ingest_grows_for_unknown_worker_index() {
        let mut rec = Recorder::new(1);
        rec.ingest_dispatch(&report(), Duration::ZERO, &groups());
        assert_eq!(rec.workers(), 2);
        assert_eq!(rec.worker_span_counts(), vec![2, 1]);
    }

    #[test]
    fn chrome_trace_is_valid_and_tracks_are_named() {
        let mut rec = Recorder::new(2);
        let step_start = rec.now();
        rec.ingest_dispatch(&report(), step_start, &groups());
        rec.record("step", step_start, vec![("step", 0.0)]);
        let doc = rec.chrome_trace();
        // round-trips through the strict parser
        let text = doc.to_string();
        let back = Json::parse(&text).unwrap();
        let events = back.get("traceEvents").unwrap().as_arr().unwrap();
        // 3 metadata (process + coordinator + 2 workers = 4) ...
        let metas: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("M"))
            .collect();
        assert_eq!(metas.len(), 4);
        let names: Vec<&str> = metas
            .iter()
            .filter_map(|e| e.get("args").unwrap().get("name").unwrap().as_str())
            .collect();
        assert!(names.contains(&"coordinator"));
        assert!(names.contains(&"worker-0"));
        assert!(names.contains(&"worker-1"));
        // ... plus complete spans: 1 dispatch + 3 tasks + 1 step
        let complete: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("X"))
            .collect();
        assert_eq!(complete.len(), 5);
        for e in &complete {
            assert!(e.get("ts").unwrap().as_f64().is_some());
            assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
            assert!(e.get("tid").unwrap().as_usize().is_some());
        }
        // every worker track carries at least one task span
        for tid in [1usize, 2] {
            assert!(
                complete.iter().any(|e| {
                    e.get("tid").unwrap().as_usize() == Some(tid)
                        && e.get("name").unwrap().as_str() == Some("task")
                }),
                "no task span on worker track tid={tid}"
            );
        }
        assert_eq!(back.get("droppedSpans").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn sink_writes_trace_and_metrics() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static SEQ: AtomicUsize = AtomicUsize::new(0);
        let out = std::env::temp_dir().join(format!(
            "dmlmc_obs_test_{}_{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let arts = RunArtifacts::create(&out, "obs").unwrap();
        let mut rec = Recorder::new(1);
        rec.ingest_dispatch(&report(), Duration::ZERO, &groups());
        let (trace, prom) = TraceSink::new(&arts).write(&rec).unwrap();
        let text = std::fs::read_to_string(&trace).unwrap();
        assert!(Json::parse(text.trim()).is_ok());
        let prom_text = std::fs::read_to_string(&prom).unwrap();
        assert!(prom_text.contains("dmlmc_tasks_dispatched_total 3"));
        std::fs::remove_dir_all(&out).unwrap();
    }
}
