//! The metrics registry: named counters, gauges and histograms with a
//! Prometheus text exposition — the scrape surface a future daemon mode
//! (`repro serve`) will expose over HTTP; today it is dumped per run as
//! `metrics.prom` next to `trace.json`.
//!
//! Histogram summaries (p50/p95/max) use the same nearest-rank
//! [`percentile`](crate::exec::stats::percentile) definition as the
//! `runs.jsonl` exec block, so "p95 makespan" means the same thing in
//! both artifacts.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::exec::stats::percentile;

/// A recording histogram: keeps raw observations (bounded use cases —
/// one observation per dispatch/step), summarized at exposition time.
#[derive(Debug, Clone, Default)]
pub struct Histogram {
    values: Vec<f64>,
}

impl Histogram {
    pub fn observe(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn count(&self) -> usize {
        self.values.len()
    }

    pub fn sum(&self) -> f64 {
        self.values.iter().sum()
    }

    /// Nearest-rank percentile, `q` in `[0, 1]`; 0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        percentile(&self.values, q)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().fold(0.0, |a: f64, &b| a.max(b))
    }
}

/// Named counters / gauges / histograms. Metric names follow Prometheus
/// conventions (`dmlmc_tasks_dispatched_total`,
/// `dmlmc_step_makespan_seconds`); the registry itself is
/// convention-free.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, f64>,
    histograms: BTreeMap<&'static str, Histogram>,
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    /// Add `by` to the named counter (created at 0 on first touch).
    pub fn inc(&mut self, name: &'static str, by: u64) {
        *self.counters.entry(name).or_insert(0) += by;
    }

    /// Set the named gauge to `v` (last write wins).
    pub fn set_gauge(&mut self, name: &'static str, v: f64) {
        self.gauges.insert(name, v);
    }

    /// Record one observation into the named histogram.
    pub fn observe(&mut self, name: &'static str, v: f64) {
        self.histograms.entry(name).or_default().observe(v);
    }

    /// Current counter value (0 if never incremented).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    /// Prometheus text exposition (format version 0.0.4): counters and
    /// gauges verbatim, histograms as `summary` families with
    /// p50/p95/max quantiles plus `_sum`/`_count`. Keys render in
    /// BTreeMap order, so the dump is deterministic.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, v) in &self.counters {
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {v}");
        }
        for (name, v) in &self.gauges {
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = writeln!(out, "{name} {v}");
        }
        for (name, h) in &self.histograms {
            let _ = writeln!(out, "# TYPE {name} summary");
            let _ = writeln!(out, "{name}{{quantile=\"0.5\"}} {}", h.quantile(0.5));
            let _ = writeln!(out, "{name}{{quantile=\"0.95\"}} {}", h.quantile(0.95));
            let _ = writeln!(out, "{name}{{quantile=\"1\"}} {}", h.max());
            let _ = writeln!(out, "{name}_sum {}", h.sum());
            let _ = writeln!(out, "{name}_count {}", h.count());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_default_to_zero() {
        let mut r = Registry::new();
        assert_eq!(r.counter("dmlmc_tasks_dispatched_total"), 0);
        r.inc("dmlmc_tasks_dispatched_total", 4);
        r.inc("dmlmc_tasks_dispatched_total", 3);
        assert_eq!(r.counter("dmlmc_tasks_dispatched_total"), 7);
    }

    #[test]
    fn gauges_take_last_write() {
        let mut r = Registry::new();
        assert_eq!(r.gauge("dmlmc_pool_workers"), None);
        r.set_gauge("dmlmc_pool_workers", 4.0);
        r.set_gauge("dmlmc_pool_workers", 2.0);
        assert_eq!(r.gauge("dmlmc_pool_workers"), Some(2.0));
    }

    #[test]
    fn histogram_summaries_match_nearest_rank() {
        let mut r = Registry::new();
        for v in [4.0, 1.0, 3.0, 2.0, 5.0] {
            r.observe("dmlmc_step_makespan_seconds", v);
        }
        let h = r.histogram("dmlmc_step_makespan_seconds").unwrap();
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 15.0);
        assert_eq!(h.quantile(0.5), 3.0);
        assert_eq!(h.quantile(0.95), 5.0);
        assert_eq!(h.max(), 5.0);
    }

    #[test]
    fn prometheus_exposition_covers_every_family() {
        let mut r = Registry::new();
        r.inc("dmlmc_steps_total", 2);
        r.set_gauge("dmlmc_pool_workers", 4.0);
        r.observe("dmlmc_step_makespan_seconds", 0.25);
        let text = r.render_prometheus();
        assert!(text.contains("# TYPE dmlmc_steps_total counter"));
        assert!(text.contains("dmlmc_steps_total 2"));
        assert!(text.contains("# TYPE dmlmc_pool_workers gauge"));
        assert!(text.contains("dmlmc_pool_workers 4"));
        assert!(text.contains("# TYPE dmlmc_step_makespan_seconds summary"));
        assert!(text.contains("dmlmc_step_makespan_seconds{quantile=\"0.5\"} 0.25"));
        assert!(text.contains("dmlmc_step_makespan_seconds_count 1"));
        // every line is `# ...` or `name[{labels}] value`
        for line in text.lines() {
            assert!(
                line.starts_with('#') || line.split_whitespace().count() == 2,
                "malformed exposition line: {line}"
            );
        }
    }
}
