//! The metrics registry: named counters, gauges and histograms with a
//! Prometheus text exposition — the scrape surface `repro serve` exposes
//! over HTTP ([`super::serve`]) and which every traced run dumps as
//! `metrics.prom` next to `trace.json`.
//!
//! Every family supports **labeled series**: the unlabeled API
//! (`inc`/`set_gauge`/`observe`) writes the empty-label series, and the
//! `*_with` variants address a series by `(key, value)` label pairs
//! (sorted internally, so label order never matters). Label values are
//! escaped per the Prometheus text format (`\\`, `\"`, `\n`), and
//! [`Registry::describe`] attaches `# HELP` text to a family.
//!
//! Histogram summaries (p50/p95/max) use the same nearest-rank
//! [`percentile`](crate::exec::stats::percentile) definition as the
//! `runs.jsonl` exec block, so "p95 makespan" means the same thing in
//! both artifacts.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::exec::stats::percentile;

/// A recording histogram: keeps raw observations (bounded use cases —
/// one observation per dispatch/step), summarized at exposition time.
#[derive(Debug, Clone, Default)]
pub struct Histogram {
    values: Vec<f64>,
}

impl Histogram {
    pub fn observe(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn count(&self) -> usize {
        self.values.len()
    }

    pub fn sum(&self) -> f64 {
        self.values.iter().sum()
    }

    /// Nearest-rank percentile, `q` in `[0, 1]`; 0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        percentile(&self.values, q)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().fold(0.0, |a: f64, &b| a.max(b))
    }
}

/// A series address within a family: sorted `(label key, label value)`
/// pairs. Empty = the unlabeled series.
type LabelSet = Vec<(&'static str, String)>;

fn label_set(labels: &[(&'static str, &str)]) -> LabelSet {
    let mut set: LabelSet = labels.iter().map(|&(k, v)| (k, v.to_string())).collect();
    set.sort();
    set
}

/// Escape a label value per the Prometheus text format: backslash,
/// double-quote and line-feed become `\\`, `\"` and `\n`.
fn escape_label_value(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Escape `# HELP` text: backslash and line-feed only (quotes are legal).
fn escape_help(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Render `{k="v",...}` for a non-empty label set (empty string otherwise),
/// with an optional extra label appended (used for summary quantiles).
fn render_labels(labels: &LabelSet, extra: Option<(&str, &str)>) -> String {
    if labels.is_empty() && extra.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label_value(v)))
        .collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{}\"", escape_label_value(v)));
    }
    format!("{{{}}}", parts.join(","))
}

/// Named counters / gauges / histograms, each a family of labeled
/// series. Metric names follow Prometheus conventions
/// (`dmlmc_tasks_dispatched_total`, `dmlmc_step_makespan_seconds`); the
/// registry itself is convention-free.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    counters: BTreeMap<&'static str, BTreeMap<LabelSet, u64>>,
    gauges: BTreeMap<&'static str, BTreeMap<LabelSet, f64>>,
    histograms: BTreeMap<&'static str, BTreeMap<LabelSet, Histogram>>,
    help: BTreeMap<&'static str, &'static str>,
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    /// Attach `# HELP` text to a family (rendered before its `# TYPE`).
    pub fn describe(&mut self, name: &'static str, help: &'static str) {
        self.help.insert(name, help);
    }

    /// Add `by` to the named counter (created at 0 on first touch).
    pub fn inc(&mut self, name: &'static str, by: u64) {
        self.inc_with(name, &[], by);
    }

    /// Add `by` to the labeled counter series.
    pub fn inc_with(&mut self, name: &'static str, labels: &[(&'static str, &str)], by: u64) {
        *self
            .counters
            .entry(name)
            .or_default()
            .entry(label_set(labels))
            .or_insert(0) += by;
    }

    /// Set the named gauge to `v` (last write wins).
    pub fn set_gauge(&mut self, name: &'static str, v: f64) {
        self.set_gauge_with(name, &[], v);
    }

    /// Set the labeled gauge series to `v` (last write wins).
    pub fn set_gauge_with(&mut self, name: &'static str, labels: &[(&'static str, &str)], v: f64) {
        self.gauges
            .entry(name)
            .or_default()
            .insert(label_set(labels), v);
    }

    /// Record one observation into the named histogram.
    pub fn observe(&mut self, name: &'static str, v: f64) {
        self.observe_with(name, &[], v);
    }

    /// Record one observation into the labeled histogram series.
    pub fn observe_with(&mut self, name: &'static str, labels: &[(&'static str, &str)], v: f64) {
        self.histograms
            .entry(name)
            .or_default()
            .entry(label_set(labels))
            .or_default()
            .observe(v);
    }

    /// Current unlabeled counter value (0 if never incremented).
    pub fn counter(&self, name: &str) -> u64 {
        self.counter_with(name, &[])
    }

    /// Current labeled counter value (0 if never incremented).
    pub fn counter_with(&self, name: &str, labels: &[(&'static str, &str)]) -> u64 {
        self.counters
            .get(name)
            .and_then(|f| f.get(&label_set(labels)))
            .copied()
            .unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauge_with(name, &[])
    }

    pub fn gauge_with(&self, name: &str, labels: &[(&'static str, &str)]) -> Option<f64> {
        self.gauges
            .get(name)
            .and_then(|f| f.get(&label_set(labels)))
            .copied()
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histogram_with(name, &[])
    }

    pub fn histogram_with(
        &self,
        name: &str,
        labels: &[(&'static str, &str)],
    ) -> Option<&Histogram> {
        self.histograms.get(name).and_then(|f| f.get(&label_set(labels)))
    }

    fn header(&self, out: &mut String, name: &str, kind: &str) {
        if let Some(help) = self.help.get(name) {
            let _ = writeln!(out, "# HELP {name} {}", escape_help(help));
        }
        let _ = writeln!(out, "# TYPE {name} {kind}");
    }

    /// Prometheus text exposition (format version 0.0.4): counters and
    /// gauges verbatim, histograms as `summary` families with
    /// p50/p95/max quantiles plus `_sum`/`_count`. Families carry
    /// `# HELP` when described; labeled series render sorted label
    /// pairs with escaped values. Everything iterates in BTreeMap
    /// order, so the dump is deterministic.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, series) in &self.counters {
            self.header(&mut out, name, "counter");
            for (labels, v) in series {
                let _ = writeln!(out, "{name}{} {v}", render_labels(labels, None));
            }
        }
        for (name, series) in &self.gauges {
            self.header(&mut out, name, "gauge");
            for (labels, v) in series {
                let _ = writeln!(out, "{name}{} {v}", render_labels(labels, None));
            }
        }
        for (name, series) in &self.histograms {
            self.header(&mut out, name, "summary");
            for (labels, h) in series {
                for (q, v) in [
                    ("0.5", h.quantile(0.5)),
                    ("0.95", h.quantile(0.95)),
                    ("1", h.max()),
                ] {
                    let lbl = render_labels(labels, Some(("quantile", q)));
                    let _ = writeln!(out, "{name}{lbl} {v}");
                }
                let lbl = render_labels(labels, None);
                let _ = writeln!(out, "{name}_sum{lbl} {}", h.sum());
                let _ = writeln!(out, "{name}_count{lbl} {}", h.count());
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_default_to_zero() {
        let mut r = Registry::new();
        assert_eq!(r.counter("dmlmc_tasks_dispatched_total"), 0);
        r.inc("dmlmc_tasks_dispatched_total", 4);
        r.inc("dmlmc_tasks_dispatched_total", 3);
        assert_eq!(r.counter("dmlmc_tasks_dispatched_total"), 7);
    }

    #[test]
    fn gauges_take_last_write() {
        let mut r = Registry::new();
        assert_eq!(r.gauge("dmlmc_pool_workers"), None);
        r.set_gauge("dmlmc_pool_workers", 4.0);
        r.set_gauge("dmlmc_pool_workers", 2.0);
        assert_eq!(r.gauge("dmlmc_pool_workers"), Some(2.0));
    }

    #[test]
    fn histogram_summaries_match_nearest_rank() {
        let mut r = Registry::new();
        for v in [4.0, 1.0, 3.0, 2.0, 5.0] {
            r.observe("dmlmc_step_makespan_seconds", v);
        }
        let h = r.histogram("dmlmc_step_makespan_seconds").unwrap();
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 15.0);
        assert_eq!(h.quantile(0.5), 3.0);
        assert_eq!(h.quantile(0.95), 5.0);
        assert_eq!(h.max(), 5.0);
    }

    #[test]
    fn labeled_series_are_independent_and_order_insensitive() {
        let mut r = Registry::new();
        r.inc_with("dmlmc_level_samples_total", &[("level", "0")], 8);
        r.inc_with("dmlmc_level_samples_total", &[("level", "1")], 2);
        r.set_gauge_with(
            "dmlmc_level_variance",
            &[("session", "0"), ("level", "1")],
            0.5,
        );
        assert_eq!(
            r.counter_with("dmlmc_level_samples_total", &[("level", "0")]),
            8
        );
        assert_eq!(
            r.counter_with("dmlmc_level_samples_total", &[("level", "1")]),
            2
        );
        // unlabeled series is distinct from labeled ones
        assert_eq!(r.counter("dmlmc_level_samples_total"), 0);
        // label order does not matter on lookup
        assert_eq!(
            r.gauge_with("dmlmc_level_variance", &[("level", "1"), ("session", "0")]),
            Some(0.5)
        );
        let text = r.render_prometheus();
        assert!(text.contains("dmlmc_level_samples_total{level=\"0\"} 8"));
        assert!(text.contains("dmlmc_level_variance{level=\"1\",session=\"0\"} 0.5"));
    }

    #[test]
    fn help_lines_precede_type_lines() {
        let mut r = Registry::new();
        r.describe("dmlmc_steps_total", "SGD steps completed.");
        r.inc("dmlmc_steps_total", 2);
        let text = r.render_prometheus();
        let help = text.find("# HELP dmlmc_steps_total SGD steps completed.");
        let typ = text.find("# TYPE dmlmc_steps_total counter");
        assert!(help.is_some() && typ.is_some());
        assert!(help.unwrap() < typ.unwrap());
    }

    #[test]
    fn hostile_label_values_are_escaped() {
        let mut r = Registry::new();
        let hostile = "a\\b\"c\nd";
        r.set_gauge_with("fleet_session_loss", &[("name", hostile)], 1.25);
        r.describe("fleet_session_loss", "loss with\nnewline and back\\slash");
        let text = r.render_prometheus();
        assert!(
            text.contains("fleet_session_loss{name=\"a\\\\b\\\"c\\nd\"} 1.25"),
            "unescaped label value in: {text}"
        );
        assert!(text.contains("# HELP fleet_session_loss loss with\\nnewline and back\\\\slash"));
        // no raw newline may survive inside any single exposition line
        for line in text.lines() {
            assert!(!line.contains('\u{0}'));
            assert!(line.starts_with('#') || !line.trim_start().is_empty());
        }
    }

    #[test]
    fn prometheus_exposition_covers_every_family() {
        let mut r = Registry::new();
        r.inc("dmlmc_steps_total", 2);
        r.set_gauge("dmlmc_pool_workers", 4.0);
        r.observe("dmlmc_step_makespan_seconds", 0.25);
        r.observe_with("dmlmc_task_busy_seconds", &[("level", "2")], 0.125);
        let text = r.render_prometheus();
        assert!(text.contains("# TYPE dmlmc_steps_total counter"));
        assert!(text.contains("dmlmc_steps_total 2"));
        assert!(text.contains("# TYPE dmlmc_pool_workers gauge"));
        assert!(text.contains("dmlmc_pool_workers 4"));
        assert!(text.contains("# TYPE dmlmc_step_makespan_seconds summary"));
        assert!(text.contains("dmlmc_step_makespan_seconds{quantile=\"0.5\"} 0.25"));
        assert!(text.contains("dmlmc_step_makespan_seconds_count 1"));
        assert!(text.contains("dmlmc_task_busy_seconds{level=\"2\",quantile=\"0.5\"} 0.125"));
        assert!(text.contains("dmlmc_task_busy_seconds_sum{level=\"2\"} 0.125"));
        // every line is `# ...` or `name[{labels}] value`
        for line in text.lines() {
            assert!(
                line.starts_with('#') || line.split_whitespace().count() == 2,
                "malformed exposition line: {line}"
            );
        }
    }
}
