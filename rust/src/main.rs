//! `repro` — the leader binary: training runs, figure/table reproduction,
//! validation and sweeps. See `repro --help`.
//!
//! Every subcommand drives [`ExperimentRunner`] and writes its outputs
//! through the runner's named-run [`RunArtifacts`] directories under
//! `--out-dir` (default `artifacts/`); bench JSONs keep a top-level
//! alias (`./BENCH_*.json`) for CI and `make bench-*`.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use anyhow::{anyhow, Result};
use dmlmc::config::{Backend, ExperimentConfig};
use dmlmc::coordinator::{
    FleetCoordinator, Method, SessionDetail, SessionState, Trainer, TrainerBuilder,
};
use dmlmc::experiments::ExperimentRunner;
use dmlmc::obs::{MetricsServer, ServeState};
use dmlmc::util::cli::{Args, Command, Opt};
use dmlmc::util::json::Json;

fn root_command() -> Command {
    let common = |c: Command| {
        c.opt(Opt::value("config", "TOML config (configs/*.toml)"))
            .opt(Opt::value("backend", "xla|native (overrides config)"))
            .opt(Opt::value(
                "scenario",
                "scenario key `<sde>-<payoff>`, e.g. bs-call|ou-asian|heston-call \
                 |bs-uo-call (see `repro scenarios`; heston is 2-factor \
                 stochastic vol, uo-call/di-put are barrier payoffs); \
                 non-default keys imply --backend native",
            ))
            .opt(Opt::value("steps", "override train.steps"))
            .opt(Opt::value("n-effective", "override mlmc.n_effective"))
            .opt(Opt::value("seeds", "override train.n_seeds"))
            .opt(Opt::value("lr", "override train.lr"))
            .opt(Opt::value("d", "override mlmc.d (delay exponent)"))
            .opt(Opt::value(
                "workers",
                "pool worker threads (execution.workers): 0 = auto (one \
                 per core), 1 = single pooled worker, n = n workers; \
                 results are bit-identical for every value. For \
                 parallel-sweep and fleet-sweep this is the comma-separated \
                 list of worker counts to sweep",
            ))
            .opt(Opt::with_default(
                "out-dir",
                "root directory for named experiment runs",
                "artifacts",
            ))
            .opt(Opt::switch(
                "trace",
                "enable span tracing (observability.trace; off by default — \
                 training commands then export trace.json + metrics.prom \
                 into their run dir)",
            ))
            .opt(Opt::switch(
                "simd",
                "route the native hot path through the 8-wide lane-blocked \
                 SIMD kernels (execution.simd; selects the scenario's \
                 `-simd` registry key — reassociates f32 reductions, \
                 tolerance-validated against scalar, native backend only)",
            ))
            .opt(Opt::switch(
                "pin-cores",
                "pin pool workers round-robin to CPU cores \
                 (execution.pin_cores; sched_setaffinity on Linux, no-op \
                 elsewhere; best-effort and bit-identical results)",
            ))
            .opt(Opt::switch(
                "adaptive",
                "route level/sample/delay decisions through the adaptive \
                 allocation policy (adaptive.enabled; per-level sample \
                 counts and refresh periods re-derived from live estimator \
                 telemetry every adaptive.adapt_every steps)",
            ))
            .opt(Opt::switch("quiet", "suppress progress output"))
    };
    Command::new("repro", "Delayed MLMC for SGD — paper reproduction driver")
        .subcommand(common(
            Command::new("train", "run one training job")
                .opt(Opt::with_default("method", "naive|mlmc|dmlmc", "dmlmc"))
                .opt(Opt::with_default("seed", "run seed", "0")),
        ))
        .subcommand(common(Command::new(
            "figure2",
            "reproduce Figure 2 (3 methods x seeds, learning curves)",
        )))
        .subcommand(common(
            Command::new("assumptions", "reproduce Figure 1 (decay diagnostics)")
                .opt(Opt::with_default("snapshots", "trajectory snapshots", "6")),
        ))
        .subcommand(common(Command::new(
            "table1",
            "reproduce Table 1 (theory vs measured complexity)",
        )))
        .subcommand(common(Command::new(
            "validate",
            "train under geometric drift; compare p0 vs Black-Scholes",
        )))
        .subcommand(common(
            Command::new("sweep", "delay-exponent ablation")
                .opt(Opt::with_default("values", "comma-separated d values", "0.5,1.0,1.5,2.0")),
        ))
        .subcommand(common(
            Command::new(
                "scenario-sweep",
                "per-scenario Assumption-2 fit + MLMC vs DMLMC parallel cost",
            )
            .opt(Opt::with_default(
                "scenarios",
                "comma-separated scenario keys, or `all`",
                "all",
            )),
        ))
        .subcommand(common(
            Command::new(
                "parallel-sweep",
                "measured pool makespan vs PRAM prediction over P x method \
                 (emits BENCH_parallel.json with per-cell dispatch overhead \
                 and a resident-vs-scoped exec_compare row; defaults to 48 \
                 steps unless --steps is given)",
            ),
        ))
        .subcommand(common(
            Command::new(
                "exec-bench",
                "resident vs scoped (spawn-per-dispatch) pool overhead on \
                 light level-0-only dispatches (--workers, default 4, \
                 0 = one per core; --steps measured dispatches per mode, \
                 default 64)",
            ),
        ))
        .subcommand(common(
            Command::new(
                "trace",
                "overhead-bounded tracing bench: the same DMLMC training \
                 with tracing off and on (bit-identical parameters \
                 asserted), exporting trace.json (Chrome trace-event JSON, \
                 Perfetto-loadable) + metrics.prom and emitting \
                 BENCH_obs.json (defaults to 24 steps unless --steps is \
                 given)",
            )
            .opt(Opt::with_default(
                "repeats",
                "traced/untraced run pairs (best-of means compared)",
                "2",
            )),
        ))
        .subcommand(common(
            Command::new(
                "fleet-sweep",
                "serving-fleet throughput: one resident pool multiplexing N \
                 DMLMC trainers, swept over fleet size x workers (emits \
                 BENCH_fleet.json with aggregate steps/sec, problems/sec \
                 and pool utilization per cell; defaults to 16 steps per \
                 problem unless --steps is given)",
            )
            .opt(Opt::with_default(
                "fleet-sizes",
                "comma-separated fleet sizes (problems per cell)",
                "1,2,4",
            ))
            .opt(Opt::with_default(
                "scenarios",
                "comma-separated scenario keys cycled over the fleet",
                "bs-call,heston-uo-call",
            )),
        ))
        .subcommand(common(
            Command::new(
                "serve",
                "long-lived telemetry daemon: a FleetCoordinator tick loop \
                 over a config-listed set of DMLMC sessions ([serve] \
                 sessions/seed0) with a dependency-free HTTP/1.1 scrape \
                 surface on 127.0.0.1 — GET /metrics (Prometheus text), \
                 GET /status (fleet JSON), GET /sessions/<id> (per-session \
                 estimator statistics); serves until SIGINT, then writes \
                 trace.json + metrics.prom + status.json into its run dir \
                 (defaults to 64 steps per session unless --steps is given)",
            )
            .opt(Opt::value(
                "port",
                "scrape port (overrides observability.serve_port; 0/unset \
                 = ephemeral, printed on startup)",
            ))
            .opt(Opt::value(
                "sessions",
                "DMLMC sessions to submit (overrides serve.sessions); \
                 session i runs seed seed0+i",
            ))
            .opt(Opt::value(
                "seed0",
                "seed of the first session (overrides serve.seed0)",
            ))
            .opt(Opt::with_default(
                "max-ticks",
                "stop after this many fleet ticks or once drained, without \
                 waiting for SIGINT (0 = keep serving until SIGINT)",
                "0",
            )),
        ))
        .subcommand(common(
            Command::new(
                "hotpath-bench",
                "scalar vs lane-blocked (SIMD) kernel throughput per \
                 scenario: one value_and_grad chunk is the timed unit \
                 (emits BENCH_hotpath.json with paths_per_sec and speedup \
                 per cell)",
            )
            .opt(Opt::with_default(
                "scenarios",
                "comma-separated scenario keys, or `all`",
                "bs-call,heston-uo-call",
            ))
            .opt(Opt::with_default(
                "batch",
                "paths per kernel invocation",
                "512",
            )),
        ))
        .subcommand(common(Command::new(
            "adaptive-sweep",
            "fixed vs adaptive allocation ablation: the same DMLMC \
             training once with the offline-theory constants and once \
             with the telemetry-driven policy, compared on wall clock to \
             a shared target loss and measured parallel cost per step \
             (emits BENCH_adaptive.json; defaults to 32 steps unless \
             --steps is given)",
        )))
        .subcommand(Command::new(
            "scenarios",
            "list the registered scenario keys",
        ))
        .subcommand(Command::new("info", "print artifact/manifest summary").opt(
            Opt::with_default("artifacts", "artifact directory", "artifacts"),
        ))
}

fn load_config(args: &Args) -> Result<ExperimentConfig> {
    load_config_with(args, false)
}

/// `workers_list_ok`: only `parallel-sweep` and `fleet-sweep` accept the
/// comma-list form of `--workers` (and parse it themselves); everywhere
/// else a list is a user error and must not silently fall back to the
/// default.
fn load_config_with(args: &Args, workers_list_ok: bool) -> Result<ExperimentConfig> {
    // Whether the TOML itself pins `runtime.backend` / `runtime.out_dir`
    // (a config file that stays silent is not a pin). Costs a second
    // parse of a sub-kilobyte file at startup; parse errors are left for
    // from_toml to report.
    let mut toml_pins_backend = false;
    let mut toml_pins_out_dir = false;
    let mut cfg = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(Path::new(path))
                .map_err(|e| anyhow!("{path}: {e}"))?;
            if let Ok(doc) = dmlmc::util::toml::TomlDoc::parse(&text) {
                toml_pins_backend = doc.get("runtime.backend").is_some();
                toml_pins_out_dir = doc.get("runtime.out_dir").is_some();
            }
            ExperimentConfig::from_toml(&text).map_err(|e| anyhow!("{e}"))?
        }
        None => ExperimentConfig::default_paper(),
    };
    if let Some(b) = args.get("backend") {
        cfg.runtime.backend =
            Backend::parse(b).ok_or_else(|| anyhow!("unknown backend `{b}`"))?;
    }
    if let Some(s) = args.get("scenario") {
        cfg.scenario = s.to_string();
        // Non-default scenarios only run on the native engine; switch
        // automatically when no backend was pinned anywhere (neither
        // --backend nor an explicit `runtime.backend` in the TOML, which
        // we must not silently override — validation rejects a conflict
        // loudly instead).
        if s != dmlmc::scenarios::DEFAULT_SCENARIO
            && args.get("backend").is_none()
            && !toml_pins_backend
        {
            cfg.runtime.backend = Backend::Native;
        }
    }
    if let Some(v) = args.parse_usize("steps")? {
        cfg.train.steps = v;
    }
    if let Some(v) = args.parse_usize("n-effective")? {
        cfg.mlmc.n_effective = v;
    }
    if let Some(v) = args.parse_usize("seeds")? {
        cfg.train.n_seeds = v;
    }
    if let Some(v) = args.parse_f64("lr")? {
        cfg.train.lr = v;
    }
    if let Some(v) = args.parse_f64("d")? {
        cfg.mlmc.d = v;
    }
    // `--workers` is a single count for training commands and a comma
    // list for parallel-sweep / fleet-sweep (which parse the list
    // themselves).
    if let Some(v) = args.get("workers") {
        if !v.contains(',') {
            cfg.execution.workers = args.parse_usize("workers")?.unwrap_or(0);
        } else if !workers_list_ok {
            return Err(anyhow!(
                "--workers takes a single integer here (got `{v}`); the \
                 comma-list form is only for `parallel-sweep` and \
                 `fleet-sweep`"
            ));
        }
    }
    // `--out-dir` defaults to `artifacts`; a TOML `runtime.out_dir` pin
    // wins over that default (but not over an explicit non-default flag).
    if let Some(v) = args.get("out-dir") {
        if v != "artifacts" || !toml_pins_out_dir {
            cfg.runtime.out_dir = PathBuf::from(v);
        }
    }
    // `--trace` / `--simd` / `--pin-cores` can only enable their knob;
    // the TOML (`[observability]` / `[execution]`) remains authoritative
    // when a switch is absent.
    if args.flag("trace") {
        cfg.observability.trace = true;
    }
    if args.flag("simd") {
        cfg.execution.simd = true;
    }
    if args.flag("pin-cores") {
        cfg.execution.pin_cores = true;
    }
    if args.flag("adaptive") {
        cfg.adaptive.enabled = true;
    }
    cfg.validate().map_err(|e| anyhow!(e))?;
    Ok(cfg)
}

/// The runner every subcommand drives: configured output root + quiet.
fn runner_for(cfg: &ExperimentConfig, args: &Args) -> ExperimentRunner {
    ExperimentRunner::new(cfg)
        .out_dir(cfg.runtime.out_dir.clone())
        .quiet(args.flag("quiet"))
}

/// Comma-separated positive-integer list (`--workers`, `--fleet-sizes`).
fn parse_usize_list(raw: &str, what: &str) -> Result<Vec<usize>> {
    raw.split(',')
        .map(|s| {
            s.trim()
                .parse::<usize>()
                .map_err(|_| anyhow!("bad {what} `{s}`"))
        })
        .collect()
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let method = Method::parse(args.get_or("method", "dmlmc"))
        .ok_or_else(|| anyhow!("unknown method"))?;
    let seed = args.parse_usize("seed")?.unwrap_or(0) as u64;
    let quiet = args.flag("quiet");

    eprintln!(
        "train: method={method} seed={seed} backend={} scenario={} steps={} N={}",
        cfg.runtime.backend.name(),
        cfg.effective_scenario(),
        cfg.train.steps,
        cfg.mlmc.n_effective
    );
    let mut tr = Trainer::from_config(&cfg, method, seed)?;
    let curve = tr.run()?;
    if !quiet {
        for p in &curve.points {
            println!(
                "step {:>6}  loss {:>10.5}  std_cost {:>12.0}  par_cost {:>10.0}",
                p.step, p.loss, p.std_cost, p.par_cost
            );
        }
    }
    let runner = runner_for(&cfg, args);
    let arts = runner.artifacts(&format!("train_{}_seed{seed}", method.name()))?;
    let out = arts.write_curve_csv(&curve)?;
    // Manifest rows carry pool telemetry keyed by stable worker indices.
    arts.append_run_jsonl(&curve, tr.exec_stats())?;
    // Under --trace the run additionally exports its span timeline and
    // metrics snapshot next to the curve.
    if let Some(rec) = tr.take_recorder() {
        let (trace_path, prom_path) = dmlmc::obs::TraceSink::new(&arts).write(&rec)?;
        eprintln!("wrote {} and {}", trace_path.display(), prom_path.display());
    }
    eprintln!("wrote {}", out.display());
    Ok(())
}

fn cmd_figure2(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let runner = runner_for(&cfg, args);
    let results = runner.figure2()?;
    let arts = runner.artifacts("figure2")?;
    for (method, curves, agg) in &results {
        for curve in curves {
            arts.write_curve_csv(curve)?;
        }
        let agg_path =
            arts.write_text(&format!("figure2_{}.csv", method.name()), &agg.to_csv())?;
        eprintln!("wrote {}", agg_path.display());
    }
    // Headline summary: cost to reach the worst method's best loss.
    println!("\nFigure 2 summary (final loss, total std cost, total par cost):");
    for (method, _, agg) in &results {
        println!(
            "  {:<8} loss {:>9.5} ± {:>8.5}   std {:>12.0}   par {:>10.0}",
            method.name(),
            agg.loss_mean.last().unwrap(),
            agg.loss_std.last().unwrap(),
            agg.std_cost.last().unwrap(),
            agg.par_cost.last().unwrap()
        );
    }
    Ok(())
}

fn cmd_assumptions(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let snapshots = args.parse_usize("snapshots")?.unwrap_or(6);
    let runner = runner_for(&cfg, args);
    let fig = runner.figure1(snapshots)?;
    println!("Figure 1 — assumption decay (levels 0..={}):", cfg.problem.lmax);
    println!(
        "{:<6} {:>16} {:>16} {:>16} {:>16}",
        "level", "E||gDl||^2", "(std)", "smoothness", "(std)"
    );
    for l in 0..fig.grad_norms.per_level.len() {
        let (gm, gs) = fig.grad_norms.per_level[l];
        let (sm, ss) = fig.smoothness.per_level[l];
        println!("{l:<6} {gm:>16.6e} {gs:>16.2e} {sm:>16.6e} {ss:>16.2e}");
    }
    println!(
        "\nfitted decay exponents: b_hat = {:.3} (paper ~1.8-2), d_hat = {:.3} (paper ~1)",
        fig.b_hat, fig.d_hat
    );

    let mut csv = String::from("level,grad_norm_mean,grad_norm_std,smooth_mean,smooth_std\n");
    for l in 0..fig.grad_norms.per_level.len() {
        let (gm, gs) = fig.grad_norms.per_level[l];
        let (sm, ss) = fig.smoothness.per_level[l];
        csv.push_str(&format!("{l},{gm},{gs},{sm},{ss}\n"));
    }
    let arts = runner.artifacts("assumptions")?;
    let path = arts.write_text("figure1.csv", &csv)?;
    eprintln!("wrote {}", path.display());
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let runner = runner_for(&cfg, args);
    let (theory, measured) = runner.table1()?;
    let table = ExperimentRunner::render_table1(&theory, &measured);
    println!("{table}");
    println!(
        "predicted avg per-step depth (schedule sim): {:.2}",
        runner.predicted_avg_depth(1 << 12)
    );
    let arts = runner.artifacts("table1")?;
    arts.write_text("table1.txt", &table)?;
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let (p0, bs) = runner_for(&cfg, args).validate_bs()?;
    println!("learned p0        = {p0:.4}");
    println!("Black-Scholes     = {bs:.4}");
    println!("relative error    = {:.2}%", 100.0 * (p0 - bs).abs() / bs);
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let ds: Vec<f64> = args
        .get_or("values", "0.5,1.0,1.5,2.0")
        .split(',')
        .map(|s| s.trim().parse::<f64>().map_err(|_| anyhow!("bad d `{s}`")))
        .collect::<Result<_>>()?;
    let rows = runner_for(&cfg, args).sweep_delay(&ds)?;
    println!(
        "{:<6} {:>12} {:>14} {:>14} {:>12}",
        "d", "final loss", "std cost", "par cost", "avg depth"
    );
    for (d, r) in rows {
        println!(
            "{d:<6} {:>12.5} {:>14.0} {:>14.0} {:>12.2}",
            r.final_loss, r.std_cost, r.par_cost, r.avg_depth
        );
    }
    Ok(())
}

fn cmd_scenarios() -> Result<()> {
    println!(
        "registered scenarios (<sde>-<payoff>; default `{}`):",
        dmlmc::scenarios::DEFAULT_SCENARIO
    );
    for name in dmlmc::scenarios::all_scenario_names() {
        println!("  {name}");
    }
    println!(
        "\nsde keys:    {}\npayoff keys: {}",
        dmlmc::scenarios::SDE_KEYS.join(", "),
        dmlmc::scenarios::PAYOFF_KEYS.join(", ")
    );
    Ok(())
}

fn cmd_scenario_sweep(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let names: Vec<String> = match args.get_or("scenarios", "all") {
        "all" => dmlmc::scenarios::all_scenario_names(),
        list => list.split(',').map(|s| s.trim().to_string()).collect(),
    };
    let runner = runner_for(&cfg, args);
    let rows = runner.scenario_sweep(&names)?;
    let table = ExperimentRunner::render_scenario_table(&rows);
    println!("{table}");
    runner
        .artifacts("scenario-sweep")?
        .write_text("scenario_sweep.txt", &table)?;
    Ok(())
}

/// Whether an explicit `train.steps` appears in the `--config` TOML (same
/// pin-detection convention as `runtime.backend` in `load_config_with`:
/// a config file silent about steps is not a pin).
fn toml_pins_steps(args: &Args) -> bool {
    args.get("config")
        .and_then(|p| std::fs::read_to_string(p).ok())
        .and_then(|t| dmlmc::util::toml::TomlDoc::parse(&t).ok())
        .map(|doc| doc.get("train.steps").is_some())
        .unwrap_or(false)
}

fn cmd_parallel_sweep(args: &Args) -> Result<()> {
    use dmlmc::util::json::{obj, Json};
    let mut cfg = load_config_with(args, true)?;
    // The paper-scale default (400 steps x 10 seeds) is a figure budget,
    // not a sweep budget; default to a short horizon unless pinned.
    if args.get("steps").is_none() && !toml_pins_steps(args) {
        cfg.train.steps = 48;
    }
    let workers = parse_usize_list(args.get_or("workers", "1,2,4,8"), "worker count")?;
    let runner = runner_for(&cfg, args);
    let cells = runner.parallel_sweep(&workers)?;
    println!("{}", ExperimentRunner::render_parallel_table(&cells));

    let rows: Vec<Json> = cells
        .iter()
        .map(|c| {
            obj(vec![
                ("method", Json::Str(c.method.name().to_string())),
                ("workers", Json::Num(c.workers as f64)),
                ("steps", Json::Num(c.steps as f64)),
                ("measured_mean_makespan_s", Json::Num(c.measured_mean_s)),
                ("measured_total_s", Json::Num(c.measured_total_s)),
                ("utilization", Json::Num(c.utilization)),
                ("dispatch_overhead_mean_s", Json::Num(c.overhead_mean_s)),
                ("pram_makespan", Json::Num(c.pram_makespan)),
                ("brent_bound", Json::Num(c.brent_bound)),
                ("final_loss", Json::Num(c.final_loss)),
            ])
        })
        .collect();
    // Resident-vs-scoped spawn-overhead comparison at P = 4 on the light
    // (level-0-only) DMLMC-style dispatch — the regime where per-step
    // executor overhead dominates and the resident pool's win shows.
    let cmp = runner.exec_overhead_compare(4, cfg.train.steps.max(8))?;
    if !args.flag("quiet") {
        eprint!("{}", ExperimentRunner::render_exec_comparison(&cmp));
    }
    let doc = obj(vec![
        ("bench", Json::Str("parallel-sweep".to_string())),
        ("scenario", Json::Str(cfg.scenario.clone())),
        ("n_effective", Json::Num(cfg.mlmc.n_effective as f64)),
        ("steps", Json::Num(cfg.train.steps as f64)),
        ("cells", Json::Arr(rows)),
        (
            "exec_compare",
            obj(vec![
                ("workers", Json::Num(cmp.workers as f64)),
                ("steps", Json::Num(cmp.steps as f64)),
                (
                    "resident_overhead_mean_s",
                    Json::Num(cmp.resident_overhead_mean_s),
                ),
                (
                    "scoped_overhead_mean_s",
                    Json::Num(cmp.scoped_overhead_mean_s),
                ),
                (
                    "resident_makespan_mean_s",
                    Json::Num(cmp.resident_makespan_mean_s),
                ),
                (
                    "scoped_makespan_mean_s",
                    Json::Num(cmp.scoped_makespan_mean_s),
                ),
                (
                    "resident_threads_spawned",
                    Json::Num(cmp.resident_threads_spawned as f64),
                ),
                (
                    "scoped_threads_spawned",
                    Json::Num(cmp.scoped_threads_spawned as f64),
                ),
            ]),
        ),
    ]);
    let path = runner
        .artifacts("parallel-sweep")?
        .write_bench_json("BENCH_parallel", &doc)?;
    eprintln!("wrote {} (+ ./BENCH_parallel.json)", path.display());
    Ok(())
}

fn cmd_fleet_sweep(args: &Args) -> Result<()> {
    use dmlmc::util::json::{obj, Json};
    let mut cfg = load_config_with(args, true)?;
    // Like parallel-sweep: a short serving horizon by default.
    if args.get("steps").is_none() && !toml_pins_steps(args) {
        cfg.train.steps = 16;
    }
    let steps = cfg.train.steps;
    let fleet_sizes =
        parse_usize_list(args.get_or("fleet-sizes", "1,2,4"), "fleet size")?;
    let workers = parse_usize_list(args.get_or("workers", "2"), "worker count")?;
    let scenarios: Vec<String> = args
        .get_or("scenarios", "bs-call,heston-uo-call")
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let runner = runner_for(&cfg, args);
    let cells = runner.fleet_sweep(&fleet_sizes, &workers, &scenarios, steps)?;
    println!("{}", ExperimentRunner::render_fleet_table(&cells));

    let rows: Vec<Json> = cells
        .iter()
        .map(|c| {
            obj(vec![
                ("fleet_size", Json::Num(c.fleet_size as f64)),
                ("workers", Json::Num(c.workers as f64)),
                (
                    "problems",
                    Json::Arr(
                        c.problems
                            .iter()
                            .map(|p| Json::Str(p.clone()))
                            .collect(),
                    ),
                ),
                ("steps_per_problem", Json::Num(c.steps_per_problem as f64)),
                ("total_steps", Json::Num(c.total_steps as f64)),
                ("ticks", Json::Num(c.ticks as f64)),
                ("wall_s", Json::Num(c.wall_s)),
                ("steps_per_sec", Json::Num(c.steps_per_sec)),
                ("problems_per_sec", Json::Num(c.problems_per_sec)),
                ("utilization", Json::Num(c.utilization)),
                (
                    "mean_step_makespan_s",
                    Json::Num(c.mean_step_makespan_s),
                ),
            ])
        })
        .collect();
    let doc = obj(vec![
        ("bench", Json::Str("fleet-sweep".to_string())),
        (
            "scenarios",
            Json::Arr(scenarios.iter().map(|s| Json::Str(s.clone())).collect()),
        ),
        ("steps_per_problem", Json::Num(steps as f64)),
        ("cells", Json::Arr(rows)),
    ]);
    let path = runner
        .artifacts("fleet-sweep")?
        .write_bench_json("BENCH_fleet", &doc)?;
    eprintln!("wrote {} (+ ./BENCH_fleet.json)", path.display());
    Ok(())
}

fn cmd_exec_bench(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    // `--workers` here is the single comparison P, not a sweep list. An
    // explicit value — flag or `execution.workers` in the config TOML —
    // is honored (0 = one per core, the flag's documented auto); with
    // neither set, default to a representative P = 4 rather than
    // whole-machine auto.
    let workers = if args.get("workers").is_some() || cfg.execution.workers != 0
    {
        cfg.execution.resolved_workers()
    } else {
        4
    };
    let steps = args.parse_usize("steps")?.unwrap_or(64);
    let cmp = runner_for(&cfg, args).exec_overhead_compare(workers, steps)?;
    print!("{}", ExperimentRunner::render_exec_comparison(&cmp));
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    use dmlmc::util::json::{obj, Json};
    let mut cfg = load_config(args)?;
    // Like parallel-sweep: the overhead bound is about per-step cost, not
    // figure-scale horizons; default short unless pinned.
    if args.get("steps").is_none() && !toml_pins_steps(args) {
        cfg.train.steps = 24;
    }
    // Same worker-resolution convention as exec-bench, with a smaller
    // representative default (the bench runs each horizon twice per
    // repeat).
    let workers = if args.get("workers").is_some() || cfg.execution.workers != 0
    {
        cfg.execution.resolved_workers()
    } else {
        2
    };
    let repeats = args.parse_usize("repeats")?.unwrap_or(2);
    let runner = runner_for(&cfg, args);
    let bench = runner.trace_bench(workers, repeats)?;
    print!("{}", ExperimentRunner::render_trace_bench(&bench));

    let doc = obj(vec![
        ("bench", Json::Str("trace".to_string())),
        ("scenario", Json::Str(cfg.scenario.clone())),
        ("workers", Json::Num(bench.workers as f64)),
        ("steps", Json::Num(bench.steps as f64)),
        ("repeats", Json::Num(bench.repeats as f64)),
        (
            "untraced_mean_makespan_s",
            Json::Num(bench.untraced_mean_makespan_s),
        ),
        (
            "traced_mean_makespan_s",
            Json::Num(bench.traced_mean_makespan_s),
        ),
        ("overhead_ratio", Json::Num(bench.overhead_ratio)),
        (
            "scraped_mean_makespan_s",
            Json::Num(bench.scraped_mean_makespan_s),
        ),
        (
            "scrape_overhead_ratio",
            Json::Num(bench.scrape_overhead_ratio),
        ),
        ("scrapes_total", Json::Num(bench.scrapes_total as f64)),
        (
            "spans_per_worker",
            Json::Arr(
                bench
                    .spans_per_worker
                    .iter()
                    .map(|&n| Json::Num(n as f64))
                    .collect(),
            ),
        ),
        ("coordinator_spans", Json::Num(bench.coordinator_spans as f64)),
        ("dropped_spans", Json::Num(bench.dropped_spans as f64)),
        (
            "trace_path",
            Json::Str(bench.trace_path.display().to_string()),
        ),
        (
            "metrics_path",
            Json::Str(bench.metrics_path.display().to_string()),
        ),
    ]);
    let path = runner
        .artifacts("trace")?
        .write_bench_json("BENCH_obs", &doc)?;
    eprintln!("wrote {} (+ ./BENCH_obs.json)", path.display());
    Ok(())
}

/// SIGINT latch for the `serve` daemon: a raw `signal(2)` registration
/// on Linux (the same no-new-dependencies idiom as [`dmlmc::exec`]'s
/// affinity syscall), a no-op elsewhere — the daemon then runs until
/// `--max-ticks` (or an external kill).
mod sigint {
    use std::sync::atomic::{AtomicBool, Ordering};

    static INTERRUPTED: AtomicBool = AtomicBool::new(false);

    #[cfg(target_os = "linux")]
    pub fn install() {
        // Async-signal-safe by construction: the handler does one atomic
        // store and returns; the serve loop polls the latch.
        extern "C" fn on_sigint(_sig: i32) {
            INTERRUPTED.store(true, Ordering::Relaxed);
        }
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        const SIGINT: i32 = 2;
        unsafe {
            signal(SIGINT, on_sigint as usize);
        }
    }

    #[cfg(not(target_os = "linux"))]
    pub fn install() {}

    pub fn interrupted() -> bool {
        INTERRUPTED.load(Ordering::Relaxed)
    }
}

fn session_state_name(s: SessionState) -> &'static str {
    match s {
        SessionState::Queued => "queued",
        SessionState::Running => "running",
        SessionState::Done => "done",
    }
}

/// The `/status` document: fleet-level progress + the last tick's pool
/// utilization (read back from the registry gauge so the JSON and the
/// scrape can never disagree).
fn serve_status_doc(fleet: &FleetCoordinator, uptime: std::time::Duration) -> Json {
    use dmlmc::util::json::obj;
    let statuses = fleet.statuses();
    let count = |st: SessionState| {
        statuses.iter().filter(|s| s.state == st).count() as f64
    };
    let util = fleet
        .recorder()
        .and_then(|r| r.metrics().gauge("fleet_pool_utilization"))
        .unwrap_or(0.0);
    let sessions: Vec<Json> = statuses
        .iter()
        .map(|s| {
            obj(vec![
                ("id", Json::Num(s.id.0 as f64)),
                ("name", Json::Str(s.name.clone())),
                ("state", Json::Str(session_state_name(s.state).to_string())),
                ("steps_done", Json::Num(s.steps_done as f64)),
                ("steps_total", Json::Num(s.steps_total as f64)),
            ])
        })
        .collect();
    obj(vec![
        ("uptime_s", Json::Num(uptime.as_secs_f64())),
        ("ticks", Json::Num(fleet.ticks() as f64)),
        ("workers", Json::Num(fleet.workers() as f64)),
        ("sessions_active", Json::Num(count(SessionState::Running))),
        ("sessions_pending", Json::Num(count(SessionState::Queued))),
        ("sessions_done", Json::Num(count(SessionState::Done))),
        ("pool_utilization", Json::Num(util)),
        ("sessions", Json::Arr(sessions)),
    ])
}

/// One `/sessions/<id>` document: progress, last evaluated loss, level
/// layout, and the live per-level estimator statistics.
fn serve_session_doc(d: &SessionDetail) -> Json {
    use dmlmc::util::json::obj;
    let levels: Vec<Json> = d
        .levels
        .iter()
        .map(|l| {
            obj(vec![
                ("level", Json::Num(l.level as f64)),
                ("refreshes_total", Json::Num(l.refreshes_total as f64)),
                ("samples_total", Json::Num(l.samples_total as f64)),
                ("variance", Json::Num(l.variance)),
                ("grad_norm2_mean", Json::Num(l.mean_norm2)),
                ("cost_mean_s", Json::Num(l.cost_mean_s)),
                ("staleness_steps", Json::Num(l.staleness as f64)),
                ("last_refresh_step", Json::Num(l.last_refresh_step as f64)),
            ])
        })
        .collect();
    obj(vec![
        ("id", Json::Num(d.status.id.0 as f64)),
        ("name", Json::Str(d.status.name.clone())),
        (
            "state",
            Json::Str(session_state_name(d.status.state).to_string()),
        ),
        ("method", Json::Str(d.method.name().to_string())),
        ("seed", Json::Num(d.seed as f64)),
        ("scenario", Json::Str(d.scenario.clone())),
        ("step", Json::Num(d.status.steps_done as f64)),
        ("steps_total", Json::Num(d.status.steps_total as f64)),
        ("last_loss", d.last_loss.map(Json::Num).unwrap_or(Json::Null)),
        (
            "chunks_per_level",
            Json::Arr(
                d.chunks_per_level
                    .iter()
                    .map(|&c| Json::Num(c as f64))
                    .collect(),
            ),
        ),
        ("levels", Json::Arr(levels)),
    ])
}

/// Refresh everything the HTTP endpoints answer from (called once per
/// tick — the registry itself is live and needs no republishing here).
fn publish_serve_state(
    state: &ServeState,
    fleet: &FleetCoordinator,
    uptime: std::time::Duration,
) {
    state.set_status(serve_status_doc(fleet, uptime));
    for st in fleet.statuses() {
        if let Some(d) = fleet.session_detail(st.id) {
            state.set_session(st.id.0 as u64, serve_session_doc(&d));
        }
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    let mut cfg = load_config(args)?;
    // A serving daemon wants a short per-session horizon unless pinned —
    // the figure-scale 400-step default is a batch budget, and the
    // daemon keeps answering scrapes after the fleet drains anyway.
    if args.get("steps").is_none() && !toml_pins_steps(args) {
        cfg.train.steps = 64;
    }
    // Fleet sessions need a shareable (native) backend even for the
    // default scenario (same forcing as fleet-sweep).
    cfg.runtime.backend = Backend::Native;
    if let Some(v) = args.parse_usize("sessions")? {
        if v == 0 {
            return Err(anyhow!("--sessions must be positive"));
        }
        cfg.serve.sessions = v;
    }
    if let Some(v) = args.parse_usize("seed0")? {
        cfg.serve.seed0 = v as u64;
    }
    let port = match args.parse_usize("port")? {
        Some(p) => u16::try_from(p)
            .map_err(|_| anyhow!("--port must fit in a u16 (got {p})"))?,
        None => cfg.observability.serve_port,
    };
    let max_ticks = args.parse_usize("max-ticks")?.unwrap_or(0);
    let quiet = args.flag("quiet");

    let workers = cfg.execution.resolved_workers();
    let mut fleet = FleetCoordinator::new(workers);
    fleet.enable_tracing(); // serving IS telemetry: always record
    let state = Arc::new(ServeState::new(
        fleet
            .recorder()
            .expect("tracing just enabled")
            .shared_metrics(),
    ));
    for i in 0..cfg.serve.sessions {
        let seed = cfg.serve.seed0 + i as u64;
        let name = format!("{}-seed{seed}", cfg.effective_scenario());
        fleet.submit(
            &name,
            TrainerBuilder::new(&cfg).method(Method::Dmlmc).seed(seed),
        )?;
    }
    let mut server = MetricsServer::start(state.clone(), port)?;
    sigint::install();
    eprintln!(
        "serve: {} DMLMC sessions x {} steps on {workers} workers — scrape \
         http://{} (GET /metrics | /status | /sessions/<id>), SIGINT to stop",
        cfg.serve.sessions,
        cfg.train.steps,
        server.addr()
    );

    let start = Instant::now();
    let mut drained_said = false;
    publish_serve_state(&state, &fleet, start.elapsed());
    loop {
        if sigint::interrupted() {
            break;
        }
        if max_ticks > 0 && fleet.ticks() >= max_ticks {
            break;
        }
        let stepped = fleet.tick()?;
        publish_serve_state(&state, &fleet, start.elapsed());
        if stepped == 0 {
            // Fleet drained: stay resident for scrapes until SIGINT (or
            // exit right away under a --max-ticks budget).
            if max_ticks > 0 {
                break;
            }
            if !quiet && !drained_said {
                eprintln!(
                    "serve: all sessions done after {} ticks; still serving \
                     (SIGINT to stop)",
                    fleet.ticks()
                );
                drained_said = true;
            }
            std::thread::sleep(Duration::from_millis(50));
        }
    }

    // Graceful shutdown: stop answering scrapes, then write the final
    // artifacts — the status document plus the span timeline and metrics
    // snapshot — into the run directory.
    server.shutdown();
    let runner = runner_for(&cfg, args);
    let arts = runner.artifacts("serve")?;
    let status_path =
        arts.write_json("status.json", &serve_status_doc(&fleet, start.elapsed()))?;
    if let Some(rec) = fleet.take_recorder() {
        let (trace_path, prom_path) = dmlmc::obs::TraceSink::new(&arts).write(&rec)?;
        eprintln!("wrote {} and {}", trace_path.display(), prom_path.display());
    }
    eprintln!(
        "serve: shut down after {} ticks; wrote {}",
        fleet.ticks(),
        status_path.display()
    );
    Ok(())
}

fn cmd_hotpath_bench(args: &Args) -> Result<()> {
    use dmlmc::util::json::{obj, Json};
    let cfg = load_config(args)?;
    let names: Vec<String> = match args.get_or("scenarios", "bs-call,heston-uo-call")
    {
        "all" => dmlmc::scenarios::all_scenario_names(),
        list => list.split(',').map(|s| s.trim().to_string()).collect(),
    };
    let batch = args.parse_usize("batch")?.unwrap_or(512);
    let runner = runner_for(&cfg, args);
    let cells = runner.hotpath_bench(&names, batch)?;
    println!("{}", ExperimentRunner::render_hotpath_table(&cells));

    let rows: Vec<Json> = cells
        .iter()
        .map(|c| {
            obj(vec![
                ("scenario", Json::Str(c.scenario.clone())),
                ("batch", Json::Num(c.batch as f64)),
                ("n_steps", Json::Num(c.n_steps as f64)),
                ("scalar_paths_per_sec", Json::Num(c.scalar_paths_per_sec)),
                ("lanes_paths_per_sec", Json::Num(c.lanes_paths_per_sec)),
                ("speedup", Json::Num(c.speedup)),
            ])
        })
        .collect();
    let doc = obj(vec![
        ("bench", Json::Str("hotpath".to_string())),
        ("batch", Json::Num(batch as f64)),
        ("cells", Json::Arr(rows)),
    ]);
    let path = runner
        .artifacts("hotpath-bench")?
        .write_bench_json("BENCH_hotpath", &doc)?;
    eprintln!("wrote {} (+ ./BENCH_hotpath.json)", path.display());
    Ok(())
}

fn cmd_adaptive_sweep(args: &Args) -> Result<()> {
    use dmlmc::util::json::{obj, Json};
    let mut cfg = load_config(args)?;
    // Like parallel-sweep: a short ablation horizon by default.
    if args.get("steps").is_none() && !toml_pins_steps(args) {
        cfg.train.steps = 32;
    }
    let runner = runner_for(&cfg, args);
    let rows = runner.adaptive_sweep()?;
    println!("{}", ExperimentRunner::render_adaptive_table(&rows));

    let cells: Vec<Json> = rows
        .iter()
        .map(|r| {
            obj(vec![
                ("mode", Json::Str(r.mode.clone())),
                ("steps", Json::Num(r.steps as f64)),
                ("final_loss", Json::Num(r.final_loss)),
                ("target_loss", Json::Num(r.target_loss)),
                (
                    "wall_clock_to_target_s",
                    Json::Num(r.wall_clock_to_target_s),
                ),
                ("mean_parallel_cost", Json::Num(r.mean_parallel_cost)),
                (
                    "mean_step_makespan_s",
                    Json::Num(r.mean_step_makespan_s),
                ),
                ("adaptations", Json::Num(r.adaptations as f64)),
            ])
        })
        .collect();
    let doc = obj(vec![
        ("bench", Json::Str("adaptive-sweep".to_string())),
        ("scenario", Json::Str(cfg.scenario.clone())),
        ("n_effective", Json::Num(cfg.mlmc.n_effective as f64)),
        ("steps", Json::Num(cfg.train.steps as f64)),
        ("adapt_every", Json::Num(cfg.adaptive.adapt_every as f64)),
        ("cells", Json::Arr(cells)),
    ]);
    let path = runner
        .artifacts("adaptive-sweep")?
        .write_bench_json("BENCH_adaptive", &doc)?;
    eprintln!("wrote {} (+ ./BENCH_adaptive.json)", path.display());
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    use dmlmc::runtime::Manifest;
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let m = Manifest::load(&dir)?;
    println!("artifacts: {}", dir.display());
    println!("problem: {:?}", m.problem);
    println!("n_params: {}", m.n_params);
    println!("entries ({}):", m.entries.len());
    for e in &m.entries {
        println!(
            "  {:<18} kind={:<12?} level={:<4} batch={:<4} n_steps={}",
            e.name,
            e.kind,
            e.level.map(|l| l.to_string()).unwrap_or_else(|| "-".into()),
            e.batch,
            e.n_steps
        );
    }
    Ok(())
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = root_command();
    let (sub, args) = match cmd.parse(&argv) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("{}", e.0);
            return ExitCode::FAILURE;
        }
    };
    let result = match sub.as_str() {
        "train" => cmd_train(&args),
        "figure2" => cmd_figure2(&args),
        "assumptions" => cmd_assumptions(&args),
        "table1" => cmd_table1(&args),
        "validate" => cmd_validate(&args),
        "sweep" => cmd_sweep(&args),
        "scenario-sweep" => cmd_scenario_sweep(&args),
        "parallel-sweep" => cmd_parallel_sweep(&args),
        "exec-bench" => cmd_exec_bench(&args),
        "trace" => cmd_trace(&args),
        "serve" => cmd_serve(&args),
        "fleet-sweep" => cmd_fleet_sweep(&args),
        "hotpath-bench" => cmd_hotpath_bench(&args),
        "adaptive-sweep" => cmd_adaptive_sweep(&args),
        "scenarios" => cmd_scenarios(),
        "info" => cmd_info(&args),
        _ => {
            eprintln!("{}", root_command().help());
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}
