//! Bench target for **Figure 1**: regenerates the assumption-decay series
//! (variance proxy + pathwise smoothness per level, mean ± std along a
//! trajectory) and the fitted exponents b̂, d̂, and times the per-level
//! diagnostic kernels.
//!
//! `cargo bench --bench figure1`

use dmlmc::bench::{black_box, Harness};
use dmlmc::config::{Backend, ExperimentConfig};
use dmlmc::engine::mlp::init_params;
use dmlmc::experiments::ExperimentRunner;
use dmlmc::rng::{brownian::Purpose, BrownianSource};
use dmlmc::runtime::{GradBackend, NativeBackend};

fn main() {
    let mut cfg = ExperimentConfig::default_paper();
    cfg.runtime.backend = Backend::Native;
    cfg.train.steps = 12;
    cfg.mlmc.n_effective = 64;

    // The figure itself.
    let fig = ExperimentRunner::new(&cfg)
        .quiet(true)
        .figure1(4)
        .expect("figure1");
    println!("\n=== FIGURE 1 (decay of variance proxy and smoothness) ===");
    println!(
        "{:<6} {:>16} {:>12} {:>16} {:>12}",
        "level", "E||gDl||^2", "(std)", "smoothness", "(std)"
    );
    for l in 0..fig.grad_norms.per_level.len() {
        let (gm, gs) = fig.grad_norms.per_level[l];
        let (sm, ss) = fig.smoothness.per_level[l];
        println!("{l:<6} {gm:>16.6e} {gs:>12.2e} {sm:>16.6e} {ss:>12.2e}");
    }
    println!(
        "fitted: b_hat = {:.3} (paper ~1.8-2), d_hat = {:.3} (paper ~1)\n",
        fig.b_hat, fig.d_hat
    );

    // Per-level diagnostic timing (the figure's cost driver).
    let backend = NativeBackend::new(cfg.problem);
    let params = init_params(0);
    let src = BrownianSource::new(1);
    let h = Harness::quick();
    for level in [0usize, 3, 6] {
        let dw = src.increments(
            Purpose::Diagnostic,
            0,
            level as u32,
            0,
            backend.diag_chunk(),
            cfg.problem.n_steps(level),
            cfg.problem.dt(level),
        );
        h.run(&format!("figure1/grad_norms_l{level}"), || {
            black_box(backend.grad_norms_chunk(level, &params, &dw).unwrap());
        });
    }
}
