//! Hot-path micro-benchmarks (§Perf): the building blocks a training step
//! is made of, on both backends, so regressions are attributable.
//!
//! * per-level coupled gradient chunk — native engine vs compiled HLO
//! * Brownian batch generation (RNG substrate)
//! * materialized vs streaming simulation (bs-call D=1, heston D=2) —
//!   the streaming refactor's headline; paths/sec per case is written to
//!   `BENCH_scenarios.json` so future PRs have a perf trajectory
//! * estimator assembly + optimizer update (pure L3 overhead)
//! * end-to-end DMLMC step latency distribution across a period
//!
//! `cargo bench --bench hotpath`

use std::sync::Arc;

use dmlmc::bench::{black_box, Harness};
use dmlmc::config::{Backend, ExperimentConfig};
use dmlmc::coordinator::{run_jobs_pool, LevelJobSpec, Method, Trainer};
use dmlmc::engine::milstein::{factor_rows, fold_path, simulate_paths_sde};
use dmlmc::exec::WorkerPool;
use dmlmc::engine::mlp::init_params;
use dmlmc::mlmc::estimator::ChunkAccumulator;
use dmlmc::optim::{Optimizer, Sgd};
use dmlmc::rng::{brownian::Purpose, BrownianSource};
use dmlmc::runtime::{GradBackend, NativeBackend, XlaRuntime};
use dmlmc::scenarios::sde::{BlackScholes, Heston};
use dmlmc::scenarios::{build_scenario, Sde};
use dmlmc::util::json::{obj, Json};

/// One `BENCH_scenarios.json` row: paths/sec for a simulation case.
struct SimCase {
    name: &'static str,
    dim: usize,
    mode: &'static str,
    paths_per_sec: f64,
}

fn paths_per_sec(batch: usize, s: &dmlmc::bench::Summary) -> f64 {
    let rate = batch as f64 / s.median.as_secs_f64();
    // a zero median (coarse timer) must not put `inf` in the artifact
    if rate.is_finite() {
        rate
    } else {
        0.0
    }
}

fn write_scenarios_json(cases: &[SimCase]) {
    let rows: Vec<Json> = cases
        .iter()
        .map(|c| {
            obj(vec![
                ("name", Json::Str(c.name.to_string())),
                ("dim", Json::Num(c.dim as f64)),
                ("mode", Json::Str(c.mode.to_string())),
                ("paths_per_sec", Json::Num(c.paths_per_sec.round())),
            ])
        })
        .collect();
    let doc = obj(vec![
        ("bench", Json::Str("hotpath/simulation".to_string())),
        ("unit", Json::Str("paths_per_sec".to_string())),
        ("cases", Json::Arr(rows)),
    ]);
    let path = "BENCH_scenarios.json";
    // panic (not just log) so a CI write failure fails THIS step, not the
    // later artifact upload with a misleading "no files found"
    std::fs::write(path, format!("{doc}\n"))
        .unwrap_or_else(|e| panic!("could not write {path}: {e}"));
    eprintln!("wrote {path}");
}

fn main() {
    let cfg = ExperimentConfig::default_paper();
    let problem = cfg.problem;
    let params = init_params(0);
    let src = BrownianSource::new(5);
    let h = Harness::quick();

    // ---- RNG substrate ------------------------------------------------
    h.run("rng/brownian_64x256", || {
        black_box(src.increments(Purpose::Grad, 0, 6, 0, 64, 256, problem.dt(6)));
    });

    // ---- materialized vs streaming simulation -------------------------
    // The streaming fold must beat (or at worst match) materialize-then-
    // read: it performs the same arithmetic without the
    // batch x (n_steps + 1) buffer. bs-call is the D=1 fast path; heston
    // exercises the D=2 generic loop.
    let mut sim_cases: Vec<SimCase> = Vec::new();
    {
        let batch = 64;
        let n = problem.n_steps(6);
        let dt = problem.dt(6) as f32;
        let bs = BlackScholes::from_problem(&problem);
        let dw = src.increments(Purpose::Grad, 0, 6, 0, batch, n, problem.dt(6));
        let s_mat = h.run("sim/bs_materialized_64x256", || {
            black_box(simulate_paths_sde(&dw, batch, n, &bs, problem.maturity));
        });
        sim_cases.push(SimCase {
            name: "bs-call",
            dim: 1,
            mode: "materialized",
            paths_per_sec: paths_per_sec(batch, &s_mat),
        });
        let s_str = h.run("sim/bs_streaming_64x256", || {
            let mut acc = 0.0f32;
            for b in 0..batch {
                let rows = factor_rows(&dw, 1, batch, n, b);
                fold_path(&bs, &rows[..1], n, dt, |_, st| acc += st[0]);
            }
            black_box(acc);
        });
        sim_cases.push(SimCase {
            name: "bs-call",
            dim: 1,
            mode: "streaming",
            paths_per_sec: paths_per_sec(batch, &s_str),
        });

        let heston = Heston::from_problem(&problem);
        let dw2 = src.increments_multi(
            Purpose::Grad, 0, 6, 0, batch, n, problem.dt(6), heston.dim(),
        );
        let s_mat2 = h.run("sim/heston_materialized_64x256", || {
            black_box(simulate_paths_sde(&dw2, batch, n, &heston, problem.maturity));
        });
        sim_cases.push(SimCase {
            name: "heston-call",
            dim: 2,
            mode: "materialized",
            paths_per_sec: paths_per_sec(batch, &s_mat2),
        });
        let s_str2 = h.run("sim/heston_streaming_64x256", || {
            let mut acc = 0.0f32;
            for b in 0..batch {
                let rows = factor_rows(&dw2, 2, batch, n, b);
                fold_path(&heston, &rows[..2], n, dt, |_, st| acc += st[0]);
            }
            black_box(acc);
        });
        sim_cases.push(SimCase {
            name: "heston-call",
            dim: 2,
            mode: "streaming",
            paths_per_sec: paths_per_sec(batch, &s_str2),
        });

        // full objective chunk under a 2-factor scenario (dyn dispatch)
        let sc = build_scenario("heston-call", &problem).unwrap();
        let hb = NativeBackend::with_scenario(problem, sc);
        let batch3 = hb.grad_chunk(3);
        let n3 = problem.n_steps(3);
        let dw3 = src.increments_multi(
            Purpose::Grad, 0, 3, 0, batch3, n3, problem.dt(3), 2,
        );
        h.run("native/grad_l3_heston", || {
            black_box(hb.grad_coupled_chunk(3, &params, &dw3).unwrap());
        });
    }

    // ---- pool dispatch (executor overhead per chunk) --------------------
    // One representative MLMC refresh (every level, a few chunks each)
    // through the chunk-sharded pool at P = 1 and P = 4. P = 1 isolates
    // the executor's fixed cost against the sequential engine numbers
    // above; P = 4 shows the realized speedup. samples/sec lands in
    // BENCH_scenarios.json next to the simulation cases.
    {
        let pool_jobs: Vec<LevelJobSpec> = (0..=problem.lmax)
            .map(|level| LevelJobSpec {
                level,
                n_chunks: if level <= 1 { 2 } else { 1 },
            })
            .collect();
        let cases: Vec<(&'static str, usize, Arc<NativeBackend>)> = vec![
            ("bs-call", 1, Arc::new(NativeBackend::new(problem))),
            (
                "heston-call",
                2,
                Arc::new(NativeBackend::with_scenario(
                    problem,
                    build_scenario("heston-call", &problem).unwrap(),
                )),
            ),
        ];
        for (name, dim, backend) in &cases {
            let total_samples: usize = pool_jobs
                .iter()
                .map(|j| j.n_chunks * backend.grad_chunk(j.level))
                .sum();
            for p in [1usize, 4] {
                let mut pool = WorkerPool::new(p);
                let s = h.run(&format!("pool/{name}_p{p}"), || {
                    black_box(
                        run_jobs_pool(backend, &src, 0, &params, &pool_jobs, &mut pool)
                            .unwrap(),
                    );
                });
                sim_cases.push(SimCase {
                    name: *name,
                    dim: *dim,
                    mode: if p == 1 { "pool-p1" } else { "pool-p4" },
                    paths_per_sec: paths_per_sec(total_samples, &s),
                });
            }
        }
    }
    write_scenarios_json(&sim_cases);

    // ---- native engine per level --------------------------------------
    let native = NativeBackend::new(problem);
    for level in [0usize, 3, 6] {
        let batch = native.grad_chunk(level);
        let dw = src.increments(
            Purpose::Grad, 0, level as u32, 0, batch,
            problem.n_steps(level), problem.dt(level),
        );
        h.run(&format!("native/grad_l{level}"), || {
            black_box(native.grad_coupled_chunk(level, &params, &dw).unwrap());
        });
    }

    // ---- XLA runtime per level (if artifacts exist and this build has
    // the real PJRT runtime rather than the stub) ------------------------
    let artifacts = std::path::Path::new("artifacts");
    if cfg!(feature = "xla") && artifacts.join("manifest.json").exists() {
        let rt = XlaRuntime::load(artifacts).expect("artifacts");
        rt.warmup().expect("warmup");
        for level in [0usize, 3, 6] {
            let batch = rt.grad_chunk(level);
            let dw = src.increments(
                Purpose::Grad, 0, level as u32, 0, batch,
                problem.n_steps(level), problem.dt(level),
            );
            h.run(&format!("xla/grad_l{level}"), || {
                black_box(rt.grad_coupled_chunk(level, &params, &dw).unwrap());
            });
        }
        let dw_eval = src.increments(
            Purpose::Eval, 0, 6, 0, rt.eval_chunk(),
            problem.n_steps(6), problem.dt(6),
        );
        h.run("xla/loss_eval_256x256", || {
            black_box(rt.loss_eval_chunk(&params, &dw_eval).unwrap());
        });
    } else {
        eprintln!(
            "artifacts not built or no `xla` feature; skipping xla/* benches"
        );
    }

    // ---- pure L3 overhead ----------------------------------------------
    let grads: Vec<Vec<f32>> = (0..7)
        .map(|l| (0..params.len()).map(|i| ((i + l) % 13) as f32 * 1e-3).collect())
        .collect();
    h.run("l3/assemble_7_levels", || {
        let mut acc = ChunkAccumulator::new(params.len());
        for g in &grads {
            acc.add(0.1, g);
        }
        black_box(acc.finish());
    });
    let mut p = params.clone();
    let mut opt = Sgd::new(0.01);
    h.run("l3/sgd_update_1186", || {
        opt.step(&mut p, &grads[0]);
        black_box(&p);
    });

    // ---- end-to-end step latency over one schedule period ---------------
    let mut cfg_step = cfg.clone();
    cfg_step.runtime.backend = Backend::Native;
    cfg_step.mlmc.n_effective = 128;
    let mut tr = Trainer::from_config(&cfg_step, Method::Dmlmc, 0).unwrap();
    let mut t = 0u64;
    h.run("e2e/dmlmc_step_native", || {
        black_box(tr.step(t).unwrap());
        t += 1;
    });
    let mut tr2 = Trainer::from_config(&cfg_step, Method::Mlmc, 0).unwrap();
    let mut t2 = 0u64;
    h.run("e2e/mlmc_step_native", || {
        black_box(tr2.step(t2).unwrap());
        t2 += 1;
    });
}
