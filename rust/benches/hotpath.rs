//! Hot-path micro-benchmarks (§Perf): the building blocks a training step
//! is made of, on both backends, so regressions are attributable.
//!
//! * per-level coupled gradient chunk — native engine vs compiled HLO
//! * Brownian batch generation (RNG substrate)
//! * estimator assembly + optimizer update (pure L3 overhead)
//! * end-to-end DMLMC step latency distribution across a period
//!
//! `cargo bench --bench hotpath`

use dmlmc::bench::{black_box, Harness};
use dmlmc::config::{Backend, ExperimentConfig};
use dmlmc::coordinator::{Method, Trainer};
use dmlmc::engine::mlp::init_params;
use dmlmc::mlmc::estimator::ChunkAccumulator;
use dmlmc::optim::{Optimizer, Sgd};
use dmlmc::rng::{brownian::Purpose, BrownianSource};
use dmlmc::runtime::{GradBackend, NativeBackend, XlaRuntime};

fn main() {
    let cfg = ExperimentConfig::default_paper();
    let problem = cfg.problem;
    let params = init_params(0);
    let src = BrownianSource::new(5);
    let h = Harness::quick();

    // ---- RNG substrate ------------------------------------------------
    h.run("rng/brownian_64x256", || {
        black_box(src.increments(Purpose::Grad, 0, 6, 0, 64, 256, problem.dt(6)));
    });

    // ---- native engine per level --------------------------------------
    let native = NativeBackend::new(problem);
    for level in [0usize, 3, 6] {
        let batch = native.grad_chunk(level);
        let dw = src.increments(
            Purpose::Grad, 0, level as u32, 0, batch,
            problem.n_steps(level), problem.dt(level),
        );
        h.run(&format!("native/grad_l{level}"), || {
            black_box(native.grad_coupled_chunk(level, &params, &dw).unwrap());
        });
    }

    // ---- XLA runtime per level (if artifacts exist and this build has
    // the real PJRT runtime rather than the stub) ------------------------
    let artifacts = std::path::Path::new("artifacts");
    if cfg!(feature = "xla") && artifacts.join("manifest.json").exists() {
        let rt = XlaRuntime::load(artifacts).expect("artifacts");
        rt.warmup().expect("warmup");
        for level in [0usize, 3, 6] {
            let batch = rt.grad_chunk(level);
            let dw = src.increments(
                Purpose::Grad, 0, level as u32, 0, batch,
                problem.n_steps(level), problem.dt(level),
            );
            h.run(&format!("xla/grad_l{level}"), || {
                black_box(rt.grad_coupled_chunk(level, &params, &dw).unwrap());
            });
        }
        let dw_eval = src.increments(
            Purpose::Eval, 0, 6, 0, rt.eval_chunk(),
            problem.n_steps(6), problem.dt(6),
        );
        h.run("xla/loss_eval_256x256", || {
            black_box(rt.loss_eval_chunk(&params, &dw_eval).unwrap());
        });
    } else {
        eprintln!(
            "artifacts not built or no `xla` feature; skipping xla/* benches"
        );
    }

    // ---- pure L3 overhead ----------------------------------------------
    let grads: Vec<Vec<f32>> = (0..7)
        .map(|l| (0..params.len()).map(|i| ((i + l) % 13) as f32 * 1e-3).collect())
        .collect();
    h.run("l3/assemble_7_levels", || {
        let mut acc = ChunkAccumulator::new(params.len());
        for g in &grads {
            acc.add(0.1, g);
        }
        black_box(acc.finish());
    });
    let mut p = params.clone();
    let mut opt = Sgd::new(0.01);
    h.run("l3/sgd_update_1186", || {
        opt.step(&mut p, &grads[0]);
        black_box(&p);
    });

    // ---- end-to-end step latency over one schedule period ---------------
    let mut cfg_step = cfg.clone();
    cfg_step.runtime.backend = Backend::Native;
    cfg_step.mlmc.n_effective = 128;
    let mut tr = Trainer::from_config(&cfg_step, Method::Dmlmc, 0).unwrap();
    let mut t = 0u64;
    h.run("e2e/dmlmc_step_native", || {
        black_box(tr.step(t).unwrap());
        t += 1;
    });
    let mut tr2 = Trainer::from_config(&cfg_step, Method::Mlmc, 0).unwrap();
    let mut t2 = 0u64;
    h.run("e2e/mlmc_step_native", || {
        black_box(tr2.step(t2).unwrap());
        t2 += 1;
    });
}
