//! Ablation bench: sensitivity of delayed MLMC to the **delay exponent
//! `d`** — the design choice DESIGN.md §3 calls out. Sweeps `d` over the
//! three regimes of the paper's footnote 6 (`c < d`, `c = d`, `c > d`)
//! and reports final loss vs parallel cost, plus the *measured bias* the
//! delay introduces (Lemma 5's quantity): distance of the delayed
//! estimator from a fresh full-MLMC gradient at the same parameters,
//! against the Monte Carlo noise floor.
//!
//! `cargo bench --bench ablation_delay`

use dmlmc::bench::{black_box, Harness};
use dmlmc::config::{Backend, ExperimentConfig};
use dmlmc::coordinator::{Method, Trainer};
use dmlmc::experiments::ExperimentRunner;
use dmlmc::mlmc::estimator::grad_norm;

fn l2_diff(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        .sqrt()
}

fn main() {
    let mut cfg = ExperimentConfig::default_paper();
    cfg.runtime.backend = Backend::Native;
    cfg.train.steps = 48;
    cfg.train.eval_every = 48;
    cfg.mlmc.n_effective = 128;
    cfg.train.dmlmc_warmup = 0; // pure-schedule ablation

    println!("\n=== ABLATION: delay exponent d (c = {}) ===", cfg.mlmc.c);
    let ds = [0.0, 0.5, 1.0, 1.5, 2.0];
    let rows = ExperimentRunner::new(&cfg)
        .quiet(true)
        .sweep_delay(&ds)
        .expect("sweep");
    println!(
        "{:<6} {:>12} {:>14} {:>14} {:>12} {:>10}",
        "d", "final loss", "std cost", "par cost", "avg depth", "regime"
    );
    for (d, r) in &rows {
        let regime = if *d < cfg.mlmc.c {
            "c > d"
        } else if (*d - cfg.mlmc.c).abs() < 1e-9 {
            "c = d"
        } else {
            "c < d"
        };
        println!(
            "{d:<6} {:>12.5} {:>14.0} {:>14.0} {:>12.2} {:>10}",
            r.final_loss, r.std_cost, r.par_cost, r.avg_depth, regime
        );
    }

    // Bias probe (Lemma 5, measured): after 17 steps, compare the cached
    // delayed estimator with a fresh full-MLMC gradient at the same
    // parameters; report next to the MC noise floor (distance between two
    // independent fresh estimates at the same parameters).
    println!("\n=== delayed-estimator bias vs MC noise floor (17 steps in) ===");
    println!("{:<6} {:>18} {:>18} {:>10}", "d", "||delayed-fresh||", "noise floor", "ratio");
    for d in [0.5, 1.0, 2.0] {
        let mut c = cfg.clone();
        c.mlmc.d = d;
        let mut tr = Trainer::from_config(&c, Method::Dmlmc, 0).unwrap();
        for t in 0..17u64 {
            tr.step(t).unwrap();
        }
        let (_, delayed) = tr.assembled_gradient();
        let (_, fresh_a) = tr.fresh_mlmc_gradient(900).unwrap();
        let (_, fresh_b) = tr.fresh_mlmc_gradient(901).unwrap();
        let bias = l2_diff(&delayed, &fresh_a) / grad_norm(&fresh_a).max(1e-12);
        let floor = l2_diff(&fresh_a, &fresh_b) / grad_norm(&fresh_a).max(1e-12);
        println!("{d:<6} {bias:>18.4} {floor:>18.4} {:>10.2}", bias / floor.max(1e-12));
    }
    println!("(ratio ~1 means the delay bias is hidden inside Monte Carlo noise)");

    // Wall-clock: average step latency per d.
    let h = Harness::quick();
    for d in [0.5, 1.0, 2.0] {
        let mut c = cfg.clone();
        c.mlmc.d = d;
        let mut tr = Trainer::from_config(&c, Method::Dmlmc, 0).unwrap();
        let mut t = 0u64;
        h.run(&format!("ablation/step_d{d}"), || {
            black_box(tr.step(t).unwrap());
            t += 1;
        });
    }
}
