//! Bench target for **Figure 2**: regenerates the 3-method learning-curve
//! comparison (loss vs standard and parallel complexity, mean ± std over
//! seeds) on a small budget, and asserts/prints the ordering the paper
//! claims: DMLMC ≫ MLMC ≈ naive in parallel complexity, DMLMC ≲ MLMC ≪
//! naive in standard complexity.
//!
//! `cargo bench --bench figure2`

use dmlmc::bench::{black_box, Harness};
use dmlmc::config::{Backend, ExperimentConfig};
use dmlmc::coordinator::{Method, Trainer};
use dmlmc::experiments::ExperimentRunner;

fn main() {
    let mut cfg = ExperimentConfig::default_paper();
    cfg.runtime.backend = Backend::Native;
    cfg.train.steps = 60;
    cfg.train.eval_every = 10;
    cfg.train.n_seeds = 3;
    cfg.mlmc.n_effective = 128;
    cfg.train.dmlmc_warmup = 0; // bench the pure schedule, not stability aids

    let results = ExperimentRunner::new(&cfg)
        .quiet(true)
        .figure2()
        .expect("figure2");
    for axis in ["standard", "parallel"] {
        println!("\n=== FIGURE 2 ({axis} complexity as x-axis) ===");
        println!(
            "{:<8} {:>10} {:>16} {:>12} {:>10}",
            "method", "step", "cum. cost", "loss mean", "loss std"
        );
        for (method, _, agg) in &results {
            let n = agg.steps.len();
            for i in [0, n / 2, n - 1] {
                let cost = if axis == "standard" {
                    agg.std_cost[i]
                } else {
                    agg.par_cost[i]
                };
                println!(
                    "{:<8} {:>10} {:>16.0} {:>12.5} {:>10.5}",
                    method.name(),
                    agg.steps[i],
                    cost,
                    agg.loss_mean[i],
                    agg.loss_std[i]
                );
            }
        }
    }
    let total = |m: Method, par: bool| {
        results
            .iter()
            .find(|(mm, _, _)| *mm == m)
            .map(|(_, _, a)| {
                if par {
                    *a.par_cost.last().unwrap()
                } else {
                    *a.std_cost.last().unwrap()
                }
            })
            .unwrap()
    };
    println!(
        "\nparallel-cost ratio  mlmc/dmlmc = {:.1}x   naive/dmlmc = {:.1}x",
        total(Method::Mlmc, true) / total(Method::Dmlmc, true),
        total(Method::Naive, true) / total(Method::Dmlmc, true)
    );
    println!(
        "standard-cost ratio  naive/mlmc = {:.1}x   mlmc/dmlmc = {:.2}x\n",
        total(Method::Naive, false) / total(Method::Mlmc, false),
        total(Method::Mlmc, false) / total(Method::Dmlmc, false)
    );

    // Wall-clock of one full DMLMC learning-curve run (the figure's unit).
    let h = Harness::quick();
    let mut small = cfg.clone();
    small.train.steps = 16;
    small.train.n_seeds = 1;
    h.run("figure2/dmlmc_run16", || {
        let mut tr = Trainer::from_config(&small, Method::Dmlmc, 0).unwrap();
        black_box(tr.run().unwrap());
    });
}
