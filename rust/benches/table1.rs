//! Bench target for **Table 1**: regenerates the theory-vs-measured
//! complexity table end to end and times each method's full training run
//! on the same budget, so rows are directly comparable run-to-run.
//!
//! `cargo bench --bench table1`

use dmlmc::bench::{black_box, Harness};
use dmlmc::config::{Backend, ExperimentConfig};
use dmlmc::coordinator::{Method, Trainer};
use dmlmc::experiments::ExperimentRunner;

fn cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_paper();
    cfg.runtime.backend = Backend::Native;
    cfg.train.steps = 32;
    cfg.train.eval_every = 32;
    cfg.mlmc.n_effective = 128;
    cfg.train.dmlmc_warmup = 0; // bench the pure schedule, not stability aids
    cfg
}

fn main() {
    let cfg = cfg();

    // The table itself (the regeneration artifact).
    let runner = ExperimentRunner::new(&cfg).quiet(true);
    let (theory, measured) = runner.table1().expect("table1");
    println!(
        "\n=== TABLE 1 (theory vs measured, T = {}, N = {}) ===",
        cfg.train.steps, cfg.mlmc.n_effective
    );
    println!("{}", ExperimentRunner::render_table1(&theory, &measured));
    println!(
        "dmlmc avg per-step depth: measured {:.2} | schedule {:.2} | theory Σ2^((c-d)l) = {:.2}\n",
        measured[2].avg_depth,
        runner.predicted_avg_depth(1 << 14),
        dmlmc::mlmc::theory::geom_sum(cfg.mlmc.c - cfg.mlmc.d, cfg.problem.lmax),
    );

    // Wall-clock per full training run, per method.
    let h = Harness::quick();
    for method in Method::all() {
        let mut run_cfg = cfg.clone();
        run_cfg.train.steps = 8;
        h.run(&format!("table1/train8_{}", method.name()), || {
            let mut tr = Trainer::from_config(&run_cfg, method, 0).unwrap();
            for t in 0..run_cfg.train.steps as u64 {
                black_box(tr.step(t).unwrap());
            }
        });
    }
}
