"""L2 — the deep-hedging compute graph in JAX, calling the L1 kernels.

Paper objective (Appendix C, Buehler et al. 2019 eq. 3.3):

    min_{theta, p0}  E | max(S_1 - K, 0) - sum_n H_theta(t_n, S_n) dS_n - p0 |^2

All functions here take the trainable state as ONE flat ``f32[n_params]``
vector (weights + biases + p0, layout in ``problem.MlpArch.sizes``) so the
Rust runtime only ever moves a single parameter buffer.

Entry points lowered by ``aot.py`` (all pure, jit-able, fixed shapes):

    grad_coupled(level)   value-and-grad of the mean coupled objective
                          Delta_l F = F_l - F_{l-1} — the MLMC/DMLMC unit
                          of work at level l.
    grad_naive            value-and-grad of F_{lmax} — the naive baseline.
    loss_eval             F_{lmax} on a held-out batch — learning curves.
    grad_norms(level)     per-sample ||grad Delta_l F_hat||^2 (Figure 1 left).
    smoothness(level)     pathwise ||g(x2,xi)-g(x1,xi)||/||x2-x1|| (Fig 1 right).
    path_eval(level)      fine+coarse terminal values (engine cross-checks).

The hot path (grad_coupled / grad_naive / loss_eval) runs through the
Pallas kernels; the per-sample diagnostics (vmap-of-grad, off the hot
path, Figure 1 only) use the pure-jnp reference graph — numerically
identical (tested) and robust under vmap-of-custom_vjp.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.milstein import coupled_milstein_paths, milstein_paths
from .kernels.mlp import hedge_mlp
from .problem import DEFAULT_ARCH, HedgingProblem, MlpArch


# ---------------------------------------------------------------------------
# objective on one grid (Pallas path)
# ---------------------------------------------------------------------------


def _residual_from_path(
    flat_params: jax.Array,
    s: jax.Array,
    problem: HedgingProblem,
    arch: MlpArch,
) -> jax.Array:
    """Hedging residual given a simulated path s[B, n+1]. Differentiable in
    ``flat_params`` only — the path is exogenous (no grad flows into S)."""
    p = ref.unflatten_params(flat_params, arch)
    batch, n_plus_1 = s.shape
    n = n_plus_1 - 1
    s = jax.lax.stop_gradient(s)
    t_grid = jnp.arange(n, dtype=s.dtype) * (problem.maturity / n)
    feats = jnp.stack(
        [jnp.broadcast_to(t_grid, (batch, n)), s[:, :-1]], axis=-1
    ).reshape(batch * n, 2)
    h = hedge_mlp(
        feats, p["w1"], p["b1"], p["w2"], p["b2"], p["w3"], p["b3"]
    ).reshape(batch, n)
    gains = jnp.sum(h * (s[:, 1:] - s[:, :-1]), axis=-1)
    payoff = jnp.maximum(s[:, -1] - problem.strike, 0.0)
    return payoff - gains - p["p0"][0]


def coupled_loss(
    flat_params: jax.Array,
    dw_fine: jax.Array,
    problem: HedgingProblem,
    arch: MlpArch,
    level: int,
) -> jax.Array:
    """Mean coupled objective Delta_l F (Pallas kernels on the hot path)."""
    s_fine, s_coarse = coupled_milstein_paths(dw_fine, problem, level)
    r_f = _residual_from_path(flat_params, s_fine, problem, arch)
    loss = jnp.mean(r_f * r_f)
    if s_coarse is not None:
        r_c = _residual_from_path(flat_params, s_coarse, problem, arch)
        loss = loss - jnp.mean(r_c * r_c)
    return loss


def naive_loss(
    flat_params: jax.Array,
    dw: jax.Array,
    problem: HedgingProblem,
    arch: MlpArch,
) -> jax.Array:
    """Mean objective on the grid implied by ``dw.shape[1]`` (naive unit)."""
    s = milstein_paths(dw, problem, dw.shape[1])
    r = _residual_from_path(flat_params, s, problem, arch)
    return jnp.mean(r * r)


# ---------------------------------------------------------------------------
# AOT entry points
# ---------------------------------------------------------------------------


def make_grad_coupled(problem: HedgingProblem, arch: MlpArch, level: int):
    """(params, dw[B, n_l]) -> (loss_delta, grad[n_params])."""

    def fn(params, dw):
        loss, grad = jax.value_and_grad(coupled_loss)(
            params, dw, problem, arch, level
        )
        return loss, grad

    return fn


def make_grad_naive(problem: HedgingProblem, arch: MlpArch):
    """(params, dw[B, n_lmax]) -> (loss, grad[n_params])."""

    def fn(params, dw):
        loss, grad = jax.value_and_grad(naive_loss)(params, dw, problem, arch)
        return loss, grad

    return fn


def make_loss_eval(problem: HedgingProblem, arch: MlpArch):
    """(params, dw[B, n_lmax]) -> loss (held-out learning-curve metric)."""

    def fn(params, dw):
        return (naive_loss(params, dw, problem, arch),)

    return fn


def make_grad_norms(problem: HedgingProblem, arch: MlpArch, level: int):
    """(params, dw[B, n_l]) -> per-sample ||grad Delta_l F_hat(x, xi_i)||^2.

    Figure 1 (left): the per-sample squared gradient norm upper-bounds the
    level variance. Uses the reference graph (off the hot path).
    """

    def per_sample(params, dw_row):
        return ref.coupled_loss_ref(params, dw_row[None, :], problem, arch, level)

    def fn(params, dw):
        grads = jax.vmap(jax.grad(per_sample), in_axes=(None, 0))(params, dw)
        return (jnp.sum(grads * grads, axis=-1),)

    return fn


def make_smoothness(problem: HedgingProblem, arch: MlpArch, level: int):
    """(params1, params2, dw[B, n_l]) -> per-sample pathwise smoothness.

    Figure 1 (right):  ||g(x2, xi) - g(x1, xi)|| / ||x2 - x1||  per sample,
    the L1-norm proxy for the level-l Lipschitz constant 2^{-dl} L.
    """

    def per_sample(params, dw_row):
        return ref.coupled_loss_ref(params, dw_row[None, :], problem, arch, level)

    def fn(params1, params2, dw):
        g1 = jax.vmap(jax.grad(per_sample), in_axes=(None, 0))(params1, dw)
        g2 = jax.vmap(jax.grad(per_sample), in_axes=(None, 0))(params2, dw)
        num = jnp.sqrt(jnp.sum((g2 - g1) ** 2, axis=-1))
        den = jnp.sqrt(jnp.sum((params2 - params1) ** 2))
        return (num / jnp.maximum(den, 1e-12),)

    return fn


def make_path_eval(problem: HedgingProblem, level: int):
    """(dw[B, n_l]) -> (fine terminal S, coarse terminal S).

    Cross-check artifact: the Rust native engine must reproduce these
    exactly (same scheme, same increments).
    """

    def fn(dw):
        s_fine, s_coarse = coupled_milstein_paths(dw, problem, level)
        if s_coarse is None:
            s_coarse = s_fine
        return s_fine[:, -1], s_coarse[:, -1]

    return fn


# ---------------------------------------------------------------------------
# parameter initialisation (Rust re-implements the same layout and reads the
# init vector from the manifest side-file, so both sides start identically)
# ---------------------------------------------------------------------------


def init_params(seed: int, arch: MlpArch = DEFAULT_ARCH) -> jax.Array:
    """He-style init, deterministic in ``seed``; biases and p0 start at 0."""
    key = jax.random.PRNGKey(seed)
    parts = []
    for name, shape in arch.sizes:
        key, sub = jax.random.split(key)
        if name.startswith("w"):
            fan_in = shape[0]
            parts.append(
                jax.random.normal(sub, shape, jnp.float32).reshape(-1)
                * jnp.sqrt(2.0 / fan_in)
            )
        else:
            parts.append(jnp.zeros(shape, jnp.float32).reshape(-1))
    return jnp.concatenate(parts)
