"""Pure-jnp reference oracles for the Pallas kernels and the objective.

Everything here is straight-line ``jax.numpy`` with no Pallas, no custom
VJPs and no cleverness — the correctness ground truth that pytest (and
hypothesis) compares the kernels against, and that the diagnostics
artifacts (Figure 1) are lowered from.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..problem import HedgingProblem, MlpArch


# ---------------------------------------------------------------------------
# Milstein path simulation
# ---------------------------------------------------------------------------


def milstein_path_ref(
    dw: jax.Array, problem: HedgingProblem, n_steps: int
) -> jax.Array:
    """Simulate S on the grid with ``n_steps`` steps from increments ``dw``.

    ``dw``: f32[batch, n_steps] Brownian increments for this grid.
    Returns f32[batch, n_steps + 1] including S_0.

    Milstein scheme for dS = a(S) dt + b(S) dB with b(S) = sigma * S:
        S+ = S + a(S) dt + sigma S dW + 1/2 sigma^2 S (dW^2 - dt)
    with a(S) = mu (additive drift, the paper's Appendix-C SDE) or
    a(S) = mu * S (geometric).
    """
    if dw.shape[-1] != n_steps:
        raise ValueError(f"dw has {dw.shape[-1]} steps, expected {n_steps}")
    dt = problem.maturity / n_steps
    mu, sigma = problem.mu, problem.sigma
    geometric = problem.drift == "geometric"

    def step(s, dw_t):
        drift = mu * s if geometric else mu
        s_next = (
            s
            + drift * dt
            + sigma * s * dw_t
            + 0.5 * sigma * sigma * s * (dw_t * dw_t - dt)
        )
        return s_next, s_next

    s0 = jnp.full(dw.shape[:-1], problem.s0, dtype=dw.dtype)
    _, path = jax.lax.scan(step, s0, jnp.moveaxis(dw, -1, 0))
    return jnp.concatenate([s0[None, ...], path], axis=0).swapaxes(0, 1)


def coarsen_increments(dw_fine: jax.Array) -> jax.Array:
    """Pairwise-sum fine increments onto the next-coarser grid.

    This is the MLMC coupling: both levels see the *same* Brownian path.
    f32[batch, 2n] -> f32[batch, n].
    """
    b, n = dw_fine.shape
    if n % 2 != 0:
        raise ValueError(f"fine grid must have even #steps, got {n}")
    return dw_fine.reshape(b, n // 2, 2).sum(axis=-1)


# ---------------------------------------------------------------------------
# Hedging MLP
# ---------------------------------------------------------------------------


def unflatten_params(flat: jax.Array, arch: MlpArch) -> dict[str, jax.Array]:
    """Split the flat f32[n_params] vector into named weight arrays."""
    out: dict[str, jax.Array] = {}
    off = 0
    for name, shape in arch.sizes:
        n = 1
        for s in shape:
            n *= s
        out[name] = flat[off : off + n].reshape(shape)
        off += n
    if off != flat.shape[0]:
        raise ValueError(f"param vector has {flat.shape[0]} entries, need {off}")
    return out


def flatten_params(params: dict[str, jax.Array], arch: MlpArch) -> jax.Array:
    return jnp.concatenate([params[name].reshape(-1) for name, _ in arch.sizes])


def silu(x: jax.Array) -> jax.Array:
    return x * jax.nn.sigmoid(x)


def mlp_ref(params: dict[str, jax.Array], x: jax.Array) -> jax.Array:
    """Hedging strategy H_theta over feature rows x: f32[rows, 2] -> f32[rows]."""
    h1 = silu(x @ params["w1"] + params["b1"])
    h2 = silu(h1 @ params["w2"] + params["b2"])
    out = jax.nn.sigmoid(h2 @ params["w3"] + params["b3"])
    return out[:, 0]


# ---------------------------------------------------------------------------
# Deep-hedging objective
# ---------------------------------------------------------------------------


def hedging_residual_ref(
    flat_params: jax.Array,
    dw: jax.Array,
    problem: HedgingProblem,
    arch: MlpArch,
    n_steps: int,
) -> jax.Array:
    """Per-sample hedging residual  payoff - sum_n H(t_n, S_n) dS_n - p0.

    Returns f32[batch].
    """
    params = unflatten_params(flat_params, arch)
    s = milstein_path_ref(dw, problem, n_steps)  # [B, n+1]
    batch = s.shape[0]
    t_grid = (
        jnp.arange(n_steps, dtype=s.dtype) * (problem.maturity / n_steps)
    )  # t_0 .. t_{n-1}
    feats = jnp.stack(
        [jnp.broadcast_to(t_grid, (batch, n_steps)), s[:, :-1]], axis=-1
    ).reshape(batch * n_steps, 2)
    h = mlp_ref(params, feats).reshape(batch, n_steps)
    gains = jnp.sum(h * (s[:, 1:] - s[:, :-1]), axis=-1)
    payoff = jnp.maximum(s[:, -1] - problem.strike, 0.0)
    return payoff - gains - params["p0"][0]


def hedging_loss_ref(
    flat_params: jax.Array,
    dw: jax.Array,
    problem: HedgingProblem,
    arch: MlpArch,
    n_steps: int,
) -> jax.Array:
    """Mean squared hedging residual at one discretisation level."""
    r = hedging_residual_ref(flat_params, dw, problem, arch, n_steps)
    return jnp.mean(r * r)


def coupled_loss_ref(
    flat_params: jax.Array,
    dw_fine: jax.Array,
    problem: HedgingProblem,
    arch: MlpArch,
    level: int,
) -> jax.Array:
    """Mean coupled objective Delta_l F = F_l - F_{l-1} (F_{-1} := 0).

    ``dw_fine`` lives on the level-``level`` grid; the coarse half uses the
    pairwise-summed increments of the *same* Brownian path.
    """
    n_fine = problem.n_steps(level)
    fine = hedging_loss_ref(flat_params, dw_fine, problem, arch, n_fine)
    if level == 0:
        return fine
    coarse = hedging_loss_ref(
        flat_params, coarsen_increments(dw_fine), problem, arch, n_fine // 2
    )
    return fine - coarse
